#!/usr/bin/env bash
# Perf-trajectory snapshot: run the simulation micro benches and the DSE
# smoke sweep, collecting medians into BENCH_sim.json at the repo root
# (bench name -> median ns, runs, cycles/sec throughput).  Future PRs diff
# this file against the committed copy to track the hot-path trajectory.
#
# Usage: scripts/perf_trajectory.sh [output.json]
# Env:   ACADL_BENCH_RUNS  samples per bench (default 7)
set -euo pipefail
cd "$(dirname "$0")/.."

OUT="${1:-BENCH_sim.json}"
rm -f "$OUT"
export ACADL_BENCH_JSON="$OUT"
export ACADL_BENCH_RUNS="${ACADL_BENCH_RUNS:-7}"

# The engine hot-path micro benches (cycles/sec across the model zoo) and
# the backend comparison (cycle-stepped vs event-driven wall-clock).
cargo bench --bench sim_micro
cargo bench --bench backend_compare

# DSE engine benches: pruned-vs-exhaustive on the quick space, plus
# streamed-vs-materialized over a 10 200-candidate `param` space
# (candidates/sec and peak-RSS rows behind the bounded-memory claim).
cargo bench --bench dse

# Platform parallel speedup: the 4-chip sharded transformer at 1/2/4
# simulation threads — identical cycle counts, wall-clock scaling.
cargo bench --bench platform

# DSE smoke sweep wall-clock: the end-to-end number every hot-path win
# multiplies into.
start_ns=$(date +%s%N)
cargo run --release --quiet -- dse --quick true --dim 8 --workers 2 > /dev/null
end_ns=$(date +%s%N)

# Transformer workload wall-clock: map + cycle-accurate simulation of
# tiny_transformer on the systolic array (the attention data path).
tf_start_ns=$(date +%s%N)
cargo run --release --quiet -- simulate --target systolic --rows 2 --cols 2 \
  --workload transformer --seq 8 --backend event > /dev/null
tf_end_ns=$(date +%s%N)

# KV-cached serving: wall-clock of a prefill-only pass over the 2-layer
# 2-head model, then a 4-token decode run whose result row reports the
# prefill/decode phase split and cycles-per-decoded-token (the serving
# latency headline).
pf_start_ns=$(date +%s%N)
cargo run --release --quiet -- simulate --target systolic --rows 2 --cols 2 \
  --workload transformer --seq 8 --layers 2 --heads 2 --backend event > /dev/null
pf_end_ns=$(date +%s%N)
serve_row=$(cargo run --release --quiet -- simulate --target systolic --rows 2 --cols 2 \
  --workload transformer --seq 8 --layers 2 --heads 2 --decode-steps 4 \
  --backend event)

# Platform wall-clock at 1 vs 4 threads (same job, same cycle count —
# the parallel-speedup row the PR-7 acceptance gate reads).
p1_start_ns=$(date +%s%N)
cargo run --release --quiet -- simulate --target systolic --rows 2 --cols 2 \
  --workload transformer --seq 8 --backend parallel \
  --platform 4 --microbatches 8 --threads 1 > /dev/null
p1_end_ns=$(date +%s%N)
p4_start_ns=$(date +%s%N)
cargo run --release --quiet -- simulate --target systolic --rows 2 --cols 2 \
  --workload transformer --seq 8 --backend parallel \
  --platform 4 --microbatches 8 --threads 4 > /dev/null
p4_end_ns=$(date +%s%N)

SERVE_ROW="$serve_row" python3 - "$OUT" $((end_ns - start_ns)) \
  $((tf_end_ns - tf_start_ns)) $((p1_end_ns - p1_start_ns)) \
  $((p4_end_ns - p4_start_ns)) $((pf_end_ns - pf_start_ns)) <<'EOF'
import json, os, sys

path = sys.argv[1]
ns, tf_ns, p1_ns, p4_ns, pf_ns = map(int, sys.argv[2:7])
data = json.load(open(path)) if os.path.exists(path) else {}
data["dse/smoke_sweep_wall"] = {"median_ns": ns, "runs": 1}
data["transformer/systolic_2x2_seq8_wall"] = {"median_ns": tf_ns, "runs": 1}
data["platform/quad_tf_seq8_wall_threads1"] = {"median_ns": p1_ns, "runs": 1}
data["platform/quad_tf_seq8_wall_threads4"] = {"median_ns": p4_ns, "runs": 1}
data["platform/speedup_4t"] = {"ratio": round(p1_ns / max(p4_ns, 1), 3), "runs": 1}

# Serving rows: the prefill-only wall clock, and the decode run's own
# simulated phase metrics (from its result row, not re-derived here).
serve = json.loads(os.environ["SERVE_ROW"])
assert serve.get("numerics_ok") is True, serve
assert serve.get("prefill_cycles") and serve.get("cycles_per_token"), serve
data["transformer/prefill_wall"] = {"median_ns": pf_ns, "runs": 1}
data["transformer/decode_per_token"] = {
    "cycles_per_token": serve["cycles_per_token"],
    "prefill_cycles": serve["prefill_cycles"],
    "runs": 1,
}

# The committed BENCH_sim.json is a null-valued schema; a run of this
# script must replace every null with a measurement.  Fail loudly when a
# row stayed null or a load-bearing row is missing entirely (a renamed
# bench would otherwise silently drop out of the trajectory).
nulls = sorted(k for k, v in data.items() if v is None)
assert not nulls, f"benches left rows unpopulated: {nulls}"
required = [
    "backend_compare/oma_dram_gemm8/cycle (cycles/s)",
    "supervisor/no_token (cycles/s)",
    "trace/off (cycles/s)",
    "trace/on (cycles/s)",
    "platform/speedup_4t",
    "transformer/prefill_wall",
    "transformer/decode_per_token",
]
missing = [k for k in required if k not in data]
assert not missing, f"expected trajectory rows missing: {missing}"

with open(path, "w") as f:
    json.dump(data, f, indent=2, sort_keys=True)
    f.write("\n")
print(f"wrote {path} ({len(data)} entries, all populated)")
EOF
