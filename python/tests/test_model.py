"""L2 model tests: MLP forward vs oracle, AOT registry shape checks."""

import numpy as np
import pytest
import jax
import jax.numpy as jnp

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def _mlp_args(seed=0):
    rng = np.random.default_rng(seed)
    args = []
    for spec in model.mlp_shapes():
        args.append(jnp.asarray(rng.standard_normal(spec.shape) * 0.1, jnp.float32))
    return args


def test_mlp_forward_matches_ref():
    args = _mlp_args()
    x, w0, b0, w1, b1, w2, b2 = args
    (got,) = model.mlp_forward(*args)
    want = ref.mlp_forward(x, [(w0, b0), (w1, b1), (w2, b2)])
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_mlp_output_shape():
    (got,) = model.mlp_forward(*_mlp_args(1))
    assert got.shape == (model.MLP_BATCH, model.MLP_LAYERS[-1][1])


def test_gemm_8x8_entry():
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    y = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    (got,) = model.gemm_8x8(x, y)
    np.testing.assert_allclose(got, ref.gemm(x, y), rtol=1e-5, atol=1e-5)
    (got_r,) = model.gemm_relu_8x8(x, y)
    np.testing.assert_allclose(got_r, ref.gemm_relu(x, y), rtol=1e-5, atol=1e-5)


def test_registry_is_lowerable():
    """Every artifact entry must trace + eval_shape without error."""
    from compile.aot import artifact_registry

    for name, (fn, specs) in artifact_registry().items():
        outs = jax.eval_shape(fn, *specs)
        assert len(outs) >= 1, name
        for o in outs:
            assert all(d > 0 for d in o.shape), (name, o.shape)


@pytest.mark.slow
def test_aot_lowering_roundtrip(tmp_path):
    """Full lowering of the smallest artifact produces parseable HLO text."""
    from compile.aot import to_hlo_text

    lowered = jax.jit(model.gemm_8x8).lower(
        jax.ShapeDtypeStruct((8, 8), jnp.float32),
        jax.ShapeDtypeStruct((8, 8), jnp.float32),
    )
    text = to_hlo_text(lowered)
    assert "HloModule" in text
    assert "f32[8,8]" in text
