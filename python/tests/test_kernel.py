"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes/dtypes/tilings; every case asserts allclose against
``kernels.ref``.  These tests run at build time (``make test``); the same
numerics are re-checked from Rust in E9 via the AOT artifacts.
"""

import numpy as np
import pytest
import jax
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.gemm import (
    default_tiling,
    mxu_utilization_estimate,
    pallas_gemm,
    pallas_gemm_relu,
    vmem_footprint_bytes,
)

jax.config.update("jax_platform_name", "cpu")


def _rand(shape, dtype, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.standard_normal(shape), dtype=dtype)


# ---------------------------------------------------------------- fixed cases


@pytest.mark.parametrize("m,k,n", [(8, 8, 8), (16, 8, 24), (32, 64, 16)])
def test_gemm_matches_ref(m, k, n):
    x, y = _rand((m, k), jnp.float32, 0), _rand((k, n), jnp.float32, 1)
    np.testing.assert_allclose(
        pallas_gemm(x, y), ref.gemm(x, y), rtol=1e-5, atol=1e-5
    )


@pytest.mark.parametrize("m,k,n", [(8, 8, 8), (16, 32, 8)])
def test_gemm_relu_matches_ref(m, k, n):
    x, y = _rand((m, k), jnp.float32, 2), _rand((k, n), jnp.float32, 3)
    out = pallas_gemm_relu(x, y)
    np.testing.assert_allclose(out, ref.gemm_relu(x, y), rtol=1e-5, atol=1e-5)
    assert (np.asarray(out) >= 0).all(), "ReLU output must be non-negative"


def test_gemm_relu_actually_clamps():
    # Force negatives: X @ (-I) = -X.
    x = _rand((8, 8), jnp.float32, 4)
    y = -jnp.eye(8, dtype=jnp.float32)
    out = np.asarray(pallas_gemm_relu(x, y))
    expect = np.maximum(-np.asarray(x), 0.0)
    np.testing.assert_allclose(out, expect, rtol=1e-6, atol=1e-6)


def test_explicit_tiling_equivalence():
    """Different legal tilings must not change the result."""
    x, y = _rand((32, 32), jnp.float32, 5), _rand((32, 32), jnp.float32, 6)
    base = np.asarray(pallas_gemm(x, y, tiling=(32, 32, 32)))
    for tiling in [(8, 8, 8), (16, 32, 16), (32, 8, 32), (8, 32, 8)]:
        np.testing.assert_allclose(
            np.asarray(pallas_gemm(x, y, tiling=tiling)),
            base,
            rtol=1e-5,
            atol=1e-5,
            err_msg=f"tiling={tiling}",
        )


def test_bad_tiling_rejected():
    x, y = _rand((8, 8), jnp.float32, 7), _rand((8, 8), jnp.float32, 8)
    with pytest.raises(ValueError, match="divide"):
        pallas_gemm(x, y, tiling=(3, 8, 8))


def test_shape_mismatch_rejected():
    x, y = _rand((8, 8), jnp.float32, 9), _rand((16, 8), jnp.float32, 10)
    with pytest.raises(ValueError, match="mismatch"):
        pallas_gemm(x, y)


# ------------------------------------------------------------ hypothesis sweep

_dims = st.sampled_from([8, 16, 24, 32, 40, 64])


@settings(max_examples=25, deadline=None)
@given(m=_dims, k=_dims, n=_dims, seed=st.integers(0, 2**16), relu=st.booleans())
def test_gemm_hypothesis_shapes(m, k, n, seed, relu):
    x, y = _rand((m, k), jnp.float32, seed), _rand((k, n), jnp.float32, seed + 1)
    fn = pallas_gemm_relu if relu else pallas_gemm
    oracle = ref.gemm_relu if relu else ref.gemm
    np.testing.assert_allclose(fn(x, y), oracle(x, y), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([8, 16, 32]),
    k=st.sampled_from([8, 16, 32]),
    n=st.sampled_from([8, 16, 32]),
    dtype=st.sampled_from(["float32", "bfloat16"]),
    seed=st.integers(0, 2**16),
)
def test_gemm_hypothesis_dtypes(m, k, n, dtype, seed):
    dt = jnp.dtype(dtype)
    x, y = _rand((m, k), dt, seed), _rand((k, n), dt, seed + 1)
    out = pallas_gemm(x, y)
    expect = ref.gemm(x, y)
    assert out.dtype == expect.dtype
    tol = 1e-4 if dtype == "float32" else 5e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(expect, np.float32),
        rtol=tol,
        atol=tol,
    )


# ----------------------------------------------------------------- utilities


def test_default_tiling_divides():
    for m, k, n in [(8, 8, 8), (128, 256, 64), (784, 784, 784), (40, 24, 8)]:
        tm, tk, tn = default_tiling(m, k, n)
        assert m % tm == 0 and k % tk == 0 and n % tn == 0


def test_vmem_footprint_monotone_and_sane():
    small = vmem_footprint_bytes((8, 8, 8))
    big = vmem_footprint_bytes((128, 128, 128))
    assert small < big
    # The MXU-aligned block set must fit comfortably in 16 MiB VMEM.
    assert big < 16 * 1024 * 1024


def test_mxu_utilization_bounds():
    assert mxu_utilization_estimate((128, 128, 128)) == 1.0
    assert 0 < mxu_utilization_estimate((8, 8, 8)) < 0.01
