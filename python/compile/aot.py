"""AOT lowering: JAX/Pallas golden models → HLO text artifacts.

Run once at build time (``make artifacts``)::

    cd python && python -m compile.aot --out-dir ../artifacts

Interchange format is **HLO text**, not a serialized HloModuleProto: jax
≥ 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly.  Lowered with ``return_tuple=True``
so the Rust side unwraps with ``to_tuple1()``.

Alongside each ``<name>.hlo.txt`` we write a ``manifest.json`` describing
argument/result shapes so the Rust runtime can allocate literals without
parsing HLO.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def artifact_registry():
    """name → (fn, [arg ShapeDtypeStructs]).  One HLO artifact per entry."""
    t = model.GAMMA_TILE
    return {
        "gemm_8x8": (model.gemm_8x8, [_spec((t, t)), _spec((t, t))]),
        "gemm_relu_8x8": (model.gemm_relu_8x8, [_spec((t, t)), _spec((t, t))]),
        "gemm_tiled_128": (
            model.gemm_tiled_128,
            [_spec((128, 128)), _spec((128, 128))],
        ),
        "mlp_forward": (model.mlp_forward, model.mlp_shapes()),
    }


def lower_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {}
    for name, (fn, specs) in artifact_registry().items():
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        out_shapes = [
            {"shape": list(s.shape), "dtype": str(s.dtype)}
            for s in jax.eval_shape(fn, *specs)
        ]
        manifest[name] = {
            "file": f"{name}.hlo.txt",
            "args": [
                {"shape": list(s.shape), "dtype": str(s.dtype)} for s in specs
            ],
            "results": out_shapes,
        }
        print(f"  {name}: {len(text)} chars, args={len(specs)}")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    return manifest


def main():
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    # Back-compat single-file flag (Makefile stamp target).
    parser.add_argument("--out", default=None, help=argparse.SUPPRESS)
    args = parser.parse_args()
    out_dir = os.path.dirname(args.out) if args.out else args.out_dir
    print(f"AOT-lowering golden models -> {out_dir}")
    lower_all(out_dir or ".")
    print("done")


if __name__ == "__main__":
    main()
