"""Layer-1 Pallas kernels for the ACADL golden models.

These kernels are the TPU-oriented realization of the paper's fused-tensor
operations (the Γ̈ accelerator's ``gemm`` instruction, §4.3): a tiled general
matrix multiplication with an optional fused ReLU activation.

Everything here is build-time only: kernels are lowered once by
``python/compile/aot.py`` into HLO text under ``artifacts/`` and executed by
the Rust runtime via PJRT.  Pallas runs with ``interpret=True`` because the
CPU PJRT plugin cannot execute Mosaic custom-calls (see DESIGN.md
§Hardware-Adaptation).
"""

from .gemm import pallas_gemm, pallas_gemm_relu, default_tiling
from . import ref

__all__ = ["pallas_gemm", "pallas_gemm_relu", "default_tiling", "ref"]
