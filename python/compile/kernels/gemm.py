"""Tiled GeMM (+ fused ReLU) as a Pallas kernel — the L1 compute hot-spot.

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the paper's Γ̈
accelerator executes ``gemm`` as a fused-tensor instruction over 8×8 int16
tiles held in 128-bit vector registers, fed by load/store units from a
scratchpad.  On TPU the same insight — keep operand tiles resident in fast
memory and stream the K dimension through the matrix unit — maps to:

* ``BlockSpec``-tiled HBM→VMEM movement (the load/store units),
* an MXU-shaped matmul on the resident blocks (the ``matMulFu``),
* an output block revisited across the K grid dimension (the scratchpad
  partial-result reuse).

The kernel is lowered with ``interpret=True`` only because the CPU PJRT
plugin cannot run Mosaic custom-calls; the *structure* (grid, block shapes,
accumulation schedule) is the TPU design point and is what DESIGN.md's VMEM /
MXU estimates are computed from.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def default_tiling(m, k, n):
    """Pick (TM, TK, TN) block shapes for an (m,k) x (k,n) GeMM.

    Blocks must divide the problem (callers pad otherwise).  The choice
    mirrors the Γ̈ design point scaled to TPU: prefer MXU-aligned 128 tiles,
    fall back to the largest divisor when the dimension is smaller.
    """

    def pick(dim):
        for t in (128, 64, 32, 16, 8):
            if dim % t == 0:
                return t
        return dim

    return pick(m), pick(k), pick(n)


def _gemm_kernel(x_ref, y_ref, o_ref, *, n_k, relu):
    """Kernel body: one (TM,TN) output block, revisited across the K grid.

    Grid is (M/TM, N/TN, K/TK) with K innermost.  The output block's index
    map ignores the K coordinate, so Pallas keeps the block resident in VMEM
    across consecutive K steps — it doubles as the float32 accumulator, the
    Γ̈ scratchpad's role for partial results.
    """
    ik = pl.program_id(2)

    @pl.when(ik == 0)
    def _zero():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)

    if relu:

        @pl.when(ik == n_k - 1)
        def _activate():
            o_ref[...] = jnp.maximum(o_ref[...], 0.0).astype(o_ref.dtype)


def _pallas_gemm(x, y, *, tiling=None, relu=False, interpret=True):
    m, k = x.shape
    k2, n = y.shape
    if k != k2:
        raise ValueError(f"inner dims mismatch: {k} vs {k2}")
    tm, tk, tn = tiling or default_tiling(m, k, n)
    if m % tm or k % tk or n % tn:
        raise ValueError(
            f"tiling ({tm},{tk},{tn}) must divide problem ({m},{k},{n})"
        )
    n_k = k // tk
    grid = (m // tm, n // tn, n_k)
    kernel = functools.partial(_gemm_kernel, n_k=n_k, relu=relu)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, ik: (i, ik)),
            pl.BlockSpec((tk, tn), lambda i, j, ik: (ik, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, ik: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, y).astype(x.dtype)


def pallas_gemm(x, y, tiling=None, interpret=True):
    """C = X @ Y via the tiled Pallas kernel (float32 accumulation)."""
    return _pallas_gemm(x, y, tiling=tiling, relu=False, interpret=interpret)


def pallas_gemm_relu(x, y, tiling=None, interpret=True):
    """C = relu(X @ Y) — the Γ̈ ``gemm …, 1: ReLU`` instruction (Listing 4)."""
    return _pallas_gemm(x, y, tiling=tiling, relu=True, interpret=interpret)


def vmem_footprint_bytes(tiling, dtype_bits=32):
    """Estimated VMEM bytes for one grid step: X block + Y block + out/acc.

    Used by DESIGN.md / EXPERIMENTS.md to reason about real-TPU behavior
    (interpret=True timing is not a TPU proxy).
    """
    tm, tk, tn = tiling
    elem = dtype_bits // 8
    return (tm * tk + tk * tn) * elem + tm * tn * 4


def mxu_utilization_estimate(tiling):
    """Fraction of the 128x128x128 MXU pass filled by one block product."""
    tm, tk, tn = tiling
    return min(tm, 128) * min(tn, 128) * min(tk, 128) / (128 * 128 * 128)
