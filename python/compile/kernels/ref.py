"""Pure-jnp correctness oracles for the Pallas kernels.

These are the reference semantics of the Γ̈ fused-tensor instructions
(§4.3 of the paper): ``gemm`` with an optional activation applied to the
output tile.  The Pallas kernels in ``gemm.py`` must match these exactly
(up to dtype accumulation rules) — enforced by ``python/tests/``.
"""

import jax.numpy as jnp


def gemm(x, y):
    """C = X @ Y with float32 accumulation (MXU semantics)."""
    return jnp.matmul(
        x, y, preferred_element_type=jnp.float32
    ).astype(x.dtype)


def gemm_relu(x, y):
    """C = relu(X @ Y) — the Γ̈ ``gemm …, 1: ReLU`` instruction (Listing 4)."""
    acc = jnp.matmul(x, y, preferred_element_type=jnp.float32)
    return jnp.maximum(acc, 0.0).astype(x.dtype)


def gemm_bias_relu(x, y, b):
    """C = relu(X @ Y + b) — fused linear layer used by the MLP golden model."""
    acc = jnp.matmul(x, y, preferred_element_type=jnp.float32)
    acc = acc + b.astype(jnp.float32)
    return jnp.maximum(acc, 0.0).astype(x.dtype)


def mlp_forward(x, params):
    """Reference MLP forward pass; ``params`` is [(W, b), ...].

    All hidden layers use ReLU; the final layer is linear (logits), matching
    ``model.mlp_forward`` and the Rust-side E9 end-to-end experiment.
    """
    h = x
    for i, (w, b) in enumerate(params):
        acc = jnp.matmul(h, w, preferred_element_type=jnp.float32) + b.astype(
            jnp.float32
        )
        if i + 1 < len(params):
            acc = jnp.maximum(acc, 0.0)
        h = acc.astype(x.dtype)
    return h
