"""Build-time compile package: L2 JAX models + L1 Pallas kernels + AOT lowering.

Never imported at runtime — ``make artifacts`` runs ``python -m compile.aot``
once, and the Rust binary consumes the resulting ``artifacts/*.hlo.txt``.
"""
