"""Layer-2 JAX golden models, built on the L1 Pallas kernels.

These are the DNN workloads the paper maps onto accelerators (§5): GeMM,
GeMM+ReLU (the Γ̈ fused-tensor instruction of Listing 4), and a small MLP
whose layers are exactly the operators the Rust mapping pipeline lowers onto
OMA / systolic / Γ̈ models.  Each model is AOT-lowered by ``aot.py`` into an
HLO-text artifact; the Rust runtime executes them via PJRT and compares the
numbers against the functional simulation of the mapped programs (E9).

Shapes are deliberately fixed here (AOT requires static shapes); the Rust
side reads the shape manifest emitted next to the artifacts.
"""

import jax.numpy as jnp

from .kernels.gemm import pallas_gemm, pallas_gemm_relu

# The E9 end-to-end MLP: 784-256-128-10, matching the paper-scale "small DNN
# inference" workload (MNIST-shaped synthetic input).  Hidden layers ReLU,
# final layer linear.
MLP_LAYERS = [(784, 256), (256, 128), (128, 10)]
MLP_BATCH = 8

# The Γ̈ Listing-4 design point: 8×8 matrices (the paper uses int16 elements
# in 128-bit vector registers; we model numerics in f32 — the simulator's
# functional payloads are f32 too, so comparisons are exact).
GAMMA_TILE = 8


def gemm_8x8(x, y):
    """Listing 4's gemm instruction without activation: C = X @ Y (8×8)."""
    return (pallas_gemm(x, y, tiling=(8, 8, 8)),)


def gemm_relu_8x8(x, y):
    """Listing 4's gemm with ReLU enabled: C = relu(X @ Y) (8×8)."""
    return (pallas_gemm_relu(x, y, tiling=(8, 8, 8)),)


def gemm_tiled_128(x, y):
    """A 128×128×128 GeMM with the MXU-aligned default tiling — the
    systolic-array experiment's workload (E3)."""
    return (pallas_gemm(x, y, tiling=(128, 128, 128)),)


def mlp_forward(x, w0, b0, w1, b1, w2, b2):
    """MLP forward pass with Pallas-kernel GeMMs + fused ReLU.

    Layer i computes relu(h @ Wi + bi) (final layer linear).  Bias add is
    plain jnp (the accelerators model it as vector add instructions); the
    matmul hot-spot goes through the Pallas kernel.
    """
    h = pallas_gemm(x, w0, tiling=(MLP_BATCH, 112, 128))
    h = jnp.maximum(h + b0, 0.0)
    h = pallas_gemm(h, w1, tiling=(MLP_BATCH, 128, 128))
    h = jnp.maximum(h + b1, 0.0)
    h = pallas_gemm(h, w2, tiling=(MLP_BATCH, 128, 10))
    return (h + b2,)


def mlp_shapes():
    """ShapeDtypeStructs for mlp_forward's arguments, in order."""
    import jax

    (d0, d1), (_, d2), (_, d3) = MLP_LAYERS
    f32 = jnp.float32
    return [
        jax.ShapeDtypeStruct((MLP_BATCH, d0), f32),
        jax.ShapeDtypeStruct((d0, d1), f32),
        jax.ShapeDtypeStruct((d1,), f32),
        jax.ShapeDtypeStruct((d1, d2), f32),
        jax.ShapeDtypeStruct((d2,), f32),
        jax.ShapeDtypeStruct((d2, d3), f32),
        jax.ShapeDtypeStruct((d3,), f32),
    ]
