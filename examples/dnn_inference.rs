//! E9 — the end-to-end driver: a real DNN inference mapped through every
//! layer of the stack.
//!
//! The 784-256-128-10 MLP (≈235k parameters, MNIST-shaped synthetic batch)
//! is lowered layer-by-layer through the UMA registry onto the Γ̈
//! fused-tensor accelerator (§4.3), simulated **cycle-accurately**, and
//! its numerics are cross-validated two ways:
//!
//! 1. against the host reference forward pass, and
//! 2. against the **PJRT-executed golden model** — the JAX/Pallas
//!    `mlp_forward` artifact AOT-lowered by `make artifacts` (L2/L1 of the
//!    three-layer architecture; Python never runs here).
//!
//! Run with: `cargo run --release --example dnn_inference`

use acadl::arch::gamma::GammaConfig;
use acadl::dnn::graph::DnnGraph;
use acadl::dnn::lowering::{lower_graph, run_schedule, SimMode};
use acadl::mapping::uma::Machine;
use acadl::metrics::Table;
use acadl::runtime::{Golden, RuntimeError};
use acadl::sim::BackendKind;

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let graph = DnnGraph::mlp_784_256_128_10();
    let batch = 8;
    println!(
        "model: {} ({} parameters), batch {batch}",
        graph.name,
        graph.parameter_count()
    );

    // Target: Γ̈ with 4 compute/scratchpad units.
    let machine = Machine::Gamma(GammaConfig::new(4).build()?);
    println!("target: Γ̈ 4 units — {}\n", machine.ag().summary());

    // Lower: per-layer fused Dense operators (gemm + bias + ReLU).
    let lowered = lower_graph(&machine, &graph, batch)?;
    let x = graph.input_batch(batch);

    // Cycle-accurate schedule run.
    let t0 = std::time::Instant::now();
    let report = run_schedule(
        &machine,
        &lowered,
        &x,
        SimMode::Timed(BackendKind::EventDriven),
        2_000_000_000,
    )?;
    let wall = t0.elapsed();

    let mut table = Table::new(
        "E9: MLP 784-256-128-10 on Γ̈ (timed)",
        &["layer", "MACs", "instructions", "cycles", "IPC", "cyc/MAC"],
    );
    for l in &report.per_layer {
        table.row(vec![
            l.name.clone(),
            l.macs.to_string(),
            l.instructions.to_string(),
            l.cycles.to_string(),
            format!("{:.2}", l.ipc),
            format!("{:.3}", l.cycles as f64 / l.macs as f64),
        ]);
    }
    table.row(vec![
        "TOTAL".into(),
        report.per_layer.iter().map(|l| l.macs).sum::<u64>().to_string(),
        report.total_instructions.to_string(),
        report.total_cycles.to_string(),
        format!(
            "{:.2}",
            report.total_instructions as f64 / report.total_cycles.max(1) as f64
        ),
        String::new(),
    ]);
    print!("{}", table.render());
    println!("simulation wall time: {wall:.2?}\n");

    // Validation 1: host reference.
    let want = graph.forward_ref(&x, batch);
    let host_diff = max_abs_diff(&report.output, &want);
    println!("vs host reference:   max |Δ| = {host_diff:.2e}");
    assert!(host_diff < 1e-2, "simulated accelerator disagrees with host");

    // Validation 2: PJRT golden model (the JAX/Pallas artifact).
    match Golden::load_default() {
        Ok(mut golden) => {
            // The artifact computes the same MLP with *its own* parameter
            // tensors; feed it the Rust-side parameters so the numbers
            // must agree.
            let mut inputs: Vec<Vec<f32>> = vec![x.clone()];
            for idx in 0..graph.layers.len() {
                let (w, b) = graph.dense_params(idx).unwrap();
                inputs.push(w);
                inputs.push(b);
            }
            let outs = golden.run("mlp_forward", &inputs)?;
            let pjrt_diff = max_abs_diff(&report.output, &outs[0]);
            println!("vs PJRT golden:      max |Δ| = {pjrt_diff:.2e}");
            assert!(
                pjrt_diff < 1e-2,
                "simulated accelerator disagrees with the XLA-executed golden model"
            );
            println!("\nE9 PASS — all three layers agree: simulated Γ̈ ≡ host ≡ XLA/Pallas ✓");
        }
        Err(RuntimeError::NoManifest(d)) => {
            println!(
                "vs PJRT golden:      skipped ({} missing — run `make artifacts`)",
                d.display()
            );
            println!("\nE9 PASS (host validation only)");
        }
        Err(RuntimeError::Disabled) => {
            println!("vs PJRT golden:      skipped (built without the `pjrt` feature)");
            println!("\nE9 PASS (host validation only)");
        }
        Err(e) => return Err(e.into()),
    }
    Ok(())
}
