//! Serving-mode demo: the coordinator's TCP front-end under concurrent
//! client load.
//!
//! Starts the JSON-lines server, then drives it with several client
//! threads submitting Γ̈ GeMM evaluation requests (the external
//! NAS/DSE-tool integration path), and reports request latency
//! percentiles and aggregate throughput.
//!
//! Run with: `cargo run --release --example gamma_serving`

use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Instant;

use acadl::coordinator::server::serve;
use acadl::coordinator::{JobResult, JobSpec, SimModeSpec, TargetSpec, Workload};
use acadl::sim::BackendKind;
use acadl::util::json::Json;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let listener = TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?;
    let workers = 4;
    std::thread::spawn(move || {
        let _ = serve(listener, workers);
    });
    println!("coordinator serving on {addr} ({workers} sim slots)\n");

    let clients = 4;
    let requests_per_client = 6;
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for c in 0..clients {
        handles.push(std::thread::spawn(move || -> Vec<(u64, f64)> {
            let stream = TcpStream::connect(addr).expect("connect");
            let mut writer = stream.try_clone().expect("clone");
            let mut reader = BufReader::new(stream);
            let mut latencies = Vec::new();
            for i in 0..requests_per_client {
                let id = (c * requests_per_client + i) as u64;
                let spec = JobSpec {
                    id,
                    target: TargetSpec::Gamma {
                        units: 1 + (i % 4),
                    },
                    workload: Workload::Gemm {
                        m: 16,
                        k: 16,
                        n: 16,
                        tile: None,
                        order: None,
                    },
                    mode: SimModeSpec::Timed,
                    // Alternate backends across requests: the serving path
                    // must report identical cycles either way.
                    backend: if i % 2 == 0 {
                        BackendKind::EventDriven
                    } else {
                        BackendKind::CycleStepped
                    },
                    max_cycles: 1_000_000_000,
                    platform: None,
                    deadline_ms: None,
                };
                let t = Instant::now();
                writer
                    .write_all((spec.to_json().to_string() + "\n").as_bytes())
                    .expect("send");
                let mut line = String::new();
                reader.read_line(&mut line).expect("recv");
                let result =
                    JobResult::from_json(&Json::parse(line.trim()).expect("json")).expect("result");
                assert_eq!(result.id, id);
                assert_eq!(result.error, None, "{result:?}");
                assert_eq!(result.numerics_ok, Some(true));
                latencies.push((result.cycles, t.elapsed().as_secs_f64() * 1000.0));
            }
            latencies
        }));
    }

    let mut all: Vec<(u64, f64)> = Vec::new();
    for h in handles {
        all.extend(h.join().expect("client"));
    }
    let wall = t0.elapsed();

    let mut lat: Vec<f64> = all.iter().map(|(_, l)| *l).collect();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pct = |p: f64| lat[((lat.len() - 1) as f64 * p) as usize];
    let total = all.len();
    println!("served {total} requests from {clients} concurrent clients in {wall:.2?}");
    println!("  throughput   {:.1} req/s", total as f64 / wall.as_secs_f64());
    println!("  latency p50  {:.1} ms", pct(0.50));
    println!("  latency p90  {:.1} ms", pct(0.90));
    println!("  latency max  {:.1} ms", lat.last().unwrap());
    println!(
        "  simulated cycles range: {}..{}",
        all.iter().map(|(c, _)| c).min().unwrap(),
        all.iter().map(|(c, _)| c).max().unwrap()
    );
    println!("\nall numerics checks passed ✓");
    Ok(())
}
