//! E10 — design-space exploration through the `dse` engine (§7's
//! "optimization loop of hardware-aware NAS and DNN/HW Co-Design").
//!
//! Enumerates the full (architecture × tile × loop order × backend)
//! candidate cross-product — OMA cache variants, every power-of-two
//! systolic grid up to 16×16, Γ̈ up to 8 units; 136 candidates — prunes
//! with the per-target roofline lower bound, evaluates the survivors in
//! parallel on the coordinator pool with memoized results, and reports
//! the cycles-vs-area Pareto frontier plus pruning/cache statistics.
//!
//! Run with: `cargo run --release --example design_space_exploration`

use acadl::dse::{explore, DseSpace};

fn main() {
    let dim = 32;
    let space = DseSpace::standard(dim);
    let candidates = space.enumerate().len();
    assert!(
        candidates >= 100,
        "the standard sweep must cover ≥100 candidates (got {candidates})"
    );

    let workers = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4);
    println!("exploring gemm {dim}³ over {candidates} candidates on {workers} workers…\n");

    let report = explore(&space, workers, true);

    print!(
        "{}",
        report
            .table(&format!("E10: design space, gemm {dim}³ (timed)"))
            .render()
    );
    println!("\n{}", report.summary());

    // Invariants the sweep must uphold.
    let s = &report.stats;
    assert_eq!(s.candidates, candidates);
    assert_eq!(s.evaluated + s.pruned, s.candidates, "every candidate accounted for");
    assert!(s.pruned > 0, "the roofline pre-filter must cut the scalar tail");
    // The scalar OMA tail (96 of 136 candidates) is bound-pruned once the
    // parallel targets set the incumbent — no machine is ever built for it.
    assert!(
        s.pruned * 2 >= s.candidates,
        "the pre-filter must cut at least half the space before machine construction \
         ({} of {} pruned)",
        s.pruned,
        s.candidates
    );
    assert!(s.cache_hits > 0, "backend aliases must be served from the memo");
    assert!(!report.frontier.is_empty(), "a frontier must exist");
    // Every error-free timed point must have *performed* the numerics
    // check and passed it — `None` would mean the comparison was skipped.
    assert!(
        report.points.iter().all(|p| p.result.error.is_some()
            || p.result.numerics_ok == Some(true)),
        "a design point produced wrong (or unchecked) numerics"
    );
    assert!(
        report
            .points
            .iter()
            .all(|p| p.result.error.is_some() || p.result.cycles >= p.lower_bound),
        "a simulation undercut its analytical lower bound"
    );

    // Sibling sweep: the same architecture axes on the transformer
    // workload (a separate exploration — pruning's cycle incumbent must
    // not cross workloads).
    let tf_specs = space.enumerate_transformer();
    assert!(!tf_specs.is_empty(), "the standard space sweeps the transformer");
    println!(
        "\nexploring tiny_transformer over {} candidates on {workers} workers…\n",
        tf_specs.len()
    );
    let tf = acadl::dse::explore_specs(tf_specs, workers, true);
    print!("{}", tf.table("E10b: design space, tiny_transformer seq 8 (timed)").render());
    println!("\n{}", tf.summary());
    let s = &tf.stats;
    assert_eq!(s.evaluated + s.pruned, s.candidates, "every candidate accounted for");
    assert!(
        tf.points.iter().all(|p| p.result.error.is_some()
            || (p.result.numerics_ok == Some(true) && p.result.cycles >= p.lower_bound)),
        "a transformer design point failed numerics or undercut its bound"
    );
}
