//! E10 — design-space exploration through the coordinator (§7's
//! "optimization loop of hardware-aware NAS and DNN/HW Co-Design").
//!
//! Sweeps systolic-array sizes and Γ̈ unit counts (plus the OMA as the
//! scalar floor) over a GeMM workload, runs every candidate in parallel on
//! the worker pool, and reports the cycles-vs-area Pareto frontier.
//!
//! Run with: `cargo run --release --example design_space_exploration`

use acadl::coordinator::{run_jobs, JobSpec, SimModeSpec, TargetSpec, Workload};
use acadl::metrics::Table;
use acadl::sim::BackendKind;

fn main() {
    let dim = 32;
    let workload = Workload::Gemm {
        m: dim,
        k: dim,
        n: dim,
        tile: None,
        order: None,
    };

    // Candidate architectures.
    let mut targets = vec![TargetSpec::Oma {
        cache: true,
        mac_latency: None,
    }];
    for edge in [2usize, 4, 8, 16] {
        targets.push(TargetSpec::Systolic {
            rows: edge,
            cols: edge,
        });
    }
    for units in [1usize, 2, 4, 8] {
        targets.push(TargetSpec::Gamma { units });
    }

    let specs: Vec<JobSpec> = targets
        .into_iter()
        .enumerate()
        .map(|(id, target)| JobSpec {
            id: id as u64,
            target,
            workload: workload.clone(),
            mode: SimModeSpec::Timed,
            // DSE sweeps are throughput-bound: the event-driven backend
            // reports identical cycles and skips the memory-stall idle
            // cycles that dominate the big Γ̈ candidates.
            backend: BackendKind::EventDriven,
            max_cycles: 2_000_000_000,
        })
        .collect();
    let n = specs.len();

    let workers = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4);
    println!("exploring {n} design points on {workers} workers…\n");
    let t0 = std::time::Instant::now();
    let results = run_jobs(specs, workers);
    let wall = t0.elapsed();

    let mut table = Table::new(
        &format!("E10: design space, gemm {dim}³ (timed)"),
        &["target", "area", "cycles", "util", "numerics", "wall ms", "pareto"],
    );
    // Pareto: no other point has both lower cycles and lower area.
    let pareto = |i: usize| -> bool {
        let r = &results[i];
        r.error.is_none()
            && !results.iter().any(|o| {
                o.error.is_none()
                    && o.cycles < r.cycles
                    && o.area_proxy <= r.area_proxy
                    && (o.cycles, o.area_proxy as u64) != (r.cycles, r.area_proxy as u64)
            })
    };
    for (i, r) in results.iter().enumerate() {
        table.row(vec![
            r.target.clone(),
            format!("{:.0}", r.area_proxy),
            if r.error.is_some() {
                format!("ERR: {}", r.error.as_deref().unwrap_or(""))
            } else {
                r.cycles.to_string()
            },
            format!("{:.1}%", r.utilization * 100.0),
            match r.numerics_ok {
                Some(true) => "ok".into(),
                Some(false) => "MISMATCH".into(),
                None => "-".into(),
            },
            (r.wall_micros / 1000).to_string(),
            if pareto(i) { "★".into() } else { String::new() },
        ]);
    }
    print!("{}", table.render());
    println!(
        "\n{} jobs in {wall:.2?} ({:.1} jobs/s) — every numerics check must be ok",
        n,
        n as f64 / wall.as_secs_f64()
    );
    assert!(
        results
            .iter()
            .all(|r| r.error.is_some() || r.numerics_ok == Some(true)),
        "a design point produced wrong numerics"
    );
}
