//! Transformer attention across the full stack: the `tiny_transformer`
//! block (embed → single-head attention → GELU FFN → head) mapped onto
//! three zoo machines, simulated cycle-accurately on both backends, and
//! cross-validated against the host reference — bit-exactly on the
//! sequentially-accumulating targets.
//!
//! The run continues with a **`.acadl`-driven pass**: the systolic array
//! is rebuilt from its textual description
//! (`examples/systolic_2x2.acadl`), verified equivalent to the builder
//! graph, and the same schedule produces the same cycle count —
//! file-described and Rust-built architectures are interchangeable all
//! the way up to attention.  It closes with a **KV-cached serving job**
//! (multi-layer, multi-head, prefill + decode) that reports the
//! prefill/decode phase split and cycles-per-decoded-token.
//!
//! Run with: `cargo run --release --example transformer_inference`

use acadl::adl;
use acadl::arch::gamma::GammaConfig;
use acadl::arch::oma::OmaConfig;
use acadl::arch::systolic::SystolicConfig;
use acadl::coordinator::job::{self, JobSpec, SimModeSpec, TargetSpec, Workload};
use acadl::dnn::graph::DnnGraph;
use acadl::dnn::lowering::{lower_graph, roofline_ops, run_schedule, SimMode};
use acadl::mapping::uma::TargetConfig;
use acadl::metrics::Table;
use acadl::sim::BackendKind;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    let graph = DnnGraph::tiny_transformer();
    let seq = 8; // sequence length = schedule batch (one token per row)
    let x = graph.input_batch(seq);
    let want = graph.forward_ref(&x, seq);
    println!(
        "model: {} ({} parameters), sequence length {seq}",
        graph.name,
        graph.parameter_count()
    );

    let targets = [
        ("oma", TargetConfig::Oma(OmaConfig::default())),
        ("systolic_2x2", TargetConfig::Systolic(SystolicConfig::new(2, 2))),
        ("gamma_1u", TargetConfig::Gamma(GammaConfig::new(1))),
    ];

    let mut summary = Table::new(
        "tiny_transformer across the zoo (event-driven, cycle-accurate)",
        &["target", "cycles", "instructions", "bound", "max |Δ| vs ref"],
    );
    let mut systolic_cycles = 0u64;
    for (name, cfg) in targets {
        let machine = cfg.build()?;
        let lg = lower_graph(&machine, &graph, seq)?;
        let ev = run_schedule(
            &machine,
            &lg,
            &x,
            SimMode::Timed(BackendKind::EventDriven),
            2_000_000_000,
        )?;
        // Both backends agree on every cycle.
        let cs = run_schedule(
            &machine,
            &lg,
            &x,
            SimMode::Timed(BackendKind::CycleStepped),
            2_000_000_000,
        )?;
        assert_eq!(ev.total_cycles, cs.total_cycles, "{name}: backends agree");
        assert_eq!(ev.output, cs.output, "{name}: identical state");

        let bound: u64 = {
            let rl = match &cfg {
                TargetConfig::Oma(_) => acadl::analytical::Roofline::oma(),
                TargetConfig::Systolic(c) => acadl::analytical::Roofline::systolic(c.rows, c.cols),
                TargetConfig::Gamma(c) => acadl::analytical::Roofline::gamma(c.units),
            };
            roofline_ops(&graph, seq).iter().map(|op| rl.op_cycles(op)).sum()
        };
        assert!(ev.total_cycles >= bound, "{name}: cycles above the roofline");

        let diff = ev
            .output
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        match name {
            // Sequential accumulation: the match is exact, not approximate.
            "oma" | "systolic_2x2" => assert_eq!(ev.output, want, "{name}: bit-exact"),
            _ => assert!(diff < 1e-3, "{name}: diff {diff}"),
        }
        if name == "systolic_2x2" {
            systolic_cycles = ev.total_cycles;
            // Per-layer detail for the most interesting target.
            let mut t = Table::new(
                "per-layer schedule on systolic_2x2",
                &["layer", "cycles", "instructions", "IPC"],
            );
            for l in &ev.per_layer {
                t.row(vec![
                    l.name.clone(),
                    l.cycles.to_string(),
                    l.instructions.to_string(),
                    format!("{:.2}", l.ipc),
                ]);
            }
            print!("{}", t.render());
        }
        summary.row(vec![
            name.to_string(),
            ev.total_cycles.to_string(),
            ev.total_instructions.to_string(),
            bound.to_string(),
            format!("{diff:.1e}"),
        ]);
    }
    print!("{}", summary.render());

    // ---- the .acadl-driven run -------------------------------------
    let path = concat!(env!("CARGO_MANIFEST_DIR"), "/../examples/systolic_2x2.acadl");
    let src = std::fs::read_to_string(path)?;
    let arch = adl::load_str(&src).map_err(|e| e.to_string())?;
    let spec = arch.target.clone().expect("systolic_2x2.acadl is bound");
    let machine = acadl::coordinator::build_cached(&spec)?;
    adl::ag_equiv(&arch.ag, machine.ag()).map_err(|e| e.to_string())?;
    let r = job::execute(&JobSpec {
        id: 0,
        target: spec,
        workload: Workload::Transformer {
            seq,
            layers: 1,
            heads: 1,
            decode_steps: 0,
        },
        mode: SimModeSpec::Timed,
        backend: BackendKind::EventDriven,
        max_cycles: 2_000_000_000,
        platform: None,
        deadline_ms: None,
    });
    assert_eq!(r.error, None);
    assert_eq!(r.numerics_ok, Some(true));
    assert_eq!(
        r.cycles, systolic_cycles,
        "file-described machine reports the builder's cycles"
    );
    println!(
        "\n.acadl-driven run ({}): {} cycles — identical to the builder path ✓",
        "systolic_2x2.acadl", r.cycles
    );

    // ---- KV-cached serving: prefill + decode ------------------------
    // A nonzero `decode_steps` turns the job into a serving scenario:
    // the prompt is prefetched through the model once (populating the
    // per-layer KV caches), then each step decodes one token against the
    // growing cache.  The result row splits the phases, and
    // cycles-per-token is the serving latency headline.
    let serving = job::execute(&JobSpec {
        id: 1,
        target: TargetSpec::Systolic { rows: 2, cols: 2 },
        workload: Workload::Transformer {
            seq,
            layers: 2,
            heads: 2,
            decode_steps: 4,
        },
        mode: SimModeSpec::Timed,
        backend: BackendKind::EventDriven,
        max_cycles: 2_000_000_000,
        platform: None,
        deadline_ms: None,
    });
    assert_eq!(serving.error, None);
    assert_eq!(serving.numerics_ok, Some(true));
    let prefill = serving
        .prefill_cycles
        .expect("serving runs report the phase split");
    let per_token = serving.cycles_per_token.expect("decode steps > 0");
    println!(
        "KV-cached serving (2 layers, 2 heads, prompt {seq} + 4 decode steps) on \
         systolic_2x2: {} cycles = {prefill} prefill + {} decode → {per_token:.1} \
         cycles/token",
        serving.cycles,
        serving.cycles - prefill
    );
    Ok(())
}
