//! Quickstart: the paper's core flow in one page.
//!
//! 1. Build the One MAC Accelerator from §4.1 (the `@generate` +
//!    `create_ag()` of Listing 1).
//! 2. Lower a tiled GeMM onto it through the UMA registry (§5).
//! 3. Validate the mapping with the functional ISS, then run the timing
//!    simulation (§6) and read off the performance characteristics.
//!
//! Run with: `cargo run --release --example quickstart`

use acadl::arch::oma::OmaConfig;
use acadl::mapping::gemm::{gemm_ref, GemmLayout, GemmParams, LoopOrder};
use acadl::mapping::uma::{lower, Machine, Operator};
use acadl::sim::engine::Engine;
use acadl::sim::functional::FunctionalSim;
use acadl::sim::BackendKind;

fn main() -> Result<(), Box<dyn std::error::Error>> {
    // 1. Model the accelerator (Fig. 2/3's block diagram → AG).
    let machine = Machine::Oma(OmaConfig::default().build()?);
    println!("OMA architecture graph: {}\n", machine.ag().summary());

    // 2. Map a tiled GeMM (Fig. 8): C (8×8) = A (8×8) · B (8×8), 4×4
    //    tiles, k-innermost order (register accumulation, Listing 5 style).
    let p = GemmParams::new(8, 8, 8)
        .with_tile(4)
        .with_order(LoopOrder::Ijk);
    let lowered = lower(&machine, &Operator::Gemm(p))?;
    println!(
        "lowered gemm_8x8x8 (tile=4, ijk): {} ACADL instructions",
        lowered.program.len()
    );
    println!("first instructions:");
    for line in lowered
        .program
        .disassemble(machine.ag())
        .lines()
        .take(6)
    {
        println!("  {line}");
    }

    // Deterministic operands.
    let a: Vec<f32> = (0..64).map(|i| ((i % 7) as f32) - 3.0).collect();
    let b: Vec<f32> = (0..64).map(|i| ((i % 5) as f32) - 2.0).collect();

    // 3a. Functional simulation validates the mapping (§5).
    let mut sim = FunctionalSim::new(machine.ag());
    lowered.layout.load_inputs(&p, &mut sim.mem, &a, &b);
    let fstats = sim.run(&lowered.program, 10_000_000)?;
    let got = lowered.layout.read_c(&p, &sim.mem);
    let want = gemm_ref(&p, &a, &b);
    assert_eq!(got, want, "functional mapping must match the oracle");
    println!(
        "\nfunctional simulation: {} instructions, result correct ✓",
        fstats.instructions
    );

    // 3b. Timing simulation infers performance characteristics (§6).
    let mut engine = Engine::new(machine.ag(), &lowered.program)?;
    lowered.layout.load_inputs(&p, &mut engine.mem, &a, &b);
    let stats = engine.run(100_000_000)?;
    assert_eq!(
        lowered.layout.read_c(&p, &engine.mem),
        want,
        "timed simulation commits identical architectural state"
    );
    println!("timing simulation:");
    println!("  cycles            {}", stats.cycles);
    println!("  instructions      {}", stats.retired);
    println!("  IPC               {:.3}", stats.ipc());
    println!("  fetch stalls      {}", stats.fetch_stalls);
    println!("  cycles/MAC        {:.1}", stats.cycles as f64 / p.macs() as f64);
    for s in &stats.storages {
        if let (Some(h), Some(m)) = (s.cache_hits, s.cache_misses) {
            println!(
                "  {:<12} {h} hits / {m} misses ({:.1}% hit rate)",
                s.name,
                100.0 * h as f64 / (h + m).max(1) as f64
            );
        }
    }

    // 3c. The event-driven backend skips idle cycles (memory stalls, long
    //     MAC latencies) yet reports the identical cycle count — pick it
    //     for memory-bound sweeps, keep the default for dense pipelines.
    let mut event = Engine::with_backend(machine.ag(), &lowered.program, BackendKind::EventDriven)?;
    lowered.layout.load_inputs(&p, &mut event.mem, &a, &b);
    let estats = event.run(100_000_000)?;
    assert_eq!(estats.cycles, stats.cycles, "backends agree cycle-for-cycle");
    println!("\nevent-driven backend: {} cycles (identical) ✓", estats.cycles);

    // The same layout/result helpers let you sweep tile sizes and loop
    // orders — see `cargo bench --bench tiling` (experiment E2).
    let _ = GemmLayout::at(machine.data_base(), &p);
    Ok(())
}
