//! E6 — AIDG fast estimation vs full timing simulation (§6, ref [16]):
//! cycle-count error and wall-time speedup per model/workload — the
//! "ultra-fast yet accurate" trade-off.
//!
//! Run: `cargo bench --bench aidg_vs_sim`

use std::time::Instant;

use acadl::aidg;
use acadl::arch::gamma::GammaConfig;
use acadl::arch::oma::OmaConfig;
use acadl::arch::systolic::SystolicConfig;
use acadl::mapping::gamma_gemm::{gamma_gemm, GammaGemmOpts};
use acadl::mapping::gemm::{oma_gemm_listing5, oma_tiled_gemm, GemmParams};
use acadl::mapping::systolic_gemm::systolic_gemm;
use acadl::metrics::Table;
use acadl::sim::engine::Engine;
use acadl::sim::BackendKind;

fn main() {
    let mut table = Table::new(
        "E6: AIDG estimate vs cycle-accurate simulation (both sim backends)",
        &[
            "workload",
            "sim cycles",
            "AIDG cycles",
            "error",
            "sim wall",
            "event wall",
            "AIDG wall",
            "speedup",
        ],
    );

    let cases: Vec<(String, acadl::acadl_core::graph::Ag, acadl::isa::program::Program)> = {
        let mut v = Vec::new();
        let oma = OmaConfig::default().build().expect("oma");
        let p = GemmParams::new(12, 12, 12);
        v.push((
            "oma/listing5 12³".to_string(),
            oma.ag.clone(),
            oma_gemm_listing5(&oma, &p).expect("asm"),
        ));
        v.push((
            "oma/unrolled 12³".to_string(),
            oma.ag.clone(),
            oma_tiled_gemm(&oma, &p).expect("codegen"),
        ));
        let sys = SystolicConfig::new(4, 4).build().expect("systolic");
        v.push((
            "systolic4x4 16³".to_string(),
            sys.ag.clone(),
            systolic_gemm(&sys, &GemmParams::new(16, 16, 16)),
        ));
        let gam = GammaConfig::new(2).build().expect("gamma");
        v.push((
            "gamma2u 16³".to_string(),
            gam.ag.clone(),
            gamma_gemm(&gam, &GemmParams::new(16, 16, 16), GammaGemmOpts::default()),
        ));
        // A big loopy workload: fixed-point extrapolation pays off here.
        let p24 = GemmParams::new(24, 24, 24);
        v.push((
            "oma/listing5 24³".to_string(),
            oma.ag.clone(),
            oma_gemm_listing5(&oma, &p24).expect("asm"),
        ));
        v
    };

    for (name, ag, prog) in &cases {
        let t0 = Instant::now();
        let mut engine = Engine::new(ag, prog).expect("engine");
        let exact = engine.run(2_000_000_000).expect("run").cycles;
        let sim_wall = t0.elapsed();

        let te = Instant::now();
        let mut event = Engine::with_backend(ag, prog, BackendKind::EventDriven).expect("engine");
        let event_cycles = event.run(2_000_000_000).expect("run").cycles;
        let event_wall = te.elapsed();
        assert_eq!(event_cycles, exact, "{name}: backends must agree");

        let t1 = Instant::now();
        let est = aidg::estimate_fixed_point(ag, prog, 2_000_000_000)
            .expect("estimate")
            .cycles;
        let aidg_wall = t1.elapsed();

        let err = (est as f64 - exact as f64) / exact as f64;
        table.row(vec![
            name.clone(),
            exact.to_string(),
            est.to_string(),
            format!("{:+.1}%", err * 100.0),
            format!("{sim_wall:.2?}"),
            format!("{event_wall:.2?}"),
            format!("{aidg_wall:.2?}"),
            format!(
                "{:.0}x",
                sim_wall.as_secs_f64() / aidg_wall.as_secs_f64().max(1e-9)
            ),
        ]);
    }
    print!("{}", table.render());
    println!("(AIDG ignores issue-buffer back-pressure and slot contention — its");
    println!(" documented optimism; error bounds are asserted in rust/tests/)");
}
