//! E2 — tiled GeMM locality (§5, Fig. 8): tile size × loop order on the
//! OMA with a small data cache.  The paper's claim: execution order has
//! "a significant impact on the execution time", and reusing A tiles
//! (k-innermost with register accumulation) wins.
//!
//! Run: `cargo bench --bench tiling`

use acadl::arch::oma::{CacheCfg, OmaConfig};
use acadl::mapping::gemm::{oma_tiled_gemm, GemmParams, LoopOrder};
use acadl::mem::cache::ReplacementPolicy;
use acadl::metrics::Table;
use acadl::sim::engine::Engine;

fn main() {
    // Deliberately small cache so tiling matters: 8 sets × 2 ways × 32 B
    // = 512 B against 3 KiB of operands (16³ f32 GeMM).
    let machine = OmaConfig {
        cache: Some(CacheCfg {
            sets: 8,
            ways: 2,
            line: 32,
            policy: ReplacementPolicy::Lru,
            hit_latency: 1,
            miss_latency: 20,
        }),
        ..OmaConfig::default()
    }
    .build()
    .expect("build OMA");
    let dim = 16;

    let mut table = Table::new(
        &format!("E2: gemm {dim}³ on OMA, 512B cache — tile × order"),
        &["order", "tile", "instrs", "cycles", "hit rate", "vs best"],
    );

    let mut rows: Vec<(String, String, u64, u64, f64)> = Vec::new();
    for order in LoopOrder::ALL {
        for tile in [None, Some(4), Some(8)] {
            let mut p = GemmParams::new(dim, dim, dim).with_order(order);
            if let Some(t) = tile {
                p = p.with_tile(t);
            }
            let prog = oma_tiled_gemm(&machine, &p).expect("codegen");
            let mut engine = Engine::new(&machine.ag, &prog).expect("engine");
            let stats = engine.run(1_000_000_000).expect("run");
            let cache = stats
                .storages
                .iter()
                .find(|s| s.name == "dcache0")
                .expect("cache stats");
            let (h, m) = (cache.cache_hits.unwrap(), cache.cache_misses.unwrap());
            rows.push((
                order.name().into(),
                tile.map(|t| t.to_string()).unwrap_or_else(|| "full".into()),
                stats.retired,
                stats.cycles,
                h as f64 / (h + m).max(1) as f64,
            ));
        }
    }
    let best = rows.iter().map(|r| r.3).min().unwrap();
    for (order, tile, instrs, cycles, hit) in rows {
        table.row(vec![
            order,
            tile,
            instrs.to_string(),
            cycles.to_string(),
            format!("{:.1}%", hit * 100.0),
            format!("{:.2}x", cycles as f64 / best as f64),
        ]);
    }
    print!("{}", table.render());
    println!("(k-innermost orders use register accumulation — Listing 5's r8)");
}
