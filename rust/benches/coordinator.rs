//! E10 (perf) — coordinator throughput: jobs/second of the worker pool as
//! worker count scales, on a mixed design-space batch.  L3 must not be the
//! bottleneck of the NAS/co-design loop the paper targets (§7).
//!
//! Run: `cargo bench --bench coordinator`

use acadl::coordinator::{run_jobs, JobSpec, SimModeSpec, TargetSpec, Workload};
use acadl::metrics::Table;

fn batch() -> Vec<JobSpec> {
    let mut specs = Vec::new();
    let mut id = 0;
    for edge in [2usize, 4] {
        for dim in [8usize, 16] {
            for mode in [SimModeSpec::Timed, SimModeSpec::Estimate] {
                specs.push(JobSpec {
                    id,
                    target: TargetSpec::Systolic {
                        rows: edge,
                        cols: edge,
                    },
                    workload: Workload::Gemm {
                        m: dim,
                        k: dim,
                        n: dim,
                        tile: None,
                        order: None,
                    },
                    mode,
                    backend: Default::default(),
                    max_cycles: 1_000_000_000,
                    platform: None,
                    deadline_ms: None,
                });
                id += 1;
            }
        }
    }
    for units in [1usize, 2] {
        specs.push(JobSpec {
            id,
            target: TargetSpec::Gamma { units },
            workload: Workload::Gemm {
                m: 16,
                k: 16,
                n: 16,
                tile: None,
                order: None,
            },
            mode: SimModeSpec::Timed,
            backend: Default::default(),
            max_cycles: 1_000_000_000,
            platform: None,
            deadline_ms: None,
        });
        id += 1;
    }
    specs
}

fn main() {
    let specs = batch();
    let n = specs.len();
    let mut table = Table::new(
        &format!("E10 perf: pool throughput, {n}-job design-space batch"),
        &["workers", "wall", "jobs/s", "speedup"],
    );
    let mut base = None;
    for workers in [1usize, 2, 4, 8] {
        let t0 = std::time::Instant::now();
        let results = run_jobs(specs.clone(), workers);
        let wall = t0.elapsed();
        assert_eq!(results.len(), n);
        assert!(results.iter().all(|r| r.error.is_none()));
        let b = *base.get_or_insert(wall);
        table.row(vec![
            workers.to_string(),
            format!("{wall:.2?}"),
            format!("{:.1}", n as f64 / wall.as_secs_f64()),
            format!("{:.2}x", b.as_secs_f64() / wall.as_secs_f64()),
        ]);
    }
    print!("{}", table.render());
}
