//! E3 — parameterizable systolic array (§4.2): rows×cols sweep on a fixed
//! GeMM; cycles, PE utilization, and speedup over the 2×2 baseline.
//! The paper's point: one parameterizable ACADL description evaluates the
//! whole family.
//!
//! Run: `cargo bench --bench systolic_sweep`

use acadl::arch::systolic::SystolicConfig;
use acadl::mapping::gemm::GemmParams;
use acadl::mapping::systolic_gemm::systolic_gemm;
use acadl::metrics::Table;
use acadl::sim::engine::Engine;

fn main() {
    let dim = 32;
    let p = GemmParams::new(dim, dim, dim);
    let mut table = Table::new(
        &format!("E3: systolic rows×cols sweep, gemm {dim}³"),
        &["array", "PEs", "instrs", "cycles", "speedup", "PE util", "cyc/MAC"],
    );
    let mut baseline = None;
    for edge in [2usize, 4, 8, 16] {
        let machine = SystolicConfig::new(edge, edge).build().expect("build");
        let prog = systolic_gemm(&machine, &p);
        let mut engine = Engine::new(&machine.ag, &prog).expect("engine");
        let stats = engine.run(2_000_000_000).expect("run");
        let base = *baseline.get_or_insert(stats.cycles);
        // Utilization over the PE MAC units only.
        let pe_busy: u64 = stats
            .fu_busy
            .iter()
            .filter(|(n, _)| n.starts_with("fu["))
            .map(|(_, b)| b)
            .sum();
        let pes = (edge * edge) as u64;
        table.row(vec![
            format!("{edge}x{edge}"),
            pes.to_string(),
            stats.retired.to_string(),
            stats.cycles.to_string(),
            format!("{:.2}x", base as f64 / stats.cycles as f64),
            format!("{:.1}%", 100.0 * pe_busy as f64 / (pes * stats.cycles) as f64),
            format!("{:.3}", stats.cycles as f64 / p.macs() as f64),
        ]);
    }
    print!("{}", table.render());
    println!("(speedup saturates when the array edge outgrows the operand tiles —");
    println!(" the crossover ScaleSim-style models predict; see E7 baselines)");
}
