//! E1 — OMA GeMM (Figs 2–3, Listing 5): cycle counts and CPI for the
//! Listing-5 register-loop implementation vs the unrolled UMA mapping,
//! across matrix sizes; plus simulator wall-time throughput.
//!
//! Run: `cargo bench --bench oma_gemm`

use acadl::arch::oma::OmaConfig;
use acadl::mapping::gemm::{oma_gemm_listing5, oma_tiled_gemm, GemmParams};
use acadl::metrics::Table;
use acadl::sim::engine::Engine;
use acadl::util::bench::Bench;

fn main() {
    let machine = OmaConfig::default().build().expect("build OMA");
    let mut table = Table::new(
        "E1: OMA GeMM — Listing-5 loop vs unrolled mapping",
        &["size", "variant", "instrs", "cycles", "CPI", "cyc/MAC"],
    );
    let mut bench = Bench::new("oma_gemm");

    for dim in [4usize, 8, 12, 16] {
        let p = GemmParams::new(dim, dim, dim);
        for (variant, prog) in [
            ("listing5", oma_gemm_listing5(&machine, &p).expect("asm")),
            ("unrolled", oma_tiled_gemm(&machine, &p).expect("codegen")),
        ] {
            let mut engine = Engine::new(&machine.ag, &prog).expect("engine");
            let stats = engine.run(1_000_000_000).expect("run");
            table.row(vec![
                format!("{dim}³"),
                variant.into(),
                stats.retired.to_string(),
                stats.cycles.to_string(),
                format!("{:.2}", stats.cycles as f64 / stats.retired as f64),
                format!("{:.1}", stats.cycles as f64 / p.macs() as f64),
            ]);
            if dim == 12 {
                // Simulator throughput on this workload (perf target §Perf).
                bench.time(
                    &format!("sim_{variant}_{dim}"),
                    Some(stats.cycles),
                    || {
                        let mut e = Engine::new(&machine.ag, &prog).expect("engine");
                        e.run(1_000_000_000).expect("run").cycles
                    },
                );
            }
        }
    }
    print!("{}", table.render());
}
