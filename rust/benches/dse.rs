//! DSE engine perf: what the analytical pre-filter, the memo, and the
//! streaming pipeline buy on a real sweep.
//!
//! Two comparisons:
//! * pruned + memoized exploration vs the exhaustive baseline over the
//!   same candidate space — the speedup of the enumerate→prune→simulate
//!   pipeline;
//! * streamed (lazy windows, bounded retention) vs materialized
//!   evaluation of a large file-style `param` space — candidates/second
//!   and peak RSS, the numbers behind the bounded-memory claim.
//!
//! Run: `cargo bench --bench dse`
//! (`ACADL_BENCH_JSON=path` appends the medians to a BENCH json.)

use acadl::dse::{
    explore, explore_source, explore_specs, DseConfig, DseSpace, FileSource, FileSpace,
};
use acadl::metrics::Table;
use acadl::util::bench::Bench;

/// Peak resident set size of this process in bytes (`VmHWM`), or `None`
/// off Linux.  Monotonic — order measurements smallest-footprint first.
fn peak_rss_bytes() -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    let line = status.lines().find(|l| l.starts_with("VmHWM:"))?;
    let kb: u64 = line.split_whitespace().nth(1)?.parse().ok()?;
    Some(kb * 1024)
}

/// A large OMA `param` space in the shape a `.acadl` sweep file takes
/// (built textually so the bench exercises the real stamp-from-one-
/// elaboration path).
fn file_space(tiles: usize) -> FileSpace {
    let mut src = String::from("arch \"bench\" targets oma {\n  cache = true\n}\n");
    src.push_str("param cache in [true, false]\n");
    let vals: Vec<String> = (1..=tiles).map(|t| t.to_string()).collect();
    src.push_str(&format!("param tile in [{}]\n", vals.join(", ")));
    src.push_str("param order in [ijk, ikj, jik, jki, kij, kji]\n");
    let arch = acadl::adl::load_str(&src).expect("bench space parses");
    FileSpace::from_arch(&arch, 8).expect("bench space elaborates")
}

fn main() {
    let dim = 16;
    let mut space = DseSpace::quick(dim);
    // Both backends so the memo has aliases to collapse.
    space.backends = vec![Default::default(), acadl::sim::BackendKind::EventDriven];
    let workers = 4;

    let mut b = Bench::new("dse");
    let n = space.total();

    let pruned = b
        .time("pruned+memoized", Some(n), || explore(&space, workers, true))
        .clone();
    let exhaustive = b
        .time("exhaustive", Some(n), || explore(&space, workers, false))
        .clone();

    // Streamed vs materialized over a ~10k-candidate param space.  The
    // streamed run goes first: VmHWM only ever rises, so the bounded
    // pipeline must be measured before the materializer inflates it.
    let big = file_space(850); // 2 × 850 × 6 = 10 200 candidates
    let big_n = big.total().expect("bench space fits u64");
    let streamed_cfg = {
        let mut cfg = DseConfig::new(workers);
        cfg.window = 2048;
        cfg.keep_points = 256;
        cfg
    };
    let streamed = b
        .time("streamed 10k (window 2048)", Some(big_n), || {
            explore_source(
                &mut FileSource::new(&big).expect("valid axes"),
                &streamed_cfg,
                None,
            )
            .expect("no checkpoint IO to fail")
        })
        .clone();
    let rss_streamed = peak_rss_bytes();
    let materialized = b
        .time("materialized 10k (full Vec)", Some(big_n), || {
            explore_specs(big.enumerate().expect("in range"), workers, true)
        })
        .clone();
    let rss_materialized = peak_rss_bytes();
    b.write_json_if_requested();

    // One representative run for the stats table.
    let rep = explore(&space, workers, true);
    let full = explore(&space, workers, false);
    let mut t = Table::new(
        &format!("dse gemm {dim}³: pruning + memoization effect"),
        &["mode", "candidates", "simulated", "cache hits", "pruned", "median wall"],
    );
    t.row(vec![
        "pruned".into(),
        rep.stats.candidates.to_string(),
        rep.stats.simulated.to_string(),
        rep.stats.cache_hits.to_string(),
        rep.stats.pruned.to_string(),
        format!("{:.3?}", pruned.median),
    ]);
    t.row(vec![
        "exhaustive".into(),
        full.stats.candidates.to_string(),
        full.stats.simulated.to_string(),
        full.stats.cache_hits.to_string(),
        full.stats.pruned.to_string(),
        format!("{:.3?}", exhaustive.median),
    ]);
    print!("{}", t.render());

    let srep = explore_source(
        &mut FileSource::new(&big).expect("valid axes"),
        &streamed_cfg,
        None,
    )
    .expect("no checkpoint IO to fail");
    let mrep = explore_specs(big.enumerate().expect("in range"), workers, true);
    let fmt_rss = |r: Option<u64>| {
        r.map(|b| format!("{:.1} MiB", b as f64 / (1024.0 * 1024.0)))
            .unwrap_or_else(|| "n/a".into())
    };
    let mut t = Table::new(
        &format!("dse {big_n}-candidate param space: streamed vs materialized"),
        &["mode", "cand/s", "peak resident pts", "peak RSS (monotonic)", "median wall"],
    );
    let cand_per_s = |median: std::time::Duration| {
        let s = median.as_secs_f64();
        if s > 0.0 {
            format!("{:.0}", big_n as f64 / s)
        } else {
            "-".into()
        }
    };
    t.row(vec![
        "streamed".into(),
        cand_per_s(streamed.median),
        srep.stats.peak_resident.to_string(),
        fmt_rss(rss_streamed),
        format!("{:.3?}", streamed.median),
    ]);
    t.row(vec![
        "materialized".into(),
        cand_per_s(materialized.median),
        mrep.stats.peak_resident.to_string(),
        fmt_rss(rss_materialized),
        format!("{:.3?}", materialized.median),
    ]);
    print!("{}", t.render());

    assert_eq!(
        rep.stats.best_cycles, full.stats.best_cycles,
        "pruning must preserve the optimum"
    );
    assert!(rep.stats.simulated <= full.stats.simulated);
    assert_eq!(
        srep.stats.best_cycles, mrep.stats.best_cycles,
        "streaming must preserve the optimum"
    );
    assert!(
        srep.stats.peak_resident < mrep.stats.peak_resident,
        "streaming must hold fewer points than materializing \
         ({} vs {})",
        srep.stats.peak_resident,
        mrep.stats.peak_resident
    );
}
