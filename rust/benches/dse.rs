//! DSE engine perf: what the analytical pre-filter and the memo buy on a
//! real sweep.  Pruned + memoized exploration vs the exhaustive baseline
//! over the same candidate space — the speedup is the headline number of
//! the enumerate→prune→simulate pipeline.
//!
//! Run: `cargo bench --bench dse`

use acadl::dse::{explore, DseSpace};
use acadl::metrics::Table;
use acadl::util::bench::Bench;

fn main() {
    let dim = 16;
    let mut space = DseSpace::quick(dim);
    // Both backends so the memo has aliases to collapse.
    space.backends = vec![Default::default(), acadl::sim::BackendKind::EventDriven];
    let workers = 4;

    let mut b = Bench::new("dse");
    let n = space.enumerate().len() as u64;

    let pruned = b
        .time("pruned+memoized", Some(n), || explore(&space, workers, true))
        .clone();
    let exhaustive = b
        .time("exhaustive", Some(n), || explore(&space, workers, false))
        .clone();

    // One representative run for the stats table.
    let rep = explore(&space, workers, true);
    let full = explore(&space, workers, false);
    let mut t = Table::new(
        &format!("dse gemm {dim}³: pruning + memoization effect"),
        &["mode", "candidates", "simulated", "cache hits", "pruned", "median wall"],
    );
    t.row(vec![
        "pruned".into(),
        rep.stats.candidates.to_string(),
        rep.stats.simulated.to_string(),
        rep.stats.cache_hits.to_string(),
        rep.stats.pruned.to_string(),
        format!("{:.3?}", pruned.median),
    ]);
    t.row(vec![
        "exhaustive".into(),
        full.stats.candidates.to_string(),
        full.stats.simulated.to_string(),
        full.stats.cache_hits.to_string(),
        full.stats.pruned.to_string(),
        format!("{:.3?}", exhaustive.median),
    ]);
    print!("{}", t.render());

    assert_eq!(
        rep.stats.best_cycles, full.stats.best_cycles,
        "pruning must preserve the optimum"
    );
    assert!(rep.stats.simulated <= full.stats.simulated);
}
