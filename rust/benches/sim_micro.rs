//! E5 — engine micro-benchmarks: simulated-cycles-per-second throughput of
//! the timing engine across the model zoo, plus the §Perf hot-path
//! numbers (the optimization target of EXPERIMENTS.md §Perf).
//!
//! Run: `cargo bench --bench sim_micro`

use acadl::arch::gamma::GammaConfig;
use acadl::arch::oma::OmaConfig;
use acadl::arch::systolic::SystolicConfig;
use acadl::mapping::gamma_gemm::{gamma_gemm, GammaGemmOpts};
use acadl::mapping::gemm::{oma_gemm_listing5, GemmParams};
use acadl::mapping::systolic_gemm::systolic_gemm;
use acadl::sim::engine::Engine;
use acadl::sim::functional::FunctionalSim;
use acadl::sim::BackendKind;
use acadl::util::bench::Bench;

fn main() {
    let mut bench = Bench::new("sim_micro");

    // OMA: branchy scalar loop code (the fetch/issue/branch path).
    {
        let m = OmaConfig::default().build().expect("oma");
        let p = GemmParams::new(8, 8, 8);
        let prog = oma_gemm_listing5(&m, &p).expect("asm");
        let cycles = {
            let mut e = Engine::new(&m.ag, &prog).expect("engine");
            e.run(1_000_000_000).expect("run").cycles
        };
        bench.time("oma_listing5_timed (cycles/s)", Some(cycles), || {
            let mut e = Engine::new(&m.ag, &prog).expect("engine");
            e.run(1_000_000_000).expect("run").cycles
        });
        bench.time("oma_listing5_timed/event (cycles/s)", Some(cycles), || {
            let mut e = Engine::with_backend(&m.ag, &prog, BackendKind::EventDriven)
                .expect("engine");
            let got = e.run(1_000_000_000).expect("run").cycles;
            assert_eq!(got, cycles, "backends must agree");
            got
        });
        let instrs = {
            let mut f = FunctionalSim::new(&m.ag);
            f.run(&prog, 100_000_000).expect("func").instructions
        };
        bench.time("oma_listing5_functional (instr/s)", Some(instrs), || {
            let mut f = FunctionalSim::new(&m.ag);
            f.run(&prog, 100_000_000).expect("func").instructions
        });
    }

    // Systolic 8×8: wide out-of-order issue (the scoreboard path).
    {
        let m = SystolicConfig::new(8, 8).build().expect("systolic");
        let p = GemmParams::new(16, 16, 16);
        let prog = systolic_gemm(&m, &p);
        let cycles = {
            let mut e = Engine::new(&m.ag, &prog).expect("engine");
            e.run(1_000_000_000).expect("run").cycles
        };
        bench.time("systolic8x8_timed (cycles/s)", Some(cycles), || {
            let mut e = Engine::new(&m.ag, &prog).expect("engine");
            e.run(1_000_000_000).expect("run").cycles
        });
        bench.time("systolic8x8_timed/event (cycles/s)", Some(cycles), || {
            let mut e = Engine::with_backend(&m.ag, &prog, BackendKind::EventDriven)
                .expect("engine");
            let got = e.run(1_000_000_000).expect("run").cycles;
            assert_eq!(got, cycles, "backends must agree");
            got
        });
    }

    // Γ̈: fused-tensor ops + DRAM path.
    {
        let m = GammaConfig::new(2).build().expect("gamma");
        let p = GemmParams::new(16, 16, 16);
        let prog = gamma_gemm(&m, &p, GammaGemmOpts::default());
        let cycles = {
            let mut e = Engine::new(&m.ag, &prog).expect("engine");
            e.run(1_000_000_000).expect("run").cycles
        };
        bench.time("gamma2u_timed (cycles/s)", Some(cycles), || {
            let mut e = Engine::new(&m.ag, &prog).expect("engine");
            e.run(1_000_000_000).expect("run").cycles
        });
        // Γ̈ is the DRAM-bound case: the event backend's idle-cycle skip
        // shows up here (cycle counts must not move).
        bench.time("gamma2u_timed/event (cycles/s)", Some(cycles), || {
            let mut e = Engine::with_backend(&m.ag, &prog, BackendKind::EventDriven)
                .expect("engine");
            let got = e.run(1_000_000_000).expect("run").cycles;
            assert_eq!(got, cycles, "backends must agree");
            got
        });
    }

    // Engine construction cost (matters for the coordinator's job rate).
    {
        let m = SystolicConfig::new(8, 8).build().expect("systolic");
        let p = GemmParams::new(8, 8, 8);
        let prog = systolic_gemm(&m, &p);
        bench.time("engine_new_systolic8x8", None, || {
            Engine::new(&m.ag, &prog).expect("engine")
        });
    }

    bench.write_json_if_requested();
}
