//! E8 — the abstraction-level trade-off (§3/§4: "modeling at different
//! abstraction levels enables fast prototyping"): the same MLP workload on
//! the scalar-level OMA, the scalar-level systolic array, and the
//! fused-tensor-level Γ̈ — modeled cycles, dynamic instruction counts, and
//! simulator wall time.  Fewer, coarser instructions ⇒ faster simulation:
//! the paper's reason for supporting all three levels in one language.
//!
//! Run: `cargo bench --bench abstraction_levels`

use std::time::Instant;

use acadl::arch::gamma::GammaConfig;
use acadl::arch::oma::OmaConfig;
use acadl::arch::systolic::SystolicConfig;
use acadl::coordinator::TargetSpec;
use acadl::dnn::graph::DnnGraph;
use acadl::dnn::lowering::{lower_graph, run_schedule, SimMode};
use acadl::mapping::uma::{Machine, TargetConfig};
use acadl::metrics::Table;

fn main() {
    let graph = DnnGraph::mlp_small();
    let batch = 8;
    let x = graph.input_batch(batch);
    let want = graph.forward_ref(&x, batch);

    let targets: Vec<(&str, &str, Machine)> = vec![
        (
            "oma",
            "scalar",
            TargetConfig::Oma(OmaConfig::default()).build().unwrap(),
        ),
        (
            "systolic 4x4",
            "scalar (spatial)",
            TargetConfig::Systolic(SystolicConfig::new(4, 4))
                .build()
                .unwrap(),
        ),
        (
            "Γ̈ 2u",
            "fused tensor",
            TargetConfig::Gamma(GammaConfig::new(2)).build().unwrap(),
        ),
    ];

    let mut table = Table::new(
        &format!("E8: {} (batch {batch}) across abstraction levels", graph.name),
        &["target", "level", "dyn instrs", "cycles", "sim wall", "max |Δ|"],
    );
    for (name, level, machine) in &targets {
        let lowered = lower_graph(machine, &graph, batch).expect("lower");
        let t0 = Instant::now();
        let rep = run_schedule(
            machine,
            &lowered,
            &x,
            SimMode::Timed(Default::default()),
            2_000_000_000,
        )
        .expect("schedule");
        let wall = t0.elapsed();
        let diff = rep
            .output
            .iter()
            .zip(&want)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(diff < 1e-2, "{name}: wrong numerics");
        table.row(vec![
            name.to_string(),
            level.to_string(),
            rep.total_instructions.to_string(),
            rep.total_cycles.to_string(),
            format!("{wall:.2?}"),
            format!("{diff:.1e}"),
        ]);
    }
    print!("{}", table.render());
    println!("(one fused-tensor gemm instruction replaces ~512 scalar mac+load+store");
    println!(" instructions — the simulation-speed argument for ACADL's levels)");
    let _ = TargetSpec::Oma {
        cache: true,
        mac_latency: None,
    };
}
