//! Platform perf — parallel speedup of the partitioned simulator: the
//! tiny transformer (seq 8) sharded across a 4-chip 2×2-systolic
//! platform with 8 pipelined microbatches, simulated at 1, 2, and 4
//! worker threads.  Cycle counts are identical at every thread count
//! (the backend-equivalence invariant); only wall-clock time moves, so
//! `items = total simulated cycles` makes cycles/s the speedup axis the
//! perf trajectory records.
//!
//! Run: `cargo bench --bench platform`

use acadl::arch::platform::PlatformDesc;
use acadl::arch::systolic::SystolicConfig;
use acadl::dnn::lowering::SimMode;
use acadl::dnn::{partition_graph, DnnGraph};
use acadl::mapping::uma::{Machine, TargetConfig};
use acadl::sim::{run_platform, BackendKind};
use acadl::util::bench::Bench;

fn main() {
    let graph = DnnGraph::tiny_transformer();
    let batch = 8;
    let machine = TargetConfig::Systolic(SystolicConfig::new(2, 2))
        .build()
        .unwrap();
    let desc = PlatformDesc::new(4).with_microbatches(8);
    let plan = partition_graph(&graph, batch, desc.chips).unwrap();
    let machines: Vec<&Machine> = (0..plan.stages.len()).map(|_| &machine).collect();
    let mode = SimMode::Timed(BackendKind::ParallelEvent);

    let mut b = Bench::new("platform");
    let mut cycles = None;
    for threads in [1usize, 2, 4] {
        let rep = run_platform(
            &machines, &graph, &plan, batch, &desc, mode, threads, 500_000_000,
        )
        .unwrap();
        // The equivalence invariant, re-checked where the speedup is
        // measured: every thread count reports the same makespan.
        let c = *cycles.get_or_insert(rep.total_cycles);
        assert_eq!(rep.total_cycles, c, "threads={threads} moved the cycle count");
        b.time(
            &format!("quad_tf_seq8_threads{threads} (cycles/s)"),
            Some(c),
            || {
                run_platform(
                    &machines, &graph, &plan, batch, &desc, mode, threads, 500_000_000,
                )
                .unwrap()
                .total_cycles
            },
        );
    }
    b.write_json_if_requested();
}
