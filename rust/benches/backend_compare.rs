//! §Perf — cycle-stepped vs event-driven backend wall-clock on the
//! workloads the event queue was built for: DRAM-bound GeMMs whose
//! functional units spend most cycles stalled on t_RCD/t_RP/t_RAS and
//! long MAC latencies.  Cycle counts are asserted identical per pair, so
//! the trajectory tracks a pure scheduling win.
//!
//! Run: `cargo bench --bench backend_compare`

use acadl::arch::gamma::GammaConfig;
use acadl::arch::oma::{DataMem, OmaConfig};
use acadl::isa::program::Program;
use acadl::mapping::gamma_gemm::{gamma_gemm, GammaGemmOpts};
use acadl::mapping::gemm::{oma_tiled_gemm, GemmParams};
use acadl::sim::{BackendKind, Engine};
use acadl::util::bench::Bench;

fn pair(
    bench: &mut Bench,
    name: &str,
    ag: &acadl::acadl_core::graph::Ag,
    prog: &Program,
    max_cycles: u64,
) {
    let cycles = {
        let mut e = Engine::new(ag, prog).expect("engine");
        e.run(max_cycles).expect("run").cycles
    };
    bench.time(&format!("{name}/cycle (cycles/s)"), Some(cycles), || {
        let mut e =
            Engine::with_backend(ag, prog, BackendKind::CycleStepped).expect("engine");
        e.run(max_cycles).expect("run").cycles
    });
    bench.time(&format!("{name}/event (cycles/s)"), Some(cycles), || {
        let mut e =
            Engine::with_backend(ag, prog, BackendKind::EventDriven).expect("engine");
        let got = e.run(max_cycles).expect("run").cycles;
        assert_eq!(got, cycles, "{name}: backends must agree on cycles");
        got
    });
}

fn main() {
    let mut bench = Bench::new("backend_compare");

    // DRAM-backed OMA: every load/store pays banked row-buffer latency
    // through a single MAU — the canonical memory-bound scalar loop.
    {
        let m = OmaConfig {
            dmem: DataMem::Dram,
            cache: None,
            ..OmaConfig::default()
        }
        .build()
        .expect("oma+dram");
        let p = GemmParams::new(8, 8, 8);
        let prog = oma_tiled_gemm(&m, &p).expect("codegen");
        pair(&mut bench, "oma_dram_gemm8", &m.ag, &prog, 2_000_000_000);
    }

    // Slow-SRAM OMA: uniform 60-cycle loads — long deterministic stalls,
    // the best case for idle-cycle skipping.
    {
        let m = OmaConfig {
            dmem: DataMem::Sram { latency: 60 },
            cache: None,
            ..OmaConfig::default()
        }
        .build()
        .expect("oma+slow-sram");
        let p = GemmParams::new(8, 8, 8);
        let prog = oma_tiled_gemm(&m, &p).expect("codegen");
        pair(&mut bench, "oma_sram60_gemm8", &m.ag, &prog, 2_000_000_000);
    }

    // Γ̈: fused tensor ops streaming tiles through DRAM.
    {
        let m = GammaConfig::new(2).build().expect("gamma");
        let p = GemmParams::new(24, 24, 24);
        let prog = gamma_gemm(&m, &p, GammaGemmOpts::default());
        pair(&mut bench, "gamma2u_gemm24", &m.ag, &prog, 2_000_000_000);
    }

    bench.write_json_if_requested();

    // Supervision overhead on the same DRAM-bound loop: `no_token` is
    // the production hot path (the probe must cost a single branch —
    // compare against backend_compare/oma_dram_gemm8 across PRs);
    // `armed_token` carries a live deadline that never trips (the
    // countdown amortizes `Instant::now` to every check interval); and
    // `cancel_latency` measures expired-deadline → structured abort.
    let mut sup = Bench::new("supervisor");
    {
        use acadl::util::cancel::{install, CancelToken};
        let m = OmaConfig {
            dmem: DataMem::Dram,
            cache: None,
            ..OmaConfig::default()
        }
        .build()
        .expect("oma+dram");
        let p = GemmParams::new(8, 8, 8);
        let prog = oma_tiled_gemm(&m, &p).expect("codegen");
        let cycles = {
            let mut e = Engine::new(&m.ag, &prog).expect("engine");
            e.run(2_000_000_000).expect("run").cycles
        };
        sup.time("no_token (cycles/s)", Some(cycles), || {
            let mut e = Engine::new(&m.ag, &prog).expect("engine");
            e.run(2_000_000_000).expect("run").cycles
        });
        sup.time("armed_token (cycles/s)", Some(cycles), || {
            let _g = install(CancelToken::with_deadline(std::time::Duration::from_secs(
                3600,
            )));
            let mut e = Engine::new(&m.ag, &prog).expect("engine");
            let got = e.run(2_000_000_000).expect("run").cycles;
            assert_eq!(got, cycles, "an untripped token must not change cycles");
            got
        });
        sup.time("cancel_latency", None, || {
            let _g = install(CancelToken::with_deadline(std::time::Duration::ZERO));
            let mut e = Engine::new(&m.ag, &prog).expect("engine");
            e.run(2_000_000_000)
                .expect_err("expired deadline must abort the run")
        });
    }
    sup.write_json_if_requested();

    // Tracing overhead on the same DRAM-bound loop: `off` is the
    // production hot path (the sink seam must cost one predictable
    // branch — compare against backend_compare/oma_dram_gemm8 across
    // PRs); `on` records every FU span, port transaction, and counter
    // sample.  Cycle counts are asserted identical — tracing observes,
    // never perturbs.
    let mut trace = Bench::new("trace");
    {
        let m = OmaConfig {
            dmem: DataMem::Dram,
            cache: None,
            ..OmaConfig::default()
        }
        .build()
        .expect("oma+dram");
        let p = GemmParams::new(8, 8, 8);
        let prog = oma_tiled_gemm(&m, &p).expect("codegen");
        let cycles = {
            let mut e = Engine::new(&m.ag, &prog).expect("engine");
            e.run(2_000_000_000).expect("run").cycles
        };
        trace.time("off (cycles/s)", Some(cycles), || {
            let mut e = Engine::new(&m.ag, &prog).expect("engine");
            e.run(2_000_000_000).expect("run").cycles
        });
        trace.time("on (cycles/s)", Some(cycles), || {
            let mut e = Engine::new(&m.ag, &prog).expect("engine");
            e.attach_trace();
            let got = e.run(2_000_000_000).expect("run").cycles;
            assert_eq!(got, cycles, "tracing must not change cycles");
            let tr = e.take_trace().expect("trace");
            assert!(!tr.fu_spans.is_empty(), "trace recorded spans");
            got
        });
    }
    trace.write_json_if_requested();
}
