//! E7 — ACADL simulation vs the §2 analytical baselines: a ScaleSim-style
//! output-stationary formula and the roofline floor, on identical systolic
//! configurations.  The shape to reproduce: the simulation tracks the
//! analytical trend but exposes effects the formulas cannot (issue
//! bandwidth, memory ports).
//!
//! Run: `cargo bench --bench baselines`

use acadl::analytical::{scalesim_cycles, scalesim_utilization, Roofline};
use acadl::arch::systolic::SystolicConfig;
use acadl::mapping::gemm::GemmParams;
use acadl::mapping::systolic_gemm::systolic_gemm;
use acadl::metrics::Table;
use acadl::sim::engine::Engine;

fn main() {
    let mut table = Table::new(
        "E7: ACADL sim vs ScaleSim-style formula vs roofline",
        &["config", "workload", "sim", "scalesim", "ratio", "roofline", "ss util"],
    );
    for (edge, dim) in [(4usize, 16usize), (4, 32), (8, 32), (8, 64)] {
        let p = GemmParams::new(dim, dim, dim);
        let machine = SystolicConfig::new(edge, edge).build().expect("build");
        let prog = systolic_gemm(&machine, &p);
        let mut engine = Engine::new(&machine.ag, &prog).expect("engine");
        let sim = engine.run(2_000_000_000).expect("run").cycles;
        let ss = scalesim_cycles(&p, edge, edge);
        let rl = Roofline {
            macs_per_cycle: (edge * edge) as u64,
            // loads stream through rows+cols load units, 1 word each.
            words_per_cycle: (2 * edge) as u64,
            capacity_words: None,
        }
        .gemm_cycles(&p);
        table.row(vec![
            format!("{edge}x{edge}"),
            format!("{dim}³"),
            sim.to_string(),
            ss.to_string(),
            format!("{:.2}x", sim as f64 / ss as f64),
            rl.to_string(),
            format!("{:.1}%", scalesim_utilization(&p, edge, edge) * 100.0),
        ]);
    }
    print!("{}", table.render());
    println!("(sim ≥ roofline always; sim/scalesim ratio is the cost of the effects");
    println!(" the closed form ignores: fetch bandwidth, ports, dependency timing)");
}
