//! E4 — Γ̈ (§4.3, Listing 4): the literal Listing-4 program's cycle count,
//! and unit-count scaling on a multi-tile GeMM showing the out-of-order
//! parallel issue the paper claims ("instructions intended for different
//! hardware components are issued in parallel and executed out-of-order").
//!
//! Run: `cargo bench --bench gamma`

use acadl::arch::gamma::GammaConfig;
use acadl::mapping::gamma_gemm::{gamma_gemm, gamma_listing4_program, GammaGemmOpts};
use acadl::mapping::gemm::GemmParams;
use acadl::metrics::Table;
use acadl::sim::engine::Engine;

fn main() {
    // Part 1: the literal Listing-4 program (8×8 gemm + ReLU, scratchpad
    // resident).
    let machine = GammaConfig::default().build().expect("build");
    let prog = gamma_listing4_program(&machine);
    let mut engine = Engine::new(&machine.ag, &prog).expect("engine");
    let stats = engine.run(1_000_000).expect("run");
    println!(
        "Listing 4 (8×8 gemm + ReLU from spad): {} instructions, {} cycles, IPC {:.2}\n",
        stats.retired,
        stats.cycles,
        stats.ipc()
    );

    // Part 2: unit scaling on a 32×32×32 GeMM (16 independent tiles),
    // with and without Listing 4's scratchpad-resident A strips.
    let p = GemmParams::new(32, 32, 32);
    let mut table = Table::new(
        "E4: Γ̈ unit scaling, gemm 32³ (+ReLU)",
        &["units", "spad", "instrs", "cycles", "speedup", "DRAM reqs", "gemm-FU util"],
    );
    let mut baseline = None;
    for units in [1usize, 2, 4, 8] {
        for use_spad in [false, true] {
            let machine = GammaConfig::new(units).build().expect("build");
            let prog = gamma_gemm(
                &machine,
                &p,
                GammaGemmOpts {
                    relu: true,
                    bias_base: None,
                    use_spad,
                },
            );
            let mut engine = Engine::new(&machine.ag, &prog).expect("engine");
            let stats = engine.run(2_000_000_000).expect("run");
            let base = *baseline.get_or_insert(stats.cycles);
            let mm_busy: u64 = stats
                .fu_busy
                .iter()
                .filter(|(n, _)| n.starts_with("matMulFu"))
                .map(|(_, b)| b)
                .sum();
            let dram = stats
                .storages
                .iter()
                .find(|s| s.name == "dram0")
                .map(|s| s.requests)
                .unwrap_or(0);
            table.row(vec![
                units.to_string(),
                if use_spad { "yes" } else { "no" }.into(),
                stats.retired.to_string(),
                stats.cycles.to_string(),
                format!("{:.2}x", base as f64 / stats.cycles as f64),
                dram.to_string(),
                format!(
                    "{:.1}%",
                    100.0 * mm_busy as f64 / (units as u64 * stats.cycles) as f64
                ),
            ]);
        }
    }
    print!("{}", table.render());
}
