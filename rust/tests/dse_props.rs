//! DSE-layer properties:
//!
//! * **Soundness of the pre-filter bound** — simulated cycles (both
//!   backends) never undercut the per-target `Roofline` lower bound, for
//!   randomized GeMMs across the arch zoo.  This is the property that
//!   makes analytical pruning safe.
//! * **Pruning preserves the optimum** — on a small exhaustively
//!   enumerated sweep, the pruned exploration finds exactly the best
//!   cycle count the exhaustive one finds.
//! * **Memo correctness** — aliased candidates (second backend) are
//!   cache-served with identical cycles.

use acadl::coordinator::job::{execute, JobSpec, SimModeSpec, TargetSpec, Workload};
use acadl::dse::{explore, lower_bound_cycles, DseSpace};
use acadl::mapping::gemm::LoopOrder;
use acadl::sim::backend::BackendKind;
use acadl::util::prop::{forall, Gen};

fn random_target(g: &mut Gen) -> TargetSpec {
    match g.usize(0, 3) {
        0 => TargetSpec::Oma {
            cache: g.bool(),
            mac_latency: None,
        },
        1 => TargetSpec::Systolic {
            rows: g.usize(1, 2) * 2,
            cols: g.usize(1, 2) * 2,
        },
        _ => TargetSpec::Gamma {
            units: g.usize(1, 2),
        },
    }
}

#[test]
fn prop_sim_cycles_never_undercut_roofline_bound() {
    forall(
        "timed cycles >= roofline bound (both backends, arch zoo)",
        12,
        |g| {
            let target = random_target(g);
            let (m, k, n) = (g.usize(2, 10), g.usize(2, 10), g.usize(2, 10));
            let tile = if g.bool() { Some(g.usize(2, 4)) } else { None };
            let order = *g.choose(&LoopOrder::ALL);
            JobSpec {
                id: 0,
                target,
                workload: Workload::Gemm {
                    m,
                    k,
                    n,
                    tile,
                    order: Some(order),
                },
                mode: SimModeSpec::Timed,
                backend: BackendKind::CycleStepped,
                max_cycles: 200_000_000,
                platform: None,
                deadline_ms: None,
            }
        },
        |spec| {
            let bound = lower_bound_cycles(spec);
            for backend in BackendKind::ALL {
                let r = execute(&JobSpec {
                    backend,
                    ..spec.clone()
                });
                if let Some(e) = &r.error {
                    return Err(format!("{}: job failed: {e}", r.target));
                }
                if r.cycles < bound {
                    return Err(format!(
                        "{} ({}): simulated {} cycles < bound {bound}",
                        r.target,
                        backend.name(),
                        r.cycles
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn dse_pruning_never_discards_the_optimum() {
    // Small, exhaustively enumerable space: scalar OMA variants plus tiny
    // arrays — the scalar tail is exactly what pruning should cut.
    let mut space = DseSpace::quick(6);
    space.backends = vec![BackendKind::EventDriven];
    let exhaustive = explore(&space, 2, false);
    let pruned = explore(&space, 2, true);

    assert_eq!(exhaustive.stats.pruned, 0);
    assert_eq!(
        exhaustive.stats.evaluated,
        exhaustive.stats.candidates,
        "exhaustive mode evaluates everything"
    );
    assert_eq!(
        pruned.stats.evaluated + pruned.stats.pruned,
        pruned.stats.candidates,
        "every candidate is evaluated or pruned"
    );
    assert_eq!(
        pruned.stats.best_cycles, exhaustive.stats.best_cycles,
        "pruning changed the optimum: {} vs {}",
        pruned.summary(),
        exhaustive.summary()
    );
    assert_eq!(pruned.stats.failed, 0, "{}", pruned.summary());
    // The pruned run must not simulate more than the exhaustive one.
    assert!(pruned.stats.simulated <= exhaustive.stats.simulated);
}

#[test]
fn dse_memo_serves_backend_aliases_with_identical_cycles() {
    let mut space = DseSpace::quick(6);
    space.include_oma = false;
    space.backends = vec![BackendKind::CycleStepped, BackendKind::EventDriven];
    let rep = explore(&space, 2, false);
    assert!(rep.stats.cache_hits > 0, "{}", rep.summary());
    // Every (target, workload) pair appears once per backend with the
    // same cycles — one simulated, one cache-served.
    for p in &rep.points {
        let twin = rep
            .points
            .iter()
            .find(|q| {
                q.spec.id != p.spec.id
                    && q.result.target == p.result.target
                    && q.result.workload == p.result.workload
            })
            .expect("every candidate has its other-backend twin");
        assert_eq!(twin.result.cycles, p.result.cycles);
    }
}
