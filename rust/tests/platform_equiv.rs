//! Platform / parallel-backend equivalence: the `ParallelEvent` backend
//! must report cycle counts **identical** to `EventDriven` on every zoo
//! machine, and a partitioned platform run must report identical cycles,
//! per-stage busy counts, and functional outputs at every thread count —
//! the same backend-equivalence discipline `tests/backend_equiv.rs`
//! established for the single-chip schedulers, extended to the
//! multi-chip parallel simulator.
//!
//! Also covers: functional outputs against the graph's `forward_ref` per
//! microbatch, randomized platform shapes (chips × hop latency ×
//! microbatches × workload), deadlock freedom with zero-latency fabric
//! edges, and the pipelining win of 4 chips over 1.

use acadl::arch::oma::OmaConfig;
use acadl::arch::platform::PlatformDesc;
use acadl::arch::systolic::SystolicConfig;
use acadl::dnn::lowering::SimMode;
use acadl::dnn::{partition_graph, DnnGraph};
use acadl::mapping::gemm::{GemmLayout, GemmParams};
use acadl::mapping::systolic_gemm::systolic_gemm;
use acadl::mapping::uma::{Machine, TargetConfig};
use acadl::sim::{
    microbatch_input, run_platform, run_platform_traced, BackendKind, Engine, PlatformReport,
    PlatformTrace,
};
use acadl::util::prop::{forall, Gen};

// ------------------------------------------------ backend equivalence

/// `ParallelEvent` is the event-driven scheduler behind a partitioned
/// front — on a single core it must be *the same simulation*: every
/// statistic and the final architectural state agree with `EventDriven`.
#[test]
fn parallel_backend_matches_event_on_systolic_gemm() {
    let m = SystolicConfig::new(2, 2).build().unwrap();
    let p = GemmParams::new(6, 6, 6);
    let prog = systolic_gemm(&m, &p);
    let layout = GemmLayout::at(m.dmem_base(), &p);
    let mut g = Gen::new(0x9A7);
    let a = g.vec_f32(36, -2.0, 2.0);
    let b = g.vec_f32(36, -2.0, 2.0);
    let run = |backend: BackendKind| {
        let mut e = Engine::with_backend(&m.ag, &prog, backend).unwrap();
        layout.load_inputs(&p, &mut e.mem, &a, &b);
        let stats = e.run(200_000_000).unwrap();
        (stats, layout.read_c(&p, &e.mem))
    };
    let (es, ec) = run(BackendKind::EventDriven);
    let (ps, pc) = run(BackendKind::ParallelEvent);
    assert_eq!(ps.cycles, es.cycles, "total cycles");
    assert_eq!(ps.retired, es.retired, "retired instructions");
    assert_eq!(ps.fu_busy, es.fu_busy, "per-FU busy cycles");
    assert_eq!(pc, ec, "C matrices");
}

/// Randomized scalar programs on the OMA: `ParallelEvent` and
/// `EventDriven` agree on cycles, retirement, and final register state.
#[test]
fn prop_parallel_backend_matches_event_on_random_programs() {
    use acadl::isa::assembler::assemble;
    let m = OmaConfig::default().build().unwrap();
    forall(
        "parallel ≡ event on random OMA programs",
        24,
        |g| {
            let mut src = String::new();
            for _ in 0..g.usize(1, 16) {
                match g.usize(0, 3) {
                    0 => src.push_str(&format!(
                        "movi #{} => r{}\n",
                        g.int(-99, 99),
                        g.usize(0, 7)
                    )),
                    1 => src.push_str(&format!(
                        "add r{}, r{} => r{}\n",
                        g.usize(0, 7),
                        g.usize(0, 7),
                        g.usize(0, 7)
                    )),
                    2 => src.push_str(&format!(
                        "mac r{}, r{} => r{}\n",
                        g.usize(0, 7),
                        g.usize(0, 7),
                        g.usize(8, 12)
                    )),
                    _ => src.push_str("nop\n"),
                }
            }
            src.push_str("halt\n");
            src
        },
        |src| {
            let p = assemble(&m.ag, src, 0).map_err(|e| e.to_string())?;
            let mut event = Engine::with_backend(&m.ag, &p, BackendKind::EventDriven)
                .map_err(|e| e.to_string())?;
            let es = event.run(10_000_000).map_err(|e| e.to_string())?;
            let mut par = Engine::with_backend(&m.ag, &p, BackendKind::ParallelEvent)
                .map_err(|e| e.to_string())?;
            let ps = par.run(10_000_000).map_err(|e| e.to_string())?;
            if ps.cycles != es.cycles || ps.retired != es.retired {
                return Err(format!(
                    "cycles {} vs {}, retired {} vs {}",
                    ps.cycles, es.cycles, ps.retired, es.retired
                ));
            }
            if par.regs != event.regs {
                return Err("register state differs".into());
            }
            Ok(())
        },
    );
}

// ------------------------------------------------ platform determinism

fn platform_run(
    machine: &Machine,
    graph: &DnnGraph,
    batch: usize,
    desc: &PlatformDesc,
    mode: SimMode,
    threads: usize,
) -> PlatformReport {
    let plan = partition_graph(graph, batch, desc.chips).unwrap();
    let machines: Vec<&Machine> = (0..plan.stages.len()).map(|_| machine).collect();
    run_platform(&machines, graph, &plan, batch, desc, mode, threads, 500_000_000).unwrap()
}

fn assert_reports_equal(a: &PlatformReport, b: &PlatformReport, what: &str) {
    assert_eq!(a.total_cycles, b.total_cycles, "{what}: total cycles");
    assert_eq!(
        a.total_instructions, b.total_instructions,
        "{what}: instructions"
    );
    assert_eq!(a.outputs, b.outputs, "{what}: functional outputs");
    assert_eq!(a.stages.len(), b.stages.len(), "{what}: stage count");
    for (x, y) in a.stages.iter().zip(&b.stages) {
        assert_eq!(x.busy_cycles, y.busy_cycles, "{what}: {} busy", x.name);
        assert_eq!(x.instructions, y.instructions, "{what}: {} instrs", x.name);
    }
}

/// The tentpole invariant: the sharded transformer on a 4-chip systolic
/// platform reports **identical** cycles, per-stage busy counts, and
/// outputs at threads ∈ {1, 2, 8} — and the `ParallelEvent` stage
/// backend matches `EventDriven` cycle-for-cycle.
#[test]
fn sharded_transformer_thread_counts_agree() {
    let g = DnnGraph::tiny_transformer();
    let machine = TargetConfig::Systolic(SystolicConfig::new(2, 2))
        .build()
        .unwrap();
    let desc = PlatformDesc::new(4).with_microbatches(4);
    let reference = platform_run(
        &machine,
        &g,
        8,
        &desc,
        SimMode::Timed(BackendKind::EventDriven),
        1,
    );
    assert!(reference.total_cycles > 0);
    for threads in [1usize, 2, 8] {
        let r = platform_run(
            &machine,
            &g,
            8,
            &desc,
            SimMode::Timed(BackendKind::ParallelEvent),
            threads,
        );
        assert_reports_equal(&r, &reference, &format!("threads {threads}"));
    }
    // Every microbatch's output is the reference forward pass on its
    // own rotated input.
    for (b, out) in reference.outputs.iter().enumerate() {
        let x = microbatch_input(&g, 8, b);
        let want = g.forward_ref(&x, 8);
        assert_eq!(out.len(), want.len(), "microbatch {b}");
        for (o, w) in out.iter().zip(&want) {
            assert!((o - w).abs() < 1e-2, "microbatch {b}: {o} vs {w}");
        }
    }
}

/// Randomized platforms (chips, hop latency, microbatches, workload,
/// mode): one worker thread and three report identical results.
#[test]
fn prop_random_platforms_are_thread_count_independent() {
    let oma = TargetConfig::Oma(OmaConfig::default()).build().unwrap();
    let sys = TargetConfig::Systolic(SystolicConfig::new(2, 2))
        .build()
        .unwrap();
    forall(
        "threads 1 ≡ threads 3 over random platforms",
        10,
        |g| {
            (
                g.usize(1, 4),          // chips (clamped by legal cuts)
                g.int(0, 16) as u64,    // hop latency
                g.usize(1, 6),          // microbatches
                g.bool(),               // mlp_small vs tiny_transformer
                g.bool(),               // functional vs timed
            )
        },
        |&(chips, hop, micro, mlp, functional)| {
            let (graph, batch, machine) = if mlp {
                (DnnGraph::mlp_small(), 4, &oma)
            } else {
                (DnnGraph::tiny_transformer(), 8, &sys)
            };
            // Ask only for as many chips as the graph has legal cuts.
            let chips = if mlp { chips.min(2) } else { chips };
            let desc = PlatformDesc::new(chips)
                .with_hop_latency(hop)
                .with_microbatches(micro);
            let mode = if functional {
                SimMode::Functional
            } else {
                SimMode::Timed(BackendKind::EventDriven)
            };
            let a = platform_run(machine, &graph, batch, &desc, mode, 1);
            let b = platform_run(machine, &graph, batch, &desc, mode, 3);
            if a.total_cycles != b.total_cycles {
                return Err(format!(
                    "cycles {} vs {}",
                    a.total_cycles, b.total_cycles
                ));
            }
            if a.outputs != b.outputs {
                return Err("outputs differ across thread counts".into());
            }
            if a.total_instructions != b.total_instructions {
                return Err("instruction counts differ".into());
            }
            Ok(())
        },
    );
}

/// The platform trace comes from the deterministic serial recurrence, so
/// it must be **bit-identical** at every worker thread count (the same
/// discipline as the cycle counts) — and its cell spans must reconcile
/// exactly with the per-stage busy counts the report carries.
#[test]
fn platform_trace_is_thread_count_invariant_and_reconciles() {
    let g = DnnGraph::tiny_transformer();
    let machine = TargetConfig::Systolic(SystolicConfig::new(2, 2))
        .build()
        .unwrap();
    let desc = PlatformDesc::new(4).with_microbatches(4);
    let plan = partition_graph(&g, 8, desc.chips).unwrap();
    let machines: Vec<&Machine> = (0..plan.stages.len()).map(|_| &machine).collect();
    let mode = SimMode::Timed(BackendKind::EventDriven);
    let run = |threads: usize| {
        let mut tr = PlatformTrace::default();
        let rep = run_platform_traced(
            &machines,
            &g,
            &plan,
            8,
            &desc,
            mode,
            threads,
            500_000_000,
            Some(&mut tr),
        )
        .unwrap();
        (rep, tr)
    };
    let (rep1, tr1) = run(1);
    let (rep4, tr4) = run(4);
    assert_reports_equal(&rep1, &rep4, "traced threads 1 vs 4");
    assert_eq!(tr1, tr4, "platform traces differ across thread counts");

    assert_eq!(tr1.total_cycles, rep1.total_cycles, "trace makespan");
    assert_eq!(tr1.chips.len(), rep1.stages.len(), "one track group per chip");
    for (c, s) in tr1.chips.iter().zip(&rep1.stages) {
        assert_eq!(c, &s.name, "chip track names match stage reports");
    }
    let busy = tr1.stage_busy_totals();
    for (i, s) in rep1.stages.iter().enumerate() {
        assert_eq!(busy[i], s.busy_cycles, "Σ cell spans == {} busy", s.name);
    }
    // Every microbatch crosses every inter-stage fabric edge exactly once.
    assert_eq!(
        tr1.fabric.len(),
        (rep1.stages.len() - 1) * desc.microbatches,
        "fabric transfer count"
    );
    // And the untraced entry point reports the same run.
    let plain = platform_run(&machine, &g, 8, &desc, mode, 4);
    assert_reports_equal(&rep1, &plain, "traced vs untraced");
}

/// Zero-latency fabric edges: the conservative recurrence is a forward
/// substitution, so a hop latency of 0 (the classic conservative-PDES
/// zero-lookahead trap) must terminate with a sane makespan rather than
/// deadlock.
#[test]
fn zero_latency_fabric_terminates() {
    let g = DnnGraph::tiny_transformer();
    let machine = TargetConfig::Systolic(SystolicConfig::new(2, 2))
        .build()
        .unwrap();
    let desc = PlatformDesc::new(4)
        .with_hop_latency(0)
        .with_microbatches(4);
    let r = platform_run(
        &machine,
        &g,
        8,
        &desc,
        SimMode::Timed(BackendKind::ParallelEvent),
        4,
    );
    assert!(r.total_cycles > 0);
    // And it still matches the single-threaded run exactly.
    let serial = platform_run(
        &machine,
        &g,
        8,
        &desc,
        SimMode::Timed(BackendKind::EventDriven),
        1,
    );
    assert_reports_equal(&r, &serial, "zero-latency fabric");
}

/// The point of the platform: pipelining 8 microbatches across 4 chips
/// finishes sooner than queueing them through 1 chip.
#[test]
fn four_chips_beat_one_on_pipelined_transformer() {
    let g = DnnGraph::tiny_transformer();
    let machine = TargetConfig::Systolic(SystolicConfig::new(2, 2))
        .build()
        .unwrap();
    let mode = SimMode::Timed(BackendKind::EventDriven);
    let single = platform_run(
        &machine,
        &g,
        8,
        &PlatformDesc::new(1).with_microbatches(8),
        mode,
        1,
    );
    let quad = platform_run(
        &machine,
        &g,
        8,
        &PlatformDesc::new(4).with_microbatches(8),
        mode,
        2,
    );
    assert!(
        quad.total_cycles < single.total_cycles,
        "4 chips ({}) should beat 1 chip ({})",
        quad.total_cycles,
        single.total_cycles
    );
}
