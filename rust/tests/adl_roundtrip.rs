//! ACADL textual-frontend acceptance tests:
//!
//! * every zoo `.acadl` example elaborates to a graph **equivalent to its
//!   Rust-builder counterpart**,
//! * `parse(print(ag))` reproduces every builder graph exactly
//!   (round-trip), and printing is byte-idempotent,
//! * file-bound targets drive `simulate`-equivalent job execution with
//!   cycle counts identical to builder-constructed machines,
//! * a file's `param` block drives a DSE sweep end-to-end.

use acadl::adl::{ag_equiv, load_str, print_arch, print_elab, ElabArch};
use acadl::arch::eyeriss::EyerissConfig;
use acadl::arch::gamma::GammaConfig;
use acadl::arch::oma::OmaConfig;
use acadl::arch::systolic::SystolicConfig;
use acadl::arch::plasticine::PlasticineConfig;
use acadl::coordinator::job::{self, JobSpec, SimModeSpec, TargetSpec, Workload};
use acadl::sim::BackendKind;

fn example(name: &str) -> String {
    let path = format!("{}/../examples/{name}", env!("CARGO_MANIFEST_DIR"));
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {path}: {e}"))
}

/// Elaborate, round-trip through the printer, and check idempotence.
fn check_roundtrip(src: &str) -> ElabArch {
    let e = load_str(src).expect("source elaborates");
    let printed = print_elab(&e);
    let e2 = load_str(&printed).expect("canonical form elaborates");
    ag_equiv(&e.ag, &e2.ag).expect("round-trip graph is equivalent");
    assert_eq!(e2.target, e.target, "target binding survives round-trip");
    assert_eq!(e2.platform, e.platform, "platform block survives round-trip");
    assert_eq!(e2.params, e.params, "param axes survive round-trip");
    assert_eq!(print_elab(&e2), printed, "printing is byte-idempotent");
    e
}

#[test]
fn oma_example_matches_builder() {
    let e = check_roundtrip(&example("oma.acadl"));
    assert_eq!(
        e.target,
        Some(TargetSpec::Oma {
            cache: true,
            mac_latency: None
        })
    );
    assert_eq!(e.params.len(), 3);
    let built = OmaConfig::default().build().unwrap();
    ag_equiv(&e.ag, &built.ag).expect("oma.acadl ≡ OmaConfig::default()");
}

#[test]
fn systolic_example_matches_builder() {
    let e = check_roundtrip(&example("systolic_2x2.acadl"));
    assert_eq!(e.target, Some(TargetSpec::Systolic { rows: 2, cols: 2 }));
    let built = SystolicConfig::new(2, 2).build().unwrap();
    ag_equiv(&e.ag, &built.ag).expect("systolic_2x2.acadl ≡ SystolicConfig::new(2, 2)");
}

#[test]
fn gamma_example_matches_builder() {
    let e = check_roundtrip(&example("gamma_1u.acadl"));
    assert_eq!(e.target, Some(TargetSpec::Gamma { units: 1 }));
    let built = GammaConfig::new(1).build().unwrap();
    ag_equiv(&e.ag, &built.ag).expect("gamma_1u.acadl ≡ GammaConfig::new(1)");
}

#[test]
fn eyeriss_example_matches_builder() {
    let e = check_roundtrip(&example("eyeriss_2x2.acadl"));
    assert_eq!(e.target, None, "no code generator targets eyeriss");
    let built = EyerissConfig {
        rows: 2,
        cols: 2,
        dma_units: 1,
        ..EyerissConfig::default()
    }
    .build()
    .unwrap();
    ag_equiv(&e.ag, &built.ag).expect("eyeriss_2x2.acadl ≡ EyerissConfig{2,2,1}");
}

#[test]
fn plasticine_example_matches_builder() {
    let e = check_roundtrip(&example("plasticine_2s.acadl"));
    assert_eq!(e.target, None, "no code generator targets plasticine");
    let built = PlasticineConfig {
        stages: 2,
        ..PlasticineConfig::default()
    }
    .build()
    .unwrap();
    ag_equiv(&e.ag, &built.ag).expect("plasticine_2s.acadl ≡ PlasticineConfig{stages: 2}");
}

#[test]
fn platform_example_binds_target_and_platform() {
    use acadl::arch::platform::PlatformDesc;
    let e = check_roundtrip(&example("platform_quad.acadl"));
    assert_eq!(e.target, Some(TargetSpec::Systolic { rows: 2, cols: 2 }));
    assert_eq!(
        e.platform,
        Some(PlatformDesc::new(4).with_hop_latency(4).with_microbatches(8))
    );
    // Same chip as the systolic_2x2 example — only the platform wrapper
    // (and the name) differ.
    let built = SystolicConfig::new(2, 2).build().unwrap();
    ag_equiv(&e.ag, &built.ag).expect("platform_quad.acadl ≡ SystolicConfig::new(2, 2)");
}

#[test]
fn printer_roundtrips_every_builder_graph() {
    // parse(print(ag)) ≡ ag over the whole zoo, independent of the
    // committed files — including an expression-latency OMA variant.
    let graphs = vec![
        ("oma", OmaConfig::default().build().unwrap().ag),
        (
            "oma_mac4",
            OmaConfig {
                mac_latency: 4,
                ..OmaConfig::default()
            }
            .build()
            .unwrap()
            .ag,
        ),
        (
            "oma_nocache_dram",
            OmaConfig {
                cache: None,
                dmem: acadl::arch::oma::DataMem::Dram,
                ..OmaConfig::default()
            }
            .build()
            .unwrap()
            .ag,
        ),
        ("systolic", SystolicConfig::new(3, 2).build().unwrap().ag),
        ("gamma", GammaConfig::new(2).build().unwrap().ag),
        ("eyeriss", EyerissConfig::default().build().unwrap().ag),
        (
            "plasticine",
            PlasticineConfig::default().build().unwrap().ag,
        ),
    ];
    for (name, ag) in graphs {
        let printed = print_arch(name, None, None, &[], &ag);
        let e = load_str(&printed)
            .unwrap_or_else(|err| panic!("printed {name} reparses: {err}"));
        ag_equiv(&ag, &e.ag).unwrap_or_else(|err| panic!("{name} round-trip: {err}"));
        assert_eq!(print_elab(&e), printed, "{name}: byte-idempotent");
    }
}

fn gemm_job(target: TargetSpec, backend: BackendKind) -> JobSpec {
    JobSpec {
        id: 0,
        target,
        workload: Workload::Gemm {
            m: 8,
            k: 8,
            n: 8,
            tile: None,
            order: None,
        },
        mode: SimModeSpec::Timed,
        backend,
        max_cycles: 50_000_000,
        platform: None,
        deadline_ms: None,
    }
}

#[test]
fn file_targets_drive_simulation_with_builder_cycles() {
    for (file, explicit) in [
        (
            "oma.acadl",
            TargetSpec::Oma {
                cache: true,
                mac_latency: None,
            },
        ),
        ("systolic_2x2.acadl", TargetSpec::Systolic { rows: 2, cols: 2 }),
        ("gamma_1u.acadl", TargetSpec::Gamma { units: 1 }),
    ] {
        let e = load_str(&example(file)).unwrap();
        let spec = e.target.clone().expect("bound example");
        // The file's graph is the machine the binding builds — the
        // guarantee behind `--arch-file` cycle fidelity.
        let machine = acadl::coordinator::build_cached(&spec).unwrap();
        ag_equiv(&e.ag, machine.ag()).unwrap_or_else(|err| panic!("{file}: {err}"));

        let from_file = job::execute(&gemm_job(spec, BackendKind::EventDriven));
        let from_rust = job::execute(&gemm_job(explicit, BackendKind::EventDriven));
        assert_eq!(from_file.error, None, "{file}");
        assert_eq!(from_file.numerics_ok, Some(true), "{file}");
        assert!(from_file.cycles > 0, "{file}");
        assert_eq!(from_file.cycles, from_rust.cycles, "{file}");
        assert_eq!(from_file.instructions, from_rust.instructions, "{file}");
    }
}

#[test]
fn committed_zoo_examples_are_byte_canonical() {
    // The local mirror of CI's `fmt --check` golden: every committed
    // description *is* its own canonical form (the scalar-epilogue
    // additions included), byte for byte.
    for file in [
        "oma.acadl",
        "systolic_2x2.acadl",
        "gamma_1u.acadl",
        "eyeriss_2x2.acadl",
        "plasticine_2s.acadl",
        "platform_quad.acadl",
    ] {
        let src = example(file);
        let e = load_str(&src).unwrap_or_else(|err| panic!("{file}: {err}"));
        assert_eq!(print_elab(&e), src, "{file} is not canonical");
    }
}

#[test]
fn file_targets_drive_transformer_with_builder_cycles() {
    // A `targets` binding lowers `tiny_transformer` from the description
    // with cycle counts identical to the Rust-builder path — the new
    // workload exercises the scalar epilogue the descriptions now carry.
    for (file, explicit) in [
        (
            "oma.acadl",
            TargetSpec::Oma {
                cache: true,
                mac_latency: None,
            },
        ),
        ("systolic_2x2.acadl", TargetSpec::Systolic { rows: 2, cols: 2 }),
        ("gamma_1u.acadl", TargetSpec::Gamma { units: 1 }),
    ] {
        let e = load_str(&example(file)).unwrap();
        let spec = e.target.clone().expect("bound example");
        let machine = acadl::coordinator::build_cached(&spec).unwrap();
        ag_equiv(&e.ag, machine.ag()).unwrap_or_else(|err| panic!("{file}: {err}"));

        let job = |target: TargetSpec| JobSpec {
            id: 0,
            target,
            workload: Workload::Transformer {
                seq: 8,
                layers: 1,
                heads: 1,
                decode_steps: 0,
            },
            mode: SimModeSpec::Timed,
            backend: BackendKind::EventDriven,
            max_cycles: 500_000_000,
            platform: None,
            deadline_ms: None,
        };
        let from_file = job::execute(&job(spec));
        let from_rust = job::execute(&job(explicit));
        assert_eq!(from_file.error, None, "{file}");
        assert_eq!(from_file.numerics_ok, Some(true), "{file}");
        assert!(from_file.cycles > 0, "{file}");
        assert_eq!(from_file.cycles, from_rust.cycles, "{file}");
        assert_eq!(from_file.instructions, from_rust.instructions, "{file}");
    }
}

#[test]
fn param_block_drives_dse_sweep() {
    let e = load_str(&example("oma.acadl")).unwrap();
    let space = acadl::dse::FileSpace::from_arch(&e, 4).unwrap();
    let specs = space.enumerate().unwrap();
    // cache(2) × tile(3) × order(2) × 1 backend.
    assert_eq!(specs.len(), 12);
    let report = acadl::dse::explore_specs(specs, 2, true);
    assert_eq!(report.stats.candidates, 12);
    assert_eq!(
        report.stats.evaluated + report.stats.pruned,
        report.stats.candidates
    );
    assert_eq!(report.stats.failed, 0, "{}", report.summary());
    assert!(report.stats.best_cycles > 0);
    assert!(!report.frontier.is_empty());
}
