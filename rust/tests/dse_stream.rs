//! Cross-checks of the streaming DSE engine against the exhaustive
//! materialized sweep — the contract the perf work must not bend:
//!
//! * **Frontier-prune equivalence**: a streamed sweep with
//!   [`PruneMode::Frontier`] reports exactly the exhaustive frontier
//!   pair set and optimum, over randomized sub-spaces.
//! * **Checkpoint/resume equivalence**: stopping a sweep mid-run and
//!   resuming from its checkpoint reproduces the uninterrupted frontier
//!   and optimum.
//! * **Bounded memory at scale**: a ≥100k-candidate file-driven `param`
//!   sweep completes with peak resident state a small fraction of the
//!   space (no full-space `Vec<JobSpec>` anywhere on the path), with
//!   the memo collapsing the aliased axes to a few hundred simulations.

use acadl::dse::{
    explore_source, Checkpoint, CheckpointCfg, DseConfig, DseReport, DseSpace, FileSource,
    FileSpace, PruneMode, SpaceSource,
};
use acadl::mapping::gemm::LoopOrder;
use acadl::sim::BackendKind;

fn xorshift(s: &mut u64) -> u64 {
    *s ^= *s << 13;
    *s ^= *s >> 7;
    *s ^= *s << 17;
    *s
}

/// The frontier as a sorted, deduplicated (cycles, area) pair set —
/// the objective-space quantity the soundness guarantees speak about.
fn frontier_pairs(rep: &DseReport) -> Vec<(u64, u64)> {
    let mut v: Vec<(u64, u64)> = rep
        .frontier
        .iter()
        .map(|&i| {
            (
                rep.points[i].result.cycles,
                rep.points[i].result.area_proxy as u64,
            )
        })
        .collect();
    v.sort();
    v.dedup();
    v
}

fn random_space(seed: &mut u64) -> DseSpace {
    let mut space = DseSpace::quick(2 + (xorshift(seed) % 6) as usize);
    space.include_oma = xorshift(seed) % 2 == 0;
    space.max_edge = if xorshift(seed) % 2 == 0 { 2 } else { 4 };
    space.max_units = 1 + (xorshift(seed) % 2) as usize;
    space.tiles = match xorshift(seed) % 3 {
        0 => vec![None],
        1 => vec![None, Some(2)],
        _ => vec![None, Some(2), Some(4)],
    };
    space.orders = if xorshift(seed) % 2 == 0 {
        vec![LoopOrder::Ijk]
    } else {
        vec![LoopOrder::Ijk, LoopOrder::Kij]
    };
    space.backends = vec![BackendKind::EventDriven];
    space
}

fn ck_path(tag: &str, case: usize) -> String {
    std::env::temp_dir()
        .join(format!("acadl_dse_stream_{tag}_{}_{case}.json", std::process::id()))
        .to_str()
        .unwrap()
        .to_string()
}

#[test]
fn streamed_pruned_and_resumed_sweeps_match_the_exhaustive_frontier() {
    let mut seed = 0x5EED_CAB5_0DD5_EE1Fu64;
    for case in 0..5 {
        let space = random_space(&mut seed);
        let total = space.total();
        assert!(total > 0);

        // Baseline: exhaustive, materializing everything.
        let exhaustive = explore_source(
            &mut SpaceSource::new(&space),
            &DseConfig::legacy(2, false),
            None,
        )
        .unwrap();
        assert_eq!(exhaustive.stats.pruned, 0);
        assert_eq!(exhaustive.stats.evaluated, exhaustive.stats.candidates);
        let expected_pairs = frontier_pairs(&exhaustive);
        let expected_best = exhaustive.stats.best_cycles;

        // Frontier-domination pruning preserves the exact pair set.
        let mut frontier_cfg = DseConfig::new(2);
        frontier_cfg.prune = PruneMode::Frontier;
        // Stress multi-window streaming; window 2 also guarantees the
        // stop_after leg below interrupts even the smallest random space
        // (≥ 3 candidates) before its last window.
        frontier_cfg.window = 2;
        let pruned = explore_source(&mut SpaceSource::new(&space), &frontier_cfg, None).unwrap();
        assert_eq!(
            frontier_pairs(&pruned),
            expected_pairs,
            "case {case}: frontier-pruned pair set diverged\n{}",
            pruned.summary()
        );
        assert_eq!(pruned.stats.best_cycles, expected_best, "case {case}");
        assert_eq!(
            pruned.stats.evaluated + pruned.stats.pruned,
            pruned.stats.candidates,
            "case {case}: {}",
            pruned.summary()
        );
        assert!(pruned.stats.simulated <= exhaustive.stats.simulated);

        // Incumbent pruning preserves the optimum.
        let cycles = explore_source(
            &mut SpaceSource::new(&space),
            &DseConfig::legacy(2, true),
            None,
        )
        .unwrap();
        assert_eq!(cycles.stats.best_cycles, expected_best, "case {case}");
        assert_eq!(
            cycles.stats.evaluated + cycles.stats.pruned,
            cycles.stats.candidates
        );

        // Stop mid-sweep, resume from the checkpoint: same frontier and
        // optimum as the uninterrupted exhaustive run.
        let path = ck_path("rand", case);
        let mut stopped_cfg = frontier_cfg.clone();
        stopped_cfg.checkpoint = Some(CheckpointCfg {
            path: path.clone(),
            every: 8,
        });
        stopped_cfg.stop_after = Some((total / 2).max(1));
        let partial =
            explore_source(&mut SpaceSource::new(&space), &stopped_cfg, None).unwrap();
        assert!(
            (partial.stats.candidates as u64) < total,
            "case {case}: stop_after did not stop ({} of {total})",
            partial.stats.candidates
        );
        let ck = Checkpoint::load(&path).unwrap();
        assert_eq!(ck.cursor, partial.stats.candidates as u64);
        let mut resume_cfg = frontier_cfg.clone();
        resume_cfg.checkpoint = Some(CheckpointCfg {
            path: path.clone(),
            every: 8,
        });
        let resumed =
            explore_source(&mut SpaceSource::new(&space), &resume_cfg, Some(ck)).unwrap();
        assert_eq!(resumed.stats.candidates as u64, total, "case {case}");
        assert_eq!(
            frontier_pairs(&resumed),
            expected_pairs,
            "case {case}: resumed pair set diverged\n{}",
            resumed.summary()
        );
        assert_eq!(resumed.stats.best_cycles, expected_best, "case {case}");
        assert_eq!(
            resumed.stats.evaluated + resumed.stats.pruned,
            resumed.stats.candidates,
            "case {case}: resumed accounting broke"
        );
        assert!(resumed.stats.restored > 0, "case {case}");

        // A checkpoint never resumes against a different space.
        let other = DseSpace::quick(9);
        let ck = Checkpoint::load(&path).unwrap();
        let err = explore_source(&mut SpaceSource::new(&other), &resume_cfg, Some(ck));
        assert!(err.is_err(), "case {case}: foreign checkpoint accepted");
        assert!(err.unwrap_err().contains("signature"));

        std::fs::remove_file(&path).ok();
    }
}

/// Builds a ≥100k-candidate OMA `param` space textually: the `.acadl`
/// source is elaborated once and candidates are stamped from it, so the
/// sweep never re-parses the file or materializes the space.
fn mega_space() -> FileSpace {
    let mut src = String::from("arch \"mega\" targets oma {\n  cache = true\n}\n");
    src.push_str("param cache in [true, false]\n");
    src.push_str("param mac_latency in [1, 2, 4]\n");
    let tiles: Vec<String> = (1..=2800).map(|t| t.to_string()).collect();
    src.push_str(&format!("param tile in [{}]\n", tiles.join(", ")));
    src.push_str("param order in [ijk, ikj, jik, jki, kij, kji]\n");
    let arch = acadl::adl::load_str(&src).expect("mega space parses");
    FileSpace::from_arch(&arch, 8).expect("mega space elaborates")
}

#[test]
fn hundred_thousand_candidate_file_sweep_is_bounded_and_resumable() {
    let space = mega_space();
    let total = space.total().unwrap();
    assert!(total >= 100_000, "only {total} candidates");

    let mut cfg = DseConfig::new(8);
    cfg.window = 4096;
    cfg.keep_points = 256;
    let rep = explore_source(&mut FileSource::new(&space).unwrap(), &cfg, None).unwrap();
    assert_eq!(rep.stats.candidates as u64, total);
    assert_eq!(
        rep.stats.evaluated + rep.stats.pruned,
        rep.stats.candidates,
        "{}",
        rep.summary()
    );
    assert_eq!(rep.stats.failed, 0, "{}", rep.summary());
    // Bounded memory: peak resident state is window + frontier +
    // reservoir — an order of magnitude under the space, not O(space).
    assert!(
        rep.stats.peak_resident < rep.stats.candidates / 10,
        "peak resident {} of {} candidates",
        rep.stats.peak_resident,
        rep.stats.candidates
    );
    // The memo collapses the aliased axes (tile ≥ dim, order × config):
    // ~10⁵ candidates cost a few hundred distinct simulations.
    assert!(
        rep.stats.simulated > 0 && rep.stats.simulated < 1_000,
        "{} simulations",
        rep.stats.simulated
    );
    assert!(rep.stats.cache_hits > rep.stats.simulated * 50);
    assert!(!rep.frontier.is_empty());
    let expected_pairs = frontier_pairs(&rep);
    let expected_best = rep.stats.best_cycles;

    // Kill at ~40% (window-aligned), resume from the checkpoint, and the
    // finished frontier matches the uninterrupted run exactly.
    let path = ck_path("mega", 0);
    let mut stopped_cfg = cfg.clone();
    stopped_cfg.checkpoint = Some(CheckpointCfg {
        path: path.clone(),
        every: 20_000,
    });
    stopped_cfg.stop_after = Some(total * 2 / 5);
    let partial = explore_source(&mut FileSource::new(&space).unwrap(), &stopped_cfg, None)
        .unwrap();
    assert!((partial.stats.candidates as u64) < total);
    let ck = Checkpoint::load(&path).unwrap();
    let mut resume_cfg = cfg.clone();
    resume_cfg.checkpoint = Some(CheckpointCfg {
        path: path.clone(),
        every: 20_000,
    });
    let resumed =
        explore_source(&mut FileSource::new(&space).unwrap(), &resume_cfg, Some(ck)).unwrap();
    assert_eq!(resumed.stats.candidates as u64, total);
    assert_eq!(frontier_pairs(&resumed), expected_pairs);
    assert_eq!(resumed.stats.best_cycles, expected_best);
    // The final checkpoint of the resumed run carries the same frontier
    // (this is what the CI kill/resume job diffs).
    let final_ck = Checkpoint::load(&path).unwrap();
    assert_eq!(final_ck.cursor, total);
    let mut ck_pairs: Vec<(u64, u64)> = final_ck
        .frontier
        .iter()
        .map(|p| (p.result.cycles, p.result.area_proxy as u64))
        .collect();
    ck_pairs.sort();
    ck_pairs.dedup();
    assert_eq!(ck_pairs, expected_pairs);
    std::fs::remove_file(&path).ok();
}
