//! Backend equivalence: the cycle-stepped and event-driven simulation
//! backends must produce **identical** cycle counts, retirement counts,
//! stall statistics, and final architectural state on every model of the
//! arch zoo — the event queue may only skip cycles in which nothing could
//! have happened.
//!
//! Covers the acceptance set (OMA, systolic array, Γ̈ GeMM workloads),
//! the Eyeriss- and Plasticine-derived models, and a property test over
//! randomized programs / GeMM shapes on three zoo models.

use acadl::acadl_core::graph::Ag;
use acadl::arch::eyeriss::EyerissConfig;
use acadl::arch::gamma::GammaConfig;
use acadl::arch::oma::{DataMem, OmaConfig};
use acadl::arch::plasticine::PlasticineConfig;
use acadl::arch::systolic::SystolicConfig;
use acadl::isa::assembler::assemble;
use acadl::isa::program::Program;
use acadl::mapping::gamma_gemm::{gamma_gemm, GammaGemmOpts};
use acadl::mapping::gemm::{oma_gemm_listing5, oma_tiled_gemm, GemmLayout, GemmParams};
use acadl::mapping::systolic_gemm::systolic_gemm;
use acadl::sim::trace::integrate;
use acadl::sim::{BackendKind, Engine, SimStats, TraceData};
use acadl::util::prop::{forall, Gen};

/// Run `prog` on both backends (identical input setup) and assert every
/// reported number and the final architectural state agree.  Returns the
/// stats and a memory dump for further workload-specific checks.
///
/// Both runs record a structured trace, which becomes two additional
/// oracles: the full span/counter timelines must be **equal** across
/// backends (not just their totals — every dispatch time, transaction
/// window, and counter sample), and each backend's trace must reconcile
/// exactly with its own statistics (span sums == busy counters, counter
/// integrals == stall totals).
fn assert_equiv(
    ag: &Ag,
    prog: &Program,
    setup: impl Fn(&mut Engine),
    dump: (u64, usize),
    max_cycles: u64,
) -> (SimStats, Vec<f32>) {
    let mut cycle = Engine::with_backend(ag, prog, BackendKind::CycleStepped).unwrap();
    cycle.attach_trace();
    setup(&mut cycle);
    let cs = cycle.run(max_cycles).unwrap();
    let ct = cycle.take_trace().expect("cycle-stepped trace");

    let mut event = Engine::with_backend(ag, prog, BackendKind::EventDriven).unwrap();
    event.attach_trace();
    setup(&mut event);
    let es = event.run(max_cycles).unwrap();
    let et = event.take_trace().expect("event-driven trace");

    assert_eq!(cs.cycles, es.cycles, "total cycles");
    assert_eq!(cs.retired, es.retired, "retired instructions");
    assert_eq!(cs.fetched, es.fetched, "fetched instructions");
    assert_eq!(cs.fetch_stalls, es.fetch_stalls, "fetch stalls");
    assert_eq!(cs.dep_stall_cycles, es.dep_stall_cycles, "dependency stalls");
    assert_eq!(
        cs.structural_stall_cycles, es.structural_stall_cycles,
        "structural stalls"
    );
    assert_eq!(cs.fu_busy, es.fu_busy, "per-FU busy cycles");
    assert_eq!(cycle.regs, event.regs, "final register state");

    assert_eq!(ct, et, "trace timelines (spans + counter samples)");
    assert_trace_reconciles(&ct, &cs, "cycle-stepped");
    assert_trace_reconciles(&et, &es, "event-driven");

    let (base, words) = dump;
    let c_dump = cycle.mem.dump_f32(base, words);
    let e_dump = event.mem.dump_f32(base, words);
    assert_eq!(c_dump, e_dump, "final memory state at {base:#x}");
    (cs, c_dump)
}

/// The trace must decompose its run's statistics exactly: per-FU span
/// durations sum to the busy counters, step-function integrals of the
/// stall counter tracks reproduce the stall totals, and per-storage
/// transaction/burst spans sum to the storage busy counters.
fn assert_trace_reconciles(tr: &TraceData, st: &SimStats, what: &str) {
    assert_eq!(tr.cycles, st.cycles, "{what}: trace end");
    let fu_totals = tr.fu_busy_totals();
    assert_eq!(fu_totals.len(), st.fu_busy.len(), "{what}: FU count");
    for (i, (name, busy)) in st.fu_busy.iter().enumerate() {
        assert_eq!(fu_totals[i], *busy, "{what}: Σ spans == busy ({name})");
    }
    assert_eq!(
        integrate(&tr.dep_stall, tr.cycles),
        st.dep_stall_cycles,
        "{what}: ∫ dep_stall == dep stall cycles"
    );
    assert_eq!(
        integrate(&tr.structural_stall, tr.cycles),
        st.structural_stall_cycles,
        "{what}: ∫ structural_stall == structural stall cycles"
    );
    assert_eq!(
        integrate(&tr.fetch_stall, tr.cycles),
        st.fetch_stalls,
        "{what}: ∫ fetch_stall == fetch stalls"
    );
    let port_totals = tr.storage_busy_totals();
    assert_eq!(port_totals.len(), st.storages.len(), "{what}: storage count");
    for (i, s) in st.storages.iter().enumerate() {
        assert_eq!(
            port_totals[i], s.busy_cycles,
            "{what}: Σ port spans == busy ({})",
            s.name
        );
    }
}

// ------------------------------------------------------- acceptance zoo

#[test]
fn oma_listing5_gemm_backends_agree() {
    let m = OmaConfig::default().build().unwrap();
    let p = GemmParams::new(8, 8, 8);
    let prog = oma_gemm_listing5(&m, &p).expect("asm");
    let layout = GemmLayout::at(m.dmem_base(), &p);
    let a: Vec<f32> = (0..64).map(|i| ((i % 7) as f32) - 3.0).collect();
    let b: Vec<f32> = (0..64).map(|i| ((i % 5) as f32) - 2.0).collect();
    let (stats, _) = assert_equiv(
        &m.ag,
        &prog,
        |e| layout.load_inputs(&p, &mut e.mem, &a, &b),
        (layout.c_base, 64),
        200_000_000,
    );
    assert!(stats.cycles > 0);
}

#[test]
fn oma_tiled_gemm_backends_agree() {
    let m = OmaConfig::default().build().unwrap();
    let p = GemmParams::new(8, 8, 8);
    let prog = oma_tiled_gemm(&m, &p).expect("codegen");
    let layout = GemmLayout::at(m.dmem_base(), &p);
    let a: Vec<f32> = (0..64).map(|i| (i % 9) as f32 * 0.5 - 2.0).collect();
    let b: Vec<f32> = (0..64).map(|i| (i % 4) as f32 - 1.5).collect();
    assert_equiv(
        &m.ag,
        &prog,
        |e| layout.load_inputs(&p, &mut e.mem, &a, &b),
        (layout.c_base, 64),
        200_000_000,
    );
}

#[test]
fn oma_dram_gemm_backends_agree() {
    // The DRAM-backed OMA is the memory-bound case the event backend
    // exists for: long t_RCD/t_RP/t_RAS stalls must be skipped without
    // moving a single reported cycle.
    let m = OmaConfig {
        dmem: DataMem::Dram,
        cache: None,
        ..OmaConfig::default()
    }
    .build()
    .unwrap();
    let p = GemmParams::new(6, 6, 6);
    let prog = oma_tiled_gemm(&m, &p).expect("codegen");
    let layout = GemmLayout::at(m.dmem_base(), &p);
    let a: Vec<f32> = (0..36).map(|i| (i % 5) as f32 - 2.0).collect();
    let b: Vec<f32> = (0..36).map(|i| (i % 3) as f32).collect();
    assert_equiv(
        &m.ag,
        &prog,
        |e| layout.load_inputs(&p, &mut e.mem, &a, &b),
        (layout.c_base, 36),
        500_000_000,
    );
}

#[test]
fn systolic_gemm_backends_agree() {
    let m = SystolicConfig::new(4, 4).build().unwrap();
    let p = GemmParams::new(8, 8, 8);
    let prog = systolic_gemm(&m, &p);
    let layout = GemmLayout::at(m.dmem_base(), &p);
    let a: Vec<f32> = (0..64).map(|i| (i % 6) as f32 - 2.5).collect();
    let b: Vec<f32> = (0..64).map(|i| (i % 7) as f32 * 0.25).collect();
    assert_equiv(
        &m.ag,
        &prog,
        |e| layout.load_inputs(&p, &mut e.mem, &a, &b),
        (layout.c_base, 64),
        200_000_000,
    );
}

#[test]
fn gamma_gemm_backends_agree() {
    let m = GammaConfig::new(2).build().unwrap();
    let p = GemmParams::new(16, 16, 16);
    let prog = gamma_gemm(&m, &p, GammaGemmOpts::default());
    let layout = GemmLayout::at(m.dram_base(), &p);
    let mut g = Gen::new(0xE0_0D);
    let a = g.vec_f32(16 * 16, -2.0, 2.0);
    let b = g.vec_f32(16 * 16, -2.0, 2.0);
    assert_equiv(
        &m.ag,
        &prog,
        |e| layout.load_inputs(&p, &mut e.mem, &a, &b),
        (layout.c_base, 16 * 16),
        200_000_000,
    );
}

#[test]
fn eyeriss_dataflow_backends_agree() {
    let m = EyerissConfig::default().build().unwrap();
    let dram = m.dram_base();
    let glb = m.glb_base();
    let src = format!(
        "load [{dram:#x}] => dma0_s0\n\
         store dma0_s0 => [{glb:#x}]\n\
         load [{:#x}] => dma0_s1\n\
         store dma0_s1 => [{:#x}]\n\
         load [{glb:#x}] => e0_0_w\n\
         load [{:#x}] => e0_0_x\n\
         mac e0_0_w, e0_0_x => e0_0_p\n\
         store e0_0_p => [{:#x}]\n\
         halt",
        dram + 4,
        glb + 4,
        glb + 4,
        glb + 64,
    );
    let prog = assemble(&m.ag, &src, 0).unwrap();
    let (_, dump) = assert_equiv(
        &m.ag,
        &prog,
        |e| e.mem.load_f32(dram, &[3.0, 4.0]),
        (glb + 64, 1),
        1_000_000,
    );
    assert_eq!(dump, vec![12.0]);
}

#[test]
fn plasticine_pipeline_backends_agree() {
    let m = PlasticineConfig::default().build().unwrap();
    let (pmu0, _) = m.pmu_range(0);
    let (pmu1, _) = m.pmu_range(1);
    let src = format!(
        "load [{pmu0:#x}] => p[0].0\n\
         load [{:#x}] => p[0].1\n\
         vmul p[0].0, p[0].1 => p[0].2\n\
         vadd p[0].2, p[0].0 => p[0].2\n\
         vrelu p[0].2 => p[0].3\n\
         store p[0].3 => [{pmu1:#x}]\n\
         halt",
        pmu0 + 32,
    );
    let prog = assemble(&m.ag, &src, 0).unwrap();
    let a: Vec<f32> = vec![1.0, -2.0, 3.0, -4.0, 5.0, -6.0, 7.0, -8.0];
    let b: Vec<f32> = vec![2.0; 8];
    let (_, dump) = assert_equiv(
        &m.ag,
        &prog,
        |e| {
            e.mem.load_f32(pmu0, &a);
            e.mem.load_f32(pmu0 + 32, &b);
        },
        (pmu1, 8),
        1_000_000,
    );
    let want: Vec<f32> = a.iter().map(|x| (x * 2.0 + x).max(0.0)).collect();
    assert_eq!(dump, want);
}

// ------------------------------------------------------- trace neutrality

/// Tracing is observation-only: every reported statistic is bit-identical
/// with the recorder attached or absent, on both backends — the guard
/// that keeps `--trace` runs representative of untraced ones.
#[test]
fn tracing_on_or_off_reports_identical_cycles() {
    let m = OmaConfig::default().build().unwrap();
    let p = GemmParams::new(8, 8, 8);
    let prog = oma_tiled_gemm(&m, &p).expect("codegen");
    let layout = GemmLayout::at(m.dmem_base(), &p);
    let a: Vec<f32> = (0..64).map(|i| (i % 9) as f32 * 0.5 - 2.0).collect();
    let b: Vec<f32> = (0..64).map(|i| (i % 4) as f32 - 1.5).collect();
    for backend in [BackendKind::CycleStepped, BackendKind::EventDriven] {
        let run = |traced: bool| {
            let mut e = Engine::with_backend(&m.ag, &prog, backend).unwrap();
            if traced {
                e.attach_trace();
            }
            layout.load_inputs(&p, &mut e.mem, &a, &b);
            let st = e.run(200_000_000).unwrap();
            let c = layout.read_c(&p, &e.mem);
            (st, c)
        };
        let (off, c_off) = run(false);
        let (on, c_on) = run(true);
        assert_eq!(on.cycles, off.cycles, "{backend:?}: tracing moved cycles");
        assert_eq!(on.retired, off.retired, "{backend:?}: retired");
        assert_eq!(on.fu_busy, off.fu_busy, "{backend:?}: FU busy");
        assert_eq!(on.dep_stall_cycles, off.dep_stall_cycles, "{backend:?}");
        assert_eq!(
            on.structural_stall_cycles, off.structural_stall_cycles,
            "{backend:?}"
        );
        assert_eq!(on.fetch_stalls, off.fetch_stalls, "{backend:?}");
        assert_eq!(c_on, c_off, "{backend:?}: results");
    }
}

// ------------------------------------------------------- property tests

/// Randomized scalar programs on the OMA — including the transformer
/// scalar-reduction patterns (`max` streaming reductions, `div`
/// normalization, `exp`/`rsqrt`/`gelu` activations): both backends agree
/// on every statistic and the final register/memory state.
///
/// The transcendental arms pin their operands (positive divisors, bounded
/// exponents) so every architectural value stays finite — NaN would make
/// bitwise-equal states compare unequal under f32 `==`.
#[test]
fn prop_random_oma_programs_backends_agree() {
    let m = OmaConfig::default().build().unwrap();
    let base = m.dmem_base();
    forall(
        "cycle ≡ event on random OMA programs",
        40,
        |g| {
            let mut src = String::new();
            let n = g.usize(1, 24);
            for i in 0..n {
                match g.usize(0, 11) {
                    0 => src.push_str(&format!("movi #{} => r{}\n", g.int(-99, 99), g.usize(0, 7))),
                    1 => src.push_str(&format!(
                        "add r{}, r{} => r{}\n",
                        g.usize(0, 7),
                        g.usize(0, 7),
                        g.usize(0, 7)
                    )),
                    2 => src.push_str(&format!(
                        "mac r{}, r{} => r{}\n",
                        g.usize(0, 7),
                        g.usize(0, 7),
                        g.usize(8, 12)
                    )),
                    3 => src.push_str(&format!(
                        "load [{:#x}] => r{}\n",
                        base + g.usize(0, 23) as u64 * 4,
                        g.usize(0, 5)
                    )),
                    4 => src.push_str(&format!(
                        "store r{} => [{:#x}]\n",
                        g.usize(0, 5),
                        base + (i as u64 % 24) * 4
                    )),
                    5 => src.push_str(&format!(
                        "addi r{}, #{} => r{}\n",
                        g.usize(0, 7),
                        g.int(-9, 9),
                        g.usize(0, 7)
                    )),
                    6 => src.push_str(&format!(
                        "max r{}, r{} => r{}\n",
                        g.usize(0, 7),
                        g.usize(0, 7),
                        g.usize(0, 7)
                    )),
                    7 => src.push_str(&format!(
                        "movi #{} => r13\ndiv r{}, r13 => r{}\n",
                        g.int(1, 9),
                        g.usize(0, 7),
                        g.usize(0, 7)
                    )),
                    8 => src.push_str(&format!(
                        "movi #{} => r14\nexp r14 => r{}\n",
                        g.int(-4, 4),
                        g.usize(0, 7)
                    )),
                    9 => src.push_str(&format!(
                        "movi #{} => r15\nrsqrt r15 => r{}\n",
                        g.int(1, 9),
                        g.usize(0, 7)
                    )),
                    10 => src.push_str(&format!(
                        "gelu r{} => r{}\n",
                        g.usize(0, 7),
                        g.usize(0, 7)
                    )),
                    _ => src.push_str("nop\n"),
                }
            }
            src.push_str("halt\n");
            src
        },
        |src| {
            let p = assemble(&m.ag, src, 0).map_err(|e| e.to_string())?;
            let mut cycle = Engine::with_backend(&m.ag, &p, BackendKind::CycleStepped)
                .map_err(|e| e.to_string())?;
            let cs = cycle.run(10_000_000).map_err(|e| e.to_string())?;
            let mut event = Engine::with_backend(&m.ag, &p, BackendKind::EventDriven)
                .map_err(|e| e.to_string())?;
            let es = event.run(10_000_000).map_err(|e| e.to_string())?;
            if cs.cycles != es.cycles {
                return Err(format!("cycles {} vs {}", cs.cycles, es.cycles));
            }
            if cs.retired != es.retired {
                return Err(format!("retired {} vs {}", cs.retired, es.retired));
            }
            if (cs.fetched, cs.fetch_stalls, cs.dep_stall_cycles, cs.structural_stall_cycles)
                != (es.fetched, es.fetch_stalls, es.dep_stall_cycles, es.structural_stall_cycles)
            {
                return Err(format!("stall stats differ: {cs:?} vs {es:?}"));
            }
            if cycle.regs != event.regs {
                return Err("register state differs".into());
            }
            for w in 0..24u64 {
                let (cv, ev) = (cycle.mem.peek(base + w * 4), event.mem.peek(base + w * 4));
                if cv != ev {
                    return Err(format!("mem[{w}]: {cv} vs {ev}"));
                }
            }
            Ok(())
        },
    );
}

/// Randomized GeMM shapes on the systolic array and Γ̈: cycles, retired
/// count, and the produced C matrix agree between backends.
#[test]
fn prop_random_gemm_shapes_backends_agree() {
    forall(
        "cycle ≡ event on random systolic/Γ̈ GeMMs",
        10,
        |g| {
            let dims = |mult: usize| {
                (
                    g.usize(1, 2) * mult,
                    g.usize(1, 2) * mult,
                    g.usize(1, 2) * mult,
                )
            };
            (dims(4), dims(8), g.next_u64())
        },
        |&((sm, sk, sn), (gm, gk, gn), seed)| {
            // Systolic array.
            {
                let m = SystolicConfig::new(2, 2).build().map_err(|e| e.to_string())?;
                let p = GemmParams::new(sm, sk, sn);
                let prog = systolic_gemm(&m, &p);
                let layout = GemmLayout::at(m.dmem_base(), &p);
                let mut g = Gen::new(seed);
                let a = g.vec_f32(sm * sk, -2.0, 2.0);
                let b = g.vec_f32(sk * sn, -2.0, 2.0);
                check_gemm(&m.ag, &prog, &layout, &p, &a, &b)?;
            }
            // Γ̈ (dims multiples of the 8×8 MXU tile).
            {
                let m = GammaConfig::new(1).build().map_err(|e| e.to_string())?;
                let p = GemmParams::new(gm, gk, gn);
                let prog = gamma_gemm(&m, &p, GammaGemmOpts::default());
                let layout = GemmLayout::at(m.dram_base(), &p);
                let mut g = Gen::new(seed ^ 0xFFFF);
                let a = g.vec_f32(gm * gk, -2.0, 2.0);
                let b = g.vec_f32(gk * gn, -2.0, 2.0);
                check_gemm(&m.ag, &prog, &layout, &p, &a, &b)?;
            }
            Ok(())
        },
    );
}

fn check_gemm(
    ag: &Ag,
    prog: &Program,
    layout: &GemmLayout,
    p: &GemmParams,
    a: &[f32],
    b: &[f32],
) -> Result<(), String> {
    let run = |backend: BackendKind| -> Result<(SimStats, Vec<f32>), String> {
        let mut e = Engine::with_backend(ag, prog, backend).map_err(|e| e.to_string())?;
        layout.load_inputs(p, &mut e.mem, a, b);
        let stats = e.run(500_000_000).map_err(|e| e.to_string())?;
        let c = layout.read_c(p, &e.mem);
        Ok((stats, c))
    };
    let (cs, cc) = run(BackendKind::CycleStepped)?;
    let (es, ec) = run(BackendKind::EventDriven)?;
    if cs.cycles != es.cycles || cs.retired != es.retired {
        return Err(format!(
            "gemm {}x{}x{}: cycles {} vs {}, retired {} vs {}",
            p.m, p.k, p.n, cs.cycles, es.cycles, cs.retired, es.retired
        ));
    }
    if cc != ec {
        return Err(format!("gemm {}x{}x{}: C matrices differ", p.m, p.k, p.n));
    }
    Ok(())
}
