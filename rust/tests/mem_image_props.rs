//! Property tests for the paged memory image: the 4 KiB-paged flat store
//! must be observationally identical to the old word-addressed
//! `HashMap<u64, f32>` semantics — unaligned masking (`addr & !3`),
//! default-zero reads, read/write counters, resident-word counts — across
//! random access patterns, including page-boundary straddles and sparse
//! outlier addresses that exercise the hash-map fallback.

use std::collections::HashMap;

use acadl::sim::exec::MemImage;
use acadl::util::prop::{forall, Gen};

/// The reference model: the seed implementation's word-addressed map.
#[derive(Default)]
struct ModelMem {
    words: HashMap<u64, f32>,
    reads: u64,
    writes: u64,
}

impl ModelMem {
    fn read(&mut self, addr: u64) -> f32 {
        self.reads += 1;
        self.peek(addr)
    }

    fn peek(&self, addr: u64) -> f32 {
        self.words.get(&(addr & !3)).copied().unwrap_or(0.0)
    }

    fn write(&mut self, addr: u64, v: f32) {
        self.writes += 1;
        self.words.insert(addr & !3, v);
    }

    fn load_f32(&mut self, base: u64, data: &[f32]) {
        for (i, v) in data.iter().enumerate() {
            self.words.insert((base + 4 * i as u64) & !3, *v);
        }
    }

    fn dump_f32(&self, base: u64, len: usize) -> Vec<f32> {
        (0..len).map(|i| self.peek(base + 4 * i as u64)).collect()
    }
}

#[derive(Debug, Clone)]
enum Op {
    Read(u64),
    Peek(u64),
    Write(u64, f32),
    Load(u64, Vec<f32>),
    Dump(u64, usize),
}

/// Address generator biased toward the interesting regimes: small dense
/// addresses, 4 KiB page boundaries, unaligned bytes, and far outliers
/// past the dense page-table range.
fn gen_addr(g: &mut Gen) -> u64 {
    const PAGE: u64 = 4096;
    let base = match g.usize(0, 3) {
        0 => g.int(0, 0x2000) as u64,
        // Hug a page boundary (first few pages).
        1 => (PAGE * g.int(1, 8) as u64).saturating_add_signed(g.int(-16, 16)),
        // Deep but still dense (tens of MiB).
        2 => g.int(0, 1 << 25) as u64,
        // Sparse outliers: far past the 128 MiB dense range.
        _ => (1u64 << 30) + (g.next_u64() % (1u64 << 40)),
    };
    // Mix in unaligned byte offsets: masking must behave identically.
    base.wrapping_add(g.int(0, 3) as u64)
}

#[test]
fn paged_store_matches_hashmap_model() {
    forall(
        "paged MemImage ≡ word-addressed HashMap",
        60,
        |g| {
            let n = g.usize(20, 120);
            (0..n)
                .map(|_| {
                    let a = gen_addr(g);
                    match g.usize(0, 4) {
                        0 => Op::Read(a),
                        1 => Op::Peek(a),
                        2 => Op::Write(a, g.f32(-100.0, 100.0)),
                        // Bulk loads use word-aligned bases (codegen's data
                        // layout contract) and may straddle a page edge.
                        3 => Op::Load(a & !3, g.vec_f32(g.usize(1, 32), -10.0, 10.0)),
                        _ => Op::Dump(a & !3, g.usize(1, 32)),
                    }
                })
                .collect::<Vec<Op>>()
        },
        |ops| {
            let mut model = ModelMem::default();
            let mut paged = MemImage::new();
            for (i, op) in ops.iter().enumerate() {
                match op {
                    Op::Read(a) => {
                        let (m, p) = (model.read(*a), paged.read(*a));
                        if m != p {
                            return Err(format!("op {i}: read({a:#x}) = {p}, model {m}"));
                        }
                    }
                    Op::Peek(a) => {
                        let (m, p) = (model.peek(*a), paged.peek(*a));
                        if m != p {
                            return Err(format!("op {i}: peek({a:#x}) = {p}, model {m}"));
                        }
                    }
                    Op::Write(a, v) => {
                        model.write(*a, *v);
                        paged.write(*a, *v);
                    }
                    Op::Load(base, data) => {
                        model.load_f32(*base, data);
                        paged.load_f32(*base, data);
                    }
                    Op::Dump(base, len) => {
                        let (m, p) = (model.dump_f32(*base, *len), paged.dump_f32(*base, *len));
                        if m != p {
                            return Err(format!("op {i}: dump({base:#x}, {len}) diverged"));
                        }
                    }
                }
                if (model.reads, model.writes) != (paged.reads, paged.writes) {
                    return Err(format!(
                        "op {i}: counters (r{}, w{}) vs model (r{}, w{})",
                        paged.reads, paged.writes, model.reads, model.writes
                    ));
                }
                if model.words.len() != paged.len() {
                    return Err(format!(
                        "op {i}: resident words {} vs model {}",
                        paged.len(),
                        model.words.len()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn load_dump_roundtrip_at_page_boundaries() {
    // Deterministic page-boundary round-trips: every span that straddles
    // the first few 4 KiB boundaries must read back exactly.
    for page in 1u64..4 {
        let boundary = page * 4096;
        for lead in [4u64, 8, 20] {
            let base = boundary - lead;
            let data: Vec<f32> = (0..16).map(|i| (page * 100 + i) as f32 * 0.25).collect();
            let mut mem = MemImage::new();
            mem.load_f32(base, &data);
            assert_eq!(mem.dump_f32(base, data.len()), data, "base {base:#x}");
            assert_eq!(mem.len(), data.len(), "resident count at {base:#x}");
            // The words before and after the span stay zero.
            assert_eq!(mem.peek(base - 4), 0.0);
            assert_eq!(mem.peek(base + 4 * data.len() as u64), 0.0);
        }
    }
}
