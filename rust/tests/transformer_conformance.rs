//! Cross-layer differential conformance for the transformer operators:
//!
//! * **Bit-exactness across abstraction layers** — for randomized shapes,
//!   the functional ISS and both timing backends produce *identical*
//!   outputs, equal bit-for-bit to the host reference (`rowwise::*_ref`),
//!   on every zoo machine that supports the operator.  (The analytical
//!   layer joins through the roofline assertions below — four layers, one
//!   oracle.)
//! * **Timing soundness** — timed cycles never undercut the per-target
//!   `Roofline::op_cycles` bound, per operator and for the whole
//!   `tiny_transformer` schedule.
//! * **Numerics properties** — softmax rows sum to 1 and are
//!   permutation-equivariant; layer norm is invariant to input shift.
//! * **Serving conformance** — KV-cached decode is a pure optimization:
//!   its assembled output is bit-identical to re-running the extended
//!   sequence from scratch, across machines, execution modes, and
//!   platform worker-thread counts.
//! * **DSE soundness on the new workload** — exploring the transformer
//!   workload prunes only candidates whose roofline bound exceeds the
//!   incumbent, and pruning preserves the optimum.

use acadl::analytical::Roofline;
use acadl::arch::gamma::GammaConfig;
use acadl::arch::oma::OmaConfig;
use acadl::arch::platform::PlatformDesc;
use acadl::arch::systolic::SystolicConfig;
use acadl::coordinator::job::{JobSpec, SimModeSpec, TargetSpec, Workload};
use acadl::dnn::graph::DnnGraph;
use acadl::dnn::lowering::{
    lower_graph, lower_serving, partition_graph, roofline_ops, run_schedule, run_serving,
    split_serving_input, SimMode,
};
use acadl::dse::{explore_specs, lower_bound_cycles};
use acadl::mapping::gemm::gemm_ref;
use acadl::mapping::rowwise::{
    addmat_ref, gelu_ref, layernorm_ref, rowwise_ref, softmax_ref, transpose_ref,
};
use acadl::mapping::uma::{self, Machine, Operator};
use acadl::sim::exec::MemImage;
use acadl::sim::functional::FunctionalSim;
use acadl::sim::platform::{microbatch_input, run_platform_serving};
use acadl::sim::{BackendKind, Engine};
use acadl::util::prop::{forall, Gen};

/// The mappable zoo with each machine's analytical roofline.
fn zoo() -> Vec<(Machine, Roofline)> {
    vec![
        (
            uma::TargetConfig::Oma(OmaConfig::default()).build().unwrap(),
            Roofline::oma(),
        ),
        (
            uma::TargetConfig::Systolic(SystolicConfig::new(2, 2)).build().unwrap(),
            Roofline::systolic(2, 2),
        ),
        (
            uma::TargetConfig::Gamma(GammaConfig::new(1)).build().unwrap(),
            Roofline::gamma(1),
        ),
    ]
}

/// Lower `op`, run it functionally and on both timing backends with the
/// same operands, and return (functional, cycle-stepped, event-driven)
/// outputs plus the agreed cycle count.
fn run_three_ways(
    machine: &Machine,
    op: &Operator,
    a: &[f32],
    b: &[f32],
) -> (Vec<f32>, Vec<f32>, Vec<f32>, u64) {
    let lw = uma::lower(machine, op).expect("operator lowers");
    let load = |mem: &mut MemImage| {
        mem.load_f32(lw.layout.a_base, a);
        if !b.is_empty() {
            mem.load_f32(lw.layout.b_base, b);
        }
    };
    let mut f = FunctionalSim::new(machine.ag());
    load(&mut f.mem);
    f.run(&lw.program, 200_000_000).unwrap();
    let func = f.mem.dump_f32(lw.layout.c_base, op.c_words());

    let run_timed = |backend: BackendKind| {
        let mut e = Engine::with_backend(machine.ag(), &lw.program, backend).unwrap();
        load(&mut e.mem);
        let stats = e.run(500_000_000).unwrap();
        (e.mem.dump_f32(lw.layout.c_base, op.c_words()), stats.cycles)
    };
    let (cs, cs_cycles) = run_timed(BackendKind::CycleStepped);
    let (ev, ev_cycles) = run_timed(BackendKind::EventDriven);
    assert_eq!(cs_cycles, ev_cycles, "backends agree on cycles for {op:?}");
    (func, cs, ev, cs_cycles)
}

#[test]
fn prop_rowwise_ops_bit_exact_across_stack_and_zoo() {
    let zoo = zoo();
    forall(
        "rowwise op ≡ reference, bit-exact, all layers, all machines",
        6,
        |g: &mut Gen| {
            let rows = g.usize(1, 5);
            let cols = g.usize(1, 8);
            let kind = g.usize(0, 4);
            let a = g.vec_f32(rows * cols, -3.0, 3.0);
            let b = g.vec_f32(rows * cols, -3.0, 3.0);
            (rows, cols, kind, a, b)
        },
        |(rows, cols, kind, a, b)| {
            let (rows, cols) = (*rows, *cols);
            let (op, b_op): (Operator, &[f32]) = match *kind {
                0 => (Operator::Softmax { rows, cols }, &[]),
                1 => (
                    Operator::LayerNorm {
                        rows,
                        cols,
                        eps: 1e-5,
                    },
                    &[1e-5f32],
                ),
                2 => (Operator::Gelu { rows, cols }, &[]),
                3 => (Operator::AddMat { rows, cols }, b),
                _ => (Operator::Transpose { rows, cols }, &[]),
            };
            let want = rowwise_ref(&op, a, b).expect("row-wise reference");
            for (machine, rl) in &zoo {
                let (func, cs, ev, cycles) = run_three_ways(machine, &op, a, b_op);
                if func != want {
                    return Err(format!("functional ≠ ref on {} for {op:?}", machine.name()));
                }
                if cs != want || ev != want {
                    return Err(format!("timed ≠ ref on {} for {op:?}", machine.name()));
                }
                let bound = rl.op_cycles(&op);
                if cycles < bound {
                    return Err(format!(
                        "{}: {cycles} cycles under roofline {bound} for {op:?}",
                        machine.name()
                    ));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_matmul_backends_agree_and_sequential_targets_are_exact() {
    let oma = uma::TargetConfig::Oma(OmaConfig::default()).build().unwrap();
    let sys = uma::TargetConfig::Systolic(SystolicConfig::new(2, 2)).build().unwrap();
    let gamma = uma::TargetConfig::Gamma(GammaConfig::new(1)).build().unwrap();
    forall(
        "activation matmul across the zoo",
        5,
        |g: &mut Gen| {
            // Multiples of 8 so the same shape runs unpadded on Γ̈.
            let m = g.usize(1, 2) * 8;
            let k = g.usize(1, 2) * 8;
            let n = 8;
            let a = g.vec_f32(m * k, -2.0, 2.0);
            let b = g.vec_f32(k * n, -2.0, 2.0);
            (m, k, n, a, b)
        },
        |(m, k, n, a, b)| {
            let p = acadl::mapping::gemm::GemmParams::new(*m, *k, *n);
            let op = Operator::Gemm(p);
            let want = gemm_ref(&p, a, b);
            // Sequentially-accumulating targets: bit-exact.
            for machine in [&oma, &sys] {
                let (func, cs, ev, _) = run_three_ways(machine, &op, a, b);
                if func != want || cs != want || ev != want {
                    return Err(format!("{}: matmul ≠ gemm_ref", machine.name()));
                }
            }
            // Γ̈ tiles its accumulation: backends still agree bit-for-bit
            // with each other and with the functional ISS; the reference
            // match is a tight tolerance.
            let (func, cs, ev, _) = run_three_ways(&gamma, &op, a, b);
            if func != cs || func != ev {
                return Err("gamma: abstraction layers disagree".into());
            }
            let diff = func
                .iter()
                .zip(&want)
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            if diff > 1e-3 {
                return Err(format!("gamma: matmul off by {diff}"));
            }
            Ok(())
        },
    );
}

// --------------------------------------------------------- numerics props

#[test]
fn prop_softmax_rows_sum_to_one_and_permutation_equivariant() {
    forall(
        "softmax Σ=1 and permutation equivariance",
        24,
        |g: &mut Gen| {
            let rows = g.usize(1, 4);
            let cols = g.usize(2, 9);
            let x = g.vec_f32(rows * cols, -6.0, 6.0);
            // A random permutation of the columns (Fisher–Yates).
            let mut perm: Vec<usize> = (0..cols).collect();
            for i in (1..cols).rev() {
                let j = g.usize(0, i);
                perm.swap(i, j);
            }
            (rows, cols, x, perm)
        },
        |(rows, cols, x, perm)| {
            let (rows, cols) = (*rows, *cols);
            let y = softmax_ref(rows, cols, x);
            for r in 0..rows {
                let s: f32 = y[r * cols..(r + 1) * cols].iter().sum();
                if (s - 1.0).abs() > 1e-5 {
                    return Err(format!("row {r} sums to {s}"));
                }
                if y[r * cols..(r + 1) * cols].iter().any(|&v| !(0.0..=1.0).contains(&v)) {
                    return Err(format!("row {r} has a probability outside [0,1]"));
                }
            }
            // softmax(P x) == P softmax(x): reductions are order-sensitive
            // only in the last ulps, so compare with a tight tolerance.
            let mut px = vec![0.0f32; rows * cols];
            for r in 0..rows {
                for (j, &pj) in perm.iter().enumerate() {
                    px[r * cols + j] = x[r * cols + pj];
                }
            }
            let py = softmax_ref(rows, cols, &px);
            for r in 0..rows {
                for (j, &pj) in perm.iter().enumerate() {
                    let (a, b) = (py[r * cols + j], y[r * cols + pj]);
                    if (a - b).abs() > 1e-6 {
                        return Err(format!("not equivariant at ({r},{j}): {a} vs {b}"));
                    }
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_layernorm_shift_invariant_and_normalized() {
    forall(
        "layernorm shift invariance",
        24,
        |g: &mut Gen| {
            let rows = g.usize(1, 4);
            let cols = g.usize(2, 9);
            let x = g.vec_f32(rows * cols, -4.0, 4.0);
            let shift = g.f32(-2.0, 2.0);
            (rows, cols, x, shift)
        },
        |(rows, cols, x, shift)| {
            let (rows, cols) = (*rows, *cols);
            let y = layernorm_ref(rows, cols, 1e-5, x);
            // Output rows are (approximately) zero-mean.
            for r in 0..rows {
                let mean: f32 =
                    y[r * cols..(r + 1) * cols].iter().sum::<f32>() / cols as f32;
                if mean.abs() > 1e-4 {
                    return Err(format!("row {r} mean {mean} after normalization"));
                }
            }
            let shifted: Vec<f32> = x.iter().map(|&v| v + shift).collect();
            let ys = layernorm_ref(rows, cols, 1e-5, &shifted);
            let diff = y
                .iter()
                .zip(&ys)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            if diff > 1e-3 {
                return Err(format!("shift by {shift} moved output by {diff}"));
            }
            Ok(())
        },
    );
}

#[test]
fn gelu_and_residual_and_transpose_identities() {
    let mut g = Gen::new(0x6E1);
    let x = g.vec_f32(24, -3.0, 3.0);
    let zero = vec![0.0f32; 24];
    // x + 0 = x, bit-exactly.
    assert_eq!(addmat_ref(&x, &zero), x);
    // Transpose is an involution, bit-exactly.
    assert_eq!(transpose_ref(6, 4, &transpose_ref(4, 6, &x)), x);
    // GELU is monotone on the sampled range's positives and bounded by x.
    for &v in &x {
        let y = gelu_ref(&[v])[0];
        assert!(y <= v.max(0.0) + 1e-6, "gelu({v}) = {y} exceeds relu");
        assert!(y >= v.min(0.0) - 0.2, "gelu({v}) = {y} far below x");
    }
}

// ------------------------------------------------- whole-model + DSE layer

#[test]
fn tiny_transformer_cycles_respect_roofline_on_all_zoo_machines() {
    let graph = DnnGraph::tiny_transformer();
    let seq = 8;
    let x = graph.input_batch(seq);
    let want = graph.forward_ref(&x, seq);
    for (machine, rl) in zoo() {
        let lg = lower_graph(&machine, &graph, seq).unwrap();
        let rep = run_schedule(
            &machine,
            &lg,
            &x,
            SimMode::Timed(BackendKind::EventDriven),
            500_000_000,
        )
        .unwrap();
        // Whole-schedule bound: Σ per-operator rooflines (unpadded).
        let bound: u64 = roofline_ops(&graph, seq).iter().map(|op| rl.op_cycles(op)).sum();
        assert!(
            rep.total_cycles >= bound,
            "{}: {} cycles under bound {bound}",
            machine.name(),
            rep.total_cycles
        );
        // Functional output of the timed run matches the reference — the
        // sequentially-accumulating targets bit-exactly, Γ̈ tightly.
        match machine {
            Machine::Gamma(_) => {
                let diff = rep
                    .output
                    .iter()
                    .zip(&want)
                    .map(|(a, b)| (a - b).abs())
                    .fold(0.0f32, f32::max);
                assert!(diff < 1e-3, "gamma diff {diff}");
            }
            _ => assert_eq!(rep.output, want, "bit-exact on {}", machine.name()),
        }
    }
}

// --------------------------------------------- serving (prefill + decode)

/// KV-cached decode is a pure optimization: for randomized serving
/// shapes, the assembled prefill+decode output is **bit-identical** to
/// lowering and running the extended sequence from scratch — per zoo
/// machine and per execution mode (functional, cycle-stepped,
/// event-driven).  On the sequentially-accumulating targets the output
/// also equals the host reference bit-for-bit; Γ̈ tiles its accumulation,
/// so it gets a tight tolerance against the host instead (while staying
/// bitwise self-consistent between cached and from-scratch runs).
#[test]
fn prop_kv_cached_decode_matches_full_prefill_reference() {
    let zoo = zoo();
    forall(
        "KV-cached decode ≡ from-scratch prefill of the extended sequence",
        3,
        |g: &mut Gen| {
            let layers = g.usize(1, 2);
            let heads = [1usize, 2, 4][g.usize(0, 2)];
            let seq = g.usize(2, 6);
            let steps = g.usize(1, 3);
            (layers, heads, seq, steps)
        },
        |&(layers, heads, seq, steps)| {
            let graph = DnnGraph::transformer(layers, heads);
            let total = seq + steps;
            let full = graph.input_batch(total);
            let want = graph.forward_ref(&full, total);
            let (prompt, dec) = split_serving_input(&full, graph.input_features, seq);
            for (machine, _) in &zoo {
                let name = machine.name();
                let sched = lower_serving(machine, &graph, seq, steps)
                    .map_err(|e| format!("{name}: {e:?}"))?;
                let scratch = lower_graph(machine, &graph, total)
                    .map_err(|e| format!("{name}: {e:?}"))?;
                for mode in [
                    SimMode::Functional,
                    SimMode::Timed(BackendKind::CycleStepped),
                    SimMode::Timed(BackendKind::EventDriven),
                ] {
                    let served = run_serving(machine, &sched, &prompt, &dec, mode, 500_000_000)
                        .map_err(|e| format!("{name}: {e:?}"))?;
                    let scratch_rep = run_schedule(machine, &scratch, &full, mode, 500_000_000)
                        .map_err(|e| format!("{name}: {e:?}"))?;
                    let out = served.assembled_output();
                    if out != scratch_rep.output {
                        return Err(format!(
                            "{name}/{mode:?}: cached decode ≠ from-scratch prefill \
                             ({layers}L {heads}H seq {seq} +{steps})"
                        ));
                    }
                    match machine {
                        Machine::Gamma(_) => {
                            let diff = out
                                .iter()
                                .zip(&want)
                                .map(|(a, b)| (a - b).abs())
                                .fold(0.0f32, f32::max);
                            if diff > 1e-2 {
                                return Err(format!("gamma: serving off reference by {diff}"));
                            }
                        }
                        _ => {
                            if out != want {
                                return Err(format!("{name}/{mode:?}: serving ≠ host reference"));
                            }
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Platform serving (continuous batching through the pipeline stages)
/// reports identical cycles, phase split, and outputs on 1 and 4 worker
/// threads, on both timing backends — and every session's assembled
/// output is the host reference of its extended sequence, bit-for-bit.
#[test]
fn platform_serving_conformance_is_thread_invariant() {
    let g = DnnGraph::transformer(2, 2);
    let machine = uma::TargetConfig::Oma(OmaConfig::default()).build().unwrap();
    let (seq, steps) = (4usize, 2usize);
    let plan = partition_graph(&g, seq, 2).unwrap();
    let machines: Vec<&Machine> = (0..plan.stages.len()).map(|_| &machine).collect();
    let desc = PlatformDesc::new(plan.stages.len()).with_microbatches(2);
    for backend in [BackendKind::CycleStepped, BackendKind::EventDriven] {
        let runs: Vec<_> = [1usize, 4]
            .iter()
            .map(|&t| {
                run_platform_serving(
                    &machines,
                    &g,
                    &plan,
                    seq,
                    steps,
                    &desc,
                    SimMode::Timed(backend),
                    t,
                    500_000_000,
                    None,
                )
                .unwrap()
            })
            .collect();
        assert_eq!(
            runs[0].report.total_cycles, runs[1].report.total_cycles,
            "{backend:?}: thread count changed the makespan"
        );
        assert_eq!(runs[0].prefill_cycles, runs[1].prefill_cycles, "{backend:?}");
        assert_eq!(runs[0].report.outputs, runs[1].report.outputs, "{backend:?}");
        assert!(runs[0].cycles_per_token().unwrap() > 0.0);
        for (b, out) in runs[0].report.outputs.iter().enumerate() {
            let x = microbatch_input(&g, seq + steps, b);
            assert_eq!(out, &g.forward_ref(&x, seq + steps), "session {b}");
        }
    }
}

#[test]
fn dse_on_transformer_prunes_only_above_the_incumbent() {
    let mk = |id: u64, target: TargetSpec| JobSpec {
        id,
        target,
        workload: Workload::Transformer {
            seq: 8,
            layers: 1,
            heads: 1,
            decode_steps: 0,
        },
        mode: SimModeSpec::Timed,
        backend: BackendKind::EventDriven,
        max_cycles: 500_000_000,
        platform: None,
        deadline_ms: None,
    };
    let specs = vec![
        mk(
            0,
            TargetSpec::Oma {
                cache: true,
                mac_latency: None,
            },
        ),
        mk(1, TargetSpec::Systolic { rows: 2, cols: 2 }),
        mk(2, TargetSpec::Systolic { rows: 4, cols: 4 }),
        mk(3, TargetSpec::Gamma { units: 1 }),
    ];
    let pruned = explore_specs(specs.clone(), 2, true);
    let exhaustive = explore_specs(specs.clone(), 2, false);
    assert_eq!(exhaustive.stats.failed, 0, "{}", exhaustive.summary());
    assert_eq!(pruned.stats.failed, 0, "{}", pruned.summary());
    // Pruning preserves the optimum.
    assert_eq!(pruned.stats.best_cycles, exhaustive.stats.best_cycles);
    assert_eq!(
        pruned.stats.evaluated + pruned.stats.pruned,
        pruned.stats.candidates
    );
    // Every evaluated point respects its own (sound) bound…
    for p in pruned.points.iter().chain(exhaustive.points.iter()) {
        assert!(
            p.result.cycles >= p.lower_bound,
            "{}: {} < bound {}",
            p.result.target,
            p.result.cycles,
            p.lower_bound
        );
    }
    // …and only candidates whose roofline bound exceeds the incumbent
    // were cut without simulation.
    let evaluated: Vec<u64> = pruned.points.iter().map(|p| p.spec.id).collect();
    for spec in &specs {
        if !evaluated.contains(&spec.id) {
            assert!(
                lower_bound_cycles(spec) > pruned.stats.best_cycles,
                "candidate {} pruned below the incumbent",
                spec.id
            );
        }
    }
}
