//! Deterministic chaos harness for the simulation service.
//!
//! A seeded fault plan drives the TCP server through the failure modes a
//! long-running coordinator actually meets — mid-line disconnects,
//! slow-loris writers, panicking jobs, clients killed mid-execution,
//! deadline-expiring simulations — interleaved with healthy requests, and
//! asserts the supervision layer's contract afterwards:
//!
//! * the server stays live (healthy requests keep being served),
//! * no simulation slot leaks (`Slots::available` returns to capacity),
//! * no `--jobs` budget lease leaks (`util::jobs::outstanding` drains),
//! * post-chaos results are bit-identical to the pre-chaos reference.
//!
//! Fault injection is opt-in (`ACADL_CHAOS=1`) and selected per job by
//! mark bits in the job id (`CHAOS_PANIC_MARK`, `CHAOS_STALL_MARK`), so
//! the plan is reproducible from its seed alone — no timing races decide
//! *what* happens, only how long it takes.

use std::io::{BufRead, BufReader, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

use acadl::coordinator::job::{
    JobError, JobResult, JobSpec, PlatformSpec, SimModeSpec, TargetSpec, Workload,
    CHAOS_PANIC_MARK, CHAOS_STALL_MARK,
};
use acadl::coordinator::server::{spawn, ServeCfg, ServerHandle};
use acadl::coordinator::supervisor;
use acadl::util::json::Json;
use acadl::util::prop::Gen;

fn gemm(id: u64, deadline_ms: Option<u64>) -> JobSpec {
    JobSpec {
        id,
        target: TargetSpec::Systolic { rows: 4, cols: 4 },
        workload: Workload::Gemm {
            m: 8,
            k: 8,
            n: 8,
            tile: None,
            order: None,
        },
        mode: SimModeSpec::Timed,
        backend: Default::default(),
        max_cycles: 10_000_000,
        platform: None,
        deadline_ms,
    }
}

fn platform_gemm(id: u64, deadline_ms: Option<u64>) -> JobSpec {
    JobSpec {
        platform: Some(PlatformSpec {
            chips: 2,
            hop_latency: 8,
            microbatches: 4,
            threads: 2,
        }),
        ..gemm(id, deadline_ms)
    }
}

fn submit(stream: &mut TcpStream, spec: &JobSpec) -> std::io::Result<()> {
    let line = spec.to_json().to_string() + "\n";
    stream.write_all(line.as_bytes())
}

fn read_result(stream: &mut TcpStream) -> JobResult {
    let mut reader = BufReader::new(stream.try_clone().expect("clone"));
    let mut reply = String::new();
    reader.read_line(&mut reply).expect("read reply");
    JobResult::from_json(&Json::parse(reply.trim()).expect("reply json")).expect("result row")
}

fn run_clean(addr: std::net::SocketAddr, spec: &JobSpec) -> JobResult {
    let mut stream = TcpStream::connect(addr).expect("connect");
    submit(&mut stream, spec).expect("submit");
    read_result(&mut stream)
}

/// Poll `cond` until it holds or `budget` expires (the quiesce barrier
/// between a fault plan and its leak assertions).
fn wait_for(what: &str, budget: Duration, mut cond: impl FnMut() -> bool) {
    let deadline = Instant::now() + budget;
    while !cond() {
        assert!(Instant::now() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// One step of the fault plan.  The discriminant is drawn from the
/// seeded generator, so the event *sequence* is a pure function of the
/// seed.
#[derive(Debug, Clone, Copy)]
enum Fault {
    MidLineDisconnect,
    SlowLoris,
    PanickingJob,
    KillDuringExecution,
    DeadlineExpires,
    HealthyJob,
    HealthyPlatformJob,
}

const FAULTS: [Fault; 7] = [
    Fault::MidLineDisconnect,
    Fault::SlowLoris,
    Fault::PanickingJob,
    Fault::KillDuringExecution,
    Fault::DeadlineExpires,
    Fault::HealthyJob,
    Fault::HealthyPlatformJob,
];

fn run_plan(
    seed: u64,
    events: usize,
    handle: &ServerHandle,
    reference: &JobResult,
    platform_reference: &JobResult,
) {
    let mut g = Gen::new(seed);
    for step in 0..events {
        let fault = *g.choose(&FAULTS);
        let id = (step as u64) << 8 | 0x40;
        match fault {
            Fault::MidLineDisconnect => {
                // A request line that stops mid-JSON, then the client dies.
                let mut s = TcpStream::connect(handle.addr()).expect("connect");
                let full = gemm(id, None).to_json().to_string();
                let cut = g.usize(1, full.len() - 1);
                s.write_all(full[..cut].as_bytes()).expect("partial write");
                drop(s);
            }
            Fault::SlowLoris => {
                // Bytes trickle in, never completing a line, then EOF.
                let mut s = TcpStream::connect(handle.addr()).expect("connect");
                let full = gemm(id, None).to_json().to_string();
                for chunk in full.as_bytes().chunks(8).take(3) {
                    s.write_all(chunk).expect("trickle");
                    std::thread::sleep(Duration::from_millis(g.usize(1, 15) as u64));
                }
                drop(s);
            }
            Fault::PanickingJob => {
                let spec = gemm(CHAOS_PANIC_MARK | id, None);
                let result = run_clean(handle.addr(), &spec);
                assert_eq!(
                    result.error_class(),
                    Some(JobError::Panic),
                    "step {step}: {:?}",
                    result.error
                );
            }
            Fault::KillDuringExecution => {
                // A stall job owns a slot; the client dies mid-execution.
                // Only the disconnect watch can end this one quickly (the
                // deadline is seconds away) — slot recovery is asserted
                // globally after the plan.
                let mut s = TcpStream::connect(handle.addr()).expect("connect");
                submit(&mut s, &gemm(CHAOS_STALL_MARK | id, Some(4_000))).expect("submit");
                std::thread::sleep(Duration::from_millis(g.usize(5, 40) as u64));
                drop(s);
            }
            Fault::DeadlineExpires => {
                let spec = gemm(CHAOS_STALL_MARK | id, Some(g.usize(20, 60) as u64));
                let result = run_clean(handle.addr(), &spec);
                assert_eq!(
                    result.error_class(),
                    Some(JobError::Deadline),
                    "step {step}: {:?}",
                    result.error
                );
            }
            Fault::HealthyJob => {
                let result = run_clean(handle.addr(), &gemm(id, None));
                assert_eq!(result.error, None, "step {step}");
                assert_eq!(result.cycles, reference.cycles, "step {step}");
            }
            Fault::HealthyPlatformJob => {
                let result = run_clean(handle.addr(), &platform_gemm(id, None));
                assert_eq!(result.error, None, "step {step}");
                assert_eq!(result.cycles, platform_reference.cycles, "step {step}");
            }
        }
    }
}

#[test]
fn seeded_fault_plan_leaves_the_server_live_and_leak_free() {
    // Opt this process into fault injection (set-only: mark bits select
    // behavior per job id, so concurrent tests are unaffected).
    std::env::set_var("ACADL_CHAOS", "1");
    let handle = spawn("127.0.0.1:0", ServeCfg::new(2)).expect("spawn server");
    let slots = handle.slots();

    // Pre-chaos references, served by the same server.
    let reference = run_clean(handle.addr(), &gemm(1, None));
    assert_eq!(reference.error, None, "{:?}", reference.error);
    let platform_reference = run_clean(handle.addr(), &platform_gemm(2, None));
    assert_eq!(platform_reference.error, None, "{:?}", platform_reference.error);

    run_plan(0xC4A0_5EED, 21, &handle, &reference, &platform_reference);

    // Quiesce, then the leak assertions: every simulation slot and every
    // `--jobs` budget lease taken during the plan must have been
    // returned — RAII guards survived panics, disconnects, and deadlines.
    wait_for("slots to return to capacity", Duration::from_secs(10), || {
        slots.available() == slots.capacity()
    });
    wait_for("job leases to drain", Duration::from_secs(10), || {
        acadl::util::jobs::outstanding() == 0
    });

    // Post-chaos determinism: bit-identical to the pre-chaos reference.
    let after = run_clean(handle.addr(), &gemm(3, None));
    assert_eq!(after.error, None);
    assert_eq!(after.cycles, reference.cycles, "post-chaos cycles drifted");
    assert_eq!(after.instructions, reference.instructions);
    assert_eq!(after.numerics_ok, reference.numerics_ok);
    let after = run_clean(handle.addr(), &platform_gemm(4, None));
    assert_eq!(after.cycles, platform_reference.cycles);

    handle.shutdown().expect("clean shutdown after chaos");
}

/// Satellite: cancellation must not perturb later runs.  A job aborted by
/// an expired deadline reports `JobError::Deadline`, and an unconstrained
/// rerun afterwards is bit-identical to a run that was never cancelled.
#[test]
fn deadline_aborted_jobs_leave_no_trace_on_reruns() {
    let clean = supervisor::execute(&gemm(10, None));
    assert_eq!(clean.error, None, "{:?}", clean.error);

    // Already-expired budget: the probe trips within one check interval.
    let t = Instant::now();
    let aborted = supervisor::execute(&gemm(11, Some(0)));
    assert_eq!(
        aborted.error_class(),
        Some(JobError::Deadline),
        "{:?}",
        aborted.error
    );
    assert!(
        t.elapsed() < Duration::from_secs(5),
        "deadline abort took {:?}",
        t.elapsed()
    );

    let rerun = supervisor::execute(&gemm(12, None));
    assert_eq!(rerun.cycles, clean.cycles, "cancellation left a trace");
    assert_eq!(rerun.instructions, clean.instructions);
    assert_eq!(rerun.ipc, clean.ipc);
    assert_eq!(rerun.numerics_ok, clean.numerics_ok);

    // Same contract across the partitioned platform simulation (stage
    // workers carry the token; `LowerError::Sim` is transparent, so the
    // deadline classification survives the platform path).
    let clean = supervisor::execute(&platform_gemm(13, None));
    assert_eq!(clean.error, None, "{:?}", clean.error);
    let aborted = supervisor::execute(&platform_gemm(14, Some(0)));
    assert_eq!(
        aborted.error_class(),
        Some(JobError::Deadline),
        "{:?}",
        aborted.error
    );
    let rerun = supervisor::execute(&platform_gemm(15, None));
    assert_eq!(rerun.cycles, clean.cycles);
    assert_eq!(rerun.utilization, clean.utilization);
}
