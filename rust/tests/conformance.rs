//! E5 — timing-semantics conformance: cycle-exact checks of the §6 state
//! machines (Figs 9–13) on hand-built micro-architectures, plus
//! end-to-end workloads on the Eyeriss- and Plasticine-derived models.

use acadl::acadl_core::data::Value;
use acadl::arch::eyeriss::EyerissConfig;
use acadl::arch::oma::{CacheCfg, DataMem, OmaConfig};
use acadl::arch::plasticine::PlasticineConfig;
use acadl::isa::assembler::assemble;
use acadl::sim::engine::Engine;
use acadl::sim::functional::FunctionalSim;

/// Fig. 11: an FU takes exactly `latency` cycles after dependencies
/// resolve.  Measured as the steady-state inter-retirement slope of a
/// dependent MAC chain (boundary effects cancel): raising the MAC latency
/// by ΔL raises the per-MAC cost by exactly ΔL.
#[test]
fn fu_latency_is_exact() {
    let run = |mac_latency: u64, n: usize| {
        let m = OmaConfig {
            mac_latency,
            cache: None,
            dmem: DataMem::Sram { latency: 1 },
            ..OmaConfig::default()
        }
        .build()
        .unwrap();
        let mut src = String::from("movi #1 => r6\nmovi #1 => r7\n");
        for _ in 0..n {
            src.push_str("mac r6, r7 => r8\n"); // dependent chain on r8
        }
        src.push_str("halt");
        let p = assemble(&m.ag, &src, 0).unwrap();
        let mut e = Engine::new(&m.ag, &p).unwrap();
        e.run(100_000).unwrap().cycles
    };
    // Per-MAC steady-state cost at latency L.
    let slope = |l: u64| (run(l, 12) - run(l, 4)) / 8;
    let (s1, s3, s5) = (slope(1), slope(3), slope(5));
    assert_eq!(s3 - s1, 2, "ΔL=2 ⇒ +2 cycles/MAC (got {s1} vs {s3})");
    assert_eq!(s5 - s3, 2, "ΔL=2 ⇒ +2 cycles/MAC (got {s3} vs {s5})");
}

/// Fig. 10 structural hazard: the execute stage is busy while its FU
/// processes, so independent ALU ops cannot overlap on the OMA.
#[test]
fn structural_hazard_blocks_stage() {
    let m = OmaConfig {
        mac_latency: 10,
        cache: None,
        dmem: DataMem::Sram { latency: 1 },
        ..OmaConfig::default()
    }
    .build()
    .unwrap();
    // Two *independent* MACs: without the structural hazard they would
    // overlap; per Fig. 10 they serialize → ≥ 20 cycles of MAC time.
    let src = "movi #1 => r0\n\
               movi #1 => r1\n\
               movi #1 => r2\n\
               movi #1 => r3\n\
               mac r0, r1 => r4\n\
               mac r2, r3 => r5\n\
               halt";
    let p = assemble(&m.ag, src, 0).unwrap();
    let mut e = Engine::new(&m.ag, &p).unwrap();
    let stats = e.run(100_000).unwrap();
    assert!(
        stats.cycles >= 20,
        "independent MACs must serialize on one FU: {} cycles",
        stats.cycles
    );
    assert!(stats.structural_stall_cycles > 0 || stats.cycles >= 20);
}

/// Fig. 9's fetch guard: a smaller issue buffer stalls fetch more.
#[test]
fn issue_buffer_backpressure() {
    let cycles = |issue_buffer: usize| {
        let m = OmaConfig {
            issue_buffer,
            fetch_width: 2,
            cache: None,
            dmem: DataMem::Sram { latency: 1 },
            ..OmaConfig::default()
        }
        .build()
        .unwrap();
        let mut src = String::new();
        for i in 0..24 {
            src.push_str(&format!("movi #{i} => r{}\n", i % 8));
        }
        src.push_str("halt");
        let p = assemble(&m.ag, &src, 0).unwrap();
        let mut e = Engine::new(&m.ag, &p).unwrap();
        let s = e.run(100_000).unwrap();
        (s.cycles, s.fetch_stalls)
    };
    let (c_small, stalls_small) = cycles(2);
    let (c_big, _) = cycles(16);
    assert!(c_small >= c_big, "small buffer can't be faster");
    assert!(stalls_small > 0, "2-deep buffer must stall fetch");
}

/// Fig. 12: DRAM row behavior visible end-to-end — streaming one row is
/// faster than striding across rows of one bank.
#[test]
fn dram_row_locality_end_to_end() {
    let m = OmaConfig {
        cache: None,
        dmem: DataMem::Dram,
        ..OmaConfig::default()
    }
    .build()
    .unwrap();
    let base = m.dmem_base();
    let run = |stride: u64| {
        let mut src = String::new();
        for i in 0..16u64 {
            src.push_str(&format!("load [{:#x}] => r1\n", base + i * stride));
        }
        src.push_str("halt");
        let p = assemble(&m.ag, &src, 0).unwrap();
        let mut e = Engine::new(&m.ag, &p).unwrap();
        e.run(1_000_000).unwrap().cycles
    };
    let sequential = run(4); // same row: row hits
    let strided = run(8 * 1024); // row i*8 of bank 0 every time: conflicts
    assert!(
        strided > sequential,
        "row conflicts must cost cycles: seq={sequential} strided={strided}"
    );
}

/// Fig. 13 + write-back: evicting dirty lines costs backing-store writes.
#[test]
fn cache_writeback_traffic_end_to_end() {
    let m = OmaConfig {
        cache: Some(CacheCfg {
            sets: 2,
            ways: 1,
            line: 16,
            ..CacheCfg::default()
        }),
        dmem: DataMem::Sram { latency: 10 },
        ..OmaConfig::default()
    }
    .build()
    .unwrap();
    let base = m.dmem_base();
    // Write 8 conflicting lines (2-set direct-mapped): every store after
    // the first 2 evicts a dirty line.
    let mut src = String::from("movi #7 => r1\n");
    for i in 0..8u64 {
        src.push_str(&format!("store r1 => [{:#x}]\n", base + i * 32));
    }
    src.push_str("halt");
    let p = assemble(&m.ag, &src, 0).unwrap();
    let mut e = Engine::new(&m.ag, &p).unwrap();
    let stats = e.run(1_000_000).unwrap();
    let dmem = stats
        .storages
        .iter()
        .find(|s| s.name == "dmem0")
        .unwrap();
    assert!(
        dmem.requests >= 6,
        "dirty evictions must reach the backing store: {} requests",
        dmem.requests
    );
}

/// Control hazards: fetch does not run ahead of unresolved branches, and
/// the taken path's architectural state matches the functional ISS on a
/// branchy program.
#[test]
fn branchy_program_timed_equals_functional() {
    let m = OmaConfig::default().build().unwrap();
    let base = m.dmem_base();
    let src = format!(
        "movi #{base} => r10\n\
         movi #10 => r0\n\
         movi #0 => r1\n\
         loop: addi r1, #3 => r1\n\
         subi r1, #1 => r1\n\
         addi r0, #-1 => r0\n\
         bnei r0, z0, @loop => pc\n\
         store r1 => [r10]\n\
         halt"
    );
    let p = assemble(&m.ag, &src, 0).unwrap();
    let mut f = FunctionalSim::new(&m.ag);
    f.run(&p, 100_000).unwrap();
    let mut e = Engine::new(&m.ag, &p).unwrap();
    e.run(1_000_000).unwrap();
    assert_eq!(e.mem.peek(base), f.mem.peek(base));
    assert_eq!(e.mem.peek(base), 20.0); // 10 × (3-1)
}

/// The Eyeriss-derived model end-to-end: DMA stages DRAM→GLB, a PE
/// computes a weighted sum from the GLB, a store unit drains the psum.
#[test]
fn eyeriss_dataflow_end_to_end() {
    let m = EyerissConfig::default().build().unwrap();
    let dram = m.dram_base();
    let glb = m.glb_base();
    // DRAM holds [w, x]; DMA copies both to GLB; PE(0,0) macs them.
    let src = format!(
        "load [{dram:#x}] => dma0_s0\n\
         store dma0_s0 => [{glb:#x}]\n\
         load [{:#x}] => dma0_s1\n\
         store dma0_s1 => [{:#x}]\n\
         load [{glb:#x}] => e0_0_w\n\
         load [{:#x}] => e0_0_x\n\
         mac e0_0_w, e0_0_x => e0_0_p\n\
         store e0_0_p => [{:#x}]\n\
         halt",
        dram + 4,
        glb + 4,
        glb + 4,
        glb + 64,
    );
    let p = assemble(&m.ag, &src, 0).unwrap();
    let mut f = FunctionalSim::new(&m.ag);
    f.mem.load_f32(dram, &[3.0, 4.0]);
    f.run(&p, 100_000).unwrap();
    assert_eq!(f.mem.peek(glb + 64), 12.0);

    let mut e = Engine::new(&m.ag, &p).unwrap();
    e.mem.load_f32(dram, &[3.0, 4.0]);
    let stats = e.run(1_000_000).unwrap();
    assert_eq!(e.mem.peek(glb + 64), 12.0);
    // The DRAM accesses must dominate the GLB ones in latency.
    assert!(stats.cycles > 30, "DRAM latency visible: {}", stats.cycles);
}

/// The Plasticine-derived model end-to-end: a map/zip vector pipeline
/// relu(a·b + a) streamed through PMU scratchpads and a PCU.
#[test]
fn plasticine_pattern_pipeline() {
    let m = PlasticineConfig::default().build().unwrap();
    let (pmu0, _) = m.pmu_range(0);
    let (pmu1, _) = m.pmu_range(1);
    let src = format!(
        "load [{pmu0:#x}] => p[0].0\n\
         load [{:#x}] => p[0].1\n\
         vmul p[0].0, p[0].1 => p[0].2\n\
         vadd p[0].2, p[0].0 => p[0].2\n\
         vrelu p[0].2 => p[0].3\n\
         store p[0].3 => [{pmu1:#x}]\n\
         halt",
        pmu0 + 32,
    );
    let p = assemble(&m.ag, &src, 0).unwrap();
    let a: Vec<f32> = vec![1.0, -2.0, 3.0, -4.0, 5.0, -6.0, 7.0, -8.0];
    let b: Vec<f32> = vec![2.0; 8];
    let mut f = FunctionalSim::new(&m.ag);
    f.mem.load_f32(pmu0, &a);
    f.mem.load_f32(pmu0 + 32, &b);
    f.run(&p, 100_000).unwrap();
    let got = f.mem.dump_f32(pmu1, 8);
    let want: Vec<f32> = a.iter().map(|x| (x * 2.0 + x).max(0.0)).collect();
    assert_eq!(got, want);

    // Timed run commits identical state.
    let mut e = Engine::new(&m.ag, &p).unwrap();
    e.mem.load_f32(pmu0, &a);
    e.mem.load_f32(pmu0 + 32, &b);
    e.run(1_000_000).unwrap();
    assert_eq!(e.mem.dump_f32(pmu1, 8), want);
}

/// Zero-register semantics survive the timed path (Listing 5 relies on
/// `z0` staying zero even when written).
#[test]
fn zero_register_is_hardwired() {
    let m = OmaConfig::default().build().unwrap();
    let p = assemble(&m.ag, "movi #42 => z0\nmov z0 => r1\nhalt", 0).unwrap();
    let mut e = Engine::new(&m.ag, &p).unwrap();
    e.run(10_000).unwrap();
    assert_eq!(e.get_reg("r1"), Some(Value::Int(0)));
}
