//! Property-based tests (E11 + cross-cutting invariants), driven by the
//! in-tree `util::prop` harness:
//!
//! * edge-validity conformance against an independent rule statement,
//! * assembler → disassembler → assembler fixpoint,
//! * GeMM mapping correctness over random shapes on all three targets,
//! * timed-engine ≡ functional-ISS architectural state on random scalar
//!   programs,
//! * coordinator JSON wire-format round-trips,
//! * cache simulator sanity (hits never exceed accesses; LRU beats
//!   pessimal on a scan).

use acadl::acadl_core::edge::{edge_allowed, EdgeKind};
use acadl::acadl_core::latency::Latency;
use acadl::acadl_core::object::build;
use acadl::arch::gamma::GammaConfig;
use acadl::arch::oma::OmaConfig;
use acadl::arch::systolic::SystolicConfig;
use acadl::coordinator::{JobSpec, SimModeSpec, TargetSpec, Workload};
use acadl::isa::assembler::assemble;
use acadl::mapping::gemm::{gemm_ref, GemmParams, LoopOrder};
use acadl::mapping::uma::{lower, Machine, Operator, TargetConfig};
use acadl::mem::cache::{CacheState, ReplacementPolicy};
use acadl::sim::backend::BackendKind;
use acadl::sim::engine::Engine;
use acadl::sim::functional::FunctionalSim;
use acadl::util::json::Json;
use acadl::util::prop::{forall, Gen};

/// E11: `edge_allowed` equals an independently-stated Fig. 1 rule table
/// for every ordered pair of randomly-parameterized objects.
#[test]
fn prop_edge_validity_conformance() {
    let make = |g: &mut Gen| {
        let which = g.usize(0, 9);
        match which {
            0 => build::pipeline_stage("ps", g.int(1, 4) as u64).kind,
            1 => build::execute_stage("ex", g.int(1, 4) as u64).kind,
            2 => build::fetch_stage("ifs", 1, g.usize(1, 16)).kind,
            3 => build::functional_unit("fu", &["add"], Latency::Const(g.int(1, 8) as u64)).kind,
            4 => build::memory_access_unit("mau", &["load"], 1).kind,
            5 => build::instruction_memory_access_unit("imau", 1).kind,
            6 => build::register_file("rf", 32, vec![]).kind,
            7 => acadl::arch::parts::sram("s", 0, 1 << g.usize(6, 16), 1, 1).kind,
            8 => acadl::arch::parts::dram_default("d", 0, 1 << g.usize(10, 20)).kind,
            _ => acadl::arch::parts::cache_default("c").kind,
        }
    };
    forall(
        "edge validity == Fig.1 rules",
        400,
        |g| (make(g), make(g)),
        |(src, dst)| {
            let cases = [
                (
                    EdgeKind::Forward,
                    src.is_pipeline_stage() && dst.is_pipeline_stage(),
                ),
                (
                    EdgeKind::Contains,
                    src.is_execute_stage() && dst.is_functional_unit(),
                ),
                (
                    EdgeKind::ReadData,
                    (src.is_register_file() && dst.is_functional_unit())
                        || (src.is_data_storage() && dst.is_memory_access_unit())
                        || (src.is_data_storage() && dst.is_data_storage()),
                ),
                (
                    EdgeKind::WriteData,
                    (src.is_functional_unit() && dst.is_register_file())
                        || (src.is_memory_access_unit() && dst.is_data_storage())
                        || (src.is_data_storage() && dst.is_data_storage()),
                ),
            ];
            for (kind, want) in cases {
                if edge_allowed(kind, src, dst) != want {
                    return Err(format!("{kind} mismatch"));
                }
            }
            Ok(())
        },
    );
}

/// Assembler fixpoint: disassembling an assembled random program and
/// re-assembling yields the identical instruction encoding.
#[test]
fn prop_assembler_roundtrip() {
    let m = OmaConfig::default().build().unwrap();
    forall(
        "asm -> disasm -> asm fixpoint",
        60,
        |g| {
            let mut src = String::new();
            let n = g.usize(1, 20);
            for _ in 0..n {
                match g.usize(0, 5) {
                    0 => src.push_str(&format!("movi #{} => r{}\n", g.int(-99, 99), g.usize(0, 7))),
                    1 => src.push_str(&format!(
                        "add r{}, r{} => r{}\n",
                        g.usize(0, 7),
                        g.usize(0, 7),
                        g.usize(0, 7)
                    )),
                    2 => src.push_str(&format!(
                        "mac r{}, r{} => r{}\n",
                        g.usize(0, 7),
                        g.usize(0, 7),
                        g.usize(8, 12)
                    )),
                    3 => src.push_str(&format!(
                        "load [{:#x}] => r{}\n",
                        0x10000 + g.usize(0, 255) * 4,
                        g.usize(0, 7)
                    )),
                    4 => src.push_str(&format!(
                        "store r{} => [r{}+{}]\n",
                        g.usize(0, 7),
                        g.usize(8, 12),
                        g.usize(0, 64) * 4
                    )),
                    _ => src.push_str("nop\n"),
                }
            }
            src.push_str("halt\n");
            src
        },
        |src| {
            let p1 = assemble(&m.ag, src, 0).map_err(|e| e.to_string())?;
            let dis = p1.disassemble(&m.ag);
            // Strip the address column the disassembler prefixes.
            let body: String = dis
                .lines()
                .map(|l| l.splitn(2, "  ").nth(1).unwrap_or(l))
                .collect::<Vec<_>>()
                .join("\n");
            let p2 = assemble(&m.ag, &body, 0).map_err(|e| e.to_string())?;
            if p1.instrs != p2.instrs {
                return Err("re-assembly differs".into());
            }
            Ok(())
        },
    );
}

/// Random GeMM shapes map correctly on every target (functional ISS vs
/// host oracle).
#[test]
fn prop_gemm_mapping_correct_all_targets() {
    let oma = TargetConfig::Oma(OmaConfig::default()).build().unwrap();
    let sys = TargetConfig::Systolic(SystolicConfig::new(3, 4)).build().unwrap();
    let gam = TargetConfig::Gamma(GammaConfig::new(2)).build().unwrap();
    forall(
        "gemm mapping correct on all targets",
        12,
        |g| {
            let m = g.usize(1, 10);
            let k = g.usize(1, 10);
            let n = g.usize(1, 10);
            let order = *g.choose(&LoopOrder::ALL);
            let tile = if g.bool() { Some(g.usize(1, 4)) } else { None };
            let a = g.vec_f32(m * k, -2.0, 2.0);
            let b = g.vec_f32(k * n, -2.0, 2.0);
            (m, k, n, order, tile, a, b)
        },
        |(m, k, n, order, tile, a, b)| {
            let mut p = GemmParams::new(*m, *k, *n).with_order(*order);
            if let Some(t) = tile {
                p = p.with_tile(*t);
            }
            let want = gemm_ref(&p, a, b);
            for machine in [&oma, &sys, &gam] {
                // Γ̈ needs multiples of 8: pad operands with zeros.
                let (p2, a2, b2) = if matches!(machine, Machine::Gamma(_)) {
                    let pm = p.m.div_ceil(8) * 8;
                    let pk = p.k.div_ceil(8) * 8;
                    let pn = p.n.div_ceil(8) * 8;
                    let mut ap = vec![0.0; pm * pk];
                    for i in 0..p.m {
                        ap[i * pk..i * pk + p.k].copy_from_slice(&a[i * p.k..(i + 1) * p.k]);
                    }
                    let mut bp = vec![0.0; pk * pn];
                    for i in 0..p.k {
                        bp[i * pn..i * pn + p.n].copy_from_slice(&b[i * p.n..(i + 1) * p.n]);
                    }
                    (GemmParams::new(pm, pk, pn), ap, bp)
                } else {
                    (p, a.clone(), b.clone())
                };
                let lw = lower(machine, &Operator::Gemm(p2)).map_err(|e| e.to_string())?;
                let mut sim = FunctionalSim::new(machine.ag());
                lw.layout.load_inputs(&p2, &mut sim.mem, &a2, &b2);
                sim.run(&lw.program, 100_000_000).map_err(|e| e.to_string())?;
                let got = lw.layout.read_c(&p2, &sim.mem);
                for i in 0..p.m {
                    for j in 0..p.n {
                        let gv = got[i * p2.n + j];
                        let wv = want[i * p.n + j];
                        if (gv - wv).abs() > 1e-2 {
                            return Err(format!(
                                "{}: C[{i}][{j}] = {gv} want {wv}",
                                machine.name()
                            ));
                        }
                    }
                }
            }
            Ok(())
        },
    );
}

/// Timed engine and functional ISS commit identical architectural state on
/// random straight-line scalar programs.
#[test]
fn prop_timed_equals_functional() {
    let m = OmaConfig::default().build().unwrap();
    let base = m.dmem_base();
    forall(
        "timed == functional state",
        25,
        |g| {
            let mut src = String::new();
            for i in 0..g.usize(4, 24) {
                match g.usize(0, 4) {
                    0 => src.push_str(&format!("movi #{} => r{}\n", g.int(-50, 50), g.usize(0, 5))),
                    1 => src.push_str(&format!(
                        "add r{}, r{} => r{}\n",
                        g.usize(0, 5),
                        g.usize(0, 5),
                        g.usize(0, 5)
                    )),
                    2 => src.push_str(&format!(
                        "mul r{}, r{} => r{}\n",
                        g.usize(0, 5),
                        g.usize(0, 5),
                        g.usize(0, 5)
                    )),
                    3 => src.push_str(&format!(
                        "store r{} => [{:#x}]\n",
                        g.usize(0, 5),
                        base + (i as u64) * 4
                    )),
                    _ => src.push_str(&format!(
                        "load [{:#x}] => r{}\n",
                        base + g.usize(0, 23) as u64 * 4,
                        g.usize(0, 5)
                    )),
                }
            }
            src.push_str("halt\n");
            src
        },
        |src| {
            let p = assemble(&m.ag, src, 0).map_err(|e| e.to_string())?;
            let mut f = FunctionalSim::new(&m.ag);
            f.run(&p, 1_000_000).map_err(|e| e.to_string())?;
            let mut e = Engine::new(&m.ag, &p).map_err(|e| e.to_string())?;
            e.run(10_000_000).map_err(|e| e.to_string())?;
            for r in 0..6 {
                let name = format!("r{r}");
                let fv = f.get_reg(&m.ag, &name).map_err(|e| e.to_string())?;
                let ev = e.get_reg(&name).ok_or("missing reg")?;
                if fv != ev {
                    return Err(format!("{name}: functional {fv:?} vs timed {ev:?}"));
                }
            }
            for w in 0..24u64 {
                let (fv, ev) = (f.mem.peek(base + w * 4), e.mem.peek(base + w * 4));
                if fv != ev {
                    return Err(format!("mem[{w}]: {fv} vs {ev}"));
                }
            }
            Ok(())
        },
    );
}

/// Coordinator wire format: random JobSpecs survive JSON round-trips.
#[test]
fn prop_jobspec_json_roundtrip() {
    forall(
        "jobspec json roundtrip",
        100,
        |g| JobSpec {
            id: g.next_u64() % 10_000,
            target: match g.usize(0, 2) {
                0 => TargetSpec::Oma {
                    cache: g.bool(),
                    mac_latency: if g.bool() { Some(g.int(1, 9) as u64) } else { None },
                },
                1 => TargetSpec::Systolic {
                    rows: g.usize(1, 32),
                    cols: g.usize(1, 32),
                },
                _ => TargetSpec::Gamma {
                    units: g.usize(1, 8),
                },
            },
            workload: if g.bool() {
                Workload::Gemm {
                    m: g.usize(1, 64),
                    k: g.usize(1, 64),
                    n: g.usize(1, 64),
                    tile: if g.bool() { Some(g.usize(1, 16)) } else { None },
                    order: if g.bool() {
                        Some(*g.choose(&LoopOrder::ALL))
                    } else {
                        None
                    },
                }
            } else {
                Workload::Mlp {
                    small: g.bool(),
                    batch: g.usize(1, 16),
                }
            },
            mode: *g.choose(&[
                SimModeSpec::Functional,
                SimModeSpec::Timed,
                SimModeSpec::Estimate,
            ]),
            backend: *g.choose(&BackendKind::ALL),
            max_cycles: g.next_u64() % 1_000_000 + 1,
            platform: if g.bool() {
                Some(acadl::coordinator::PlatformSpec {
                    chips: g.usize(1, 4),
                    hop_latency: g.int(0, 16) as u64,
                    microbatches: g.usize(1, 8),
                    threads: g.usize(0, 4),
                })
            } else {
                None
            },
            deadline_ms: if g.bool() {
                Some(g.next_u64() % 60_000)
            } else {
                None
            },
        },
        |spec| {
            let line = spec.to_json().to_string();
            let back = JobSpec::parse(&line).map_err(|e| e.to_string())?;
            if &back != spec {
                return Err(format!("roundtrip differs: {line}"));
            }
            // And the JSON itself re-parses.
            Json::parse(&line).map_err(|e| e.to_string())?;
            Ok(())
        },
    );
}

/// Cache invariants under random access streams: hits+misses == accesses,
/// hit rate in [0,1], and a repeated working set smaller than the cache
/// eventually stops missing (for LRU).
#[test]
fn prop_cache_invariants() {
    forall(
        "cache invariants",
        60,
        |g| {
            let sets = 1 << g.usize(0, 4);
            let ways = g.usize(1, 4);
            let policy = *g.choose(&[
                ReplacementPolicy::Lru,
                ReplacementPolicy::Fifo,
                ReplacementPolicy::Plru,
                ReplacementPolicy::Random,
            ]);
            let accesses: Vec<(u64, bool)> = (0..g.usize(10, 200))
                .map(|_| (g.usize(0, 2047) as u64, g.bool()))
                .collect();
            (sets, ways, policy, accesses)
        },
        |(sets, ways, policy, accesses)| {
            let mut c = CacheState::new(*sets, *ways, 16, *policy, true, true);
            for (a, w) in accesses {
                c.access(*a, *w);
            }
            if c.hits + c.misses != accesses.len() as u64 {
                return Err("hits+misses != accesses".into());
            }
            let r = c.hit_rate();
            if !(0.0..=1.0).contains(&r) {
                return Err(format!("hit rate {r}"));
            }
            Ok(())
        },
    );
    // LRU steady state: a fitting working set stops missing.
    let mut c = CacheState::new(4, 2, 16, ReplacementPolicy::Lru, true, true);
    let ws: Vec<u64> = (0..8).map(|i| i * 16).collect(); // exactly 8 lines
    for _ in 0..4 {
        for &a in &ws {
            c.access(a, false);
        }
    }
    assert_eq!(c.misses, 8, "only compulsory misses for a fitting set");
}
