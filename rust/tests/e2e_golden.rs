//! E9/E12 — golden-model cross-validation: the simulated accelerators'
//! functional results vs the PJRT-executed JAX/Pallas artifacts
//! (`artifacts/*.hlo.txt`, built by `make artifacts`).
//!
//! Without the `pjrt` feature these tests are **ignored** — they show up
//! as `ignored` in the test summary instead of silently passing, so CI
//! cannot mistake "not run" for "validated".  *With* the feature, a
//! missing artifacts directory is a hard failure (the opt-in asked for
//! golden validation; `make artifacts` builds the inputs).

use acadl::arch::gamma::GammaConfig;
use acadl::arch::systolic::SystolicConfig;
use acadl::isa::GAMMA_TILE;
use acadl::mapping::gamma_gemm::{gamma_gemm, GammaGemmOpts};
use acadl::mapping::gemm::{GemmLayout, GemmParams};
use acadl::mapping::systolic_gemm::systolic_gemm;
use acadl::runtime::{Golden, RuntimeError};
use acadl::sim::engine::Engine;
use acadl::util::prop::Gen;

/// Marker every golden test carries: ignored (visibly) when the `pjrt`
/// feature is off, a real run otherwise.
macro_rules! requires_pjrt {
    () => {
        if cfg!(not(feature = "pjrt")) {
            // Belt and braces: the `#[cfg_attr(..., ignore)]` below keeps
            // this unreachable without `--ignored`.
            eprintln!("SKIPPED: built without the `pjrt` feature — run with --features pjrt");
            return;
        }
    };
}

fn golden() -> Option<Golden> {
    match Golden::load_default() {
        Ok(g) => Some(g),
        Err(RuntimeError::NoManifest(p)) => {
            panic!(
                "pjrt builds must validate against the golden artifacts: \
                 manifest missing at {} — run `make artifacts` first",
                p.display()
            )
        }
        Err(RuntimeError::Disabled) => {
            eprintln!("SKIPPED: pjrt runtime disabled at build time");
            None
        }
        Err(e) => panic!("unexpected runtime error: {e}"),
    }
}

fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    a.iter()
        .zip(b)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0, f32::max)
}

/// Γ̈'s gemm instruction (timed engine) ≡ the Pallas kernel via PJRT.
#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "golden cross-validation needs --features pjrt")]
fn gamma_gemm_matches_pallas_kernel() {
    requires_pjrt!();
    let Some(mut golden) = golden() else { return };
    let t = GAMMA_TILE;
    let p = GemmParams::new(t, t, t);
    let machine = GammaConfig::new(1).build().unwrap();
    let prog = gamma_gemm(&machine, &p, GammaGemmOpts::default());
    let layout = GemmLayout::at(machine.dram_base(), &p);

    let mut g = Gen::new(0xE9);
    let a = g.vec_f32(t * t, -2.0, 2.0);
    let b = g.vec_f32(t * t, -2.0, 2.0);

    let mut e = Engine::new(&machine.ag, &prog).unwrap();
    layout.load_inputs(&p, &mut e.mem, &a, &b);
    e.run(10_000_000).unwrap();
    let sim = layout.read_c(&p, &e.mem);

    let pjrt = golden.run("gemm_8x8", &[a, b]).unwrap();
    let diff = max_abs_diff(&sim, &pjrt[0]);
    assert!(diff < 1e-4, "sim vs pallas kernel: max |Δ| = {diff}");
}

/// The ReLU variant (Listing 4's `1:` flag) against `gemm_relu_8x8`.
#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "golden cross-validation needs --features pjrt")]
fn gamma_gemm_relu_matches_pallas_kernel() {
    requires_pjrt!();
    let Some(mut golden) = golden() else { return };
    let t = GAMMA_TILE;
    let p = GemmParams::new(t, t, t);
    let machine = GammaConfig::new(1).build().unwrap();
    let prog = gamma_gemm(
        &machine,
        &p,
        GammaGemmOpts {
            relu: true,
            bias_base: None,
            ..Default::default()
        },
    );
    let layout = GemmLayout::at(machine.dram_base(), &p);

    let mut g = Gen::new(0xE12);
    let a = g.vec_f32(t * t, -2.0, 2.0);
    let b = g.vec_f32(t * t, -2.0, 2.0);

    let mut e = Engine::new(&machine.ag, &prog).unwrap();
    layout.load_inputs(&p, &mut e.mem, &a, &b);
    e.run(10_000_000).unwrap();
    let sim = layout.read_c(&p, &e.mem);
    assert!(sim.iter().all(|&x| x >= 0.0), "ReLU output non-negative");

    let pjrt = golden.run("gemm_relu_8x8", &[a, b]).unwrap();
    let diff = max_abs_diff(&sim, &pjrt[0]);
    assert!(diff < 1e-4, "sim vs pallas relu kernel: max |Δ| = {diff}");
}

/// The systolic array (scalar abstraction level) also reproduces the
/// MXU-tiled 128³ Pallas kernel's numbers on a 16³ sub-problem — different
/// abstraction, same semantics; here the full 128³ is validated on Γ̈
/// against `gemm_tiled_128`.
#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "golden cross-validation needs --features pjrt")]
fn tiled_128_gemm_matches_pallas_kernel() {
    requires_pjrt!();
    let Some(mut golden) = golden() else { return };
    let p = GemmParams::new(128, 128, 128);
    let machine = GammaConfig::new(4).build().unwrap();
    let prog = gamma_gemm(&machine, &p, GammaGemmOpts::default());
    let layout = GemmLayout::at(machine.dram_base(), &p);

    let mut g = Gen::new(0x128);
    let a = g.vec_f32(128 * 128, -1.0, 1.0);
    let b = g.vec_f32(128 * 128, -1.0, 1.0);

    let mut e = Engine::new(&machine.ag, &prog).unwrap();
    layout.load_inputs(&p, &mut e.mem, &a, &b);
    let stats = e.run(2_000_000_000).unwrap();
    let sim = layout.read_c(&p, &e.mem);

    let pjrt = golden.run("gemm_tiled_128", &[a, b]).unwrap();
    let diff = max_abs_diff(&sim, &pjrt[0]);
    assert!(diff < 1e-2, "128³ sim vs pallas: max |Δ| = {diff}");
    assert!(stats.cycles > 0);
}

/// The systolic array agrees with the Pallas kernel too (cross-level).
#[test]
#[cfg_attr(not(feature = "pjrt"), ignore = "golden cross-validation needs --features pjrt")]
fn systolic_matches_pallas_kernel() {
    requires_pjrt!();
    let Some(mut golden) = golden() else { return };
    let t = GAMMA_TILE;
    let p = GemmParams::new(t, t, t);
    let machine = SystolicConfig::new(4, 4).build().unwrap();
    let prog = systolic_gemm(&machine, &p);
    let layout = GemmLayout::at(machine.dmem_base(), &p);

    let mut g = Gen::new(0x5757);
    let a = g.vec_f32(t * t, -2.0, 2.0);
    let b = g.vec_f32(t * t, -2.0, 2.0);

    let mut e = Engine::new(&machine.ag, &prog).unwrap();
    layout.load_inputs(&p, &mut e.mem, &a, &b);
    e.run(10_000_000).unwrap();
    let sim = layout.read_c(&p, &e.mem);

    let pjrt = golden.run("gemm_8x8", &[a, b]).unwrap();
    let diff = max_abs_diff(&sim, &pjrt[0]);
    assert!(diff < 1e-4, "systolic vs pallas: max |Δ| = {diff}");
}
