//! Property tests for the ACADL textual frontend: the pretty-printer
//! round-trips randomized architecture graphs (`parse(print(ag)) ≡ ag`,
//! byte-idempotent), and random `targets`/`param` headers survive the
//! trip — built on `util::prop` (the in-tree proptest substitute).

use acadl::acadl_core::data::Data;
use acadl::acadl_core::edge::EdgeKind;
use acadl::acadl_core::graph::Ag;
use acadl::acadl_core::latency::Latency;
use acadl::acadl_core::object::build;
use acadl::adl::{ag_equiv, load_str, print_arch, print_elab, ParamAxis, ParamValue};
use acadl::arch::platform::PlatformDesc;
use acadl::coordinator::job::TargetSpec;
use acadl::util::prop::{forall, Gen};

/// A random, *valid* flat architecture graph: 1–4 cores (execute stage +
/// functional unit + register file), optionally a memory access unit
/// with an SRAM behind an optional cache, with randomized attributes,
/// exotic names, and occasional duplicate edges.
fn random_ag(g: &mut Gen) -> Ag {
    let mut ag = Ag::new();
    let cores = g.usize(1, 4);
    for c in 0..cores {
        let ex = ag
            .add(build::execute_stage(&format!("core[{c}].ex"), g.int(1, 4) as u64))
            .unwrap();
        let all_ops = ["mac", "add", "mov", "gemm", "vadd", "macf"];
        let n_ops = g.usize(1, all_ops.len());
        let ops: Vec<&str> = (0..n_ops).map(|i| all_ops[i]).collect();
        let latency = if g.bool() {
            Latency::Const(g.int(1, 20) as u64)
        } else {
            Latency::parse(&format!("{} + is_mac * {}", g.int(1, 4), g.int(1, 8)))
                .unwrap()
        };
        let fu = ag
            .add(build::functional_unit(&format!("core[{c}].fu"), &ops, latency))
            .unwrap();
        let mut regs: Vec<(String, Data)> = Vec::new();
        for r in 0..g.usize(1, 4) {
            let name = format!("c{c}_r{r}");
            let data = match g.usize(0, 2) {
                0 => Data::int(32, g.int(-5, 5)),
                1 => Data::f32(0.0),
                _ => Data::vec(128, 8),
            };
            regs.push((name, data));
        }
        let width = if g.bool() { 32 } else { 128 };
        let rf = ag
            .add(build::register_file(&format!("core[{c}].rf"), width, regs))
            .unwrap();
        ag.connect(ex, fu, EdgeKind::Contains).unwrap();
        ag.connect(rf, fu, EdgeKind::ReadData).unwrap();
        ag.connect(fu, rf, EdgeKind::WriteData).unwrap();
        if g.bool() {
            // Duplicate edge: the multiset must survive the round-trip.
            ag.connect(fu, rf, EdgeKind::WriteData).unwrap();
        }

        if g.bool() {
            let mau = ag
                .add(build::memory_access_unit(
                    &format!("core[{c}].mau"),
                    &["load", "store"],
                    g.int(1, 3) as u64,
                ))
                .unwrap();
            ag.connect(ex, mau, EdgeKind::Contains).unwrap();
            ag.connect(rf, mau, EdgeKind::ReadData).unwrap();
            ag.connect(mau, rf, EdgeKind::WriteData).unwrap();
            let base = 0x1000 * (c as u64 + 1) * 16;
            let end = base + 0x100 * g.int(1, 16) as u64;
            let sram = ag
                .add(acadl::arch::parts::sram_ports(
                    &format!("core[{c}].sram"),
                    base,
                    end,
                    g.int(1, 8) as u64,
                    g.usize(1, 8),
                    g.usize(1, 4),
                    g.usize(1, 4),
                ))
                .unwrap();
            if g.bool() {
                use acadl::mem::cache::ReplacementPolicy;
                let policy = *g.choose(&[
                    ReplacementPolicy::Lru,
                    ReplacementPolicy::Fifo,
                    ReplacementPolicy::Plru,
                    ReplacementPolicy::Random,
                ]);
                let cache = ag
                    .add(acadl::arch::parts::cache(
                        &format!("core[{c}].cache"),
                        1 << g.usize(2, 6),
                        1 << g.usize(0, 3),
                        64,
                        policy,
                        g.int(1, 2) as u64,
                        g.int(4, 12) as u64,
                    ))
                    .unwrap();
                ag.connect(mau, cache, EdgeKind::WriteData).unwrap();
                ag.connect(cache, mau, EdgeKind::ReadData).unwrap();
                ag.connect(cache, sram, EdgeKind::WriteData).unwrap();
                ag.connect(sram, cache, EdgeKind::ReadData).unwrap();
            } else {
                ag.connect(mau, sram, EdgeKind::WriteData).unwrap();
                ag.connect(sram, mau, EdgeKind::ReadData).unwrap();
            }
        }
    }
    ag.validate().expect("generator must emit valid graphs");
    ag
}

#[test]
fn printer_roundtrips_random_graphs() {
    forall(
        "parse(print(ag)) ≡ ag over random graphs",
        64,
        |g| {
            let ag = random_ag(g);
            // Return the printed form: it is both the test input and the
            // debug artifact shown on failure.
            print_arch("rand", None, None, &[], &ag)
        },
        |printed| {
            let e = load_str(printed).map_err(|err| format!("reparse failed: {err}"))?;
            let back = print_elab(&e);
            if back != *printed {
                return Err("printing is not byte-idempotent".into());
            }
            Ok(())
        },
    );
}

#[test]
fn roundtrip_preserves_graph_equivalence() {
    forall(
        "ag_equiv(ag, parse(print(ag)))",
        32,
        random_ag,
        |ag| {
            let printed = print_arch("rand", None, None, &[], ag);
            let e = load_str(&printed).map_err(|err| format!("reparse failed: {err}"))?;
            ag_equiv(ag, &e.ag)
        },
    );
}

/// Random (target, params) headers survive the round-trip.
#[test]
fn headers_roundtrip() {
    forall(
        "target + param headers round-trip",
        32,
        |g| {
            let (target, params) = match g.usize(0, 2) {
                0 => (
                    TargetSpec::Oma {
                        cache: g.bool(),
                        mac_latency: if g.bool() {
                            Some(g.int(1, 8) as u64)
                        } else {
                            None
                        },
                    },
                    vec![
                        ParamAxis {
                            key: "tile".into(),
                            values: vec![ParamValue::Int(2), ParamValue::Int(4)],
                        },
                        ParamAxis {
                            key: "order".into(),
                            values: vec![
                                ParamValue::Name("ijk".into()),
                                ParamValue::Name("kij".into()),
                            ],
                        },
                    ],
                ),
                1 => (
                    TargetSpec::Systolic {
                        rows: 1 << g.usize(1, 4),
                        cols: 1 << g.usize(1, 4),
                    },
                    vec![ParamAxis {
                        key: "rows".into(),
                        values: vec![ParamValue::Int(2), ParamValue::Int(4), ParamValue::Int(8)],
                    }],
                ),
                _ => (
                    TargetSpec::Gamma {
                        units: g.usize(1, 8),
                    },
                    vec![ParamAxis {
                        key: "units".into(),
                        values: vec![ParamValue::Int(1), ParamValue::Int(2)],
                    }],
                ),
            };
            // Optionally shard the chip across a randomized platform.
            let platform = if g.bool() {
                Some(
                    PlatformDesc::new(1 << g.usize(0, 3))
                        .with_hop_latency(g.int(0, 16) as u64)
                        .with_microbatches(g.usize(1, 8)),
                )
            } else {
                None
            };
            let ag = random_ag(g);
            (target, platform, params, print_arch("hdr", None, None, &[], &ag))
        },
        |(target, platform, params, body)| {
            // Reuse the printed body; prepend a fresh header.
            let ag = load_str(body).map_err(|e| e.to_string())?.ag;
            let printed = print_arch("hdr", Some(target), platform.as_ref(), params, &ag);
            let e = load_str(&printed).map_err(|err| format!("reparse failed: {err}"))?;
            if e.target.as_ref() != Some(target) {
                return Err(format!("target changed: {:?} vs {:?}", e.target, target));
            }
            if e.platform != *platform {
                return Err(format!(
                    "platform changed: {:?} vs {:?}",
                    e.platform, platform
                ));
            }
            if e.params != *params {
                return Err(format!("params changed: {:?} vs {:?}", e.params, params));
            }
            if print_elab(&e) != printed {
                return Err("not byte-idempotent".into());
            }
            Ok(())
        },
    );
}
