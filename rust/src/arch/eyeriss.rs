//! An Eyeriss-v1-derived accelerator model (§6: the AIDG timing semantics
//! were validated on "an Eyeriss v1 derived accelerator" [26]).
//!
//! Three-level storage hierarchy with a spatial PE array:
//!
//! * DRAM — off-chip, banked timing;
//! * GLB — the global buffer SRAM;
//! * PE array — `rows×cols` PEs, each with a register file holding
//!   `ifmap`/`weight`/`psum` values and a MAC FU (row-stationary at our
//!   scalar abstraction: weights stay resident per PE while ifmap values
//!   stream).
//!
//! DMA units stage DRAM↔GLB transfers through staging registers (our MAU
//! semantics move memory↔register, so a copy is a `load` + `store` pair —
//! exactly how the paper's MemoryAccessUnit is defined); PE load units
//! multicast GLB rows into PE register files; store units drain psums.

use crate::acadl_core::data::Data;
use crate::acadl_core::edge::EdgeKind;
use crate::acadl_core::graph::{Ag, AgError, ObjId};
use crate::acadl_core::latency::Latency;
use crate::acadl_core::object::build;
use crate::arch::parts;

#[derive(Debug, Clone)]
pub struct EyerissConfig {
    pub rows: usize,
    pub cols: usize,
    pub mac_latency: u64,
    /// Global buffer size in bytes.
    pub glb_bytes: u64,
    pub glb_latency: u64,
    pub dma_units: usize,
    pub issue_buffer: usize,
    pub imem_range: (u64, u64),
    pub glb_base: u64,
    pub dram_range: (u64, u64),
}

impl Default for EyerissConfig {
    fn default() -> Self {
        EyerissConfig {
            rows: 3,
            cols: 4,
            mac_latency: 1,
            glb_bytes: 0x20000,
            glb_latency: 2,
            dma_units: 2,
            issue_buffer: 64,
            imem_range: (0, 0x100000),
            glb_base: 0x20_0000,
            dram_range: (0x1000_0000, 0x2000_0000),
        }
    }
}

#[derive(Debug, Clone)]
pub struct EyerissMachine {
    pub ag: Ag,
    pub cfg: EyerissConfig,
    pub glb: ObjId,
    pub dram: ObjId,
}

impl EyerissConfig {
    pub fn build(&self) -> Result<EyerissMachine, AgError> {
        let mut ag = Ag::new();
        let fe = parts::fetch_frontend(
            &mut ag,
            "",
            self.imem_range.0,
            self.imem_range.1,
            self.issue_buffer,
            4,
        )?;
        let dram = ag.add(parts::dram_ports(
            "dram0",
            self.dram_range.0,
            self.dram_range.1,
            self.dma_units,
        ))?;
        let units_on_glb = self.dma_units + self.rows + self.cols;
        let glb = ag.add(parts::sram_ports(
            "glb0",
            self.glb_base,
            self.glb_base + self.glb_bytes,
            self.glb_latency,
            4,
            units_on_glb,
            4,
        ))?;

        // DMA units: staging register + MAU reaching both DRAM and GLB.
        for u in 0..self.dma_units {
            let ex = ag.add(build::execute_stage(&format!("dma_ex[{u}]"), 1))?;
            let mau = ag.add(build::memory_access_unit(
                &format!("dma[{u}]"),
                &["load", "store"],
                1,
            ))?;
            let rf = ag.add(build::register_file(
                &format!("dma_rf[{u}]"),
                32,
                (0..4)
                    .map(|r| (format!("dma{u}_s{r}"), Data::f32(0.0)))
                    .collect(),
            ))?;
            ag.connect(ex, mau, EdgeKind::Contains)?;
            ag.connect(fe.ifs, ex, EdgeKind::Forward)?;
            ag.connect(mau, rf, EdgeKind::WriteData)?;
            ag.connect(rf, mau, EdgeKind::ReadData)?;
            ag.connect(dram, mau, EdgeKind::ReadData)?;
            ag.connect(mau, dram, EdgeKind::WriteData)?;
            ag.connect(glb, mau, EdgeKind::ReadData)?;
            ag.connect(mau, glb, EdgeKind::WriteData)?;
        }

        // PE array.
        let mut pe_rfs = Vec::new();
        for r in 0..self.rows {
            for c in 0..self.cols {
                let ex = ag.add(build::execute_stage(&format!("pe_ex[{r}][{c}]"), 1))?;
                let fu = ag.add(build::functional_unit(
                    &format!("pe_fu[{r}][{c}]"),
                    &["mac", "mov", "movi"],
                    Latency::Const(self.mac_latency),
                ))?;
                let rf = ag.add(build::register_file(
                    &format!("pe_rf[{r}][{c}]"),
                    32,
                    vec![
                        (format!("e{r}_{c}_w"), Data::f32(0.0)),
                        (format!("e{r}_{c}_x"), Data::f32(0.0)),
                        (format!("e{r}_{c}_p"), Data::f32(0.0)),
                    ],
                ))?;
                ag.connect(ex, fu, EdgeKind::Contains)?;
                ag.connect(rf, fu, EdgeKind::ReadData)?;
                ag.connect(fu, rf, EdgeKind::WriteData)?;
                ag.connect(fe.ifs, ex, EdgeKind::Forward)?;
                pe_rfs.push(rf);
            }
        }

        // GLB↔PE load/store units (one per row feeds ifmaps/weights; one
        // per column drains psums).
        for r in 0..self.rows {
            let ex = ag.add(build::execute_stage(&format!("glbl_ex[{r}]"), 1))?;
            let mau = ag.add(build::memory_access_unit(
                &format!("glbl[{r}]"),
                &["load"],
                1,
            ))?;
            ag.connect(ex, mau, EdgeKind::Contains)?;
            ag.connect(fe.ifs, ex, EdgeKind::Forward)?;
            ag.connect(glb, mau, EdgeKind::ReadData)?;
            for rf in &pe_rfs {
                ag.connect(mau, *rf, EdgeKind::WriteData)?;
            }
        }
        for c in 0..self.cols {
            let ex = ag.add(build::execute_stage(&format!("glbs_ex[{c}]"), 1))?;
            let mau = ag.add(build::memory_access_unit(
                &format!("glbs[{c}]"),
                &["store"],
                1,
            ))?;
            ag.connect(ex, mau, EdgeKind::Contains)?;
            ag.connect(fe.ifs, ex, EdgeKind::Forward)?;
            ag.connect(mau, glb, EdgeKind::WriteData)?;
            for rf in &pe_rfs {
                ag.connect(*rf, mau, EdgeKind::ReadData)?;
            }
        }

        ag.validate()?;
        Ok(EyerissMachine {
            ag,
            cfg: self.clone(),
            glb,
            dram,
        })
    }
}

impl EyerissMachine {
    pub fn glb_base(&self) -> u64 {
        self.cfg.glb_base
    }

    pub fn dram_base(&self) -> u64 {
        self.cfg.dram_range.0
    }

    pub fn pe_reg(&self, r: usize, c: usize, which: &str) -> String {
        format!("e{r}_{c}_{which}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_validates() {
        let m = EyerissConfig::default().build().unwrap();
        let s = m.ag.summary();
        assert!(s.contains("DRAM=1"), "{s}");
        // 3 regs per PE × 12 PEs + 2 DMA × 4 staging + pc = 45.
        assert_eq!(m.ag.reg_count(), 45);
    }

    #[test]
    fn dma_reaches_both_levels() {
        let m = EyerissConfig::default().build().unwrap();
        let dma = m.ag.id("dma[0]").unwrap();
        let storages = m.ag.storages_of_mau(dma);
        assert!(storages.contains(&m.glb));
        assert!(storages.contains(&m.dram));
    }
}
