//! A Plasticine-derived pattern-compute model (§6: the AIDG semantics were
//! validated on "a Plasticine derived architecture" [27]).
//!
//! Plasticine organizes reconfigurable *pattern compute units* (PCUs —
//! SIMD pipelines) and *pattern memory units* (PMUs — scratchpads with
//! address generation) on an interconnect.  At ACADL's tensor abstraction
//! level we model a chain of `stages` PCU/PMU pairs:
//!
//! * PMU `i` — scratchpad SRAM + MAU with vector staging registers
//!   (`load`/`store` of whole 8-lane rows);
//! * PCU `i` — ExecuteStage + vector FU (`vadd vmul vrelu vmaxp`) over a
//!   vector register file.
//!
//! Dataflow programs stream rows: PMU loads feed PCU vector ops whose
//! results the next PMU stores — the "parallel patterns" map/zip pipeline.

use crate::acadl_core::data::Data;
use crate::acadl_core::edge::EdgeKind;
use crate::acadl_core::graph::{Ag, AgError, ObjId};
use crate::acadl_core::latency::Latency;
use crate::acadl_core::object::build;
use crate::arch::parts;
use crate::isa::GAMMA_TILE;

#[derive(Debug, Clone)]
pub struct PlasticineConfig {
    /// Number of PCU/PMU pairs in the chain.
    pub stages: usize,
    /// Vector registers per PCU.
    pub vregs: usize,
    pub vec_latency: u64,
    pub pmu_bytes: u64,
    pub pmu_latency: u64,
    pub issue_buffer: usize,
    pub imem_range: (u64, u64),
    pub pmu_base: u64,
    pub dram_range: (u64, u64),
}

impl Default for PlasticineConfig {
    fn default() -> Self {
        PlasticineConfig {
            stages: 4,
            vregs: 16,
            vec_latency: 1,
            pmu_bytes: 0x4000,
            pmu_latency: 1,
            issue_buffer: 48,
            imem_range: (0, 0x100000),
            pmu_base: 0x40_0000,
            dram_range: (0x1000_0000, 0x2000_0000),
        }
    }
}

#[derive(Debug, Clone)]
pub struct PlasticineMachine {
    pub ag: Ag,
    pub cfg: PlasticineConfig,
    pub pmus: Vec<ObjId>,
    pub dram: ObjId,
}

impl PlasticineConfig {
    pub fn build(&self) -> Result<PlasticineMachine, AgError> {
        let mut ag = Ag::new();
        let fe = parts::fetch_frontend(
            &mut ag,
            "",
            self.imem_range.0,
            self.imem_range.1,
            self.issue_buffer,
            4,
        )?;
        let dram = ag.add(parts::dram_ports(
            "dram0",
            self.dram_range.0,
            self.dram_range.1,
            self.stages,
        ))?;

        let mut pmus = Vec::with_capacity(self.stages);
        let mut prev_pmu: Option<ObjId> = None;
        for i in 0..self.stages {
            let lo = self.pmu_base + i as u64 * self.pmu_bytes;
            let pmu = ag.add(parts::sram_ports(
                &format!("pmu[{i}]"),
                lo,
                lo + self.pmu_bytes,
                self.pmu_latency,
                GAMMA_TILE,
                4,
                2,
            ))?;

            // PCU: vector FU + vector rf.
            let ex = ag.add(build::execute_stage(&format!("pcu_ex[{i}]"), 1))?;
            let fu = ag.add(build::functional_unit(
                &format!("pcu_fu[{i}]"),
                &["vadd", "vmul", "vrelu", "vmaxp", "mov"],
                Latency::Const(self.vec_latency),
            ))?;
            let vrf = ag.add(build::register_file(
                &format!("pcu_rf[{i}]"),
                128,
                (0..self.vregs)
                    .map(|r| (format!("p[{i}].{r}"), Data::vec(128, GAMMA_TILE)))
                    .collect(),
            ))?;
            ag.connect(ex, fu, EdgeKind::Contains)?;
            ag.connect(vrf, fu, EdgeKind::ReadData)?;
            ag.connect(fu, vrf, EdgeKind::WriteData)?;
            ag.connect(fe.ifs, ex, EdgeKind::Forward)?;

            // PMU access unit: feeds this PCU's registers from its own
            // scratchpad, the previous stage's scratchpad, and DRAM.
            let mex = ag.add(build::execute_stage(&format!("pmu_ex[{i}]"), 1))?;
            let mau = ag.add(build::memory_access_unit(
                &format!("pmu_mau[{i}]"),
                &["load", "store"],
                1,
            ))?;
            ag.connect(mex, mau, EdgeKind::Contains)?;
            ag.connect(fe.ifs, mex, EdgeKind::Forward)?;
            ag.connect(mau, vrf, EdgeKind::WriteData)?;
            ag.connect(vrf, mau, EdgeKind::ReadData)?;
            ag.connect(pmu, mau, EdgeKind::ReadData)?;
            ag.connect(mau, pmu, EdgeKind::WriteData)?;
            ag.connect(dram, mau, EdgeKind::ReadData)?;
            ag.connect(mau, dram, EdgeKind::WriteData)?;
            if let Some(prev) = prev_pmu {
                ag.connect(prev, mau, EdgeKind::ReadData)?;
                ag.connect(mau, prev, EdgeKind::WriteData)?;
            }
            prev_pmu = Some(pmu);
            pmus.push(pmu);
        }

        ag.validate()?;
        Ok(PlasticineMachine {
            ag,
            cfg: self.clone(),
            pmus,
            dram,
        })
    }
}

impl PlasticineMachine {
    pub fn vreg(&self, stage: usize, idx: usize) -> String {
        format!("p[{stage}].{idx}")
    }

    pub fn pmu_range(&self, stage: usize) -> (u64, u64) {
        let lo = self.cfg.pmu_base + stage as u64 * self.cfg.pmu_bytes;
        (lo, lo + self.cfg.pmu_bytes)
    }

    pub fn dram_base(&self) -> u64 {
        self.cfg.dram_range.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_validates() {
        let m = PlasticineConfig::default().build().unwrap();
        assert_eq!(m.pmus.len(), 4);
        assert_eq!(m.ag.reg_count(), 4 * 16 + 1);
    }

    #[test]
    fn chain_reaches_previous_pmu() {
        let m = PlasticineConfig::default().build().unwrap();
        let mau1 = m.ag.id("pmu_mau[1]").unwrap();
        let storages = m.ag.storages_of_mau(mau1);
        assert!(storages.contains(&m.pmus[0]), "reads previous stage");
        assert!(storages.contains(&m.pmus[1]));
        assert!(!storages.contains(&m.pmus[2]), "no skip-ahead");
    }
}
