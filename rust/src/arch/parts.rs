//! Shared building blocks for the model zoo: storage constructors and the
//! fetch front-end template every paper model reuses (imem + pc register
//! file + InstructionMemoryAccessUnit + InstructionFetchStage, §4.1).

use crate::acadl_core::data::Data;
use crate::acadl_core::edge::EdgeKind;
use crate::acadl_core::graph::{Ag, AgError, ObjId};
use crate::acadl_core::latency::Latency;
use crate::acadl_core::object::{
    build, DataStorageParams, Dram, Object, ObjectKind, SetAssociativeCache, Sram,
};
use crate::mem::cache::ReplacementPolicy;

/// SRAM object: `[base, end)` byte range, given read/write latency and
/// port width (words per transaction).
pub fn sram(name: &str, base: u64, end: u64, latency: u64, port_width: usize) -> Object {
    sram_ports(name, base, end, latency, port_width, 4, 2)
}

/// SRAM with explicit port count and concurrent-request slots (banked
/// scratchpads feeding many MAUs, e.g. the systolic array's data memory).
pub fn sram_ports(
    name: &str,
    base: u64,
    end: u64,
    latency: u64,
    port_width: usize,
    ports: usize,
    slots: usize,
) -> Object {
    Object::new(
        name,
        ObjectKind::Sram(Sram {
            ds: DataStorageParams {
                data_width: 32,
                max_concurrent_requests: slots,
                read_write_ports: ports,
                port_width,
            },
            read_latency: Latency::Const(latency),
            write_latency: Latency::Const(latency),
            address_range: (base, end),
        }),
    )
}

/// DRAM object with DDR4-ish default timing (in controller cycles).
pub fn dram_default(name: &str, base: u64, end: u64) -> Object {
    dram_ports(name, base, end, 4)
}

/// DRAM with an explicit memory-controller port count (models with many
/// load/store units sharing one channel).
pub fn dram_ports(name: &str, base: u64, end: u64, ports: usize) -> Object {
    Object::new(
        name,
        ObjectKind::Dram(Dram {
            ds: DataStorageParams {
                data_width: 32,
                max_concurrent_requests: ports.max(4),
                read_write_ports: ports.max(4),
                port_width: 8,
            },
            address_range: (base, end),
            banks: 8,
            row_bytes: 1024,
            t_rcd: 14,
            t_rp: 14,
            t_ras: 33,
            t_cas: 10,
        }),
    )
}

/// Small default L1-style cache: 64 sets × 4 ways × 64 B lines, LRU,
/// write-allocate + write-back, 1-cycle hit, 8-cycle miss overhead.
pub fn cache_default(name: &str) -> Object {
    cache(name, 64, 4, 64, ReplacementPolicy::Lru, 1, 8)
}

pub fn cache(
    name: &str,
    sets: usize,
    ways: usize,
    line: u64,
    policy: ReplacementPolicy,
    hit_latency: u64,
    miss_latency: u64,
) -> Object {
    Object::new(
        name,
        ObjectKind::Cache(SetAssociativeCache {
            ds: DataStorageParams {
                data_width: 32,
                max_concurrent_requests: 2,
                read_write_ports: 4,
                port_width: 1,
            },
            write_allocate: true,
            write_back: true,
            miss_latency: Latency::Const(miss_latency),
            hit_latency: Latency::Const(hit_latency),
            cache_line_size: line,
            replacement_policy: policy,
            sets,
            ways,
        }),
    )
}

/// Mnemonics of the scalar epilogue unit: exactly the instruction set the
/// row-wise transformer mappers ([`crate::mapping::rowwise`]) emit for
/// softmax / layer-norm / GELU / residual-add / transpose loops.  Shared
/// by the systolic and Γ̈ models so the two epilogues (and their `.acadl`
/// descriptions) cannot drift apart.  Deliberately excludes `mac` so the
/// unit never dilutes the MAC-capable utilization statistic.
pub const SCALAR_EPILOGUE_OPS: &[&str] = &[
    "add", "div", "exp", "gelu", "max", "movi", "mul", "rsqrt", "sub",
];

/// Number of scalar registers (`s0..s{N-1}`) in the epilogue register
/// file.
pub const SCALAR_EPILOGUE_REGS: usize = 8;

/// Attach a scalar post-processing ("epilogue") unit to a parallel model:
/// one execute stage `sfu_ex0` containing a scalar FU `sfu0`
/// ([`SCALAR_EPILOGUE_OPS`]) and a MAU `smau0` (`load store`), over a
/// small register file `srf0` (`s0..s7`), with the MAU wired to `dmem`.
///
/// This is the softmax/layer-norm engine of the transformer mappings:
/// GeMM-shaped work keeps running on the array / tensor units, while the
/// streaming row reductions (max, Σexp, mean/variance) run here — the
/// usual "vector/scalar tail unit" of real accelerators.  The unit's
/// registers are private (`s*` names), so it can never capture
/// instructions belonging to the PE grid or the tensor units: existing
/// programs route, and time, exactly as before.
pub fn scalar_epilogue(ag: &mut Ag, ifs: ObjId, dmem: ObjId) -> Result<(), AgError> {
    let ex = ag.add(build::execute_stage("sfu_ex0", 1))?;
    let fu = ag.add(build::functional_unit(
        "sfu0",
        SCALAR_EPILOGUE_OPS,
        Latency::Const(1),
    ))?;
    let mau = ag.add(build::memory_access_unit("smau0", &["load", "store"], 1))?;
    let rf = ag.add(build::register_file(
        "srf0",
        32,
        (0..SCALAR_EPILOGUE_REGS)
            .map(|i| (format!("s{i}"), Data::int(32, 0)))
            .collect(),
    ))?;
    ag.connect(ifs, ex, EdgeKind::Forward)?;
    ag.connect(ex, fu, EdgeKind::Contains)?;
    ag.connect(ex, mau, EdgeKind::Contains)?;
    ag.connect(fu, rf, EdgeKind::WriteData)?;
    ag.connect(rf, fu, EdgeKind::ReadData)?;
    ag.connect(mau, rf, EdgeKind::WriteData)?;
    ag.connect(rf, mau, EdgeKind::ReadData)?;
    ag.connect(mau, dmem, EdgeKind::WriteData)?;
    ag.connect(dmem, mau, EdgeKind::ReadData)?;
    Ok(())
}

/// A complete fetch front-end (Fig. 3's upper half): instruction memory,
/// pc register file, IMAU, and the fetch stage containing it.
///
/// Returns `(ifs, imem)`. The caller wires `FORWARD` edges from `ifs` to
/// its decode/execute stages.
pub struct FetchFrontend {
    pub ifs: ObjId,
    pub imau: ObjId,
    pub imem: ObjId,
    pub pcrf: ObjId,
}

/// `prefix` namespaces object and register names (`{prefix}ifs0` etc.) so a
/// model can host several independent front-ends.
pub fn fetch_frontend(
    ag: &mut Ag,
    prefix: &str,
    imem_base: u64,
    imem_end: u64,
    issue_buffer_size: usize,
    fetch_port_width: usize,
) -> Result<FetchFrontend, AgError> {
    let imem = ag.add(sram(
        &format!("{prefix}imem0"),
        imem_base,
        imem_end,
        1,
        fetch_port_width,
    ))?;
    let pcrf = ag.add(build::register_file(
        &format!("{prefix}pcrf0"),
        32,
        vec![(format!("{prefix}pc"), Data::int(32, imem_base as i64))],
    ))?;
    let imau = ag.add(build::instruction_memory_access_unit(
        &format!("{prefix}imau0"),
        1,
    ))?;
    let ifs = ag.add(build::fetch_stage(
        &format!("{prefix}ifs0"),
        1,
        issue_buffer_size,
    ))?;
    ag.connect(imem, imau, EdgeKind::ReadData)?;
    ag.connect(pcrf, imau, EdgeKind::ReadData)?;
    ag.connect(imau, pcrf, EdgeKind::WriteData)?;
    ag.connect(ifs, imau, EdgeKind::Contains)?;
    Ok(FetchFrontend {
        ifs,
        imau,
        imem,
        pcrf,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frontend_wires_validate() {
        let mut ag = Ag::new();
        let fe = fetch_frontend(&mut ag, "", 0, 0x1000, 4, 4).unwrap();
        assert_eq!(ag.instruction_memory(fe.ifs), Some(fe.imem));
        ag.validate().unwrap();
    }

    #[test]
    fn prefixed_frontends_coexist() {
        let mut ag = Ag::new();
        fetch_frontend(&mut ag, "a_", 0, 0x1000, 4, 4).unwrap();
        fetch_frontend(&mut ag, "b_", 0x1000, 0x2000, 8, 2).unwrap();
        assert_eq!(ag.fetch_stages().len(), 2);
        ag.validate().unwrap();
    }

    #[test]
    fn storage_constructors_classify() {
        let mut ag = Ag::new();
        let s = ag.add(sram("s", 0, 64, 1, 1)).unwrap();
        let d = ag.add(dram_default("d", 0x1000, 0x2000)).unwrap();
        let c = ag.add(cache_default("c")).unwrap();
        assert!(ag.kind(s).is_memory_interface());
        assert!(ag.kind(d).is_memory_interface());
        assert!(ag.kind(c).is_cache());
        assert!(ag.storage_accepts(s, 10));
        assert!(!ag.storage_accepts(s, 64));
        assert!(ag.storage_accepts(d, 0x1800));
    }
}
