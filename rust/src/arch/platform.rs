//! Multi-accelerator **platform** descriptions: N zoo machines behind a
//! shared fabric (per-hop transfer latency, bounded link bandwidth) and a
//! shared DRAM (weight/activation streaming).  A platform is pure
//! configuration — `sim::platform` turns one plus a partitioned DNN
//! workload into cycle counts, and `dse::DseSpace` sweeps the chip-count
//! and fabric-latency axes for cycles-vs-chips Pareto points.
//!
//! The cost model is deliberately simple and **closed-form per transfer**
//! (hops × hop latency + words / link width): every quantity the parallel
//! simulator needs for its conservative timing recurrence is a pure
//! function of the description, which is what makes the `--threads 1` ≡
//! `--threads N` invariant provable rather than hoped-for.

/// The inter-chip interconnect: a linear chain of links (chip `i` talks
/// to chip `i+1`), each hop adding a fixed latency, all hops sharing one
/// words-per-cycle link width.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FabricConfig {
    /// Fixed cycles added per hop traversed (0 = wires are free).
    pub hop_latency: u64,
    /// Payload words moved per cycle once the route is open.
    pub link_words_per_cycle: u64,
}

impl Default for FabricConfig {
    fn default() -> Self {
        FabricConfig {
            hop_latency: 4,
            link_words_per_cycle: 4,
        }
    }
}

impl FabricConfig {
    /// Cycles to move `words` across `hops` links: route-opening latency
    /// plus serialization at the link width.  Zero words cost zero cycles
    /// (no transfer is issued), matching the deadlock-freedom tests'
    /// zero-latency-fabric case.
    pub fn transfer_cycles(&self, words: usize, hops: u64) -> u64 {
        if words == 0 {
            return 0;
        }
        let width = self.link_words_per_cycle.max(1);
        hops * self.hop_latency + (words as u64).div_ceil(width)
    }
}

/// The platform-shared DRAM all chips load weights/inputs from and store
/// outputs to — one channel, so concurrent streams serialize (the timing
/// recurrence orders them deterministically by stage then microbatch).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SharedDramConfig {
    /// Fixed access latency per burst.
    pub base_latency: u64,
    /// Streaming words per cycle once the burst is open.
    pub words_per_cycle: u64,
}

impl Default for SharedDramConfig {
    fn default() -> Self {
        SharedDramConfig {
            base_latency: 8,
            words_per_cycle: 2,
        }
    }
}

impl SharedDramConfig {
    /// Cycles to stream `words` out of the shared DRAM.
    pub fn load_cycles(&self, words: usize) -> u64 {
        if words == 0 {
            return 0;
        }
        self.base_latency + (words as u64).div_ceil(self.words_per_cycle.max(1))
    }

    /// Cycles to stream `words` into the shared DRAM (same channel model).
    pub fn store_cycles(&self, words: usize) -> u64 {
        self.load_cycles(words)
    }
}

/// A platform: `chips` accelerators in a chain behind one fabric and one
/// shared DRAM, pipelining `microbatches` independent inferences.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PlatformDesc {
    /// Number of accelerator chips (pipeline stages available).
    pub chips: usize,
    pub fabric: FabricConfig,
    pub dram: SharedDramConfig,
    /// Independent inferences pipelined through the chip stages.  More
    /// microbatches amortize the pipeline fill/drain and expose more
    /// thread-level parallelism to the simulator.
    pub microbatches: usize,
}

impl Default for PlatformDesc {
    fn default() -> Self {
        PlatformDesc {
            chips: 1,
            fabric: FabricConfig::default(),
            dram: SharedDramConfig::default(),
            microbatches: 4,
        }
    }
}

impl PlatformDesc {
    pub fn new(chips: usize) -> Self {
        PlatformDesc {
            chips: chips.max(1),
            ..PlatformDesc::default()
        }
    }

    pub fn with_hop_latency(mut self, hop_latency: u64) -> Self {
        self.fabric.hop_latency = hop_latency;
        self
    }

    pub fn with_microbatches(mut self, microbatches: usize) -> Self {
        self.microbatches = microbatches.max(1);
        self
    }

    /// Chip counts a DSE space sweeps: powers of two up to `max`.
    pub fn enumerate_chip_counts(max: usize) -> Vec<usize> {
        let mut out = Vec::new();
        let mut c = 1;
        while c <= max.max(1) {
            out.push(c);
            c *= 2;
        }
        out
    }

    /// Fabric hop latencies a DSE space sweeps.
    pub fn enumerate_hop_latencies() -> Vec<u64> {
        vec![0, 4, 16]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn transfer_cost_is_hops_plus_serialization() {
        let f = FabricConfig {
            hop_latency: 4,
            link_words_per_cycle: 4,
        };
        assert_eq!(f.transfer_cycles(0, 3), 0, "no words, no transfer");
        assert_eq!(f.transfer_cycles(1, 1), 4 + 1);
        assert_eq!(f.transfer_cycles(16, 1), 4 + 4);
        assert_eq!(f.transfer_cycles(17, 2), 8 + 5);
        // A zero-latency fabric still serializes payload.
        let free = FabricConfig {
            hop_latency: 0,
            link_words_per_cycle: 4,
        };
        assert_eq!(free.transfer_cycles(8, 5), 2);
    }

    #[test]
    fn dram_streaming_cost() {
        let d = SharedDramConfig {
            base_latency: 8,
            words_per_cycle: 2,
        };
        assert_eq!(d.load_cycles(0), 0);
        assert_eq!(d.load_cycles(1), 9);
        assert_eq!(d.load_cycles(64), 8 + 32);
        assert_eq!(d.store_cycles(64), d.load_cycles(64));
    }

    #[test]
    fn enumeration_hooks_cover_powers_of_two() {
        assert_eq!(PlatformDesc::enumerate_chip_counts(4), vec![1, 2, 4]);
        assert_eq!(PlatformDesc::enumerate_chip_counts(1), vec![1]);
        assert_eq!(PlatformDesc::enumerate_chip_counts(7), vec![1, 2, 4]);
        assert!(!PlatformDesc::enumerate_hop_latencies().is_empty());
    }

    #[test]
    fn builders_clamp_degenerate_values() {
        let p = PlatformDesc::new(0).with_microbatches(0);
        assert_eq!(p.chips, 1);
        assert_eq!(p.microbatches, 1);
        let p = PlatformDesc::new(4).with_hop_latency(0).with_microbatches(8);
        assert_eq!((p.chips, p.fabric.hop_latency, p.microbatches), (4, 0, 8));
    }
}
