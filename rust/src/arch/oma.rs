//! The One MAC Accelerator (OMA) — §4.1, Figs 2–3, Listing 1.
//!
//! Scalar-operations-level model: one fetch front-end, a decode pipeline
//! stage, and a single execute stage containing one ALU-style
//! `FunctionalUnit` (`mov addi … mac`) and one `MemoryAccessUnit`
//! (`load store`) behind a data cache backed by the data memory.  The OMA
//! processes one operation at a time in its execute stage — exactly the
//! structural hazard the paper uses to introduce the timing semantics.

use crate::acadl_core::data::Data;
use crate::acadl_core::edge::EdgeKind;
use crate::acadl_core::graph::{Ag, AgError, ObjId};
use crate::acadl_core::latency::Latency;
use crate::acadl_core::object::build;
use crate::arch::parts::{self, FetchFrontend};
use crate::mem::cache::ReplacementPolicy;

/// Data-memory backing for the OMA.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DataMem {
    /// On-chip SRAM with a flat latency.
    Sram { latency: u64 },
    /// Banked DRAM with default DDR4-ish timing.
    Dram,
}

/// Cache configuration (None = no cache; MAU talks to memory directly).
#[derive(Debug, Clone, Copy)]
pub struct CacheCfg {
    pub sets: usize,
    pub ways: usize,
    pub line: u64,
    pub policy: ReplacementPolicy,
    pub hit_latency: u64,
    pub miss_latency: u64,
}

impl Default for CacheCfg {
    fn default() -> Self {
        CacheCfg {
            sets: 64,
            ways: 4,
            line: 64,
            policy: ReplacementPolicy::Lru,
            hit_latency: 1,
            miss_latency: 8,
        }
    }
}

/// Parameters of the OMA model (Listing 1's constructor arguments).
#[derive(Debug, Clone)]
pub struct OmaConfig {
    /// General-purpose registers `r0..r{gprs-1}` (+ the zero reg `z0`).
    pub gprs: usize,
    /// MAC instruction latency in cycles.
    pub mac_latency: u64,
    /// ALU (non-MAC) latency.
    pub alu_latency: u64,
    /// Issue buffer depth of the fetch stage.
    pub issue_buffer: usize,
    /// Instructions fetched per transaction (imem port width).
    pub fetch_width: usize,
    pub cache: Option<CacheCfg>,
    pub dmem: DataMem,
    /// Instruction memory byte range.
    pub imem_range: (u64, u64),
    /// Data memory byte range.
    pub dmem_range: (u64, u64),
}

impl Default for OmaConfig {
    fn default() -> Self {
        OmaConfig {
            gprs: 16,
            mac_latency: 1,
            alu_latency: 1,
            issue_buffer: 4,
            fetch_width: 4,
            cache: Some(CacheCfg::default()),
            dmem: DataMem::Sram { latency: 2 },
            imem_range: (0x0, 0x10000),
            dmem_range: (0x10000, 0x90000),
        }
    }
}

/// The built OMA: its AG plus the handles and layout codegen needs.
#[derive(Debug, Clone)]
pub struct OmaMachine {
    pub ag: Ag,
    pub fe: OmaHandles,
    pub cfg: OmaConfig,
}

#[derive(Debug, Clone)]
pub struct OmaHandles {
    pub ifs: ObjId,
    pub ds: ObjId,
    pub ex: ObjId,
    pub fu: ObjId,
    pub mau: ObjId,
    pub rf: ObjId,
    pub dcache: Option<ObjId>,
    pub dmem: ObjId,
}

impl OmaConfig {
    /// DSE enumeration hook: the cache on/off variants of the scalar core
    /// (the OMA's only sweep-relevant structural knob).
    pub fn enumerate_cache_variants() -> Vec<bool> {
        vec![true, false]
    }

    /// Instantiate the AG of Listing 1.
    pub fn build(&self) -> Result<OmaMachine, AgError> {
        let mut ag = Ag::new();
        let FetchFrontend { ifs, .. } = parts::fetch_frontend(
            &mut ag,
            "",
            self.imem_range.0,
            self.imem_range.1,
            self.issue_buffer,
            self.fetch_width,
        )?;

        // Decode stage and execute stage (Fig. 3: ds0, ex0).
        let ds = ag.add(build::pipeline_stage("ds0", 1))?;
        let ex = ag.add(build::execute_stage("ex0", 1))?;

        // ALU-style functional unit. MAC latency may differ from the rest,
        // expressed with a latency function over the mnemonic class.
        let fu = ag.add(build::functional_unit(
            "fu0",
            &[
                "nop", "halt", "mov", "movi", "add", "addi", "sub", "subi", "mul", "muli",
                "mac", "div", "max", "exp", "rsqrt", "gelu", "beqi", "bnei", "jumpi",
            ],
            if self.mac_latency == self.alu_latency {
                Latency::Const(self.alu_latency)
            } else {
                // `is_mac` is bound by the engine when evaluating.
                Latency::parse(&format!(
                    "{} + is_mac * {}",
                    self.alu_latency,
                    self.mac_latency.saturating_sub(self.alu_latency)
                ))
                .expect("static expression")
            },
        ))?;
        let mau = ag.add(build::memory_access_unit("mau0", &["load", "store"], 1))?;

        // Register file: r0..r{n-1} + z0 (hardwired zero, Listing 5).
        let mut regs: Vec<(String, Data)> = (0..self.gprs)
            .map(|i| (format!("r{i}"), Data::int(32, 0)))
            .collect();
        regs.push(("z0".into(), Data::int(32, 0)));
        let rf = ag.add(build::register_file("rf0", 32, regs))?;

        // Data memory + optional cache.
        let dmem = match self.dmem {
            DataMem::Sram { latency } => ag.add(parts::sram(
                "dmem0",
                self.dmem_range.0,
                self.dmem_range.1,
                latency,
                1,
            ))?,
            DataMem::Dram => {
                ag.add(parts::dram_default("dmem0", self.dmem_range.0, self.dmem_range.1))?
            }
        };
        let dcache = match &self.cache {
            Some(c) => Some(ag.add(parts::cache(
                "dcache0",
                c.sets,
                c.ways,
                c.line,
                c.policy,
                c.hit_latency,
                c.miss_latency,
            ))?),
            None => None,
        };

        // Edges (Listing 1, lines 35–51).
        ag.connect(ifs, ds, EdgeKind::Forward)?;
        ag.connect(ds, ex, EdgeKind::Forward)?;
        ag.connect(ex, fu, EdgeKind::Contains)?;
        ag.connect(fu, rf, EdgeKind::WriteData)?;
        ag.connect(rf, fu, EdgeKind::ReadData)?;
        ag.connect(ex, mau, EdgeKind::Contains)?;
        ag.connect(mau, rf, EdgeKind::WriteData)?;
        ag.connect(rf, mau, EdgeKind::ReadData)?;
        // Branches write the pc (held in the fetch front-end's pcrf0).
        let pcrf = ag.id("pcrf0").expect("front-end created pcrf0");
        ag.connect(fu, pcrf, EdgeKind::WriteData)?;
        ag.connect(pcrf, fu, EdgeKind::ReadData)?;
        match dcache {
            Some(c) => {
                ag.connect(mau, c, EdgeKind::WriteData)?;
                ag.connect(c, mau, EdgeKind::ReadData)?;
                ag.connect(c, dmem, EdgeKind::WriteData)?;
                ag.connect(dmem, c, EdgeKind::ReadData)?;
            }
            None => {
                ag.connect(mau, dmem, EdgeKind::WriteData)?;
                ag.connect(dmem, mau, EdgeKind::ReadData)?;
            }
        }

        ag.validate()?;
        Ok(OmaMachine {
            ag,
            fe: OmaHandles {
                ifs,
                ds,
                ex,
                fu,
                mau,
                rf,
                dcache,
                dmem,
            },
            cfg: self.clone(),
        })
    }
}

impl OmaMachine {
    /// Base address of the data region used by the GeMM mapping: A matrix
    /// at `dmem_base`, B after it, C after B (see `mapping::gemm`).
    pub fn dmem_base(&self) -> u64 {
        self.cfg.dmem_range.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_validates() {
        let m = OmaConfig::default().build().unwrap();
        let s = m.ag.summary();
        assert!(s.contains("InstructionFetchStage=1"), "{s}");
        assert!(s.contains("SetAssociativeCache=1"), "{s}");
        // 17 registers in rf0 (r0..r15 + z0) + pc.
        assert_eq!(m.ag.reg_count(), 18);
    }

    #[test]
    fn no_cache_variant() {
        let m = OmaConfig {
            cache: None,
            ..OmaConfig::default()
        }
        .build()
        .unwrap();
        assert!(m.fe.dcache.is_none());
        assert_eq!(m.ag.storages_of_mau(m.fe.mau), vec![m.fe.dmem]);
    }

    #[test]
    fn dram_variant() {
        let m = OmaConfig {
            dmem: DataMem::Dram,
            ..OmaConfig::default()
        }
        .build()
        .unwrap();
        assert!(m.ag.summary().contains("DRAM=1"));
    }

    #[test]
    fn mau_reaches_dmem_through_cache() {
        let m = OmaConfig::default().build().unwrap();
        let c = m.fe.dcache.unwrap();
        assert_eq!(m.ag.backing_of(c), Some(m.fe.dmem));
        assert!(m.ag.storage_accepts(c, m.dmem_base() + 0x100));
    }
}
