//! The parameterizable systolic array — §4.2, Figs 4–5, Listings 2–3.
//!
//! A rows×cols grid of processing elements modeled with the PE template of
//! Listing 2 (ExecuteStage + FunctionalUnit + RegisterFile, plus dangling
//! edges), connected exactly as Listing 3: each PE's FU writes its `a`
//! operand to the right neighbor's register file and its `b` operand to
//! the neighbor below (output-stationary dataflow).  Load units feed the
//! first row (B columns) and first column (A rows); store units drain the
//! accumulators.
//!
//! Registers per PE (r, c): `pe{r}_{c}_a`, `pe{r}_{c}_b`, `pe{r}_{c}_acc`.
//! The PE FU processes `macf` (mac + forward, [`Opcode::MacFwd`]) and
//! `movi` (accumulator reset).
//!
//! *Deviation from Fig. 4, documented:* store units are connected to every
//! PE's register file rather than only the last row/column, so the
//! output-stationary accumulators can be drained without a shift-out
//! instruction sequence; the store-unit *count* still scales with the
//! array edge as in the figure.

use crate::acadl_core::data::Data;
use crate::acadl_core::edge::EdgeKind;
use crate::acadl_core::graph::{Ag, AgError, ObjId};
use crate::acadl_core::latency::Latency;
use crate::acadl_core::object::build;
use crate::acadl_core::template::{connect_dangling, DanglingEdge};
use crate::arch::parts;

/// Parameters of the systolic array model (Listing 3's
/// `generate_architecture(rows, columns)`).
#[derive(Debug, Clone)]
pub struct SystolicConfig {
    pub rows: usize,
    pub cols: usize,
    /// MAC-and-forward latency per PE step.
    pub pe_latency: u64,
    /// Number of load units on each array edge (defaults to edge length).
    pub load_units: Option<usize>,
    pub store_units: Option<usize>,
    /// Issue buffer of the fetch unit (needs to cover the instruction
    /// window of a wavefront; defaults to 4·rows·cols).
    pub issue_buffer: Option<usize>,
    pub fetch_width: usize,
    pub imem_range: (u64, u64),
    pub dmem_range: (u64, u64),
    /// Data memory latency (SRAM).
    pub dmem_latency: u64,
}

impl Default for SystolicConfig {
    fn default() -> Self {
        SystolicConfig {
            rows: 4,
            cols: 4,
            pe_latency: 1,
            load_units: None,
            store_units: None,
            issue_buffer: None,
            fetch_width: 8,
            imem_range: (0x0, 0x100000),
            dmem_range: (0x100000, 0x900000),
            dmem_latency: 2,
        }
    }
}

impl SystolicConfig {
    /// DSE enumeration hook: every power-of-two `(rows, cols)` grid with
    /// both edges in `[2, max_edge]` — the candidate array shapes a sweep
    /// considers (square and rectangular).
    pub fn enumerate_grids(max_edge: usize) -> Vec<(usize, usize)> {
        let edges: Vec<usize> = std::iter::successors(Some(2usize), |e| Some(e * 2))
            .take_while(|&e| e <= max_edge)
            .collect();
        edges
            .iter()
            .flat_map(|&r| edges.iter().map(move |&c| (r, c)))
            .collect()
    }

    pub fn new(rows: usize, cols: usize) -> Self {
        SystolicConfig {
            rows,
            cols,
            ..Default::default()
        }
    }
}

/// The PE template (Listing 2): objects + internal edges + dangling edges.
struct PeTemplate {
    rf: ObjId,
    /// `fu_outgoing_write` of Listing 2.
    fu_outgoing_write: DanglingEdge,
    /// `rf_ingoing_write` of Listing 2.
    rf_ingoing_write: DanglingEdge,
}

impl PeTemplate {
    fn new(ag: &mut Ag, row: usize, col: usize, latency: u64) -> Result<Self, AgError> {
        let ex = ag.add(build::execute_stage(&format!("ex[{row}][{col}]"), 1))?;
        let fu = ag.add(build::functional_unit(
            &format!("fu[{row}][{col}]"),
            &["macf", "movi", "mov"],
            Latency::Const(latency),
        ))?;
        let rf = ag.add(build::register_file(
            &format!("rf[{row}][{col}]"),
            32,
            vec![
                (format!("pe{row}_{col}_a"), Data::f32(0.0)),
                (format!("pe{row}_{col}_b"), Data::f32(0.0)),
                (format!("pe{row}_{col}_acc"), Data::f32(0.0)),
            ],
        ))?;
        ag.connect(ex, fu, EdgeKind::Contains)?;
        ag.connect(rf, fu, EdgeKind::ReadData)?;
        ag.connect(fu, rf, EdgeKind::WriteData)?;
        Ok(PeTemplate {
            rf,
            fu_outgoing_write: DanglingEdge::from_source(EdgeKind::WriteData, fu),
            rf_ingoing_write: DanglingEdge::to_target(EdgeKind::WriteData, rf),
        })
    }
}

/// The built systolic array.
#[derive(Debug, Clone)]
pub struct SystolicMachine {
    pub ag: Ag,
    pub cfg: SystolicConfig,
    pub dmem: ObjId,
}

impl SystolicConfig {
    pub fn build(&self) -> Result<SystolicMachine, AgError> {
        assert!(self.rows >= 1 && self.cols >= 1);
        let mut ag = Ag::new();
        let issue = self
            .issue_buffer
            .unwrap_or((4 * self.rows * self.cols).max(16));
        let fe = parts::fetch_frontend(
            &mut ag,
            "",
            self.imem_range.0,
            self.imem_range.1,
            issue,
            self.fetch_width,
        )?;

        // PEs (Listing 3's nested instantiation loop).
        let mut pes: Vec<Vec<PeTemplate>> = Vec::with_capacity(self.rows);
        for r in 0..self.rows {
            let mut row = Vec::with_capacity(self.cols);
            for c in 0..self.cols {
                let pe = PeTemplate::new(&mut ag, r, c, self.pe_latency)?;
                // Fetch unit issues PE instructions directly.
                let ex = ag.id(&format!("ex[{r}][{c}]")).unwrap();
                ag.connect(fe.ifs, ex, EdgeKind::Forward)?;
                row.push(pe);
            }
            pes.push(row);
        }
        // Neighbor connections via dangling edges (Listing 3):
        // vertical (b flows down) and horizontal (a flows right).
        for r in 0..self.rows {
            for c in 0..self.cols {
                if r > 0 {
                    connect_dangling(
                        &mut ag,
                        pes[r - 1][c].fu_outgoing_write,
                        pes[r][c].rf_ingoing_write,
                    )
                    .map_err(|e| match e {
                        crate::acadl_core::template::TemplateError::Ag(a) => a,
                        other => AgError::Invalid(other.to_string()),
                    })?;
                }
                if c > 0 {
                    connect_dangling(
                        &mut ag,
                        pes[r][c - 1].fu_outgoing_write,
                        pes[r][c].rf_ingoing_write,
                    )
                    .map_err(|e| match e {
                        crate::acadl_core::template::TemplateError::Ag(a) => a,
                        other => AgError::Invalid(other.to_string()),
                    })?;
                }
            }
        }

        // Data memory: enough ports and request slots for every load and
        // store unit to stream concurrently (the array-edge bandwidth of
        // Fig. 4).
        let n_load = self.load_units.unwrap_or(self.rows + self.cols).max(1);
        let n_store = self
            .store_units
            .unwrap_or((self.rows + self.cols) / 2)
            .max(1);
        // +1 port/slot for the scalar epilogue's MAU; the extra port is
        // idle during GeMM programs (≤ n_load + n_store concurrent
        // requesters), so existing cycle counts are unchanged.
        let dmem = ag.add(parts::sram_ports(
            "dmem0",
            self.dmem_range.0,
            self.dmem_range.1,
            self.dmem_latency,
            4,
            n_load + n_store + 1,
            n_load + n_store + 1,
        ))?;

        // Load units: first row + first column (B from the top, A from the
        // left).  Each unit = ExecuteStage + MAU (its own stage so loads
        // proceed in parallel).
        for u in 0..n_load {
            let ex = ag.add(build::execute_stage(&format!("lu_ex[{u}]"), 1))?;
            let mau = ag.add(build::memory_access_unit(
                &format!("lu[{u}]"),
                &["load"],
                1,
            ))?;
            ag.connect(ex, mau, EdgeKind::Contains)?;
            ag.connect(fe.ifs, ex, EdgeKind::Forward)?;
            ag.connect(dmem, mau, EdgeKind::ReadData)?;
            // Load units write edge-PE registers (first row and column) —
            // and, for generality of mappings, any PE rf (multicast NoC).
            for row in &pes {
                for pe in row {
                    ag.connect(mau, pe.rf, EdgeKind::WriteData)?;
                }
            }
        }

        // Store units: drain accumulators to memory.
        for u in 0..n_store {
            let ex = ag.add(build::execute_stage(&format!("su_ex[{u}]"), 1))?;
            let mau = ag.add(build::memory_access_unit(
                &format!("su[{u}]"),
                &["store"],
                1,
            ))?;
            ag.connect(ex, mau, EdgeKind::Contains)?;
            ag.connect(fe.ifs, ex, EdgeKind::Forward)?;
            ag.connect(mau, dmem, EdgeKind::WriteData)?;
            for row in &pes {
                for pe in row {
                    ag.connect(pe.rf, mau, EdgeKind::ReadData)?;
                }
            }
        }

        // Scalar epilogue unit (softmax / layer-norm tail for the
        // transformer mappings): private registers, so PE instruction
        // routing — and therefore every existing cycle count — is
        // untouched.
        parts::scalar_epilogue(&mut ag, fe.ifs, dmem)?;

        ag.validate()?;
        Ok(SystolicMachine {
            ag,
            cfg: self.clone(),
            dmem,
        })
    }
}

impl SystolicMachine {
    pub fn dmem_base(&self) -> u64 {
        self.cfg.dmem_range.0
    }

    /// PE register names for codegen.
    pub fn pe_reg(&self, r: usize, c: usize, which: &str) -> String {
        format!("pe{r}_{c}_{which}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_square_and_rect() {
        for (r, c) in [(1, 1), (2, 3), (4, 4)] {
            let m = SystolicConfig::new(r, c).build().unwrap();
            let s = m.ag.summary();
            // One RF per PE + pcrf0 + the scalar epilogue's srf0.
            assert!(
                s.contains(&format!("RegisterFile={}", r * c + 2)),
                "{r}x{c}: {s}"
            );
            // 3 regs per PE + pc + 8 epilogue scalars.
            assert_eq!(m.ag.reg_count(), 3 * r * c + 1 + 8);
        }
    }

    #[test]
    fn scalar_epilogue_is_private() {
        let m = SystolicConfig::new(2, 2).build().unwrap();
        let sfu = m.ag.id("sfu0").expect("epilogue FU exists");
        let smau = m.ag.id("smau0").expect("epilogue MAU exists");
        let srf = m.ag.id("srf0").unwrap();
        // The epilogue only reaches its own registers — PE routing is
        // untouched.
        assert_eq!(m.ag.writable_rfs(sfu), vec![srf]);
        assert_eq!(m.ag.storages_of_mau(smau), vec![m.dmem]);
        assert!(m.ag.reg_id("s0").is_some() && m.ag.reg_id("s7").is_some());
    }

    #[test]
    fn neighbor_edges_exist() {
        let m = SystolicConfig::new(2, 2).build().unwrap();
        let fu00 = m.ag.id("fu[0][0]").unwrap();
        let rf01 = m.ag.id("rf[0][1]").unwrap();
        let rf10 = m.ag.id("rf[1][0]").unwrap();
        let writable = m.ag.writable_rfs(fu00);
        assert!(writable.contains(&rf01), "a forwards right");
        assert!(writable.contains(&rf10), "b forwards down");
    }

    #[test]
    fn scales_to_16x16() {
        let m = SystolicConfig::new(16, 16).build().unwrap();
        assert_eq!(m.ag.reg_count(), 3 * 256 + 1 + 8);
        m.ag.validate().unwrap();
    }
}
