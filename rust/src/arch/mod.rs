//! The model zoo: AI hardware accelerators expressed as ACADL architecture
//! graphs, mirroring the paper's Python front-end listings.
//!
//! * [`oma`] — the One MAC Accelerator (§4.1, Figs 2–3, Listing 1):
//!   scalar-operations level, single FU + MAU behind one execute stage.
//! * [`systolic`] — the parameterizable rows×cols systolic array
//!   (§4.2, Figs 4–5, Listings 2–3): scalar level, PE templates with
//!   dangling edges, load/store units on the array edges.
//! * [`gamma`] — Γ̈, the General Operationally Extendable Neural Network
//!   Accelerator (§4.3, Figs 6–7, Listing 4): fused-tensor level,
//!   load/store + compute + scratchpad template pairs, out-of-order
//!   parallel issue.
//! * [`eyeriss`] — an Eyeriss-v1-derived row-stationary model (§6, [26]).
//! * [`plasticine`] — a Plasticine-derived pattern compute/memory chain
//!   (§6, [27]).
//! * [`parts`] — shared constructors for storages and fetch front-ends.
//! * [`platform`] — multi-accelerator platform descriptions: N chips
//!   behind a shared fabric + DRAM, the configuration `sim::platform`
//!   simulates in parallel.
//!
//! Every builder returns a machine struct bundling the [`Ag`] with the
//! memory layout the mapping layer (code generators) needs.

pub mod eyeriss;
pub mod gamma;
pub mod oma;
pub mod parts;
pub mod plasticine;
pub mod platform;
pub mod systolic;

pub use gamma::GammaConfig;
pub use oma::OmaConfig;
pub use platform::{FabricConfig, PlatformDesc, SharedDramConfig};
pub use systolic::SystolicConfig;
