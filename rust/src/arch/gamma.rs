//! Γ̈ [gœna] — the General Operationally Extendable Neural Network
//! Accelerator, §4.3, Figs 6–7, Listing 4.
//!
//! Fused-tensor-operations level: `units` template instances, each a
//! load/store unit + compute unit + scratchpad complex (the dashed boxes of
//! Fig. 6), sharing one DRAM data memory and one fetch front-end whose
//! large issue buffer lets instructions for different units issue in
//! parallel and execute out-of-order (§4.3's closing claim — measured by
//! experiment E4).
//!
//! Per unit `i`:
//! * `lsu[i]` — ExecuteStage + MemoryAccessUnit (`load`, `store`): moves
//!   rows between DRAM/scratchpads and the compute unit's vector registers.
//! * `cu[i]` — ExecuteStage containing `matMulFu[i]` (`gemm`) and
//!   `matAddFu[i]` (`vadd vmul vrelu vmaxp`) over the vector register file
//!   `vrf[i]` (registers `v[i].0 … v[i].{vregs-1}`, 128-bit, 8 f32 lanes —
//!   the paper's 8×int16 design point in our f32 payload model).
//! * `spad[i]` — SRAM scratchpad; adjacent units can reach their
//!   neighbors' scratchpads (partial-result sharing).

use crate::acadl_core::data::Data;
use crate::acadl_core::edge::EdgeKind;
use crate::acadl_core::graph::{Ag, AgError, ObjId};
use crate::acadl_core::latency::Latency;
use crate::acadl_core::object::build;
use crate::arch::parts;
use crate::isa::GAMMA_TILE;

/// Parameters of the Γ̈ model.
#[derive(Debug, Clone)]
pub struct GammaConfig {
    /// Number of load-store/compute/scratchpad template instances.
    pub units: usize,
    /// Vector registers per compute unit.
    pub vregs: usize,
    /// gemm latency in cycles (one 8×8×8 fused tensor op).
    pub gemm_latency: u64,
    /// Element-wise tensor op latency.
    pub vec_latency: u64,
    /// Scratchpad bytes per unit.
    pub spad_bytes: u64,
    pub spad_latency: u64,
    /// Issue buffer of the fetch stage.
    pub issue_buffer: usize,
    pub fetch_width: usize,
    pub imem_range: (u64, u64),
    /// DRAM data-memory range.
    pub dram_range: (u64, u64),
    /// Base address of the first scratchpad (they are laid out
    /// contiguously: spad i at `spad_base + i * spad_bytes`).
    pub spad_base: u64,
}

impl Default for GammaConfig {
    fn default() -> Self {
        GammaConfig {
            units: 2,
            vregs: 32,
            gemm_latency: 8,
            vec_latency: 1,
            spad_bytes: 0x4000,
            spad_latency: 1,
            issue_buffer: 32,
            fetch_width: 4,
            imem_range: (0x0, 0x100000),
            dram_range: (0x1000_0000, 0x2000_0000),
            spad_base: 0x10_0000,
        }
    }
}

impl GammaConfig {
    /// DSE enumeration hook: power-of-two unit counts in `[1, max_units]`.
    pub fn enumerate_units(max_units: usize) -> Vec<usize> {
        std::iter::successors(Some(1usize), |u| Some(u * 2))
            .take_while(|&u| u <= max_units)
            .collect()
    }

    pub fn new(units: usize) -> Self {
        GammaConfig {
            units,
            ..Default::default()
        }
    }
}

/// Handles of one Γ̈ template instance.
#[derive(Debug, Clone)]
pub struct GammaUnit {
    pub lsu: ObjId,
    pub cu: ObjId,
    pub mat_mul_fu: ObjId,
    pub mat_add_fu: ObjId,
    pub vrf: ObjId,
    pub spad: ObjId,
    /// Scratchpad byte range.
    pub spad_range: (u64, u64),
}

/// The built Γ̈ machine.
#[derive(Debug, Clone)]
pub struct GammaMachine {
    pub ag: Ag,
    pub cfg: GammaConfig,
    pub units: Vec<GammaUnit>,
    pub dram: ObjId,
}

impl GammaConfig {
    pub fn build(&self) -> Result<GammaMachine, AgError> {
        assert!(self.units >= 1);
        assert!(self.vregs >= 3 * GAMMA_TILE, "need at least A+B+C row groups");
        let mut ag = Ag::new();
        let fe = parts::fetch_frontend(
            &mut ag,
            "",
            self.imem_range.0,
            self.imem_range.1,
            self.issue_buffer,
            self.fetch_width,
        )?;

        // One controller port per LSU plus one for the scalar epilogue's
        // MAU, so scaling the unit count never violates the port budget —
        // contention is still modeled by the request slots.  (The ≤-LSU
        // concurrent-requester count is unchanged during tensor programs,
        // so existing cycle counts are too.)
        let dram = ag.add(parts::dram_ports(
            "dram0",
            self.dram_range.0,
            self.dram_range.1,
            self.units + 1,
        ))?;

        let mut units = Vec::with_capacity(self.units);
        for i in 0..self.units {
            let spad_lo = self.spad_base + i as u64 * self.spad_bytes;
            let spad_hi = spad_lo + self.spad_bytes;
            let spad = ag.add(parts::sram_ports(
                &format!("spad[{i}]"),
                spad_lo,
                spad_hi,
                self.spad_latency,
                GAMMA_TILE, // one row per transaction
                4,
                2,
            ))?;

            // Compute unit: one execute stage per FU so gemm and vector ops
            // from *different* dependency chains can overlap across units,
            // while within a stage the paper's wait-on-FU semantics hold.
            let cu = ag.add(build::execute_stage(&format!("cu[{i}]"), 1))?;
            let mat_mul = ag.add(build::functional_unit(
                &format!("matMulFu[{i}]"),
                &["gemm"],
                Latency::Const(self.gemm_latency),
            ))?;
            let mat_add = ag.add(build::functional_unit(
                &format!("matAddFu[{i}]"),
                &["vadd", "vmul", "vrelu", "vmaxp"],
                Latency::Const(self.vec_latency),
            ))?;
            ag.connect(cu, mat_mul, EdgeKind::Contains)?;
            ag.connect(cu, mat_add, EdgeKind::Contains)?;

            let vrf = ag.add(build::register_file(
                &format!("vrf[{i}]"),
                128,
                (0..self.vregs)
                    .map(|r| (format!("v[{i}].{r}"), Data::vec(128, GAMMA_TILE)))
                    .collect(),
            ))?;
            ag.connect(vrf, mat_mul, EdgeKind::ReadData)?;
            ag.connect(mat_mul, vrf, EdgeKind::WriteData)?;
            ag.connect(vrf, mat_add, EdgeKind::ReadData)?;
            ag.connect(mat_add, vrf, EdgeKind::WriteData)?;

            // Load/store unit.
            let lsu_ex = ag.add(build::execute_stage(&format!("lsu_ex[{i}]"), 1))?;
            let lsu = ag.add(build::memory_access_unit(
                &format!("lsu[{i}]"),
                &["load", "store"],
                1,
            ))?;
            ag.connect(lsu_ex, lsu, EdgeKind::Contains)?;
            ag.connect(fe.ifs, lsu_ex, EdgeKind::Forward)?;
            ag.connect(fe.ifs, cu, EdgeKind::Forward)?;
            // LSU moves data between storages and the vector registers.
            ag.connect(lsu, vrf, EdgeKind::WriteData)?;
            ag.connect(vrf, lsu, EdgeKind::ReadData)?;
            ag.connect(lsu, spad, EdgeKind::WriteData)?;
            ag.connect(spad, lsu, EdgeKind::ReadData)?;
            ag.connect(lsu, dram, EdgeKind::WriteData)?;
            ag.connect(dram, lsu, EdgeKind::ReadData)?;

            units.push(GammaUnit {
                lsu,
                cu,
                mat_mul_fu: mat_mul,
                mat_add_fu: mat_add,
                vrf,
                spad,
                spad_range: (spad_lo, spad_hi),
            });
        }

        // Adjacent scratchpad sharing: lsu[i] reaches spad[i±1].
        for i in 0..self.units {
            if i > 0 {
                let (lsu, spad) = (units[i].lsu, units[i - 1].spad);
                ag.connect(lsu, spad, EdgeKind::WriteData)?;
                ag.connect(spad, lsu, EdgeKind::ReadData)?;
            }
            if i + 1 < self.units {
                let (lsu, spad) = (units[i].lsu, units[i + 1].spad);
                ag.connect(lsu, spad, EdgeKind::WriteData)?;
                ag.connect(spad, lsu, EdgeKind::ReadData)?;
            }
        }

        // Scalar epilogue unit over the shared DRAM (softmax / layer-norm
        // tail for the transformer mappings): private `s*` registers, so
        // LSU / tensor-unit routing — and existing cycle counts — are
        // untouched.
        parts::scalar_epilogue(&mut ag, fe.ifs, dram)?;

        ag.validate()?;
        Ok(GammaMachine {
            ag,
            cfg: self.clone(),
            units,
            dram,
        })
    }
}

impl GammaMachine {
    /// Vector register name `v[unit].{idx}`.
    pub fn vreg(&self, unit: usize, idx: usize) -> String {
        format!("v[{unit}].{idx}")
    }

    pub fn dram_base(&self) -> u64 {
        self.cfg.dram_range.0
    }

    /// Row-group base addresses inside unit `i`'s scratchpad for Listing 4
    /// style programs: (A, B, C) each `GAMMA_TILE` rows of `GAMMA_TILE`
    /// f32s.
    pub fn spad_tile_bases(&self, unit: usize) -> (u64, u64, u64) {
        let lo = self.units[unit].spad_range.0;
        let tile_bytes = (GAMMA_TILE * GAMMA_TILE * 4) as u64;
        (lo, lo + tile_bytes, lo + 2 * tile_bytes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_and_validates() {
        let m = GammaConfig::default().build().unwrap();
        let s = m.ag.summary();
        assert!(s.contains("DRAM=1"), "{s}");
        assert!(s.contains("SRAM=3"), "2 spads + imem: {s}"); // imem is SRAM
        assert_eq!(m.units.len(), 2);
        // 2 units × 32 vregs + pc + 8 epilogue scalars.
        assert_eq!(m.ag.reg_count(), 73);
    }

    #[test]
    fn scalar_epilogue_reaches_dram_only() {
        let m = GammaConfig::new(1).build().unwrap();
        let smau = m.ag.id("smau0").expect("epilogue MAU exists");
        assert_eq!(m.ag.storages_of_mau(smau), vec![m.dram]);
        let sfu = m.ag.id("sfu0").unwrap();
        let ops = m.ag.kind(sfu).to_process().unwrap();
        assert!(ops.contains("exp") && ops.contains("rsqrt") && !ops.contains("mac"));
    }

    #[test]
    fn unit_fus_have_correct_caps() {
        let m = GammaConfig::new(1).build().unwrap();
        let mm = m.ag.kind(m.units[0].mat_mul_fu).to_process().unwrap();
        assert!(mm.contains("gemm") && !mm.contains("vadd"));
        let ma = m.ag.kind(m.units[0].mat_add_fu).to_process().unwrap();
        assert!(ma.contains("vrelu") && !ma.contains("gemm"));
    }

    #[test]
    fn adjacent_scratchpads_shared() {
        let m = GammaConfig::new(3).build().unwrap();
        let s0 = m.ag.storages_of_mau(m.units[1].lsu);
        assert!(s0.contains(&m.units[0].spad));
        assert!(s0.contains(&m.units[2].spad));
        assert!(s0.contains(&m.dram));
        // Unit 0 does not reach spad[2].
        let s1 = m.ag.storages_of_mau(m.units[0].lsu);
        assert!(!s1.contains(&m.units[2].spad));
    }

    #[test]
    fn spad_tile_layout() {
        let m = GammaConfig::default().build().unwrap();
        let (a, b, c) = m.spad_tile_bases(0);
        assert_eq!(b - a, 256);
        assert_eq!(c - b, 256);
        assert!(m.ag.storage_accepts(m.units[0].spad, c));
    }
}
