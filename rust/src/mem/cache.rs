//! Set-associative cache simulation (the paper's `SetAssociativeCache` +
//! `CacheInterface`, Fig. 13's hit/miss decision).
//!
//! Behavior per access:
//! * **read hit / write hit** — update replacement metadata; write hits mark
//!   the line dirty under write-back.
//! * **read miss** — allocate (fill) the line, possibly evicting; the
//!   evicted line reports whether a dirty write-back to the backing store is
//!   required.
//! * **write miss** — allocate only under `write_allocate`; otherwise the
//!   write goes straight through to the backing store.

/// Replacement policy for a cache set.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ReplacementPolicy {
    /// Evict the least-recently-used way.
    Lru,
    /// Evict in fill order.
    Fifo,
    /// Tree-based pseudo-LRU (power-of-two ways; falls back to LRU else).
    Plru,
    /// Deterministic xorshift-seeded random way.
    Random,
}

/// Outcome of one cache access.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Access {
    pub hit: bool,
    /// A dirty victim line's base address that must be written back.
    pub writeback: Option<u64>,
    /// Whether the access touches the backing store (miss fill or
    /// write-through/no-allocate write).
    pub backing_access: bool,
}

#[derive(Debug, Clone, Default)]
struct Line {
    tag: u64,
    valid: bool,
    dirty: bool,
    /// LRU timestamp or FIFO fill order.
    stamp: u64,
}

/// The cache state machine. Addresses are byte addresses; lines are
/// `line_size` bytes; set index = (addr / line_size) % sets.
#[derive(Debug, Clone)]
pub struct CacheState {
    sets: usize,
    ways: usize,
    line_size: u64,
    policy: ReplacementPolicy,
    write_allocate: bool,
    write_back: bool,
    lines: Vec<Line>,
    /// PLRU tree bits per set (ways-1 bits packed into a u64).
    plru: Vec<u64>,
    clock: u64,
    rng: u64,
    pub hits: u64,
    pub misses: u64,
    pub writebacks: u64,
}

impl CacheState {
    pub fn new(
        sets: usize,
        ways: usize,
        line_size: u64,
        policy: ReplacementPolicy,
        write_allocate: bool,
        write_back: bool,
    ) -> Self {
        assert!(sets > 0 && ways > 0 && line_size > 0);
        assert!(line_size.is_power_of_two(), "line size must be a power of two");
        CacheState {
            sets,
            ways,
            line_size,
            policy,
            write_allocate,
            write_back,
            lines: vec![Line::default(); sets * ways],
            plru: vec![0; sets],
            clock: 0,
            rng: 0x9E37_79B9_7F4A_7C15,
            hits: 0,
            misses: 0,
            writebacks: 0,
        }
    }

    #[inline]
    fn set_of(&self, addr: u64) -> usize {
        ((addr / self.line_size) % self.sets as u64) as usize
    }

    #[inline]
    fn tag_of(&self, addr: u64) -> u64 {
        addr / self.line_size / self.sets as u64
    }

    #[inline]
    fn line_base(&self, set: usize, tag: u64) -> u64 {
        (tag * self.sets as u64 + set as u64) * self.line_size
    }

    fn xorshift(&mut self) -> u64 {
        let mut x = self.rng;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.rng = x;
        x
    }

    fn touch_plru(&mut self, set: usize, way: usize) {
        // Walk the tree from root to the leaf `way`, pointing bits away.
        if !self.ways.is_power_of_two() {
            return;
        }
        let mut node = 0usize; // tree node index within the set's bits
        let mut lo = 0usize;
        let mut hi = self.ways;
        let mut bits = self.plru[set];
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            let right = way >= mid;
            // Point the bit at the *other* half (the colder one).
            if right {
                bits &= !(1 << node);
                lo = mid;
            } else {
                bits |= 1 << node;
                hi = mid;
            }
            node = 2 * node + if right { 2 } else { 1 };
        }
        self.plru[set] = bits;
    }

    fn plru_victim(&self, set: usize) -> usize {
        let mut node = 0usize;
        let mut lo = 0usize;
        let mut hi = self.ways;
        let bits = self.plru[set];
        while hi - lo > 1 {
            let mid = (lo + hi) / 2;
            let right = bits & (1 << node) != 0;
            if right {
                lo = mid;
                node = 2 * node + 2;
            } else {
                hi = mid;
                node = 2 * node + 1;
            }
        }
        lo
    }

    fn victim_way(&mut self, set: usize) -> usize {
        let base = set * self.ways;
        // Prefer an invalid way.
        if let Some(w) = (0..self.ways).find(|w| !self.lines[base + w].valid) {
            return w;
        }
        match self.policy {
            ReplacementPolicy::Lru | ReplacementPolicy::Fifo => (0..self.ways)
                .min_by_key(|w| self.lines[base + w].stamp)
                .unwrap(),
            ReplacementPolicy::Plru if self.ways.is_power_of_two() => self.plru_victim(set),
            ReplacementPolicy::Plru => (0..self.ways)
                .min_by_key(|w| self.lines[base + w].stamp)
                .unwrap(),
            ReplacementPolicy::Random => (self.xorshift() % self.ways as u64) as usize,
        }
    }

    /// Simulate one access; returns hit/miss and any required write-back.
    pub fn access(&mut self, addr: u64, is_write: bool) -> Access {
        self.clock += 1;
        let set = self.set_of(addr);
        let tag = self.tag_of(addr);
        let base = set * self.ways;

        // Lookup.
        if let Some(w) = (0..self.ways)
            .find(|w| self.lines[base + w].valid && self.lines[base + w].tag == tag)
        {
            self.hits += 1;
            if self.policy == ReplacementPolicy::Lru {
                self.lines[base + w].stamp = self.clock;
            }
            self.touch_plru(set, w);
            let mut backing_access = false;
            if is_write {
                if self.write_back {
                    self.lines[base + w].dirty = true;
                } else {
                    backing_access = true; // write-through
                }
            }
            return Access {
                hit: true,
                writeback: None,
                backing_access,
            };
        }

        // Miss.
        self.misses += 1;
        if is_write && !self.write_allocate {
            // Write-around: no fill, direct backing write.
            return Access {
                hit: false,
                writeback: None,
                backing_access: true,
            };
        }
        let w = self.victim_way(set);
        let line = &self.lines[base + w];
        let writeback = if line.valid && line.dirty {
            Some(self.line_base(set, line.tag))
        } else {
            None
        };
        if writeback.is_some() {
            self.writebacks += 1;
        }
        let dirty = is_write && self.write_back;
        self.lines[base + w] = Line {
            tag,
            valid: true,
            dirty,
            stamp: self.clock,
        };
        self.touch_plru(set, w);
        let backing_access = true; // fill (and write-through stores also write)
        Access {
            hit: false,
            writeback,
            backing_access,
        }
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }

    pub fn reset_stats(&mut self) {
        self.hits = 0;
        self.misses = 0;
        self.writebacks = 0;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lru(sets: usize, ways: usize, line: u64) -> CacheState {
        CacheState::new(sets, ways, line, ReplacementPolicy::Lru, true, true)
    }

    #[test]
    fn cold_miss_then_hit() {
        let mut c = lru(4, 2, 16);
        assert!(!c.access(0x100, false).hit);
        assert!(c.access(0x100, false).hit);
        assert!(c.access(0x10F, false).hit, "same line");
        assert!(!c.access(0x110, false).hit, "next line");
        assert_eq!(c.hits, 2);
        assert_eq!(c.misses, 2);
    }

    #[test]
    fn lru_eviction_order() {
        // 1 set, 2 ways, 16B lines: addresses 0x000, 0x010*sets.. map to
        // the same set when sets=1.
        let mut c = lru(1, 2, 16);
        c.access(0x00, false); // miss, fill way A
        c.access(0x10, false); // miss, fill way B
        c.access(0x00, false); // hit, A is now MRU
        let a = c.access(0x20, false); // evicts B (LRU)
        assert!(!a.hit);
        assert!(c.access(0x00, false).hit, "A must survive");
        assert!(!c.access(0x10, false).hit, "B was evicted");
    }

    #[test]
    fn fifo_ignores_recency() {
        let mut c = CacheState::new(1, 2, 16, ReplacementPolicy::Fifo, true, true);
        c.access(0x00, false);
        c.access(0x10, false);
        c.access(0x00, false); // hit, but FIFO does not refresh stamp
        c.access(0x20, false); // evicts 0x00 (oldest fill)
        assert!(!c.access(0x00, false).hit, "FIFO evicted the oldest fill");
    }

    #[test]
    fn writeback_on_dirty_eviction() {
        let mut c = lru(1, 1, 16);
        c.access(0x00, true); // write miss, allocate + dirty
        let a = c.access(0x10, false); // evicts dirty line
        assert_eq!(a.writeback, Some(0x00));
        assert_eq!(c.writebacks, 1);
    }

    #[test]
    fn write_through_no_allocate() {
        let mut c = CacheState::new(1, 1, 16, ReplacementPolicy::Lru, false, false);
        let a = c.access(0x00, true); // write miss, no allocate
        assert!(!a.hit);
        assert!(a.backing_access);
        assert!(!c.access(0x00, false).hit, "no line was filled");
        // Read fill, then write hit must still go through.
        c.access(0x40, false);
        let wh = c.access(0x40, true);
        assert!(wh.hit && wh.backing_access, "write-through on hit");
    }

    #[test]
    fn plru_behaves_sanely() {
        let mut c = CacheState::new(1, 4, 16, ReplacementPolicy::Plru, true, true);
        for i in 0..4u64 {
            assert!(!c.access(i * 16, false).hit);
        }
        // Touch 0..2, victim should be among the untouched.
        c.access(0, false);
        c.access(16, false);
        c.access(32, false);
        c.access(4 * 16, false); // forces an eviction
        assert!(c.access(0, false).hit || c.access(16, false).hit);
    }

    #[test]
    fn random_is_deterministic() {
        let run = || {
            let mut c = CacheState::new(2, 2, 32, ReplacementPolicy::Random, true, true);
            for i in 0..64u64 {
                c.access(i * 32 % 512, i % 3 == 0);
            }
            (c.hits, c.misses)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn hit_rate_tracks() {
        let mut c = lru(8, 2, 16);
        for _ in 0..3 {
            for a in (0..256u64).step_by(16) {
                c.access(a, false);
            }
        }
        // 16 lines fit in 8 sets * 2 ways: everything hits after warm-up.
        assert!(c.hit_rate() > 0.6, "rate={}", c.hit_rate());
    }
}
