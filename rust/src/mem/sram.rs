//! SRAM timing helper: flat read/write latencies, optionally expressed as
//! latency functions over the access context (`size`, `port_width`).
//!
//! The interesting SRAM behavior — request slots, FIFO queuing, port
//! contention — is shared by every `DataStorage` and lives in
//! [`crate::sim::storage`]; this module only evaluates the per-access
//! latency attributes.

use crate::acadl_core::latency::{Latency, LatencyCtx};
use crate::acadl_core::object::Sram;

/// Evaluate an SRAM access latency. `words` is the number of data words in
/// the transaction (≤ `port_width`).
pub fn access_latency(cfg: &Sram, is_write: bool, words: usize) -> u64 {
    let lat = if is_write {
        &cfg.write_latency
    } else {
        &cfg.read_latency
    };
    match lat {
        Latency::Const(v) => *v,
        Latency::Expr(_) => {
            let ctx = LatencyCtx::new()
                .with("words", words as i64)
                .with("port_width", cfg.ds.port_width as i64)
                .with("data_width", cfg.ds.data_width as i64);
            lat.eval(&ctx).unwrap_or(1)
        }
    }
}

/// Capacity in data words implied by the served address range.
pub fn capacity_words(cfg: &Sram) -> u64 {
    let bytes = cfg.address_range.1.saturating_sub(cfg.address_range.0);
    bytes / (cfg.ds.data_width as u64 / 8).max(1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acadl_core::object::DataStorageParams;

    fn sram(read: Latency, write: Latency) -> Sram {
        Sram {
            ds: DataStorageParams {
                data_width: 32,
                max_concurrent_requests: 2,
                read_write_ports: 1,
                port_width: 4,
            },
            read_latency: read,
            write_latency: write,
            address_range: (0, 4096),
        }
    }

    #[test]
    fn const_latencies() {
        let s = sram(Latency::Const(2), Latency::Const(3));
        assert_eq!(access_latency(&s, false, 1), 2);
        assert_eq!(access_latency(&s, true, 1), 3);
    }

    #[test]
    fn expr_latencies_see_context() {
        let s = sram(
            Latency::parse("1 + ceil_div(words, port_width)").unwrap(),
            Latency::Const(1),
        );
        assert_eq!(access_latency(&s, false, 1), 2);
        assert_eq!(access_latency(&s, false, 8), 3);
    }

    #[test]
    fn capacity() {
        let s = sram(Latency::Const(1), Latency::Const(1));
        assert_eq!(capacity_words(&s), 1024); // 4096 B / 4 B words
    }
}
