//! Memory substrates: the stateful timing models behind the ACADL
//! `DataStorage` classes (§3, Figs 12–13).
//!
//! The paper delegates DRAM timing to DRAMsim3 and cache behavior to
//! pycachesim; per DESIGN.md's substitution table we implement the same
//! interfaces natively:
//!
//! * [`cache`] — set-associative cache with LRU/FIFO/PLRU/Random
//!   replacement, write-allocate and write-back policies (pycachesim's
//!   role: a hit/miss oracle per access).
//! * [`dram`] — banked row-buffer timing with t_RCD/t_RP/t_RAS/t_CAS
//!   (DRAMsim3's role: a per-request latency oracle).
//! * [`sram`] — flat-latency scratchpad helper.
//!
//! These are *pure* state machines (no simulator coupling); the request-slot
//! and FIFO-queue semantics of Figs 12–13 live in [`crate::sim::storage`].

pub mod cache;
pub mod dram;
pub mod sram;

pub use cache::{CacheState, ReplacementPolicy};
pub use dram::DramState;
