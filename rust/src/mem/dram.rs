//! Banked DRAM timing: the paper's `DRAM` class overrides read/write
//! latency with *stateful functions* parameterized by `bank_address_ranges`,
//! `t_RCD`, `t_RP`, and `t_RAS` (§3).  This module is our DRAMsim3-lite:
//! a row-buffer state machine per bank producing per-request latencies.
//!
//! Timing rules per access at cycle `now`:
//! * **row hit** (bank's open row == requested row): `t_CAS`.
//! * **row closed** (no open row): activate → `t_RCD + t_CAS`.
//! * **row conflict** (different row open): precharge must additionally wait
//!   until the open row has been active `t_RAS` cycles, then
//!   `t_RP + t_RCD + t_CAS`.

use crate::acadl_core::object::Dram;

#[derive(Debug, Clone, Copy, Default)]
struct Bank {
    open_row: Option<u64>,
    /// Cycle at which the open row was activated.
    activated_at: u64,
}

/// Row-buffer timing state for one DRAM object.
#[derive(Debug, Clone)]
pub struct DramState {
    banks: Vec<Bank>,
    row_bytes: u64,
    t_rcd: u64,
    t_rp: u64,
    t_ras: u64,
    t_cas: u64,
    base: u64,
    pub row_hits: u64,
    pub row_conflicts: u64,
    pub activations: u64,
}

impl DramState {
    pub fn new(cfg: &Dram) -> Self {
        DramState {
            banks: vec![Bank::default(); cfg.banks.max(1)],
            row_bytes: cfg.row_bytes.max(1),
            t_rcd: cfg.t_rcd,
            t_rp: cfg.t_rp,
            t_ras: cfg.t_ras,
            t_cas: cfg.t_cas,
            base: cfg.address_range.0,
            row_hits: 0,
            row_conflicts: 0,
            activations: 0,
        }
    }

    #[inline]
    fn locate(&self, addr: u64) -> (usize, u64) {
        let off = addr.saturating_sub(self.base);
        let global_row = off / self.row_bytes;
        // Rows interleave across banks (the common XOR-free mapping):
        // consecutive rows land in consecutive banks.
        let bank = (global_row % self.banks.len() as u64) as usize;
        let row = global_row / self.banks.len() as u64;
        (bank, row)
    }

    /// Latency in cycles for a request issued at `now`; updates bank state.
    /// Reads and writes share the row-buffer path (write recovery is folded
    /// into t_CAS at this abstraction level).
    pub fn access(&mut self, addr: u64, now: u64) -> u64 {
        let (bank_idx, row) = self.locate(addr);
        let bank = &mut self.banks[bank_idx];
        match bank.open_row {
            Some(open) if open == row => {
                self.row_hits += 1;
                self.t_cas
            }
            Some(_) => {
                self.row_conflicts += 1;
                self.activations += 1;
                // Respect minimum row-active time before precharge.
                let active_for = now.saturating_sub(bank.activated_at);
                let ras_stall = self.t_ras.saturating_sub(active_for);
                let lat = ras_stall + self.t_rp + self.t_rcd + self.t_cas;
                bank.open_row = Some(row);
                bank.activated_at = now + ras_stall + self.t_rp;
                lat
            }
            None => {
                self.activations += 1;
                bank.open_row = Some(row);
                bank.activated_at = now;
                self.t_rcd + self.t_cas
            }
        }
    }

    /// Latency if the request were issued now, without changing state
    /// (used by the AIDG estimator's optimistic pass).
    pub fn peek(&self, addr: u64, now: u64) -> u64 {
        let (bank_idx, row) = self.locate(addr);
        let bank = &self.banks[bank_idx];
        match bank.open_row {
            Some(open) if open == row => self.t_cas,
            Some(_) => {
                let active_for = now.saturating_sub(bank.activated_at);
                self.t_ras.saturating_sub(active_for) + self.t_rp + self.t_rcd + self.t_cas
            }
            None => self.t_rcd + self.t_cas,
        }
    }

    /// Earliest cycle at which the bank serving `addr` can change state
    /// without a t_RAS stall: a conflicting access issued before this
    /// cycle pays the remaining row-active time on top of precharge.
    /// This is the bank's next-event horizon for external schedulers and
    /// estimators ([`Self::peek`] gives the latency itself; this gives
    /// the boundary past which that latency stops shrinking).
    pub fn bank_ready(&self, addr: u64) -> u64 {
        let (bank_idx, _) = self.locate(addr);
        let bank = &self.banks[bank_idx];
        match bank.open_row {
            Some(_) => bank.activated_at + self.t_ras,
            None => 0,
        }
    }

    pub fn row_hit_rate(&self) -> f64 {
        let total = self.row_hits + self.row_conflicts + self.activations
            - self.row_conflicts; // activations double-count conflicts
        if total == 0 {
            0.0
        } else {
            self.row_hits as f64 / total as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acadl_core::object::DataStorageParams;

    fn dram(banks: usize) -> DramState {
        DramState::new(&Dram {
            ds: DataStorageParams::default(),
            address_range: (0x1000, 0x100000),
            banks,
            row_bytes: 1024,
            t_rcd: 14,
            t_rp: 14,
            t_ras: 33,
            t_cas: 10,
        })
    }

    #[test]
    fn first_access_activates() {
        let mut d = dram(4);
        assert_eq!(d.access(0x1000, 0), 14 + 10); // t_RCD + t_CAS
        assert_eq!(d.activations, 1);
    }

    #[test]
    fn row_hit_is_cas_only() {
        let mut d = dram(4);
        d.access(0x1000, 0);
        assert_eq!(d.access(0x1008, 30), 10); // same row: t_CAS
        assert_eq!(d.row_hits, 1);
    }

    #[test]
    fn row_conflict_pays_precharge() {
        let mut d = dram(1); // one bank: consecutive rows conflict
        d.access(0x1000, 0);
        // Next row, long after t_RAS satisfied: t_RP + t_RCD + t_CAS.
        let lat = d.access(0x1000 + 1024, 100);
        assert_eq!(lat, 14 + 14 + 10);
        assert_eq!(d.row_conflicts, 1);
    }

    #[test]
    fn ras_constraint_stalls_early_precharge() {
        let mut d = dram(1);
        d.access(0x1000, 0); // activated at 0
        // Conflict at cycle 5: row active only 5 < t_RAS=33 → stall 28 more.
        let lat = d.access(0x1000 + 1024, 5);
        assert_eq!(lat, 28 + 14 + 14 + 10);
    }

    #[test]
    fn banks_remove_conflicts() {
        let mut d = dram(4);
        // Rows 0..4 land in different banks: all are activations, no
        // conflicts.
        for r in 0..4u64 {
            d.access(0x1000 + r * 1024, r * 50);
        }
        assert_eq!(d.row_conflicts, 0);
        assert_eq!(d.activations, 4);
        // Revisiting row 0 is still a hit.
        assert_eq!(d.access(0x1000, 300), 10);
    }

    #[test]
    fn bank_ready_reflects_ras_window() {
        let mut d = dram(1);
        assert_eq!(d.bank_ready(0x1000), 0, "closed bank is ready");
        d.access(0x1000, 0); // activated at 0
        assert_eq!(d.bank_ready(0x1000), 33, "ready once t_RAS elapses");
        // A conflict before the horizon pays exactly the remaining t_RAS.
        let lat = d.peek(0x1000 + 1024, 5);
        assert_eq!(lat, (33 - 5) + 14 + 14 + 10);
        // At/after the horizon the latency bottoms out.
        assert_eq!(d.peek(0x1000 + 1024, 33), 14 + 14 + 10);
    }

    #[test]
    fn peek_does_not_mutate() {
        let mut d = dram(2);
        d.access(0x1000, 0);
        let before = d.row_hits;
        let p1 = d.peek(0x1000, 10);
        let p2 = d.peek(0x1000, 10);
        assert_eq!(p1, p2);
        assert_eq!(d.row_hits, before);
    }
}
