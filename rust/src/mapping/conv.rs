//! Convolution lowering: im2col + GeMM (§5's "to accelerate, e.g. a
//! convolution operation, one needs to define the necessary input data
//! transformations and computation schedules" — im2col is that transform,
//! and it is what TVM emits for GeMM-only accelerators like the OMA/Γ̈).

use crate::mapping::gemm::GemmParams;
use crate::mapping::mapper::{CostHints, Mapper};
use crate::mapping::uma::{Lowered, Machine, Operator, Registry, UmaError};

/// A 2-D convolution: NCHW input (N=1), OIHW weights, unit dilation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Conv2d {
    pub in_c: usize,
    pub in_h: usize,
    pub in_w: usize,
    pub out_c: usize,
    pub k_h: usize,
    pub k_w: usize,
    pub stride: usize,
    pub pad: usize,
}

impl Conv2d {
    pub fn out_h(&self) -> usize {
        (self.in_h + 2 * self.pad - self.k_h) / self.stride + 1
    }

    pub fn out_w(&self) -> usize {
        (self.in_w + 2 * self.pad - self.k_w) / self.stride + 1
    }

    /// The GeMM this convolution lowers to:
    /// `(out_h·out_w) × (in_c·k_h·k_w)` patches times
    /// `(in_c·k_h·k_w) × out_c` reshaped weights.
    pub fn as_gemm(&self) -> GemmParams {
        GemmParams::new(
            self.out_h() * self.out_w(),
            self.in_c * self.k_h * self.k_w,
            self.out_c,
        )
    }

    /// im2col: CHW input → patch matrix (row-major, rows = output pixels).
    pub fn im2col(&self, input: &[f32]) -> Vec<f32> {
        assert_eq!(input.len(), self.in_c * self.in_h * self.in_w);
        let (oh, ow) = (self.out_h(), self.out_w());
        let kk = self.in_c * self.k_h * self.k_w;
        let mut out = vec![0.0f32; oh * ow * kk];
        for oy in 0..oh {
            for ox in 0..ow {
                let row = oy * ow + ox;
                let mut col = 0usize;
                for c in 0..self.in_c {
                    for ky in 0..self.k_h {
                        for kx in 0..self.k_w {
                            let iy = (oy * self.stride + ky) as isize - self.pad as isize;
                            let ix = (ox * self.stride + kx) as isize - self.pad as isize;
                            if iy >= 0
                                && ix >= 0
                                && (iy as usize) < self.in_h
                                && (ix as usize) < self.in_w
                            {
                                out[row * kk + col] = input
                                    [c * self.in_h * self.in_w + iy as usize * self.in_w + ix as usize];
                            }
                            col += 1;
                        }
                    }
                }
            }
        }
        out
    }

    /// OIHW weights → (in_c·k_h·k_w) × out_c GeMM operand.
    pub fn reshape_weights(&self, w: &[f32]) -> Vec<f32> {
        let kk = self.in_c * self.k_h * self.k_w;
        assert_eq!(w.len(), self.out_c * kk);
        let mut out = vec![0.0f32; kk * self.out_c];
        for o in 0..self.out_c {
            for i in 0..kk {
                out[i * self.out_c + o] = w[o * kk + i];
            }
        }
        out
    }

    /// Direct reference convolution (validation oracle).
    pub fn conv_ref(&self, input: &[f32], w: &[f32]) -> Vec<f32> {
        let (oh, ow) = (self.out_h(), self.out_w());
        let mut out = vec![0.0f32; self.out_c * oh * ow];
        for o in 0..self.out_c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut acc = 0.0f32;
                    for c in 0..self.in_c {
                        for ky in 0..self.k_h {
                            for kx in 0..self.k_w {
                                let iy = (oy * self.stride + ky) as isize - self.pad as isize;
                                let ix = (ox * self.stride + kx) as isize - self.pad as isize;
                                if iy >= 0
                                    && ix >= 0
                                    && (iy as usize) < self.in_h
                                    && (ix as usize) < self.in_w
                                {
                                    acc += input[c * self.in_h * self.in_w
                                        + iy as usize * self.in_w
                                        + ix as usize]
                                        * w[o * self.in_c * self.k_h * self.k_w
                                            + c * self.k_h * self.k_w
                                            + ky * self.k_w
                                            + kx];
                                }
                            }
                        }
                    }
                    out[o * oh * ow + oy * ow + ox] = acc;
                }
            }
        }
        out
    }
}

/// Registry entry for im2col convolution: a **composite** mapper.  It
/// owns no code generation of its own — it re-enters the registry with
/// the patch-matrix GeMM the convolution reduces to, so every target that
/// implements GeMM gets convolution for free (exactly TVM's im2col
/// strategy for GeMM-only accelerators).  The host performs the im2col
/// data transform when loading inputs (see `dnn::lowering`).
pub struct Im2colConvMapper;

impl Im2colConvMapper {
    fn inner_gemm(op: &Operator) -> Option<Operator> {
        match op {
            Operator::Conv2d { gemm, .. } => Some(Operator::Gemm(*gemm)),
            _ => None,
        }
    }
}

impl Mapper for Im2colConvMapper {
    fn name(&self) -> &'static str {
        "im2col_conv"
    }

    fn supports(&self, reg: &Registry, machine: &Machine, op: &Operator) -> bool {
        // Supported wherever the *owning* registry maps the reduced GeMM
        // (the stored `gemm` carries any target padding the caller
        // applied), so `supports` and `lower` always agree.
        Self::inner_gemm(op).is_some_and(|g| reg.mapper_for(machine, &g).is_some())
    }

    fn lower(
        &self,
        reg: &Registry,
        machine: &Machine,
        op: &Operator,
    ) -> Result<Lowered, UmaError> {
        let Some(gemm) = Self::inner_gemm(op) else {
            return Err(UmaError::Unsupported(machine.name(), *op));
        };
        reg.lower(machine, &gemm)
    }

    fn cost_hints(&self, reg: &Registry, machine: &Machine, op: &Operator) -> CostHints {
        Self::inner_gemm(op)
            .and_then(|g| reg.cost_hints(machine, &g).ok())
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mapping::gemm::gemm_ref;

    fn conv() -> Conv2d {
        Conv2d {
            in_c: 2,
            in_h: 5,
            in_w: 5,
            out_c: 3,
            k_h: 3,
            k_w: 3,
            stride: 1,
            pad: 1,
        }
    }

    #[test]
    fn shapes() {
        let c = conv();
        assert_eq!((c.out_h(), c.out_w()), (5, 5));
        let g = c.as_gemm();
        assert_eq!((g.m, g.k, g.n), (25, 18, 3));
    }

    #[test]
    fn im2col_gemm_matches_direct_conv() {
        let c = conv();
        let input: Vec<f32> = (0..c.in_c * c.in_h * c.in_w)
            .map(|x| ((x % 11) as f32) - 5.0)
            .collect();
        let w: Vec<f32> = (0..c.out_c * c.in_c * c.k_h * c.k_w)
            .map(|x| ((x % 7) as f32) - 3.0)
            .collect();
        let patches = c.im2col(&input);
        let wg = c.reshape_weights(&w);
        let g = c.as_gemm();
        let gemm_out = gemm_ref(&g, &patches, &wg); // (oh·ow) × out_c
        let direct = c.conv_ref(&input, &w); // out_c × oh × ow
        let (oh, ow) = (c.out_h(), c.out_w());
        for o in 0..c.out_c {
            for p in 0..oh * ow {
                let a = gemm_out[p * c.out_c + o];
                let b = direct[o * oh * ow + p];
                assert!((a - b).abs() < 1e-3, "o={o} p={p}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn stride_and_pad_variants() {
        for (stride, pad) in [(1, 0), (2, 1), (2, 0)] {
            let c = Conv2d {
                stride,
                pad,
                ..conv()
            };
            let input: Vec<f32> = (0..c.in_c * c.in_h * c.in_w).map(|x| x as f32).collect();
            let w = vec![1.0f32; c.out_c * c.in_c * c.k_h * c.k_w];
            let patches = c.im2col(&input);
            let wg = c.reshape_weights(&w);
            let g = c.as_gemm();
            let got = gemm_ref(&g, &patches, &wg);
            let want = c.conv_ref(&input, &w);
            let (oh, ow) = (c.out_h(), c.out_w());
            for o in 0..c.out_c {
                for p in 0..oh * ow {
                    assert!((got[p * c.out_c + o] - want[o * oh * ow + p]).abs() < 1e-2);
                }
            }
        }
    }
}
