//! Fused-tensor GeMM mapping onto Γ̈ (§4.3, Listing 4).
//!
//! `C (m×n) = act(A (m×k) · B (k×n) + bias)` with 8×8 tiles.  Per output
//! tile, on the assigned unit `u` (round-robin across units — the paper's
//! "instructions intended for different hardware components are issued in
//! parallel and executed out-of-order"):
//!
//! ```text
//! v[u].0–7   A tile rows      v[u].16–23 C accumulator rows
//! v[u].8–15  B tile rows      v[u].24–31 gemm product / bias staging
//! ```
//!
//! Each k-step loads the A and B tiles row-by-row (Listing 4's `load
//! [0x3000] => r[0].0` pattern), issues one fused `gemm`, and accumulates
//! with `vadd`.  The final k-step applies bias (`vadd`) and ReLU (`vrelu`)
//! before storing the 8 result rows.

use crate::acadl_core::graph::RegId;
use crate::analytical::Roofline;
use crate::arch::gamma::GammaMachine;
use crate::isa::instruction::{AddrRef, Instruction};
use crate::isa::opcode::Opcode;
use crate::isa::program::Program;
use crate::isa::GAMMA_TILE;
use crate::mapping::gemm::{GemmLayout, GemmParams};
use crate::mapping::mapper::{CostHints, Mapper};
use crate::mapping::uma::{Lowered, Machine, Operator, Registry, UmaError};

/// Extra mapping options for the Γ̈ generator.
#[derive(Debug, Clone, Copy, Default)]
pub struct GammaGemmOpts {
    /// Apply ReLU to the output (the `1:` flag of Listing 4).
    pub relu: bool,
    /// Add a bias row (length n, stored at `bias_base`) to every C row.
    pub bias_base: Option<u64>,
    /// Stage each A row-strip into the unit's scratchpad once (Listing 4's
    /// spad-resident dataflow): the strip is DMA'd DRAM→spad via the LSU's
    /// staging registers and then reused by every output tile of that row
    /// block — cutting DRAM A-traffic by a factor of n/8.
    pub use_spad: bool,
}

/// Generate the Γ̈ program. Dimensions must be multiples of 8 (callers pad
/// — see `dnn::lowering`).
pub fn gamma_gemm(machine: &GammaMachine, p: &GemmParams, opts: GammaGemmOpts) -> Program {
    let t = GAMMA_TILE;
    assert!(
        p.m % t == 0 && p.k % t == 0 && p.n % t == 0,
        "Γ̈ mapping needs multiples of {t} (got {}x{}x{})",
        p.m,
        p.k,
        p.n
    );
    let layout = GemmLayout::at(machine.dram_base(), p);
    let ag = &machine.ag;
    let units = machine.cfg.units;
    let vreg = |u: usize, i: usize| -> RegId {
        ag.reg_id(&machine.vreg(u, i)).expect("vector registers exist")
    };

    let mut out: Vec<Instruction> = Vec::new();
    for ti in 0..p.m / t {
        // Row blocks round-robin over units so each unit owns a whole
        // strip — the reuse unit of the scratchpad staging.
        let u = ti % units;
        let spad_a = machine.units[u].spad_range.0;
        if opts.use_spad {
            // DMA the A strip (8 rows × K) DRAM → spad once, cycling the
            // product/staging registers so transfers overlap.
            assert!(
                (t * p.k * 4) as u64 <= machine.cfg.spad_bytes,
                "A strip ({} B) must fit the scratchpad",
                t * p.k * 4
            );
            for r in 0..t {
                for kk in 0..p.k / t {
                    let s = vreg(u, 3 * t + (r + kk) % t);
                    out.push(
                        Instruction::new(Opcode::Load)
                            .with_read_addrs(vec![AddrRef::Direct(
                                layout.a(p, ti * t + r, kk * t),
                            )])
                            .with_writes(vec![s]),
                    );
                    out.push(
                        Instruction::new(Opcode::Store)
                            .with_reads(vec![s])
                            .with_write_addrs(vec![AddrRef::Direct(
                                spad_a + ((r * p.k + kk * t) * 4) as u64,
                            )]),
                    );
                }
            }
        }
        for tj in 0..p.n / t {
            let a0 = 0; // A rows
            let b0 = t; // B rows
            let c0 = 2 * t; // accumulator rows
            let s0 = 3 * t; // staging rows (gemm product / bias)

            for kk in 0..p.k / t {
                // Load A tile rows (from the staged strip when enabled).
                for r in 0..t {
                    let src = if opts.use_spad {
                        spad_a + ((r * p.k + kk * t) * 4) as u64
                    } else {
                        layout.a(p, ti * t + r, kk * t)
                    };
                    out.push(
                        Instruction::new(Opcode::Load)
                            .with_read_addrs(vec![AddrRef::Direct(src)])
                            .with_writes(vec![vreg(u, a0 + r)]),
                    );
                }
                // Load B tile rows.
                for r in 0..t {
                    out.push(
                        Instruction::new(Opcode::Load)
                            .with_read_addrs(vec![AddrRef::Direct(
                                layout.b(p, kk * t + r, tj * t),
                            )])
                            .with_writes(vec![vreg(u, b0 + r)]),
                    );
                }
                if kk == 0 {
                    // First product lands directly in the accumulator.
                    out.push(gemm_instr(u, a0, b0, c0, 0, &vreg));
                } else {
                    // Product to staging, then accumulate.
                    out.push(gemm_instr(u, a0, b0, s0, 0, &vreg));
                    for r in 0..t {
                        out.push(
                            Instruction::new(Opcode::VAdd)
                                .with_reads(vec![vreg(u, c0 + r), vreg(u, s0 + r)])
                                .with_writes(vec![vreg(u, c0 + r)]),
                        );
                    }
                }
            }
            // Bias.
            if let Some(bias) = opts.bias_base {
                out.push(
                    Instruction::new(Opcode::Load)
                        .with_read_addrs(vec![AddrRef::Direct(bias + (tj * t * 4) as u64)])
                        .with_writes(vec![vreg(u, s0)]),
                );
                for r in 0..t {
                    out.push(
                        Instruction::new(Opcode::VAdd)
                            .with_reads(vec![vreg(u, c0 + r), vreg(u, s0)])
                            .with_writes(vec![vreg(u, c0 + r)]),
                    );
                }
            }
            // Activation.
            if opts.relu {
                for r in 0..t {
                    out.push(
                        Instruction::new(Opcode::VRelu)
                            .with_reads(vec![vreg(u, c0 + r)])
                            .with_writes(vec![vreg(u, c0 + r)]),
                    );
                }
            }
            // Store C tile rows.
            for r in 0..t {
                out.push(
                    Instruction::new(Opcode::Store)
                        .with_reads(vec![vreg(u, c0 + r)])
                        .with_write_addrs(vec![AddrRef::Direct(
                            layout.c(p, ti * t + r, tj * t),
                        )]),
                );
            }
        }
    }
    out.push(Instruction::new(Opcode::Halt));
    Program::new(out, machine.cfg.imem_range.0)
}

fn gemm_instr(
    u: usize,
    a0: usize,
    b0: usize,
    dst0: usize,
    act: i64,
    vreg: &dyn Fn(usize, usize) -> RegId,
) -> Instruction {
    let t = GAMMA_TILE;
    Instruction::new(Opcode::Gemm)
        .with_reads(
            (0..t)
                .map(|r| vreg(u, a0 + r))
                .chain((0..t).map(|r| vreg(u, b0 + r)))
                .collect(),
        )
        .with_writes((0..t).map(|r| vreg(u, dst0 + r)).collect())
        .with_imms(vec![act])
}

/// The literal Listing-4 program: an 8×8 gemm with ReLU whose inputs live
/// in unit 0's scratchpad and whose output returns there — assembled from
/// (address-adjusted) Listing 4 text.
pub fn gamma_listing4_program(machine: &GammaMachine) -> Program {
    let (a, b, c) = machine.spad_tile_bases(0);
    let t = GAMMA_TILE as u64;
    let mut src = String::new();
    // load [A row r] => v[0].r     (Listing 4 lines 1–3)
    for r in 0..t {
        src.push_str(&format!("load [{:#x}] => v[0].{r}\n", a + r * t * 4));
    }
    // load [B row r] => v[0].{8+r} (Listing 4 lines 4–6)
    for r in 0..t {
        src.push_str(&format!("load [{:#x}] => v[0].{}\n", b + r * t * 4, t + r));
    }
    // gemm with ReLU (line 7: `gemm r[0].0, r[0].8, 1 => r[0].16`).
    src.push_str("gemm v[0].0, v[0].8, 1 => v[0].16\n");
    // store result rows (lines 8–11).
    for r in 0..t {
        src.push_str(&format!(
            "store v[0].{} => [{:#x}]\n",
            2 * t + r,
            c + r * t * 4
        ));
    }
    src.push_str("halt\n");
    crate::isa::assembler::assemble(&machine.ag, &src, machine.cfg.imem_range.0)
        .expect("listing 4 text assembles")
}

/// Registry entry for [`gamma_gemm`]: the Γ̈ fused-tensor mapping.  The
/// only mapper that accepts the fused `Dense` operator (bias + ReLU
/// applied on-device); requires all GeMM dims padded to [`GAMMA_TILE`].
pub struct GammaFusedTensorMapper;

impl Mapper for GammaFusedTensorMapper {
    fn name(&self) -> &'static str {
        "gamma_fused_gemm"
    }

    fn supports(&self, _reg: &Registry, machine: &Machine, op: &Operator) -> bool {
        let t = GAMMA_TILE;
        let padded = |p: &GemmParams| p.m % t == 0 && p.k % t == 0 && p.n % t == 0;
        matches!(machine, Machine::Gamma(_))
            && match op {
                Operator::Gemm(p) => padded(p),
                Operator::Dense { gemm, .. } => padded(gemm),
                _ => false,
            }
    }

    fn lower(
        &self,
        _reg: &Registry,
        machine: &Machine,
        op: &Operator,
    ) -> Result<Lowered, UmaError> {
        let Machine::Gamma(m) = machine else {
            return Err(UmaError::Unsupported(machine.name(), *op));
        };
        let program = match op {
            Operator::Gemm(p) => gamma_gemm(m, p, GammaGemmOpts::default()),
            Operator::Dense {
                gemm,
                bias_base,
                relu,
            } => gamma_gemm(
                m,
                gemm,
                GammaGemmOpts {
                    relu: *relu,
                    bias_base: Some(*bias_base),
                    ..Default::default()
                },
            ),
            _ => return Err(UmaError::Unsupported(machine.name(), *op)),
        };
        Ok(Lowered::new(program, machine, op))
    }

    fn cost_hints(&self, _reg: &Registry, machine: &Machine, op: &Operator) -> CostHints {
        let Some(p) = op.gemm_params() else {
            return CostHints::default();
        };
        let units = match machine {
            Machine::Gamma(m) => m.cfg.units,
            _ => 1,
        };
        let t = GAMMA_TILE as u64;
        // Per 8×8 output tile and k-step: 2·8 row loads + gemm + vadd;
        // plus 8 stores (and bias/activation ops) per output tile.
        let out_tiles = ((p.m / GAMMA_TILE) * (p.n / GAMMA_TILE)).max(1) as u64;
        let ksteps = (p.k / GAMMA_TILE).max(1) as u64;
        let est = out_tiles * (ksteps * (2 * t + 2) + t + 2) + 1;
        CostHints {
            min_cycles: Roofline::gamma(units).gemm_cycles(p),
            est_instructions: est,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::gamma::GammaConfig;
    use crate::mapping::gemm::gemm_ref;
    use crate::sim::engine::Engine;
    use crate::sim::functional::FunctionalSim;

    fn inputs(p: &GemmParams) -> (Vec<f32>, Vec<f32>) {
        let a: Vec<f32> = (0..p.m * p.k).map(|x| ((x % 9) as f32) - 4.0).collect();
        let b: Vec<f32> = (0..p.k * p.n).map(|x| ((x % 7) as f32) - 3.0).collect();
        (a, b)
    }

    #[test]
    fn single_tile_correct() {
        let m = GammaConfig::new(1).build().unwrap();
        let p = GemmParams::new(8, 8, 8);
        let prog = gamma_gemm(&m, &p, GammaGemmOpts::default());
        let layout = GemmLayout::at(m.dram_base(), &p);
        let (a, b) = inputs(&p);
        let mut sim = FunctionalSim::new(&m.ag);
        layout.load_inputs(&p, &mut sim.mem, &a, &b);
        sim.run(&prog, 1_000_000).unwrap();
        assert_eq!(layout.read_c(&p, &sim.mem), gemm_ref(&p, &a, &b));
    }

    #[test]
    fn multi_tile_with_accumulation() {
        let m = GammaConfig::new(2).build().unwrap();
        let p = GemmParams::new(16, 24, 16);
        let prog = gamma_gemm(&m, &p, GammaGemmOpts::default());
        let layout = GemmLayout::at(m.dram_base(), &p);
        let (a, b) = inputs(&p);
        let mut sim = FunctionalSim::new(&m.ag);
        layout.load_inputs(&p, &mut sim.mem, &a, &b);
        sim.run(&prog, 10_000_000).unwrap();
        let got = layout.read_c(&p, &sim.mem);
        let want = gemm_ref(&p, &a, &b);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-2, "{g} vs {w}");
        }
    }

    #[test]
    fn relu_and_bias() {
        let m = GammaConfig::new(1).build().unwrap();
        let p = GemmParams::new(8, 8, 8);
        let bias_base = m.dram_base() + 0x10_0000;
        let prog = gamma_gemm(
            &m,
            &p,
            GammaGemmOpts {
                relu: true,
                bias_base: Some(bias_base),
                ..Default::default()
            },
        );
        let layout = GemmLayout::at(m.dram_base(), &p);
        let (a, b) = inputs(&p);
        let bias: Vec<f32> = (0..p.n).map(|j| j as f32 * 0.5 - 2.0).collect();
        let mut sim = FunctionalSim::new(&m.ag);
        layout.load_inputs(&p, &mut sim.mem, &a, &b);
        sim.mem.load_f32(bias_base, &bias);
        sim.run(&prog, 1_000_000).unwrap();
        let got = layout.read_c(&p, &sim.mem);
        let plain = gemm_ref(&p, &a, &b);
        for i in 0..p.m {
            for j in 0..p.n {
                let want = (plain[i * p.n + j] + bias[j]).max(0.0);
                let g = got[i * p.n + j];
                assert!((g - want).abs() < 1e-3, "{g} vs {want}");
            }
        }
    }

    #[test]
    fn spad_staging_correct_and_cuts_dram_traffic() {
        let m = GammaConfig::new(2).build().unwrap();
        let p = GemmParams::new(16, 16, 32); // 2 row strips × 4 tiles
        let (a, b) = inputs(&p);
        let layout = GemmLayout::at(m.dram_base(), &p);
        let run = |use_spad: bool| {
            let prog = gamma_gemm(
                &m,
                &p,
                GammaGemmOpts {
                    use_spad,
                    ..Default::default()
                },
            );
            let mut e = Engine::new(&m.ag, &prog).unwrap();
            layout.load_inputs(&p, &mut e.mem, &a, &b);
            let stats = e.run(10_000_000).unwrap();
            let dram_reqs = stats
                .storages
                .iter()
                .find(|s| s.name == "dram0")
                .unwrap()
                .requests;
            (layout.read_c(&p, &e.mem), dram_reqs, stats.cycles)
        };
        let (c_plain, dram_plain, _) = run(false);
        let (c_spad, dram_spad, _) = run(true);
        let want = gemm_ref(&p, &a, &b);
        for (g, w) in c_spad.iter().zip(&want) {
            assert!((g - w).abs() < 1e-2, "{g} vs {w}");
        }
        assert_eq!(c_plain, c_spad, "staging must not change results");
        assert!(
            dram_spad < dram_plain,
            "A reuse must cut DRAM traffic: {dram_spad} vs {dram_plain}"
        );
    }

    #[test]
    fn two_units_run_faster_than_one() {
        let p = GemmParams::new(16, 8, 16); // 4 independent tiles
        let cycles = |units: usize| {
            let m = GammaConfig::new(units).build().unwrap();
            let prog = gamma_gemm(&m, &p, GammaGemmOpts::default());
            let layout = GemmLayout::at(m.dram_base(), &p);
            let (a, b) = inputs(&p);
            let mut e = Engine::new(&m.ag, &prog).unwrap();
            layout.load_inputs(&p, &mut e.mem, &a, &b);
            e.run(10_000_000).unwrap().cycles
        };
        let (c1, c2) = (cycles(1), cycles(2));
        assert!(c2 < c1, "parallel units must help: 1u={c1} 2u={c2}");
    }

    #[test]
    fn listing4_program_runs_and_relus() {
        let m = GammaConfig::default().build().unwrap();
        let prog = gamma_listing4_program(&m);
        let (a_base, b_base, c_base) = m.spad_tile_bases(0);
        let t = GAMMA_TILE;
        // A = -identity, B = identity → raw product −I; ReLU clamps to 0.
        let mut a = vec![0.0f32; t * t];
        let mut b = vec![0.0f32; t * t];
        for i in 0..t {
            a[i * t + i] = -1.0;
            b[i * t + i] = 1.0;
        }
        let mut sim = FunctionalSim::new(&m.ag);
        sim.mem.load_f32(a_base, &a);
        sim.mem.load_f32(b_base, &b);
        sim.run(&prog, 100_000).unwrap();
        let c = sim.mem.dump_f32(c_base, t * t);
        assert!(c.iter().all(|&x| x == 0.0), "ReLU(-I) == 0");
    }
}
