//! The `Mapper` trait: the single seam between DNN operators and
//! accelerator code generation.
//!
//! Every per-architecture code generator (the paper's UMA "interface
//! functions", §5) implements this trait and is registered with the
//! [`Registry`](crate::mapping::uma::Registry).  Consumers — the DNN graph
//! lowering, the coordinator's job executor, the DSE engine — never call a
//! generator directly: they ask the registry for a mapper that `supports`
//! the (machine, operator) pair and get back a lowered program, an operand
//! layout, and **static cost hints** (simulation-free estimates for
//! consumers that already hold a built machine).
//!
//! Both the hints' `min_cycles` and the DSE pre-filter's machine-free
//! bound (`TargetSpec::roofline()` in `dse::lower_bound_cycles`) derive
//! from the same per-target constructors
//! (`analytical::Roofline::{oma,systolic,gamma}`), so the two paths
//! cannot drift apart: `analytical` is the single source of truth for
//! what "cycles can never go below this" means.

use crate::mapping::uma::{Lowered, Machine, Operator, Registry, UmaError};

/// Static, simulation-free cost estimates for a lowered operator.
///
/// `min_cycles` is the load-bearing field: it must be a **sound lower
/// bound** on the cycles any timed simulation of the mapping reports — it
/// is built from the same `analytical::Roofline` per-target constructors
/// the DSE pre-filter prunes with, and a property test asserts simulated
/// cycles never dip below that roofline.  The instruction estimate is
/// advisory (program-size ballpark for memory budgeting and reporting).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CostHints {
    /// Sound lower bound on timed-simulation cycles (0 = no claim).
    pub min_cycles: u64,
    /// Approximate static instruction count of the generated program.
    pub est_instructions: u64,
}

/// A registered operator → program code generator for one target family.
///
/// Implementations are stateless (`Send + Sync`, zero-sized in practice):
/// all problem state arrives through the operator and the built machine.
/// `lower` and `cost_hints` receive the registry so composite mappers
/// (e.g. im2col convolution) can delegate to the mapper of the operator
/// they decompose into.
pub trait Mapper: Send + Sync {
    /// Stable registry name (CLI `--mapper`, diagnostics).
    fn name(&self) -> &'static str;

    /// Can this mapper lower `op` onto `machine`?  The registry dispatches
    /// to the first registered mapper that answers yes, passing itself so
    /// composite mappers probe the *owning* registry (not the global one)
    /// and `supports`/`lower` can never disagree on a custom registry.
    fn supports(&self, reg: &Registry, machine: &Machine, op: &Operator) -> bool;

    /// Generate the program and operand layout.
    fn lower(
        &self,
        reg: &Registry,
        machine: &Machine,
        op: &Operator,
    ) -> Result<Lowered, UmaError>;

    /// Analytical cost hints for the DSE pre-filter (see [`CostHints`]).
    fn cost_hints(&self, reg: &Registry, machine: &Machine, op: &Operator) -> CostHints;
}
