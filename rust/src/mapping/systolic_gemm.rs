//! Output-stationary GeMM mapping onto the parameterizable systolic array
//! (§4.2).
//!
//! The output matrix is tiled into rows×cols blocks; within a block, each
//! PE (r, c) owns output element (i, j) and performs K `macf` steps.  Only
//! the array edges touch memory: load units feed `A[i][k]` into column 0
//! and `B[k][j]` into row 0; interior PEs receive operands through the
//! neighbor-forwarding writes of the PE template (Listing 2's dangling
//! edges).  The wavefront timing emerges from the dependency scoreboard —
//! PE (r, c)'s step k waits on PE (r, c-1)'s step k (`a` chain) and
//! PE (r-1, c)'s step k (`b` chain), which is exactly the diagonal-fill
//! pipeline of a physical systolic array.

use crate::acadl_core::graph::RegId;
use crate::analytical::Roofline;
use crate::arch::systolic::SystolicMachine;
use crate::isa::instruction::{AddrRef, Instruction};
use crate::isa::opcode::Opcode;
use crate::isa::program::Program;
use crate::mapping::gemm::{GemmLayout, GemmParams};
use crate::mapping::mapper::{CostHints, Mapper};
use crate::mapping::uma::{Lowered, Machine, Operator, Registry, UmaError};

/// Generate the output-stationary program for `C (m×n) = A (m×k) · B (k×n)`
/// on `machine`.  Dimensions need not divide the array; edge tiles shrink.
pub fn systolic_gemm(machine: &SystolicMachine, p: &GemmParams) -> Program {
    let layout = GemmLayout::at(machine.dmem_base(), p);
    let ag = &machine.ag;
    let (rows, cols) = (machine.cfg.rows, machine.cfg.cols);
    let reg = |r: usize, c: usize, which: &str| -> RegId {
        ag.reg_id(&machine.pe_reg(r, c, which))
            .expect("PE registers exist")
    };

    let mut out: Vec<Instruction> = Vec::new();
    for bi in 0..p.m.div_ceil(rows) {
        for bj in 0..p.n.div_ceil(cols) {
            let tr = rows.min(p.m - bi * rows); // tile rows
            let tc = cols.min(p.n - bj * cols); // tile cols
            // Reset accumulators.
            for r in 0..tr {
                for c in 0..tc {
                    out.push(
                        Instruction::new(Opcode::Movi)
                            .with_imms(vec![0])
                            .with_writes(vec![reg(r, c, "acc")]),
                    );
                }
            }
            // K steps.
            for kk in 0..p.k {
                // Edge feeds.
                for r in 0..tr {
                    let i = bi * rows + r;
                    out.push(
                        Instruction::new(Opcode::Load)
                            .with_read_addrs(vec![AddrRef::Direct(layout.a(p, i, kk))])
                            .with_writes(vec![reg(r, 0, "a")]),
                    );
                }
                for c in 0..tc {
                    let j = bj * cols + c;
                    out.push(
                        Instruction::new(Opcode::Load)
                            .with_read_addrs(vec![AddrRef::Direct(layout.b(p, kk, j))])
                            .with_writes(vec![reg(0, c, "b")]),
                    );
                }
                // macf wavefront (anti-diagonal order for readability; the
                // scoreboard enforces the actual timing).
                for d in 0..(tr + tc - 1) {
                    for r in 0..tr {
                        let Some(c) = d.checked_sub(r) else { continue };
                        if c >= tc {
                            continue;
                        }
                        let mut writes = vec![reg(r, c, "acc")];
                        let mut flags = 0i64;
                        if c + 1 < tc {
                            writes.push(reg(r, c + 1, "a"));
                            flags |= 1;
                        }
                        if r + 1 < tr {
                            writes.push(reg(r + 1, c, "b"));
                            flags |= 2;
                        }
                        out.push(
                            Instruction::new(Opcode::MacFwd)
                                .with_reads(vec![
                                    reg(r, c, "a"),
                                    reg(r, c, "b"),
                                    reg(r, c, "acc"),
                                ])
                                .with_writes(writes)
                                .with_imms(vec![flags]),
                        );
                    }
                }
            }
            // Drain accumulators.
            for r in 0..tr {
                for c in 0..tc {
                    let (i, j) = (bi * rows + r, bj * cols + c);
                    out.push(
                        Instruction::new(Opcode::Store)
                            .with_reads(vec![reg(r, c, "acc")])
                            .with_write_addrs(vec![AddrRef::Direct(layout.c(p, i, j))]),
                    );
                }
            }
        }
    }
    out.push(Instruction::new(Opcode::Halt));
    Program::new(out, machine.cfg.imem_range.0)
}

/// Registry entry for [`systolic_gemm`]: the output-stationary wavefront
/// mapping onto the rows×cols array.
pub struct SystolicWavefrontMapper;

impl Mapper for SystolicWavefrontMapper {
    fn name(&self) -> &'static str {
        "systolic_wavefront_gemm"
    }

    fn supports(&self, _reg: &Registry, machine: &Machine, op: &Operator) -> bool {
        matches!(machine, Machine::Systolic(_)) && matches!(op, Operator::Gemm(_))
    }

    fn lower(
        &self,
        _reg: &Registry,
        machine: &Machine,
        op: &Operator,
    ) -> Result<Lowered, UmaError> {
        let (Machine::Systolic(m), Operator::Gemm(p)) = (machine, op) else {
            return Err(UmaError::Unsupported(machine.name(), *op));
        };
        Ok(Lowered::new(systolic_gemm(m, p), machine, op))
    }

    fn cost_hints(&self, _reg: &Registry, machine: &Machine, op: &Operator) -> CostHints {
        let Some(p) = op.gemm_params() else {
            return CostHints::default();
        };
        let (rows, cols) = match machine {
            Machine::Systolic(m) => (m.cfg.rows, m.cfg.cols),
            _ => (1, 1),
        };
        // Per output tile: reset + drain (tr·tc each) and, per k-step,
        // tr + tc edge loads plus tr·tc macf ops.
        let tiles = (p.m.div_ceil(rows) * p.n.div_ceil(cols)) as u64;
        let per_tile =
            (2 * rows * cols + p.k * (rows + cols) + p.k * rows * cols) as u64;
        CostHints {
            min_cycles: Roofline::systolic(rows, cols).gemm_cycles(p),
            est_instructions: tiles * per_tile + 1,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::systolic::SystolicConfig;
    use crate::mapping::gemm::gemm_ref;
    use crate::sim::engine::Engine;
    use crate::sim::functional::FunctionalSim;

    fn inputs(p: &GemmParams) -> (Vec<f32>, Vec<f32>) {
        let a: Vec<f32> = (0..p.m * p.k).map(|x| ((x % 7) as f32) - 3.0).collect();
        let b: Vec<f32> = (0..p.k * p.n).map(|x| ((x % 5) as f32) - 2.0).collect();
        (a, b)
    }

    #[test]
    fn functional_correct_exact_fit() {
        let m = SystolicConfig::new(4, 4).build().unwrap();
        let p = GemmParams::new(4, 6, 4);
        let prog = systolic_gemm(&m, &p);
        let layout = GemmLayout::at(m.dmem_base(), &p);
        let (a, b) = inputs(&p);
        let mut sim = FunctionalSim::new(&m.ag);
        layout.load_inputs(&p, &mut sim.mem, &a, &b);
        sim.run(&prog, 10_000_000).unwrap();
        assert_eq!(layout.read_c(&p, &sim.mem), gemm_ref(&p, &a, &b));
    }

    #[test]
    fn functional_correct_multi_tile() {
        let m = SystolicConfig::new(2, 2).build().unwrap();
        let p = GemmParams::new(5, 3, 4); // ragged tiles
        let prog = systolic_gemm(&m, &p);
        let layout = GemmLayout::at(m.dmem_base(), &p);
        let (a, b) = inputs(&p);
        let mut sim = FunctionalSim::new(&m.ag);
        layout.load_inputs(&p, &mut sim.mem, &a, &b);
        sim.run(&prog, 10_000_000).unwrap();
        assert_eq!(layout.read_c(&p, &sim.mem), gemm_ref(&p, &a, &b));
    }

    #[test]
    fn timed_matches_functional_and_shows_parallelism() {
        let m = SystolicConfig::new(4, 4).build().unwrap();
        let p = GemmParams::new(4, 8, 4);
        let prog = systolic_gemm(&m, &p);
        let layout = GemmLayout::at(m.dmem_base(), &p);
        let (a, b) = inputs(&p);

        let mut f = FunctionalSim::new(&m.ag);
        layout.load_inputs(&p, &mut f.mem, &a, &b);
        f.run(&prog, 10_000_000).unwrap();

        let mut e = Engine::new(&m.ag, &prog).unwrap();
        layout.load_inputs(&p, &mut e.mem, &a, &b);
        let stats = e.run(10_000_000).unwrap();

        assert_eq!(layout.read_c(&p, &e.mem), layout.read_c(&p, &f.mem));
        // 16 PEs × 8 k-steps = 128 macs; a serial machine would need >128
        // execute cycles for the macs alone plus loads. The array must
        // beat 1 mac/cycle overall.
        let macs = p.macs();
        assert!(
            stats.ipc() > 1.0,
            "parallel issue should exceed scalar IPC: ipc={} cycles={} macs={macs}",
            stats.ipc(),
            stats.cycles
        );
    }
}
