//! Tiled GeMM on the OMA (§5, Listing 5, Fig. 8).
//!
//! Two code generators:
//!
//! * [`oma_tiled_gemm`] — the UMA interface function: parameterizable tile
//!   size and loop order.  Outer (tile) and inner loops are unrolled by the
//!   generator into direct-addressed instructions, which keeps the memory
//!   access *order* (the thing tiling and loop order change, §5: "various
//!   execution orders ... significant impact on the execution time") fully
//!   visible to the cache model.  When `k` is innermost the accumulator
//!   lives in a register (Listing 5's `r8`); otherwise partial sums
//!   read-modify-write C in memory — exactly the locality trade-off the
//!   paper's Fig. 8 discussion motivates.
//! * [`oma_gemm_listing5`] — the literal register-loop implementation of
//!   Listing 5 (pointer-walking inner loop, countdown branches), assembled
//!   from the paper's asm syntax.
//!
//! Memory layout: row-major `A (m×k)` at `a_base`, `B (k×n)` at `b_base`,
//! `C (m×n)` at `c_base`, f32 elements.

use crate::acadl_core::graph::{Ag, RegId};
use crate::analytical::Roofline;
use crate::arch::oma::OmaMachine;
use crate::isa::assembler::{assemble, AsmError};
use crate::isa::instruction::{AddrRef, Instruction};
use crate::isa::opcode::Opcode;
use crate::isa::program::Program;
use crate::mapping::mapper::{CostHints, Mapper};
use crate::mapping::uma::{Lowered, Machine, Operator, Registry, UmaError};
use crate::sim::exec::MemImage;

/// The six classic GeMM loop orders.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LoopOrder {
    Ijk,
    Ikj,
    Jik,
    Jki,
    Kij,
    Kji,
}

impl LoopOrder {
    pub const ALL: [LoopOrder; 6] = [
        LoopOrder::Ijk,
        LoopOrder::Ikj,
        LoopOrder::Jik,
        LoopOrder::Jki,
        LoopOrder::Kij,
        LoopOrder::Kji,
    ];

    pub fn name(self) -> &'static str {
        match self {
            LoopOrder::Ijk => "ijk",
            LoopOrder::Ikj => "ikj",
            LoopOrder::Jik => "jik",
            LoopOrder::Jki => "jki",
            LoopOrder::Kij => "kij",
            LoopOrder::Kji => "kji",
        }
    }

    /// Is `k` the innermost loop (register accumulation possible)?
    pub fn k_innermost(self) -> bool {
        matches!(self, LoopOrder::Ijk | LoopOrder::Jik)
    }
}

/// GeMM problem + mapping parameters: `C (m×n) = A (m×k) · B (k×n)`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GemmParams {
    pub m: usize,
    pub k: usize,
    pub n: usize,
    /// Tile edge (None = untiled / single full tile).
    pub tile: Option<usize>,
    pub order: LoopOrder,
}

impl GemmParams {
    pub fn new(m: usize, k: usize, n: usize) -> Self {
        GemmParams {
            m,
            k,
            n,
            tile: None,
            order: LoopOrder::Ijk,
        }
    }

    pub fn with_tile(mut self, t: usize) -> Self {
        self.tile = Some(t);
        self
    }

    pub fn with_order(mut self, o: LoopOrder) -> Self {
        self.order = o;
        self
    }

    pub fn macs(&self) -> u64 {
        (self.m * self.k * self.n) as u64
    }
}

/// Row-major operand placement in the data memory.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct GemmLayout {
    pub a_base: u64,
    pub b_base: u64,
    pub c_base: u64,
}

impl GemmLayout {
    pub fn at(base: u64, p: &GemmParams) -> Self {
        Self::regions(base, p.m * p.k, p.k * p.n)
    }

    /// A layout from explicit region sizes (f32 words): A at `base`, B
    /// after it, C after B.  [`Self::at`] is the GeMM-shaped special case;
    /// the row-wise transformer operators size their regions directly
    /// (`Operator::layout_at`).
    pub fn regions(base: u64, a_words: usize, b_words: usize) -> Self {
        let a_bytes = (a_words * 4) as u64;
        let b_bytes = (b_words * 4) as u64;
        GemmLayout {
            a_base: base,
            b_base: base + a_bytes,
            c_base: base + a_bytes + b_bytes,
        }
    }

    pub fn a(&self, p: &GemmParams, i: usize, kk: usize) -> u64 {
        self.a_base + ((i * p.k + kk) * 4) as u64
    }

    pub fn b(&self, p: &GemmParams, kk: usize, j: usize) -> u64 {
        self.b_base + ((kk * p.n + j) * 4) as u64
    }

    pub fn c(&self, p: &GemmParams, i: usize, j: usize) -> u64 {
        self.c_base + ((i * p.n + j) * 4) as u64
    }

    /// Write A and B into a functional memory image.
    pub fn load_inputs(&self, p: &GemmParams, mem: &mut MemImage, a: &[f32], b: &[f32]) {
        assert_eq!(a.len(), p.m * p.k);
        assert_eq!(b.len(), p.k * p.n);
        mem.load_f32(self.a_base, a);
        mem.load_f32(self.b_base, b);
    }

    /// Read C back.
    pub fn read_c(&self, p: &GemmParams, mem: &MemImage) -> Vec<f32> {
        mem.dump_f32(self.c_base, p.m * p.n)
    }
}

/// Reference result (row-major f32).
pub fn gemm_ref(p: &GemmParams, a: &[f32], b: &[f32]) -> Vec<f32> {
    let mut c = vec![0.0f32; p.m * p.n];
    for i in 0..p.m {
        for kk in 0..p.k {
            let av = a[i * p.k + kk];
            for j in 0..p.n {
                c[i * p.n + j] += av * b[kk * p.n + j];
            }
        }
    }
    c
}

fn oma_regs(ag: &Ag) -> Option<(RegId, RegId, RegId)> {
    Some((ag.reg_id("r6")?, ag.reg_id("r7")?, ag.reg_id("r8")?))
}

/// The UMA interface function for the OMA: generate the tiled-GeMM
/// instruction list (§5's `oma_tiled_gemm(...)`).
pub fn oma_tiled_gemm(machine: &OmaMachine, p: &GemmParams) -> Result<Program, AsmError> {
    let layout = GemmLayout::at(machine.dmem_base(), p);
    let ag = &machine.ag;
    let (r6, r7, r8) = oma_regs(ag).expect("OMA register file has r6/r7/r8");
    let t = p.tile.unwrap_or(p.m.max(p.k).max(p.n));
    let tiles = |dim: usize| dim.div_ceil(t);

    let mut out: Vec<Instruction> = Vec::new();
    let load = |addr: u64, dst: RegId| {
        Instruction::new(Opcode::Load)
            .with_read_addrs(vec![AddrRef::Direct(addr)])
            .with_writes(vec![dst])
    };
    let store = |src: RegId, addr: u64| {
        Instruction::new(Opcode::Store)
            .with_reads(vec![src])
            .with_write_addrs(vec![AddrRef::Direct(addr)])
    };
    let mac = || {
        Instruction::new(Opcode::Mac)
            .with_reads(vec![r6, r7, r8])
            .with_writes(vec![r8])
    };

    // Iterate tile triples then in-tile triples, both in `order`.
    let order_iter = |o: LoopOrder, ni: usize, nj: usize, nk: usize| -> Vec<(usize, usize, usize)> {
        let mut v = Vec::with_capacity(ni * nj * nk);
        let (d0, d1, d2) = match o {
            LoopOrder::Ijk => (ni, nj, nk),
            LoopOrder::Ikj => (ni, nk, nj),
            LoopOrder::Jik => (nj, ni, nk),
            LoopOrder::Jki => (nj, nk, ni),
            LoopOrder::Kij => (nk, ni, nj),
            LoopOrder::Kji => (nk, nj, ni),
        };
        for x0 in 0..d0 {
            for x1 in 0..d1 {
                for x2 in 0..d2 {
                    let (i, j, kk) = match o {
                        LoopOrder::Ijk => (x0, x1, x2),
                        LoopOrder::Ikj => (x0, x2, x1),
                        LoopOrder::Jik => (x1, x0, x2),
                        LoopOrder::Jki => (x2, x0, x1),
                        LoopOrder::Kij => (x1, x2, x0),
                        LoopOrder::Kji => (x2, x1, x0),
                    };
                    v.push((i, j, kk));
                }
            }
        }
        v
    };

    if p.order.k_innermost() && tiles(p.k) == 1 {
        // Register accumulation: for each (i, j) in order, run the whole k
        // reduction in r8 then store once (Listing 5's structure).
        for (ti, tj, _) in order_iter(p.order, tiles(p.m), tiles(p.n), 1) {
            for (ii, jj, _) in order_iter(p.order, t.min(p.m - ti * t), t.min(p.n - tj * t), 1)
            {
                let (i, j) = (ti * t + ii, tj * t + jj);
                out.push(
                    Instruction::new(Opcode::Movi)
                        .with_imms(vec![0])
                        .with_writes(vec![r8]),
                );
                for kk in 0..p.k {
                    out.push(load(layout.a(p, i, kk), r6));
                    out.push(load(layout.b(p, kk, j), r7));
                    out.push(mac());
                }
                out.push(store(r8, layout.c(p, i, j)));
            }
        }
    } else {
        // General order: C is read-modify-written per MAC step.
        for (ti, tj, tk) in order_iter(p.order, tiles(p.m), tiles(p.n), tiles(p.k)) {
            let (mi, mj, mk) = (
                t.min(p.m - ti * t),
                t.min(p.n - tj * t),
                t.min(p.k - tk * t),
            );
            for (ii, jj, kk) in order_iter(p.order, mi, mj, mk) {
                let (i, j, k2) = (ti * t + ii, tj * t + jj, tk * t + kk);
                out.push(load(layout.c(p, i, j), r8));
                out.push(load(layout.a(p, i, k2), r6));
                out.push(load(layout.b(p, k2, j), r7));
                out.push(mac());
                out.push(store(r8, layout.c(p, i, j)));
            }
        }
    }
    out.push(Instruction::new(Opcode::Halt));
    Ok(Program::new(out, machine.cfg.imem_range.0))
}

/// The literal Listing-5-style register-loop GeMM: pointer-walking inner
/// loop, countdown branches, `z0` comparisons — assembled from asm text.
pub fn oma_gemm_listing5(machine: &OmaMachine, p: &GemmParams) -> Result<Program, AsmError> {
    let layout = GemmLayout::at(machine.dmem_base(), p);
    let (m, k, n) = (p.m, p.k, p.n);
    let (a, b, c) = (layout.a_base, layout.b_base, layout.c_base);
    let src = format!(
        "; C[{m}x{n}] = A[{m}x{k}] . B[{k}x{n}] — Listing 5 structure\n\
         movi #{a} => r12      ; A row base\n\
         movi #{b} => r13      ; B column base\n\
         movi #{c} => r11      ; C pointer\n\
         movi #{m} => r0       ; i countdown\n\
         iloop: movi #{n} => r1 ; j countdown\n\
         jloop: movi #{k} => r2 ; k countdown\n\
         mov z0 => r8          ; acc = 0\n\
         mov r12 => r9         ; a element ptr\n\
         mov r13 => r10        ; b element ptr\n\
         kloop: load [r9] => r6\n\
         load [r10] => r7\n\
         mac r6, r7 => r8\n\
         addi r9, #4 => r9\n\
         addi r10, #{bstride} => r10\n\
         addi r2, #-1 => r2\n\
         bnei r2, z0, @kloop => pc\n\
         store r8 => [r11]\n\
         addi r11, #4 => r11\n\
         addi r13, #4 => r13   ; next B column\n\
         addi r1, #-1 => r1\n\
         bnei r1, z0, @jloop => pc\n\
         addi r12, #{astride} => r12 ; next A row\n\
         movi #{b} => r13      ; reset B column base\n\
         addi r0, #-1 => r0\n\
         bnei r0, z0, @iloop => pc\n\
         halt\n",
        bstride = n * 4,
        astride = k * 4,
    );
    assemble(&machine.ag, &src, machine.cfg.imem_range.0)
}

/// Registry entry for [`oma_tiled_gemm`]: the parameterizable tiled-GeMM
/// generator, the OMA's preferred (first-registered) GeMM mapping.
pub struct OmaTiledGemmMapper;

impl Mapper for OmaTiledGemmMapper {
    fn name(&self) -> &'static str {
        "oma_tiled_gemm"
    }

    fn supports(&self, _reg: &Registry, machine: &Machine, op: &Operator) -> bool {
        matches!(machine, Machine::Oma(_)) && matches!(op, Operator::Gemm(_))
    }

    fn lower(
        &self,
        _reg: &Registry,
        machine: &Machine,
        op: &Operator,
    ) -> Result<Lowered, UmaError> {
        let (Machine::Oma(m), Operator::Gemm(p)) = (machine, op) else {
            return Err(UmaError::Unsupported(machine.name(), *op));
        };
        Ok(Lowered::new(oma_tiled_gemm(m, p)?, machine, op))
    }

    fn cost_hints(&self, _reg: &Registry, _machine: &Machine, op: &Operator) -> CostHints {
        let Some(p) = op.gemm_params() else {
            return CostHints::default();
        };
        let est = if p.order.k_innermost() && p.tile.map_or(true, |t| t >= p.k) {
            // movi + k·(load, load, mac) + store per output element.
            (p.m * p.n * (3 * p.k + 2) + 1) as u64
        } else {
            // load C, load A, load B, mac, store C per MAC step.
            5 * p.macs() + 1
        };
        CostHints {
            min_cycles: Roofline::oma().gemm_cycles(p),
            est_instructions: est,
        }
    }
}

/// Registry entry for [`oma_gemm_listing5`]: the literal register-loop
/// program.  Shadowed by the unrolled generator in dispatch order, so it
/// is reached via `Registry::lower_with("oma_gemm_listing5", ..)`.
pub struct OmaListing5Mapper;

impl Mapper for OmaListing5Mapper {
    fn name(&self) -> &'static str {
        "oma_gemm_listing5"
    }

    fn supports(&self, _reg: &Registry, machine: &Machine, op: &Operator) -> bool {
        // The loop program hard-codes the ijk untiled traversal.
        matches!(machine, Machine::Oma(_))
            && matches!(
                op,
                Operator::Gemm(p) if p.tile.is_none() && p.order == LoopOrder::Ijk
            )
    }

    fn lower(
        &self,
        _reg: &Registry,
        machine: &Machine,
        op: &Operator,
    ) -> Result<Lowered, UmaError> {
        let (Machine::Oma(m), Operator::Gemm(p)) = (machine, op) else {
            return Err(UmaError::Unsupported(machine.name(), *op));
        };
        Ok(Lowered::new(oma_gemm_listing5(m, p)?, machine, op))
    }

    fn cost_hints(&self, _reg: &Registry, _machine: &Machine, op: &Operator) -> CostHints {
        let Some(p) = op.gemm_params() else {
            return CostHints::default();
        };
        CostHints {
            min_cycles: Roofline::oma().gemm_cycles(p),
            // Static size of the Listing-5 program (loops, not unrolled).
            est_instructions: 24,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::oma::OmaConfig;
    use crate::sim::functional::FunctionalSim;

    fn inputs(p: &GemmParams, seed: u64) -> (Vec<f32>, Vec<f32>) {
        // Small deterministic pseudo-random values.
        let mut s = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = move || {
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            ((s % 17) as f32 - 8.0) / 4.0
        };
        let a: Vec<f32> = (0..p.m * p.k).map(|_| next()).collect();
        let b: Vec<f32> = (0..p.k * p.n).map(|_| next()).collect();
        (a, b)
    }

    fn check_functional(p: GemmParams, program_of: impl Fn(&OmaMachine) -> Program) {
        let m = OmaConfig::default().build().unwrap();
        let prog = program_of(&m);
        let layout = GemmLayout::at(m.dmem_base(), &p);
        let (a, b) = inputs(&p, 7);
        let mut sim = FunctionalSim::new(&m.ag);
        layout.load_inputs(&p, &mut sim.mem, &a, &b);
        sim.run(&prog, 50_000_000).unwrap();
        let got = layout.read_c(&p, &sim.mem);
        let want = gemm_ref(&p, &a, &b);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-3, "got {g}, want {w} ({p:?})");
        }
    }

    #[test]
    fn unrolled_all_orders_correct() {
        for order in LoopOrder::ALL {
            let p = GemmParams::new(4, 5, 3).with_order(order);
            check_functional(p, |m| oma_tiled_gemm(m, &p).unwrap());
        }
    }

    #[test]
    fn tiling_preserves_result() {
        for tile in [1, 2, 4, 8] {
            let p = GemmParams::new(8, 8, 8)
                .with_tile(tile)
                .with_order(LoopOrder::Kij);
            check_functional(p, |m| oma_tiled_gemm(m, &p).unwrap());
        }
    }

    #[test]
    fn non_divisible_tiles_correct() {
        let p = GemmParams::new(7, 5, 6)
            .with_tile(4)
            .with_order(LoopOrder::Ijk);
        check_functional(p, |m| oma_tiled_gemm(m, &p).unwrap());
    }

    #[test]
    fn listing5_loop_version_correct() {
        let p = GemmParams::new(4, 4, 4);
        check_functional(p, |m| oma_gemm_listing5(m, &p).unwrap());
    }

    #[test]
    fn k_innermost_uses_register_accumulator() {
        let m = OmaConfig::default().build().unwrap();
        let p_reg = GemmParams::new(4, 4, 4).with_order(LoopOrder::Ijk);
        let p_mem = GemmParams::new(4, 4, 4).with_order(LoopOrder::Kij);
        let n_reg = oma_tiled_gemm(&m, &p_reg).unwrap().len();
        let n_mem = oma_tiled_gemm(&m, &p_mem).unwrap().len();
        assert!(
            n_reg < n_mem,
            "register accumulation saves instructions: {n_reg} vs {n_mem}"
        );
    }

    #[test]
    fn ref_gemm_identity() {
        let p = GemmParams::new(3, 3, 3);
        let a = vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0, 7.0, 8.0, 9.0];
        let id = vec![1.0, 0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0, 1.0];
        assert_eq!(gemm_ref(&p, &a, &id), a);
    }
}
