//! The UMA-style operator registry (§5): a uniform seam between DNN
//! operators and accelerator targets, mirroring TVM's Universal Modular
//! Accelerator interface — *"accelerator architectures can be easily
//! integrated ... by registering an interface function which implements a
//! DNN operator such as GeMM"*.
//!
//! [`lower`] dispatches an [`Operator`] to the target machine's registered
//! generator and returns the ACADL program plus the memory layout the
//! caller uses to place inputs and read results.

use thiserror::Error;

use crate::acadl_core::graph::Ag;
use crate::arch::gamma::{GammaConfig, GammaMachine};
use crate::arch::oma::{OmaConfig, OmaMachine};
use crate::arch::systolic::{SystolicConfig, SystolicMachine};
use crate::isa::program::Program;
use crate::mapping::gamma_gemm::{gamma_gemm, GammaGemmOpts};
use crate::mapping::gemm::{oma_tiled_gemm, GemmLayout, GemmParams};
use crate::mapping::systolic_gemm::systolic_gemm;

/// A built accelerator, uniformly accessible.
#[derive(Debug, Clone)]
pub enum Machine {
    Oma(OmaMachine),
    Systolic(SystolicMachine),
    Gamma(GammaMachine),
}

impl Machine {
    pub fn ag(&self) -> &Ag {
        match self {
            Machine::Oma(m) => &m.ag,
            Machine::Systolic(m) => &m.ag,
            Machine::Gamma(m) => &m.ag,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Machine::Oma(_) => "oma",
            Machine::Systolic(_) => "systolic",
            Machine::Gamma(_) => "gamma",
        }
    }

    /// Base address of the data region operators are laid out in.
    pub fn data_base(&self) -> u64 {
        match self {
            Machine::Oma(m) => m.dmem_base(),
            Machine::Systolic(m) => m.dmem_base(),
            Machine::Gamma(m) => m.dram_base(),
        }
    }
}

/// Target configuration (serializable — the coordinator's job descriptor).
#[derive(Debug, Clone)]
pub enum TargetConfig {
    Oma(OmaConfig),
    Systolic(SystolicConfig),
    Gamma(GammaConfig),
}

impl TargetConfig {
    pub fn build(&self) -> Result<Machine, crate::acadl_core::graph::AgError> {
        Ok(match self {
            TargetConfig::Oma(c) => Machine::Oma(c.build()?),
            TargetConfig::Systolic(c) => Machine::Systolic(c.build()?),
            TargetConfig::Gamma(c) => Machine::Gamma(c.build()?),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TargetConfig::Oma(_) => "oma",
            TargetConfig::Systolic(_) => "systolic",
            TargetConfig::Gamma(_) => "gamma",
        }
    }
}

/// A DNN operator instance to lower.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Operator {
    /// Plain GeMM.
    Gemm(GemmParams),
    /// GeMM + bias + optional ReLU (a dense/linear layer).
    Dense {
        gemm: GemmParams,
        bias_base: u64,
        relu: bool,
    },
}

impl Operator {
    pub fn gemm_params(&self) -> &GemmParams {
        match self {
            Operator::Gemm(p) => p,
            Operator::Dense { gemm, .. } => gemm,
        }
    }
}

/// A lowered operator: the program plus its operand layout.
#[derive(Debug, Clone)]
pub struct Lowered {
    pub program: Program,
    pub layout: GemmLayout,
}

#[derive(Debug, Error)]
pub enum UmaError {
    #[error("target `{0}` does not implement operator {1:?} (fused bias/activation is fused-tensor level)")]
    Unsupported(&'static str, Operator),
    #[error(transparent)]
    Asm(#[from] crate::isa::assembler::AsmError),
}

/// The registry dispatch: lower `op` onto `machine`.
pub fn lower(machine: &Machine, op: &Operator) -> Result<Lowered, UmaError> {
    let p = op.gemm_params();
    let layout = GemmLayout::at(machine.data_base(), p);
    let program = match (machine, op) {
        (Machine::Oma(m), Operator::Gemm(p)) => oma_tiled_gemm(m, p)?,
        (Machine::Systolic(m), Operator::Gemm(p)) => systolic_gemm(m, p),
        (Machine::Gamma(m), Operator::Gemm(p)) => {
            gamma_gemm(m, p, GammaGemmOpts::default())
        }
        (
            Machine::Gamma(m),
            Operator::Dense {
                gemm,
                bias_base,
                relu,
            },
        ) => gamma_gemm(
            m,
            gemm,
            GammaGemmOpts {
                relu: *relu,
                bias_base: Some(*bias_base),
                ..Default::default()
            },
        ),
        (m, op @ Operator::Dense { .. }) => {
            return Err(UmaError::Unsupported(m.name(), *op))
        }
    };
    Ok(Lowered { program, layout })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::functional::FunctionalSim;

    #[test]
    fn all_targets_lower_gemm() {
        let p = GemmParams::new(8, 8, 8);
        let targets = [
            TargetConfig::Oma(OmaConfig::default()),
            TargetConfig::Systolic(SystolicConfig::new(4, 4)),
            TargetConfig::Gamma(GammaConfig::new(1)),
        ];
        for t in targets {
            let m = t.build().unwrap();
            let lowered = lower(&m, &Operator::Gemm(p)).unwrap();
            assert!(!lowered.program.is_empty(), "{}", m.name());
        }
    }

    #[test]
    fn dense_only_on_gamma() {
        let p = GemmParams::new(8, 8, 8);
        let dense = Operator::Dense {
            gemm: p,
            bias_base: 0x2000_0000,
            relu: true,
        };
        let oma = TargetConfig::Oma(OmaConfig::default()).build().unwrap();
        assert!(matches!(
            lower(&oma, &dense),
            Err(UmaError::Unsupported("oma", _))
        ));
        let gamma = TargetConfig::Gamma(GammaConfig::new(1)).build().unwrap();
        assert!(lower(&gamma, &dense).is_ok());
    }

    #[test]
    fn lowered_programs_agree_across_targets() {
        // Same operator, three targets, identical results: the registry's
        // core correctness property.
        let p = GemmParams::new(8, 8, 8);
        let a: Vec<f32> = (0..64).map(|x| (x % 5) as f32 - 2.0).collect();
        let b: Vec<f32> = (0..64).map(|x| (x % 3) as f32 - 1.0).collect();
        let mut results = Vec::new();
        for t in [
            TargetConfig::Oma(OmaConfig::default()),
            TargetConfig::Systolic(SystolicConfig::new(4, 4)),
            TargetConfig::Gamma(GammaConfig::new(2)),
        ] {
            let m = t.build().unwrap();
            let lw = lower(&m, &Operator::Gemm(p)).unwrap();
            let mut sim = FunctionalSim::new(m.ag());
            lw.layout.load_inputs(&p, &mut sim.mem, &a, &b);
            sim.run(&lw.program, 50_000_000).unwrap();
            results.push(lw.layout.read_c(&p, &sim.mem));
        }
        assert_eq!(results[0], results[1]);
        assert_eq!(results[0], results[2]);
    }
}
