//! The UMA-style operator registry (§5): a uniform seam between DNN
//! operators and accelerator targets, mirroring TVM's Universal Modular
//! Accelerator interface — *"accelerator architectures can be easily
//! integrated ... by registering an interface function which implements a
//! DNN operator such as GeMM"*.
//!
//! Since the `Mapper`-trait refactor this module owns the **registry**
//! only; the code generators themselves live in their modules and
//! implement [`Mapper`](crate::mapping::mapper::Mapper):
//!
//! * [`OmaTiledGemmMapper`](crate::mapping::gemm::OmaTiledGemmMapper)
//! * [`OmaListing5Mapper`](crate::mapping::gemm::OmaListing5Mapper)
//! * [`SystolicWavefrontMapper`](crate::mapping::systolic_gemm::SystolicWavefrontMapper)
//! * [`GammaFusedTensorMapper`](crate::mapping::gamma_gemm::GammaFusedTensorMapper)
//! * [`Im2colConvMapper`](crate::mapping::conv::Im2colConvMapper)
//! * [`ScalarRowwiseMapper`](crate::mapping::rowwise::ScalarRowwiseMapper)
//!
//! [`lower`] dispatches an [`Operator`] to the first registered mapper
//! that supports the (machine, operator) pair and returns the ACADL
//! program plus the memory layout the caller uses to place inputs and
//! read results; [`cost_hints`] returns the same mapper's analytical
//! estimates without generating anything — the DSE pre-filter's probe.

use std::sync::OnceLock;

use thiserror::Error;

use crate::acadl_core::graph::Ag;
use crate::arch::gamma::{GammaConfig, GammaMachine};
use crate::arch::oma::{OmaConfig, OmaMachine};
use crate::arch::systolic::{SystolicConfig, SystolicMachine};
use crate::isa::program::Program;
use crate::mapping::conv::{Conv2d, Im2colConvMapper};
use crate::mapping::gamma_gemm::GammaFusedTensorMapper;
use crate::mapping::gemm::{GemmLayout, GemmParams, OmaListing5Mapper, OmaTiledGemmMapper};
use crate::mapping::mapper::{CostHints, Mapper};
use crate::mapping::rowwise::ScalarRowwiseMapper;
use crate::mapping::systolic_gemm::SystolicWavefrontMapper;

/// A built accelerator, uniformly accessible.
#[derive(Debug, Clone)]
pub enum Machine {
    Oma(OmaMachine),
    Systolic(SystolicMachine),
    Gamma(GammaMachine),
}

impl Machine {
    pub fn ag(&self) -> &Ag {
        match self {
            Machine::Oma(m) => &m.ag,
            Machine::Systolic(m) => &m.ag,
            Machine::Gamma(m) => &m.ag,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Machine::Oma(_) => "oma",
            Machine::Systolic(_) => "systolic",
            Machine::Gamma(_) => "gamma",
        }
    }

    /// Base address of the data region operators are laid out in.
    pub fn data_base(&self) -> u64 {
        match self {
            Machine::Oma(m) => m.dmem_base(),
            Machine::Systolic(m) => m.dmem_base(),
            Machine::Gamma(m) => m.dram_base(),
        }
    }
}

/// Target configuration (serializable — the coordinator's job descriptor).
#[derive(Debug, Clone)]
pub enum TargetConfig {
    Oma(OmaConfig),
    Systolic(SystolicConfig),
    Gamma(GammaConfig),
}

impl TargetConfig {
    pub fn build(&self) -> Result<Machine, crate::acadl_core::graph::AgError> {
        Ok(match self {
            TargetConfig::Oma(c) => Machine::Oma(c.build()?),
            TargetConfig::Systolic(c) => Machine::Systolic(c.build()?),
            TargetConfig::Gamma(c) => Machine::Gamma(c.build()?),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            TargetConfig::Oma(_) => "oma",
            TargetConfig::Systolic(_) => "systolic",
            TargetConfig::Gamma(_) => "gamma",
        }
    }
}

/// A DNN operator instance to lower.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Operator {
    /// Plain GeMM.
    Gemm(GemmParams),
    /// GeMM + bias + optional ReLU (a dense/linear layer).
    Dense {
        gemm: GemmParams,
        bias_base: u64,
        relu: bool,
    },
    /// 2-D convolution lowered im2col → GeMM.  `gemm` is the (possibly
    /// target-padded) patch-matrix GeMM the convolution reduces to; the
    /// host performs the im2col data transform before loading inputs
    /// (TVM's layout-transform glue).
    Conv2d { conv: Conv2d, gemm: GemmParams },
    /// Row-wise numerically stable softmax over a `rows × cols` matrix
    /// (max-reduce, exp, sum-reduce, normalize — the attention-score
    /// operator).
    Softmax { rows: usize, cols: usize },
    /// Row-wise (non-affine) layer normalization with epsilon:
    /// `(x − mean) / sqrt(var + eps)`.  The epsilon word travels in the
    /// operand layout's B region (one f32 at `b_base`).
    LayerNorm { rows: usize, cols: usize, eps: f32 },
    /// Element-wise GELU activation (tanh approximation).
    Gelu { rows: usize, cols: usize },
    /// Element-wise matrix addition `C = A + B` (residual connections);
    /// both operands are `rows × cols`.
    AddMat { rows: usize, cols: usize },
    /// Matrix transpose: `rows × cols` in the A region becomes
    /// `cols × rows` in the C region (attention's `K^T` data movement).
    Transpose { rows: usize, cols: usize },
}

impl Operator {
    /// The GeMM view of a GeMM-backed operator (`None` for the row-wise
    /// transformer operators, which have no `m × k · k × n` structure).
    pub fn gemm_params(&self) -> Option<&GemmParams> {
        match self {
            Operator::Gemm(p) => Some(p),
            Operator::Dense { gemm, .. } => Some(gemm),
            Operator::Conv2d { gemm, .. } => Some(gemm),
            _ => None,
        }
    }

    /// `(rows, cols)` of the primary input operand (the A region).
    pub fn a_dims(&self) -> (usize, usize) {
        match *self {
            Operator::Gemm(p) => (p.m, p.k),
            Operator::Dense { gemm, .. } => (gemm.m, gemm.k),
            Operator::Conv2d { gemm, .. } => (gemm.m, gemm.k),
            Operator::Softmax { rows, cols }
            | Operator::LayerNorm { rows, cols, .. }
            | Operator::Gelu { rows, cols }
            | Operator::AddMat { rows, cols }
            | Operator::Transpose { rows, cols } => (rows, cols),
        }
    }

    /// f32 words of the A (primary input) operand region.
    pub fn a_words(&self) -> usize {
        let (r, c) = self.a_dims();
        r * c
    }

    /// f32 words of the B (secondary operand) region: the `k × n` matrix
    /// for GeMM-backed operators, the second addend for [`Self::AddMat`],
    /// one epsilon word for [`Self::LayerNorm`], nothing otherwise.
    pub fn b_words(&self) -> usize {
        match *self {
            Operator::Gemm(p) => p.k * p.n,
            Operator::Dense { gemm, .. } | Operator::Conv2d { gemm, .. } => gemm.k * gemm.n,
            Operator::AddMat { rows, cols } => rows * cols,
            Operator::LayerNorm { .. } => 1,
            _ => 0,
        }
    }

    /// f32 words of the C (output) region.
    pub fn c_words(&self) -> usize {
        match *self {
            Operator::Gemm(p) => p.m * p.n,
            Operator::Dense { gemm, .. } | Operator::Conv2d { gemm, .. } => gemm.m * gemm.n,
            Operator::Softmax { rows, cols }
            | Operator::LayerNorm { rows, cols, .. }
            | Operator::Gelu { rows, cols }
            | Operator::AddMat { rows, cols }
            | Operator::Transpose { rows, cols } => rows * cols,
        }
    }

    /// The operand layout for this operator at `base`: A, then B, then C,
    /// each region sized by the operator ([`GemmLayout::at`] semantics
    /// for GeMM-backed operators — existing layouts are unchanged).
    pub fn layout_at(&self, base: u64) -> GemmLayout {
        GemmLayout::regions(base, self.a_words(), self.b_words())
    }
}

/// A lowered operator: the program plus its operand layout.
#[derive(Debug, Clone)]
pub struct Lowered {
    pub program: Program,
    pub layout: GemmLayout,
}

impl Lowered {
    /// The uniform (program, layout) pair every mapper returns.
    pub fn new(program: Program, machine: &Machine, op: &Operator) -> Self {
        Lowered {
            program,
            layout: op.layout_at(machine.data_base()),
        }
    }
}

#[derive(Debug, Error)]
pub enum UmaError {
    #[error("target `{0}` does not implement operator {1:?} (fused bias/activation is fused-tensor level)")]
    Unsupported(&'static str, Operator),
    #[error("no mapper named `{0}` is registered")]
    UnknownMapper(String),
    #[error(transparent)]
    Asm(#[from] crate::isa::assembler::AsmError),
}

/// The mapper registry: an ordered list of [`Mapper`] implementations.
/// Dispatch picks the first mapper whose `supports` accepts the
/// (machine, operator) pair, so registration order encodes preference
/// (e.g. the unrolled OMA GeMM shadows the Listing-5 register-loop
/// variant, which stays reachable by name).
pub struct Registry {
    mappers: Vec<Box<dyn Mapper>>,
}

impl Registry {
    /// An empty registry (tests; custom tool stacks).
    pub fn empty() -> Self {
        Registry {
            mappers: Vec::new(),
        }
    }

    /// The six in-tree code generators, in dispatch-preference order.
    pub fn with_defaults() -> Self {
        let mut r = Registry::empty();
        r.register(Box::new(OmaTiledGemmMapper));
        r.register(Box::new(SystolicWavefrontMapper));
        r.register(Box::new(GammaFusedTensorMapper));
        r.register(Box::new(Im2colConvMapper));
        r.register(Box::new(ScalarRowwiseMapper));
        r.register(Box::new(OmaListing5Mapper));
        r
    }

    /// The process-wide default registry (what [`lower`] dispatches
    /// through).
    pub fn global() -> &'static Registry {
        static GLOBAL: OnceLock<Registry> = OnceLock::new();
        GLOBAL.get_or_init(Registry::with_defaults)
    }

    pub fn register(&mut self, mapper: Box<dyn Mapper>) {
        self.mappers.push(mapper);
    }

    /// Registered mapper names, in dispatch order.
    pub fn names(&self) -> Vec<&'static str> {
        self.mappers.iter().map(|m| m.name()).collect()
    }

    /// First registered mapper supporting the pair.
    pub fn mapper_for(&self, machine: &Machine, op: &Operator) -> Option<&dyn Mapper> {
        self.mappers
            .iter()
            .map(|m| m.as_ref())
            .find(|m| m.supports(self, machine, op))
    }

    /// Dispatch: lower `op` onto `machine` through the first supporting
    /// mapper.
    pub fn lower(&self, machine: &Machine, op: &Operator) -> Result<Lowered, UmaError> {
        match self.mapper_for(machine, op) {
            Some(m) => m.lower(self, machine, op),
            None => Err(UmaError::Unsupported(machine.name(), *op)),
        }
    }

    /// Lower through a specific mapper by registry name (ignores
    /// dispatch preference but still checks `supports`).
    pub fn lower_with(
        &self,
        name: &str,
        machine: &Machine,
        op: &Operator,
    ) -> Result<Lowered, UmaError> {
        let m = self
            .mappers
            .iter()
            .find(|m| m.name() == name)
            .ok_or_else(|| UmaError::UnknownMapper(name.to_string()))?;
        if !m.supports(self, machine, op) {
            return Err(UmaError::Unsupported(machine.name(), *op));
        }
        m.lower(self, machine, op)
    }

    /// Analytical cost hints for the pair, from the mapper dispatch would
    /// pick — no program is generated.
    pub fn cost_hints(&self, machine: &Machine, op: &Operator) -> Result<CostHints, UmaError> {
        match self.mapper_for(machine, op) {
            Some(m) => Ok(m.cost_hints(self, machine, op)),
            None => Err(UmaError::Unsupported(machine.name(), *op)),
        }
    }
}

/// The registry dispatch: lower `op` onto `machine` through the global
/// default registry (the seam every consumer calls).
pub fn lower(machine: &Machine, op: &Operator) -> Result<Lowered, UmaError> {
    Registry::global().lower(machine, op)
}

/// Analytical cost hints through the global registry.
pub fn cost_hints(machine: &Machine, op: &Operator) -> Result<CostHints, UmaError> {
    Registry::global().cost_hints(machine, op)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::functional::FunctionalSim;

    #[test]
    fn all_targets_lower_gemm() {
        let p = GemmParams::new(8, 8, 8);
        let targets = [
            TargetConfig::Oma(OmaConfig::default()),
            TargetConfig::Systolic(SystolicConfig::new(4, 4)),
            TargetConfig::Gamma(GammaConfig::new(1)),
        ];
        for t in targets {
            let m = t.build().unwrap();
            let lowered = lower(&m, &Operator::Gemm(p)).unwrap();
            assert!(!lowered.program.is_empty(), "{}", m.name());
        }
    }

    #[test]
    fn dense_only_on_gamma() {
        let p = GemmParams::new(8, 8, 8);
        let dense = Operator::Dense {
            gemm: p,
            bias_base: 0x2000_0000,
            relu: true,
        };
        let oma = TargetConfig::Oma(OmaConfig::default()).build().unwrap();
        assert!(matches!(
            lower(&oma, &dense),
            Err(UmaError::Unsupported("oma", _))
        ));
        let gamma = TargetConfig::Gamma(GammaConfig::new(1)).build().unwrap();
        assert!(lower(&gamma, &dense).is_ok());
    }

    #[test]
    fn registry_lists_all_six_generators() {
        let names = Registry::global().names();
        for expect in [
            "oma_tiled_gemm",
            "systolic_wavefront_gemm",
            "gamma_fused_gemm",
            "im2col_conv",
            "scalar_rowwise",
            "oma_gemm_listing5",
        ] {
            assert!(names.contains(&expect), "missing mapper `{expect}` in {names:?}");
        }
        assert_eq!(names.len(), 6);
    }

    #[test]
    fn lower_with_reaches_shadowed_mapper() {
        let p = GemmParams::new(4, 4, 4);
        let oma = TargetConfig::Oma(OmaConfig::default()).build().unwrap();
        let reg = Registry::global();
        // Dispatch preference picks the unrolled generator…
        let dispatched = reg.lower(&oma, &Operator::Gemm(p)).unwrap();
        // …while the Listing-5 register-loop variant stays reachable by
        // name and produces a (much shorter) branchy program.
        let listing5 = reg
            .lower_with("oma_gemm_listing5", &oma, &Operator::Gemm(p))
            .unwrap();
        assert!(listing5.program.len() < dispatched.program.len());
        assert!(matches!(
            reg.lower_with("nope", &oma, &Operator::Gemm(p)),
            Err(UmaError::UnknownMapper(_))
        ));
    }

    #[test]
    fn cost_hints_are_positive_and_ordered() {
        let p = GemmParams::new(16, 16, 16);
        let op = Operator::Gemm(p);
        let oma = TargetConfig::Oma(OmaConfig::default()).build().unwrap();
        let sys = TargetConfig::Systolic(SystolicConfig::new(8, 8))
            .build()
            .unwrap();
        let h_oma = cost_hints(&oma, &op).unwrap();
        let h_sys = cost_hints(&sys, &op).unwrap();
        assert!(h_oma.min_cycles > 0 && h_sys.min_cycles > 0);
        assert!(
            h_oma.min_cycles > h_sys.min_cycles,
            "scalar bound above array bound: {h_oma:?} vs {h_sys:?}"
        );
    }

    #[test]
    fn lowered_programs_agree_across_targets() {
        // Same operator, three targets, identical results: the registry's
        // core correctness property.
        let p = GemmParams::new(8, 8, 8);
        let a: Vec<f32> = (0..64).map(|x| (x % 5) as f32 - 2.0).collect();
        let b: Vec<f32> = (0..64).map(|x| (x % 3) as f32 - 1.0).collect();
        let mut results = Vec::new();
        for t in [
            TargetConfig::Oma(OmaConfig::default()),
            TargetConfig::Systolic(SystolicConfig::new(4, 4)),
            TargetConfig::Gamma(GammaConfig::new(2)),
        ] {
            let m = t.build().unwrap();
            let lw = lower(&m, &Operator::Gemm(p)).unwrap();
            let mut sim = FunctionalSim::new(m.ag());
            lw.layout.load_inputs(&p, &mut sim.mem, &a, &b);
            sim.run(&lw.program, 50_000_000).unwrap();
            results.push(lw.layout.read_c(&p, &sim.mem));
        }
        assert_eq!(results[0], results[1]);
        assert_eq!(results[0], results[2]);
    }
}
