//! Row-wise transformer operators (softmax, layer norm, GELU, residual
//! add, transpose) as **scalar-FU streaming loops**.
//!
//! GeMM-shaped work runs on each target's array / tensor units; the
//! reductions a transformer interleaves between its GeMMs (attention-row
//! max and Σexp, layer-norm mean/variance) have no `m×k · k×n` structure,
//! so they run on the scalar unit every zoo machine provides: the OMA's
//! own ALU (`fu0`), or the scalar epilogue unit
//! ([`crate::arch::parts::scalar_epilogue`]) of the systolic array and Γ̈.
//!
//! ## Bit-exactness contract
//!
//! Each generated program performs **exactly the f32 operations of the
//! matching `*_ref` function here, in the same order** (streaming
//! left-to-right per row), and the instruction semantics call the same
//! [`crate::util::numerics`] helpers.  The cross-layer conformance suite
//! (`tests/transformer_conformance.rs`) therefore asserts *bit equality*
//! between the functional simulation, both timing backends, and the host
//! reference — not a tolerance.
//!
//! ## Operand layout
//!
//! `Operator::layout_at` places the input matrix in the A region and the
//! output in the C region; `AddMat` reads its second operand from the B
//! region, and `LayerNorm` reads its epsilon from a single f32 word at
//! `b_base` (immediates are integers, so an arbitrary f32 constant must
//! travel through memory).

use crate::acadl_core::graph::RegId;
use crate::analytical::Roofline;
use crate::isa::instruction::{AddrRef, Instruction};
use crate::isa::opcode::Opcode;
use crate::isa::program::Program;
use crate::mapping::mapper::{CostHints, Mapper};
use crate::mapping::uma::{Lowered, Machine, Operator, Registry, UmaError};
use crate::util::numerics::{gelu_f32, rsqrt_f32};

// ------------------------------------------------------------- references

/// Row-wise numerically stable softmax (the oracle the generated program
/// reproduces bit-for-bit): per row, streaming max, then `Σ exp(x − max)`
/// accumulated left-to-right, then per-element division.
pub fn softmax_ref(rows: usize, cols: usize, x: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), rows * cols);
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        let row = &x[r * cols..(r + 1) * cols];
        let mut m = row[0];
        for &v in &row[1..] {
            m = m.max(v);
        }
        let mut sum = 0.0f32;
        for (i, &v) in row.iter().enumerate() {
            let e = (v - m).exp();
            out[r * cols + i] = e;
            sum += e;
        }
        for i in 0..cols {
            out[r * cols + i] /= sum;
        }
    }
    out
}

/// Row-wise non-affine layer normalization: `(x − mean) · rsqrt(var + eps)`
/// with mean and (population) variance accumulated left-to-right.
pub fn layernorm_ref(rows: usize, cols: usize, eps: f32, x: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), rows * cols);
    let mut out = vec![0.0f32; rows * cols];
    for r in 0..rows {
        let row = &x[r * cols..(r + 1) * cols];
        let mut sum = 0.0f32;
        for &v in row {
            sum += v;
        }
        let mean = sum / cols as f32;
        let mut q = 0.0f32;
        for &v in row {
            let d = v - mean;
            q += d * d;
        }
        let var = q / cols as f32;
        let inv = rsqrt_f32(var + eps);
        for (i, &v) in row.iter().enumerate() {
            out[r * cols + i] = (v - mean) * inv;
        }
    }
    out
}

/// Element-wise GELU (tanh approximation, shared f32 helper).
pub fn gelu_ref(x: &[f32]) -> Vec<f32> {
    x.iter().map(|&v| gelu_f32(v)).collect()
}

/// Element-wise matrix addition.
pub fn addmat_ref(a: &[f32], b: &[f32]) -> Vec<f32> {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(&x, &y)| x + y).collect()
}

/// Transpose a row-major `rows × cols` matrix into `cols × rows`.
pub fn transpose_ref(rows: usize, cols: usize, x: &[f32]) -> Vec<f32> {
    assert_eq!(x.len(), rows * cols);
    let mut out = vec![0.0f32; rows * cols];
    for i in 0..rows {
        for j in 0..cols {
            out[j * rows + i] = x[i * cols + j];
        }
    }
    out
}

/// Host reference for any row-wise operator (dispatch table for tests and
/// the schedule oracle).  `b` is the second operand (AddMat) and is
/// ignored otherwise.
pub fn rowwise_ref(op: &Operator, a: &[f32], b: &[f32]) -> Option<Vec<f32>> {
    match *op {
        Operator::Softmax { rows, cols } => Some(softmax_ref(rows, cols, a)),
        Operator::LayerNorm { rows, cols, eps } => Some(layernorm_ref(rows, cols, eps, a)),
        Operator::Gelu { .. } => Some(gelu_ref(a)),
        Operator::AddMat { .. } => Some(addmat_ref(a, b)),
        Operator::Transpose { rows, cols } => Some(transpose_ref(rows, cols, a)),
        _ => None,
    }
}

// --------------------------------------------------------------- codegen

/// The three scalar registers the generated loops cycle through.
struct ScalarRegs {
    /// Row statistic (max / mean).
    t0: RegId,
    /// Streaming element scratch.
    t1: RegId,
    /// Running accumulator (sum / variance / divisor).
    acc: RegId,
}

/// The scalar registers of `machine`'s scalar unit, when it has one: the
/// OMA's general registers, or the `s*` file of the epilogue unit.
fn scalar_regs(machine: &Machine) -> Option<ScalarRegs> {
    let ag = machine.ag();
    let names: [&str; 3] = match machine {
        Machine::Oma(_) => ["r4", "r5", "r6"],
        _ => ["s0", "s1", "s2"],
    };
    Some(ScalarRegs {
        t0: ag.reg_id(names[0])?,
        t1: ag.reg_id(names[1])?,
        acc: ag.reg_id(names[2])?,
    })
}

fn imem_base(machine: &Machine) -> u64 {
    match machine {
        Machine::Oma(m) => m.cfg.imem_range.0,
        Machine::Systolic(m) => m.cfg.imem_range.0,
        Machine::Gamma(m) => m.cfg.imem_range.0,
    }
}

fn load(addr: u64, dst: RegId) -> Instruction {
    Instruction::new(Opcode::Load)
        .with_read_addrs(vec![AddrRef::Direct(addr)])
        .with_writes(vec![dst])
}

fn store(src: RegId, addr: u64) -> Instruction {
    Instruction::new(Opcode::Store)
        .with_reads(vec![src])
        .with_write_addrs(vec![AddrRef::Direct(addr)])
}

fn bin(op: Opcode, a: RegId, b: RegId, dst: RegId) -> Instruction {
    Instruction::new(op)
        .with_reads(vec![a, b])
        .with_writes(vec![dst])
}

fn un(op: Opcode, a: RegId, dst: RegId) -> Instruction {
    Instruction::new(op)
        .with_reads(vec![a])
        .with_writes(vec![dst])
}

fn movi(imm: i64, dst: RegId) -> Instruction {
    Instruction::new(Opcode::Movi)
        .with_imms(vec![imm])
        .with_writes(vec![dst])
}

/// Generate the unrolled scalar program for a row-wise operator on
/// `machine`, or `None` when the operator is not row-wise or the machine
/// has no scalar unit.  Direct-addressed and branch-free, like the
/// unrolled OMA GeMM — the full access order stays visible to the memory
/// timing model.
pub fn scalar_rowwise_program(machine: &Machine, op: &Operator) -> Option<Program> {
    let r = scalar_regs(machine)?;
    let layout = op.layout_at(machine.data_base());
    let a = |i: usize| layout.a_base + 4 * i as u64;
    let b = |i: usize| layout.b_base + 4 * i as u64;
    let c = |i: usize| layout.c_base + 4 * i as u64;
    let mut out: Vec<Instruction> = Vec::new();
    match *op {
        Operator::Softmax { rows, cols } => {
            if rows == 0 || cols == 0 {
                return None;
            }
            for row in 0..rows {
                let ar = |i: usize| a(row * cols + i);
                let cr = |i: usize| c(row * cols + i);
                // Pass 1: streaming row max into t0.
                out.push(load(ar(0), r.t0));
                for i in 1..cols {
                    out.push(load(ar(i), r.t1));
                    out.push(bin(Opcode::Max, r.t0, r.t1, r.t0));
                }
                // Pass 2: e_i = exp(x_i − max) staged into C; Σ e_i in acc.
                out.push(movi(0, r.acc));
                for i in 0..cols {
                    out.push(load(ar(i), r.t1));
                    out.push(bin(Opcode::Sub, r.t1, r.t0, r.t1));
                    out.push(un(Opcode::Exp, r.t1, r.t1));
                    out.push(store(r.t1, cr(i)));
                    out.push(bin(Opcode::Add, r.acc, r.t1, r.acc));
                }
                // Pass 3: normalize in place.
                for i in 0..cols {
                    out.push(load(cr(i), r.t1));
                    out.push(bin(Opcode::Div, r.t1, r.acc, r.t1));
                    out.push(store(r.t1, cr(i)));
                }
            }
        }
        Operator::LayerNorm { rows, cols, .. } => {
            if rows == 0 || cols == 0 {
                return None;
            }
            for row in 0..rows {
                let ar = |i: usize| a(row * cols + i);
                let cr = |i: usize| c(row * cols + i);
                // Mean: Σ x / n  (n as an integer immediate; `div`
                // converts it to f32 exactly like the reference).
                out.push(movi(0, r.acc));
                for i in 0..cols {
                    out.push(load(ar(i), r.t1));
                    out.push(bin(Opcode::Add, r.acc, r.t1, r.acc));
                }
                out.push(movi(cols as i64, r.t1));
                out.push(bin(Opcode::Div, r.acc, r.t1, r.t0)); // t0 = mean
                // Variance: Σ (x − mean)² / n.
                out.push(movi(0, r.acc));
                for i in 0..cols {
                    out.push(load(ar(i), r.t1));
                    out.push(bin(Opcode::Sub, r.t1, r.t0, r.t1));
                    out.push(bin(Opcode::Mul, r.t1, r.t1, r.t1));
                    out.push(bin(Opcode::Add, r.acc, r.t1, r.acc));
                }
                out.push(movi(cols as i64, r.t1));
                out.push(bin(Opcode::Div, r.acc, r.t1, r.acc)); // acc = var
                // inv = rsqrt(var + eps); eps travels at b_base.
                out.push(load(layout.b_base, r.t1));
                out.push(bin(Opcode::Add, r.acc, r.t1, r.acc));
                out.push(un(Opcode::Rsqrt, r.acc, r.acc));
                // Normalize.
                for i in 0..cols {
                    out.push(load(ar(i), r.t1));
                    out.push(bin(Opcode::Sub, r.t1, r.t0, r.t1));
                    out.push(bin(Opcode::Mul, r.t1, r.acc, r.t1));
                    out.push(store(r.t1, cr(i)));
                }
            }
        }
        Operator::Gelu { rows, cols } => {
            if rows * cols == 0 {
                return None;
            }
            for i in 0..rows * cols {
                out.push(load(a(i), r.t0));
                out.push(un(Opcode::Gelu, r.t0, r.t0));
                out.push(store(r.t0, c(i)));
            }
        }
        Operator::AddMat { rows, cols } => {
            if rows * cols == 0 {
                return None;
            }
            for i in 0..rows * cols {
                out.push(load(a(i), r.t0));
                out.push(load(b(i), r.t1));
                out.push(bin(Opcode::Add, r.t0, r.t1, r.t0));
                out.push(store(r.t0, c(i)));
            }
        }
        Operator::Transpose { rows, cols } => {
            if rows * cols == 0 {
                return None;
            }
            for i in 0..rows {
                for j in 0..cols {
                    out.push(load(a(i * cols + j), r.t0));
                    out.push(store(r.t0, c(j * rows + i)));
                }
            }
        }
        _ => return None,
    }
    out.push(Instruction::new(Opcode::Halt));
    Some(Program::new(out, imem_base(machine)))
}

/// Static instruction count of [`scalar_rowwise_program`] (without
/// generating it) — the cost-hint estimate.
fn static_len(op: &Operator) -> u64 {
    match *op {
        Operator::Softmax { rows, cols } => {
            (rows * (1 + 2 * (cols - 1) + 1 + 5 * cols + 3 * cols)) as u64 + 1
        }
        Operator::LayerNorm { rows, cols, .. } => {
            // Per row: movi + 2·cols (sum), movi + div (mean), movi +
            // 4·cols (variance terms), movi + div (variance), load + add
            // + rsqrt (epsilon), 4·cols (normalize) — 10·cols + 9.
            (rows * (10 * cols + 9)) as u64 + 1
        }
        Operator::Gelu { rows, cols } => (3 * rows * cols) as u64 + 1,
        Operator::AddMat { rows, cols } => (4 * rows * cols) as u64 + 1,
        Operator::Transpose { rows, cols } => (2 * rows * cols) as u64 + 1,
        _ => 0,
    }
}

fn machine_roofline(machine: &Machine) -> Roofline {
    match machine {
        Machine::Oma(_) => Roofline::oma(),
        Machine::Systolic(m) => Roofline::systolic(m.cfg.rows, m.cfg.cols),
        Machine::Gamma(m) => Roofline::gamma(m.cfg.units),
    }
}

/// Registry entry for the row-wise scalar loops: every machine with a
/// scalar unit (the whole zoo) gets softmax / layer norm / GELU /
/// residual add / transpose through the same generator.
pub struct ScalarRowwiseMapper;

impl Mapper for ScalarRowwiseMapper {
    fn name(&self) -> &'static str {
        "scalar_rowwise"
    }

    fn supports(&self, _reg: &Registry, machine: &Machine, op: &Operator) -> bool {
        let rowwise = matches!(
            op,
            Operator::Softmax { .. }
                | Operator::LayerNorm { .. }
                | Operator::Gelu { .. }
                | Operator::AddMat { .. }
                | Operator::Transpose { .. }
        );
        let (rows, cols) = op.a_dims();
        rowwise && rows > 0 && cols > 0 && scalar_regs(machine).is_some()
    }

    fn lower(
        &self,
        _reg: &Registry,
        machine: &Machine,
        op: &Operator,
    ) -> Result<Lowered, UmaError> {
        match scalar_rowwise_program(machine, op) {
            Some(program) => Ok(Lowered::new(program, machine, op)),
            None => Err(UmaError::Unsupported(machine.name(), *op)),
        }
    }

    fn cost_hints(&self, _reg: &Registry, machine: &Machine, op: &Operator) -> CostHints {
        CostHints {
            min_cycles: machine_roofline(machine).op_cycles(op),
            est_instructions: static_len(op),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::gamma::GammaConfig;
    use crate::arch::oma::OmaConfig;
    use crate::arch::systolic::SystolicConfig;
    use crate::mapping::uma::{self, TargetConfig};
    use crate::sim::functional::FunctionalSim;

    fn zoo() -> Vec<Machine> {
        vec![
            TargetConfig::Oma(OmaConfig::default()).build().unwrap(),
            TargetConfig::Systolic(SystolicConfig::new(2, 2)).build().unwrap(),
            TargetConfig::Gamma(GammaConfig::new(1)).build().unwrap(),
        ]
    }

    fn inputs(n: usize, seed: u64) -> Vec<f32> {
        let mut s = seed | 1;
        (0..n)
            .map(|_| {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                ((s % 401) as f32 - 200.0) / 100.0
            })
            .collect()
    }

    /// Lower `op` on `machine`, run the functional ISS, return the C
    /// region.
    fn run_functional(machine: &Machine, op: &Operator, a: &[f32], b: &[f32]) -> Vec<f32> {
        let lw = uma::lower(machine, op).expect("rowwise op lowers");
        let mut sim = FunctionalSim::new(machine.ag());
        sim.mem.load_f32(lw.layout.a_base, a);
        if !b.is_empty() {
            sim.mem.load_f32(lw.layout.b_base, b);
        }
        sim.run(&lw.program, 50_000_000).unwrap();
        sim.mem.dump_f32(lw.layout.c_base, op.c_words())
    }

    #[test]
    fn softmax_bit_exact_on_all_targets() {
        let (rows, cols) = (3, 5);
        let op = Operator::Softmax { rows, cols };
        let x = inputs(rows * cols, 0xA1);
        let want = softmax_ref(rows, cols, &x);
        for m in zoo() {
            let got = run_functional(&m, &op, &x, &[]);
            assert_eq!(got, want, "softmax on {}", m.name());
        }
        // Rows sum to 1 (within float noise — the exactness claim is
        // sim ≡ ref, not Σ ≡ 1.0 exactly).
        for r in 0..rows {
            let s: f32 = want[r * cols..(r + 1) * cols].iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row {r} sums to {s}");
        }
    }

    #[test]
    fn layernorm_bit_exact_on_all_targets() {
        let (rows, cols) = (2, 7);
        let op = Operator::LayerNorm {
            rows,
            cols,
            eps: 1e-5,
        };
        let x = inputs(rows * cols, 0xB2);
        let want = layernorm_ref(rows, cols, 1e-5, &x);
        for m in zoo() {
            let got = run_functional(&m, &op, &x, &[1e-5]);
            assert_eq!(got, want, "layernorm on {}", m.name());
        }
    }

    #[test]
    fn elementwise_ops_bit_exact_on_all_targets() {
        let (rows, cols) = (4, 3);
        let x = inputs(rows * cols, 0xC3);
        let y = inputs(rows * cols, 0xD4);
        for m in zoo() {
            let gelu = run_functional(&m, &Operator::Gelu { rows, cols }, &x, &[]);
            assert_eq!(gelu, gelu_ref(&x), "gelu on {}", m.name());
            let add = run_functional(&m, &Operator::AddMat { rows, cols }, &x, &y);
            assert_eq!(add, addmat_ref(&x, &y), "addmat on {}", m.name());
            let tr = run_functional(&m, &Operator::Transpose { rows, cols }, &x, &[]);
            assert_eq!(tr, transpose_ref(rows, cols, &x), "transpose on {}", m.name());
        }
    }

    #[test]
    fn transpose_ref_involution() {
        let x = inputs(12, 7);
        let t = transpose_ref(3, 4, &x);
        assert_eq!(transpose_ref(4, 3, &t), x);
    }

    #[test]
    fn static_len_matches_generated_programs() {
        let m = TargetConfig::Oma(OmaConfig::default()).build().unwrap();
        for op in [
            Operator::Softmax { rows: 3, cols: 5 },
            Operator::LayerNorm {
                rows: 2,
                cols: 4,
                eps: 1e-5,
            },
            Operator::Gelu { rows: 2, cols: 3 },
            Operator::AddMat { rows: 2, cols: 3 },
            Operator::Transpose { rows: 2, cols: 3 },
        ] {
            let p = scalar_rowwise_program(&m, &op).unwrap();
            assert_eq!(p.len() as u64, static_len(&op), "{op:?}");
        }
    }

    #[test]
    fn gemm_ops_are_not_rowwise() {
        let m = TargetConfig::Oma(OmaConfig::default()).build().unwrap();
        let p = crate::mapping::gemm::GemmParams::new(4, 4, 4);
        assert!(scalar_rowwise_program(&m, &Operator::Gemm(p)).is_none());
        assert!(!ScalarRowwiseMapper.supports(
            Registry::global(),
            &m,
            &Operator::Gemm(p)
        ));
    }
}
