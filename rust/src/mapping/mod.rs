//! DNN operator mapping (§5): code generators that lower operators onto
//! the model zoo's accelerators, and the UMA-style registry exposing them.
//!
//! The paper proposes TVM + UMA: *"the interface function for GeMM
//! `oma_tiled_gemm(...)` may generate ACADL instructions ... according to
//! the arguments passed, and then runs a functional and optional timing
//! simulation"*.  Our equivalents:
//!
//! * [`gemm`] — `oma_tiled_gemm`: parameterizable tiled GeMM on the OMA
//!   (tile size, six loop orders, Fig. 8's divide-and-conquer), plus the
//!   literal Listing-5 register-loop program.
//! * [`systolic_gemm`] — output-stationary wavefront mapping onto the
//!   rows×cols systolic array (macf chains carry the dataflow).
//! * [`gamma_gemm`] — fused-tensor mapping onto Γ̈ (Listing 4 codegen):
//!   8×8 `gemm` tiles with accumulation, optional fused ReLU and bias,
//!   optional scratchpad staging, multi-unit round-robin.
//! * [`conv`] — im2col lowering of 2-D convolution to GeMM.
//! * [`uma`] — the operator registry: (operator, target) → program +
//!   memory layout, the seam the DNN graph lowering plugs into.

pub mod conv;
pub mod gamma_gemm;
pub mod gemm;
pub mod systolic_gemm;
pub mod uma;
