//! DNN operator mapping (§5): code generators that lower operators onto
//! the model zoo's accelerators, and the UMA-style registry exposing them.
//!
//! The paper proposes TVM + UMA: *"the interface function for GeMM
//! `oma_tiled_gemm(...)` may generate ACADL instructions ... according to
//! the arguments passed, and then runs a functional and optional timing
//! simulation"*.  Since the `Mapper` refactor every generator implements
//! one trait and is reachable **only** through the registry seam:
//!
//! * [`mapper`] — the [`Mapper`](mapper::Mapper) trait: (operator, target)
//!   → lowered program + layout + static [`CostHints`](mapper::CostHints)
//!   (simulation-free estimates; their `min_cycles` derives from the same
//!   `analytical::Roofline` constructors the DSE pre-filter prunes with).
//! * [`gemm`] — `oma_tiled_gemm`: parameterizable tiled GeMM on the OMA
//!   (tile size, six loop orders, Fig. 8's divide-and-conquer), plus the
//!   literal Listing-5 register-loop program; registered as
//!   `oma_tiled_gemm` and `oma_gemm_listing5`.
//! * [`systolic_gemm`] — output-stationary wavefront mapping onto the
//!   rows×cols systolic array (`systolic_wavefront_gemm`).
//! * [`gamma_gemm`] — fused-tensor mapping onto Γ̈ (Listing 4 codegen):
//!   8×8 `gemm` tiles with accumulation, optional fused ReLU and bias,
//!   optional scratchpad staging, multi-unit round-robin
//!   (`gamma_fused_gemm`).
//! * [`conv`] — im2col lowering of 2-D convolution, a composite mapper
//!   that re-enters the registry with the reduced GeMM (`im2col_conv`).
//! * [`rowwise`] — the transformer's row-wise operators (softmax, layer
//!   norm, GELU, residual add, transpose) as scalar-unit streaming loops,
//!   bit-exact against their host references (`scalar_rowwise`).
//! * [`uma`] — the operator registry: (operator, target) → program +
//!   memory layout, the seam the DNN graph lowering, the coordinator's
//!   job executor, and the DSE engine all call.

pub mod conv;
pub mod gamma_gemm;
pub mod gemm;
pub mod mapper;
pub mod rowwise;
pub mod systolic_gemm;
pub mod uma;
