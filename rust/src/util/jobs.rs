//! One process-wide parallelism budget shared by every layer that spawns
//! worker threads — the coordinator pool, the TCP server's slot count,
//! and the parallel platform simulator.  Nested parallelism (a parallel
//! DSE sweep whose jobs each run a parallel platform simulation) leases
//! from the same budget, so the process never oversubscribes the host:
//! once the pool's workers hold the budget, inner sims are granted 1.
//!
//! The budget resolves, in priority order: the CLI override
//! (`--jobs`/`--threads` via [`set_override`]), the `ACADL_JOBS`
//! environment variable, then `std::thread::available_parallelism()`.
//! Grant sizes only ever affect wall-clock — reported cycle counts are
//! thread-count-independent by construction (see `sim::platform`).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

static OVERRIDE: OnceLock<usize> = OnceLock::new();
static OUTSTANDING: AtomicUsize = AtomicUsize::new(0);

/// The configured process-wide budget: CLI override, else `ACADL_JOBS`,
/// else the host's available parallelism (min 1).
pub fn configured() -> usize {
    if let Some(&n) = OVERRIDE.get() {
        return n.max(1);
    }
    if let Some(n) = std::env::var("ACADL_JOBS")
        .ok()
        .and_then(|s| s.parse::<usize>().ok())
    {
        return n.max(1);
    }
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Install the CLI's `--jobs` value as the process budget.  First caller
/// wins (the CLI parses flags once, before any worker spawns); later
/// calls with the same value are no-ops.
pub fn set_override(n: usize) {
    let _ = OVERRIDE.set(n.max(1));
}

/// Pure grant arithmetic (unit-testable without touching the globals):
/// clamp `want` to what's left of the budget, never below 1 — a caller
/// that wants parallelism always gets at least its own thread.
pub fn grant(want: usize, configured: usize, outstanding: usize) -> usize {
    want.max(1).min(configured.saturating_sub(outstanding).max(1))
}

/// An RAII lease on part of the parallelism budget.  `granted` is the
/// worker count the holder may spawn; dropping the lease returns it.
#[derive(Debug)]
pub struct Lease {
    pub granted: usize,
}

impl Drop for Lease {
    fn drop(&mut self) {
        OUTSTANDING.fetch_sub(self.granted, Ordering::SeqCst);
    }
}

/// Leases currently outstanding.  Observability only (the chaos harness
/// asserts leases balance back to their pre-fault value); racy by
/// nature, so callers must quiesce their own workers before reading.
pub fn outstanding() -> usize {
    OUTSTANDING.load(Ordering::SeqCst)
}

/// Lease up to `want` workers from the process budget, accounting for
/// leases already outstanding (nested parallelism collapses toward 1).
pub fn lease(want: usize) -> Lease {
    let budget = configured();
    // One CAS loop so concurrent leases never jointly exceed the budget.
    let mut cur = OUTSTANDING.load(Ordering::SeqCst);
    loop {
        let g = grant(want, budget, cur);
        match OUTSTANDING.compare_exchange(cur, cur + g, Ordering::SeqCst, Ordering::SeqCst) {
            Ok(_) => return Lease { granted: g },
            Err(actual) => cur = actual,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn grant_clamps_to_remaining_budget() {
        assert_eq!(grant(8, 4, 0), 4);
        assert_eq!(grant(2, 4, 0), 2);
        assert_eq!(grant(8, 4, 3), 1);
        assert_eq!(grant(8, 4, 4), 1, "exhausted budget still grants 1");
        assert_eq!(grant(8, 4, 9), 1, "oversubscribed budget still grants 1");
        assert_eq!(grant(0, 4, 0), 1, "want=0 normalizes to 1");
    }

    #[test]
    fn leases_stack_and_release() {
        // Serialize against other tests through the shared counter: take
        // a snapshot delta rather than asserting absolute values.
        let before = OUTSTANDING.load(Ordering::SeqCst);
        {
            let a = lease(1);
            assert_eq!(a.granted, 1);
            let b = lease(1);
            assert_eq!(b.granted, 1);
            assert!(OUTSTANDING.load(Ordering::SeqCst) >= before + 2);
        }
        assert_eq!(OUTSTANDING.load(Ordering::SeqCst), before);
    }

    #[test]
    fn configured_is_at_least_one() {
        assert!(configured() >= 1);
    }
}
