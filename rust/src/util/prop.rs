//! Property-test support (proptest's role): a seeded xorshift generator
//! and a `forall` driver that reports the failing case and its seed.

/// Deterministic xorshift64* PRNG for test-case generation.
#[derive(Debug, Clone)]
pub struct Gen {
    state: u64,
}

impl Gen {
    pub fn new(seed: u64) -> Self {
        Gen {
            state: seed ^ 0x9E37_79B9_7F4A_7C15 | 1,
        }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[lo, hi]` (inclusive).
    pub fn int(&mut self, lo: i64, hi: i64) -> i64 {
        debug_assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as i64
    }

    pub fn usize(&mut self, lo: usize, hi: usize) -> usize {
        self.int(lo as i64, hi as i64) as usize
    }

    pub fn bool(&mut self) -> bool {
        self.next_u64() & 1 == 1
    }

    pub fn f32(&mut self, lo: f32, hi: f32) -> f32 {
        let u = (self.next_u64() >> 11) as f32 / (1u64 << 53) as f32;
        lo + (hi - lo) * u
    }

    /// Pick one element.
    pub fn choose<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        &items[self.usize(0, items.len() - 1)]
    }

    pub fn vec_f32(&mut self, len: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..len).map(|_| self.f32(lo, hi)).collect()
    }
}

/// Run `prop` over `cases` generated inputs; panic with the seed and debug
/// form of the first failing case.
pub fn forall<T: std::fmt::Debug>(
    name: &str,
    cases: usize,
    mut generate: impl FnMut(&mut Gen) -> T,
    mut prop: impl FnMut(&T) -> Result<(), String>,
) {
    let base_seed = 0xACAD_1u64;
    for i in 0..cases {
        let seed = base_seed.wrapping_add(i as u64);
        let mut g = Gen::new(seed);
        let case = generate(&mut g);
        if let Err(msg) = prop(&case) {
            panic!("property `{name}` failed (seed {seed:#x}, case {i}): {msg}\ncase: {case:#?}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_is_deterministic() {
        let mut a = Gen::new(1);
        let mut b = Gen::new(1);
        for _ in 0..32 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn ranges_respected() {
        let mut g = Gen::new(7);
        for _ in 0..1000 {
            let v = g.int(-3, 9);
            assert!((-3..=9).contains(&v));
            let f = g.f32(0.5, 2.0);
            assert!((0.5..=2.0).contains(&f));
            let u = g.usize(1, 4);
            assert!((1..=4).contains(&u));
        }
    }

    #[test]
    fn forall_passes_good_property() {
        forall(
            "abs is non-negative",
            64,
            |g| g.int(-100, 100),
            |&x| {
                if x.abs() >= 0 {
                    Ok(())
                } else {
                    Err("negative abs".into())
                }
            },
        );
    }

    #[test]
    #[should_panic(expected = "property `always fails`")]
    fn forall_reports_failures() {
        forall("always fails", 4, |g| g.int(0, 1), |_| Err("nope".into()));
    }
}
