//! Cooperative cancellation: a shared [`CancelToken`] carrying an
//! explicit cancel flag and an optional wall-clock deadline, threaded
//! through every long-running loop in the stack (simulation backends,
//! platform stage workers, the DSE wave loop).
//!
//! Design constraints, in order:
//!
//! 1. **Zero-cost when unset.**  The hot loops obtain the token once via
//!    [`current`] before iterating and poll it only every
//!    [`CHECK_INTERVAL_STEPS`] steps; with no token installed the
//!    per-check cost is a branch on a register-resident `Option`, so
//!    PR 3's allocation-free steady state is untouched (pinned by
//!    `benches/backend_compare.rs`).
//! 2. **Cooperative, never preemptive.**  Nothing is killed: a loop that
//!    observes the token returns a structured error
//!    (`SimError::Deadline` / `SimError::Cancelled`) through the normal
//!    `Result` path, so RAII guards (slots, jobs-budget leases, pooled
//!    effects) unwind exactly as on any other error.
//! 3. **Composable.**  Tokens chain: a per-job deadline token created by
//!    `execute_on` keeps a handle on whatever token was already
//!    installed (e.g. the server's client-disconnect watch), so either
//!    source stops the simulation and the *cause* is reported
//!    faithfully — an explicit [`cancel`](CancelToken::cancel) wins over
//!    a deadline when both have fired.
//!
//! Propagation across threads is explicit: worker threads do not inherit
//! the parent's thread-local, so fan-out sites (`pool::run_jobs`,
//! `sim::platform::run_platform`) capture [`current`] before spawning
//! and [`install`] the clone inside each worker.

use std::cell::RefCell;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// How many scheduler steps a simulation loop runs between token polls.
/// Small enough that a deadline overshoots by at most a few microseconds
/// of simulated work, large enough that the amortized cost (one branch +
/// rare `Instant::now`) vanishes next to `SimCore::step`.
pub const CHECK_INTERVAL_STEPS: u64 = 4096;

/// Why a token reports itself as tripped.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CancelCause {
    /// Someone called [`CancelToken::cancel`] (client disconnect,
    /// shutdown drain, ctrl-c plumbing).
    Cancelled,
    /// The wall-clock deadline passed.
    Deadline,
}

#[derive(Debug)]
struct Inner {
    cancelled: AtomicBool,
    deadline: Option<Instant>,
    /// An outer token this one was chained onto (see [`CancelToken::child`]).
    parent: Option<Arc<Inner>>,
}

impl Inner {
    fn cause(&self) -> Option<CancelCause> {
        // Explicit cancellation anywhere in the chain wins over a
        // deadline: "the client hung up" is more actionable than "and
        // the budget also expired while we noticed".
        if self.cancelled_flag() {
            return Some(CancelCause::Cancelled);
        }
        let mut node = Some(self);
        while let Some(n) = node {
            if let Some(d) = n.deadline {
                if Instant::now() >= d {
                    return Some(CancelCause::Deadline);
                }
            }
            node = n.parent.as_deref();
        }
        None
    }

    fn cancelled_flag(&self) -> bool {
        let mut node = Some(self);
        while let Some(n) = node {
            if n.cancelled.load(Ordering::Relaxed) {
                return true;
            }
            node = n.parent.as_deref();
        }
        false
    }
}

/// A cheaply clonable cancellation handle (one `Arc` clone).  All clones
/// observe the same flag; chained children observe their ancestors too.
#[derive(Debug, Clone)]
pub struct CancelToken {
    inner: Arc<Inner>,
}

impl CancelToken {
    /// A token with no deadline; trips only via [`cancel`](Self::cancel).
    pub fn new() -> Self {
        Self::build(None, None)
    }

    /// A token that trips once `budget` of wall-clock time has elapsed.
    pub fn with_deadline(budget: Duration) -> Self {
        Self::build(Some(Instant::now() + budget), None)
    }

    /// A child token that trips when *either* this token trips or the
    /// child's own `budget` expires.  Used by `execute_on` to merge a
    /// per-job `deadline_ms` with an already-installed outer token.
    pub fn child_with_deadline(&self, budget: Duration) -> Self {
        Self::build(Some(Instant::now() + budget), Some(self.inner.clone()))
    }

    fn build(deadline: Option<Instant>, parent: Option<Arc<Inner>>) -> Self {
        CancelToken {
            inner: Arc::new(Inner {
                cancelled: AtomicBool::new(false),
                deadline,
                parent,
            }),
        }
    }

    /// Trip the token.  Idempotent; visible to all clones and children.
    pub fn cancel(&self) {
        self.inner.cancelled.store(true, Ordering::Relaxed);
    }

    /// Why the token is tripped, or `None` if it is still live.  Checks
    /// the cancel flag (and ancestors') first, then deadlines, so the
    /// reported cause is stable once observed.
    pub fn cause(&self) -> Option<CancelCause> {
        self.inner.cause()
    }

    /// `cause().is_some()` without constructing the cause.
    pub fn is_cancelled(&self) -> bool {
        self.inner.cause().is_some()
    }

    /// The nearest wall-clock deadline in the chain, if any.
    pub fn deadline(&self) -> Option<Instant> {
        let mut node = Some(self.inner.as_ref());
        let mut min: Option<Instant> = None;
        while let Some(n) = node {
            if let Some(d) = n.deadline {
                min = Some(min.map_or(d, |m: Instant| m.min(d)));
            }
            node = n.parent.as_deref();
        }
        min
    }
}

impl Default for CancelToken {
    fn default() -> Self {
        Self::new()
    }
}

thread_local! {
    static CURRENT: RefCell<Option<CancelToken>> = const { RefCell::new(None) };
}

/// The token installed on this thread, if any.  Hot loops call this once
/// before iterating, never per step.
pub fn current() -> Option<CancelToken> {
    CURRENT.with(|c| c.borrow().clone())
}

/// Install `token` as this thread's current token for the lifetime of
/// the returned guard; the previous token (if any) is restored on drop,
/// so nested installs (server token → job deadline) unwind correctly
/// even across panics.
#[must_use = "dropping the guard immediately uninstalls the token"]
pub fn install(token: CancelToken) -> InstallGuard {
    let prev = CURRENT.with(|c| c.borrow_mut().replace(token));
    InstallGuard { prev }
}

/// RAII guard from [`install`]; restores the previously-installed token.
pub struct InstallGuard {
    prev: Option<CancelToken>,
}

impl Drop for InstallGuard {
    fn drop(&mut self) {
        let prev = self.prev.take();
        CURRENT.with(|c| *c.borrow_mut() = prev);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_token_is_live() {
        let t = CancelToken::new();
        assert_eq!(t.cause(), None);
        assert!(!t.is_cancelled());
    }

    #[test]
    fn cancel_is_visible_to_clones() {
        let t = CancelToken::new();
        let c = t.clone();
        t.cancel();
        assert_eq!(c.cause(), Some(CancelCause::Cancelled));
    }

    #[test]
    fn deadline_trips_after_budget() {
        let t = CancelToken::with_deadline(Duration::from_millis(0));
        // A zero budget is already expired.
        assert_eq!(t.cause(), Some(CancelCause::Deadline));
        let far = CancelToken::with_deadline(Duration::from_secs(3600));
        assert_eq!(far.cause(), None);
    }

    #[test]
    fn child_observes_parent_cancel_and_own_deadline() {
        let parent = CancelToken::new();
        let child = parent.child_with_deadline(Duration::from_secs(3600));
        assert_eq!(child.cause(), None);
        parent.cancel();
        assert_eq!(child.cause(), Some(CancelCause::Cancelled));

        let parent2 = CancelToken::new();
        let child2 = parent2.child_with_deadline(Duration::from_millis(0));
        assert_eq!(child2.cause(), Some(CancelCause::Deadline));
        // The parent stays live: child deadlines never propagate upward.
        assert_eq!(parent2.cause(), None);
    }

    #[test]
    fn explicit_cancel_wins_over_expired_deadline() {
        let t = CancelToken::with_deadline(Duration::from_millis(0));
        t.cancel();
        assert_eq!(t.cause(), Some(CancelCause::Cancelled));
    }

    #[test]
    fn install_guard_nests_and_restores() {
        assert!(current().is_none());
        let outer = CancelToken::new();
        {
            let _g1 = install(outer.clone());
            assert!(current().is_some());
            let inner = current().unwrap().child_with_deadline(Duration::from_secs(1));
            {
                let _g2 = install(inner);
                // The innermost token is the visible one.
                assert!(current().unwrap().deadline().is_some());
            }
            // Back to the outer token (no deadline).
            assert!(current().unwrap().deadline().is_none());
        }
        assert!(current().is_none());
    }

    #[test]
    fn nearest_deadline_reported_through_chain() {
        let parent = CancelToken::with_deadline(Duration::from_secs(10));
        let child = parent.child_with_deadline(Duration::from_secs(3600));
        // The chain minimum is the parent's (sooner) deadline.
        assert!(child.deadline().unwrap() <= Instant::now() + Duration::from_secs(11));
    }
}
