//! Micro-benchmark harness (criterion's role for the `harness = false`
//! bench targets): warmup, repeated timed runs, median/mean/min report.

use std::time::{Duration, Instant};

/// One benchmark's timing summary.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub runs: usize,
    pub median: Duration,
    pub mean: Duration,
    pub min: Duration,
    /// Optional throughput denominator (items per run).
    pub items: Option<u64>,
}

impl BenchResult {
    /// items / second at the median, when a denominator was set.
    pub fn throughput(&self) -> Option<f64> {
        self.items
            .map(|n| n as f64 / self.median.as_secs_f64().max(1e-12))
    }

    pub fn line(&self) -> String {
        let tp = match self.throughput() {
            Some(t) if t >= 1e6 => format!("  {:>8.2} M/s", t / 1e6),
            Some(t) if t >= 1e3 => format!("  {:>8.2} k/s", t / 1e3),
            Some(t) => format!("  {t:>8.2} /s"),
            None => String::new(),
        };
        format!(
            "{:<44} median {:>10.3?}  mean {:>10.3?}  min {:>10.3?}{}",
            self.name, self.median, self.mean, self.min, tp
        )
    }
}

/// A named group of benchmarks (one per experiment table).
pub struct Bench {
    group: String,
    warmup: usize,
    runs: usize,
    results: Vec<BenchResult>,
}

impl Bench {
    pub fn new(group: &str) -> Self {
        // `ACADL_BENCH_RUNS` trims runs for smoke-testing the harness.
        let runs = std::env::var("ACADL_BENCH_RUNS")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(7);
        Bench {
            group: group.to_string(),
            warmup: 1,
            runs,
            results: Vec::new(),
        }
    }

    pub fn with_runs(mut self, runs: usize) -> Self {
        self.runs = runs.max(1);
        self
    }

    /// Time `f` (its return value is black-boxed) and record the result.
    pub fn time<T>(&mut self, name: &str, items: Option<u64>, mut f: impl FnMut() -> T) -> &BenchResult {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut samples: Vec<Duration> = (0..self.runs)
            .map(|_| {
                let t0 = Instant::now();
                black_box(f());
                t0.elapsed()
            })
            .collect();
        samples.sort();
        let median = samples[samples.len() / 2];
        let mean = samples.iter().sum::<Duration>() / samples.len() as u32;
        let min = samples[0];
        let r = BenchResult {
            name: format!("{}/{}", self.group, name),
            runs: self.runs,
            median,
            mean,
            min,
            items,
        };
        println!("{}", r.line());
        self.results.push(r);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Merge this group's results into the perf-trajectory JSON file named
    /// by the `ACADL_BENCH_JSON` env var (no-op when unset).  Driven by
    /// `scripts/perf_trajectory.sh`, which collects every bench group into
    /// one `BENCH_sim.json` so future PRs can diff perf.
    pub fn write_json_if_requested(&self) {
        if let Ok(path) = std::env::var("ACADL_BENCH_JSON") {
            if let Err(e) = self.write_json(&path) {
                eprintln!("bench: failed to write {path}: {e}");
            }
        }
    }

    /// Merge into the JSON object at `path` (bench name → median/mean/min
    /// nanoseconds, run count, and items-per-second throughput when a
    /// denominator was set), preserving entries from other groups.
    pub fn write_json(&self, path: &str) -> std::io::Result<()> {
        use crate::util::json::Json;
        let mut entries: Vec<(String, Json)> = std::fs::read_to_string(path)
            .ok()
            .and_then(|s| Json::parse(&s).ok())
            .and_then(|j| match j {
                Json::Obj(fields) => Some(fields),
                _ => None,
            })
            .unwrap_or_default();
        for r in &self.results {
            let mut fields = vec![
                ("median_ns".to_string(), Json::num(r.median.as_nanos() as f64)),
                ("mean_ns".to_string(), Json::num(r.mean.as_nanos() as f64)),
                ("min_ns".to_string(), Json::num(r.min.as_nanos() as f64)),
                ("runs".to_string(), Json::num(r.runs as f64)),
            ];
            if let Some(n) = r.items {
                fields.push(("items".to_string(), Json::num(n as f64)));
            }
            if let Some(tp) = r.throughput() {
                fields.push(("items_per_s".to_string(), Json::num(tp)));
            }
            let entry = Json::Obj(fields);
            match entries.iter_mut().find(|(k, _)| *k == r.name) {
                Some((_, v)) => *v = entry,
                None => entries.push((r.name.clone(), entry)),
            }
        }
        std::fs::write(path, format!("{}\n", Json::Obj(entries)))
    }
}

/// Optimizer barrier (std::hint::black_box stabilized in 1.66).
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_trajectory_merges_groups() {
        let path = std::env::temp_dir().join(format!("acadl_bench_{}.json", std::process::id()));
        let path = path.to_str().unwrap().to_string();
        let _ = std::fs::remove_file(&path);
        let mut a = Bench::new("g1").with_runs(2);
        a.time("x", Some(100), || 1);
        a.write_json(&path).unwrap();
        let mut b = Bench::new("g2").with_runs(2);
        b.time("y", None, || 2);
        b.write_json(&path).unwrap();
        let parsed =
            crate::util::json::Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let x = parsed.get("g1/x").expect("first group survives the merge");
        assert!(x.get("median_ns").is_some());
        assert!(x.get("items_per_s").is_some());
        assert!(parsed.get("g2/y").is_some());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn reports_ordered_stats() {
        let mut b = Bench::new("unit").with_runs(5);
        let r = b.time("noop", Some(1000), || 42).clone();
        assert!(r.min <= r.median);
        assert_eq!(r.runs, 5);
        assert!(r.throughput().unwrap() > 0.0);
        assert!(r.line().contains("unit/noop"));
    }
}
