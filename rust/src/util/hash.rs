//! Stable hashing for canonical keys (machine cache, DSE memo).
//!
//! `std::hash::DefaultHasher` makes no cross-release stability promise,
//! and the coordinator's caches key persisted/wire-visible identities
//! (canonical config and job JSON) — so we pin the exact function.

/// FNV-1a, 64-bit.
pub fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// FNV-1a over a string (the common canonical-JSON case).
pub fn fnv1a_str(s: &str) -> u64 {
    fnv1a(s.as_bytes())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Published FNV-1a test vectors.
        assert_eq!(fnv1a(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a_str("foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn distinguishes_close_inputs() {
        assert_ne!(fnv1a_str("gemm_8x8x8"), fnv1a_str("gemm_8x8x9"));
    }
}
