//! Minimal JSON: a value model, a recursive-descent parser, and a writer.
//! Covers the full JSON grammar (strings with escapes, numbers, nesting);
//! object key order is preserved (insertion order) so round-trips are
//! stable.

use std::collections::BTreeMap;
use std::fmt;

use thiserror::Error;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

#[derive(Debug, Error, PartialEq)]
pub enum JsonError {
    #[error("json parse error at byte {0}: {1}")]
    Parse(usize, String),
    #[error("expected {0}, found {1}")]
    Type(&'static str, &'static str),
    #[error("missing field `{0}`")]
    Missing(String),
    /// A field parsed as JSON but failed domain validation (e.g. inline
    /// ADL text in a job spec that does not elaborate).
    #[error("{0}")]
    Invalid(String),
}

impl Json {
    // -------------------------------------------------------- accessors

    pub fn kind(&self) -> &'static str {
        match self {
            Json::Null => "null",
            Json::Bool(_) => "bool",
            Json::Num(_) => "number",
            Json::Str(_) => "string",
            Json::Arr(_) => "array",
            Json::Obj(_) => "object",
        }
    }

    pub fn as_f64(&self) -> Result<f64, JsonError> {
        match self {
            Json::Num(v) => Ok(*v),
            other => Err(JsonError::Type("number", other.kind())),
        }
    }

    pub fn as_u64(&self) -> Result<u64, JsonError> {
        Ok(self.as_f64()? as u64)
    }

    pub fn as_usize(&self) -> Result<usize, JsonError> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_bool(&self) -> Result<bool, JsonError> {
        match self {
            Json::Bool(v) => Ok(*v),
            other => Err(JsonError::Type("bool", other.kind())),
        }
    }

    pub fn as_str(&self) -> Result<&str, JsonError> {
        match self {
            Json::Str(v) => Ok(v),
            other => Err(JsonError::Type("string", other.kind())),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json], JsonError> {
        match self {
            Json::Arr(v) => Ok(v),
            other => Err(JsonError::Type("array", other.kind())),
        }
    }

    pub fn as_obj(&self) -> Result<&[(String, Json)], JsonError> {
        match self {
            Json::Obj(v) => Ok(v),
            other => Err(JsonError::Type("object", other.kind())),
        }
    }

    /// Object field lookup.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Required object field.
    pub fn field(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key)
            .ok_or_else(|| JsonError::Missing(key.to_string()))
    }

    /// Optional field with default.
    pub fn opt_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.as_u64().ok()).unwrap_or(default)
    }

    pub fn opt_bool(&self, key: &str, default: bool) -> bool {
        self.get(key).and_then(|v| v.as_bool().ok()).unwrap_or(default)
    }

    // ------------------------------------------------------ constructors

    pub fn obj(fields: Vec<(&str, Json)>) -> Json {
        Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(v: impl Into<f64>) -> Json {
        Json::Num(v.into())
    }

    /// A sorted map view (tests, canonical comparisons).
    pub fn to_map(&self) -> Result<BTreeMap<&str, &Json>, JsonError> {
        Ok(self
            .as_obj()?
            .iter()
            .map(|(k, v)| (k.as_str(), v))
            .collect())
    }

    // ----------------------------------------------------------- parsing

    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let bytes = src.as_bytes();
        let mut p = Parser { bytes, pos: 0 };
        let v = p.value()?;
        p.skip_ws();
        if p.pos != bytes.len() {
            return Err(JsonError::Parse(p.pos, "trailing input".into()));
        }
        Ok(v)
    }
}

impl fmt::Display for Json {
    /// Compact JSON serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => f.write_str("null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(v) => {
                if v.fract() == 0.0 && v.abs() < 9e15 {
                    write!(f, "{}", *v as i64)
                } else {
                    write!(f, "{v}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(items) => {
                f.write_str("[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write!(f, "{v}")?;
                }
                f.write_str("]")
            }
            Json::Obj(fields) => {
                f.write_str("{")?;
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        f.write_str(",")?;
                    }
                    write_escaped(f, k)?;
                    f.write_str(":")?;
                    write!(f, "{v}")?;
                }
                f.write_str("}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    f.write_str("\"")?;
    for c in s.chars() {
        match c {
            '"' => f.write_str("\\\"")?,
            '\\' => f.write_str("\\\\")?,
            '\n' => f.write_str("\\n")?,
            '\r' => f.write_str("\\r")?,
            '\t' => f.write_str("\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    f.write_str("\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self
            .bytes
            .get(self.pos)
            .is_some_and(|b| b.is_ascii_whitespace())
        {
            self.pos += 1;
        }
    }

    fn peek(&mut self) -> Option<u8> {
        self.skip_ws();
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(JsonError::Parse(self.pos, format!("expected `{}`", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(JsonError::Parse(self.pos, format!("expected `{word}`")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => {
                self.pos += 1;
                let mut items = Vec::new();
                if self.peek() == Some(b']') {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                loop {
                    items.push(self.value()?);
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b']') => {
                            self.pos += 1;
                            return Ok(Json::Arr(items));
                        }
                        _ => return Err(JsonError::Parse(self.pos, "expected `,` or `]`".into())),
                    }
                }
            }
            Some(b'{') => {
                self.pos += 1;
                let mut fields = Vec::new();
                if self.peek() == Some(b'}') {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                loop {
                    self.skip_ws();
                    let key = self.string()?;
                    self.expect(b':')?;
                    fields.push((key, self.value()?));
                    match self.peek() {
                        Some(b',') => self.pos += 1,
                        Some(b'}') => {
                            self.pos += 1;
                            return Ok(Json::Obj(fields));
                        }
                        _ => return Err(JsonError::Parse(self.pos, "expected `,` or `}`".into())),
                    }
                }
            }
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(JsonError::Parse(self.pos, "expected a value".into())),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            let Some(&c) = self.bytes.get(self.pos) else {
                return Err(JsonError::Parse(self.pos, "unterminated string".into()));
            };
            self.pos += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(&e) = self.bytes.get(self.pos) else {
                        return Err(JsonError::Parse(self.pos, "bad escape".into()));
                    };
                    self.pos += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .and_then(|h| std::str::from_utf8(h).ok())
                                .and_then(|h| u32::from_str_radix(h, 16).ok())
                                .ok_or_else(|| {
                                    JsonError::Parse(self.pos, "bad \\u escape".into())
                                })?;
                            self.pos += 4;
                            out.push(char::from_u32(hex).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(JsonError::Parse(self.pos, "bad escape".into())),
                    }
                }
                c if c < 0x80 => out.push(c as char),
                _ => {
                    // Multi-byte UTF-8: copy the full sequence.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    let end = (start + len).min(self.bytes.len());
                    let s = std::str::from_utf8(&self.bytes[start..end])
                        .map_err(|_| JsonError::Parse(start, "bad utf-8".into()))?;
                    out.push_str(s);
                    self.pos = end;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.bytes.get(self.pos) == Some(&b'-') {
            self.pos += 1;
        }
        while self.bytes.get(self.pos).is_some_and(|b| {
            b.is_ascii_digit() || matches!(b, b'.' | b'e' | b'E' | b'+' | b'-')
        }) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| JsonError::Parse(start, format!("bad number `{text}`")))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xF0..=0xF7 => 4,
        0xE0..=0xEF => 3,
        0xC0..=0xDF => 2,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -42 ").unwrap(), Json::Num(-42.0));
        assert_eq!(Json::parse("2.5e2").unwrap(), Json::Num(250.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn nesting_and_lookup() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": true}], "c": null}"#).unwrap();
        assert_eq!(v.field("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.field("a").unwrap().as_arr().unwrap()[2]
                .field("b")
                .unwrap(),
            &Json::Bool(true)
        );
        assert!(matches!(v.field("zzz"), Err(JsonError::Missing(_))));
    }

    #[test]
    fn string_escapes_roundtrip() {
        let original = Json::str("line1\nline2\t\"quoted\" \\ end");
        let text = original.to_string();
        assert_eq!(Json::parse(&text).unwrap(), original);
        // Unicode escape parsing.
        assert_eq!(
            Json::parse(r#""Aé""#).unwrap(),
            Json::Str("Aé".into())
        );
        // Raw UTF-8 passthrough.
        assert_eq!(Json::parse("\"Γ̈ gœna\"").unwrap(), Json::Str("Γ̈ gœna".into()));
    }

    #[test]
    fn writer_roundtrip_complex() {
        let v = Json::obj(vec![
            ("id", Json::num(7)),
            ("name", Json::str("systolic_4x4")),
            (
                "cycles",
                Json::Arr(vec![Json::num(10), Json::num(20.5), Json::Null]),
            ),
            ("nested", Json::obj(vec![("ok", Json::Bool(true))])),
        ]);
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v);
        assert!(text.contains("\"cycles\":[10,20.5,null]"), "{text}");
    }

    #[test]
    fn errors_have_positions() {
        assert!(matches!(Json::parse("{"), Err(JsonError::Parse(..))));
        assert!(matches!(Json::parse("[1,]"), Err(JsonError::Parse(..))));
        assert!(matches!(Json::parse("1 2"), Err(JsonError::Parse(..))));
        assert!(matches!(
            Json::parse("\"abc"),
            Err(JsonError::Parse(..))
        ));
    }

    #[test]
    fn integer_rendering_is_clean() {
        assert_eq!(Json::num(123456789.0).to_string(), "123456789");
        assert_eq!(Json::num(0.25).to_string(), "0.25");
    }

    #[test]
    fn control_and_rare_escapes_roundtrip() {
        // \b, \f, and raw control characters below 0x20.
        assert_eq!(
            Json::parse(r#""a\bb\fc\/d""#).unwrap(),
            Json::Str("a\u{8}b\u{c}c/d".into())
        );
        let original = Json::str("bell\u{7} ctl\u{1}");
        let text = original.to_string();
        assert!(text.contains("\\u0007"), "{text}");
        assert!(text.contains("\\u0001"), "{text}");
        assert_eq!(Json::parse(&text).unwrap(), original);
        // \u escape for an ASCII control char parses back.
        assert_eq!(
            Json::parse("\"\\u0009\"").unwrap(),
            Json::Str("\t".into())
        );
    }

    #[test]
    fn escape_error_paths() {
        // Unknown escape, truncated escape, bad \u payload.
        assert!(matches!(Json::parse(r#""\q""#), Err(JsonError::Parse(..))));
        assert!(matches!(Json::parse("\"abc\\"), Err(JsonError::Parse(..))));
        assert!(matches!(
            Json::parse(r#""\uZZZZ""#),
            Err(JsonError::Parse(..))
        ));
        assert!(matches!(Json::parse(r#""\u00""#), Err(JsonError::Parse(..))));
        // An unpaired surrogate code point degrades to the replacement
        // character instead of erroring.
        assert_eq!(
            Json::parse(r#""\ud800""#).unwrap(),
            Json::Str("\u{fffd}".into())
        );
    }

    #[test]
    fn accessor_error_paths() {
        let v = Json::parse(r#"{"a": [1], "s": "x"}"#).unwrap();
        assert!(matches!(
            v.field("a").unwrap().as_obj(),
            Err(JsonError::Type("object", "array"))
        ));
        assert!(matches!(
            v.field("s").unwrap().as_arr(),
            Err(JsonError::Type("array", "string"))
        ));
        assert!(matches!(
            v.field("a").unwrap().as_bool(),
            Err(JsonError::Type("bool", "array"))
        ));
        assert!(matches!(
            v.field("s").unwrap().as_f64(),
            Err(JsonError::Type("number", "string"))
        ));
        assert!(v.to_map().is_ok());
        assert!(v.field("a").unwrap().to_map().is_err());
        assert!(v.get("zzz").is_none());
        assert!(!v.opt_bool("s", false), "non-bool falls back to default");
        assert!(v.opt_bool("zzz", true));
        let inv = JsonError::Invalid("inline ADL: bad".into());
        assert_eq!(inv.to_string(), "inline ADL: bad");
    }

    #[test]
    fn type_errors() {
        let v = Json::parse(r#"{"a": 1}"#).unwrap();
        assert!(matches!(
            v.field("a").unwrap().as_str(),
            Err(JsonError::Type("string", "number"))
        ));
        assert_eq!(v.opt_u64("a", 9), 1);
        assert_eq!(v.opt_u64("b", 9), 9);
    }
}
