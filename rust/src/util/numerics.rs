//! Shared scalar numerics: the *single* definitions of the transcendental
//! helpers the transformer operators use.
//!
//! Both the instruction semantics ([`crate::sim::exec`]'s `rsqrt`/`gelu`
//! opcodes) and the host reference implementations
//! ([`crate::mapping::rowwise`]'s `*_ref` functions, `DnnGraph::forward_ref`)
//! call these functions, so a mapped operator and its oracle execute the
//! **same f32 expression in the same order** — the property the
//! bit-exact cross-layer conformance suite relies on.

/// `1 / sqrt(x)` in f32 (the layer-norm denominator).  Negative inputs
/// produce `NaN`, zero produces `+inf` — IEEE semantics, no clamping.
#[inline]
pub fn rsqrt_f32(x: f32) -> f32 {
    1.0 / x.sqrt()
}

/// GELU, tanh approximation (the form used by GPT-family transformers):
/// `0.5·x·(1 + tanh(√(2/π)·(x + 0.044715·x³)))`, evaluated entirely in
/// f32.
#[inline]
pub fn gelu_f32(x: f32) -> f32 {
    const SQRT_2_OVER_PI: f32 = 0.797_884_56;
    const CUBIC: f32 = 0.044_715;
    0.5 * x * (1.0 + (SQRT_2_OVER_PI * (x + CUBIC * x * x * x)).tanh())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rsqrt_matches_ieee() {
        assert_eq!(rsqrt_f32(4.0), 0.5);
        assert_eq!(rsqrt_f32(1.0), 1.0);
        assert!(rsqrt_f32(0.0).is_infinite());
        assert!(rsqrt_f32(-1.0).is_nan());
    }

    #[test]
    fn gelu_fixed_points_and_asymptotes() {
        assert_eq!(gelu_f32(0.0), 0.0);
        // Large positive x → identity; large negative x → 0.
        assert!((gelu_f32(10.0) - 10.0).abs() < 1e-4);
        assert!(gelu_f32(-10.0).abs() < 1e-4);
        // Around zero the curve sits below the identity but above zero.
        let y = gelu_f32(1.0);
        assert!(y > 0.8 && y < 1.0, "gelu(1) = {y}");
        // Odd-ish shape: gelu(-x) = -x - gelu(x) ... spot check monotonicity.
        assert!(gelu_f32(2.0) > gelu_f32(1.0));
    }
}
