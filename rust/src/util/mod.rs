//! In-tree substrates replacing ecosystem crates that the offline build
//! cannot resolve (DESIGN.md §Substitutions):
//!
//! * [`json`]  — serde_json's role: a small JSON value model + parser +
//!   writer for the artifact manifest and the coordinator wire format.
//! * [`bench`] — criterion's role: a warmup/median micro-bench harness
//!   behind `cargo bench` (`harness = false` targets).
//! * [`prop`]  — proptest's role: seeded generators + a `forall` driver
//!   with failure-case reporting for property tests.
//! * [`hash`]  — stable FNV-1a for canonical cache/memo keys.
//! * [`jobs`]  — the process-wide parallelism budget (`--jobs` /
//!   `ACADL_JOBS`) leased by the pool, the server, and the parallel
//!   platform simulator so nested parallelism can't oversubscribe.
//! * [`cancel`] — cooperative cancellation tokens (deadline + explicit
//!   cancel) polled by every long-running simulation loop.

pub mod bench;
pub mod cancel;
pub mod hash;
pub mod jobs;
pub mod json;
pub mod numerics;
pub mod prop;
