//! AIDG — the Architectural Instruction Dependency Graph fast performance
//! estimator (§6: "implemented in [16] using an Architectural Instruction
//! Dependency Graph for fast performance estimation ... using a fixed
//! point analysis of consecutive loop iterations").
//!
//! Instead of stepping every clock cycle through every stage, the AIDG
//! estimator schedules the *dynamic instruction stream* once:
//!
//! 1. the dynamic stream comes from the functional ISS (branches resolved
//!    functionally — the AIDG nodes);
//! 2. each instruction's start time is the max of (a) the finish times of
//!    its producers over registers/memory (the dependency edges) and
//!    (b) its executing resource's next-free time (the architectural
//!    edges: FU occupancy, issue width);
//! 3. finish = start + FU latency + an uncontended memory-path estimate.
//!
//! This is O(dynamic instructions) with no per-cycle work — the "ultra-
//! fast" claim — at the cost of ignoring issue-buffer back-pressure and
//! slot contention (measured as estimation error in experiment E6).
//!
//! [`estimate_fixed_point`] adds the paper's loop extrapolation: schedule
//! until the per-iteration time delta of the hottest backward branch
//! converges (three equal deltas), then extrapolate the remaining trip
//! count arithmetically — sublinear in loop trip counts.

use std::collections::HashMap;

use thiserror::Error;

use crate::acadl_core::graph::{Ag, ObjId};
use crate::acadl_core::latency::{Latency, LatencyCtx};
use crate::acadl_core::object::ObjectKind;
use crate::isa::instruction::AddrRef;
use crate::isa::opcode::Opcode;
use crate::isa::program::Program;
use crate::isa::INSTR_BYTES;
use crate::mem::sram;
use crate::sim::exec::{self, MemImage, RegState};
use crate::sim::functional::FuncError;

#[derive(Debug, Error)]
pub enum AidgError {
    #[error(transparent)]
    Func(#[from] FuncError),
    #[error(transparent)]
    Exec(#[from] exec::ExecError),
    #[error("step limit {0} exceeded")]
    StepLimit(u64),
}

/// Estimation result.
#[derive(Debug, Clone, PartialEq)]
pub struct Estimate {
    pub cycles: u64,
    pub instructions: u64,
    /// Whether loop extrapolation kicked in (fixed-point mode).
    pub extrapolated: bool,
}

/// Per-FU resource model extracted from the AG.  Register accessibility
/// masks keep instruction routing faithful: a systolic PE's `macf` maps to
/// *that* PE's FU, not the first MAC-capable unit in the graph.
///
/// Occupancy is tracked per **execute stage**, not per FU: §6's
/// ExecuteStage "waits until the processing is finished" and "cannot
/// receive new instructions" — so FUs sharing a stage (the OMA's fu0+mau0,
/// Γ̈'s matMulFu+matAddFu) serialize.  [`STAGE_HANDOFF`] calibrates any
/// extra receive/hand-off cost (0 matches the engine, whose stage refill
/// overlaps the final processing cycle).
struct Resource {
    cap_mask: u64,
    latency: Latency,
    latency_const: Option<u64>,
    is_mau: bool,
    /// Index into the shared per-stage `next_free` array.
    stage: usize,
    read_mask: Vec<u64>,
    write_mask: Vec<u64>,
    /// (storage, uncontended per-word latency estimate) for each attached
    /// storage, resolved per access address (SRAM latency / DRAM CAS on
    /// steady-state row hits / cache hit latency — the estimator's
    /// documented optimism).
    storages: Vec<(ObjId, u64)>,
}

/// Extra cycles an execute stage spends receiving/handing off one
/// instruction (receive → FU dispatch → free, Fig. 10).
const STAGE_HANDOFF: u64 = 0;

impl Resource {
    fn supports(&self, ins: &crate::isa::instruction::Instruction) -> bool {
        if self.cap_mask & (1 << ins.op.index()) == 0 {
            return false;
        }
        for r in ins.all_read_regs() {
            let i = r.idx();
            if self.read_mask[i / 64] & (1 << (i % 64)) == 0
                && self.write_mask[i / 64] & (1 << (i % 64)) == 0
            {
                return false;
            }
        }
        for w in &ins.writes {
            let i = w.idx();
            if self.write_mask[i / 64] & (1 << (i % 64)) == 0 {
                return false;
            }
        }
        true
    }
}

/// Returns (per-FU resources, number of distinct execute stages).
fn build_resources(ag: &Ag) -> (Vec<Resource>, usize) {
    let words = ag.reg_count().div_ceil(64).max(1);
    // Map each FU to its containing execute stage's dense index.
    let mut stage_index: std::collections::HashMap<ObjId, usize> =
        std::collections::HashMap::new();
    let mut out = Vec::new();
    for id in (0..ag.len() as u32).map(ObjId) {
        let kind = ag.kind(id);
        if !kind.is_functional_unit()
            || matches!(kind, ObjectKind::InstructionMemoryAccessUnit(_))
        {
            continue;
        }
        let mut cap_mask = 0u64;
        if let Some(ops) = kind.to_process() {
            for op in Opcode::all() {
                if ops.contains(op.mnemonic()) {
                    cap_mask |= 1 << op.index();
                }
            }
        }
        let mut read_mask = vec![0u64; words];
        let mut write_mask = vec![0u64; words];
        for rf in ag.readable_rfs(id) {
            for (i, info) in ag.regs().iter().enumerate() {
                if info.rf == rf {
                    read_mask[i / 64] |= 1 << (i % 64);
                }
            }
        }
        for rf in ag.writable_rfs(id) {
            for (i, info) in ag.regs().iter().enumerate() {
                if info.rf == rf {
                    write_mask[i / 64] |= 1 << (i % 64);
                }
            }
        }
        let latency = kind.latency().cloned().unwrap_or(Latency::Const(1));
        let latency_const = match &latency {
            Latency::Const(v) => Some((*v).max(1)),
            _ => None,
        };
        let is_mau = kind.is_memory_access_unit();
        let storages = if is_mau {
            ag.storages_of_mau(id)
                .into_iter()
                .map(|s| (s, storage_latency_estimate(ag, s)))
                .collect()
        } else {
            Vec::new()
        };
        let parent = ag
            .edges_to(id, crate::acadl_core::edge::EdgeKind::Contains)
            .next()
            .unwrap_or(id);
        let n = stage_index.len();
        let stage = *stage_index.entry(parent).or_insert(n);
        out.push(Resource {
            cap_mask,
            latency,
            latency_const,
            is_mau,
            stage,
            read_mask,
            write_mask,
            storages,
        });
    }
    let stages = stage_index.len();
    (out, stages)
}

/// Forward-edge hops from the fetch stage to the nearest execute stage
/// that contains FUs (the pipeline refill depth after a taken branch).
fn pipeline_depth(ag: &Ag, ifs: ObjId) -> u64 {
    let mut frontier = vec![ifs];
    let mut seen = std::collections::HashSet::new();
    seen.insert(ifs);
    let mut depth = 0u64;
    while !frontier.is_empty() && depth < 16 {
        let mut next = Vec::new();
        for &s in &frontier {
            if s != ifs && !ag.contained_fus(s).is_empty() {
                return depth;
            }
            for t in ag.forward_targets(s) {
                if seen.insert(t) {
                    next.push(t);
                }
            }
        }
        frontier = next;
        depth += 1;
    }
    depth.min(2)
}

fn storage_latency_estimate(ag: &Ag, s: ObjId) -> u64 {
    match ag.kind(s) {
        ObjectKind::Sram(cfg) => sram::access_latency(cfg, false, 1),
        ObjectKind::Dram(d) => d.t_cas, // steady-state row hits
        ObjectKind::Cache(c) => c.hit_latency.eval_const().unwrap_or(1),
        _ => 1,
    }
}

/// Straight AIDG schedule over the full dynamic stream.
pub fn estimate(ag: &Ag, program: &Program, max_steps: u64) -> Result<Estimate, AidgError> {
    run(ag, program, max_steps, false)
}

/// AIDG with fixed-point loop extrapolation.
pub fn estimate_fixed_point(
    ag: &Ag,
    program: &Program,
    max_steps: u64,
) -> Result<Estimate, AidgError> {
    run(ag, program, max_steps, true)
}

fn run(
    ag: &Ag,
    program: &Program,
    max_steps: u64,
    fixed_point: bool,
) -> Result<Estimate, AidgError> {
    let (resources, stage_count) = build_resources(ag);
    let mut stage_free: Vec<u64> = vec![0; stage_count.max(1)];
    // Issue width: fetch port of the (single) front-end bounds how many
    // instructions can enter the window per cycle.
    let issue_width = ag
        .fetch_stages()
        .first()
        .and_then(|&ifs| ag.instruction_memory(ifs))
        .and_then(|im| ag.kind(im).storage_params().map(|p| p.port_width.max(1)))
        .unwrap_or(1) as u64;

    let mut regs: RegState = ag.regs().iter().map(|r| r.init.payload.clone()).collect();
    let zero_regs: Vec<usize> = ag
        .regs()
        .iter()
        .enumerate()
        .filter(|(_, r)| r.name == "z0" || r.name.ends_with("_z0"))
        .map(|(i, _)| i)
        .collect();
    let mut mem = MemImage::new();

    let mut reg_ready: Vec<u64> = vec![0; ag.reg_count()];
    let mut mem_ready: HashMap<u64, u64> = HashMap::new();

    let mut pc = program.base;
    let mut steps: u64 = 0;
    let mut finish_max: u64 = 0;

    // Control-hazard model: the engine fetches nothing past an unresolved
    // control instruction (no speculation, §6), so instructions after a
    // branch cannot start before the branch finishes plus a full pipeline
    // refill: instruction-memory transaction + issue + the forward-chain
    // depth from the fetch stage to the first FU-bearing execute stage.
    let refetch_penalty = ag
        .fetch_stages()
        .first()
        .map(|&ifs| {
            let imem_lat = ag
                .instruction_memory(ifs)
                .map(|im| storage_latency_estimate(ag, im))
                .unwrap_or(1);
            imem_lat + 1 + pipeline_depth(ag, ifs)
        })
        .unwrap_or(2);
    let mut fetch_floor: u64 = 0;

    // Fixed-point bookkeeping: completion time at each visit of the
    // program's minimal address (loop head proxy) + functional state hash
    // would be overkill; we track (branch target -> last finish, delta
    // streak, iteration body step count).
    let mut loop_track: HashMap<u64, (u64, u64, u64, u64)> = HashMap::new(); // target -> (last_finish, last_delta, streak, steps_per_iter)
    let mut extrapolated = false;
    let mut extra_steps: u64 = 0;

    loop {
        let Some(idx) = program.index_of(pc) else {
            break;
        };
        let ins = &program.instrs[idx];
        let fx = exec::execute(ins, pc, &regs, &mut mem)?;

        // Dependency-ready time.
        let mut ready = (steps / issue_width).max(fetch_floor); // fetch floors
        for r in ins.all_read_regs() {
            ready = ready.max(reg_ready[r.idx()]);
        }
        for w in &ins.writes {
            ready = ready.max(reg_ready[w.idx()]);
        }
        let addr_of = |a: &AddrRef, regs: &RegState| exec::resolve_addr(a, regs);
        for a in &ins.read_addrs {
            let addr = addr_of(a, &regs) & !3;
            ready = ready.max(mem_ready.get(&addr).copied().unwrap_or(0));
        }
        for a in &ins.write_addrs {
            let addr = addr_of(a, &regs) & !3;
            ready = ready.max(mem_ready.get(&addr).copied().unwrap_or(0));
        }

        // Resource: the supporting FU whose *execute stage* frees earliest
        // (Fig. 10: the stage blocks while its FU processes).
        let r = resources
            .iter()
            .filter(|r| r.supports(ins))
            .min_by_key(|r| stage_free[r.stage]);
        let (start, finish) = match r {
            Some(r) => {
                let start = ready.max(stage_free[r.stage]);
                let lat = match r.latency_const {
                    Some(v) => v,
                    None => {
                        let ctx = LatencyCtx::new()
                            .with("is_mac", i64::from(ins.op == Opcode::Mac))
                            .with("lanes", 8);
                        r.latency.eval(&ctx).unwrap_or(1).max(1)
                    }
                };
                let mem_cost = if r.is_mau && ins.is_memory() {
                    // Resolve each access to its storage's latency estimate.
                    fx.mem_reads
                        .iter()
                        .chain(fx.mem_stores.iter())
                        .map(|(a, b)| {
                            let per_word = r
                                .storages
                                .iter()
                                .find(|(s, _)| ag.storage_accepts(*s, *a))
                                .map(|(_, l)| *l)
                                .unwrap_or(1);
                            per_word * (*b as u64).div_ceil(32).max(1)
                        })
                        .sum()
                } else {
                    0
                };
                let finish = start + lat + mem_cost;
                // Non-pipelined stage occupancy + handoff (§6, Fig. 10).
                stage_free[r.stage] = finish + STAGE_HANDOFF;
                (start, finish)
            }
            None => (ready, ready + 1),
        };

        for (rr, _) in &fx.reg_writes {
            reg_ready[rr.idx()] = finish;
        }
        for (a, _) in &fx.mem_writes {
            mem_ready.insert(a & !3, finish);
        }
        for (a, bytes) in &fx.mem_reads {
            // Readers extend availability for WAR-ish ordering: writers
            // after must not finish before this read started.
            let e = mem_ready.entry(a & !3).or_insert(0);
            *e = (*e).max(start);
            let _ = bytes;
        }
        finish_max = finish_max.max(finish);
        if ins.is_control() {
            fetch_floor = fetch_floor.max(finish + refetch_penalty);
        }

        exec::apply(&fx, &mut regs, &mut mem);
        for &z in &zero_regs {
            regs.set_int(z, 0);
        }
        steps += 1;
        if fx.halt {
            break;
        }

        // Fixed-point: backward branches close loop iterations.
        if fixed_point {
            if let Some(target) = fx.branch {
                if target < pc {
                    let entry = loop_track.entry(target).or_insert((finish_max, 0, 0, steps));
                    let delta = finish_max.saturating_sub(entry.0);
                    let steps_per_iter = steps - entry.3;
                    if delta > 0 && delta == entry.1 && steps_per_iter > 0 {
                        entry.2 += 1;
                        if entry.2 >= 3 {
                            // Converged: run the remaining iterations
                            // *functionally* (no scheduling), charging each
                            // the converged per-iteration delta; the final
                            // partial (exit) pass is charged one more.
                            let (iters, final_pc, skipped_steps) = count_remaining_iters(
                                program, target, pc, &mut regs, &mut mem, &zero_regs,
                                max_steps,
                            )?;
                            extra_steps += skipped_steps;
                            extrapolated = true;
                            let trailing = skipped_steps > iters * steps_per_iter;
                            finish_max += delta * (iters + u64::from(trailing));
                            pc = final_pc;
                            loop_track.clear();
                            continue;
                        }
                    } else {
                        entry.2 = 0;
                    }
                    *entry = (finish_max, delta, entry.2, steps);
                }
            }
        }

        pc = fx.branch.unwrap_or(pc + INSTR_BYTES);
        if steps + extra_steps >= max_steps {
            return Err(AidgError::StepLimit(max_steps));
        }
    }

    Ok(Estimate {
        cycles: finish_max,
        instructions: steps + extra_steps,
        extrapolated,
    })
}

/// Functionally execute the loop at `head`..`branch_pc` until it exits,
/// returning (completed iterations, exit pc, instructions executed here).
/// Keeps architectural state consistent so post-loop code schedules
/// correctly; the step count keeps the estimator's dynamic instruction
/// count exact.
fn count_remaining_iters(
    program: &Program,
    head: u64,
    branch_pc: u64,
    regs: &mut RegState,
    mem: &mut MemImage,
    zero_regs: &[usize],
    max_steps: u64,
) -> Result<(u64, u64, u64), AidgError> {
    let mut iters = 0u64;
    let mut pc = head;
    let mut steps = 0u64;
    loop {
        let Some(idx) = program.index_of(pc) else {
            return Ok((iters, pc, steps));
        };
        let ins = &program.instrs[idx];
        let fx = exec::execute(ins, pc, regs, mem)?;
        exec::apply(&fx, regs, mem);
        for &z in zero_regs {
            regs.set_int(z, 0);
        }
        steps += 1;
        if steps >= max_steps {
            return Err(AidgError::StepLimit(max_steps));
        }
        if fx.halt {
            return Ok((iters, pc, steps));
        }
        if pc == branch_pc {
            match fx.branch {
                Some(t) if t == head => {
                    iters += 1;
                    pc = t;
                }
                Some(t) => return Ok((iters, t, steps)),
                None => return Ok((iters, pc + INSTR_BYTES, steps)),
            }
        } else {
            pc = fx.branch.unwrap_or(pc + INSTR_BYTES);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::oma::OmaConfig;
    use crate::isa::assembler::assemble;
    use crate::mapping::gemm::{oma_gemm_listing5, oma_tiled_gemm, GemmParams};
    use crate::sim::engine::Engine;

    #[test]
    fn estimates_straight_line() {
        let m = OmaConfig::default().build().unwrap();
        let p = assemble(&m.ag, "movi #1 => r0\nmovi #2 => r1\nadd r0, r1 => r2\nhalt", 0)
            .unwrap();
        let e = estimate(&m.ag, &p, 1000).unwrap();
        assert_eq!(e.instructions, 4);
        assert!(e.cycles >= 2 && e.cycles < 20, "cycles={}", e.cycles);
    }

    #[test]
    fn estimate_tracks_engine_within_tolerance() {
        // E6's core claim: AIDG error stays small on real mappings.
        let m = OmaConfig::default().build().unwrap();
        let p = GemmParams::new(6, 6, 6);
        let prog = oma_tiled_gemm(&m, &p).unwrap();

        let mut eng = Engine::new(&m.ag, &prog).unwrap();
        let exact = eng.run(10_000_000).unwrap().cycles;

        // The estimator's reference point is backend-independent: the
        // event-driven engine reports the same exact cycle count.
        let mut ev = Engine::with_backend(
            &m.ag,
            &prog,
            crate::sim::backend::BackendKind::EventDriven,
        )
        .unwrap();
        assert_eq!(ev.run(10_000_000).unwrap().cycles, exact);

        let est = estimate(&m.ag, &prog, 10_000_000).unwrap().cycles;
        let err = (est as f64 - exact as f64).abs() / exact as f64;
        assert!(
            err < 0.5,
            "estimate {est} vs exact {exact} (err {:.0}%)",
            err * 100.0
        );
    }

    #[test]
    fn fixed_point_extrapolates_loops() {
        let m = OmaConfig::default().build().unwrap();
        // A 200-iteration countdown loop with a steady body.
        let p = assemble(
            &m.ag,
            "movi #200 => r0\n\
             loop: addi r1, #1 => r1\n\
             addi r0, #-1 => r0\n\
             bnei r0, z0, @loop => pc\n\
             halt",
            0,
        )
        .unwrap();
        let full = estimate(&m.ag, &p, 100_000).unwrap();
        let fp = estimate_fixed_point(&m.ag, &p, 100_000).unwrap();
        assert!(fp.extrapolated, "loop must be detected");
        assert_eq!(fp.instructions, full.instructions);
        let err = (fp.cycles as f64 - full.cycles as f64).abs() / full.cycles as f64;
        assert!(err < 0.05, "fp {} vs full {}", fp.cycles, full.cycles);
    }

    #[test]
    fn fixed_point_on_listing5_gemm() {
        let m = OmaConfig::default().build().unwrap();
        let p = GemmParams::new(6, 6, 6);
        let prog = oma_gemm_listing5(&m, &p).unwrap();
        let full = estimate(&m.ag, &prog, 10_000_000).unwrap();
        let fp = estimate_fixed_point(&m.ag, &prog, 10_000_000).unwrap();
        let err = (fp.cycles as f64 - full.cycles as f64).abs() / full.cycles as f64;
        assert!(err < 0.15, "fp {} vs full {}", fp.cycles, full.cycles);
        assert_eq!(fp.instructions, full.instructions, "same dynamic count");
    }
}
