//! # acadl — Abstract Computer Architecture Description Language, in Rust
//!
//! A production-grade implementation of the ACADL methodology from
//! *"Using the Abstract Computer Architecture Description Language to Model
//! AI Hardware Accelerators"* (Müller, Borst, Lübeck, Jung, Bringmann, 2024).
//!
//! ACADL formalizes computer-architecture block diagrams: a small set of
//! object classes (pipeline stages, functional units, register files, memory
//! hierarchies) connected by typed edges form an **architecture graph** (AG),
//! and an instruction-centric timing semantic turns any AG plus an
//! instruction stream into cycle-accurate performance numbers.
//!
//! ## Layer map (see DESIGN.md)
//!
//! * [`acadl_core`] — the language: objects, typed edges, validity rules,
//!   templates with dangling edges, latency expressions.
//! * [`adl`] — the textual frontend: a concrete `.acadl` syntax (objects,
//!   connects, templates with dangling edges, `param` sweep axes), its
//!   lexer/parser with spanned diagnostics, the elaborator lowering to a
//!   validated [`acadl_core::graph::Ag`], and the canonical round-trip
//!   pretty-printer behind `acadl-cli parse` / `fmt` / `--arch-file`.
//! * [`mem`] — memory substrates: SRAM, banked DRAM timing (t_RCD/t_RP/t_RAS),
//!   set-associative cache simulation (LRU/FIFO/PLRU/Random).
//! * [`isa`] — the union instruction set of the paper's three accelerators,
//!   plus a two-pass assembler for the paper's listing syntax.
//! * [`sim`] — the timing-simulation semantics of §6 (Figs 9–13), split
//!   into a pluggable kernel: `sim::kernel` holds the fetch / pipeline /
//!   execute / functional-unit state machines ([`sim::SimCore`]), the
//!   global last-user dependency scoreboard, and storage request slots;
//!   `sim::backend` schedules them through the [`sim::SimBackend`] trait —
//!   cycle-stepped (reference) or event-driven (idle-cycle-skipping, same
//!   reported cycles); `sim::engine` is the front-end; plus a pure
//!   functional ISS for mapping validation.  `sim::platform` layers a
//!   partitioned parallel simulation on top: a DNN graph sharded across
//!   a multi-chip platform, worker threads per stage chain, and a
//!   conservative-sync timing recurrence that reports bit-identical
//!   cycles at any thread count.  `sim::trace` is the structured
//!   observability layer: a zero-cost-when-off recording sink capturing
//!   per-FU/port spans and stall/occupancy counter tracks that
//!   reconcile exactly with [`sim::SimStats`], exported as Chrome-trace
//!   JSON (`acadl-cli trace`, `simulate --trace`) for
//!   [ui.perfetto.dev](https://ui.perfetto.dev).
//! * [`arch`] — the model zoo: OMA (§4.1), the parameterizable systolic
//!   array (§4.2), Γ̈ (§4.3), Eyeriss- / Plasticine-derived models (§6),
//!   and `arch::platform` — N chips + fabric + shared DRAM descriptors.
//! * [`mapping`] — DNN operator mapping (§5): the `Mapper` trait and the
//!   UMA-style registry it plugs into — tiled-GeMM code generation per
//!   accelerator, loop orders, im2col convolution — the single seam every
//!   consumer lowers through.
//! * [`dnn`] — a DNN graph IR and its lowering to operator schedules
//!   (Dense and Conv2d on the accelerator, pool/flatten as host glue),
//!   plus the layer-wise platform partitioner (`dnn::partition_graph`).
//! * [`aidg`] — the Architectural Instruction Dependency Graph fast
//!   performance estimator (fixed-point loop analysis).
//! * [`analytical`] — ScaleSim-like and roofline baselines (§2 comparisons).
//! * [`runtime`] — PJRT golden-model execution of the AOT artifacts
//!   (`artifacts/*.hlo.txt`) via the `xla` crate; gated behind the
//!   `pjrt` cargo feature (stubbed otherwise, golden tests skip).
//! * [`coordinator`] — async job queue + worker pool for simulation
//!   campaigns, design-space sweeps, and the TCP serving front-end, with
//!   a process-wide built-machine cache.
//! * [`dse`] — the design-space exploration engine: candidate
//!   enumeration, analytical pruning, memoized parallel evaluation, and
//!   Pareto-frontier reporting (`acadl-cli dse`).
//! * [`metrics`] — report tables for the EXPERIMENTS.md experiments.
//!
//! ## Quickstart
//!
//! (Compile-checked only: rustdoc test binaries don't inherit the
//! xla-extension rpath this image needs at load time.)
//!
//! ```no_run
//! use acadl::arch::oma::OmaConfig;
//! use acadl::mapping::gemm::{oma_tiled_gemm, GemmParams, LoopOrder};
//! use acadl::sim::engine::Engine;
//!
//! let machine = OmaConfig::default().build().unwrap();
//! let params = GemmParams::new(8, 8, 8).with_tile(4).with_order(LoopOrder::Ijk);
//! let program = oma_tiled_gemm(&machine, &params).unwrap();
//! let mut engine = Engine::new(&machine.ag, &program).unwrap();
//! let stats = engine.run(1_000_000).unwrap();
//! println!("GeMM took {} cycles", stats.cycles);
//! # assert!(stats.cycles > 0);
//! ```

pub mod acadl_core;
pub mod adl;
pub mod aidg;
pub mod util;
pub mod analytical;
pub mod arch;
pub mod coordinator;
pub mod dnn;
pub mod dse;
pub mod isa;
pub mod mapping;
pub mod mem;
pub mod metrics;
pub mod runtime;
pub mod sim;

/// Convenience re-exports for the common "build → map → simulate" flow.
pub mod prelude {
    pub use crate::acadl_core::{
        edge::EdgeKind,
        graph::{Ag, ObjId},
        latency::Latency,
    };
    pub use crate::arch::{gamma::GammaConfig, oma::OmaConfig, systolic::SystolicConfig};
    pub use crate::isa::program::Program;
    pub use crate::mapping::gemm::{GemmParams, LoopOrder};
    pub use crate::sim::backend::{BackendKind, SimBackend};
    pub use crate::sim::engine::{Engine, SimStats};
    pub use crate::sim::functional::FunctionalSim;
}
