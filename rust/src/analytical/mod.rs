//! Analytical baselines (§2's related work): the closed-form estimators
//! ACADL's simulation is compared against in experiment E7.
//!
//! * [`scalesim_cycles`] — a ScaleSim-style [9] output-stationary systolic
//!   formula over the same ten-ish parameters (array dims, operand dims,
//!   bandwidth).
//! * [`Roofline`] — compute-vs-memory bound cycles, the sanity floor every
//!   simulated number must sit above.

use crate::mapping::gemm::GemmParams;
use crate::mapping::uma::Operator;

/// ScaleSim-like output-stationary estimate for `C (m×n) = A(m×k)·B(k×n)`
/// on an `rows×cols` array.
///
/// Each output tile takes `2·T + k − 1` cycles to fill+drain its wavefront
/// (T = max(rows, cols) skew) plus the K-deep accumulation; tiles are
/// serialized, loads overlapped (the ScaleSim "compute-bound" regime).
pub fn scalesim_cycles(p: &GemmParams, rows: usize, cols: usize) -> u64 {
    let tiles = (p.m.div_ceil(rows) * p.n.div_ceil(cols)) as u64;
    let skew = (rows + cols - 1) as u64;
    tiles * (p.k as u64 + skew)
}

/// Utilization the ScaleSim model predicts (mac slots used / provided).
pub fn scalesim_utilization(p: &GemmParams, rows: usize, cols: usize) -> f64 {
    let provided = scalesim_cycles(p, rows, cols) * (rows * cols) as u64;
    if provided == 0 {
        0.0
    } else {
        p.macs() as f64 / provided as f64
    }
}

/// Roofline bound: cycles ≥ max(compute, memory-traffic) cycles.
#[derive(Debug, Clone, Copy)]
pub struct Roofline {
    /// MAC units available per cycle.
    pub macs_per_cycle: u64,
    /// Memory words transferable per cycle.
    pub words_per_cycle: u64,
    /// f32 words the target's data memory can hold (`None` = unmodeled).
    /// This is a *feasibility* parameter, not a bound denominator: a
    /// workload whose resident operand set exceeds it cannot be laid out
    /// on the target at all.
    pub capacity_words: Option<u64>,
}

impl Roofline {
    /// The OMA scalar core: one single-slot MAC functional unit (≤ 1 MAC
    /// retired per cycle) and one single-slot memory access unit (≤ 1 word
    /// per cycle).  Both sides are sound lower-bound denominators.  Data
    /// memory: `dmem0` spans 512 KiB (bytes 65536..589824) = 128 Ki f32
    /// words.
    pub fn oma() -> Self {
        Roofline {
            macs_per_cycle: 1,
            words_per_cycle: 1,
            capacity_words: Some(131_072),
        }
    }

    /// A `rows×cols` systolic array: one MAC-and-forward unit per PE, and
    /// `rows + cols` edge load units plus as many store units — each a
    /// single-slot unit moving one word per operation.  Data memory: the
    /// array's 8 MiB SRAM = 2 Mi f32 words.
    pub fn systolic(rows: usize, cols: usize) -> Self {
        Roofline {
            macs_per_cycle: (rows * cols) as u64,
            words_per_cycle: (2 * (rows + cols)) as u64,
            capacity_words: Some(2_097_152),
        }
    }

    /// Γ̈ with `units` LSU/compute/scratchpad complexes: each fused `gemm`
    /// op performs 8·8·8 = 512 MACs and a unit cannot complete more than
    /// one op per cycle even fully pipelined; each LSU moves one 8-wide
    /// vector row per op.  Data memory: the 256 MiB DRAM window = 64 Mi
    /// f32 words.
    pub fn gamma(units: usize) -> Self {
        Roofline {
            macs_per_cycle: (units * 512) as u64,
            words_per_cycle: (units * 8) as u64,
            capacity_words: Some(67_108_864),
        }
    }

    /// Memory-capacity feasibility: can a resident operand set of `words`
    /// f32 words be laid out in the target's data memory?
    pub fn fits_capacity(&self, words: u64) -> bool {
        self.capacity_words.map_or(true, |cap| words <= cap)
    }

    /// Port-bandwidth feasibility: can `words` of mandatory traffic cross
    /// the memory interface within `budget` cycles at full port
    /// bandwidth?  `false` means a timed run is *guaranteed* to hit the
    /// cycle limit (the streaming bound is sound), so the candidate can
    /// be rejected before any machine is built.
    pub fn traffic_fits_budget(&self, words: u64, budget: u64) -> bool {
        self.stream_cycles(words) <= budget
    }

    /// Minimum cycles for a GeMM with perfect reuse (each operand word
    /// moved once).
    pub fn gemm_cycles(&self, p: &GemmParams) -> u64 {
        let compute = p.macs().div_ceil(self.macs_per_cycle.max(1));
        let words = (p.m * p.k + p.k * p.n + p.m * p.n) as u64;
        let memory = words.div_ceil(self.words_per_cycle.max(1));
        compute.max(memory)
    }

    /// Minimum cycles to stream `words` f32 words through the memory
    /// system — the bound for element-wise / row-reduction operators
    /// whose arithmetic is dominated by operand movement.  Sound on every
    /// target: a word cannot cross the memory interface faster than
    /// `words_per_cycle`, however the arithmetic is scheduled.
    pub fn stream_cycles(&self, words: u64) -> u64 {
        words.div_ceil(self.words_per_cycle.max(1)).max(1)
    }

    /// Sound lower bound for any [`Operator`]: GeMM-backed operators use
    /// the compute-vs-memory GeMM bound; the row-wise transformer
    /// operators use the streaming bound over their mandatory traffic
    /// (each input word read once, each output word written once).
    ///
    /// This is the *single* definition both the mapper cost hints and the
    /// DSE pre-filter (`dse::lower_bound_cycles`) derive from, so the two
    /// paths cannot drift apart.
    pub fn op_cycles(&self, op: &Operator) -> u64 {
        match op.gemm_params() {
            Some(p) => self.gemm_cycles(p),
            None => self.stream_cycles((op.a_words() + op.b_words() + op.c_words()) as u64),
        }
    }

    /// Which side binds?
    pub fn gemm_bound(&self, p: &GemmParams) -> &'static str {
        let compute = p.macs().div_ceil(self.macs_per_cycle.max(1));
        if compute >= self.gemm_cycles(p) {
            "compute"
        } else {
            "memory"
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalesim_scales_with_array() {
        let p = GemmParams::new(16, 16, 16);
        let small = scalesim_cycles(&p, 4, 4);
        let big = scalesim_cycles(&p, 16, 16);
        assert!(big < small, "bigger array, fewer cycles: {big} vs {small}");
    }

    #[test]
    fn scalesim_utilization_bounds() {
        let p = GemmParams::new(64, 64, 64);
        let u = scalesim_utilization(&p, 8, 8);
        assert!(u > 0.0 && u <= 1.0, "u={u}");
        // Perfect fit with long K → utilization approaches 1.
        let p_long = GemmParams::new(8, 1024, 8);
        assert!(scalesim_utilization(&p_long, 8, 8) > 0.9);
    }

    #[test]
    fn per_target_rooflines_order_sensibly() {
        let p = GemmParams::new(32, 32, 32);
        let oma = Roofline::oma().gemm_cycles(&p);
        let sys = Roofline::systolic(8, 8).gemm_cycles(&p);
        let gam = Roofline::gamma(4).gemm_cycles(&p);
        assert!(oma > sys, "scalar floor above array: {oma} vs {sys}");
        assert!(sys > gam, "array above fused tensor: {sys} vs {gam}");
        assert_eq!(oma, p.macs(), "OMA is compute-bound at 1 MAC/cycle");
    }

    #[test]
    fn op_cycles_covers_rowwise_operators() {
        let rl = Roofline::oma();
        // Softmax 4×8: 32 in + 32 out words at 1 word/cycle.
        let sm = Operator::Softmax { rows: 4, cols: 8 };
        assert_eq!(rl.op_cycles(&sm), 64);
        // AddMat moves three matrices.
        let add = Operator::AddMat { rows: 4, cols: 8 };
        assert_eq!(rl.op_cycles(&add), 96);
        // LayerNorm carries one epsilon word in B.
        let ln = Operator::LayerNorm {
            rows: 4,
            cols: 8,
            eps: 1e-5,
        };
        assert_eq!(rl.op_cycles(&ln), 65);
        // GeMM-backed operators defer to the GeMM bound.
        let p = GemmParams::new(8, 8, 8);
        assert_eq!(rl.op_cycles(&Operator::Gemm(p)), rl.gemm_cycles(&p));
        // Wider memory lowers the streaming bound but never below 1.
        let wide = Roofline::systolic(8, 8);
        assert!(wide.op_cycles(&sm) < rl.op_cycles(&sm));
        assert!(wide.stream_cycles(0) >= 1);
    }

    #[test]
    fn roofline_switches_bound() {
        let compute_bound = Roofline {
            macs_per_cycle: 1,
            words_per_cycle: 1000,
            capacity_words: None,
        };
        let memory_bound = Roofline {
            macs_per_cycle: 1000,
            words_per_cycle: 1,
            capacity_words: None,
        };
        let p = GemmParams::new(16, 16, 16);
        assert_eq!(compute_bound.gemm_bound(&p), "compute");
        assert_eq!(memory_bound.gemm_bound(&p), "memory");
        assert_eq!(compute_bound.gemm_cycles(&p), p.macs());
    }

    #[test]
    fn feasibility_checks_gate_on_capacity_and_budget() {
        let oma = Roofline::oma();
        // The OMA's 512 KiB dmem holds 128 Ki words.
        assert!(oma.fits_capacity(131_072));
        assert!(!oma.fits_capacity(131_073));
        // Unmodeled capacity never rejects.
        let open = Roofline {
            capacity_words: None,
            ..oma
        };
        assert!(open.fits_capacity(u64::MAX));
        // 100 words at 1 word/cycle needs 100 cycles.
        assert!(oma.traffic_fits_budget(100, 100));
        assert!(!oma.traffic_fits_budget(100, 99));
        // A wider interface relaxes the same budget.
        assert!(Roofline::systolic(4, 4).traffic_fits_budget(100, 13));
    }
}
