//! Functional instruction-set simulation: program-order execution of a
//! [`Program`] against the AG's architectural state, with no timing.
//!
//! This is the paper's "functional simulation" (§3: `Data.payload` "is used
//! for the functional simulation"; §5: the UMA interface function "runs a
//! functional and optional timing simulation to validate the DNN operator
//! mapping").  The timed engine reuses the same [`exec`] semantics, so a
//! mapped operator that validates here produces bit-identical architectural
//! state under timing simulation.

use thiserror::Error;

use crate::acadl_core::data::Value;
use crate::acadl_core::graph::{Ag, RegId};
use crate::isa::program::Program;
use crate::isa::INSTR_BYTES;
use crate::sim::exec::{self, ExecError, MemImage, RegState};

#[derive(Debug, Error)]
pub enum FuncError {
    #[error("pc {0:#x} is outside the program")]
    PcOutOfRange(u64),
    #[error("step limit {0} exceeded (missing halt or infinite loop?)")]
    StepLimit(u64),
    #[error(transparent)]
    Exec(#[from] ExecError),
    #[error("unknown register `{0}`")]
    UnknownReg(String),
}

/// Result summary of a functional run.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FuncStats {
    pub instructions: u64,
    pub mem_reads: u64,
    pub mem_writes: u64,
}

/// Program-order ISS over the AG's register namespace.
#[derive(Debug, Clone)]
pub struct FunctionalSim {
    pub regs: RegState,
    pub mem: MemImage,
    zero_regs: Vec<RegId>,
}

impl FunctionalSim {
    /// Initialize architectural state from the AG's register init values.
    pub fn new(ag: &Ag) -> Self {
        let regs: RegState = ag.regs().iter().map(|r| r.init.payload.clone()).collect();
        // Hardwired-zero registers by convention: any register named `z0`
        // or `*z0` stays zero (Listing 5 relies on this).
        let zero_regs = ag
            .regs()
            .iter()
            .enumerate()
            .filter(|(_, r)| r.name == "z0" || r.name.ends_with("_z0"))
            .map(|(i, _)| RegId(i as u32))
            .collect();
        FunctionalSim {
            regs,
            mem: MemImage::new(),
            zero_regs,
        }
    }

    /// Set a register by AG name (workload setup).
    pub fn set_reg(&mut self, ag: &Ag, name: &str, v: Value) -> Result<(), FuncError> {
        let id = ag
            .reg_id(name)
            .ok_or_else(|| FuncError::UnknownReg(name.to_string()))?;
        self.regs.set(id.idx(), v);
        Ok(())
    }

    pub fn get_reg(&self, ag: &Ag, name: &str) -> Result<Value, FuncError> {
        let id = ag
            .reg_id(name)
            .ok_or_else(|| FuncError::UnknownReg(name.to_string()))?;
        Ok(self.regs.get(id.idx()))
    }

    /// Run `program` to `halt` (or fall off the end), program order.
    pub fn run(&mut self, program: &Program, max_steps: u64) -> Result<FuncStats, FuncError> {
        let mut pc = program.base;
        let mut steps = 0u64;
        let (r0, w0) = (self.mem.reads, self.mem.writes);
        // One pooled effects buffer for the whole run: cleared per
        // instruction, capacities retained, vector payloads moved.
        let mut fx = exec::Effects::default();
        loop {
            let Some(idx) = program.index_of(pc) else {
                if pc == program.end_addr() {
                    break; // fell off the end — treat like halt
                }
                return Err(FuncError::PcOutOfRange(pc));
            };
            let ins = &program.instrs[idx];
            exec::execute_into(ins, pc, &self.regs, &mut self.mem, &mut fx)?;
            exec::commit(&mut fx, &mut self.regs, &mut self.mem);
            for z in &self.zero_regs {
                self.regs.set_int(z.idx(), 0);
            }
            steps += 1;
            if fx.halt {
                break;
            }
            pc = fx.branch.unwrap_or(pc + INSTR_BYTES);
            if steps >= max_steps {
                return Err(FuncError::StepLimit(max_steps));
            }
        }
        Ok(FuncStats {
            instructions: steps,
            mem_reads: self.mem.reads - r0,
            mem_writes: self.mem.writes - w0,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::oma::OmaConfig;
    use crate::isa::assembler::assemble;

    #[test]
    fn straight_line_program() {
        let m = OmaConfig::default().build().unwrap();
        let p = assemble(
            &m.ag,
            "movi #5 => r0\n\
             movi #7 => r1\n\
             add r0, r1 => r2\n\
             halt",
            m.cfg.imem_range.0,
        )
        .unwrap();
        let mut sim = FunctionalSim::new(&m.ag);
        let stats = sim.run(&p, 1000).unwrap();
        assert_eq!(stats.instructions, 4);
        assert_eq!(sim.get_reg(&m.ag, "r2").unwrap().as_int(), 12);
    }

    #[test]
    fn loop_with_branch() {
        let m = OmaConfig::default().build().unwrap();
        // Sum 1..=5 into r1 using a countdown loop.
        let p = assemble(
            &m.ag,
            "movi #5 => r0\n\
             movi #0 => r1\n\
             loop: add r1, r0 => r1\n\
             addi r0, #-1 => r0\n\
             bnei r0, z0, @loop => pc\n\
             halt",
            0,
        )
        .unwrap();
        let mut sim = FunctionalSim::new(&m.ag);
        sim.run(&p, 1000).unwrap();
        assert_eq!(sim.get_reg(&m.ag, "r1").unwrap().as_int(), 15);
    }

    #[test]
    fn memory_roundtrip_and_zero_reg() {
        let m = OmaConfig::default().build().unwrap();
        let base = m.dmem_base();
        let p = assemble(
            &m.ag,
            &format!(
                "movi #{base} => r10\n\
                 load [r10] => r4\n\
                 load [r10+4] => r5\n\
                 mac r4, r5 => r6\n\
                 store r6 => [r10+8]\n\
                 mov z0 => r7\n\
                 halt"
            ),
            0,
        )
        .unwrap();
        let mut sim = FunctionalSim::new(&m.ag);
        sim.mem.load_f32(base, &[3.0, 4.0]);
        sim.set_reg(&m.ag, "r6", Value::F32(1.0)).unwrap();
        sim.run(&p, 100).unwrap();
        assert_eq!(sim.mem.peek(base + 8), 13.0); // 1 + 3*4
        assert_eq!(sim.get_reg(&m.ag, "r7").unwrap().as_int(), 0);
    }

    #[test]
    fn step_limit_guards_infinite_loops() {
        let m = OmaConfig::default().build().unwrap();
        let p = assemble(&m.ag, "loop: jumpi @loop => pc", 0).unwrap();
        let mut sim = FunctionalSim::new(&m.ag);
        assert!(matches!(
            sim.run(&p, 50),
            Err(FuncError::StepLimit(50))
        ));
    }

    #[test]
    fn fall_off_end_is_clean_stop() {
        let m = OmaConfig::default().build().unwrap();
        let p = assemble(&m.ag, "movi #1 => r0\nmovi #2 => r1", 0).unwrap();
        let mut sim = FunctionalSim::new(&m.ag);
        let stats = sim.run(&p, 100).unwrap();
        assert_eq!(stats.instructions, 2);
    }
}
