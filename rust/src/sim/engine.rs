//! The timing engine front-end: one (AG, program) pair plus a selected
//! [`SimBackend`] scheduler.
//!
//! The §6 state machines live in [`super::kernel`] ([`SimCore`]); the
//! drivers live in [`super::backend`].  `Engine` binds the two and keeps
//! the historical API (`Engine::new` → cycle-stepped) stable for every
//! caller, while `Engine::with_backend` selects the event-driven kernel.
//! `Engine` derefs to its [`SimCore`], so architectural state (`.regs`,
//! `.mem`, `.get_reg(..)`) reads exactly as before.

use std::ops::{Deref, DerefMut};

use crate::acadl_core::graph::Ag;
use crate::isa::program::Program;

use super::backend::{BackendKind, SimBackend};
pub use super::kernel::{SimCore, SimError, SimStats};

/// The timing engine for one (AG, program) pair.
pub struct Engine<'a> {
    core: SimCore<'a>,
    backend: BackendKind,
}

impl<'a> Engine<'a> {
    /// Build with the default cycle-stepped backend (reference semantics).
    pub fn new(ag: &'a Ag, program: &'a Program) -> Result<Self, SimError> {
        Self::with_backend(ag, program, BackendKind::default())
    }

    /// Build with an explicit backend.  Both backends produce identical
    /// cycle counts and final architectural state.
    pub fn with_backend(
        ag: &'a Ag,
        program: &'a Program,
        backend: BackendKind,
    ) -> Result<Self, SimError> {
        Ok(Engine {
            core: SimCore::new(ag, program)?,
            backend,
        })
    }

    pub fn backend(&self) -> BackendKind {
        self.backend
    }

    /// Run to completion (halt + drained pipeline) or `max_cycles`.
    pub fn run(&mut self, max_cycles: u64) -> Result<SimStats, SimError> {
        self.backend.instance().run(&mut self.core, max_cycles)
    }
}

impl<'a> Deref for Engine<'a> {
    type Target = SimCore<'a>;

    fn deref(&self) -> &Self::Target {
        &self.core
    }
}

impl<'a> DerefMut for Engine<'a> {
    fn deref_mut(&mut self) -> &mut Self::Target {
        &mut self.core
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::oma::{CacheCfg, DataMem, OmaConfig};
    use crate::isa::assembler::assemble;
    use crate::sim::functional::FunctionalSim;

    fn run_oma(src: &str) -> (SimStats, crate::arch::oma::OmaMachine, Vec<f32>) {
        let m = OmaConfig::default().build().unwrap();
        let p = assemble(&m.ag, src, 0).unwrap();
        let mut e = Engine::new(&m.ag, &p).unwrap();
        let stats = e.run(1_000_000).unwrap();
        let dump = e.mem.dump_f32(m.dmem_base(), 8);
        (stats, m, dump)
    }

    #[test]
    fn straight_line_completes() {
        let (stats, ..) = run_oma(
            "movi #5 => r0\n\
             movi #7 => r1\n\
             add r0, r1 => r2\n\
             halt",
        );
        assert_eq!(stats.retired, 4);
        assert!(stats.cycles >= 4, "at least fetch+decode+execute per instr");
        assert!(stats.cycles < 50, "but pipelined, cycles={}", stats.cycles);
    }

    #[test]
    fn timed_matches_functional_state() {
        let m = OmaConfig::default().build().unwrap();
        let base = m.dmem_base();
        let src = format!(
            "movi #{base} => r10\n\
             movi #3 => r0\n\
             movi #0 => r1\n\
             loop: add r1, r0 => r1\n\
             addi r0, #-1 => r0\n\
             bnei r0, z0, @loop => pc\n\
             store r1 => [r10]\n\
             halt"
        );
        let p = assemble(&m.ag, &src, 0).unwrap();

        let mut f = FunctionalSim::new(&m.ag);
        f.run(&p, 10_000).unwrap();

        let mut e = Engine::new(&m.ag, &p).unwrap();
        e.run(1_000_000).unwrap();

        assert_eq!(
            e.get_reg("r1").unwrap().as_int(),
            f.get_reg(&m.ag, "r1").unwrap().as_int()
        );
        assert_eq!(e.mem.peek(base), f.mem.peek(base));
        assert_eq!(e.mem.peek(base), 6.0); // 3+2+1
    }

    #[test]
    fn branch_taken_squashes_wrong_path() {
        // If the fall-through path executed, r5 would be clobbered.
        let (_, m, _) = run_oma("nop\nhalt");
        let src = "movi #1 => r0\n\
                   beqi r0, r0, @skip => pc\n\
                   movi #99 => r5\n\
                   skip: movi #7 => r6\n\
                   halt";
        let p = assemble(&m.ag, src, 0).unwrap();
        let mut e = Engine::new(&m.ag, &p).unwrap();
        e.run(100_000).unwrap();
        assert_eq!(e.get_reg("r5").unwrap().as_int(), 0, "wrong path squashed");
        assert_eq!(e.get_reg("r6").unwrap().as_int(), 7);
    }

    #[test]
    fn structural_hazard_serializes_execute_stage() {
        // OMA's single ExecuteStage: back-to-back independent adds cannot
        // overlap in the FU — IPC well below 1 on this machine.
        let (stats, ..) = run_oma(
            "movi #1 => r0\n\
             movi #2 => r1\n\
             movi #3 => r2\n\
             movi #4 => r3\n\
             movi #5 => r4\n\
             movi #6 => r5\n\
             halt",
        );
        assert_eq!(stats.retired, 7);
        assert!(stats.ipc() < 1.0, "ipc={}", stats.ipc());
    }

    #[test]
    fn dependency_stall_counted() {
        let (stats, ..) = run_oma(
            "movi #1 => r0\n\
             addi r0, #1 => r0\n\
             addi r0, #1 => r0\n\
             addi r0, #1 => r0\n\
             halt",
        );
        assert_eq!(stats.retired, 5);
        // The chain serializes; engine must not deadlock and must count
        // *some* waiting unless timing hides it completely.
        assert!(stats.cycles > 5);
    }

    #[test]
    fn memory_latency_visible_in_cycles() {
        let fast = OmaConfig {
            dmem: DataMem::Sram { latency: 1 },
            cache: None,
            ..OmaConfig::default()
        }
        .build()
        .unwrap();
        let slow = OmaConfig {
            dmem: DataMem::Sram { latency: 40 },
            cache: None,
            ..OmaConfig::default()
        }
        .build()
        .unwrap();
        let src = format!(
            "movi #{} => r10\n\
             load [r10] => r1\n\
             load [r10+4] => r2\n\
             load [r10+8] => r3\n\
             halt",
            fast.dmem_base()
        );
        let run = |m: &crate::arch::oma::OmaMachine| {
            let p = assemble(&m.ag, &src, 0).unwrap();
            let mut e = Engine::new(&m.ag, &p).unwrap();
            e.run(1_000_000).unwrap().cycles
        };
        let (cf, cs) = (run(&fast), run(&slow));
        assert!(cs > cf + 3 * 30, "fast={cf} slow={cs}");
    }

    #[test]
    fn cache_speeds_up_repeated_access() {
        let cached = OmaConfig {
            dmem: DataMem::Sram { latency: 30 },
            cache: Some(CacheCfg::default()),
            ..OmaConfig::default()
        }
        .build()
        .unwrap();
        let base = cached.dmem_base();
        // 16 loads of the same word: 1 miss + 15 hits.
        let mut src = format!("movi #{base} => r10\n");
        for _ in 0..16 {
            src.push_str("load [r10] => r1\n");
        }
        src.push_str("halt");
        let p = assemble(&cached.ag, &src, 0).unwrap();
        let mut e = Engine::new(&cached.ag, &p).unwrap();
        let stats = e.run(1_000_000).unwrap();
        let c = stats
            .storages
            .iter()
            .find(|s| s.name == "dcache0")
            .unwrap();
        assert_eq!(c.cache_misses, Some(1));
        assert_eq!(c.cache_hits, Some(15));
    }

    #[test]
    fn utilization_counts_only_mac_capable_units() {
        // Loads keep the MAU busy; the mac runs on fu0.  The reported
        // utilization must be fu0's busy fraction alone — the MAU does not
        // dilute it.
        let m = OmaConfig::default().build().unwrap();
        let base = m.dmem_base();
        let src = format!(
            "movi #{base} => r10\n\
             load [r10] => r4\n\
             load [r10+4] => r5\n\
             mac r4, r5 => r6\n\
             halt"
        );
        let p = assemble(&m.ag, &src, 0).unwrap();
        let mut e = Engine::new(&m.ag, &p).unwrap();
        let stats = e.run(100_000).unwrap();
        let fu0 = stats.fu_busy.iter().position(|(n, _)| n == "fu0").unwrap();
        let mau = stats
            .fu_busy
            .iter()
            .position(|(n, _)| n.starts_with("mau"))
            .unwrap();
        assert!(stats.fu_mac_capable[fu0], "fu0 processes mac");
        assert!(!stats.fu_mac_capable[mau], "the MAU is not mac-capable");
        assert!(stats.fu_busy[mau].1 > 0, "loads kept the MAU busy");
        let want = stats.fu_busy[fu0].1 as f64 / stats.cycles as f64;
        assert!(
            (stats.mean_fu_utilization() - want).abs() < 1e-9,
            "utilization {} must equal fu0 busy fraction {want}",
            stats.mean_fu_utilization()
        );
    }

    #[test]
    fn halt_drains_pipeline() {
        let (stats, ..) = run_oma("movi #1 => r0\nhalt");
        assert_eq!(stats.retired, 2, "instruction before halt still retires");
    }

    #[test]
    fn cycle_limit_errors() {
        let m = OmaConfig::default().build().unwrap();
        let p = assemble(&m.ag, "loop: jumpi @loop => pc", 0).unwrap();
        let mut e = Engine::new(&m.ag, &p).unwrap();
        assert!(matches!(e.run(500), Err(SimError::CycleLimit(500, _))));
    }

    // ------------------------------------------------ backend parity

    /// Run `src` on the OMA with both backends and assert identical
    /// cycles, retirements, fetches, stall statistics, and final state.
    fn assert_backend_parity(m: &crate::arch::oma::OmaMachine, src: &str) -> SimStats {
        let p = assemble(&m.ag, src, 0).unwrap();
        let mut cycle = Engine::with_backend(&m.ag, &p, BackendKind::CycleStepped).unwrap();
        let cs = cycle.run(10_000_000).unwrap();
        let mut event = Engine::with_backend(&m.ag, &p, BackendKind::EventDriven).unwrap();
        let es = event.run(10_000_000).unwrap();
        assert_eq!(cs.cycles, es.cycles, "cycle count");
        assert_eq!(cs.retired, es.retired, "retired");
        assert_eq!(cs.fetched, es.fetched, "fetched");
        assert_eq!(cs.fetch_stalls, es.fetch_stalls, "fetch stalls");
        assert_eq!(cs.dep_stall_cycles, es.dep_stall_cycles, "dep stalls");
        assert_eq!(
            cs.structural_stall_cycles, es.structural_stall_cycles,
            "structural stalls"
        );
        assert_eq!(cs.fu_busy, es.fu_busy, "fu busy cycles");
        assert_eq!(cycle.regs, event.regs, "final registers");
        for w in 0..32u64 {
            let a = m.dmem_base() + w * 4;
            assert_eq!(cycle.mem.peek(a), event.mem.peek(a), "mem[{a:#x}]");
        }
        es
    }

    #[test]
    fn event_backend_matches_on_branchy_loop() {
        let m = OmaConfig::default().build().unwrap();
        let base = m.dmem_base();
        let src = format!(
            "movi #{base} => r10\n\
             movi #5 => r0\n\
             movi #0 => r1\n\
             loop: add r1, r0 => r1\n\
             addi r0, #-1 => r0\n\
             bnei r0, z0, @loop => pc\n\
             store r1 => [r10]\n\
             halt"
        );
        assert_backend_parity(&m, &src);
    }

    #[test]
    fn event_backend_matches_on_slow_memory() {
        // 40-cycle SRAM: the event backend must skip the stall windows yet
        // report the exact same numbers.
        let m = OmaConfig {
            dmem: DataMem::Sram { latency: 40 },
            cache: None,
            ..OmaConfig::default()
        }
        .build()
        .unwrap();
        let base = m.dmem_base();
        let src = format!(
            "movi #{base} => r10\n\
             movi #3 => r1\n\
             store r1 => [r10]\n\
             load [r10] => r2\n\
             load [r10+4] => r3\n\
             add r2, r3 => r4\n\
             store r4 => [r10+8]\n\
             halt"
        );
        let stats = assert_backend_parity(&m, &src);
        assert!(stats.cycles > 200, "memory latency dominates: {stats:?}");
    }

    #[test]
    fn event_backend_matches_on_dram() {
        let m = OmaConfig {
            dmem: DataMem::Dram,
            cache: None,
            ..OmaConfig::default()
        }
        .build()
        .unwrap();
        let base = m.dmem_base();
        let mut src = format!("movi #{base} => r10\nmovi #2 => r1\n");
        for i in 0..8u64 {
            src.push_str(&format!("store r1 => [r10+{}]\n", i * 4));
        }
        src.push_str("halt");
        assert_backend_parity(&m, &src);
    }

    #[test]
    fn event_backend_cycle_limit_matches() {
        let m = OmaConfig::default().build().unwrap();
        let p = assemble(&m.ag, "loop: jumpi @loop => pc", 0).unwrap();
        let mut e = Engine::with_backend(&m.ag, &p, BackendKind::EventDriven).unwrap();
        assert!(matches!(e.run(500), Err(SimError::CycleLimit(500, _))));
    }
}
