//! Pluggable simulation backends: schedulers that drive a
//! [`SimCore`](crate::sim::kernel::SimCore) to completion.
//!
//! Both backends execute the *same* per-object state machines
//! ([`SimCore::step`]) and therefore produce identical cycle counts,
//! retirement counts, and final architectural state — asserted by the
//! backend-equivalence tests.  They differ only in how the clock advances:
//!
//! * [`CycleStepped`] — the classical loop: one `step()` per simulated
//!   cycle, plus a no-progress window that reports deadlocks.  Fastest
//!   when almost every cycle does work (dense scalar pipelines).
//! * [`EventDriven`] — a binary-heap event queue of scheduled timer
//!   expiries (FU completions, stage buffering expiries, fetch
//!   transactions).  After any *quiescent* step (no state change beyond
//!   timer decrements), the clock jumps straight to the next scheduled
//!   event via [`SimCore::advance_bulk`] instead of replaying idle
//!   retries.  Wins big on memory-bound workloads where objects stall for
//!   tens of cycles on DRAM t_RCD/t_RP/t_RAS or long MAC-array latencies.
//!
//! Backend selection threads through the stack as a [`BackendKind`]: the
//! coordinator's `JobSpec`, the DNN schedule runner's `SimMode`, the CLI's
//! `--backend` flag, and `Engine::with_backend`.

use std::cmp::Reverse;

use super::kernel::{SimCore, SimError, SimStats, DEADLOCK_WINDOW};
use crate::util::cancel::{self, CancelCause, CancelToken};

/// The per-loop cancellation probe.  Obtained once before the step loop
/// (`CancelProbe::new` reads the thread-local a single time), then
/// polled per iteration: with no token installed the poll is one branch
/// on a `None` held in a register — the hot path stays allocation- and
/// syscall-free.  With a token, the countdown defers the (compara-
/// tively costly) `Instant::now` to every
/// [`cancel::CHECK_INTERVAL_STEPS`] steps, bounding deadline overshoot
/// to one check interval.
struct CancelProbe {
    token: Option<CancelToken>,
    until_check: u64,
}

impl CancelProbe {
    fn new() -> Self {
        CancelProbe {
            token: cancel::current(),
            // First check on the first step: a run whose budget already
            // expired (deadline_ms = 0, pre-cancelled token) must stop
            // even when the whole program is shorter than one interval.
            until_check: 1,
        }
    }

    #[inline]
    fn poll(&mut self, core: &SimCore) -> Result<(), SimError> {
        let Some(token) = &self.token else {
            return Ok(());
        };
        self.until_check -= 1;
        if self.until_check > 0 {
            return Ok(());
        }
        self.until_check = cancel::CHECK_INTERVAL_STEPS;
        match token.cause() {
            None => Ok(()),
            Some(CancelCause::Deadline) => Err(SimError::Deadline {
                cycle: core.t,
                retired: core.stats.retired,
            }),
            Some(CancelCause::Cancelled) => Err(SimError::Cancelled {
                cycle: core.t,
                retired: core.stats.retired,
            }),
        }
    }
}

/// A scheduler for the shared simulation kernel.
pub trait SimBackend {
    /// Short stable name (CLI flags, job JSON, bench labels).
    fn name(&self) -> &'static str;

    /// Run `core` until the machine drains (halt + empty pipeline) or
    /// `max_cycles` is reached.
    fn run(&self, core: &mut SimCore, max_cycles: u64) -> Result<SimStats, SimError>;
}

/// One `step()` per simulated cycle (the paper's reference semantics).
pub struct CycleStepped;

impl SimBackend for CycleStepped {
    fn name(&self) -> &'static str {
        "cycle"
    }

    fn run(&self, core: &mut SimCore, max_cycles: u64) -> Result<SimStats, SimError> {
        let mut probe = CancelProbe::new();
        let mut last_progress = (core.t, core.stats.retired, core.stats.fetched);
        while !core.idle() {
            if core.t >= max_cycles {
                return Err(SimError::CycleLimit(max_cycles, core.stats.retired));
            }
            probe.poll(core)?;
            core.step()?;
            if (core.stats.retired, core.stats.fetched) != (last_progress.1, last_progress.2) {
                last_progress = (core.t, core.stats.retired, core.stats.fetched);
            } else if core.t - last_progress.0 > DEADLOCK_WINDOW {
                return Err(SimError::Deadlock {
                    cycle: core.t,
                    retired: core.stats.retired,
                    window: DEADLOCK_WINDOW,
                });
            }
        }
        Ok(core.finish_stats())
    }
}

/// Event-queue scheduler: advances `T` directly to the next scheduled
/// completion after quiescent steps.
pub struct EventDriven;

impl SimBackend for EventDriven {
    fn name(&self) -> &'static str {
        "event"
    }

    fn run(&self, core: &mut SimCore, max_cycles: u64) -> Result<SimStats, SimError> {
        core.collect_events = true;
        let mut probe = CancelProbe::new();
        while !core.idle() {
            if core.t >= max_cycles {
                return Err(SimError::CycleLimit(max_cycles, core.stats.retired));
            }
            probe.poll(core)?;
            core.activity = false;
            core.step()?;
            if core.activity {
                // State changed: cascades may continue next cycle.
                continue;
            }
            // Quiescent: every pending timer has an entry in the event
            // queue, so nothing can change before its minimum.  Drop
            // events that executed steps already passed (including
            // squashed fetch transactions — spurious wake-ups are no-op
            // steps, never missed work).
            let now = core.t;
            while matches!(core.events.peek(), Some(&Reverse(e)) if e < now) {
                core.events.pop();
            }
            match core.events.peek() {
                Some(&Reverse(e)) if e > now => {
                    // Consume the event we are jumping to — and every
                    // duplicate scheduled for the same cycle (same-cycle
                    // FU completions, squashed fetch transactions) — so
                    // dead entries never trigger a second wake-up or
                    // bloat the heap.  The step at `e` services all
                    // timers due then regardless of heap contents.
                    while matches!(core.events.peek(), Some(&Reverse(x)) if x == e) {
                        core.events.pop();
                    }
                    // Clamp to the cycle limit so a CycleLimit error
                    // reports the same retirement count as cycle-stepped.
                    let dt = e.min(max_cycles).saturating_sub(now);
                    if dt > 0 {
                        core.advance_bulk(dt);
                    }
                }
                Some(_) => {} // an event is due this very cycle: step again
                None => {
                    // Not idle, quiescent, and no scheduled event: the
                    // remaining instructions wait on dependencies that can
                    // never resolve.
                    return Err(SimError::Deadlock {
                        cycle: core.t,
                        retired: core.stats.retired,
                        window: 0,
                    });
                }
            }
        }
        Ok(core.finish_stats())
    }
}

/// The backend behind platform-parallel runs.  A *single* core's
/// simulation is inherently sequential, so for one machine this is
/// exactly [`EventDriven`]; the parallelism lives one level up, in
/// [`crate::sim::platform::run_platform`], which fans independent
/// microbatch chains (each a sequence of these single-core runs) across
/// worker threads.  Keeping it a [`SimBackend`] lets job specs, CLI
/// flags, and the DSE axes name it like any other scheduler — and the
/// backend-equivalence oracle pins it to the reference semantics.
pub struct ParallelEvent;

impl SimBackend for ParallelEvent {
    fn name(&self) -> &'static str {
        "parallel"
    }

    fn run(&self, core: &mut SimCore, max_cycles: u64) -> Result<SimStats, SimError> {
        EventDriven.run(core, max_cycles)
    }
}

/// Value-level backend selector (job specs, CLI flags, JSON wire format).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, Default)]
pub enum BackendKind {
    /// One engine step per cycle (reference semantics).
    #[default]
    CycleStepped,
    /// Idle-cycle-skipping event queue (identical results, faster on
    /// memory-bound workloads).
    EventDriven,
    /// Event-driven per core, with platform microbatch chains fanned
    /// across threads (identical cycle counts at any thread count).
    ParallelEvent,
}

impl BackendKind {
    pub const ALL: [BackendKind; 3] = [
        BackendKind::CycleStepped,
        BackendKind::EventDriven,
        BackendKind::ParallelEvent,
    ];

    pub fn name(self) -> &'static str {
        self.instance().name()
    }

    /// Parse a CLI/JSON spelling (`cycle`, `cycle-stepped`, `event`,
    /// `event-driven`, `parallel`, `parallel-event`).
    pub fn from_name(s: &str) -> Option<Self> {
        match s {
            "cycle" | "cycle-stepped" | "cycle_stepped" => Some(BackendKind::CycleStepped),
            "event" | "event-driven" | "event_driven" => Some(BackendKind::EventDriven),
            "parallel" | "parallel-event" | "parallel_event" => Some(BackendKind::ParallelEvent),
            _ => None,
        }
    }

    /// The backend implementation for this selector.
    pub fn instance(self) -> &'static dyn SimBackend {
        match self {
            BackendKind::CycleStepped => &CycleStepped,
            BackendKind::EventDriven => &EventDriven,
            BackendKind::ParallelEvent => &ParallelEvent,
        }
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::oma::{DataMem, OmaConfig};
    use crate::isa::assembler::assemble;

    /// Squashed fetches leave dead entries in the event heap; they are
    /// drained at pop time, so a branch-heavy loop must not make the event
    /// backend take spurious extra steps or report different stall
    /// statistics than the cycle-stepped reference.
    #[test]
    fn branchy_loop_has_no_spurious_event_steps() {
        let m = OmaConfig::default().build().unwrap();
        let base = m.dmem_base();
        // Tight countdown loop: every taken branch squashes an in-flight
        // wrong-path fetch, leaving a dead event behind.
        let src = format!(
            "movi #{base} => r10\n\
             movi #12 => r0\n\
             movi #0 => r1\n\
             loop: add r1, r0 => r1\n\
             addi r0, #-1 => r0\n\
             bnei r0, z0, @loop => pc\n\
             store r1 => [r10]\n\
             halt"
        );
        let p = assemble(&m.ag, &src, 0).unwrap();

        let mut cycle_core = SimCore::new(&m.ag, &p).unwrap();
        let cs = CycleStepped.run(&mut cycle_core, 1_000_000).unwrap();
        let mut event_core = SimCore::new(&m.ag, &p).unwrap();
        let es = EventDriven.run(&mut event_core, 1_000_000).unwrap();

        assert_eq!(cs.cycles, es.cycles, "cycle count");
        assert_eq!(cs.fetched, es.fetched, "fetched");
        assert_eq!(cs.fetch_stalls, es.fetch_stalls, "fetch stalls");
        assert_eq!(cs.dep_stall_cycles, es.dep_stall_cycles, "dep stalls");
        assert_eq!(
            cs.structural_stall_cycles, es.structural_stall_cycles,
            "structural stalls"
        );
        assert!(
            event_core.steps_executed <= cycle_core.steps_executed,
            "event backend stepped {} times vs {} cycles — dead heap \
             entries caused spurious wake-up steps",
            event_core.steps_executed,
            cycle_core.steps_executed
        );
    }

    /// On a long-stall workload the event backend must actually skip: far
    /// fewer steps than simulated cycles, with identical reported numbers.
    #[test]
    fn event_backend_skips_stall_windows() {
        let m = OmaConfig {
            dmem: DataMem::Sram { latency: 60 },
            cache: None,
            ..OmaConfig::default()
        }
        .build()
        .unwrap();
        let base = m.dmem_base();
        let src = format!(
            "movi #{base} => r10\n\
             load [r10] => r1\n\
             load [r10+4] => r2\n\
             add r1, r2 => r3\n\
             store r3 => [r10+8]\n\
             halt"
        );
        let p = assemble(&m.ag, &src, 0).unwrap();
        let mut cycle_core = SimCore::new(&m.ag, &p).unwrap();
        let cs = CycleStepped.run(&mut cycle_core, 1_000_000).unwrap();
        let mut event_core = SimCore::new(&m.ag, &p).unwrap();
        let es = EventDriven.run(&mut event_core, 1_000_000).unwrap();
        assert_eq!(cs.cycles, es.cycles);
        assert_eq!(cs.dep_stall_cycles, es.dep_stall_cycles);
        assert!(
            event_core.steps_executed < cs.cycles / 2,
            "expected idle-cycle skipping: {} steps for {} cycles",
            event_core.steps_executed,
            cs.cycles
        );
    }

    /// A program long enough that every backend crosses several
    /// cancellation check intervals before draining.
    fn long_program() -> (crate::arch::oma::OmaMachine, crate::isa::program::Program) {
        let m = OmaConfig::default().build().unwrap();
        let base = m.dmem_base();
        let src = format!(
            "movi #{base} => r10\n\
             movi #20000 => r0\n\
             movi #0 => r1\n\
             loop: add r1, r0 => r1\n\
             addi r0, #-1 => r0\n\
             bnei r0, z0, @loop => pc\n\
             store r1 => [r10]\n\
             halt"
        );
        let p = assemble(&m.ag, &src, 0).unwrap();
        (m, p)
    }

    #[test]
    fn expired_deadline_stops_both_backends() {
        use crate::util::cancel;
        let (m, p) = long_program();
        for kind in [BackendKind::CycleStepped, BackendKind::EventDriven] {
            let _g = cancel::install(cancel::CancelToken::with_deadline(
                std::time::Duration::from_millis(0),
            ));
            let mut core = SimCore::new(&m.ag, &p).unwrap();
            let err = kind.instance().run(&mut core, 10_000_000).unwrap_err();
            assert!(
                matches!(err, SimError::Deadline { .. }),
                "{kind}: expected Deadline, got {err}"
            );
            // The loop stopped within one check interval of the first
            // poll opportunity, not at the cycle limit.
            assert!(
                core.t < 10_000_000,
                "{kind}: ran to the cycle limit despite an expired deadline"
            );
        }
    }

    #[test]
    fn cancelled_token_stops_the_run_and_reruns_are_unaffected() {
        use crate::util::cancel;
        let (m, p) = long_program();
        // Clean reference run, no token anywhere.
        let mut clean = SimCore::new(&m.ag, &p).unwrap();
        let reference = CycleStepped.run(&mut clean, 10_000_000).unwrap();

        let tok = cancel::CancelToken::new();
        tok.cancel();
        {
            let _g = cancel::install(tok);
            let mut core = SimCore::new(&m.ag, &p).unwrap();
            let err = CycleStepped.run(&mut core, 10_000_000).unwrap_err();
            assert!(matches!(err, SimError::Cancelled { .. }), "got {err}");
        }
        // Guard dropped: the next run on this thread sees no token and
        // reproduces the clean cycle count exactly.
        let mut rerun = SimCore::new(&m.ag, &p).unwrap();
        let stats = CycleStepped.run(&mut rerun, 10_000_000).unwrap();
        assert_eq!(stats.cycles, reference.cycles);
        assert_eq!(stats.retired, reference.retired);
    }

    #[test]
    fn kind_names_roundtrip() {
        for k in BackendKind::ALL {
            assert_eq!(BackendKind::from_name(k.name()), Some(k));
        }
        assert_eq!(
            BackendKind::from_name("event-driven"),
            Some(BackendKind::EventDriven)
        );
        assert_eq!(BackendKind::from_name("nope"), None);
        assert_eq!(BackendKind::default(), BackendKind::CycleStepped);
    }
}
