//! Structured simulation tracing: spans and counter tracks recorded by
//! the kernel, exported as Chrome-trace JSON (loads in Perfetto /
//! chrome://tracing).
//!
//! # The sink seam
//!
//! [`TraceSink`] is the event interface the kernel and storage model emit
//! into.  Every method has a no-op default body, so a sink pays only for
//! what it overrides; [`NullSink`] overrides nothing and monomorphizes to
//! zero code.  The kernel holds `Option<Box<Recorder>>` — the disabled
//! path is a single `None` branch per step (the same budget as the
//! cancellation probe), no allocation, no virtual dispatch, and
//! [`crate::sim::kernel::SimCore::advance_bulk`] needs *no* trace code at
//! all (see below), so the event-driven backend's skip path is untouched.
//!
//! # What gets recorded
//!
//! * **FU spans** — one [`FuSpan`] per instruction occupancy of a
//!   functional unit, recorded at dispatch with its full duration
//!   (`t_left`).  Because the span carries absolute `(start, dur)` and
//!   `busy_cycles` accrues exactly `dur` over the occupancy, span sums
//!   reconcile bit-exactly with `SimStats::fu_busy`.
//! * **Port spans** — one [`PortSpan`] per storage-port transaction
//!   (SRAM/cache) or per DRAM burst (contiguous, so per-port sums still
//!   equal the storage's `busy_cycles`), tagged with the granted request
//!   slot so concurrent requests land on distinct tracks.
//! * **Counter tracks** — per-cycle dep/structural/fetch stall charge and
//!   issue-buffer depth, sampled *on change only*.  Change-only sampling
//!   is what makes traces backend-identical: between events every charge
//!   is provably constant (that is the quiescence invariant), so the
//!   cycle-stepped backend (which evaluates every cycle) and the
//!   event-driven backend (which evaluates only executed steps) emit the
//!   same sample list — the skipped windows need no synthesis beyond
//!   "nothing changed".  Integrating a track as a step function over
//!   `[0, cycles)` reproduces the corresponding `SimStats` total exactly.
//!
//! Platform runs get their own [`PlatformTrace`]: per-chip compute cells,
//! shared-DRAM streams (weights, inputs, writeback), and fabric
//! transfers, derived from the conservative timing recurrence — identical
//! at every thread count for the same reason the cycle counts are.

use crate::util::json::Json;

/// Event interface the simulation emits into.  Default bodies are no-ops;
/// a disabled sink costs nothing.
pub trait TraceSink {
    /// An instruction occupied functional unit `fu` for `dur` cycles
    /// starting at `start`, executing opcode `op`.
    fn fu_span(&mut self, fu: u32, op: &'static str, start: u64, dur: u64) {
        let _ = (fu, op, start, dur);
    }

    /// Per-cycle counter values for cycle `t`: the dep/structural/fetch
    /// stall charge of this cycle and the issue-buffer depth after it.
    fn counters(&mut self, t: u64, dep: u64, structural: u64, fetch: u64, buffer: u64) {
        let _ = (t, dep, structural, fetch, buffer);
    }

    /// A storage-port transaction (or DRAM burst) completed.
    fn port_span(&mut self, span: PortSpan) {
        let _ = span;
    }
}

/// The zero-cost disabled sink: overrides nothing, records nothing.
#[derive(Debug, Clone, Copy, Default)]
pub struct NullSink;

impl TraceSink for NullSink {}

/// One instruction occupancy of a functional unit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FuSpan {
    /// Functional-unit index (into [`TraceData::fu_names`]).
    pub fu: u32,
    pub op: &'static str,
    pub start: u64,
    pub dur: u64,
}

/// One storage-port transaction (or one DRAM burst of a transaction).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PortSpan {
    /// Storage index (into [`TraceData::storage_names`]).
    pub storage: u32,
    /// Request slot the transaction was granted.
    pub slot: u32,
    pub write: bool,
    /// True for DRAM bursts (several contiguous spans per transaction).
    pub burst: bool,
    pub addr: u64,
    pub start: u64,
    pub end: u64,
}

/// A change-only sampled counter track: `(cycle, value)` with an implicit
/// initial value of 0 at cycle 0; each value holds until the next sample.
pub type CounterTrack = Vec<(u64, u64)>;

/// A finalized recording: everything needed to reconcile against
/// [`crate::sim::kernel::SimStats`] or export Chrome-trace JSON.
/// Derives `PartialEq` — trace equality is a backend-equivalence oracle.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TraceData {
    /// Total simulated cycles (the timeline end).
    pub cycles: u64,
    /// Functional-unit names, indexed by [`FuSpan::fu`].
    pub fu_names: Vec<String>,
    /// Storage names, indexed by [`PortSpan::storage`].
    pub storage_names: Vec<String>,
    pub fu_spans: Vec<FuSpan>,
    pub port_spans: Vec<PortSpan>,
    /// Per-cycle dependency-stall charge (integrates to
    /// `dep_stall_cycles`).
    pub dep_stall: CounterTrack,
    /// Per-cycle structural-stall charge (integrates to
    /// `structural_stall_cycles`).
    pub structural_stall: CounterTrack,
    /// Per-cycle fetch-stall charge (integrates to `fetch_stalls`).
    pub fetch_stall: CounterTrack,
    /// Issue-buffer depth after each cycle.
    pub issue_buffer: CounterTrack,
}

/// Integrate a change-only counter track as a step function over
/// `[0, end)`.
pub fn integrate(track: &[(u64, u64)], end: u64) -> u64 {
    let mut total = 0u64;
    let mut last_t = 0u64;
    let mut last_v = 0u64;
    for &(t, v) in track {
        total += last_v * t.saturating_sub(last_t);
        last_t = t;
        last_v = v;
    }
    total + last_v * end.saturating_sub(last_t)
}

fn push_changed(track: &mut CounterTrack, t: u64, v: u64, last: &mut u64) {
    if v != *last {
        track.push((t, v));
        *last = v;
    }
}

/// Index of `name` in the table, interning it at the end if absent.
fn intern(names: &mut Vec<String>, name: &str) -> u32 {
    match names.iter().position(|n| n == name) {
        Some(i) => i as u32,
        None => {
            names.push(name.to_string());
            (names.len() - 1) as u32
        }
    }
}

/// Close a track back to 0 at `t` (schedule-concatenation boundary).
fn close_track(track: &mut CounterTrack, t: u64) {
    if track.last().is_some_and(|&(_, v)| v != 0) {
        track.push((t, 0));
    }
}

impl TraceData {
    /// Span-duration sum per functional unit — must equal
    /// `SimStats::fu_busy` exactly.
    pub fn fu_busy_totals(&self) -> Vec<u64> {
        let mut totals = vec![0u64; self.fu_names.len()];
        for s in &self.fu_spans {
            totals[s.fu as usize] += s.dur;
        }
        totals
    }

    /// Span-duration sum per storage — must equal each storage's
    /// `busy_cycles` exactly (DRAM bursts are contiguous sub-spans).
    pub fn storage_busy_totals(&self) -> Vec<u64> {
        let mut totals = vec![0u64; self.storage_names.len()];
        for s in &self.port_spans {
            totals[s.storage as usize] += s.end - s.start;
        }
        totals
    }

    /// Derived outstanding-requests counter for one storage: a sweep over
    /// its port spans (+1 at start, −1 at end; ends process first so
    /// FIFO-queued back-to-back spans don't inflate the level).
    pub fn outstanding(&self, storage: u32) -> CounterTrack {
        let mut deltas: Vec<(u64, i64)> = Vec::new();
        for s in self.port_spans.iter().filter(|s| s.storage == storage) {
            deltas.push((s.start, 1));
            deltas.push((s.end, -1));
        }
        deltas.sort_unstable();
        let mut out: CounterTrack = Vec::new();
        let mut level = 0i64;
        let mut i = 0;
        while i < deltas.len() {
            let t = deltas[i].0;
            while i < deltas.len() && deltas[i].0 == t {
                level += deltas[i].1;
                i += 1;
            }
            let v = level.max(0) as u64;
            if out.last().map(|&(_, x)| x) != Some(v) {
                out.push((t, v));
            }
        }
        out
    }

    /// Append another run's trace shifted by `offset` cycles — sequential
    /// schedule concatenation (one engine run per mapped layer).  Counter
    /// tracks are closed to 0 at the boundary.  When the two runs
    /// describe different machines (heterogeneous platform stages), the
    /// other trace's FU/storage names are interned into this trace's
    /// tables and its span indices remapped — a span is never silently
    /// attributed to the wrong unit.
    pub fn append_offset(&mut self, mut other: TraceData, offset: u64) {
        if self.fu_names.is_empty() && self.storage_names.is_empty() {
            self.fu_names = std::mem::take(&mut other.fu_names);
            self.storage_names = std::mem::take(&mut other.storage_names);
        } else if self.fu_names != other.fu_names || self.storage_names != other.storage_names {
            let fu_map: Vec<u32> = other
                .fu_names
                .iter()
                .map(|n| intern(&mut self.fu_names, n))
                .collect();
            let st_map: Vec<u32> = other
                .storage_names
                .iter()
                .map(|n| intern(&mut self.storage_names, n))
                .collect();
            for s in &mut other.fu_spans {
                s.fu = fu_map[s.fu as usize];
            }
            for s in &mut other.port_spans {
                s.storage = st_map[s.storage as usize];
            }
        }
        for s in &mut other.fu_spans {
            s.start += offset;
        }
        self.fu_spans.append(&mut other.fu_spans);
        for s in &mut other.port_spans {
            s.start += offset;
            s.end += offset;
        }
        self.port_spans.append(&mut other.port_spans);
        for (dst, src) in [
            (&mut self.dep_stall, other.dep_stall),
            (&mut self.structural_stall, other.structural_stall),
            (&mut self.fetch_stall, other.fetch_stall),
            (&mut self.issue_buffer, other.issue_buffer),
        ] {
            close_track(dst, offset);
            dst.extend(src.into_iter().map(|(t, v)| (t + offset, v)));
        }
        self.cycles = offset + other.cycles;
    }
}

/// The concrete recording sink the kernel installs.
#[derive(Debug, Clone, Default)]
pub struct Recorder {
    data: TraceData,
    /// Last emitted value per counter track (change detection); tracks
    /// start at an implicit 0.
    last: [u64; 4],
}

impl Recorder {
    pub fn into_data(self) -> TraceData {
        self.data
    }
}

impl TraceSink for Recorder {
    fn fu_span(&mut self, fu: u32, op: &'static str, start: u64, dur: u64) {
        self.data.fu_spans.push(FuSpan { fu, op, start, dur });
    }

    fn counters(&mut self, t: u64, dep: u64, structural: u64, fetch: u64, buffer: u64) {
        push_changed(&mut self.data.dep_stall, t, dep, &mut self.last[0]);
        push_changed(&mut self.data.structural_stall, t, structural, &mut self.last[1]);
        push_changed(&mut self.data.fetch_stall, t, fetch, &mut self.last[2]);
        push_changed(&mut self.data.issue_buffer, t, buffer, &mut self.last[3]);
    }

    fn port_span(&mut self, span: PortSpan) {
        self.data.port_spans.push(span);
    }
}

// ------------------------------------------------------------ platform

/// One `(stage, microbatch)` compute occupancy on a platform chip.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CellSpan {
    pub stage: u32,
    pub microbatch: u32,
    pub start: u64,
    pub end: u64,
}

/// A named transfer span (DRAM stream or fabric hop).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct XferSpan {
    pub name: String,
    pub start: u64,
    pub end: u64,
}

/// Trace of a platform run, derived from the conservative timing
/// recurrence — bit-identical at every worker thread count.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PlatformTrace {
    /// Per-stage chip labels (`machine[start..end]`), indexed by
    /// [`CellSpan::stage`].
    pub chips: Vec<String>,
    pub cells: Vec<CellSpan>,
    /// Weight streaming over the shared DRAM channel (one span per
    /// stage, serial).
    pub weights: Vec<XferSpan>,
    /// Input microbatch loads over the shared DRAM channel.
    pub inputs: Vec<XferSpan>,
    /// Output writeback over the shared DRAM channel.
    pub writeback: Vec<XferSpan>,
    /// Inter-chip fabric transfers.
    pub fabric: Vec<XferSpan>,
    pub total_cycles: u64,
}

impl PlatformTrace {
    /// Cell-duration sum per stage — must equal each
    /// `StageReport::busy_cycles` exactly.
    pub fn stage_busy_totals(&self) -> Vec<u64> {
        let mut totals = vec![0u64; self.chips.len()];
        for c in &self.cells {
            totals[c.stage as usize] += c.end - c.start;
        }
        totals
    }
}

// ------------------------------------------------- Chrome-trace export

fn n(v: u64) -> Json {
    Json::Num(v as f64)
}

fn meta_process(pid: u64, name: &str) -> Json {
    Json::obj(vec![
        ("ph", Json::str("M")),
        ("pid", n(pid)),
        ("name", Json::str("process_name")),
        ("args", Json::obj(vec![("name", Json::str(name))])),
    ])
}

fn meta_thread(pid: u64, tid: u64, name: &str) -> Json {
    Json::obj(vec![
        ("ph", Json::str("M")),
        ("pid", n(pid)),
        ("tid", n(tid)),
        ("name", Json::str("thread_name")),
        ("args", Json::obj(vec![("name", Json::str(name))])),
    ])
}

fn complete_event(pid: u64, tid: u64, name: &str, start: u64, dur: u64, args: Option<Json>) -> Json {
    let mut fields = vec![
        ("ph", Json::str("X")),
        ("pid", n(pid)),
        ("tid", n(tid)),
        ("ts", n(start)),
        ("dur", n(dur)),
        ("name", Json::str(name)),
    ];
    if let Some(a) = args {
        fields.push(("args", a));
    }
    Json::obj(fields)
}

fn counter_events(events: &mut Vec<Json>, pid: u64, name: &str, track: &[(u64, u64)]) {
    for &(t, v) in track {
        events.push(Json::obj(vec![
            ("ph", Json::str("C")),
            ("pid", n(pid)),
            ("tid", n(0)),
            ("ts", n(t)),
            ("name", Json::str(name)),
            ("args", Json::obj(vec![("value", n(v))])),
        ]));
    }
}

/// Chrome-trace JSON for a single-machine recording: pid 1 is the core
/// (one track per FU, plus stall/occupancy counters), pid 2 is the
/// storage subsystem (one track per request slot, plus
/// outstanding-request counters).  One cycle = one microsecond tick.
pub fn chrome_trace_json(d: &TraceData) -> Json {
    let mut events = vec![meta_process(1, "core"), meta_process(2, "storage")];
    for (i, name) in d.fu_names.iter().enumerate() {
        events.push(meta_thread(1, i as u64 + 1, name));
    }
    for s in &d.fu_spans {
        events.push(complete_event(1, s.fu as u64 + 1, s.op, s.start, s.dur, None));
    }
    counter_events(&mut events, 1, "dep_stall", &d.dep_stall);
    counter_events(&mut events, 1, "structural_stall", &d.structural_stall);
    counter_events(&mut events, 1, "fetch_stall", &d.fetch_stall);
    counter_events(&mut events, 1, "issue_buffer", &d.issue_buffer);

    // One storage track per (storage, request slot) pair with activity.
    let mut tracks: Vec<(u32, u32)> = d.port_spans.iter().map(|s| (s.storage, s.slot)).collect();
    tracks.sort_unstable();
    tracks.dedup();
    for (i, &(st, slot)) in tracks.iter().enumerate() {
        let label = format!("{}.p{}", d.storage_names[st as usize], slot);
        events.push(meta_thread(2, i as u64 + 1, &label));
    }
    for s in &d.port_spans {
        let tid = tracks.binary_search(&(s.storage, s.slot)).unwrap() as u64 + 1;
        let name = if s.write { "wr" } else { "rd" };
        let args = Json::obj(vec![
            ("addr", Json::str(format!("{:#x}", s.addr))),
            ("burst", Json::Bool(s.burst)),
        ]);
        events.push(complete_event(2, tid, name, s.start, s.end - s.start, Some(args)));
    }
    let mut storages: Vec<u32> = tracks.iter().map(|&(st, _)| st).collect();
    storages.dedup();
    for st in storages {
        let name = format!("outstanding {}", d.storage_names[st as usize]);
        counter_events(&mut events, 2, &name, &d.outstanding(st));
    }
    Json::obj(vec![
        ("displayTimeUnit", Json::str("ns")),
        ("traceEvents", Json::Arr(events)),
    ])
}

/// Chrome-trace JSON for a platform run: pid 1 is the platform fabric
/// (shared-DRAM streams and inter-chip transfers on separate tracks —
/// the recurrence lets them overlap, so each stream gets its own), and
/// one pid (track group) per chip from pid 2 up.
pub fn chrome_trace_platform_json(p: &PlatformTrace) -> Json {
    let mut events = vec![
        meta_process(1, "platform"),
        meta_thread(1, 1, "dram weights"),
        meta_thread(1, 2, "dram inputs"),
        meta_thread(1, 3, "dram writeback"),
        meta_thread(1, 4, "fabric"),
    ];
    for (tid, spans) in [
        (1u64, &p.weights),
        (2, &p.inputs),
        (3, &p.writeback),
        (4, &p.fabric),
    ] {
        for s in spans {
            events.push(complete_event(1, tid, &s.name, s.start, s.end - s.start, None));
        }
    }
    for (s, chip) in p.chips.iter().enumerate() {
        let pid = s as u64 + 2;
        events.push(meta_process(pid, chip));
        events.push(meta_thread(pid, 1, "compute"));
    }
    for c in &p.cells {
        let name = format!("mb{}", c.microbatch);
        events.push(complete_event(c.stage as u64 + 2, 1, &name, c.start, c.end - c.start, None));
    }
    Json::obj(vec![
        ("displayTimeUnit", Json::str("ns")),
        ("traceEvents", Json::Arr(events)),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_trace() -> TraceData {
        TraceData {
            cycles: 20,
            fu_names: vec!["fu0".into(), "mau0".into()],
            storage_names: vec!["dmem".into()],
            fu_spans: vec![
                FuSpan { fu: 0, op: "mac", start: 2, dur: 3 },
                FuSpan { fu: 0, op: "mac", start: 7, dur: 2 },
                FuSpan { fu: 1, op: "load", start: 1, dur: 6 },
            ],
            port_spans: vec![
                PortSpan { storage: 0, slot: 0, write: false, burst: false, addr: 0x10, start: 1, end: 5 },
                PortSpan { storage: 0, slot: 1, write: true, burst: true, addr: 0x20, start: 3, end: 6 },
            ],
            dep_stall: vec![(2, 1), (5, 0)],
            structural_stall: vec![(4, 2), (6, 0)],
            fetch_stall: vec![(10, 1), (12, 0)],
            issue_buffer: vec![(0, 2), (8, 0)],
        }
    }

    #[test]
    fn busy_totals_sum_span_durations() {
        let d = sample_trace();
        assert_eq!(d.fu_busy_totals(), vec![5, 6]);
        assert_eq!(d.storage_busy_totals(), vec![7]);
    }

    #[test]
    fn integrate_is_a_step_function_with_tail() {
        // 0 until 2, 1 over [2,5), 0 after: integral 3.
        assert_eq!(integrate(&[(2, 1), (5, 0)], 20), 3);
        // Tail segment extends to the end.
        assert_eq!(integrate(&[(2, 1)], 10), 8);
        assert_eq!(integrate(&[], 10), 0);
        // Implicit initial 0 before the first sample.
        assert_eq!(integrate(&[(0, 2), (8, 0)], 20), 16);
    }

    #[test]
    fn recorder_samples_on_change_only() {
        let mut r = Recorder::default();
        r.counters(0, 0, 0, 0, 2);
        r.counters(1, 0, 0, 0, 2); // no change: no samples
        r.counters(2, 1, 0, 0, 2);
        r.counters(3, 1, 0, 0, 1);
        r.counters(4, 0, 0, 0, 1);
        let d = r.into_data();
        assert_eq!(d.dep_stall, vec![(2, 1), (4, 0)]);
        assert_eq!(d.issue_buffer, vec![(0, 2), (3, 1)]);
        assert!(d.structural_stall.is_empty());
        // Integrals reproduce the per-cycle sums: dep charged at 2 and 3.
        assert_eq!(integrate(&d.dep_stall, 5), 2);
    }

    #[test]
    fn outstanding_sweeps_ends_before_starts() {
        let d = sample_trace();
        // [1,5) and [3,6): 1 at 1, 2 at 3, 1 at 5, 0 at 6.
        assert_eq!(d.outstanding(0), vec![(1, 1), (3, 2), (5, 1), (6, 0)]);
        // Back-to-back FIFO spans never read as concurrent.
        let d2 = TraceData {
            storage_names: vec!["s".into()],
            port_spans: vec![
                PortSpan { storage: 0, slot: 0, write: false, burst: false, addr: 0, start: 0, end: 4 },
                PortSpan { storage: 0, slot: 0, write: false, burst: false, addr: 4, start: 4, end: 8 },
            ],
            ..TraceData::default()
        };
        assert_eq!(d2.outstanding(0), vec![(0, 1), (8, 0)]);
    }

    #[test]
    fn append_offset_shifts_and_closes_tracks() {
        let mut a = sample_trace();
        let b = sample_trace();
        // Leave `a`'s fetch track open (nonzero at the boundary).
        a.fetch_stall = vec![(10, 1)];
        let dep_before = integrate(&a.dep_stall, a.cycles) + integrate(&b.dep_stall, b.cycles);
        a.append_offset(b, 20);
        assert_eq!(a.cycles, 40);
        assert_eq!(a.fu_spans.len(), 6);
        assert_eq!(a.fu_spans[3].start, 22, "second run's spans shifted");
        assert_eq!(a.port_spans[3].end, 26);
        // The open track closed to 0 at the boundary, so integrals of the
        // merged trace equal the per-run sums.
        assert_eq!(a.fetch_stall, vec![(10, 1), (20, 0), (30, 1), (32, 0)]);
        assert_eq!(integrate(&a.dep_stall, a.cycles), dep_before);
        assert_eq!(a.fu_busy_totals(), vec![10, 12]);
    }

    #[test]
    fn append_offset_adopts_names_into_empty_trace() {
        let mut a = TraceData::default();
        a.append_offset(sample_trace(), 0);
        assert_eq!(a.fu_names, vec!["fu0".to_string(), "mau0".to_string()]);
        assert_eq!(a.cycles, 20);
    }

    #[test]
    fn append_offset_remaps_heterogeneous_name_tables() {
        // Regression: merging traces from stages on *different* machines
        // used to be guarded by debug_assert only — release builds would
        // attribute the other stage's spans to whatever units happened to
        // share an index.  This test runs in release mode too: the names
        // must be interned into a unioned table and indices remapped.
        let mut a = sample_trace();
        let mut b = sample_trace();
        b.fu_names = vec!["vec0".into(), "fu0".into()];
        b.storage_names = vec!["l1".into()];
        a.append_offset(b, 20);
        assert_eq!(
            a.fu_names,
            vec!["fu0".to_string(), "mau0".to_string(), "vec0".to_string()]
        );
        assert_eq!(a.storage_names, vec!["dmem".to_string(), "l1".to_string()]);
        // b's fu 0 ("vec0") remapped to the interned index 2, its fu 1
        // ("fu0") to the shared index 0 — busy totals land on the right
        // units: fu0 carries its own 5 plus b's 6-cycle load.
        assert_eq!(a.fu_busy_totals(), vec![11, 6, 5]);
        assert_eq!(a.storage_busy_totals(), vec![7, 7]);
    }

    #[test]
    fn null_sink_compiles_to_nothing() {
        let mut s = NullSink;
        s.fu_span(0, "mac", 0, 1);
        s.counters(0, 1, 2, 3, 4);
        s.port_span(PortSpan {
            storage: 0,
            slot: 0,
            write: false,
            burst: false,
            addr: 0,
            start: 0,
            end: 1,
        });
    }

    #[test]
    fn chrome_json_roundtrips_and_has_required_fields() {
        let d = sample_trace();
        let j = chrome_trace_json(&d);
        let text = j.to_string();
        let parsed = Json::parse(&text).unwrap();
        let events = parsed.field("traceEvents").unwrap().as_arr().unwrap();
        assert!(!events.is_empty());
        let mut saw_x = 0;
        let mut saw_c = 0;
        let mut saw_m = 0;
        for e in events {
            match e.field("ph").unwrap().as_str().unwrap() {
                "X" => {
                    saw_x += 1;
                    assert!(e.get("ts").is_some() && e.get("dur").is_some());
                    e.field("name").unwrap().as_str().unwrap();
                }
                "C" => {
                    saw_c += 1;
                    e.field("args").unwrap().field("value").unwrap().as_u64().unwrap();
                }
                "M" => saw_m += 1,
                other => panic!("unexpected phase {other}"),
            }
        }
        // 3 FU spans + 2 port spans; counters from 4 core tracks + 1
        // outstanding track; metadata for 2 processes + 2 FU + 2 ports.
        assert_eq!(saw_x, 5);
        assert!(saw_c >= 8);
        assert_eq!(saw_m, 8);
    }

    #[test]
    fn platform_chrome_json_groups_tracks_per_chip() {
        let p = PlatformTrace {
            chips: vec!["oma[0..2]".into(), "oma[2..4]".into()],
            cells: vec![
                CellSpan { stage: 0, microbatch: 0, start: 5, end: 9 },
                CellSpan { stage: 1, microbatch: 0, start: 12, end: 20 },
            ],
            weights: vec![XferSpan { name: "weights s0".into(), start: 0, end: 3 }],
            inputs: vec![XferSpan { name: "input mb0".into(), start: 0, end: 5 }],
            writeback: vec![XferSpan { name: "writeback mb0".into(), start: 20, end: 22 }],
            fabric: vec![XferSpan { name: "s0->s1 mb0".into(), start: 9, end: 12 }],
            total_cycles: 22,
        };
        assert_eq!(p.stage_busy_totals(), vec![4, 8]);
        let j = chrome_trace_platform_json(&p);
        let parsed = Json::parse(&j.to_string()).unwrap();
        let events = parsed.field("traceEvents").unwrap().as_arr().unwrap();
        let processes: Vec<&str> = events
            .iter()
            .filter(|e| {
                e.get("name").and_then(|v| v.as_str().ok()) == Some("process_name")
            })
            .map(|e| e.field("args").unwrap().field("name").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(processes, vec!["platform", "oma[0..2]", "oma[2..4]"]);
    }
}
