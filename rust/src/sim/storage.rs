//! Request slots and FIFO queuing for `DataStorage` objects — the timing
//! semantics of Figs 12–13.
//!
//! Every storage gets `max_concurrent_requests` request slots, each with
//! its own busy-until time; a request arriving with no ready slot queues
//! FIFO (modeled by granting the earliest-freeing slot: start time =
//! max(now, slot free)).  Latency per request:
//!
//! * **SRAM** — `read_latency` / `write_latency` per transaction of up to
//!   `port_width` words; wider accesses issue ⌈words/port_width⌉ chained
//!   transactions.
//! * **DRAM** — the banked row-buffer model of [`crate::mem::dram`]
//!   (Fig. 12's "latency ... provided by a memory simulator").
//! * **Cache** — hit: `hit_latency`; miss: `miss_latency` (tag+fill
//!   overhead) plus the *dynamic* backing-store access, then the hit path
//!   delivers (Fig. 13); dirty evictions additionally occupy the backing
//!   store for a write-back.

use crate::acadl_core::graph::{Ag, ObjId};
use crate::acadl_core::latency::Latency;
use crate::acadl_core::object::ObjectKind;
use crate::mem::cache::CacheState;
use crate::mem::dram::DramState;
use crate::mem::sram;
use crate::sim::trace::PortSpan;

#[derive(Debug, Clone)]
enum Model {
    Sram {
        cfg: crate::acadl_core::object::Sram,
    },
    Dram {
        state: DramState,
        port_width: usize,
    },
    Cache {
        state: CacheState,
        hit: u64,
        miss: u64,
        backing: usize,
        line: u64,
    },
}

#[derive(Debug, Clone)]
struct Node {
    obj: ObjId,
    model: Model,
    /// busy-until per request slot.
    slots: Vec<u64>,
    pub requests: u64,
    pub busy_cycles: u64,
}

/// Timing state for every `DataStorage` in the AG.
#[derive(Debug, Clone)]
pub struct StorageSim {
    nodes: Vec<Node>,
    /// ObjId -> node index (dense, usize::MAX = not a storage).
    index: Vec<usize>,
    /// Reused backing-job buffer for cache accesses (fills, write-backs):
    /// the hot path allocates nothing in steady state.
    scratch_jobs: Vec<(u64, bool)>,
    /// Record per-transaction / per-burst spans into `log` when set.
    tracing: bool,
    /// Port-span log, drained by `SimCore::take_trace`.  Spans append
    /// *after* the model borrow ends — the cache arm recurses into its
    /// backing store mid-access, and a take/restore log (the
    /// `scratch_jobs` pattern) would lose the inner entries.
    log: Vec<PortSpan>,
    /// Reused DRAM burst-boundary buffer (only touched while tracing).
    scratch_bursts: Vec<(u64, u64)>,
}

/// Per-storage statistics snapshot.
#[derive(Debug, Clone, PartialEq)]
pub struct StorageStats {
    pub name: String,
    pub requests: u64,
    pub busy_cycles: u64,
    pub cache_hits: Option<u64>,
    pub cache_misses: Option<u64>,
    pub dram_row_hits: Option<u64>,
    pub dram_row_conflicts: Option<u64>,
}

impl StorageSim {
    pub fn new(ag: &Ag) -> Self {
        let mut nodes = Vec::new();
        let mut index = vec![usize::MAX; ag.len()];
        // First pass: create nodes for all storages.
        for id in (0..ag.len() as u32).map(ObjId) {
            let model = match ag.kind(id) {
                ObjectKind::Sram(s) => Model::Sram { cfg: s.clone() },
                ObjectKind::Dram(d) => Model::Dram {
                    state: DramState::new(d),
                    port_width: d.ds.port_width.max(1),
                },
                ObjectKind::Cache(c) => Model::Cache {
                    state: CacheState::new(
                        c.sets,
                        c.ways,
                        c.cache_line_size,
                        c.replacement_policy,
                        c.write_allocate,
                        c.write_back,
                    ),
                    hit: const_lat(&c.hit_latency, 1),
                    miss: const_lat(&c.miss_latency, 8),
                    backing: usize::MAX, // fixed in second pass
                    line: c.cache_line_size,
                },
                _ => continue,
            };
            let slots = ag
                .kind(id)
                .storage_params()
                .map(|p| p.max_concurrent_requests.max(1))
                .unwrap_or(1);
            index[id.idx()] = nodes.len();
            nodes.push(Node {
                obj: id,
                model,
                slots: vec![0; slots],
                requests: 0,
                busy_cycles: 0,
            });
        }
        // Second pass: resolve cache backing pointers.
        for i in 0..nodes.len() {
            if let Model::Cache { .. } = nodes[i].model {
                let backing_obj = ag
                    .backing_of(nodes[i].obj)
                    .expect("validated AGs have cache backings");
                let b = index[backing_obj.idx()];
                if let Model::Cache { backing, .. } = &mut nodes[i].model {
                    *backing = b;
                }
            }
        }
        StorageSim {
            nodes,
            index,
            scratch_jobs: Vec::new(),
            tracing: false,
            log: Vec::new(),
            scratch_bursts: Vec::new(),
        }
    }

    /// Enable or disable port-span recording.
    pub fn set_tracing(&mut self, on: bool) {
        self.tracing = on;
    }

    /// Drain the recorded port spans.
    pub fn take_trace(&mut self) -> Vec<PortSpan> {
        std::mem::take(&mut self.log)
    }

    /// Storage names in node order — the index space of
    /// [`PortSpan::storage`].
    pub fn trace_names(&self, ag: &Ag) -> Vec<String> {
        self.nodes.iter().map(|n| ag.name(n.obj).to_string()).collect()
    }

    /// Issue a `bytes`-wide request at `storage` starting no earlier than
    /// `now`; returns the completion cycle.
    pub fn access(&mut self, storage: ObjId, addr: u64, bytes: u32, is_write: bool, now: u64) -> u64 {
        let idx = self.index[storage.idx()];
        debug_assert_ne!(idx, usize::MAX, "not a storage object");
        self.access_idx(idx, addr, bytes, is_write, now)
    }

    fn access_idx(&mut self, idx: usize, addr: u64, bytes: u32, is_write: bool, now: u64) -> u64 {
        // Grant the earliest-freeing slot (FIFO queue semantics).
        let slot = (0..self.nodes[idx].slots.len())
            .min_by_key(|&s| self.nodes[idx].slots[s])
            .unwrap();
        let start = now.max(self.nodes[idx].slots[slot]);

        // Take the pooled backing-job buffer before borrowing the model so
        // the recursive backing access below cannot alias it (a nested
        // cache level simply starts from an empty buffer).  The burst
        // buffer follows the same take/restore discipline.
        let mut jobs = std::mem::take(&mut self.scratch_jobs);
        jobs.clear();
        let mut bursts = std::mem::take(&mut self.scratch_bursts);
        bursts.clear();
        let tracing = self.tracing;
        let completion = match &mut self.nodes[idx].model {
            Model::Sram { cfg } => {
                let words = (bytes as usize).div_ceil(4).max(1);
                let txns = words.div_ceil(cfg.ds.port_width.max(1)) as u64;
                start + txns * sram::access_latency(cfg, is_write, words).max(1)
            }
            Model::Dram { state, port_width } => {
                let words = (bytes as usize).div_ceil(4).max(1);
                let chunks = words.div_ceil(*port_width);
                let mut t = start;
                for c in 0..chunks {
                    let a = addr + (c * *port_width * 4) as u64;
                    let t0 = t;
                    t += state.access(a, t);
                    if tracing {
                        bursts.push((t0, t));
                    }
                }
                t
            }
            Model::Cache {
                state,
                hit,
                miss,
                backing,
                line,
            } => {
                // Touch every line the access spans.
                let first = addr / *line;
                let last = (addr + bytes.max(1) as u64 - 1) / *line;
                let (hit_l, miss_l, backing_i, line_sz) = (*hit, *miss, *backing, *line);
                let mut t = start;
                let mut missed = false;
                for l in first..=last {
                    let a = state.access(l * line_sz, is_write);
                    if a.hit {
                        t += hit_l;
                    } else {
                        missed = true;
                        t += miss_l;
                        if a.backing_access {
                            jobs.push((l * line_sz, is_write && !a.hit));
                        }
                    }
                    if let Some(victim) = a.writeback {
                        jobs.push((victim, true));
                    }
                }
                // Backing accesses (fills are reads; write-through /
                // write-back victims are writes). They serialize the
                // request per Fig. 13 (slot stays busy through the miss).
                for (a, w) in jobs.drain(..) {
                    t = self.access_idx(backing_i, a, line_sz as u32, w, t);
                }
                // After a miss the filled line delivers through the hit
                // path (Fig. 13: t := hit_latency after the fill).
                t + if missed { hit_l } else { 0 }
            }
        };
        self.scratch_jobs = jobs;
        if self.tracing {
            // DRAM transactions log one span per burst (contiguous, so the
            // per-port sum still equals `busy_cycles`); everything else
            // logs the whole transaction.  Cache backing accesses logged
            // their own spans on the backing node during the recursion.
            if bursts.is_empty() {
                self.log.push(PortSpan {
                    storage: idx as u32,
                    slot: slot as u32,
                    write: is_write,
                    burst: false,
                    addr,
                    start,
                    end: completion,
                });
            } else {
                for &(b0, b1) in &bursts {
                    self.log.push(PortSpan {
                        storage: idx as u32,
                        slot: slot as u32,
                        write: is_write,
                        burst: true,
                        addr,
                        start: b0,
                        end: b1,
                    });
                }
            }
        }
        bursts.clear();
        self.scratch_bursts = bursts;

        let node = &mut self.nodes[idx];
        node.slots[slot] = completion;
        node.requests += 1;
        node.busy_cycles += completion - start;
        completion
    }

    /// Earliest cycle at which `storage` can *begin* a new request: the
    /// busy-until time of its earliest-freeing request slot.  This is the
    /// storage's next-event horizon — before it, a newly issued request
    /// only queues deeper; at it, the FIFO state changes.  External
    /// schedulers and estimators read this instead of polling the slots
    /// every cycle (the simulation kernel itself folds the absolute
    /// completion cycles [`Self::access`] returns into its event queue).
    pub fn next_free(&self, storage: ObjId) -> u64 {
        let idx = self.index[storage.idx()];
        debug_assert_ne!(idx, usize::MAX, "not a storage object");
        self.nodes[idx].slots.iter().copied().min().unwrap_or(0)
    }

    /// Statistics for all storages (experiment reports).
    pub fn stats(&self, ag: &Ag) -> Vec<StorageStats> {
        self.nodes
            .iter()
            .map(|n| {
                let (ch, cm, dh, dc) = match &n.model {
                    Model::Cache { state, .. } => {
                        (Some(state.hits), Some(state.misses), None, None)
                    }
                    Model::Dram { state, .. } => (
                        None,
                        None,
                        Some(state.row_hits),
                        Some(state.row_conflicts),
                    ),
                    _ => (None, None, None, None),
                };
                StorageStats {
                    name: ag.name(n.obj).to_string(),
                    requests: n.requests,
                    busy_cycles: n.busy_cycles,
                    cache_hits: ch,
                    cache_misses: cm,
                    dram_row_hits: dh,
                    dram_row_conflicts: dc,
                }
            })
            .collect()
    }
}

fn const_lat(l: &Latency, default: u64) -> u64 {
    l.eval_const().unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acadl_core::edge::EdgeKind;
    use crate::arch::parts;

    fn ag_with_cache() -> (Ag, ObjId, ObjId) {
        let mut ag = Ag::new();
        let dmem = ag.add(parts::sram("dmem", 0, 0x10000, 4, 1)).unwrap();
        let cache = ag
            .add(parts::cache(
                "c0",
                4,
                2,
                16,
                crate::mem::cache::ReplacementPolicy::Lru,
                1,
                3,
            ))
            .unwrap();
        ag.connect(cache, dmem, EdgeKind::WriteData).unwrap();
        ag.connect(dmem, cache, EdgeKind::ReadData).unwrap();
        (ag, cache, dmem)
    }

    #[test]
    fn sram_flat_latency_and_slots() {
        let mut ag = Ag::new();
        let s = ag.add(parts::sram("s", 0, 0x1000, 2, 1)).unwrap();
        let mut sim = StorageSim::new(&ag);
        // Two concurrent requests (2 slots), third queues.
        let c1 = sim.access(s, 0x0, 4, false, 10);
        let c2 = sim.access(s, 0x4, 4, false, 10);
        let c3 = sim.access(s, 0x8, 4, false, 10);
        assert_eq!(c1, 12);
        assert_eq!(c2, 12);
        assert_eq!(c3, 14, "third request waits for a slot");
    }

    #[test]
    fn next_free_tracks_earliest_slot() {
        let mut ag = Ag::new();
        let s = ag.add(parts::sram("s", 0, 0x1000, 2, 1)).unwrap();
        let mut sim = StorageSim::new(&ag);
        assert_eq!(sim.next_free(s), 0, "fresh storage is immediately free");
        sim.access(s, 0x0, 4, false, 10); // slot 0 busy until 12
        assert_eq!(sim.next_free(s), 0, "second slot still free");
        sim.access(s, 0x4, 4, false, 10); // slot 1 busy until 12
        assert_eq!(sim.next_free(s), 12, "both slots busy until 12");
        sim.access(s, 0x8, 4, false, 10); // queues on slot 0 (until 14)
        assert_eq!(sim.next_free(s), 12, "slot 1 frees first");
    }

    #[test]
    fn sram_wide_access_chains_transactions() {
        let mut ag = Ag::new();
        let s = ag.add(parts::sram("s", 0, 0x1000, 2, 2)).unwrap();
        let mut sim = StorageSim::new(&ag);
        // 8 words / port_width 2 = 4 transactions × 2 cycles.
        assert_eq!(sim.access(s, 0x0, 32, false, 0), 8);
    }

    #[test]
    fn cache_hit_vs_miss_latency() {
        let (ag, cache, _) = ag_with_cache();
        let mut sim = StorageSim::new(&ag);
        // Miss: 3 (miss overhead) + line fill from the 1-word-port SRAM
        // (16 B line = 4 words × 4 cycles = 16) + 1 (deliver) = 20.
        let c1 = sim.access(cache, 0x100, 4, false, 0);
        assert_eq!(c1, 20);
        // Hit on the same line: 1 cycle.
        let c2 = sim.access(cache, 0x104, 4, false, c1);
        assert_eq!(c2, c1 + 1);
    }

    #[test]
    fn dirty_eviction_writes_back() {
        let (ag, cache, dmem) = ag_with_cache();
        let mut sim = StorageSim::new(&ag);
        sim.access(cache, 0x000, 4, true, 0); // dirty line in set 0
        // 4 sets * 16B lines: 0x000 and 0x040 share set 0 (2 ways) — fill
        // both ways then a third line evicts the dirty one.
        sim.access(cache, 0x040, 4, true, 100);
        let before = sim.stats(&ag);
        let dmem_reqs_before = before
            .iter()
            .find(|s| s.name == "dmem")
            .unwrap()
            .requests;
        sim.access(cache, 0x080, 4, false, 200);
        let after = sim.stats(&ag);
        let dmem_reqs_after = after.iter().find(|s| s.name == "dmem").unwrap().requests;
        // Fill read + victim write-back = 2 extra backing requests.
        assert_eq!(dmem_reqs_after - dmem_reqs_before, 2);
        let _ = dmem;
    }

    #[test]
    fn dram_row_behavior_through_slots() {
        let mut ag = Ag::new();
        let d = ag.add(parts::dram_default("d", 0, 0x100000)).unwrap();
        let mut sim = StorageSim::new(&ag);
        let c1 = sim.access(d, 0x0, 4, false, 0);
        assert_eq!(c1, 24, "activate + cas");
        let c2 = sim.access(d, 0x8, 4, false, c1);
        assert_eq!(c2 - c1, 10, "row hit = cas");
    }

    #[test]
    fn tracing_logs_spans_that_reconcile_with_busy_cycles() {
        let (ag, cache, _) = ag_with_cache();
        let mut sim = StorageSim::new(&ag);
        sim.set_tracing(true);
        // Miss (recursive backing fill) then hit.
        let c1 = sim.access(cache, 0x100, 4, false, 0);
        sim.access(cache, 0x104, 4, false, c1);
        let spans = sim.take_trace();
        let names = sim.trace_names(&ag);
        let stats = sim.stats(&ag);
        // The cache-arm recursion must not lose the backing store's span.
        for (i, name) in names.iter().enumerate() {
            let logged: u64 = spans
                .iter()
                .filter(|s| s.storage == i as u32)
                .map(|s| s.end - s.start)
                .sum();
            let busy = stats.iter().find(|s| &s.name == name).unwrap().busy_cycles;
            assert_eq!(logged, busy, "span sum != busy_cycles for {name}");
        }
        assert!(spans.iter().any(|s| names[s.storage as usize] == "dmem"));
        // Timing is identical with tracing off.
        let mut plain = StorageSim::new(&ag);
        let p1 = plain.access(cache, 0x100, 4, false, 0);
        assert_eq!(p1, c1);
        assert!(plain.take_trace().is_empty());
    }

    #[test]
    fn tracing_logs_dram_bursts_contiguously() {
        let mut ag = Ag::new();
        let d = ag.add(parts::dram_default("d", 0, 0x100000)).unwrap();
        let mut sim = StorageSim::new(&ag);
        sim.set_tracing(true);
        // A wide access splits into per-chunk bursts.
        let done = sim.access(d, 0x0, 64, false, 5);
        let spans = sim.take_trace();
        assert!(spans.len() > 1, "wide DRAM access logs multiple bursts");
        assert!(spans.iter().all(|s| s.burst));
        assert_eq!(spans.first().unwrap().start, 5);
        assert_eq!(spans.last().unwrap().end, done);
        for w in spans.windows(2) {
            assert_eq!(w[0].end, w[1].start, "bursts are contiguous");
        }
    }

    #[test]
    fn stats_report_hits_and_rows() {
        let (ag, cache, _) = ag_with_cache();
        let mut sim = StorageSim::new(&ag);
        sim.access(cache, 0x100, 4, false, 0);
        sim.access(cache, 0x100, 4, false, 50);
        let st = sim.stats(&ag);
        let c = st.iter().find(|s| s.name == "c0").unwrap();
        assert_eq!(c.cache_hits, Some(1));
        assert_eq!(c.cache_misses, Some(1));
    }
}
