//! The ACADL timing-simulation semantics (§6, Figs 9–13) plus the
//! functional instruction-set simulation the paper's C++ core provides.
//!
//! * [`exec`] — the `Instruction.execute()` semantics shared by both
//!   simulators: pure state-transition functions per opcode.
//! * [`functional`] — program-order ISS: validates operator mappings and
//!   produces the golden architectural state (E9 cross-checks it against
//!   the PJRT-executed artifacts).
//! * [`scoreboard`] — the global last-user map (§6): RAW/WAW/WAR tracking
//!   over registers and memory addresses.
//! * [`storage`] — request slots + FIFO queuing for `DataStorage` objects
//!   (Figs 12–13), recursing caches into their backing stores; exposes
//!   next-free horizons for event-driven scheduling.
//! * [`kernel`] — the shared simulation kernel: fetch (Fig 9), pipeline /
//!   execute stages (Fig 10), functional units (Fig 11) as reusable
//!   per-object state machines with activity tracking and an event queue.
//! * [`backend`] — the [`SimBackend`] schedulers: [`CycleStepped`] (one
//!   step per cycle), [`EventDriven`] (idle-cycle-skipping event queue),
//!   and [`ParallelEvent`] (event-driven per core, thread-parallel at
//!   the platform level).  Identical results, different wall-clock
//!   profiles.
//! * [`engine`] — the front-end binding one (AG, program) pair to a
//!   selected backend.
//! * [`platform`] — partitioned parallel simulation of multi-accelerator
//!   platforms: microbatch chains pipelined through chip stages, with a
//!   deterministic fabric/DRAM timing recurrence.
//! * [`trace`] — structured tracing: per-FU / per-storage-port spans and
//!   stall/occupancy counter tracks with a Chrome-trace (Perfetto) JSON
//!   exporter; zero-cost when disabled, backend-identical when enabled.

pub mod backend;
pub mod engine;
pub mod exec;
pub mod functional;
pub mod kernel;
pub mod platform;
pub mod scoreboard;
pub mod storage;
pub mod trace;

pub use backend::{BackendKind, CycleStepped, EventDriven, ParallelEvent, SimBackend};
pub use engine::{Engine, SimStats};
pub use functional::FunctionalSim;
pub use kernel::{SimCore, SimError};
pub use platform::{
    microbatch_input, run_platform, run_platform_traced, PlatformReport, StageReport,
};
pub use trace::{
    chrome_trace_json, chrome_trace_platform_json, NullSink, PlatformTrace, TraceData, TraceSink,
};
