//! The shared simulation kernel: ACADL's §6 per-object state machines,
//! factored out of the driver loop so multiple [`super::backend`]s can
//! schedule them.
//!
//! [`SimCore`] owns the compiled topology (stages, functional units,
//! storage timing) and the full architectural + micro-architectural state
//! of one (AG, program) pair.  One [`SimCore::step`] processes, in order
//! (downstream-first so an instruction advances at most one stage per
//! cycle while freed slots refill the same cycle, like a real pipeline):
//!
//! 1. **FU completions** (Fig. 11) — commit effects, retire, resolve
//!    branches (squash/steer fetch), free the owning execute stage.
//! 2. **Stage forwarding** (Fig. 10) — buffered instructions whose latency
//!    elapsed move to a ready, accepting target stage; execute stages hand
//!    received instructions to a supporting, idle functional unit
//!    (structural hazard = hold + not-ready otherwise).
//! 3. **Issue** (Fig. 9) — the fetch stage forwards any number of buffered,
//!    *registered* instructions out-of-order to distinct ready stages; a
//!    fetched-but-unresolved control instruction acts as a register/issue
//!    barrier (no speculation).
//! 4. **FU start** — waiting instructions whose scoreboard dependencies all
//!    retired begin processing: operands captured, memory requests issued
//!    through the storage request slots (Figs 12–13).
//! 5. **Fetch** — complete an in-flight instruction-memory transaction
//!    (register in program order) and launch the next while
//!    `insts + port_width <= issue_buffer_size` (Fig. 9's guard).
//!
//! ## O(active) scheduling
//!
//! Phases 1, 2, and 4 iterate *active lists* — the processing-FU list,
//! the occupied-stage list (buffering/holding), and the waiting-FU list —
//! instead of scanning every object each cycle, so step cost scales with
//! the live instructions, not the machine size (a 16×16 systolic grid has
//! hundreds of mostly idle PEs per cycle).  The lists are exact: every
//! state transition goes through the phase loops or [`Self::stage_receive`],
//! which maintain membership.  Each phase snapshots its list into a reused
//! scratch buffer and sorts it (by downstream-first order position for
//! stages, by index for FUs) so iteration order — and therefore every
//! reported cycle count — is identical to the full scans.  The same lists
//! drive [`Self::advance_bulk`] and the O(1) [`Self::idle`] check (busy
//! counters), and a cached control-in-buffer counter keeps
//! [`Self::phase_fetch`] from re-scanning the issue buffer.
//!
//! ## Backend hooks
//!
//! Two small additions let an event-driven scheduler skip idle cycles
//! without changing a single reported cycle count:
//!
//! * **`activity`** — every phase raises this flag on any state change
//!   *other than* a pure timer decrement (a completion, forward, issue,
//!   dispatch, FU start, fetch event, control resolution).  A step that
//!   ends with `activity == false` is *quiescent*: until the next timer
//!   expiry, every subsequent cycle would replay the exact same no-op
//!   retries, so the clock may jump.
//! * **`events`** — when `collect_events` is set, phases push the absolute
//!   step time of every newly scheduled timer (FU completion, stage
//!   buffering expiry, fetch transaction completion) into a binary-heap
//!   event queue.  [`SimCore::advance_bulk`] then advances `T` directly to
//!   the next scheduled event, bulk-decrementing timers and bulk-charging
//!   the per-cycle statistics (FU busy, dependency / structural / fetch
//!   stalls) exactly as the skipped cycles would have.
//!
//! The kernel shares the functional semantics of [`super::exec`], so the
//! final architectural state equals the functional ISS's — asserted by the
//! conformance tests, the backend-equivalence tests, and the E9
//! golden-model comparison.
//!
//! An optional structured trace ([`super::trace`]) records FU spans,
//! storage-port spans, and stall/occupancy counter tracks; see
//! [`SimCore::attach_trace`].  Disabled tracing costs one branch per step
//! and nothing in [`SimCore::advance_bulk`].

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use thiserror::Error;

use crate::acadl_core::data::Value;
use crate::acadl_core::graph::{Ag, ObjId, RegId};
use crate::acadl_core::latency::{Latency, LatencyCtx};
use crate::acadl_core::object::ObjectKind;
use crate::isa::instruction::Instruction;
use crate::isa::opcode::Opcode;
use crate::isa::program::Program;
use crate::isa::INSTR_BYTES;
use crate::sim::exec::{self, Effects, MemImage, RegState};
use crate::sim::scoreboard::{Scoreboard, Seq};
use crate::sim::storage::{StorageSim, StorageStats};
use crate::sim::trace::{Recorder, TraceData, TraceSink};
use crate::util::json::Json;

/// Cycles without a retirement or fetch before the cycle-stepped backend
/// reports a deadlock (far cheaper than spinning to the cycle limit).
pub(crate) const DEADLOCK_WINDOW: u64 = 100_000;

#[derive(Debug, Error)]
pub enum SimError {
    #[error("model has {0} fetch stages; the engine drives exactly one")]
    FetchStageCount(usize),
    #[error("program base {0:#x} is outside the instruction memory")]
    ProgramOutsideImem(u64),
    #[error("cycle limit {0} exceeded at {1} retired instructions (deadlock or runaway loop)")]
    CycleLimit(u64, u64),
    #[error("no forward progress for {window} cycles at T={cycle} ({retired} retired) — deadlock")]
    Deadlock {
        cycle: u64,
        retired: u64,
        window: u64,
    },
    #[error(transparent)]
    Exec(#[from] exec::ExecError),
    #[error("no stage accepts instruction `{0}` (routing dead-end)")]
    Unroutable(String),
    // The message prefixes below are the wire contract for
    // `JobError::classify` — keep them in sync with coordinator::job.
    #[error("deadline exceeded at T={cycle} ({retired} retired)")]
    Deadline { cycle: u64, retired: u64 },
    #[error("cancelled at T={cycle} ({retired} retired)")]
    Cancelled { cycle: u64, retired: u64 },
}

// ------------------------------------------------------------------ topology

#[derive(Debug, Clone)]
struct StageNode {
    obj: ObjId,
    latency: u64,
    targets: Vec<usize>,
    fus: Vec<usize>,
}

#[derive(Debug, Clone)]
struct FuNode {
    obj: ObjId,
    cap_mask: u64,
    latency: Latency,
    latency_is_const: Option<u64>,
    read_mask: Vec<u64>,
    write_mask: Vec<u64>,
    is_mau: bool,
    /// Processes a MAC-family op (`mac`/`macf`/`gemm`) — the units whose
    /// busy fraction defines PE utilization.
    mac_capable: bool,
    /// (storage, served byte range) — caches resolved to their backing
    /// range at build time so the hot path never walks the graph.
    storages: Vec<(ObjId, u64, u64)>,
    busy_cycles: u64,
}

// ------------------------------------------------------------------- state

#[derive(Debug, Clone)]
struct Fetched {
    static_idx: u32,
    addr: u64,
    /// Set once the instruction is registered with the scoreboard
    /// (program order, blocked behind unresolved control instructions).
    reg: Option<(Seq, Vec<Seq>)>,
}

#[derive(Debug, Clone)]
struct DynInstr {
    static_idx: u32,
    addr: u64,
    seq: Seq,
    deps: Vec<Seq>,
}

#[derive(Debug, Clone, PartialEq)]
enum StageState {
    Empty,
    /// Buffering for `t_left` cycles before forwarding (pure pipeline
    /// stage path, or execute stage with no supporting FU).
    Buffering { di_slot: usize, t_left: u64 },
    /// Holding an instruction because every supporting FU is busy
    /// (structural hazard).
    Holding { di_slot: usize },
    /// Instruction handed to contained FU; stage blocked until it retires.
    WaitingFu { fu: usize },
}

#[derive(Debug, Clone)]
enum FuState {
    Idle,
    /// Received; waiting for scoreboard dependencies.
    Waiting { di_slot: usize },
    /// Executing; effects commit when `t_left` reaches 0.
    Processing { seq: Seq, t_left: u64, fx_slot: usize },
}

/// Simulation statistics — the per-run report row of every experiment.
#[derive(Debug, Clone, Default)]
pub struct SimStats {
    pub cycles: u64,
    pub retired: u64,
    pub fetched: u64,
    /// Cycles the fetch stage could not start a transaction because the
    /// issue buffer was full.
    pub fetch_stalls: u64,
    /// Cycles instructions spent waiting on data dependencies in FUs.
    pub dep_stall_cycles: u64,
    /// Cycles instructions were held by busy FUs (structural hazards).
    pub structural_stall_cycles: u64,
    /// (object name, busy cycles) per functional unit.
    pub fu_busy: Vec<(String, u64)>,
    /// Parallel to `fu_busy`: does the unit process a MAC-family op
    /// (`mac`/`macf`/`gemm`)?  The denominator set of
    /// [`Self::mean_fu_utilization`].
    pub fu_mac_capable: Vec<bool>,
    pub storages: Vec<StorageStats>,
}

impl SimStats {
    pub fn ipc(&self) -> f64 {
        if self.cycles == 0 {
            0.0
        } else {
            self.retired as f64 / self.cycles as f64
        }
    }

    /// Mean busy fraction over all `mac`-capable units (PE utilization in
    /// the systolic experiments) — MAUs and control units do not dilute
    /// the average.  Stats lacking capability info (or models with no
    /// MAC-family unit at all) fall back to averaging every FU.
    pub fn mean_fu_utilization(&self) -> f64 {
        if self.fu_busy.is_empty() || self.cycles == 0 {
            return 0.0;
        }
        let (n, total) = if self.fu_mac_capable.iter().any(|&m| m) {
            self.fu_busy
                .iter()
                .zip(self.fu_mac_capable.iter())
                .filter(|(_, &m)| m)
                .fold((0u64, 0u64), |(n, t), ((_, b), _)| (n + 1, t + b))
        } else {
            (
                self.fu_busy.len() as u64,
                self.fu_busy.iter().map(|(_, b)| b).sum(),
            )
        };
        total as f64 / (n as f64 * self.cycles as f64)
    }

    /// Accumulate another run's statistics (sequential schedule
    /// concatenation: one engine run per mapped layer).  Scalar counters
    /// sum; the per-FU and per-storage vectors must describe the same
    /// machine (the first merge adopts them, later merges add
    /// element-wise).
    pub fn merge(&mut self, other: &SimStats) {
        self.cycles += other.cycles;
        self.retired += other.retired;
        self.fetched += other.fetched;
        self.fetch_stalls += other.fetch_stalls;
        self.dep_stall_cycles += other.dep_stall_cycles;
        self.structural_stall_cycles += other.structural_stall_cycles;
        if self.fu_busy.is_empty() {
            self.fu_busy = other.fu_busy.clone();
            self.fu_mac_capable = other.fu_mac_capable.clone();
            self.storages = other.storages.clone();
            return;
        }
        debug_assert_eq!(self.fu_busy.len(), other.fu_busy.len(), "merge across machines");
        for (a, b) in self.fu_busy.iter_mut().zip(&other.fu_busy) {
            debug_assert_eq!(a.0, b.0);
            a.1 += b.1;
        }
        for (a, b) in self.storages.iter_mut().zip(&other.storages) {
            a.requests += b.requests;
            a.busy_cycles += b.busy_cycles;
            add_opt(&mut a.cache_hits, b.cache_hits);
            add_opt(&mut a.cache_misses, b.cache_misses);
            add_opt(&mut a.dram_row_hits, b.dram_row_hits);
            add_opt(&mut a.dram_row_conflicts, b.dram_row_conflicts);
        }
    }

    /// Stable-schema JSON dump (the `simulate --stats-json` contract):
    /// every field of the report, so scripts stop scraping stdout.
    pub fn to_json(&self) -> Json {
        let n = |v: u64| Json::Num(v as f64);
        let opt = |v: Option<u64>| v.map_or(Json::Null, |x| Json::Num(x as f64));
        Json::obj(vec![
            ("schema", Json::str("acadl.simstats/1")),
            ("cycles", n(self.cycles)),
            ("retired", n(self.retired)),
            ("fetched", n(self.fetched)),
            ("fetch_stalls", n(self.fetch_stalls)),
            ("dep_stall_cycles", n(self.dep_stall_cycles)),
            ("structural_stall_cycles", n(self.structural_stall_cycles)),
            ("ipc", Json::Num(self.ipc())),
            ("mean_fu_utilization", Json::Num(self.mean_fu_utilization())),
            (
                "fu",
                Json::Arr(
                    self.fu_busy
                        .iter()
                        .enumerate()
                        .map(|(i, (name, busy))| {
                            Json::obj(vec![
                                ("name", Json::str(name.clone())),
                                ("busy_cycles", n(*busy)),
                                (
                                    "mac_capable",
                                    Json::Bool(self.fu_mac_capable.get(i).copied().unwrap_or(false)),
                                ),
                            ])
                        })
                        .collect(),
                ),
            ),
            (
                "storages",
                Json::Arr(
                    self.storages
                        .iter()
                        .map(|s| {
                            Json::obj(vec![
                                ("name", Json::str(s.name.clone())),
                                ("requests", n(s.requests)),
                                ("busy_cycles", n(s.busy_cycles)),
                                ("cache_hits", opt(s.cache_hits)),
                                ("cache_misses", opt(s.cache_misses)),
                                ("dram_row_hits", opt(s.dram_row_hits)),
                                ("dram_row_conflicts", opt(s.dram_row_conflicts)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

fn add_opt(a: &mut Option<u64>, b: Option<u64>) {
    *a = match (*a, b) {
        (Some(x), Some(y)) => Some(x + y),
        (x, None) => x,
        (None, y) => y,
    };
}

/// The compiled machine + full simulation state for one (AG, program)
/// pair.  Backends drive it exclusively through [`SimCore::step`],
/// [`SimCore::idle`], and (event-driven only) [`SimCore::advance_bulk`].
pub struct SimCore<'a> {
    ag: &'a Ag,
    program: &'a Program,
    stages: Vec<StageNode>,
    fus: Vec<FuNode>,
    ifs_stage: usize,
    issue_cap: usize,
    fetch_port: usize,
    imem: ObjId,

    pub(crate) t: u64,
    pub regs: RegState,
    pub mem: MemImage,
    zero_regs: Vec<RegId>,
    sb: Scoreboard,
    storage: StorageSim,
    stage_state: Vec<StageState>,
    fu_state: Vec<FuState>,

    pc: u64,
    fetch_in_flight: Option<(u64, u64, usize)>, // (complete_at, addr, count)
    buffer: VecDeque<Fetched>,
    /// Registered-but-unretired control instruction (barrier), if any.
    pending_control: Option<Seq>,
    halted: bool,
    fetch_done: bool,
    outstanding: u64,

    // slot arenas: avoid cloning DynInstr/Effects through state enums.
    // `fx_arena` slots are pooled: a freed slot keeps its vectors'
    // capacity and `execute_into` refills it in place.
    di_arena: Vec<DynInstr>,
    fx_arena: Vec<Effects>,
    free_di: Vec<usize>,
    free_fx: Vec<usize>,
    /// Recycled dependency buffers (capacity reuse for `issue_into`).
    free_deps: Vec<Vec<Seq>>,

    // Active sets (see module docs, "O(active) scheduling").  Exact
    // membership: `processing_fus` ⇔ FuState::Processing, `waiting_fus`
    // ⇔ FuState::Waiting, `occupied_stages` ⇔ Buffering | Holding.
    processing_fus: Vec<u32>,
    waiting_fus: Vec<u32>,
    occupied_stages: Vec<u32>,
    /// stage index -> position in `stage_order` (snapshot sort key).
    order_pos: Vec<u32>,
    /// Reused per-phase snapshot buffer.
    scratch: Vec<u32>,
    /// Count of non-Idle FUs / non-Empty stages (O(1) `idle()`).
    busy_fus: usize,
    busy_stages: usize,
    /// Control instructions currently sitting in the issue buffer
    /// (cached so `phase_fetch` stops re-scanning the buffer).
    control_in_buffer: usize,

    /// fu index -> owning stage index (completion fast path).
    fu_stage: Vec<usize>,
    /// static instruction -> fetch-stage targets that accept it (lazy;
    /// routing is static so this memoizes the hot issue scan).
    accept_cache: Vec<Option<Vec<u16>>>,
    /// RegId -> fetch-stage targets whose FU can write that register
    /// (candidate pruning for the accept-cache fill).
    reg_writer_stages: Vec<Vec<u16>>,
    /// RegId -> fetch-stage targets whose FU can read that register.
    reg_reader_stages: Vec<Vec<u16>>,
    /// Fetch-stage targets that are pure forwarders (accept anything).
    forwarder_targets: Vec<u16>,

    /// Raised by any phase on a non-timer state change this step.
    pub(crate) activity: bool,
    /// When set (event-driven backend), phases push newly scheduled timer
    /// expiries into `events`.
    pub(crate) collect_events: bool,
    /// Min-heap of absolute step times at which a scheduled timer fires.
    pub(crate) events: BinaryHeap<Reverse<u64>>,
    /// Total `step()` invocations (backend efficiency diagnostics: the
    /// event-driven backend must never step more often than cycle-stepped).
    pub(crate) steps_executed: u64,

    pub(crate) stats: SimStats,

    /// Recording trace sink, when attached ([`Self::attach_trace`]).
    /// `None` in the hot path: one predictable branch per step — the same
    /// guard budget as the cancellation probe — and no code at all in
    /// [`Self::advance_bulk`] (spans carry absolute durations and counter
    /// charges are constant across quiescent windows, so skipped cycles
    /// need nothing recorded).
    trace: Option<Box<Recorder>>,
}

impl<'a> SimCore<'a> {
    pub fn new(ag: &'a Ag, program: &'a Program) -> Result<Self, SimError> {
        let fetch_stages = ag.fetch_stages();
        if fetch_stages.len() != 1 {
            return Err(SimError::FetchStageCount(fetch_stages.len()));
        }
        let ifs_obj = fetch_stages[0];
        let imem = ag
            .instruction_memory(ifs_obj)
            .expect("validated AG has an instruction memory");
        if !ag.storage_accepts(imem, program.base) {
            return Err(SimError::ProgramOutsideImem(program.base));
        }

        // Compile FUs (skip IMAUs — fetch is modeled directly).
        let mut fus = Vec::new();
        let mut fu_index = vec![usize::MAX; ag.len()];
        let words = ag.reg_count().div_ceil(64).max(1);
        for id in (0..ag.len() as u32).map(ObjId) {
            let kind = ag.kind(id);
            if !kind.is_functional_unit()
                || matches!(kind, ObjectKind::InstructionMemoryAccessUnit(_))
            {
                continue;
            }
            let mut cap_mask = 0u64;
            if let Some(ops) = kind.to_process() {
                for op in Opcode::all() {
                    if ops.contains(op.mnemonic()) {
                        cap_mask |= 1 << op.index();
                    }
                }
            }
            let mut read_mask = vec![0u64; words];
            let mut write_mask = vec![0u64; words];
            for rf in ag.readable_rfs(id) {
                for (i, info) in ag.regs().iter().enumerate() {
                    if info.rf == rf {
                        read_mask[i / 64] |= 1 << (i % 64);
                    }
                }
            }
            for rf in ag.writable_rfs(id) {
                for (i, info) in ag.regs().iter().enumerate() {
                    if info.rf == rf {
                        write_mask[i / 64] |= 1 << (i % 64);
                    }
                }
            }
            let latency = kind.latency().cloned().unwrap_or(Latency::Const(1));
            // Constant latencies resolve to a static horizon once; expression
            // latencies re-evaluate per dispatched instruction.
            let latency_is_const = latency.const_horizon().map(|v| v.max(1));
            // Resolve each reachable storage's served byte range once
            // (caches inherit their backing store's range).
            let storages = ag
                .storages_of_mau(id)
                .into_iter()
                .filter_map(|s| {
                    let target = if ag.kind(s).is_cache() { ag.backing_of(s)? } else { s };
                    let (lo, hi) = ag.kind(target).address_range()?;
                    Some((s, lo, hi))
                })
                .collect();
            let mac_capable = cap_mask
                & ((1 << Opcode::Mac.index())
                    | (1 << Opcode::MacFwd.index())
                    | (1 << Opcode::Gemm.index()))
                != 0;
            fu_index[id.idx()] = fus.len();
            fus.push(FuNode {
                obj: id,
                cap_mask,
                latency,
                latency_is_const,
                read_mask,
                write_mask,
                is_mau: kind.is_memory_access_unit(),
                mac_capable,
                storages,
                busy_cycles: 0,
            });
        }

        // Compile stages.
        let mut stages = Vec::new();
        let mut stage_index = vec![usize::MAX; ag.len()];
        for id in (0..ag.len() as u32).map(ObjId) {
            if !ag.kind(id).is_pipeline_stage() {
                continue;
            }
            let latency = ag
                .kind(id)
                .latency()
                .and_then(|l| l.eval_const().ok())
                .unwrap_or(1)
                .max(1);
            stage_index[id.idx()] = stages.len();
            stages.push(StageNode {
                obj: id,
                latency,
                targets: Vec::new(),
                fus: Vec::new(),
            });
        }
        for i in 0..stages.len() {
            let obj = stages[i].obj;
            stages[i].targets = ag
                .forward_targets(obj)
                .into_iter()
                .map(|o| stage_index[o.idx()])
                .filter(|&x| x != usize::MAX)
                .collect();
            stages[i].fus = ag
                .contained_fus(obj)
                .into_iter()
                .map(|o| fu_index[o.idx()])
                .filter(|&x| x != usize::MAX)
                .collect();
        }
        let ifs_stage = stage_index[ifs_obj.idx()];

        // Downstream-first order: Kahn over reversed FORWARD edges.
        let mut out_deg: Vec<usize> = stages.iter().map(|s| s.targets.len()).collect();
        let mut order: Vec<usize> = Vec::with_capacity(stages.len());
        let mut queue: VecDeque<usize> = (0..stages.len()).filter(|&i| out_deg[i] == 0).collect();
        let mut preds: Vec<Vec<usize>> = vec![Vec::new(); stages.len()];
        for (i, s) in stages.iter().enumerate() {
            for &t in &s.targets {
                preds[t].push(i);
            }
        }
        while let Some(i) = queue.pop_front() {
            order.push(i);
            for &p in &preds[i] {
                out_deg[p] -= 1;
                if out_deg[p] == 0 {
                    queue.push_back(p);
                }
            }
        }
        // Cyclic forward graphs (not produced by the model zoo) fall back
        // to declaration order for the leftover stages.
        for i in 0..stages.len() {
            if !order.contains(&i) {
                order.push(i);
            }
        }
        let mut order_pos = vec![0u32; stages.len()];
        for (p, &s) in order.iter().enumerate() {
            order_pos[s] = p as u32;
        }

        let (issue_cap, fetch_port) = match ag.kind(ifs_obj) {
            ObjectKind::InstructionFetchStage(f) => {
                let pw = ag
                    .kind(imem)
                    .storage_params()
                    .map(|p| p.port_width.max(1))
                    .unwrap_or(1);
                (f.issue_buffer_size.max(1), pw)
            }
            _ => unreachable!(),
        };

        let mut fu_stage = vec![usize::MAX; fus.len()];
        for (si, s) in stages.iter().enumerate() {
            for &f in &s.fus {
                fu_stage[f] = si;
            }
        }
        let accept_cache = vec![None; program.len()];

        // Candidate-stage maps over the fetch stage's targets: the
        // accept-cache fill only examines stages that can actually touch
        // one of the instruction's registers (plus pure forwarders).
        let reg_count = ag.reg_count();
        let mut reg_writer_stages: Vec<Vec<u16>> = vec![Vec::new(); reg_count];
        let mut reg_reader_stages: Vec<Vec<u16>> = vec![Vec::new(); reg_count];
        let mut forwarder_targets: Vec<u16> = Vec::new();
        for &tgt in &stages[ifs_stage].targets {
            let sn = &stages[tgt];
            if sn.fus.is_empty() {
                forwarder_targets.push(tgt as u16);
                continue;
            }
            for &f in &sn.fus {
                for r in 0..reg_count {
                    if fus[f].write_mask[r / 64] & (1 << (r % 64)) != 0 {
                        let v = &mut reg_writer_stages[r];
                        if v.last() != Some(&(tgt as u16)) {
                            v.push(tgt as u16);
                        }
                    }
                    if fus[f].read_mask[r / 64] & (1 << (r % 64)) != 0 {
                        let v = &mut reg_reader_stages[r];
                        if v.last() != Some(&(tgt as u16)) {
                            v.push(tgt as u16);
                        }
                    }
                }
            }
        }

        let regs: RegState = ag.regs().iter().map(|r| r.init.payload.clone()).collect();
        let zero_regs = ag
            .regs()
            .iter()
            .enumerate()
            .filter(|(_, r)| r.name == "z0" || r.name.ends_with("_z0"))
            .map(|(i, _)| RegId(i as u32))
            .collect();
        let stage_count = stages.len();
        let fu_count = fus.len();

        Ok(SimCore {
            ag,
            program,
            stages,
            fus,
            ifs_stage,
            issue_cap,
            fetch_port,
            imem,
            t: 0,
            regs,
            mem: MemImage::new(),
            zero_regs,
            sb: Scoreboard::new(ag.reg_count()),
            storage: StorageSim::new(ag),
            stage_state: vec![StageState::Empty; stage_count],
            fu_state: vec![FuState::Idle; fu_count],
            pc: program.base,
            fetch_in_flight: None,
            buffer: VecDeque::new(),
            pending_control: None,
            halted: false,
            fetch_done: false,
            outstanding: 0,
            di_arena: Vec::new(),
            fx_arena: Vec::new(),
            free_di: Vec::new(),
            free_fx: Vec::new(),
            free_deps: Vec::new(),
            processing_fus: Vec::new(),
            waiting_fus: Vec::new(),
            occupied_stages: Vec::new(),
            order_pos,
            scratch: Vec::new(),
            busy_fus: 0,
            busy_stages: 0,
            control_in_buffer: 0,
            fu_stage,
            accept_cache,
            reg_writer_stages,
            reg_reader_stages,
            forwarder_targets,
            activity: false,
            collect_events: false,
            events: BinaryHeap::new(),
            steps_executed: 0,
            stats: SimStats::default(),
            trace: None,
        })
    }

    // ----------------------------------------------------------- tracing

    /// Install a recording trace sink: FU spans, storage-port spans, and
    /// change-only counter tracks from here on.  Recording never alters
    /// timing — cycle counts are bit-identical with tracing on or off.
    pub fn attach_trace(&mut self) {
        self.storage.set_tracing(true);
        self.trace = Some(Box::default());
    }

    /// Detach the sink and finalize the recording: stamps the timeline
    /// end, resolves FU/storage names, and drains the storage-port log.
    /// Returns `None` when no trace was attached.
    pub fn take_trace(&mut self) -> Option<TraceData> {
        let mut rec = self.trace.take()?;
        self.storage.set_tracing(false);
        for span in self.storage.take_trace() {
            rec.port_span(span);
        }
        let mut data = rec.into_data();
        data.cycles = self.t;
        data.fu_names = self
            .fus
            .iter()
            .map(|f| self.ag.name(f.obj).to_string())
            .collect();
        data.storage_names = self.storage.trace_names(self.ag);
        Some(data)
    }

    // ------------------------------------------------------------ arenas

    fn alloc_di(&mut self, di: DynInstr) -> usize {
        if let Some(i) = self.free_di.pop() {
            self.di_arena[i] = di;
            i
        } else {
            self.di_arena.push(di);
            self.di_arena.len() - 1
        }
    }

    /// Claim a pooled effects slot.  The slot's stale contents keep their
    /// buffer capacity; `execute_into` clears and refills it in place.
    fn take_fx_slot(&mut self) -> usize {
        if let Some(i) = self.free_fx.pop() {
            i
        } else {
            self.fx_arena.push(Effects::default());
            self.fx_arena.len() - 1
        }
    }

    // ----------------------------------------------------------- routing

    #[inline]
    fn instr(&self, static_idx: u32) -> &Instruction {
        &self.program.instrs[static_idx as usize]
    }

    fn fu_supports(&self, fu: &FuNode, ins: &Instruction) -> bool {
        if fu.cap_mask & (1 << ins.op.index()) == 0 {
            return false;
        }
        for r in ins.all_read_regs() {
            let i = r.idx();
            if fu.read_mask[i / 64] & (1 << (i % 64)) == 0
                && fu.write_mask[i / 64] & (1 << (i % 64)) == 0
            {
                return false;
            }
        }
        for w in &ins.writes {
            let i = w.idx();
            if fu.write_mask[i / 64] & (1 << (i % 64)) == 0 {
                return false;
            }
        }
        true
    }

    /// §3's ExecuteStage receive check: a contained FU supports the op and
    /// can reach its registers — or the stage is a pure forwarder.
    fn stage_accepts(&self, stage: usize, ins: &Instruction) -> bool {
        let s = &self.stages[stage];
        if s.fus.iter().any(|&f| self.fu_supports(&self.fus[f], ins)) {
            return true;
        }
        s.fus.is_empty() && !s.targets.is_empty()
    }

    /// On receive: hand to a supporting idle FU (no stage latency), hold on
    /// structural hazard, or start buffering for later forwarding.  The
    /// target stage must be Empty; every resulting state registers itself
    /// with the active sets and busy counters.
    fn stage_receive(&mut self, stage: usize, di_slot: usize) {
        self.busy_stages += 1;
        let program = self.program;
        let ins = &program.instrs[self.di_arena[di_slot].static_idx as usize];
        let sn = &self.stages[stage];
        let mut supporting_busy = false;
        for &f in &sn.fus {
            if self.fu_supports(&self.fus[f], ins) {
                if matches!(self.fu_state[f], FuState::Idle) {
                    self.fu_state[f] = FuState::Waiting { di_slot };
                    self.busy_fus += 1;
                    self.waiting_fus.push(f as u32);
                    self.stage_state[stage] = StageState::WaitingFu { fu: f };
                    return;
                }
                supporting_busy = true;
            }
        }
        if supporting_busy {
            self.stage_state[stage] = StageState::Holding { di_slot };
            self.occupied_stages.push(stage as u32);
        } else {
            let lat = self.stages[stage].latency;
            // The buffered instruction attempts its forward at step T+lat.
            if self.collect_events {
                self.events.push(Reverse(self.t + lat));
            }
            self.stage_state[stage] = StageState::Buffering {
                di_slot,
                t_left: lat,
            };
            self.occupied_stages.push(stage as u32);
        }
    }

    // -------------------------------------------------------- phase 1: FUs

    fn phase_completions(&mut self) {
        if self.processing_fus.is_empty() {
            return;
        }
        // Snapshot and sort by FU index so commit order matches the old
        // full scan exactly (effects application, storage FIFO order).
        let mut snap = std::mem::take(&mut self.scratch);
        snap.clear();
        snap.append(&mut self.processing_fus);
        snap.sort_unstable();
        for &fw in &snap {
            let f = fw as usize;
            let FuState::Processing { seq, t_left, fx_slot } = &mut self.fu_state[f] else {
                continue;
            };
            self.fus[f].busy_cycles += 1;
            *t_left -= 1;
            if *t_left > 0 {
                self.processing_fus.push(fw);
                continue;
            }
            let seq = *seq;
            let fx_slot = *fx_slot;
            self.activity = true;
            // Commit: drain the pooled effects, moving vector payloads.
            exec::commit(&mut self.fx_arena[fx_slot], &mut self.regs, &mut self.mem);
            for z in &self.zero_regs {
                self.regs.set_int(z.idx(), 0);
            }
            let (branch, halt) = {
                let fx = &self.fx_arena[fx_slot];
                (fx.branch, fx.halt)
            };
            self.sb.retire(seq);
            self.outstanding -= 1;
            self.stats.retired += 1;
            self.free_fx.push(fx_slot);
            self.fu_state[f] = FuState::Idle;
            self.busy_fus -= 1;
            // Free the owning stage (precomputed fu -> stage map).
            let s = self.fu_stage[f];
            if s != usize::MAX && self.stage_state[s] == (StageState::WaitingFu { fu: f }) {
                self.stage_state[s] = StageState::Empty;
                self.busy_stages -= 1;
            }
            // Control resolution.
            if self.pending_control == Some(seq) {
                self.pending_control = None;
                if halt {
                    self.halted = true;
                    self.fetch_done = true;
                    self.buffer.clear();
                    self.control_in_buffer = 0;
                    self.fetch_in_flight = None;
                } else if let Some(target) = branch {
                    // Taken: squash unregistered (post-branch) entries and
                    // any in-flight fetch, steer pc.  A cancelled fetch may
                    // leave a stale entry in the event queue; the event
                    // backend drains such duplicates at pop time.
                    let program = self.program;
                    self.buffer.retain(|e| e.reg.is_some());
                    self.control_in_buffer = self
                        .buffer
                        .iter()
                        .filter(|e| program.instrs[e.static_idx as usize].is_control())
                        .count();
                    self.fetch_in_flight = None;
                    self.pc = target;
                    self.fetch_done = false;
                }
            }
        }
        self.scratch = snap;
    }

    // ------------------------------------------------- phase 2: forwarding

    fn phase_forward(&mut self) {
        if self.occupied_stages.is_empty() {
            return;
        }
        // Snapshot and sort downstream-first so freed slots refill the
        // same cycle and nothing moves two stages per cycle — identical
        // iteration order to the old full scan over `stage_order`.
        let mut snap = std::mem::take(&mut self.scratch);
        snap.clear();
        snap.append(&mut self.occupied_stages);
        snap.sort_unstable_by_key(|&s| self.order_pos[s as usize]);
        for &sw in &snap {
            let s = sw as usize;
            if s == self.ifs_stage {
                continue;
            }
            match self.stage_state[s] {
                StageState::Buffering { di_slot, t_left } => {
                    if t_left > 1 {
                        self.stage_state[s] = StageState::Buffering {
                            di_slot,
                            t_left: t_left - 1,
                        };
                        self.occupied_stages.push(sw);
                        continue;
                    }
                    // Try to forward to a ready, accepting target
                    // (take/put-back avoids cloning in the cycle loop).
                    let ins_idx = self.di_arena[di_slot].static_idx;
                    let targets = std::mem::take(&mut self.stages[s].targets);
                    let target = targets.iter().copied().find(|&tgt| {
                        matches!(self.stage_state[tgt], StageState::Empty)
                            && self.stage_accepts(tgt, self.instr(ins_idx))
                    });
                    self.stages[s].targets = targets;
                    match target {
                        Some(tgt) => {
                            self.activity = true;
                            self.stage_state[s] = StageState::Empty;
                            self.busy_stages -= 1;
                            self.stage_receive(tgt, di_slot);
                        }
                        None => {
                            // Stalled at 1 remaining cycle; retried every
                            // step, but it can only succeed after another
                            // phase empties a target — which raises
                            // `activity` — so quiescent skips stay exact.
                            self.stage_state[s] = StageState::Buffering { di_slot, t_left: 1 };
                            self.occupied_stages.push(sw);
                        }
                    }
                }
                StageState::Holding { di_slot } => {
                    // Structural hazard: retry dispatch.
                    self.stats.structural_stall_cycles += 1;
                    self.stage_state[s] = StageState::Empty;
                    self.busy_stages -= 1;
                    self.stage_receive(s, di_slot);
                    debug_assert!(
                        self.stage_state[s] != StageState::Empty,
                        "stage_receive always sets a non-empty state"
                    );
                    if !matches!(self.stage_state[s], StageState::Holding { .. }) {
                        self.activity = true;
                    }
                }
                _ => {}
            }
        }
        self.scratch = snap;
    }

    // ------------------------------------------------------ phase 3: issue

    /// `halt` retires at the fetch stage once every earlier instruction
    /// has drained — models whose functional units process no `halt`
    /// mnemonic (the parallel machines: systolic, Γ̈, …) stop here; the
    /// OMA's `fu0` may alternatively consume it through the pipeline.
    fn try_retire_halt_at_fetch(&mut self) {
        if self.outstanding != 1 {
            return;
        }
        let Some(head) = self.buffer.front() else {
            return;
        };
        let Some((seq, _)) = head.reg else { return };
        if self.pending_control != Some(seq)
            || self.program.instrs[head.static_idx as usize].op != Opcode::Halt
        {
            return;
        }
        self.activity = true;
        self.sb.retire(seq);
        self.outstanding -= 1;
        self.stats.retired += 1;
        self.pending_control = None;
        self.halted = true;
        self.fetch_done = true;
        self.buffer.clear();
        self.control_in_buffer = 0;
        self.fetch_in_flight = None;
    }

    fn phase_issue(&mut self) -> Result<(), SimError> {
        self.try_retire_halt_at_fetch();
        // Register buffered entries in program order up to (and including)
        // the first control instruction.
        let mut i = 0;
        while i < self.buffer.len() {
            if self.buffer[i].reg.is_none() {
                if self.pending_control.is_some() {
                    break;
                }
                let static_idx = self.buffer[i].static_idx;
                let program = self.program;
                let ins = &program.instrs[static_idx as usize];
                let mut deps = self.free_deps.pop().unwrap_or_default();
                let seq = self.sb.issue_into(ins, &mut deps);
                self.activity = true;
                self.outstanding += 1;
                if ins.is_control() {
                    self.pending_control = Some(seq);
                }
                self.buffer[i].reg = Some((seq, deps));
            }
            i += 1;
        }

        // Out-of-order issue: any registered entry may go to a ready,
        // accepting stage; one instruction per stage per cycle (Fig. 9's
        // multi-forward double arrow).  Routing is static per instruction,
        // so the accepting-stage set is memoized per static index.
        let mut bi = 0;
        while bi < self.buffer.len() {
            let Some((_seq, _)) = self.buffer[bi].reg else {
                break; // unregistered tail
            };
            let static_idx = self.buffer[bi].static_idx;
            self.ensure_accept_cache(static_idx);
            let tgt = self.accept_cache[static_idx as usize]
                .as_ref()
                .unwrap()
                .iter()
                .map(|&t| t as usize)
                .find(|&t| matches!(self.stage_state[t], StageState::Empty));
            match tgt {
                Some(tgt) => {
                    self.activity = true;
                    let e = self.buffer.remove(bi).unwrap();
                    if self.program.instrs[e.static_idx as usize].is_control() {
                        self.control_in_buffer -= 1;
                    }
                    let (seq, deps) = e.reg.unwrap();
                    let slot = self.alloc_di(DynInstr {
                        static_idx: e.static_idx,
                        addr: e.addr,
                        seq,
                        deps,
                    });
                    self.stage_receive(tgt, slot);
                }
                None => bi += 1,
            }
        }
        Ok(())
    }

    /// Memoize the fetch-stage targets that accept static instruction `i`.
    /// Candidates come from the register-ownership maps (a stage can only
    /// accept an instruction whose registers one of its FUs can touch),
    /// so the fill is O(candidates), not O(stages).
    fn ensure_accept_cache(&mut self, i: u32) {
        if self.accept_cache[i as usize].is_some() {
            return;
        }
        let ins = &self.program.instrs[i as usize];
        let candidates: &[u16] = if let Some(w) = ins.writes.first() {
            &self.reg_writer_stages[w.idx()]
        } else if let Some(r) = ins.reads.first() {
            &self.reg_reader_stages[r.idx()]
        } else {
            // Register-free instructions (nop/halt/jumpi): no pruning key;
            // scan all fetch targets.
            let targets = std::mem::take(&mut self.stages[self.ifs_stage].targets);
            let mut list: Vec<u16> = targets
                .iter()
                .copied()
                .filter(|&t| self.stage_accepts(t, self.instr(i)))
                .map(|t| t as u16)
                .collect();
            self.stages[self.ifs_stage].targets = targets;
            list.extend_from_slice(&self.forwarder_targets);
            list.dedup();
            self.accept_cache[i as usize] = Some(list);
            return;
        };
        let mut list: Vec<u16> = candidates
            .iter()
            .copied()
            .filter(|&t| self.stage_accepts(t as usize, self.instr(i)))
            .collect();
        list.extend_from_slice(&self.forwarder_targets);
        self.accept_cache[i as usize] = Some(list);
    }

    // --------------------------------------------------- phase 4: FU start

    fn phase_fu_start(&mut self) -> Result<(), SimError> {
        if self.waiting_fus.is_empty() {
            return Ok(());
        }
        // Snapshot in FU-index order (storage request slots are FIFO, so
        // same-cycle dispatch order is observable in completion times).
        let mut snap = std::mem::take(&mut self.scratch);
        snap.clear();
        snap.append(&mut self.waiting_fus);
        snap.sort_unstable();
        for &fw in &snap {
            let f = fw as usize;
            let FuState::Waiting { di_slot } = self.fu_state[f] else {
                continue;
            };
            let (deps_ok, seq, addr, static_idx) = {
                let di = &mut self.di_arena[di_slot];
                di.deps.retain(|&d| !self.sb.is_retired(d));
                (di.deps.is_empty(), di.seq, di.addr, di.static_idx)
            };
            if !deps_ok {
                self.stats.dep_stall_cycles += 1;
                self.waiting_fus.push(fw);
                continue;
            }
            let program = self.program;
            let ins = &program.instrs[static_idx as usize];
            let fx_slot = self.take_fx_slot();
            // On an ExecError the simulation aborts; the emptied scratch
            // buffer is simply reallocated by the next run.
            exec::execute_into(ins, addr, &self.regs, &mut self.mem, &mut self.fx_arena[fx_slot])?;

            // Latency: FU latency (+ memory path for MAUs).
            let base_lat = match self.fus[f].latency_is_const {
                Some(v) => v,
                None => {
                    let ctx = LatencyCtx::new()
                        .with("is_mac", i64::from(ins.op == Opcode::Mac))
                        .with("lanes", 8);
                    self.fus[f].latency.eval(&ctx).unwrap_or(1).max(1)
                }
            };
            let mut completion = self.t + base_lat;
            if self.fus[f].is_mau {
                let storages = std::mem::take(&mut self.fus[f].storages);
                {
                    let fx = &self.fx_arena[fx_slot];
                    for (a, bytes) in fx.mem_reads.iter().chain(fx.mem_stores.iter()) {
                        let is_write = fx.mem_stores.iter().any(|(sa, _)| sa == a)
                            && !fx.mem_reads.iter().any(|(ra, _)| ra == a);
                        if let Some(&(st, _, _)) =
                            storages.iter().find(|&&(_, lo, hi)| (lo..hi).contains(a))
                        {
                            let done = self.storage.access(st, *a, *bytes, is_write, self.t);
                            completion = completion.max(done + base_lat);
                        }
                    }
                }
                self.fus[f].storages = storages;
            }
            let t_left = (completion - self.t).max(1);
            // Recycle the (drained) dependency buffer and the DynInstr slot.
            let deps = std::mem::take(&mut self.di_arena[di_slot].deps);
            self.free_deps.push(deps);
            self.free_di.push(di_slot);
            self.activity = true;
            // Effects commit during the step at T + t_left.
            if self.collect_events {
                self.events.push(Reverse(self.t + t_left));
            }
            // The span is complete at dispatch: `busy_cycles` will accrue
            // exactly `t_left` over this occupancy on either backend, so
            // recording (start, dur) here reconciles with `fu_busy` and
            // needs no synthesis across event-driven skip windows.
            if let Some(tr) = self.trace.as_deref_mut() {
                tr.fu_span(f as u32, ins.op.mnemonic(), self.t, t_left);
            }
            self.fu_state[f] = FuState::Processing {
                seq,
                t_left,
                fx_slot,
            };
            self.processing_fus.push(fw);
        }
        self.scratch = snap;
        Ok(())
    }

    // ------------------------------------------------------ phase 5: fetch

    fn phase_fetch(&mut self) {
        // Complete an in-flight transaction.
        if let Some((complete_at, addr, count)) = self.fetch_in_flight {
            if complete_at <= self.t {
                self.activity = true;
                for k in 0..count {
                    let a = addr + k as u64 * INSTR_BYTES;
                    if let Some(idx) = self.program.index_of(a) {
                        if self.program.instrs[idx].is_control() {
                            self.control_in_buffer += 1;
                        }
                        self.buffer.push_back(Fetched {
                            static_idx: idx as u32,
                            addr: a,
                            reg: None,
                        });
                        self.stats.fetched += 1;
                    }
                }
                self.fetch_in_flight = None;
            }
        }
        if self.fetch_in_flight.is_some() || self.fetch_done {
            return;
        }
        // No speculation: while a control instruction is unresolved (or
        // sits unregistered in the buffer), do not fetch further.  The
        // buffer's control population is a maintained counter, not a scan.
        if self.pending_control.is_some() || self.control_in_buffer > 0 {
            return;
        }
        if self.program.index_of(self.pc).is_none() {
            self.fetch_done = true;
            self.activity = true;
            return;
        }
        // Fig. 9 guard: insts + port_width <= issue_buffer_size.
        if self.buffer.len() + self.fetch_port > self.issue_cap {
            self.stats.fetch_stalls += 1;
            return;
        }
        let remaining = self
            .program
            .index_of(self.pc)
            .map(|i| self.program.len() - i)
            .unwrap_or(0);
        let count = self.fetch_port.min(remaining);
        // Stop the batch at the first control instruction (later slots
        // would be speculative).
        let mut take = 0;
        for k in 0..count {
            take = k + 1;
            let idx = self.program.index_of(self.pc + k as u64 * INSTR_BYTES).unwrap();
            if self.program.instrs[idx].is_control() {
                break;
            }
        }
        let done = self
            .storage
            .access(self.imem, self.pc, (take as u32) * INSTR_BYTES as u32, false, self.t);
        self.activity = true;
        // The transaction completes in the first step at or after `done`.
        if self.collect_events {
            self.events.push(Reverse(done.max(self.t + 1)));
        }
        self.fetch_in_flight = Some((done, self.pc, take));
        self.pc += take as u64 * INSTR_BYTES;
    }

    // -------------------------------------------------------------- driver

    /// Everything drained: nothing fetched, buffered, staged, or executing.
    /// O(1): the busy counters mirror the stage/FU state arrays.
    pub fn idle(&self) -> bool {
        (self.halted || (self.fetch_done && self.buffer.is_empty() && self.fetch_in_flight.is_none()))
            && self.outstanding == 0
            && self.busy_stages == 0
            && self.busy_fus == 0
    }

    /// One clock cycle (T := T + 1 at the end).
    pub fn step(&mut self) -> Result<(), SimError> {
        self.steps_executed += 1;
        // Pre-phase stall snapshot for the trace counter tracks (three
        // plain loads; the tracing guard itself is the single branch at
        // the end of the step).
        let dep0 = self.stats.dep_stall_cycles;
        let structural0 = self.stats.structural_stall_cycles;
        let fetch0 = self.stats.fetch_stalls;
        self.phase_completions();
        self.phase_forward();
        self.phase_issue()?;
        self.phase_fu_start()?;
        self.phase_fetch();
        // This cycle's stall charge (the per-phase deltas) and the issue
        // buffer's resulting depth.  The recorder samples on change only,
        // which is what keeps traces identical across backends: between
        // events every charge is constant (the quiescence invariant), so
        // skipped cycles would re-emit nothing.
        let dep = self.stats.dep_stall_cycles - dep0;
        let structural = self.stats.structural_stall_cycles - structural0;
        let fetch = self.stats.fetch_stalls - fetch0;
        let buffer = self.buffer.len() as u64;
        let t = self.t;
        if let Some(tr) = self.trace.as_deref_mut() {
            tr.counters(t, dep, structural, fetch, buffer);
        }
        self.t += 1;
        Ok(())
    }

    /// Mirror of the exact path in [`Self::phase_fetch`] that charges a
    /// `fetch_stalls` cycle: ready to fetch, but the Fig. 9 issue-buffer
    /// guard blocks the transaction.  Used by [`Self::advance_bulk`] to
    /// charge skipped cycles identically.
    fn fetch_capacity_blocked(&self) -> bool {
        self.fetch_in_flight.is_none()
            && !self.fetch_done
            && self.pending_control.is_none()
            && self.control_in_buffer == 0
            && self.program.index_of(self.pc).is_some()
            && self.buffer.len() + self.fetch_port > self.issue_cap
    }

    /// Advance the clock by `dt` cycles at once, as if `dt` quiescent
    /// steps had run: bulk-decrement every running timer and bulk-charge
    /// the per-cycle statistics — touching only the active sets.  Only
    /// sound when called from a quiescent configuration (the previous step
    /// raised no `activity`) with `dt` at most the distance to the next
    /// scheduled event, both of which the event-driven backend guarantees.
    pub(crate) fn advance_bulk(&mut self, dt: u64) {
        debug_assert!(dt > 0, "bulk advance of zero cycles");
        for &fw in &self.processing_fus {
            let f = fw as usize;
            if let FuState::Processing { t_left, .. } = &mut self.fu_state[f] {
                debug_assert!(*t_left > dt, "bulk advance skipped a completion");
                *t_left -= dt;
                self.fus[f].busy_cycles += dt;
            }
        }
        // A Waiting FU after a quiescent step has unmet dependencies, and
        // none can retire while skipping.
        self.stats.dep_stall_cycles += dt * self.waiting_fus.len() as u64;
        for &sw in &self.occupied_stages {
            match &mut self.stage_state[sw as usize] {
                StageState::Buffering { t_left, .. } if *t_left > 1 => {
                    debug_assert!(*t_left > dt, "bulk advance skipped a forward attempt");
                    *t_left -= dt;
                }
                StageState::Holding { .. } => self.stats.structural_stall_cycles += dt,
                _ => {}
            }
        }
        if self.fetch_capacity_blocked() {
            self.stats.fetch_stalls += dt;
        }
        self.t += dt;
    }

    /// Finalize and snapshot the statistics (end of a backend run).
    pub(crate) fn finish_stats(&mut self) -> SimStats {
        self.stats.cycles = self.t;
        self.stats.fu_busy = self
            .fus
            .iter()
            .map(|f| (self.ag.name(f.obj).to_string(), f.busy_cycles))
            .collect();
        self.stats.fu_mac_capable = self.fus.iter().map(|f| f.mac_capable).collect();
        self.stats.storages = self.storage.stats(self.ag);
        self.stats.clone()
    }

    pub fn cycles(&self) -> u64 {
        self.t
    }

    /// Total [`Self::step`] invocations this run — the scheduler-efficiency
    /// metric: on stall-heavy workloads the event-driven backend executes
    /// far fewer steps than simulated cycles.
    pub fn steps_executed(&self) -> u64 {
        self.steps_executed
    }

    /// Register value by AG name (result extraction / validation).
    pub fn get_reg(&self, name: &str) -> Option<Value> {
        self.ag.reg_id(name).map(|r| self.regs.get(r.idx()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_fu_utilization_filters_to_mac_capable() {
        let st = SimStats {
            cycles: 100,
            fu_busy: vec![
                ("pe_0_0".into(), 80),
                ("pe_0_1".into(), 60),
                ("mau0".into(), 10),
            ],
            fu_mac_capable: vec![true, true, false],
            ..SimStats::default()
        };
        // (80 + 60) / (2 * 100): the MAU does not dilute PE utilization.
        assert!((st.mean_fu_utilization() - 0.70).abs() < 1e-9);
    }

    #[test]
    fn mean_fu_utilization_falls_back_without_capability_info() {
        let st = SimStats {
            cycles: 100,
            fu_busy: vec![("a".into(), 80), ("b".into(), 10)],
            fu_mac_capable: Vec::new(),
            ..SimStats::default()
        };
        assert!((st.mean_fu_utilization() - 0.45).abs() < 1e-9);
    }

    #[test]
    fn mean_fu_utilization_degenerate_cases() {
        assert_eq!(SimStats::default().mean_fu_utilization(), 0.0);
        let st = SimStats {
            cycles: 0,
            fu_busy: vec![("a".into(), 5)],
            ..SimStats::default()
        };
        assert_eq!(st.mean_fu_utilization(), 0.0);
    }
}
