//! Partitioned **parallel** simulation of a multi-accelerator platform:
//! a DNN graph sharded across N chips ([`crate::dnn::lowering::partition_graph`])
//! pipelines M microbatches through the chip stages, each stage an
//! independent per-machine island whose programs run through the ordinary
//! [`SimMode`] engines, connected by the platform's fabric + shared-DRAM
//! cost model ([`crate::arch::platform`]).
//!
//! # Determinism argument (`--threads 1` ≡ `--threads N`)
//!
//! The computation decomposes into *cells* `(s, b)` — stage `s` of
//! microbatch `b`.  A cell's **functional** result and its **duration**
//! (the stage's simulated cycles for that microbatch's activations)
//! depend only on cell `(s-1, b)`: each microbatch chain carries its own
//! [`StepCtx`], chips share no architectural state, and every per-cell
//! simulation is the same single-threaded `SimCore` run the equivalence
//! oracle already guards.  Chains are therefore embarrassingly parallel —
//! the worker threads only decide *which* chain simulates *when*, never
//! what any cell computes.
//!
//! The platform-level **timing** (when each cell would start on real
//! hardware, given fabric hops, the shared DRAM channel, and chip
//! occupancy) is then resolved by a conservative recurrence evaluated
//! serially over the completed duration matrix:
//!
//! ```text
//! start[s][b]  = max(dram_ready[s],            // weights streamed in
//!                    arrive[s][b],             // input crossed the fabric
//!                    finish[s][b-1])           // chip busy with prior µbatch
//! finish[s][b] = start[s][b] + dur[s][b]
//! arrive[s][b] = finish[s-1][b] + fabric.transfer_cycles(words, 1)
//! ```
//!
//! Every input to the recurrence is a pure function of the description
//! and the duration matrix, so the reported cycle count is bit-identical
//! at any thread count — and because the recurrence is a forward
//! substitution with no cyclic waits, it cannot deadlock even with
//! zero-latency fabric edges (the conservative lookahead never needs to
//! block: durations are already known when it runs).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;

use crate::arch::platform::PlatformDesc;
use crate::dnn::graph::DnnGraph;
use crate::dnn::lowering::{
    lower_graph, lower_serving, run_step, split_serving_input, LowerError, LoweredGraph,
    PlatformPlan, ServingSchedule, SimMode, StepCtx,
};
use crate::mapping::uma::Machine;
use crate::sim::trace::{CellSpan, PlatformTrace, XferSpan};

/// Per-stage aggregate of a platform run.
#[derive(Debug, Clone)]
pub struct StageReport {
    /// Machine name plus the layer range the stage executes.
    pub name: String,
    /// Schedule steps (graph layers) on this stage.
    pub steps: usize,
    /// Simulated compute cycles summed over all microbatches.
    pub busy_cycles: u64,
    pub instructions: u64,
}

/// The platform run's results: per-stage aggregates, the pipelined
/// makespan, and every microbatch's functional output.
#[derive(Debug, Clone)]
pub struct PlatformReport {
    pub stages: Vec<StageReport>,
    /// Pipelined makespan: weights + inputs streamed from the shared
    /// DRAM, compute, fabric transfers, and output writeback.
    pub total_cycles: u64,
    pub total_instructions: u64,
    /// Final activations per microbatch (unpadded).
    pub outputs: Vec<Vec<f32>>,
    /// Mean chip occupancy: Σ busy / (stages × makespan).
    pub utilization: f64,
}

/// Deterministic input for microbatch `b`: microbatch 0 is the graph's
/// seeded [`DnnGraph::input_batch`]; later microbatches rotate it so
/// every chain computes on distinct data.  Shared with the conformance
/// tests and the coordinator's numerics check so references can't drift.
pub fn microbatch_input(graph: &DnnGraph, batch: usize, b: usize) -> Vec<f32> {
    let mut x = graph.input_batch(batch);
    if !x.is_empty() {
        x.rotate_left((b * graph.input_features) % x.len());
    }
    x
}

/// One completed microbatch chain: per-stage durations + the output.
struct ChainOut {
    durs: Vec<u64>,
    instrs: Vec<u64>,
    output: Vec<f32>,
}

fn run_chain(
    machines: &[&Machine],
    lowered: &[LoweredGraph],
    plan: &PlatformPlan,
    batch: usize,
    input: Vec<f32>,
    mode: SimMode,
    max_cycles: u64,
) -> Result<ChainOut, LowerError> {
    let mut ctx = StepCtx::new(&input);
    let mut durs = Vec::with_capacity(plan.stages.len());
    let mut instrs = Vec::with_capacity(plan.stages.len());
    for (s, stage) in plan.stages.iter().enumerate() {
        let mut cycles = 0u64;
        let mut instructions = 0u64;
        for step in &lowered[s].steps[stage.steps.clone()] {
            if let Some(lr) = run_step(machines[s], step, batch, &mut ctx, mode, max_cycles)? {
                cycles += lr.cycles;
                instructions += lr.instructions;
            }
        }
        durs.push(cycles);
        instrs.push(instructions);
        // Stashes are chip-local; the partitioner only cuts where no
        // slot's live range crosses, so nothing useful is discarded.
        ctx.stash.clear();
    }
    Ok(ChainOut {
        durs,
        instrs,
        output: ctx.act,
    })
}

/// Simulate `graph` sharded per `plan` over `machines` (one per stage —
/// repeat the same reference for a homogeneous platform), pipelining
/// `desc.microbatches` inferences, with up to `threads` worker threads
/// advancing independent microbatch chains.  The reported cycle count is
/// identical at every thread count (see the module docs).
#[allow(clippy::too_many_arguments)]
pub fn run_platform(
    machines: &[&Machine],
    graph: &DnnGraph,
    plan: &PlatformPlan,
    batch: usize,
    desc: &PlatformDesc,
    mode: SimMode,
    threads: usize,
    max_cycles: u64,
) -> Result<PlatformReport, LowerError> {
    run_platform_traced(machines, graph, plan, batch, desc, mode, threads, max_cycles, None)
}

/// [`run_platform`] with an optional platform trace: per-chip compute
/// cells, shared-DRAM streams, and fabric transfers, all derived from the
/// serial timing recurrence — so the trace, like the cycle count, is
/// bit-identical at every worker thread count.
#[allow(clippy::too_many_arguments)]
pub fn run_platform_traced(
    machines: &[&Machine],
    graph: &DnnGraph,
    plan: &PlatformPlan,
    batch: usize,
    desc: &PlatformDesc,
    mode: SimMode,
    threads: usize,
    max_cycles: u64,
    mut trace: Option<&mut PlatformTrace>,
) -> Result<PlatformReport, LowerError> {
    let s_count = plan.stages.len();
    if machines.len() != s_count {
        return Err(LowerError::BadGraph(
            0,
            format!("platform has {} machines but the plan has {s_count} stages", machines.len()),
        ));
    }
    let m_count = desc.microbatches.max(1);

    // Lower once per stage machine (stages slice the shared schedule).
    let mut lowered: Vec<LoweredGraph> = Vec::with_capacity(s_count);
    for (s, machine) in machines.iter().enumerate() {
        // Homogeneous platforms repeat one &Machine — reuse its lowering.
        if let Some(prev) = (0..s).find(|&p| std::ptr::eq(machines[p], *machine)) {
            lowered.push(lowered[prev].clone());
        } else {
            lowered.push(lower_graph(machine, graph, batch)?);
        }
    }

    // --- simulate every (stage, microbatch) cell: independent chains ---
    let workers = threads.max(1).min(m_count);
    let mut chains: Vec<Option<ChainOut>> = (0..m_count).map(|_| None).collect();
    if workers == 1 {
        // `--threads 1` is literally single-threaded — the reference run.
        for (b, slot) in chains.iter_mut().enumerate() {
            let input = microbatch_input(graph, batch, b);
            *slot = Some(run_chain(machines, &lowered, plan, batch, input, mode, max_cycles)?);
        }
    } else {
        let next = AtomicUsize::new(0);
        let (tx, rx) = mpsc::channel::<(usize, Result<ChainOut, LowerError>)>();
        // Stage workers don't inherit the caller's thread-local cancel
        // token; install a clone in each so a job deadline or client
        // disconnect stops every in-flight chain simulation, not just
        // whatever ran on the calling thread.
        let caller_token = crate::util::cancel::current();
        std::thread::scope(|scope| {
            for _ in 0..workers {
                let tx = tx.clone();
                let next = &next;
                let lowered = &lowered;
                let token = caller_token.clone();
                scope.spawn(move || {
                    let _token_guard = token.map(crate::util::cancel::install);
                    loop {
                        let b = next.fetch_add(1, Ordering::SeqCst);
                        if b >= m_count {
                            break;
                        }
                        let input = microbatch_input(graph, batch, b);
                        let out =
                            run_chain(machines, lowered, plan, batch, input, mode, max_cycles);
                        if tx.send((b, out)).is_err() {
                            break;
                        }
                    }
                });
            }
        });
        drop(tx);
        let mut results: Vec<(usize, Result<ChainOut, LowerError>)> = rx.iter().collect();
        results.sort_by_key(|(b, _)| *b);
        // Propagate the lowest-index error so failures are deterministic
        // regardless of which worker hit one first.
        for (b, res) in results {
            chains[b] = Some(res?);
        }
    }
    let chains: Vec<ChainOut> = chains
        .into_iter()
        .map(|c| c.expect("every microbatch chain completed"))
        .collect();

    // --- conservative timing recurrence (serial, deterministic) --------
    // The optional trace is filled here, from the same recurrence values
    // that produce the cycle count — never from the worker threads.
    if let Some(tr) = trace.as_deref_mut() {
        *tr = PlatformTrace::default();
        tr.chips = plan
            .stages
            .iter()
            .enumerate()
            .map(|(s, stage)| {
                format!("{}[{}..{}]", machines[s].name(), stage.steps.start, stage.steps.end)
            })
            .collect();
    }
    // Weight streaming: the shared DRAM channel serves chips in order.
    let mut dram_ready = vec![0u64; s_count];
    let mut t = 0u64;
    for (s, stage) in plan.stages.iter().enumerate() {
        let t0 = t;
        t += desc.dram.load_cycles(stage.weight_words);
        dram_ready[s] = t;
        if let Some(tr) = trace.as_deref_mut() {
            tr.weights.push(XferSpan { name: format!("weights s{s}"), start: t0, end: t });
        }
    }
    let in_words = plan.stages[0].in_words();
    let out_words = plan.stages[s_count - 1].out_words();
    let mut finish = vec![vec![0u64; m_count]; s_count];
    for b in 0..m_count {
        for s in 0..s_count {
            let arrive = if s == 0 {
                // Inputs stream from the shared DRAM, one microbatch at
                // a time on the single channel.
                (b as u64 + 1) * desc.dram.load_cycles(in_words)
            } else {
                finish[s - 1][b]
                    + desc
                        .fabric
                        .transfer_cycles(plan.stages[s - 1].out_words(), 1)
            };
            let chip_free = if b == 0 { 0 } else { finish[s][b - 1] };
            let start = dram_ready[s].max(arrive).max(chip_free);
            finish[s][b] = start + chains[b].durs[s];
            if let Some(tr) = trace.as_deref_mut() {
                if s == 0 {
                    let load = desc.dram.load_cycles(in_words);
                    tr.inputs.push(XferSpan {
                        name: format!("input mb{b}"),
                        start: b as u64 * load,
                        end: (b as u64 + 1) * load,
                    });
                } else {
                    tr.fabric.push(XferSpan {
                        name: format!("s{}->s{s} mb{b}", s - 1),
                        start: finish[s - 1][b],
                        end: arrive,
                    });
                }
                tr.cells.push(CellSpan {
                    stage: s as u32,
                    microbatch: b as u32,
                    start,
                    end: finish[s][b],
                });
            }
        }
    }
    // Writeback: outputs drain over the single shared-DRAM channel.
    let mut wb = 0u64;
    for (b, fin) in finish[s_count - 1].iter().enumerate() {
        let wb0 = wb.max(*fin);
        wb = wb0 + desc.dram.store_cycles(out_words);
        if let Some(tr) = trace.as_deref_mut() {
            tr.writeback.push(XferSpan { name: format!("writeback mb{b}"), start: wb0, end: wb });
        }
    }
    let total_cycles = wb;
    if let Some(tr) = trace.as_deref_mut() {
        tr.total_cycles = total_cycles;
    }

    // --- aggregate ------------------------------------------------------
    let mut stages = Vec::with_capacity(s_count);
    let mut total_instructions = 0u64;
    let mut busy_sum = 0u64;
    for (s, stage) in plan.stages.iter().enumerate() {
        let busy: u64 = chains.iter().map(|c| c.durs[s]).sum();
        let instructions: u64 = chains.iter().map(|c| c.instrs[s]).sum();
        busy_sum += busy;
        total_instructions += instructions;
        stages.push(StageReport {
            name: format!(
                "{}[{}..{}]",
                machines[s].name(),
                stage.steps.start,
                stage.steps.end
            ),
            steps: stage.steps.len(),
            busy_cycles: busy,
            instructions,
        });
    }
    let utilization = if total_cycles > 0 {
        busy_sum as f64 / (s_count as f64 * total_cycles as f64)
    } else {
        0.0
    };
    Ok(PlatformReport {
        stages,
        total_cycles,
        total_instructions,
        outputs: chains.into_iter().map(|c| c.output).collect(),
        utilization,
    })
}

// ------------------------------------------------------------- serving

/// A platform serving run: the pipelined report plus the phase split a
/// serving deployment actually prices — prompt-processing makespan and
/// the steady-state cost of each generated token.
#[derive(Debug, Clone)]
pub struct PlatformServingReport {
    pub report: PlatformReport,
    /// Cycle at which every session's prompt has fully drained through
    /// the pipeline (end of the prefill phase).
    pub prefill_cycles: u64,
    /// Tokens generated across all sessions (sessions × decode_steps).
    pub decoded_tokens: u64,
}

impl PlatformServingReport {
    /// Mean decode cost per generated token, the serving-optimization
    /// objective; `None` when no tokens were decoded.
    pub fn cycles_per_token(&self) -> Option<f64> {
        (self.decoded_tokens > 0).then(|| {
            (self.report.total_cycles - self.prefill_cycles) as f64 / self.decoded_tokens as f64
        })
    }
}

/// One completed serving session: per-`(phase, stage)` durations plus
/// the assembled `(seq + decode_steps) × out` output.
struct ServingChainOut {
    /// `durs[phase][stage]` — phase 0 is prefill, phase `t + 1` decode
    /// step `t`.
    durs: Vec<Vec<u64>>,
    instrs: Vec<Vec<u64>>,
    output: Vec<f32>,
}

/// Run one serving session through the staged pipeline: the prefill
/// phase at `seq` rows, then one single-row decode phase per generated
/// token.  Each stage keeps its [`StepCtx`] alive across phases, so the
/// per-head K/V stashes seeded by prefill keep growing — the platform
/// analogue of [`crate::dnn::lowering::run_serving`].
fn run_serving_chain(
    machines: &[&Machine],
    scheds: &[ServingSchedule],
    plan: &PlatformPlan,
    seq: usize,
    full_input: &[f32],
    feat: usize,
    mode: SimMode,
    max_cycles: u64,
) -> Result<ServingChainOut, LowerError> {
    let s_count = plan.stages.len();
    let (prompt, dec_rows) = split_serving_input(full_input, feat, seq);
    let phases = 1 + dec_rows.len();
    let mut ctxs: Vec<StepCtx> = (0..s_count).map(|_| StepCtx::new(&[])).collect();
    let mut durs = vec![vec![0u64; s_count]; phases];
    let mut instrs = vec![vec![0u64; s_count]; phases];
    let mut output = Vec::new();
    for p in 0..phases {
        let (rows, mut act) = if p == 0 {
            (seq, prompt.clone())
        } else {
            (1, dec_rows[p - 1].clone())
        };
        for s in 0..s_count {
            let lg = if p == 0 {
                &scheds[s].prefill
            } else {
                &scheds[s].decode[p - 1]
            };
            let ctx = &mut ctxs[s];
            ctx.act = act;
            let mut cycles = 0u64;
            let mut instructions = 0u64;
            for step in &lg.steps[plan.stages[s].steps.clone()] {
                if let Some(lr) = run_step(machines[s], step, rows, ctx, mode, max_cycles)? {
                    cycles += lr.cycles;
                    instructions += lr.instructions;
                }
            }
            durs[p][s] = cycles;
            instrs[p][s] = instructions;
            act = ctx.act.clone();
        }
        output.extend_from_slice(&act);
    }
    Ok(ServingChainOut { durs, instrs, output })
}

/// Simulate a KV-cached serving loop — prefill then `decode_steps`
/// single-token phases — for `desc.microbatches` independent sessions
/// sharded per `plan` over `machines`.  Sessions run lockstep per phase
/// (continuous-batching style): every session's prompt pipelines through
/// the stages first, then the sessions' decode steps pipeline one token
/// at a time, each token's input fed back over the fabric from the last
/// stage.  Functional results and per-cell durations are computed on up
/// to `threads` worker threads (one session chain per task); platform
/// timing is then resolved by the same serial conservative recurrence as
/// [`run_platform`], so cycles are bit-identical at every thread count.
#[allow(clippy::too_many_arguments)]
pub fn run_platform_serving(
    machines: &[&Machine],
    graph: &DnnGraph,
    plan: &PlatformPlan,
    seq: usize,
    decode_steps: usize,
    desc: &PlatformDesc,
    mode: SimMode,
    threads: usize,
    max_cycles: u64,
    mut trace: Option<&mut PlatformTrace>,
) -> Result<PlatformServingReport, LowerError> {
    let s_count = plan.stages.len();
    if machines.len() != s_count {
        return Err(LowerError::BadGraph(
            0,
            format!("platform has {} machines but the plan has {s_count} stages", machines.len()),
        ));
    }
    let m_count = desc.microbatches.max(1);
    let feat = graph.input_features;
    let total_rows = seq + decode_steps;

    // Lower the full serving schedule once per distinct stage machine.
    let mut scheds: Vec<ServingSchedule> = Vec::with_capacity(s_count);
    for (s, machine) in machines.iter().enumerate() {
        if let Some(prev) = (0..s).find(|&p| std::ptr::eq(machines[p], *machine)) {
            scheds.push(scheds[prev].clone());
        } else {
            scheds.push(lower_serving(machine, graph, seq, decode_steps)?);
        }
    }

    // --- simulate every session: independent chains ---------------------
    let workers = threads.max(1).min(m_count);
    let mut chains: Vec<Option<ServingChainOut>> = (0..m_count).map(|_| None).collect();
    if workers == 1 {
        for (b, slot) in chains.iter_mut().enumerate() {
            let input = microbatch_input(graph, total_rows, b);
            *slot = Some(run_serving_chain(
                machines, &scheds, plan, seq, &input, feat, mode, max_cycles,
            )?);
        }
    } else {
        let next = AtomicUsize::new(0);
        let (tx, rx) = mpsc::channel::<(usize, Result<ServingChainOut, LowerError>)>();
        let caller_token = crate::util::cancel::current();
        std::thread::scope(|scope| {
            for _ in 0..workers {
                let tx = tx.clone();
                let next = &next;
                let scheds = &scheds;
                let token = caller_token.clone();
                scope.spawn(move || {
                    let _token_guard = token.map(crate::util::cancel::install);
                    loop {
                        let b = next.fetch_add(1, Ordering::SeqCst);
                        if b >= m_count {
                            break;
                        }
                        let input = microbatch_input(graph, total_rows, b);
                        let out = run_serving_chain(
                            machines, scheds, plan, seq, &input, feat, mode, max_cycles,
                        );
                        if tx.send((b, out)).is_err() {
                            break;
                        }
                    }
                });
            }
        });
        drop(tx);
        let mut results: Vec<(usize, Result<ServingChainOut, LowerError>)> = rx.iter().collect();
        results.sort_by_key(|(b, _)| *b);
        for (b, res) in results {
            chains[b] = Some(res?);
        }
    }
    let chains: Vec<ServingChainOut> = chains
        .into_iter()
        .map(|c| c.expect("every serving session completed"))
        .collect();

    // --- conservative timing recurrence (serial, deterministic) --------
    if let Some(tr) = trace.as_deref_mut() {
        *tr = PlatformTrace::default();
        tr.chips = plan
            .stages
            .iter()
            .enumerate()
            .map(|(s, stage)| {
                format!("{}[{}..{}]", machines[s].name(), stage.steps.start, stage.steps.end)
            })
            .collect();
    }
    // Weights stream once over the shared channel; decode phases reuse
    // the resident copies.
    let mut dram_ready = vec![0u64; s_count];
    let mut t = 0u64;
    for (s, stage) in plan.stages.iter().enumerate() {
        let t0 = t;
        t += desc.dram.load_cycles(stage.weight_words);
        dram_ready[s] = t;
        if let Some(tr) = trace.as_deref_mut() {
            tr.weights.push(XferSpan { name: format!("weights s{s}"), start: t0, end: t });
        }
    }
    let phases = 1 + decode_steps;
    let in_words = plan.stages[0].in_words();
    let mut finish = vec![vec![vec![0u64; m_count]; s_count]; phases];
    let mut chip_free = vec![0u64; s_count];
    let mut prefill_cycles = 0u64;
    for p in 0..phases {
        let rows = if p == 0 { seq } else { 1 };
        for b in 0..m_count {
            for s in 0..s_count {
                let arrive = if s == 0 {
                    if p == 0 {
                        // Prompts stream from the shared DRAM, one
                        // session at a time on the single channel.
                        (b as u64 + 1) * desc.dram.load_cycles(in_words)
                    } else {
                        // Feedback: the token generated by the previous
                        // phase returns over the fabric to stage 0.
                        finish[p - 1][s_count - 1][b] + desc.fabric.transfer_cycles(feat, 1)
                    }
                } else {
                    finish[p][s - 1][b]
                        + desc
                            .fabric
                            .transfer_cycles(rows * plan.stages[s - 1].out_feat, 1)
                };
                let start = dram_ready[s].max(arrive).max(chip_free[s]);
                finish[p][s][b] = start + chains[b].durs[p][s];
                chip_free[s] = finish[p][s][b];
                if let Some(tr) = trace.as_deref_mut() {
                    if s == 0 {
                        if p == 0 {
                            let load = desc.dram.load_cycles(in_words);
                            tr.inputs.push(XferSpan {
                                name: format!("prompt mb{b}"),
                                start: b as u64 * load,
                                end: (b as u64 + 1) * load,
                            });
                        } else {
                            tr.fabric.push(XferSpan {
                                name: format!("feedback t{} mb{b}", p - 1),
                                start: finish[p - 1][s_count - 1][b],
                                end: arrive,
                            });
                        }
                    } else {
                        tr.fabric.push(XferSpan {
                            name: format!("s{}->s{s} mb{b}", s - 1),
                            start: finish[p][s - 1][b],
                            end: arrive,
                        });
                    }
                    tr.cells.push(CellSpan {
                        stage: s as u32,
                        microbatch: b as u32,
                        start,
                        end: finish[p][s][b],
                    });
                }
            }
            if p == 0 {
                prefill_cycles = prefill_cycles.max(finish[0][s_count - 1][b]);
            }
        }
    }
    // Writeback: each session's full output (prompt + generated rows)
    // drains once over the shared channel after its last phase.
    let out_feat = plan.stages[s_count - 1].out_feat;
    let mut wb = 0u64;
    for b in 0..m_count {
        let wb0 = wb.max(finish[phases - 1][s_count - 1][b]);
        wb = wb0 + desc.dram.store_cycles(total_rows * out_feat);
        if let Some(tr) = trace.as_deref_mut() {
            tr.writeback.push(XferSpan { name: format!("writeback mb{b}"), start: wb0, end: wb });
        }
    }
    let total_cycles = wb;
    if let Some(tr) = trace.as_deref_mut() {
        tr.total_cycles = total_cycles;
    }

    // --- aggregate ------------------------------------------------------
    let mut stages = Vec::with_capacity(s_count);
    let mut total_instructions = 0u64;
    let mut busy_sum = 0u64;
    for (s, stage) in plan.stages.iter().enumerate() {
        let busy: u64 = chains.iter().map(|c| c.durs.iter().map(|d| d[s]).sum::<u64>()).sum();
        let instructions: u64 =
            chains.iter().map(|c| c.instrs.iter().map(|d| d[s]).sum::<u64>()).sum();
        busy_sum += busy;
        total_instructions += instructions;
        stages.push(StageReport {
            name: format!(
                "{}[{}..{}]",
                machines[s].name(),
                stage.steps.start,
                stage.steps.end
            ),
            steps: stage.steps.len(),
            busy_cycles: busy,
            instructions,
        });
    }
    let utilization = if total_cycles > 0 {
        busy_sum as f64 / (s_count as f64 * total_cycles as f64)
    } else {
        0.0
    };
    Ok(PlatformServingReport {
        report: PlatformReport {
            stages,
            total_cycles,
            total_instructions,
            outputs: chains.into_iter().map(|c| c.output).collect(),
            utilization,
        },
        prefill_cycles,
        decoded_tokens: (m_count * decode_steps) as u64,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::arch::oma::OmaConfig;
    use crate::dnn::lowering::partition_graph;
    use crate::mapping::uma::TargetConfig;
    use crate::sim::backend::BackendKind;

    #[test]
    fn functional_platform_matches_forward_ref_per_microbatch() {
        let g = DnnGraph::mlp_small();
        let machine = TargetConfig::Oma(OmaConfig::default()).build().unwrap();
        let plan = partition_graph(&g, 4, 2).unwrap();
        let machines: Vec<&Machine> = (0..plan.stages.len()).map(|_| &machine).collect();
        let desc = PlatformDesc::new(2).with_microbatches(3);
        let rep = run_platform(
            &machines,
            &g,
            &plan,
            4,
            &desc,
            SimMode::Functional,
            2,
            500_000_000,
        )
        .unwrap();
        assert_eq!(rep.outputs.len(), 3);
        for (b, out) in rep.outputs.iter().enumerate() {
            let x = microbatch_input(&g, 4, b);
            assert_eq!(out, &g.forward_ref(&x, 4), "microbatch {b}");
        }
        // Microbatches see distinct data.
        assert_ne!(rep.outputs[0], rep.outputs[1]);
    }

    #[test]
    fn thread_counts_report_identical_cycles() {
        let g = DnnGraph::mlp_small();
        let machine = TargetConfig::Oma(OmaConfig::default()).build().unwrap();
        let plan = partition_graph(&g, 4, 2).unwrap();
        let machines: Vec<&Machine> = (0..plan.stages.len()).map(|_| &machine).collect();
        let desc = PlatformDesc::new(2).with_microbatches(4);
        let mode = SimMode::Timed(BackendKind::EventDriven);
        let runs: Vec<PlatformReport> = [1usize, 2, 8]
            .iter()
            .map(|&t| {
                run_platform(&machines, &g, &plan, 4, &desc, mode, t, 500_000_000).unwrap()
            })
            .collect();
        for r in &runs[1..] {
            assert_eq!(r.total_cycles, runs[0].total_cycles);
            assert_eq!(r.total_instructions, runs[0].total_instructions);
            assert_eq!(r.outputs, runs[0].outputs);
            for (a, b) in r.stages.iter().zip(&runs[0].stages) {
                assert_eq!(a.busy_cycles, b.busy_cycles);
            }
        }
        assert!(runs[0].total_cycles > 0);
        assert!(runs[0].utilization > 0.0 && runs[0].utilization <= 1.0);
    }

    #[test]
    fn platform_trace_reconciles_with_stage_reports() {
        let g = DnnGraph::mlp_small();
        let machine = TargetConfig::Oma(OmaConfig::default()).build().unwrap();
        let plan = partition_graph(&g, 4, 2).unwrap();
        let machines: Vec<&Machine> = (0..plan.stages.len()).map(|_| &machine).collect();
        let desc = PlatformDesc::new(2).with_microbatches(3);
        let mode = SimMode::Timed(BackendKind::EventDriven);
        let mut tr = PlatformTrace::default();
        let rep = run_platform_traced(
            &machines,
            &g,
            &plan,
            4,
            &desc,
            mode,
            2,
            500_000_000,
            Some(&mut tr),
        )
        .unwrap();
        assert_eq!(tr.total_cycles, rep.total_cycles);
        assert_eq!(tr.chips.len(), rep.stages.len());
        assert_eq!(tr.cells.len(), rep.stages.len() * 3);
        let busy = tr.stage_busy_totals();
        for (s, st) in rep.stages.iter().enumerate() {
            assert_eq!(busy[s], st.busy_cycles, "stage {s} cell sum");
            assert_eq!(tr.chips[s], st.name);
        }
        assert_eq!(tr.weights.len(), rep.stages.len());
        assert_eq!(tr.inputs.len(), 3);
        assert_eq!(tr.writeback.len(), 3);
        assert_eq!(tr.fabric.len(), (rep.stages.len() - 1) * 3);
        // Every span is well-formed and inside the makespan.
        for c in &tr.cells {
            assert!(c.start <= c.end && c.end <= tr.total_cycles);
        }
    }

    #[test]
    fn platform_serving_is_thread_invariant_and_matches_reference() {
        let g = DnnGraph::transformer(2, 2);
        let machine = TargetConfig::Oma(OmaConfig::default()).build().unwrap();
        let (seq, steps) = (4usize, 2usize);
        let plan = partition_graph(&g, seq, 2).unwrap();
        let machines: Vec<&Machine> = (0..plan.stages.len()).map(|_| &machine).collect();
        let desc = PlatformDesc::new(plan.stages.len()).with_microbatches(2);
        let mode = SimMode::Timed(BackendKind::EventDriven);
        let runs: Vec<PlatformServingReport> = [1usize, 4]
            .iter()
            .map(|&t| {
                run_platform_serving(
                    &machines,
                    &g,
                    &plan,
                    seq,
                    steps,
                    &desc,
                    mode,
                    t,
                    500_000_000,
                    None,
                )
                .unwrap()
            })
            .collect();
        assert_eq!(runs[0].report.total_cycles, runs[1].report.total_cycles);
        assert_eq!(runs[0].prefill_cycles, runs[1].prefill_cycles);
        assert_eq!(runs[0].report.outputs, runs[1].report.outputs);
        assert!(runs[0].prefill_cycles > 0);
        assert!(runs[0].report.total_cycles > runs[0].prefill_cycles);
        assert_eq!(runs[0].decoded_tokens, 4);
        assert!(runs[0].cycles_per_token().unwrap() > 0.0);
        // Each session's assembled output is the KV-cache oracle:
        // bit-identical to the host reference over the extended sequence
        // (OMA lowers every op exactly).
        for (b, out) in runs[0].report.outputs.iter().enumerate() {
            let x = microbatch_input(&g, seq + steps, b);
            assert_eq!(out, &g.forward_ref(&x, seq + steps), "session {b}");
        }
    }

    #[test]
    fn platform_serving_trace_reconciles_with_stage_reports() {
        let g = DnnGraph::transformer(1, 2);
        let machine = TargetConfig::Oma(OmaConfig::default()).build().unwrap();
        let (seq, steps) = (3usize, 2usize);
        let plan = partition_graph(&g, seq, 2).unwrap();
        let machines: Vec<&Machine> = (0..plan.stages.len()).map(|_| &machine).collect();
        let desc = PlatformDesc::new(plan.stages.len()).with_microbatches(2);
        let mut tr = PlatformTrace::default();
        let rep = run_platform_serving(
            &machines,
            &g,
            &plan,
            seq,
            steps,
            &desc,
            SimMode::Timed(BackendKind::CycleStepped),
            2,
            500_000_000,
            Some(&mut tr),
        )
        .unwrap();
        assert_eq!(tr.total_cycles, rep.report.total_cycles);
        // One cell per (phase, stage, session).
        assert_eq!(tr.cells.len(), (1 + steps) * rep.report.stages.len() * 2);
        let busy = tr.stage_busy_totals();
        for (s, st) in rep.report.stages.iter().enumerate() {
            assert_eq!(busy[s], st.busy_cycles, "stage {s} cell sum");
        }
        assert_eq!(tr.inputs.len(), 2, "one prompt stream per session");
        assert_eq!(tr.writeback.len(), 2);
        for c in &tr.cells {
            assert!(c.start <= c.end && c.end <= tr.total_cycles);
        }
    }

    #[test]
    fn mismatched_machine_count_is_rejected() {
        let g = DnnGraph::mlp_small();
        let machine = TargetConfig::Oma(OmaConfig::default()).build().unwrap();
        let plan = partition_graph(&g, 4, 2).unwrap();
        let machines = [&machine]; // plan has 2 stages
        let desc = PlatformDesc::new(2);
        assert!(run_platform(
            &machines,
            &g,
            &plan,
            4,
            &desc,
            SimMode::Functional,
            1,
            1_000_000
        )
        .is_err());
    }
}
