//! Shared instruction semantics: the paper's `Instruction.function` /
//! `execute()` (§3), implemented once and used by both the functional ISS
//! and the timed engine (which captures operands at dispatch and commits
//! effects at completion).
//!
//! Memory is a word-addressed f32 image (4-byte words) — the payload type
//! of every modeled workload; integer register traffic never touches
//! memory in the paper's mappings except through loads/stores of data
//! values, which we model in f32 like the Γ̈ datapath.
//!
//! ## Allocation discipline (the hot-loop contract)
//!
//! `execute` runs once per dynamic instruction per simulation, multiplied
//! by hundreds of simulations per DSE sweep, so this module is built so
//! the steady state allocates nothing:
//!
//! * [`MemImage`] is a paged flat store — 4 KiB pages in a dense page
//!   table, with a hash-map fallback only for sparse outlier addresses —
//!   so a word access is a shift + mask + array index, not a SipHash
//!   probe.
//! * [`RegState`] keeps scalars as untagged 64-bit words beside a dense
//!   tag array; vector registers live in a stable arena.  Scalar reads
//!   and writes never touch the heap or clone a `Value`.
//! * [`execute_into`] fills a caller-owned [`Effects`] buffer (cleared,
//!   capacity retained) and [`commit`] *moves* vector payloads into the
//!   register file instead of cloning boxed slices.

use std::collections::HashMap;

use thiserror::Error;

use crate::acadl_core::data::{Value, ValueTag};
use crate::acadl_core::graph::RegId;
use crate::isa::instruction::{AddrRef, Instruction};
use crate::isa::opcode::Opcode;
use crate::isa::GAMMA_TILE;
use crate::util::numerics::{gelu_f32, rsqrt_f32};

#[derive(Debug, Error, Clone, PartialEq)]
pub enum ExecError {
    #[error("instruction {0} expects {1}")]
    Malformed(String, &'static str),
}

// ---------------------------------------------------------- register file

/// Register state: a scalar fast path (dense tags + untagged 64-bit
/// payload words) with arena-backed vector registers.
///
/// Scalar registers (`Int`/`F32`) live entirely in `tags[i]` + `bits[i]`;
/// the ALU paths ([`execute_into`]) read and write them without matching
/// on a [`Value`] or touching the heap.  Vector registers store an arena
/// slot in `bits[i]`; overwriting a vector register *moves* the incoming
/// boxed slice into the slot.  Slots orphaned by a scalar overwrite are
/// recycled through a free list, so long runs never grow the arena.
#[derive(Debug, Clone)]
pub struct RegState {
    tags: Vec<ValueTag>,
    /// `Int`: the `i64` bits.  `F32`: `f32::to_bits` in the low word.
    /// `Vec`: the arena slot index.
    bits: Vec<u64>,
    /// Vector-register payload arena.
    vecs: Vec<Box<[f32]>>,
    /// Arena slots orphaned by scalar overwrites, reused on the next
    /// vector write.
    free_vecs: Vec<u32>,
}

impl RegState {
    pub fn len(&self) -> usize {
        self.tags.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tags.is_empty()
    }

    #[inline]
    pub fn tag(&self, i: usize) -> ValueTag {
        self.tags[i]
    }

    #[inline]
    fn vec(&self, i: usize) -> &[f32] {
        &self.vecs[self.bits[i] as usize]
    }

    /// Integer view with [`Value::as_int`] semantics (floats truncate,
    /// vectors read as 0).
    #[inline]
    pub fn int(&self, i: usize) -> i64 {
        match self.tags[i] {
            ValueTag::Int => self.bits[i] as i64,
            ValueTag::F32 => f32::from_bits(self.bits[i] as u32) as i64,
            ValueTag::Vec => 0,
        }
    }

    /// Float view with [`Value::as_f32`] semantics (ints convert, vectors
    /// read their first lane).
    #[inline]
    pub fn f32(&self, i: usize) -> f32 {
        match self.tags[i] {
            ValueTag::Int => self.bits[i] as i64 as f32,
            ValueTag::F32 => f32::from_bits(self.bits[i] as u32),
            ValueTag::Vec => self.vec(i).first().copied().unwrap_or(0.0),
        }
    }

    /// Lane view with [`Value::as_slice`] semantics (scalars are empty).
    #[inline]
    pub fn slice(&self, i: usize) -> &[f32] {
        match self.tags[i] {
            ValueTag::Vec => self.vec(i),
            _ => &[],
        }
    }

    /// Lane count of a vector register, `None` for scalars.
    #[inline]
    pub fn lanes(&self, i: usize) -> Option<usize> {
        match self.tags[i] {
            ValueTag::Vec => Some(self.vec(i).len()),
            _ => None,
        }
    }

    #[inline]
    fn release_vec_slot(&mut self, i: usize) {
        if self.tags[i] == ValueTag::Vec {
            self.free_vecs.push(self.bits[i] as u32);
        }
    }

    #[inline]
    pub fn set_int(&mut self, i: usize, v: i64) {
        self.release_vec_slot(i);
        self.tags[i] = ValueTag::Int;
        self.bits[i] = v as u64;
    }

    #[inline]
    pub fn set_f32(&mut self, i: usize, v: f32) {
        self.release_vec_slot(i);
        self.tags[i] = ValueTag::F32;
        self.bits[i] = u64::from(v.to_bits());
    }

    /// Move a boxed lane payload into register `i` (no lane copy when the
    /// register already holds a vector: the arena slot is replaced).
    pub fn set_vec(&mut self, i: usize, v: Box<[f32]>) {
        if self.tags[i] == ValueTag::Vec {
            self.vecs[self.bits[i] as usize] = v;
            return;
        }
        let slot = match self.free_vecs.pop() {
            Some(s) => {
                self.vecs[s as usize] = v;
                s
            }
            None => {
                self.vecs.push(v);
                (self.vecs.len() - 1) as u32
            }
        };
        self.tags[i] = ValueTag::Vec;
        self.bits[i] = u64::from(slot);
    }

    /// Move a [`Value`] into register `i`.
    #[inline]
    pub fn set(&mut self, i: usize, v: Value) {
        match v {
            Value::Int(x) => self.set_int(i, x),
            Value::F32(x) => self.set_f32(i, x),
            Value::Vec(b) => self.set_vec(i, b),
        }
    }

    /// Snapshot register `i` as a [`Value`] (clones vector lanes — result
    /// extraction and `mov` capture, not the scalar hot path).
    pub fn get(&self, i: usize) -> Value {
        match self.tags[i] {
            ValueTag::Int => Value::Int(self.bits[i] as i64),
            ValueTag::F32 => Value::F32(f32::from_bits(self.bits[i] as u32)),
            ValueTag::Vec => Value::Vec(self.vec(i).into()),
        }
    }
}

impl FromIterator<Value> for RegState {
    fn from_iter<T: IntoIterator<Item = Value>>(iter: T) -> Self {
        let mut rs = RegState {
            tags: Vec::new(),
            bits: Vec::new(),
            vecs: Vec::new(),
            free_vecs: Vec::new(),
        };
        for v in iter {
            let i = rs.tags.len();
            rs.tags.push(ValueTag::Int);
            rs.bits.push(0);
            rs.set(i, v);
        }
        rs
    }
}

impl PartialEq for RegState {
    /// Logical per-register equality: arena slot layout is ignored, so two
    /// runs that allocated vector slots in different orders still compare
    /// equal.  Scalars keep `Value` semantics (`Int(5) != F32(5.0)`; f32
    /// compares as a float, not by bits).
    fn eq(&self, other: &Self) -> bool {
        self.tags.len() == other.tags.len()
            && (0..self.tags.len()).all(|i| match (self.tags[i], other.tags[i]) {
                (ValueTag::Int, ValueTag::Int) => self.bits[i] == other.bits[i],
                (ValueTag::F32, ValueTag::F32) => {
                    f32::from_bits(self.bits[i] as u32) == f32::from_bits(other.bits[i] as u32)
                }
                (ValueTag::Vec, ValueTag::Vec) => self.vec(i) == other.vec(i),
                _ => false,
            })
    }
}

// ---------------------------------------------------------- memory image

/// Words per page: 1024 × f32 = 4 KiB.
const PAGE_WORDS_LOG2: u32 = 10;
const PAGE_WORDS: usize = 1 << PAGE_WORDS_LOG2;
/// Pages below this index live in the dense page table (grown on demand);
/// higher addresses fall back to the word-keyed hash map.  1 << 15 pages
/// covers the first 128 MiB of the address space — every zoo model's
/// storage ranges fit — while a stray huge address costs one hash probe
/// instead of a giant table.
const DENSE_PAGES: usize = 1 << 15;

#[derive(Debug, Clone)]
struct Page {
    words: Box<[f32; PAGE_WORDS]>,
    /// One bit per word: ever written?  Keeps [`MemImage::len`] (distinct
    /// resident words) exact, matching the old hash-map semantics.
    occupied: Box<[u64; PAGE_WORDS / 64]>,
}

impl Page {
    fn new() -> Self {
        Page {
            words: Box::new([0.0; PAGE_WORDS]),
            occupied: Box::new([0; PAGE_WORDS / 64]),
        }
    }

    /// Store word `w`; returns whether it was newly occupied.
    #[inline]
    fn set(&mut self, w: usize, v: f32) -> bool {
        self.words[w] = v;
        let (i, m) = (w >> 6, 1u64 << (w & 63));
        let newly = self.occupied[i] & m == 0;
        self.occupied[i] |= m;
        newly
    }
}

/// Word-addressed functional memory image (f32 payloads): a paged flat
/// store.  Reads and writes mask to the 4-byte word (`addr & !3`); unknown
/// words read as zero.  The dense page table serves the model zoo's
/// storage ranges; `outliers` catches sparse far addresses.
#[derive(Debug, Clone, Default)]
pub struct MemImage {
    /// Dense page table over the low address range, lazily grown; `None`
    /// pages were never written.
    pages: Vec<Option<Page>>,
    /// Word-index-keyed fallback for addresses past the dense range.
    outliers: HashMap<u64, f32>,
    /// Distinct words ever written.
    resident: usize,
    pub reads: u64,
    pub writes: u64,
}

impl MemImage {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn read(&mut self, addr: u64) -> f32 {
        self.reads += 1;
        self.peek(addr)
    }

    #[inline]
    pub fn peek(&self, addr: u64) -> f32 {
        let w = (addr & !3) >> 2;
        let page = (w >> PAGE_WORDS_LOG2) as usize;
        if page < DENSE_PAGES {
            match self.pages.get(page) {
                Some(Some(p)) => p.words[w as usize & (PAGE_WORDS - 1)],
                _ => 0.0,
            }
        } else {
            self.outliers.get(&w).copied().unwrap_or(0.0)
        }
    }

    #[inline]
    pub fn write(&mut self, addr: u64, v: f32) {
        self.writes += 1;
        self.poke(addr, v);
    }

    /// Raw store without touching the write counter (bulk workload setup).
    fn poke(&mut self, addr: u64, v: f32) {
        let w = (addr & !3) >> 2;
        let page = (w >> PAGE_WORDS_LOG2) as usize;
        if page < DENSE_PAGES {
            if page >= self.pages.len() {
                self.pages.resize_with(page + 1, || None);
            }
            let p = self.pages[page].get_or_insert_with(Page::new);
            if p.set(w as usize & (PAGE_WORDS - 1), v) {
                self.resident += 1;
            }
        } else if self.outliers.insert(w, v).is_none() {
            self.resident += 1;
        }
    }

    /// Bulk-load a row-major f32 slice at `base` (workload setup).
    ///
    /// `base` is expected word-aligned (every codegen layout emits 4-byte
    /// aligned bases).  An unaligned base masks down to its word — the old
    /// hash-map store instead wrote unmasked keys that reads could never
    /// see, so this path is saner but only equivalent for aligned bases.
    pub fn load_f32(&mut self, base: u64, data: &[f32]) {
        debug_assert_eq!(base & 3, 0, "bulk loads use word-aligned bases");
        for (i, v) in data.iter().enumerate() {
            self.poke(base + 4 * i as u64, *v);
        }
    }

    /// Read back `len` f32 words from `base` (result extraction).
    pub fn dump_f32(&self, base: u64, len: usize) -> Vec<f32> {
        (0..len)
            .map(|i| self.peek(base + 4 * i as u64))
            .collect()
    }

    /// Distinct words ever written.
    pub fn len(&self) -> usize {
        self.resident
    }

    pub fn is_empty(&self) -> bool {
        self.resident == 0
    }
}

// ---------------------------------------------------------------- effects

/// The computed effects of one instruction: applied later by the caller
/// (at completion in the timed engine; immediately in the ISS).
#[derive(Debug, Clone, Default)]
pub struct Effects {
    pub reg_writes: Vec<(RegId, Value)>,
    pub mem_writes: Vec<(u64, f32)>,
    /// Absolute branch target, if the instruction redirects fetch.
    pub branch: Option<u64>,
    pub halt: bool,
    /// Resolved byte addresses read (addr, bytes) — for the timing model.
    pub mem_reads: Vec<(u64, u32)>,
    /// Resolved byte addresses written (addr, bytes).
    pub mem_stores: Vec<(u64, u32)>,
}

impl Effects {
    /// Reset for reuse, keeping every buffer's capacity (the simulation
    /// kernel and the ISS pool one `Effects` across instructions).
    pub fn clear(&mut self) {
        self.reg_writes.clear();
        self.mem_writes.clear();
        self.branch = None;
        self.halt = false;
        self.mem_reads.clear();
        self.mem_stores.clear();
    }
}

/// Resolve an address operand against current register values.
#[inline]
pub fn resolve_addr(a: &AddrRef, regs: &RegState) -> u64 {
    match a {
        AddrRef::Direct(x) => *x,
        AddrRef::Indirect { base, offset } => (regs.int(base.idx()) + offset) as u64,
    }
}

fn lanewise(op: Opcode, av: &[f32], bv: &[f32]) -> Value {
    let n = av.len().max(bv.len());
    let get = |s: &[f32], i: usize| s.get(i).copied().unwrap_or(0.0);
    let out: Vec<f32> = (0..n)
        .map(|i| {
            let (x, y) = (get(av, i), get(bv, i));
            match op {
                Opcode::VAdd => x + y,
                Opcode::VMul => x * y,
                Opcode::VMaxp => x.max(y),
                _ => unreachable!(),
            }
        })
        .collect();
    Value::Vec(out.into_boxed_slice())
}

/// Execute one instruction against `(regs, mem)` state into a caller-owned
/// effects buffer (cleared first; capacities are reused).  `self_addr` is
/// the instruction's byte address (relative branch bases).  Pure apart
/// from the memory read counters.
pub fn execute_into(
    ins: &Instruction,
    self_addr: u64,
    regs: &RegState,
    mem: &mut MemImage,
    fx: &mut Effects,
) -> Result<(), ExecError> {
    fx.clear();
    // Register index of source operand `i`.
    let r = |i: usize| -> usize { ins.reads[i].idx() };
    match ins.op {
        Opcode::Nop => {}
        Opcode::Halt => fx.halt = true,
        Opcode::Mov => {
            fx.reg_writes.push((ins.writes[0], regs.get(r(0))));
        }
        Opcode::Movi => {
            fx.reg_writes.push((ins.writes[0], Value::Int(ins.imms[0])));
        }
        Opcode::Add | Opcode::Sub | Opcode::Mul => {
            let (a, b) = (r(0), r(1));
            let v = if regs.tag(a) == ValueTag::Int && regs.tag(b) == ValueTag::Int {
                let (x, y) = (regs.int(a), regs.int(b));
                Value::Int(match ins.op {
                    Opcode::Add => x.wrapping_add(y),
                    Opcode::Sub => x.wrapping_sub(y),
                    _ => x.wrapping_mul(y),
                })
            } else {
                let (x, y) = (regs.f32(a), regs.f32(b));
                Value::F32(match ins.op {
                    Opcode::Add => x + y,
                    Opcode::Sub => x - y,
                    _ => x * y,
                })
            };
            fx.reg_writes.push((ins.writes[0], v));
        }
        Opcode::Addi | Opcode::Subi | Opcode::Muli => {
            let a = r(0);
            let imm = ins.imms[0];
            let v = if regs.tag(a) == ValueTag::Int {
                let x = regs.int(a);
                Value::Int(match ins.op {
                    Opcode::Addi => x.wrapping_add(imm),
                    Opcode::Subi => x.wrapping_sub(imm),
                    _ => x.wrapping_mul(imm),
                })
            } else {
                let (x, y) = (regs.f32(a), imm as f32);
                Value::F32(match ins.op {
                    Opcode::Addi => x + y,
                    Opcode::Subi => x - y,
                    _ => x * y,
                })
            };
            fx.reg_writes.push((ins.writes[0], v));
        }
        Opcode::Mac => {
            // acc' = acc + a*b; reads = [a, b, acc].
            if ins.reads.len() < 3 {
                return Err(ExecError::Malformed(ins.to_string(), "3 source registers"));
            }
            let (a, b, acc) = (r(0), r(1), r(2));
            let all_int = regs.tag(a) == ValueTag::Int
                && regs.tag(b) == ValueTag::Int
                && regs.tag(acc) == ValueTag::Int;
            let v = if all_int {
                Value::Int(regs.int(acc).wrapping_add(regs.int(a).wrapping_mul(regs.int(b))))
            } else {
                Value::F32(regs.f32(acc) + regs.f32(a) * regs.f32(b))
            };
            fx.reg_writes.push((ins.writes[0], v));
        }
        Opcode::MacFwd => {
            // reads = [a, b, acc]; writes = [acc, fwd_a?, fwd_b?];
            // imms[0] bit0 = forward a, bit1 = forward b.
            if ins.reads.len() < 3 || ins.writes.is_empty() {
                return Err(ExecError::Malformed(ins.to_string(), "3 reads / 1+ writes"));
            }
            let (a, b, acc) = (r(0), r(1), r(2));
            fx.reg_writes
                .push((ins.writes[0], Value::F32(regs.f32(acc) + regs.f32(a) * regs.f32(b))));
            let flags = ins.imms.first().copied().unwrap_or(0);
            let mut w = 1;
            if flags & 1 != 0 {
                fx.reg_writes.push((ins.writes[w], regs.get(a)));
                w += 1;
            }
            if flags & 2 != 0 {
                fx.reg_writes.push((ins.writes[w], regs.get(b)));
            }
        }
        Opcode::Div => {
            // Always f32: the paper's datapath divides activations, not
            // addresses (integer division is not modeled).
            if ins.reads.len() < 2 || ins.writes.is_empty() {
                return Err(ExecError::Malformed(ins.to_string(), "2 source registers"));
            }
            let v = regs.f32(r(0)) / regs.f32(r(1));
            fx.reg_writes.push((ins.writes[0], Value::F32(v)));
        }
        Opcode::Max => {
            if ins.reads.len() < 2 || ins.writes.is_empty() {
                return Err(ExecError::Malformed(ins.to_string(), "2 source registers"));
            }
            let (a, b) = (r(0), r(1));
            let v = if regs.tag(a) == ValueTag::Int && regs.tag(b) == ValueTag::Int {
                Value::Int(regs.int(a).max(regs.int(b)))
            } else {
                Value::F32(regs.f32(a).max(regs.f32(b)))
            };
            fx.reg_writes.push((ins.writes[0], v));
        }
        Opcode::Exp | Opcode::Rsqrt | Opcode::Gelu => {
            if ins.reads.is_empty() || ins.writes.is_empty() {
                return Err(ExecError::Malformed(ins.to_string(), "1 source register"));
            }
            let x = regs.f32(r(0));
            let v = match ins.op {
                Opcode::Exp => x.exp(),
                Opcode::Rsqrt => rsqrt_f32(x),
                _ => gelu_f32(x),
            };
            fx.reg_writes.push((ins.writes[0], Value::F32(v)));
        }
        Opcode::Load => {
            let addr = resolve_addr(&ins.read_addrs[0], regs);
            let dest = ins.writes[0];
            match regs.lanes(dest.idx()) {
                Some(n) => {
                    let v: Vec<f32> = (0..n).map(|i| mem.read(addr + 4 * i as u64)).collect();
                    fx.mem_reads.push((addr, 4 * n as u32));
                    fx.reg_writes.push((dest, Value::Vec(v.into_boxed_slice())));
                }
                None => {
                    let v = mem.read(addr);
                    fx.mem_reads.push((addr, 4));
                    // Preserve integer-ness for address registers: data
                    // loads land in f32.
                    fx.reg_writes.push((dest, Value::F32(v)));
                }
            }
        }
        Opcode::Store => {
            let addr = resolve_addr(&ins.write_addrs[0], regs);
            let src = r(0);
            match regs.tag(src) {
                ValueTag::Vec => {
                    let v = regs.slice(src);
                    for (i, x) in v.iter().enumerate() {
                        fx.mem_writes.push((addr + 4 * i as u64, *x));
                    }
                    fx.mem_stores.push((addr, 4 * v.len() as u32));
                }
                _ => {
                    fx.mem_writes.push((addr, regs.f32(src)));
                    fx.mem_stores.push((addr, 4));
                }
            }
        }
        Opcode::Beqi | Opcode::Bnei => {
            let taken = match ins.op {
                Opcode::Beqi => regs.int(r(0)) == regs.int(r(1)),
                _ => regs.int(r(0)) != regs.int(r(1)),
            };
            if taken {
                fx.branch = Some((self_addr as i64 + ins.imms[0]) as u64);
            }
        }
        Opcode::Jumpi => {
            fx.branch = Some((self_addr as i64 + ins.imms[0]) as u64);
        }
        Opcode::VAdd | Opcode::VMul | Opcode::VMaxp => {
            fx.reg_writes
                .push((ins.writes[0], lanewise(ins.op, regs.slice(r(0)), regs.slice(r(1)))));
        }
        Opcode::VRelu => {
            let v: Vec<f32> = regs.slice(r(0)).iter().map(|x| x.max(0.0)).collect();
            fx.reg_writes
                .push((ins.writes[0], Value::Vec(v.into_boxed_slice())));
        }
        Opcode::Gemm => {
            // reads = 8 A rows ++ 8 B rows; writes = 8 C rows;
            // imms[0] = 1 enables ReLU (Listing 4).
            let t = GAMMA_TILE;
            if ins.reads.len() != 2 * t || ins.writes.len() != t {
                return Err(ExecError::Malformed(
                    ins.to_string(),
                    "16 source rows and 8 destination rows",
                ));
            }
            let relu = ins.imms.first().copied().unwrap_or(0) == 1;
            let row = |i: usize| -> &[f32] { regs.slice(ins.reads[i].idx()) };
            for i in 0..t {
                let mut out = vec![0.0f32; t];
                for (j, o) in out.iter_mut().enumerate() {
                    let mut acc = 0.0f32;
                    for k in 0..t {
                        let a = row(i).get(k).copied().unwrap_or(0.0);
                        let b = row(t + k).get(j).copied().unwrap_or(0.0);
                        acc += a * b;
                    }
                    *o = if relu { acc.max(0.0) } else { acc };
                }
                fx.reg_writes
                    .push((ins.writes[i], Value::Vec(out.into_boxed_slice())));
            }
        }
    }
    Ok(())
}

/// Execute into a fresh [`Effects`] (one-shot callers; the hot paths use
/// [`execute_into`] with a pooled buffer).
pub fn execute(
    ins: &Instruction,
    self_addr: u64,
    regs: &RegState,
    mem: &mut MemImage,
) -> Result<Effects, ExecError> {
    let mut fx = Effects::default();
    execute_into(ins, self_addr, regs, mem, &mut fx)?;
    Ok(fx)
}

/// Apply computed effects to register state + memory, leaving `fx` intact
/// (clones vector payloads — estimator paths that re-read the effects).
pub fn apply(fx: &Effects, regs: &mut RegState, mem: &mut MemImage) {
    for (r, v) in &fx.reg_writes {
        regs.set(r.idx(), v.clone());
    }
    for (a, v) in &fx.mem_writes {
        mem.write(*a, *v);
    }
}

/// Commit computed effects, draining the write lists and *moving* vector
/// payloads into the register file (no lane clone).  `branch`/`halt` stay
/// readable afterwards.
pub fn commit(fx: &mut Effects, regs: &mut RegState, mem: &mut MemImage) {
    for (r, v) in fx.reg_writes.drain(..) {
        regs.set(r.idx(), v);
    }
    for (a, v) in fx.mem_writes.drain(..) {
        mem.write(a, v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn regs(n: usize) -> RegState {
        (0..n).map(|_| Value::Int(0)).collect()
    }

    #[test]
    fn scalar_alu() {
        let mut mem = MemImage::new();
        let mut rs = regs(4);
        rs.set(0, Value::Int(5));
        rs.set(1, Value::Int(3));
        let add = Instruction::new(Opcode::Add)
            .with_reads(vec![RegId(0), RegId(1)])
            .with_writes(vec![RegId(2)]);
        let fx = execute(&add, 0, &rs, &mut mem).unwrap();
        apply(&fx, &mut rs, &mut mem);
        assert_eq!(rs.get(2), Value::Int(8));

        let subi = Instruction::new(Opcode::Subi)
            .with_reads(vec![RegId(2)])
            .with_imms(vec![10])
            .with_writes(vec![RegId(3)]);
        let fx = execute(&subi, 0, &rs, &mut mem).unwrap();
        apply(&fx, &mut rs, &mut mem);
        assert_eq!(rs.get(3), Value::Int(-2));
    }

    #[test]
    fn scalar_alu_mixed_types_fall_back_to_f32() {
        let mut mem = MemImage::new();
        let mut rs = regs(3);
        rs.set(0, Value::Int(2));
        rs.set(1, Value::F32(1.5));
        let add = Instruction::new(Opcode::Add)
            .with_reads(vec![RegId(0), RegId(1)])
            .with_writes(vec![RegId(2)]);
        let fx = execute(&add, 0, &rs, &mut mem).unwrap();
        apply(&fx, &mut rs, &mut mem);
        assert_eq!(rs.get(2), Value::F32(3.5));
    }

    #[test]
    fn mac_int_and_float() {
        let mut mem = MemImage::new();
        let mut rs = regs(4);
        rs.set(0, Value::F32(2.0));
        rs.set(1, Value::F32(3.0));
        rs.set(2, Value::F32(10.0));
        let mac = Instruction::new(Opcode::Mac)
            .with_reads(vec![RegId(0), RegId(1), RegId(2)])
            .with_writes(vec![RegId(2)]);
        let fx = execute(&mac, 0, &rs, &mut mem).unwrap();
        apply(&fx, &mut rs, &mut mem);
        assert_eq!(rs.get(2), Value::F32(16.0));

        rs.set(0, Value::Int(2));
        rs.set(1, Value::Int(3));
        rs.set(2, Value::Int(10));
        let fx = execute(&mac, 0, &rs, &mut mem).unwrap();
        apply(&fx, &mut rs, &mut mem);
        assert_eq!(rs.get(2), Value::Int(16), "all-int mac stays integer");
    }

    #[test]
    fn load_store_scalar_roundtrip() {
        let mut mem = MemImage::new();
        let mut rs = regs(4);
        rs.set(1, Value::F32(7.5));
        rs.set(3, Value::Int(0x100));
        let st = Instruction::new(Opcode::Store)
            .with_reads(vec![RegId(1)])
            .with_write_addrs(vec![AddrRef::Indirect {
                base: RegId(3),
                offset: 8,
            }]);
        let fx = execute(&st, 0, &rs, &mut mem).unwrap();
        apply(&fx, &mut rs, &mut mem);
        assert_eq!(mem.peek(0x108), 7.5);
        assert_eq!(fx.mem_stores, vec![(0x108, 4)]);

        let ld = Instruction::new(Opcode::Load)
            .with_read_addrs(vec![AddrRef::Direct(0x108)])
            .with_writes(vec![RegId(0)]);
        let fx = execute(&ld, 0, &rs, &mut mem).unwrap();
        apply(&fx, &mut rs, &mut mem);
        assert_eq!(rs.get(0), Value::F32(7.5));
    }

    #[test]
    fn vector_load_uses_dest_lanes() {
        let mut mem = MemImage::new();
        mem.load_f32(0x200, &[1.0, 2.0, 3.0, 4.0]);
        let mut rs = regs(2);
        rs.set(0, Value::zero_vec(4));
        let ld = Instruction::new(Opcode::Load)
            .with_read_addrs(vec![AddrRef::Direct(0x200)])
            .with_writes(vec![RegId(0)]);
        let fx = execute(&ld, 0, &rs, &mut mem).unwrap();
        assert_eq!(fx.mem_reads, vec![(0x200, 16)]);
        apply(&fx, &mut rs, &mut mem);
        assert_eq!(rs.slice(0), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn branches() {
        let mut mem = MemImage::new();
        let mut rs = regs(2);
        rs.set(0, Value::Int(0));
        rs.set(1, Value::Int(0));
        let beq = Instruction::new(Opcode::Beqi)
            .with_reads(vec![RegId(0), RegId(1)])
            .with_imms(vec![-28]);
        let fx = execute(&beq, 100, &rs, &mut mem).unwrap();
        assert_eq!(fx.branch, Some(72));
        rs.set(0, Value::Int(1));
        let fx = execute(&beq, 100, &rs, &mut mem).unwrap();
        assert_eq!(fx.branch, None, "not taken");
        let j = Instruction::new(Opcode::Jumpi).with_imms(vec![8]);
        assert_eq!(execute(&j, 100, &rs, &mut mem).unwrap().branch, Some(108));
    }

    #[test]
    fn gemm_matches_naive() {
        let t = GAMMA_TILE;
        let mut mem = MemImage::new();
        let mut rs: RegState = (0..3 * t).map(|_| Value::zero_vec(t)).collect();
        // A = row-index matrix, B = identity → C = A.
        for i in 0..t {
            let a: Vec<f32> = (0..t).map(|k| (i * t + k) as f32).collect();
            rs.set(i, Value::Vec(a.into_boxed_slice()));
            let mut b = vec![0.0f32; t];
            b[i] = 1.0;
            rs.set(t + i, Value::Vec(b.into_boxed_slice()));
        }
        let g = Instruction::new(Opcode::Gemm)
            .with_reads((0..2 * t as u32).map(RegId).collect())
            .with_writes((2 * t as u32..3 * t as u32).map(RegId).collect())
            .with_imms(vec![0]);
        let fx = execute(&g, 0, &rs, &mut mem).unwrap();
        apply(&fx, &mut rs, &mut mem);
        for i in 0..t {
            let want: Vec<f32> = (0..t).map(|k| (i * t + k) as f32).collect();
            assert_eq!(rs.slice(2 * t + i), &want[..]);
        }
    }

    #[test]
    fn gemm_relu_flag() {
        let t = GAMMA_TILE;
        let mut mem = MemImage::new();
        let mut rs: RegState = (0..3 * t).map(|_| Value::zero_vec(t)).collect();
        for i in 0..t {
            rs.set(i, Value::Vec(vec![-1.0; t].into_boxed_slice()));
            let mut b = vec![0.0f32; t];
            b[i] = 1.0;
            rs.set(t + i, Value::Vec(b.into_boxed_slice()));
        }
        let mut g = Instruction::new(Opcode::Gemm)
            .with_reads((0..2 * t as u32).map(RegId).collect())
            .with_writes((2 * t as u32..3 * t as u32).map(RegId).collect())
            .with_imms(vec![1]);
        let fx = execute(&g, 0, &rs, &mut mem).unwrap();
        assert!(fx
            .reg_writes
            .iter()
            .all(|(_, v)| v.as_slice().iter().all(|&x| x == 0.0)));
        g.imms = vec![0];
        let fx = execute(&g, 0, &rs, &mut mem).unwrap();
        assert!(fx
            .reg_writes
            .iter()
            .any(|(_, v)| v.as_slice().iter().any(|&x| x < 0.0)));
    }

    #[test]
    fn macfwd_forwards_operands() {
        let mut mem = MemImage::new();
        let mut rs = regs(6);
        rs.set(0, Value::F32(2.0)); // a
        rs.set(1, Value::F32(4.0)); // b
        rs.set(2, Value::F32(1.0)); // acc
        let m = Instruction::new(Opcode::MacFwd)
            .with_reads(vec![RegId(0), RegId(1), RegId(2)])
            .with_writes(vec![RegId(2), RegId(4), RegId(5)])
            .with_imms(vec![3]);
        let fx = execute(&m, 0, &rs, &mut mem).unwrap();
        apply(&fx, &mut rs, &mut mem);
        assert_eq!(rs.get(2), Value::F32(9.0));
        assert_eq!(rs.get(4), Value::F32(2.0), "a forwarded");
        assert_eq!(rs.get(5), Value::F32(4.0), "b forwarded");
    }

    #[test]
    fn scalar_reduction_ops() {
        let mut mem = MemImage::new();
        let mut rs = regs(4);
        rs.set(0, Value::F32(8.0));
        rs.set(1, Value::F32(2.0));
        let bin = |op: Opcode| {
            Instruction::new(op)
                .with_reads(vec![RegId(0), RegId(1)])
                .with_writes(vec![RegId(2)])
        };
        let fx = execute(&bin(Opcode::Div), 0, &rs, &mut mem).unwrap();
        assert_eq!(fx.reg_writes[0].1, Value::F32(4.0));
        let fx = execute(&bin(Opcode::Max), 0, &rs, &mut mem).unwrap();
        assert_eq!(fx.reg_writes[0].1, Value::F32(8.0));
        // Both-int max stays integer (address/index comparisons).
        rs.set(0, Value::Int(-3));
        rs.set(1, Value::Int(5));
        let fx = execute(&bin(Opcode::Max), 0, &rs, &mut mem).unwrap();
        assert_eq!(fx.reg_writes[0].1, Value::Int(5));
        // Int operands divide as f32 (Value::as_f32 view).
        let fx = execute(&bin(Opcode::Div), 0, &rs, &mut mem).unwrap();
        assert_eq!(fx.reg_writes[0].1, Value::F32(-0.6));
    }

    #[test]
    fn scalar_unary_ops_match_shared_numerics() {
        let mut mem = MemImage::new();
        let mut rs = regs(2);
        let un = |op: Opcode| {
            Instruction::new(op)
                .with_reads(vec![RegId(0)])
                .with_writes(vec![RegId(1)])
        };
        for x in [-2.5f32, -0.5, 0.25, 1.0, 3.0] {
            rs.set(0, Value::F32(x));
            let fx = execute(&un(Opcode::Exp), 0, &rs, &mut mem).unwrap();
            assert_eq!(fx.reg_writes[0].1, Value::F32(x.exp()));
            let fx = execute(&un(Opcode::Gelu), 0, &rs, &mut mem).unwrap();
            assert_eq!(
                fx.reg_writes[0].1,
                Value::F32(crate::util::numerics::gelu_f32(x))
            );
        }
        rs.set(0, Value::F32(4.0));
        let fx = execute(&un(Opcode::Rsqrt), 0, &rs, &mut mem).unwrap();
        assert_eq!(fx.reg_writes[0].1, Value::F32(0.5));
    }

    #[test]
    fn malformed_scalar_reduction_ops_report_exec_error() {
        let mut mem = MemImage::new();
        let rs = regs(2);
        let short = Instruction::new(Opcode::Div)
            .with_reads(vec![RegId(0)])
            .with_writes(vec![RegId(1)]);
        assert!(matches!(
            execute(&short, 0, &rs, &mut mem),
            Err(ExecError::Malformed(_, "2 source registers"))
        ));
        let no_write = Instruction::new(Opcode::Exp).with_reads(vec![RegId(0)]);
        assert!(matches!(
            execute(&no_write, 0, &rs, &mut mem),
            Err(ExecError::Malformed(_, _))
        ));
        let no_reads = Instruction::new(Opcode::Gelu).with_writes(vec![RegId(1)]);
        assert!(matches!(
            execute(&no_reads, 0, &rs, &mut mem),
            Err(ExecError::Malformed(_, _))
        ));
    }

    // ------------------------------------------------ malformed operands

    #[test]
    fn malformed_mac_reports_exec_error() {
        let mut mem = MemImage::new();
        let rs = regs(4);
        let mac = Instruction::new(Opcode::Mac)
            .with_reads(vec![RegId(0), RegId(1)]) // needs 3
            .with_writes(vec![RegId(2)]);
        assert!(matches!(
            execute(&mac, 0, &rs, &mut mem),
            Err(ExecError::Malformed(_, "3 source registers"))
        ));
    }

    #[test]
    fn malformed_macfwd_reports_exec_error() {
        let mut mem = MemImage::new();
        let rs = regs(4);
        let short_reads = Instruction::new(Opcode::MacFwd)
            .with_reads(vec![RegId(0), RegId(1)])
            .with_writes(vec![RegId(2)]);
        assert!(matches!(
            execute(&short_reads, 0, &rs, &mut mem),
            Err(ExecError::Malformed(_, _))
        ));
        let no_writes = Instruction::new(Opcode::MacFwd)
            .with_reads(vec![RegId(0), RegId(1), RegId(2)]);
        assert!(matches!(
            execute(&no_writes, 0, &rs, &mut mem),
            Err(ExecError::Malformed(_, _))
        ));
    }

    #[test]
    fn malformed_gemm_reports_exec_error() {
        let t = GAMMA_TILE;
        let mut mem = MemImage::new();
        let rs = regs(3 * t);
        let wrong_reads = Instruction::new(Opcode::Gemm)
            .with_reads((0..t as u32).map(RegId).collect()) // needs 2t
            .with_writes((0..t as u32).map(RegId).collect());
        assert!(matches!(
            execute(&wrong_reads, 0, &rs, &mut mem),
            Err(ExecError::Malformed(_, _))
        ));
        let wrong_writes = Instruction::new(Opcode::Gemm)
            .with_reads((0..2 * t as u32).map(RegId).collect())
            .with_writes((0..(t as u32 - 1)).map(RegId).collect()); // needs t
        assert!(matches!(
            execute(&wrong_writes, 0, &rs, &mut mem),
            Err(ExecError::Malformed(_, _))
        ));
    }

    // ----------------------------------------------------- effects pool

    #[test]
    fn execute_into_reuses_buffers_and_commit_moves() {
        let mut mem = MemImage::new();
        let mut rs = regs(3);
        rs.set(0, Value::Int(1));
        rs.set(1, Value::Int(2));
        let add = Instruction::new(Opcode::Add)
            .with_reads(vec![RegId(0), RegId(1)])
            .with_writes(vec![RegId(2)]);
        let mut fx = Effects::default();
        execute_into(&add, 0, &rs, &mut mem, &mut fx).unwrap();
        commit(&mut fx, &mut rs, &mut mem);
        assert_eq!(rs.get(2), Value::Int(3));
        assert!(fx.reg_writes.is_empty(), "commit drains the write list");
        // Second use of the same buffer sees a clean slate.
        let halt = Instruction::new(Opcode::Halt);
        execute_into(&halt, 0, &rs, &mut mem, &mut fx).unwrap();
        assert!(fx.halt && fx.reg_writes.is_empty() && fx.branch.is_none());
    }

    // ------------------------------------------------------ paged memory

    #[test]
    fn mem_defaults_masking_and_counters() {
        let mut mem = MemImage::new();
        assert_eq!(mem.peek(0x4000), 0.0, "unwritten words read zero");
        mem.write(0x103, 2.5); // masks to 0x100
        assert_eq!(mem.peek(0x100), 2.5);
        assert_eq!(mem.read(0x101), 2.5, "reads mask too");
        assert_eq!((mem.reads, mem.writes), (1, 1));
        assert_eq!(mem.len(), 1);
        mem.write(0x100, 3.5); // overwrite: resident count unchanged
        assert_eq!(mem.len(), 1);
        assert_eq!(mem.peek(0x100), 3.5);
    }

    #[test]
    fn mem_page_boundary_roundtrip() {
        let mut mem = MemImage::new();
        // Straddle the 4 KiB page boundary at 0x1000.
        let data: Vec<f32> = (0..8).map(|i| i as f32 + 0.5).collect();
        mem.load_f32(0x1000 - 16, &data);
        assert_eq!(mem.dump_f32(0x1000 - 16, 8), data);
        assert_eq!(mem.len(), 8);
        assert_eq!(mem.writes, 0, "bulk load does not count as writes");
    }

    #[test]
    fn mem_outlier_addresses_fall_back() {
        let mut mem = MemImage::new();
        let far = 1u64 << 40; // far past the dense page range
        mem.write(far, 9.0);
        assert_eq!(mem.peek(far), 9.0);
        assert_eq!(mem.peek(far + 4), 0.0);
        assert_eq!(mem.len(), 1);
    }

    // ----------------------------------------------------- register file

    #[test]
    fn regstate_roundtrip_and_accessors() {
        let mut rs = regs(3);
        rs.set(0, Value::Int(7));
        rs.set(1, Value::F32(2.5));
        rs.set(2, Value::Vec(vec![1.0, 2.0].into_boxed_slice()));
        assert_eq!(rs.int(0), 7);
        assert_eq!(rs.f32(0), 7.0);
        assert_eq!(rs.f32(1), 2.5);
        assert_eq!(rs.int(1), 2, "float truncates like Value::as_int");
        assert_eq!(rs.f32(2), 1.0, "vector reads first lane");
        assert_eq!(rs.int(2), 0, "vector reads 0 as int");
        assert_eq!(rs.slice(2), &[1.0, 2.0]);
        assert_eq!(rs.slice(0), &[] as &[f32]);
        assert_eq!(rs.lanes(2), Some(2));
        assert_eq!(rs.lanes(0), None);
        assert_eq!(rs.get(2), Value::Vec(vec![1.0, 2.0].into_boxed_slice()));
    }

    #[test]
    fn regstate_equality_ignores_arena_layout() {
        let mut a = regs(2);
        let mut b = regs(2);
        // Fill vector slots in opposite orders: arena indices differ.
        a.set(0, Value::Vec(vec![1.0].into_boxed_slice()));
        a.set(1, Value::Vec(vec![2.0].into_boxed_slice()));
        b.set(1, Value::Vec(vec![2.0].into_boxed_slice()));
        b.set(0, Value::Vec(vec![1.0].into_boxed_slice()));
        assert_eq!(a, b);
        b.set(0, Value::Vec(vec![9.0].into_boxed_slice()));
        assert_ne!(a, b);
        // Scalars keep Value semantics: Int(5) != F32(5.0).
        let mut c = regs(1);
        let mut d = regs(1);
        c.set(0, Value::Int(5));
        d.set(0, Value::F32(5.0));
        assert_ne!(c, d);
    }

    #[test]
    fn regstate_recycles_vector_slots() {
        let mut rs = regs(1);
        // Flip the register between vector and scalar repeatedly; the
        // arena must recycle the orphaned slot instead of growing.
        for i in 0..64 {
            rs.set(0, Value::Vec(vec![i as f32; 4].into_boxed_slice()));
            rs.set(0, Value::Int(i));
        }
        rs.set(0, Value::Vec(vec![42.0; 4].into_boxed_slice()));
        assert_eq!(rs.vecs.len(), 1, "orphaned arena slots are reused");
        assert_eq!(rs.slice(0), &[42.0; 4]);
    }
}
