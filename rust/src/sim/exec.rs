//! Shared instruction semantics: the paper's `Instruction.function` /
//! `execute()` (§3), implemented once and used by both the functional ISS
//! and the timed engine (which captures operands at dispatch and commits
//! effects at completion).
//!
//! Memory is a word-addressed f32 image (4-byte words) — the payload type
//! of every modeled workload; integer register traffic never touches
//! memory in the paper's mappings except through loads/stores of data
//! values, which we model in f32 like the Γ̈ datapath.

use std::collections::HashMap;

use thiserror::Error;

use crate::acadl_core::data::Value;
use crate::acadl_core::graph::RegId;
use crate::isa::instruction::{AddrRef, Instruction};
use crate::isa::opcode::Opcode;
use crate::isa::GAMMA_TILE;

#[derive(Debug, Error, Clone, PartialEq)]
pub enum ExecError {
    #[error("instruction {0} expects {1}")]
    Malformed(String, &'static str),
    #[error("register %{0:?} holds no vector but a vector op needs one")]
    NotVector(RegId),
}

/// Register state: dense values indexed by `RegId`.
pub type RegState = Vec<Value>;

/// Word-addressed functional memory image (f32 payloads).
#[derive(Debug, Clone, Default)]
pub struct MemImage {
    words: HashMap<u64, f32>,
    pub reads: u64,
    pub writes: u64,
}

impl MemImage {
    pub fn new() -> Self {
        Self::default()
    }

    #[inline]
    pub fn read(&mut self, addr: u64) -> f32 {
        self.reads += 1;
        self.words.get(&(addr & !3)).copied().unwrap_or(0.0)
    }

    #[inline]
    pub fn peek(&self, addr: u64) -> f32 {
        self.words.get(&(addr & !3)).copied().unwrap_or(0.0)
    }

    #[inline]
    pub fn write(&mut self, addr: u64, v: f32) {
        self.writes += 1;
        self.words.insert(addr & !3, v);
    }

    /// Bulk-load a row-major f32 slice at `base` (workload setup).
    pub fn load_f32(&mut self, base: u64, data: &[f32]) {
        for (i, v) in data.iter().enumerate() {
            self.words.insert(base + 4 * i as u64, *v);
        }
    }

    /// Read back `len` f32 words from `base` (result extraction).
    pub fn dump_f32(&self, base: u64, len: usize) -> Vec<f32> {
        (0..len)
            .map(|i| self.peek(base + 4 * i as u64))
            .collect()
    }

    pub fn len(&self) -> usize {
        self.words.len()
    }

    pub fn is_empty(&self) -> bool {
        self.words.is_empty()
    }
}

/// The computed effects of one instruction: applied later by the caller
/// (at completion in the timed engine; immediately in the ISS).
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Effects {
    pub reg_writes: Vec<(RegId, Value)>,
    pub mem_writes: Vec<(u64, f32)>,
    /// Absolute branch target, if the instruction redirects fetch.
    pub branch: Option<u64>,
    pub halt: bool,
    /// Resolved byte addresses read (addr, bytes) — for the timing model.
    pub mem_reads: Vec<(u64, u32)>,
    /// Resolved byte addresses written (addr, bytes).
    pub mem_stores: Vec<(u64, u32)>,
}

/// Resolve an address operand against current register values.
#[inline]
pub fn resolve_addr(a: &AddrRef, regs: &RegState) -> u64 {
    match a {
        AddrRef::Direct(x) => *x,
        AddrRef::Indirect { base, offset } => {
            (regs[base.idx()].as_int() + offset) as u64
        }
    }
}

#[inline]
fn lanes_of(v: &Value) -> Option<usize> {
    match v {
        Value::Vec(x) => Some(x.len()),
        _ => None,
    }
}

fn binop_scalar(op: Opcode, a: &Value, b: &Value) -> Value {
    match (a, b) {
        (Value::Int(x), Value::Int(y)) => Value::Int(match op {
            Opcode::Add | Opcode::Addi => x.wrapping_add(*y),
            Opcode::Sub | Opcode::Subi => x.wrapping_sub(*y),
            Opcode::Mul | Opcode::Muli => x.wrapping_mul(*y),
            _ => unreachable!(),
        }),
        _ => {
            let (x, y) = (a.as_f32(), b.as_f32());
            Value::F32(match op {
                Opcode::Add | Opcode::Addi => x + y,
                Opcode::Sub | Opcode::Subi => x - y,
                Opcode::Mul | Opcode::Muli => x * y,
                _ => unreachable!(),
            })
        }
    }
}

fn lanewise(op: Opcode, a: &Value, b: &Value) -> Result<Value, ExecError> {
    let (av, bv) = (a.as_slice(), b.as_slice());
    let n = av.len().max(bv.len());
    let get = |s: &[f32], i: usize| s.get(i).copied().unwrap_or(0.0);
    let out: Vec<f32> = (0..n)
        .map(|i| {
            let (x, y) = (get(av, i), get(bv, i));
            match op {
                Opcode::VAdd => x + y,
                Opcode::VMul => x * y,
                Opcode::VMaxp => x.max(y),
                _ => unreachable!(),
            }
        })
        .collect();
    Ok(Value::Vec(out.into_boxed_slice()))
}

/// Execute one instruction against `(regs, mem)` state.  `self_addr` is the
/// instruction's byte address (relative branch bases).  Pure apart from the
/// memory read counters.
pub fn execute(
    ins: &Instruction,
    self_addr: u64,
    regs: &RegState,
    mem: &mut MemImage,
) -> Result<Effects, ExecError> {
    let mut fx = Effects::default();
    let rd = |i: usize| -> &Value { &regs[ins.reads[i].idx()] };
    match ins.op {
        Opcode::Nop => {}
        Opcode::Halt => fx.halt = true,
        Opcode::Mov => {
            fx.reg_writes.push((ins.writes[0], rd(0).clone()));
        }
        Opcode::Movi => {
            fx.reg_writes.push((ins.writes[0], Value::Int(ins.imms[0])));
        }
        Opcode::Add | Opcode::Sub | Opcode::Mul => {
            fx.reg_writes
                .push((ins.writes[0], binop_scalar(ins.op, rd(0), rd(1))));
        }
        Opcode::Addi | Opcode::Subi | Opcode::Muli => {
            fx.reg_writes.push((
                ins.writes[0],
                binop_scalar(ins.op, rd(0), &Value::Int(ins.imms[0])),
            ));
        }
        Opcode::Mac => {
            // acc' = acc + a*b; reads = [a, b, acc].
            if ins.reads.len() < 3 {
                return Err(ExecError::Malformed(ins.to_string(), "3 source registers"));
            }
            let (a, b, acc) = (rd(0), rd(1), rd(2));
            let v = match (a, b, acc) {
                (Value::Int(x), Value::Int(y), Value::Int(z)) => {
                    Value::Int(z.wrapping_add(x.wrapping_mul(*y)))
                }
                _ => Value::F32(acc.as_f32() + a.as_f32() * b.as_f32()),
            };
            fx.reg_writes.push((ins.writes[0], v));
        }
        Opcode::MacFwd => {
            // reads = [a, b, acc]; writes = [acc, fwd_a?, fwd_b?];
            // imms[0] bit0 = forward a, bit1 = forward b.
            if ins.reads.len() < 3 || ins.writes.is_empty() {
                return Err(ExecError::Malformed(ins.to_string(), "3 reads / 1+ writes"));
            }
            let (a, b, acc) = (rd(0).clone(), rd(1).clone(), rd(2));
            fx.reg_writes
                .push((ins.writes[0], Value::F32(acc.as_f32() + a.as_f32() * b.as_f32())));
            let flags = ins.imms.first().copied().unwrap_or(0);
            let mut w = 1;
            if flags & 1 != 0 {
                fx.reg_writes.push((ins.writes[w], a));
                w += 1;
            }
            if flags & 2 != 0 {
                fx.reg_writes.push((ins.writes[w], b));
            }
        }
        Opcode::Load => {
            let addr = resolve_addr(&ins.read_addrs[0], regs);
            let dest = ins.writes[0];
            match lanes_of(&regs[dest.idx()]) {
                Some(n) => {
                    let v: Vec<f32> = (0..n).map(|i| mem.read(addr + 4 * i as u64)).collect();
                    fx.mem_reads.push((addr, 4 * n as u32));
                    fx.reg_writes.push((dest, Value::Vec(v.into_boxed_slice())));
                }
                None => {
                    let v = mem.read(addr);
                    fx.mem_reads.push((addr, 4));
                    // Preserve integer-ness for address registers: data
                    // loads land in f32.
                    fx.reg_writes.push((dest, Value::F32(v)));
                }
            }
        }
        Opcode::Store => {
            let addr = resolve_addr(&ins.write_addrs[0], regs);
            let src = rd(0);
            match src {
                Value::Vec(v) => {
                    for (i, x) in v.iter().enumerate() {
                        fx.mem_writes.push((addr + 4 * i as u64, *x));
                    }
                    fx.mem_stores.push((addr, 4 * v.len() as u32));
                }
                s => {
                    fx.mem_writes.push((addr, s.as_f32()));
                    fx.mem_stores.push((addr, 4));
                }
            }
        }
        Opcode::Beqi | Opcode::Bnei => {
            let taken = match ins.op {
                Opcode::Beqi => rd(0).as_int() == rd(1).as_int(),
                _ => rd(0).as_int() != rd(1).as_int(),
            };
            if taken {
                fx.branch = Some((self_addr as i64 + ins.imms[0]) as u64);
            }
        }
        Opcode::Jumpi => {
            fx.branch = Some((self_addr as i64 + ins.imms[0]) as u64);
        }
        Opcode::VAdd | Opcode::VMul | Opcode::VMaxp => {
            fx.reg_writes
                .push((ins.writes[0], lanewise(ins.op, rd(0), rd(1))?));
        }
        Opcode::VRelu => {
            let v: Vec<f32> = rd(0).as_slice().iter().map(|x| x.max(0.0)).collect();
            fx.reg_writes
                .push((ins.writes[0], Value::Vec(v.into_boxed_slice())));
        }
        Opcode::Gemm => {
            // reads = 8 A rows ++ 8 B rows; writes = 8 C rows;
            // imms[0] = 1 enables ReLU (Listing 4).
            let t = GAMMA_TILE;
            if ins.reads.len() != 2 * t || ins.writes.len() != t {
                return Err(ExecError::Malformed(
                    ins.to_string(),
                    "16 source rows and 8 destination rows",
                ));
            }
            let relu = ins.imms.first().copied().unwrap_or(0) == 1;
            let row = |r: usize| -> &[f32] { regs[ins.reads[r].idx()].as_slice() };
            for i in 0..t {
                let mut out = vec![0.0f32; t];
                for (j, o) in out.iter_mut().enumerate() {
                    let mut acc = 0.0f32;
                    for k in 0..t {
                        let a = row(i).get(k).copied().unwrap_or(0.0);
                        let b = row(t + k).get(j).copied().unwrap_or(0.0);
                        acc += a * b;
                    }
                    *o = if relu { acc.max(0.0) } else { acc };
                }
                fx.reg_writes
                    .push((ins.writes[i], Value::Vec(out.into_boxed_slice())));
            }
        }
    }
    Ok(fx)
}

/// Apply computed effects to register state + memory.
pub fn apply(fx: &Effects, regs: &mut RegState, mem: &mut MemImage) {
    for (r, v) in &fx.reg_writes {
        regs[r.idx()] = v.clone();
    }
    for (a, v) in &fx.mem_writes {
        mem.write(*a, *v);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn regs(n: usize) -> RegState {
        vec![Value::Int(0); n]
    }

    #[test]
    fn scalar_alu() {
        let mut mem = MemImage::new();
        let mut rs = regs(4);
        rs[0] = Value::Int(5);
        rs[1] = Value::Int(3);
        let add = Instruction::new(Opcode::Add)
            .with_reads(vec![RegId(0), RegId(1)])
            .with_writes(vec![RegId(2)]);
        let fx = execute(&add, 0, &rs, &mut mem).unwrap();
        apply(&fx, &mut rs, &mut mem);
        assert_eq!(rs[2], Value::Int(8));

        let subi = Instruction::new(Opcode::Subi)
            .with_reads(vec![RegId(2)])
            .with_imms(vec![10])
            .with_writes(vec![RegId(3)]);
        let fx = execute(&subi, 0, &rs, &mut mem).unwrap();
        apply(&fx, &mut rs, &mut mem);
        assert_eq!(rs[3], Value::Int(-2));
    }

    #[test]
    fn mac_int_and_float() {
        let mut mem = MemImage::new();
        let mut rs = regs(4);
        rs[0] = Value::F32(2.0);
        rs[1] = Value::F32(3.0);
        rs[2] = Value::F32(10.0);
        let mac = Instruction::new(Opcode::Mac)
            .with_reads(vec![RegId(0), RegId(1), RegId(2)])
            .with_writes(vec![RegId(2)]);
        let fx = execute(&mac, 0, &rs, &mut mem).unwrap();
        apply(&fx, &mut rs, &mut mem);
        assert_eq!(rs[2], Value::F32(16.0));
    }

    #[test]
    fn load_store_scalar_roundtrip() {
        let mut mem = MemImage::new();
        let mut rs = regs(4);
        rs[1] = Value::F32(7.5);
        rs[3] = Value::Int(0x100);
        let st = Instruction::new(Opcode::Store)
            .with_reads(vec![RegId(1)])
            .with_write_addrs(vec![AddrRef::Indirect {
                base: RegId(3),
                offset: 8,
            }]);
        let fx = execute(&st, 0, &rs, &mut mem).unwrap();
        apply(&fx, &mut rs, &mut mem);
        assert_eq!(mem.peek(0x108), 7.5);
        assert_eq!(fx.mem_stores, vec![(0x108, 4)]);

        let ld = Instruction::new(Opcode::Load)
            .with_read_addrs(vec![AddrRef::Direct(0x108)])
            .with_writes(vec![RegId(0)]);
        let fx = execute(&ld, 0, &rs, &mut mem).unwrap();
        apply(&fx, &mut rs, &mut mem);
        assert_eq!(rs[0], Value::F32(7.5));
    }

    #[test]
    fn vector_load_uses_dest_lanes() {
        let mut mem = MemImage::new();
        mem.load_f32(0x200, &[1.0, 2.0, 3.0, 4.0]);
        let mut rs = regs(2);
        rs[0] = Value::zero_vec(4);
        let ld = Instruction::new(Opcode::Load)
            .with_read_addrs(vec![AddrRef::Direct(0x200)])
            .with_writes(vec![RegId(0)]);
        let fx = execute(&ld, 0, &rs, &mut mem).unwrap();
        assert_eq!(fx.mem_reads, vec![(0x200, 16)]);
        apply(&fx, &mut rs, &mut mem);
        assert_eq!(rs[0].as_slice(), &[1.0, 2.0, 3.0, 4.0]);
    }

    #[test]
    fn branches() {
        let mut mem = MemImage::new();
        let mut rs = regs(2);
        rs[0] = Value::Int(0);
        rs[1] = Value::Int(0);
        let beq = Instruction::new(Opcode::Beqi)
            .with_reads(vec![RegId(0), RegId(1)])
            .with_imms(vec![-28]);
        let fx = execute(&beq, 100, &rs, &mut mem).unwrap();
        assert_eq!(fx.branch, Some(72));
        rs[0] = Value::Int(1);
        let fx = execute(&beq, 100, &rs, &mut mem).unwrap();
        assert_eq!(fx.branch, None, "not taken");
        let j = Instruction::new(Opcode::Jumpi).with_imms(vec![8]);
        assert_eq!(execute(&j, 100, &rs, &mut mem).unwrap().branch, Some(108));
    }

    #[test]
    fn gemm_matches_naive() {
        let t = GAMMA_TILE;
        let mut mem = MemImage::new();
        let mut rs: RegState = (0..3 * t).map(|_| Value::zero_vec(t)).collect();
        // A = row-index matrix, B = identity → C = A.
        for i in 0..t {
            let a: Vec<f32> = (0..t).map(|k| (i * t + k) as f32).collect();
            rs[i] = Value::Vec(a.into_boxed_slice());
            let mut b = vec![0.0f32; t];
            b[i] = 1.0;
            rs[t + i] = Value::Vec(b.into_boxed_slice());
        }
        let g = Instruction::new(Opcode::Gemm)
            .with_reads((0..2 * t as u32).map(RegId).collect())
            .with_writes((2 * t as u32..3 * t as u32).map(RegId).collect())
            .with_imms(vec![0]);
        let fx = execute(&g, 0, &rs, &mut mem).unwrap();
        apply(&fx, &mut rs, &mut mem);
        for i in 0..t {
            let want: Vec<f32> = (0..t).map(|k| (i * t + k) as f32).collect();
            assert_eq!(rs[2 * t + i].as_slice(), &want[..]);
        }
    }

    #[test]
    fn gemm_relu_flag() {
        let t = GAMMA_TILE;
        let mut mem = MemImage::new();
        let mut rs: RegState = (0..3 * t).map(|_| Value::zero_vec(t)).collect();
        for i in 0..t {
            rs[i] = Value::Vec(vec![-1.0; t].into_boxed_slice());
            let mut b = vec![0.0f32; t];
            b[i] = 1.0;
            rs[t + i] = Value::Vec(b.into_boxed_slice());
        }
        let mut g = Instruction::new(Opcode::Gemm)
            .with_reads((0..2 * t as u32).map(RegId).collect())
            .with_writes((2 * t as u32..3 * t as u32).map(RegId).collect())
            .with_imms(vec![1]);
        let fx = execute(&g, 0, &rs, &mut mem).unwrap();
        assert!(fx
            .reg_writes
            .iter()
            .all(|(_, v)| v.as_slice().iter().all(|&x| x == 0.0)));
        g.imms = vec![0];
        let fx = execute(&g, 0, &rs, &mut mem).unwrap();
        assert!(fx
            .reg_writes
            .iter()
            .any(|(_, v)| v.as_slice().iter().any(|&x| x < 0.0)));
    }

    #[test]
    fn macfwd_forwards_operands() {
        let mut mem = MemImage::new();
        let mut rs = regs(6);
        rs[0] = Value::F32(2.0); // a
        rs[1] = Value::F32(4.0); // b
        rs[2] = Value::F32(1.0); // acc
        let m = Instruction::new(Opcode::MacFwd)
            .with_reads(vec![RegId(0), RegId(1), RegId(2)])
            .with_writes(vec![RegId(2), RegId(4), RegId(5)])
            .with_imms(vec![3]);
        let fx = execute(&m, 0, &rs, &mut mem).unwrap();
        apply(&fx, &mut rs, &mut mem);
        assert_eq!(rs[2], Value::F32(9.0));
        assert_eq!(rs[4], Value::F32(2.0), "a forwarded");
        assert_eq!(rs[5], Value::F32(4.0), "b forwarded");
    }
}
