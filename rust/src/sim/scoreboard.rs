//! The global last-user dependency map (§6): *"This is facilitated by a
//! global hash map which contains the last user for each register and
//! memory address and ensures correct simulation of data dependencies."*
//!
//! Dependencies are snapshotted in **program order at issue time** (fetch
//! order), producing for each dynamic instruction the set of earlier
//! instruction sequence numbers that must retire first:
//!
//! * **RAW** — readers depend on the last writer of each read register /
//!   address.
//! * **WAW** — writers depend on the last writer.
//! * **WAR** — writers additionally depend on every still-open reader
//!   since the last write (so a later writer cannot clobber a value an
//!   earlier, not-yet-dispatched reader still needs).
//!
//! Registers are tracked exactly (dense arrays over `RegId`).  Memory is
//! tracked per word for statically-known addresses; an instruction with a
//! register-indirect address falls back to a conservative whole-memory
//! ordering (sound for the OMA, whose single execute stage serializes
//! memory operations anyway; the parallel models — systolic, Γ̈ — emit
//! direct addresses from codegen).

use std::collections::HashMap;

use crate::isa::instruction::{AddrRef, Instruction};

/// Dynamic instruction sequence number (issue order).
pub type Seq = u64;

#[derive(Debug, Clone, Default)]
struct UserSet {
    last_writer: Option<Seq>,
    /// Readers issued since the last write.
    open_readers: Vec<Seq>,
}

impl UserSet {
    fn read_dep(&self, deps: &mut Vec<Seq>) {
        if let Some(w) = self.last_writer {
            deps.push(w);
        }
    }

    fn write_dep(&self, deps: &mut Vec<Seq>) {
        if let Some(w) = self.last_writer {
            deps.push(w);
        }
        deps.extend_from_slice(&self.open_readers);
    }

    fn note_read(&mut self, seq: Seq) {
        self.open_readers.push(seq);
    }

    fn note_write(&mut self, seq: Seq) {
        self.last_writer = Some(seq);
        self.open_readers.clear();
    }
}

/// The scoreboard: register and memory last-user state plus the retired
/// set. Registers use dense storage; memory addresses a hash map, exactly
/// as the paper describes.
#[derive(Debug, Clone)]
pub struct Scoreboard {
    regs: Vec<UserSet>,
    mem: HashMap<u64, UserSet>,
    /// Conservative whole-memory ordering for indirect addresses.
    mem_any: UserSet,
    /// retired[seq] — dense bitmap grown on issue.
    retired: Vec<bool>,
    next_seq: Seq,
}

impl Scoreboard {
    pub fn new(reg_count: usize) -> Self {
        Scoreboard {
            regs: vec![UserSet::default(); reg_count],
            mem: HashMap::new(),
            mem_any: UserSet::default(),
            retired: Vec::new(),
            next_seq: 0,
        }
    }

    /// Issue one instruction (program order!). Returns its sequence number
    /// and dependency list (seqs that must retire before it may start).
    pub fn issue(&mut self, ins: &Instruction) -> (Seq, Vec<Seq>) {
        let mut deps = Vec::new();
        let seq = self.issue_into(ins, &mut deps);
        (seq, deps)
    }

    /// [`Self::issue`] into a caller-owned dependency buffer (cleared
    /// first) — the kernel recycles these buffers across dynamic
    /// instructions so the issue path allocates nothing in steady state.
    pub fn issue_into(&mut self, ins: &Instruction, deps: &mut Vec<Seq>) -> Seq {
        deps.clear();
        let seq = self.next_seq;
        self.next_seq += 1;
        self.retired.push(false);
        debug_assert_eq!(self.retired.len() as Seq, self.next_seq);

        // Register RAW (includes address base registers).
        for r in ins.all_read_regs() {
            self.regs[r.idx()].read_dep(deps);
        }
        // Register WAW + WAR.
        for w in &ins.writes {
            self.regs[w.idx()].write_dep(deps);
        }

        // Memory dependencies.  Direct addresses are tracked per word
        // (codegen emits word-aligned per-element addresses; vector rows
        // are tracked by their base — sound because producers write whole
        // rows through the same base).  Indirect addresses use the
        // conservative `mem_any` ordering, and the two worlds cross-check
        // each other so a direct access never races an aliasing indirect
        // one.  Programs are typically all-direct (systolic, Γ̈) or
        // all-indirect (OMA), so the cross terms stay cheap.
        let word = |a: u64| a & !3;
        for a in &ins.read_addrs {
            match a {
                AddrRef::Direct(addr) => {
                    self.mem.entry(word(*addr)).or_default().read_dep(deps);
                    self.mem_any.read_dep(deps); // vs indirect writers
                }
                AddrRef::Indirect { .. } => {
                    self.mem_any.read_dep(deps);
                    for u in self.mem.values() {
                        u.read_dep(deps); // vs direct writers
                    }
                }
            }
        }
        for a in &ins.write_addrs {
            match a {
                AddrRef::Direct(addr) => {
                    self.mem.entry(word(*addr)).or_default().write_dep(deps);
                    self.mem_any.write_dep(deps);
                }
                AddrRef::Indirect { .. } => {
                    self.mem_any.write_dep(deps);
                    // May alias any tracked word.
                    for u in self.mem.values() {
                        u.write_dep(deps);
                    }
                }
            }
        }

        // Record this instruction as the new last user.
        for r in ins.all_read_regs() {
            self.regs[r.idx()].note_read(seq);
        }
        for w in &ins.writes {
            self.regs[w.idx()].note_write(seq);
        }
        for a in &ins.read_addrs {
            match a {
                AddrRef::Direct(addr) => self.mem.entry(word(*addr)).or_default().note_read(seq),
                AddrRef::Indirect { .. } => self.mem_any.note_read(seq),
            }
        }
        for a in &ins.write_addrs {
            match a {
                AddrRef::Direct(addr) => {
                    self.mem.entry(word(*addr)).or_default().note_write(seq)
                }
                AddrRef::Indirect { .. } => {
                    self.mem_any.note_write(seq);
                }
            }
        }

        deps.sort_unstable();
        deps.dedup();
        deps.retain(|&d| !self.retired[d as usize]);
        seq
    }

    /// Mark a dynamic instruction finished.
    #[inline]
    pub fn retire(&mut self, seq: Seq) {
        self.retired[seq as usize] = true;
    }

    #[inline]
    pub fn is_retired(&self, seq: Seq) -> bool {
        self.retired[seq as usize]
    }

    /// Are all of `deps` retired? Callers prune retired entries to keep
    /// this O(outstanding).
    #[inline]
    pub fn all_retired(&self, deps: &[Seq]) -> bool {
        deps.iter().all(|&d| self.retired[d as usize])
    }

    pub fn issued(&self) -> u64 {
        self.next_seq
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acadl_core::graph::RegId;
    use crate::isa::opcode::Opcode;

    fn w(op: Opcode, reads: Vec<u32>, writes: Vec<u32>) -> Instruction {
        Instruction::new(op)
            .with_reads(reads.into_iter().map(RegId).collect())
            .with_writes(writes.into_iter().map(RegId).collect())
    }

    #[test]
    fn raw_dependency() {
        let mut sb = Scoreboard::new(8);
        let (s0, d0) = sb.issue(&w(Opcode::Movi, vec![], vec![0]));
        assert!(d0.is_empty());
        let (_s1, d1) = sb.issue(&w(Opcode::Mov, vec![0], vec![1]));
        assert_eq!(d1, vec![s0]);
    }

    #[test]
    fn waw_and_war() {
        let mut sb = Scoreboard::new(8);
        let (s0, _) = sb.issue(&w(Opcode::Movi, vec![], vec![0])); // write r0
        let (s1, _) = sb.issue(&w(Opcode::Mov, vec![0], vec![1])); // read r0
        let (_, d2) = sb.issue(&w(Opcode::Movi, vec![], vec![0])); // write r0 again
        assert!(d2.contains(&s0), "WAW on r0");
        assert!(d2.contains(&s1), "WAR on r0 (open reader)");
    }

    #[test]
    fn retired_deps_are_pruned() {
        let mut sb = Scoreboard::new(8);
        let (s0, _) = sb.issue(&w(Opcode::Movi, vec![], vec![0]));
        sb.retire(s0);
        let (_, d1) = sb.issue(&w(Opcode::Mov, vec![0], vec![1]));
        assert!(d1.is_empty(), "already-retired writer is not a dependency");
    }

    #[test]
    fn independent_instructions_have_no_deps() {
        let mut sb = Scoreboard::new(8);
        sb.issue(&w(Opcode::Movi, vec![], vec![0]));
        let (_, d) = sb.issue(&w(Opcode::Movi, vec![], vec![1]));
        assert!(d.is_empty());
    }

    #[test]
    fn direct_memory_raw() {
        let mut sb = Scoreboard::new(8);
        let st = Instruction::new(Opcode::Store)
            .with_reads(vec![RegId(0)])
            .with_write_addrs(vec![AddrRef::Direct(0x100)]);
        let (s0, _) = sb.issue(&st);
        let ld = Instruction::new(Opcode::Load)
            .with_read_addrs(vec![AddrRef::Direct(0x100)])
            .with_writes(vec![RegId(1)]);
        let (_, d) = sb.issue(&ld);
        assert!(d.contains(&s0), "load sees earlier store to same word");
        // A load from a different word is independent.
        let ld2 = Instruction::new(Opcode::Load)
            .with_read_addrs(vec![AddrRef::Direct(0x200)])
            .with_writes(vec![RegId(2)]);
        let (_, d2) = sb.issue(&ld2);
        assert!(!d2.contains(&s0));
    }

    #[test]
    fn indirect_memory_is_conservative() {
        let mut sb = Scoreboard::new(8);
        let st = Instruction::new(Opcode::Store)
            .with_reads(vec![RegId(0)])
            .with_write_addrs(vec![AddrRef::Direct(0x100)]);
        let (s0, _) = sb.issue(&st);
        // Indirect store may alias 0x100: depends on s0.
        let st2 = Instruction::new(Opcode::Store)
            .with_reads(vec![RegId(1)])
            .with_write_addrs(vec![AddrRef::Indirect {
                base: RegId(2),
                offset: 0,
            }]);
        let (s1, d1) = sb.issue(&st2);
        assert!(d1.contains(&s0), "indirect store may alias direct word");
        // A later *direct* load must also see the indirect store.
        let ld_direct = Instruction::new(Opcode::Load)
            .with_read_addrs(vec![AddrRef::Direct(0x100)])
            .with_writes(vec![RegId(5)]);
        let (_, dd) = sb.issue(&ld_direct);
        assert!(dd.contains(&s1), "direct load sees indirect writer");
        // Indirect -> indirect ordering.
        let ld = Instruction::new(Opcode::Load)
            .with_read_addrs(vec![AddrRef::Indirect {
                base: RegId(3),
                offset: 0,
            }])
            .with_writes(vec![RegId(4)]);
        let (_, d2) = sb.issue(&ld);
        assert!(d2.contains(&s1));
    }
}
