//! Report tables: aligned-column / markdown output for the experiment
//! harness (every bench prints the paper-style rows through this).

use std::fmt::Write as _;

/// A simple column-aligned table with a title, printable as text or
/// markdown.
#[derive(Debug, Clone, Default)]
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(cells.len(), self.headers.len(), "row arity");
        self.rows.push(cells);
        self
    }

    fn widths(&self) -> Vec<usize> {
        let mut w: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                w[i] = w[i].max(c.len());
            }
        }
        w
    }

    /// Fixed-width text rendering.
    pub fn render(&self) -> String {
        let w = self.widths();
        let mut out = String::new();
        let _ = writeln!(out, "== {} ==", self.title);
        let line = |cells: &[String], w: &[usize], out: &mut String| {
            let mut parts = Vec::new();
            for (i, c) in cells.iter().enumerate() {
                parts.push(format!("{:<width$}", c, width = w[i]));
            }
            let _ = writeln!(out, "| {} |", parts.join(" | "));
        };
        line(&self.headers, &w, &mut out);
        let _ = writeln!(
            out,
            "|{}|",
            w.iter()
                .map(|x| "-".repeat(x + 2))
                .collect::<Vec<_>>()
                .join("|")
        );
        for r in &self.rows {
            line(r, &w, &mut out);
        }
        out
    }

    /// Markdown rendering (EXPERIMENTS.md snippets).
    pub fn markdown(&self) -> String {
        let mut out = String::new();
        let _ = writeln!(out, "### {}\n", self.title);
        let _ = writeln!(out, "| {} |", self.headers.join(" | "));
        let _ = writeln!(
            out,
            "|{}|",
            self.headers.iter().map(|_| "---").collect::<Vec<_>>().join("|")
        );
        for r in &self.rows {
            let _ = writeln!(out, "| {} |", r.join(" | "));
        }
        out
    }
}

/// Format helpers used across benches.
pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

pub fn pct(x: f64) -> String {
    format!("{:.1}%", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "cycles"]);
        t.row(vec!["oma".into(), "12345".into()]);
        t.row(vec!["systolic_16x16".into(), "99".into()]);
        let s = t.render();
        assert!(s.contains("== demo =="));
        assert!(s.contains("| systolic_16x16 | 99     |"), "{s}");
    }

    #[test]
    fn markdown_shape() {
        let mut t = Table::new("md", &["a", "b"]);
        t.row(vec!["1".into(), "2".into()]);
        let s = t.markdown();
        assert!(s.contains("| a | b |"));
        assert!(s.contains("|---|---|"));
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("x", &["a"]);
        t.row(vec!["1".into(), "2".into()]);
    }
}
