//! Lexer for the `.acadl` concrete syntax: a flat token stream with
//! line/column spans, so parser and elaborator diagnostics can point at
//! the offending source position.
//!
//! Tokens: identifiers (`arch`, `SRAM`, `lru` — keywords are contextual),
//! quoted names/strings (`"ex[0][1]"`, latency expressions), integers
//! (decimal or `0x` hex, optionally negative), floats (`1.5`, `-0.25`),
//! and the punctuation `{ } [ ] ( ) : , = . ->`.  Comments run from `//`
//! or `#` to end of line.

use std::fmt;

use crate::adl::{AdlError, Span};

/// One lexical token (payload only; the span lives in [`Lexed`]).
#[derive(Debug, Clone, PartialEq)]
pub enum Tok {
    Ident(String),
    /// Quoted string: object/register names, latency expressions.
    Str(String),
    Int(i64),
    Float(f64),
    LBrace,
    RBrace,
    LBracket,
    RBracket,
    LParen,
    RParen,
    Colon,
    Comma,
    Eq,
    Dot,
    Arrow,
    /// Synthetic end-of-input marker (simplifies the parser's lookahead).
    Eof,
}

impl fmt::Display for Tok {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Tok::Ident(s) => write!(f, "`{s}`"),
            Tok::Str(s) => write!(f, "\"{s}\""),
            Tok::Int(v) => write!(f, "`{v}`"),
            Tok::Float(v) => write!(f, "`{v}`"),
            Tok::LBrace => f.write_str("`{`"),
            Tok::RBrace => f.write_str("`}`"),
            Tok::LBracket => f.write_str("`[`"),
            Tok::RBracket => f.write_str("`]`"),
            Tok::LParen => f.write_str("`(`"),
            Tok::RParen => f.write_str("`)`"),
            Tok::Colon => f.write_str("`:`"),
            Tok::Comma => f.write_str("`,`"),
            Tok::Eq => f.write_str("`=`"),
            Tok::Dot => f.write_str("`.`"),
            Tok::Arrow => f.write_str("`->`"),
            Tok::Eof => f.write_str("end of input"),
        }
    }
}

/// A token plus the source position where it starts.
#[derive(Debug, Clone, PartialEq)]
pub struct Lexed {
    pub tok: Tok,
    pub span: Span,
}

struct Lexer<'a> {
    bytes: &'a [u8],
    pos: usize,
    line: u32,
    col: u32,
}

impl<'a> Lexer<'a> {
    fn span(&self) -> Span {
        Span {
            line: self.line,
            col: self.col,
        }
    }

    fn err(&self, msg: impl Into<String>) -> AdlError {
        AdlError::at(self.span(), msg)
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn peek2(&self) -> Option<u8> {
        self.bytes.get(self.pos + 1).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.bytes.get(self.pos).copied()?;
        self.pos += 1;
        if b == b'\n' {
            self.line += 1;
            self.col = 1;
        } else {
            self.col += 1;
        }
        Some(b)
    }

    fn skip_trivia(&mut self) {
        loop {
            match self.peek() {
                Some(b) if b.is_ascii_whitespace() => {
                    self.bump();
                }
                Some(b'#') => self.skip_line(),
                Some(b'/') if self.peek2() == Some(b'/') => self.skip_line(),
                _ => break,
            }
        }
    }

    fn skip_line(&mut self) {
        while let Some(b) = self.peek() {
            if b == b'\n' {
                break;
            }
            self.bump();
        }
    }

    fn ident(&mut self) -> String {
        let start = self.pos;
        while self
            .peek()
            .is_some_and(|b| b.is_ascii_alphanumeric() || b == b'_')
        {
            self.bump();
        }
        String::from_utf8_lossy(&self.bytes[start..self.pos]).into_owned()
    }

    fn string(&mut self) -> Result<String, AdlError> {
        // Opening quote already seen by the caller.
        self.bump();
        let mut out = String::new();
        loop {
            match self.bump() {
                None | Some(b'\n') => {
                    return Err(self.err("unterminated string (missing closing `\"`)"))
                }
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'n') => out.push('\n'),
                    Some(b't') => out.push('\t'),
                    other => {
                        return Err(self.err(format!(
                            "bad string escape `\\{}`",
                            other.map(|b| b as char).unwrap_or(' ')
                        )))
                    }
                },
                Some(b) if b < 0x80 => out.push(b as char),
                Some(b) => {
                    // Multi-byte UTF-8: copy the full sequence verbatim.
                    let start = self.pos - 1;
                    let len = match b {
                        0xF0..=0xF7 => 4,
                        0xE0..=0xEF => 3,
                        0xC0..=0xDF => 2,
                        _ => 1,
                    };
                    for _ in 1..len {
                        self.bump();
                    }
                    let end = self.pos.min(self.bytes.len());
                    match std::str::from_utf8(&self.bytes[start..end]) {
                        Ok(s) => out.push_str(s),
                        Err(_) => return Err(self.err("bad utf-8 in string")),
                    }
                }
            }
        }
    }

    fn number(&mut self, neg: bool) -> Result<Tok, AdlError> {
        if neg {
            self.bump(); // the `-`
        }
        let start = self.pos;
        if self.peek() == Some(b'0') && matches!(self.peek2(), Some(b'x') | Some(b'X')) {
            self.bump();
            self.bump();
            let hstart = self.pos;
            while self.peek().is_some_and(|b| b.is_ascii_hexdigit()) {
                self.bump();
            }
            let text = std::str::from_utf8(&self.bytes[hstart..self.pos]).unwrap_or("");
            let v = i64::from_str_radix(text, 16)
                .map_err(|_| self.err(format!("bad hex literal `0x{text}`")))?;
            return Ok(Tok::Int(if neg { -v } else { v }));
        }
        while self.peek().is_some_and(|b| b.is_ascii_digit()) {
            self.bump();
        }
        let is_float = self.peek() == Some(b'.')
            && self.peek2().is_some_and(|b| b.is_ascii_digit());
        if is_float {
            self.bump(); // `.`
            while self.peek().is_some_and(|b| b.is_ascii_digit()) {
                self.bump();
            }
            let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap_or("");
            let v: f64 = text
                .parse()
                .map_err(|_| self.err(format!("bad float literal `{text}`")))?;
            return Ok(Tok::Float(if neg { -v } else { v }));
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap_or("");
        let v: i64 = text
            .parse()
            .map_err(|_| self.err(format!("bad integer literal `{text}`")))?;
        Ok(Tok::Int(if neg { -v } else { v }))
    }
}

/// Lex `src` into a token stream ending with [`Tok::Eof`].
pub fn lex(src: &str) -> Result<Vec<Lexed>, AdlError> {
    let mut lx = Lexer {
        bytes: src.as_bytes(),
        pos: 0,
        line: 1,
        col: 1,
    };
    let mut out = Vec::new();
    loop {
        lx.skip_trivia();
        let span = lx.span();
        let Some(b) = lx.peek() else {
            out.push(Lexed {
                tok: Tok::Eof,
                span,
            });
            return Ok(out);
        };
        let tok = match b {
            b'{' => {
                lx.bump();
                Tok::LBrace
            }
            b'}' => {
                lx.bump();
                Tok::RBrace
            }
            b'[' => {
                lx.bump();
                Tok::LBracket
            }
            b']' => {
                lx.bump();
                Tok::RBracket
            }
            b'(' => {
                lx.bump();
                Tok::LParen
            }
            b')' => {
                lx.bump();
                Tok::RParen
            }
            b':' => {
                lx.bump();
                Tok::Colon
            }
            b',' => {
                lx.bump();
                Tok::Comma
            }
            b'=' => {
                lx.bump();
                Tok::Eq
            }
            b'.' => {
                lx.bump();
                Tok::Dot
            }
            b'-' => {
                if lx.peek2() == Some(b'>') {
                    lx.bump();
                    lx.bump();
                    Tok::Arrow
                } else if lx.peek2().is_some_and(|c| c.is_ascii_digit()) {
                    lx.number(true)?
                } else {
                    return Err(lx.err("stray `-` (expected `->` or a negative number)"));
                }
            }
            b'"' => Tok::Str(lx.string()?),
            c if c.is_ascii_digit() => lx.number(false)?,
            c if c.is_ascii_alphabetic() || c == b'_' => Tok::Ident(lx.ident()),
            c => return Err(lx.err(format!("unexpected character `{}`", c as char))),
        };
        out.push(Lexed { tok, span });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(src: &str) -> Vec<Tok> {
        lex(src).unwrap().into_iter().map(|l| l.tok).collect()
    }

    #[test]
    fn punctuation_and_idents() {
        assert_eq!(
            toks("arch \"x\" { a = 1 }"),
            vec![
                Tok::Ident("arch".into()),
                Tok::Str("x".into()),
                Tok::LBrace,
                Tok::Ident("a".into()),
                Tok::Eq,
                Tok::Int(1),
                Tok::RBrace,
                Tok::Eof,
            ]
        );
    }

    #[test]
    fn numbers() {
        assert_eq!(toks("0x10 -4 1.5 -0.25 0"), vec![
            Tok::Int(16),
            Tok::Int(-4),
            Tok::Float(1.5),
            Tok::Float(-0.25),
            Tok::Int(0),
            Tok::Eof,
        ]);
    }

    #[test]
    fn arrow_vs_minus() {
        assert_eq!(
            toks("\"a\" -> \"b\""),
            vec![
                Tok::Str("a".into()),
                Tok::Arrow,
                Tok::Str("b".into()),
                Tok::Eof
            ]
        );
        assert!(lex("a - b").is_err());
    }

    #[test]
    fn comments_skipped_and_spans_tracked() {
        let l = lex("// c1\n# c2\n  arch").unwrap();
        assert_eq!(l[0].tok, Tok::Ident("arch".into()));
        assert_eq!(l[0].span.line, 3);
        assert_eq!(l[0].span.col, 3);
    }

    #[test]
    fn strings_with_escapes_and_brackets() {
        assert_eq!(
            toks(r#""ex[0][1]" "v[0].3" "a\"b" "1 + is_mac * 3""#),
            vec![
                Tok::Str("ex[0][1]".into()),
                Tok::Str("v[0].3".into()),
                Tok::Str("a\"b".into()),
                Tok::Str("1 + is_mac * 3".into()),
                Tok::Eof,
            ]
        );
    }

    #[test]
    fn errors_carry_positions() {
        let e = lex("a\n  \"oops").unwrap_err();
        assert_eq!(e.span.unwrap().line, 2);
        let e = lex("$").unwrap_err();
        assert!(e.to_string().contains("unexpected character"));
    }
}
