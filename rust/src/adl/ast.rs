//! The spanned abstract syntax tree of an `.acadl` file.
//!
//! Every declaration carries the [`Span`] of its defining token so the
//! elaborator can report semantic errors ("unknown object", "invalid
//! edge") at the source position that caused them.  The AST is purely
//! syntactic: names are strings, classes and edge kinds are uninterpreted
//! identifiers — binding and validation happen in [`crate::adl::elab`].

use crate::adl::Span;

/// A whole `.acadl` file: one architecture description.
#[derive(Debug, Clone, PartialEq)]
pub struct Arch {
    pub name: String,
    pub name_span: Span,
    /// Optional mapping-family binding (`targets oma { cache = true }`).
    pub target: Option<TargetDecl>,
    /// Optional multi-chip platform wrapper (`platform { chips = 4 … }`).
    pub platform: Option<PlatformDecl>,
    pub items: Vec<Item>,
}

/// The `targets <family> { key = value … }` binding: which code-generator
/// family this description instantiates, with its serializable knobs.
#[derive(Debug, Clone, PartialEq)]
pub struct TargetDecl {
    pub family: String,
    pub span: Span,
    pub attrs: Vec<Attr>,
}

/// The `platform { chips = 4 hop_latency = 4 … }` block: replicate the
/// described chip behind a shared fabric + DRAM (see
/// [`crate::arch::platform::PlatformDesc`]).  Purely additive — a file
/// without the block describes a single chip, exactly as before.
#[derive(Debug, Clone, PartialEq)]
pub struct PlatformDecl {
    pub span: Span,
    pub attrs: Vec<Attr>,
}

/// One top-level declaration, in file order.
#[derive(Debug, Clone, PartialEq)]
pub enum Item {
    Object(ObjectDecl),
    Connect(ConnectDecl),
    Param(ParamDecl),
    Template(TemplateDecl),
    Instance(InstanceDecl),
    Join(JoinDecl),
    Attach(AttachDecl),
}

/// `object "name" : Class { attrs… [regs { … }] }`
#[derive(Debug, Clone, PartialEq)]
pub struct ObjectDecl {
    pub name: String,
    pub span: Span,
    pub class: String,
    pub class_span: Span,
    pub attrs: Vec<Attr>,
    /// RegisterFile contents (empty for every other class).
    pub regs: Vec<RegDecl>,
}

/// `key = value`
#[derive(Debug, Clone, PartialEq)]
pub struct Attr {
    pub key: String,
    pub span: Span,
    pub value: ValueExpr,
}

/// An attribute or parameter value.
#[derive(Debug, Clone, PartialEq)]
pub enum ValueExpr {
    Int(i64),
    Float(f64),
    Bool(bool),
    Str(String),
    /// A bare identifier (cache policies, loop orders, mnemonics).
    Ident(String),
    List(Vec<ValueExpr>),
}

impl ValueExpr {
    /// Human description of the value's shape, for diagnostics.
    pub fn kind(&self) -> &'static str {
        match self {
            ValueExpr::Int(_) => "integer",
            ValueExpr::Float(_) => "float",
            ValueExpr::Bool(_) => "bool",
            ValueExpr::Str(_) => "string",
            ValueExpr::Ident(_) => "identifier",
            ValueExpr::List(_) => "list",
        }
    }
}

/// One register of a RegisterFile: `"name" : i32 = 0`, `"a" : f32 = 0`,
/// `"v" : vec(128, 8)`.
#[derive(Debug, Clone, PartialEq)]
pub struct RegDecl {
    pub name: String,
    pub span: Span,
    pub ty: RegType,
}

#[derive(Debug, Clone, PartialEq)]
pub enum RegType {
    Int { width: u32, init: i64 },
    F32 { init: f32 },
    Vec { size: u32, lanes: usize },
}

/// `connect "src" -> "dst" : EDGE_KIND`
#[derive(Debug, Clone, PartialEq)]
pub struct ConnectDecl {
    pub src: String,
    pub dst: String,
    pub kind: String,
    pub span: Span,
}

/// `param key in [v1, v2, …]` — one DSE sweep axis over a target knob.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamDecl {
    pub key: String,
    pub span: Span,
    pub values: Vec<ValueExpr>,
}

/// `template Name { objects… connects… danglings… }` — a reusable block
/// instantiated with a name prefix (the paper's §4.2 templates).
#[derive(Debug, Clone, PartialEq)]
pub struct TemplateDecl {
    pub name: String,
    pub span: Span,
    pub objects: Vec<ObjectDecl>,
    pub connects: Vec<ConnectDecl>,
    pub danglings: Vec<DanglingDecl>,
}

/// `dangling "port" : EDGE_KIND from "obj"` (source half-edge) or
/// `… to "obj"` (target half-edge) — the template's exported interface.
#[derive(Debug, Clone, PartialEq)]
pub struct DanglingDecl {
    pub name: String,
    pub kind: String,
    pub dir: DangleDir,
    pub obj: String,
    pub span: Span,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DangleDir {
    /// The half-edge knows its source; the target is supplied later.
    From,
    /// The half-edge knows its target; the source is supplied later.
    To,
}

/// `instance "prefix" : TemplateName` — instantiate a template; its
/// objects (and registers) are named `prefix.local`.
#[derive(Debug, Clone, PartialEq)]
pub struct InstanceDecl {
    pub prefix: String,
    pub template: String,
    pub span: Span,
}

/// `join "a".port -> "b".port` — connect two dangling half-edges
/// (`acadl_core::template::connect_dangling`).
#[derive(Debug, Clone, PartialEq)]
pub struct JoinDecl {
    pub a: PortRef,
    pub b: PortRef,
    pub span: Span,
}

/// `attach "a".port -> "obj"` — connect a dangling half-edge straight to
/// an object (`acadl_core::template::connect_dangling_to`).
#[derive(Debug, Clone, PartialEq)]
pub struct AttachDecl {
    pub port: PortRef,
    pub obj: String,
    pub span: Span,
}

/// `"instance".port`
#[derive(Debug, Clone, PartialEq)]
pub struct PortRef {
    pub instance: String,
    pub port: String,
}
