//! The elaborator: lowers a parsed [`ast::Arch`] into a validated
//! [`Ag`] architecture graph, resolving classes, attributes, edges,
//! templates, and the optional `targets` binding, with `line:col`
//! diagnostics for every semantic error.
//!
//! Every edge — whether written as a `connect` statement or formed by
//! `join`/`attach` between template ports — is materialized through the
//! existing [`crate::acadl_core::template`] half-edge machinery, so the
//! class-diagram validity check of Fig. 1 runs on exactly the same path
//! as the Rust builders.  An exported dangling edge that is never joined
//! simply does not materialize (the paper's §4.2 semantics).

use std::collections::{BTreeMap, BTreeSet, HashMap};

use crate::acadl_core::data::Data;
use crate::acadl_core::edge::EdgeKind;
use crate::acadl_core::graph::Ag;
use crate::acadl_core::latency::Latency;
use crate::acadl_core::object::{
    DataStorageParams, Dram, ExecuteStage, FunctionalUnit, InstructionFetchStage,
    InstructionMemoryAccessUnit, MemoryAccessUnit, Object, ObjectKind, PipelineStage,
    RegisterFile, SetAssociativeCache, Sram,
};
use crate::acadl_core::template::{connect_dangling, connect_dangling_to, DanglingEdge};
use crate::adl::ast::{self, DangleDir, RegType, ValueExpr};
use crate::adl::{printer, AdlError, Span};
use crate::arch::platform::PlatformDesc;
use crate::coordinator::job::TargetSpec;
use crate::mapping::gemm::LoopOrder;
use crate::mem::cache::ReplacementPolicy;

/// One DSE sweep axis from a `param` declaration.
#[derive(Debug, Clone, PartialEq)]
pub struct ParamAxis {
    pub key: String,
    pub values: Vec<ParamValue>,
}

/// A single swept value.
#[derive(Debug, Clone, PartialEq)]
pub enum ParamValue {
    Int(i64),
    Bool(bool),
    Name(String),
}

/// The elaborated form of one `.acadl` description.
#[derive(Debug, Clone)]
pub struct ElabArch {
    pub name: String,
    /// The validated architecture graph described by the file body.
    pub ag: Ag,
    /// The mapping-family binding, when the file declares one.
    pub target: Option<TargetSpec>,
    /// The multi-chip `platform { … }` block, when the file declares one.
    pub platform: Option<PlatformDesc>,
    /// The `param` sweep axes, in file order.
    pub params: Vec<ParamAxis>,
}

/// One point of a file-defined design space: the base target with a set
/// of `param` values applied, plus the workload knobs (`tile`, `order`)
/// the OMA generator reads.
#[derive(Debug, Clone)]
pub struct Candidate {
    pub target: TargetSpec,
    pub tile: Option<usize>,
    pub order: Option<LoopOrder>,
}

impl ElabArch {
    /// The base candidate: the `targets` binding with no params applied.
    pub fn base_candidate(&self) -> Option<Candidate> {
        self.target.as_ref().map(|t| Candidate {
            target: t.clone(),
            tile: None,
            order: None,
        })
    }

    /// Incremental elaboration: stamp out **one** point of the `param`
    /// cross-product from this already-elaborated description.  The file
    /// is parsed and elaborated once; each candidate is then the base
    /// `targets` binding with `indices[i]`-th value of axis `i` applied —
    /// `O(axes)` per candidate, no re-parse, no re-validation of the
    /// architecture graph.  Because candidates differ only in their
    /// [`TargetSpec`] fields, the coordinator's config-hash machine cache
    /// keys them exactly as it keys built-in sweeps.
    ///
    /// `indices` is interpreted positionally against [`Self::params`]
    /// (missing trailing indices keep the base value).
    pub fn stamp(&self, indices: &[usize]) -> Result<Candidate, String> {
        let mut c = self.base_candidate().ok_or_else(|| {
            format!(
                "architecture `{}` has no `targets` binding — nothing to stamp",
                self.name
            )
        })?;
        for (axis, &ix) in self.params.iter().zip(indices) {
            let v = axis.values.get(ix).ok_or_else(|| {
                format!(
                    "param `{}`: value index {ix} out of range ({} values)",
                    axis.key,
                    axis.values.len()
                )
            })?;
            apply_param(&mut c, &axis.key, v).map_err(|e| format!("param `{}`: {e}", axis.key))?;
        }
        Ok(c)
    }
}

/// Apply one swept `param` value onto a candidate.  Key/family validity
/// was already checked during elaboration; this re-checks defensively so
/// the DSE layer can call it on hand-built axes too.
pub fn apply_param(c: &mut Candidate, key: &str, v: &ParamValue) -> Result<(), String> {
    match (key, v) {
        ("cache", ParamValue::Bool(b)) => match &mut c.target {
            TargetSpec::Oma { cache, .. } => *cache = *b,
            other => return Err(format!("param `cache` does not apply to {other:?}")),
        },
        ("mac_latency", ParamValue::Int(n)) if *n > 0 => match &mut c.target {
            TargetSpec::Oma { mac_latency, .. } => *mac_latency = Some(*n as u64),
            other => return Err(format!("param `mac_latency` does not apply to {other:?}")),
        },
        ("rows", ParamValue::Int(n)) if *n > 0 => match &mut c.target {
            TargetSpec::Systolic { rows, .. } => *rows = *n as usize,
            other => return Err(format!("param `rows` does not apply to {other:?}")),
        },
        ("cols", ParamValue::Int(n)) if *n > 0 => match &mut c.target {
            TargetSpec::Systolic { cols, .. } => *cols = *n as usize,
            other => return Err(format!("param `cols` does not apply to {other:?}")),
        },
        ("units", ParamValue::Int(n)) if *n > 0 => match &mut c.target {
            TargetSpec::Gamma { units } => *units = *n as usize,
            other => return Err(format!("param `units` does not apply to {other:?}")),
        },
        ("tile", ParamValue::Int(n)) if *n > 0 => c.tile = Some(*n as usize),
        ("order", ParamValue::Name(name)) => {
            c.order = Some(
                LoopOrder::ALL
                    .into_iter()
                    .find(|o| o.name() == name)
                    .ok_or_else(|| format!("unknown loop order `{name}`"))?,
            );
        }
        (key, v) => return Err(format!("invalid param `{key}` value {v:?}")),
    }
    Ok(())
}

// --------------------------------------------------------------- attrs

/// Attribute extraction with duplicate/unknown detection.
struct AttrSet<'a> {
    span: Span,
    attrs: &'a [ast::Attr],
    used: Vec<bool>,
}

impl<'a> AttrSet<'a> {
    fn new(span: Span, attrs: &'a [ast::Attr]) -> Self {
        AttrSet {
            span,
            attrs,
            used: vec![false; attrs.len()],
        }
    }

    fn take(&mut self, key: &str) -> Result<Option<&'a ast::Attr>, AdlError> {
        let mut found: Option<usize> = None;
        for (i, a) in self.attrs.iter().enumerate() {
            if a.key == key {
                if found.is_some() {
                    return Err(AdlError::at(a.span, format!("duplicate attribute `{key}`")));
                }
                found = Some(i);
            }
        }
        match found {
            Some(i) => {
                self.used[i] = true;
                let attrs: &'a [ast::Attr] = self.attrs;
                Ok(Some(&attrs[i]))
            }
            None => Ok(None),
        }
    }

    fn int(&mut self, key: &str, default: i64) -> Result<i64, AdlError> {
        match self.take(key)? {
            None => Ok(default),
            Some(a) => match &a.value {
                ValueExpr::Int(v) => Ok(*v),
                other => Err(AdlError::at(
                    a.span,
                    format!("attribute `{key}` must be an integer, found {}", other.kind()),
                )),
            },
        }
    }

    fn req_int(&mut self, key: &str) -> Result<i64, AdlError> {
        match self.take(key)? {
            None => Err(AdlError::at(
                self.span,
                format!("missing required attribute `{key}`"),
            )),
            Some(a) => match &a.value {
                ValueExpr::Int(v) => Ok(*v),
                other => Err(AdlError::at(
                    a.span,
                    format!("attribute `{key}` must be an integer, found {}", other.kind()),
                )),
            },
        }
    }

    fn unsigned(&mut self, key: &str, default: u64) -> Result<u64, AdlError> {
        let v = self.int(key, default as i64)?;
        u64::try_from(v).map_err(|_| {
            AdlError::at(self.span, format!("attribute `{key}` must be non-negative"))
        })
    }

    fn req_unsigned(&mut self, key: &str) -> Result<u64, AdlError> {
        let v = self.req_int(key)?;
        u64::try_from(v).map_err(|_| {
            AdlError::at(self.span, format!("attribute `{key}` must be non-negative"))
        })
    }

    /// A u32-ranged attribute (bit widths): rejects out-of-range values
    /// instead of silently truncating them.
    fn unsigned_u32(&mut self, key: &str, default: u32) -> Result<u32, AdlError> {
        let v = self.unsigned(key, default as u64)?;
        u32::try_from(v).map_err(|_| {
            AdlError::at(
                self.span,
                format!("attribute `{key}` out of range (max {})", u32::MAX),
            )
        })
    }

    fn boolean(&mut self, key: &str, default: bool) -> Result<bool, AdlError> {
        match self.take(key)? {
            None => Ok(default),
            Some(a) => match &a.value {
                ValueExpr::Bool(v) => Ok(*v),
                other => Err(AdlError::at(
                    a.span,
                    format!(
                        "attribute `{key}` must be true or false, found {}",
                        other.kind()
                    ),
                )),
            },
        }
    }

    /// A latency: integer (constant cycles) or string (expression).
    fn latency(&mut self, key: &str, default: u64) -> Result<Latency, AdlError> {
        match self.take(key)? {
            None => Ok(Latency::Const(default)),
            Some(a) => match &a.value {
                ValueExpr::Int(v) if *v >= 0 => Ok(Latency::Const(*v as u64)),
                ValueExpr::Str(s) => Latency::parse(s)
                    .map_err(|e| AdlError::at(a.span, format!("bad latency expression: {e}"))),
                other => Err(AdlError::at(
                    a.span,
                    format!(
                        "attribute `{key}` must be a non-negative integer or a quoted expression, found {}",
                        other.kind()
                    ),
                )),
            },
        }
    }

    /// Mnemonic list: `ops = [load, store]`.
    fn ops(&mut self) -> Result<BTreeSet<String>, AdlError> {
        match self.take("ops")? {
            None => Ok(BTreeSet::new()),
            Some(a) => match &a.value {
                ValueExpr::List(items) => {
                    let mut out = BTreeSet::new();
                    for it in items {
                        match it {
                            ValueExpr::Ident(s) | ValueExpr::Str(s) => {
                                out.insert(s.clone());
                            }
                            other => {
                                return Err(AdlError::at(
                                    a.span,
                                    format!("ops entries must be mnemonics, found {}", other.kind()),
                                ))
                            }
                        }
                    }
                    Ok(out)
                }
                other => Err(AdlError::at(
                    a.span,
                    format!("attribute `ops` must be a list, found {}", other.kind()),
                )),
            },
        }
    }

    fn policy(&mut self) -> Result<ReplacementPolicy, AdlError> {
        match self.take("policy")? {
            None => Ok(ReplacementPolicy::Lru),
            Some(a) => match &a.value {
                ValueExpr::Ident(s) => match s.as_str() {
                    "lru" => Ok(ReplacementPolicy::Lru),
                    "fifo" => Ok(ReplacementPolicy::Fifo),
                    "plru" => Ok(ReplacementPolicy::Plru),
                    "random" => Ok(ReplacementPolicy::Random),
                    other => Err(AdlError::at(
                        a.span,
                        format!("unknown replacement policy `{other}` (lru|fifo|plru|random)"),
                    )),
                },
                other => Err(AdlError::at(
                    a.span,
                    format!("attribute `policy` must be an identifier, found {}", other.kind()),
                )),
            },
        }
    }

    /// Error on the first attribute no extractor consumed.
    fn finish(self, class: &str) -> Result<(), AdlError> {
        for (i, a) in self.attrs.iter().enumerate() {
            if !self.used[i] {
                return Err(AdlError::at(
                    a.span,
                    format!("unknown attribute `{}` for class {class}", a.key),
                ));
            }
        }
        Ok(())
    }
}

// ------------------------------------------------------------- objects

fn storage_params(attrs: &mut AttrSet<'_>) -> Result<DataStorageParams, AdlError> {
    let d = DataStorageParams::default();
    Ok(DataStorageParams {
        data_width: attrs.unsigned_u32("width", d.data_width)?,
        max_concurrent_requests: attrs.unsigned("requests", d.max_concurrent_requests as u64)?
            as usize,
        read_write_ports: attrs.unsigned("ports", d.read_write_ports as u64)? as usize,
        port_width: attrs.unsigned("port_width", d.port_width as u64)? as usize,
    })
}

fn registers(decl: &ast::ObjectDecl, reg_prefix: &str) -> Vec<(String, Data)> {
    decl.regs
        .iter()
        .map(|r| {
            let name = format!("{reg_prefix}{}", r.name);
            let data = match r.ty {
                RegType::Int { width, init } => Data::int(width, init),
                RegType::F32 { init } => Data::f32(init),
                RegType::Vec { size, lanes } => Data::vec(size, lanes),
            };
            (name, data)
        })
        .collect()
}

/// Build one [`Object`] from its declaration.  `name` is the final
/// (possibly instance-prefixed) object name; `reg_prefix` prefixes
/// register names the same way.
fn object_from_decl(
    name: String,
    decl: &ast::ObjectDecl,
    reg_prefix: &str,
) -> Result<Object, AdlError> {
    if !decl.regs.is_empty() && decl.class != "RegisterFile" {
        return Err(AdlError::at(
            decl.class_span,
            format!("`regs` block is only valid for RegisterFile, not {}", decl.class),
        ));
    }
    let mut attrs = AttrSet::new(decl.span, &decl.attrs);
    let kind = match decl.class.as_str() {
        "PipelineStage" => ObjectKind::PipelineStage(PipelineStage {
            latency: attrs.latency("latency", 1)?,
        }),
        "ExecuteStage" => ObjectKind::ExecuteStage(ExecuteStage {
            latency: attrs.latency("latency", 1)?,
        }),
        "InstructionFetchStage" => ObjectKind::InstructionFetchStage(InstructionFetchStage {
            latency: attrs.latency("latency", 1)?,
            issue_buffer_size: attrs.unsigned("issue_buffer", 4)? as usize,
        }),
        "FunctionalUnit" => ObjectKind::FunctionalUnit(FunctionalUnit {
            to_process: attrs.ops()?,
            latency: attrs.latency("latency", 1)?,
        }),
        "MemoryAccessUnit" => ObjectKind::MemoryAccessUnit(MemoryAccessUnit {
            to_process: attrs.ops()?,
            latency: attrs.latency("latency", 1)?,
        }),
        "InstructionMemoryAccessUnit" => {
            ObjectKind::InstructionMemoryAccessUnit(InstructionMemoryAccessUnit {
                latency: attrs.latency("latency", 1)?,
            })
        }
        "RegisterFile" => ObjectKind::RegisterFile(RegisterFile {
            data_width: attrs.unsigned_u32("width", 32)?,
            registers: registers(decl, reg_prefix),
        }),
        "SRAM" => ObjectKind::Sram(Sram {
            address_range: (attrs.req_unsigned("base")?, attrs.req_unsigned("end")?),
            read_latency: attrs.latency("read_latency", 1)?,
            write_latency: attrs.latency("write_latency", 1)?,
            ds: storage_params(&mut attrs)?,
        }),
        "DRAM" => ObjectKind::Dram(Dram {
            address_range: (attrs.req_unsigned("base")?, attrs.req_unsigned("end")?),
            banks: attrs.unsigned("banks", 8)? as usize,
            row_bytes: attrs.unsigned("row_bytes", 1024)?,
            t_rcd: attrs.unsigned("t_rcd", 14)?,
            t_rp: attrs.unsigned("t_rp", 14)?,
            t_ras: attrs.unsigned("t_ras", 33)?,
            t_cas: attrs.unsigned("t_cas", 10)?,
            ds: storage_params(&mut attrs)?,
        }),
        "SetAssociativeCache" => ObjectKind::Cache(SetAssociativeCache {
            sets: attrs.unsigned("sets", 64)? as usize,
            ways: attrs.unsigned("ways", 4)? as usize,
            cache_line_size: attrs.unsigned("line", 64)?,
            replacement_policy: attrs.policy()?,
            hit_latency: attrs.latency("hit_latency", 1)?,
            miss_latency: attrs.latency("miss_latency", 8)?,
            write_allocate: attrs.boolean("write_allocate", true)?,
            write_back: attrs.boolean("write_back", true)?,
            ds: storage_params(&mut attrs)?,
        }),
        other => {
            return Err(AdlError::at(
                decl.class_span,
                format!(
                    "unknown ACADL class `{other}` (expected PipelineStage, ExecuteStage, \
                     InstructionFetchStage, FunctionalUnit, MemoryAccessUnit, \
                     InstructionMemoryAccessUnit, RegisterFile, SRAM, DRAM, or \
                     SetAssociativeCache)"
                ),
            ))
        }
    };
    attrs.finish(&decl.class)?;
    Ok(Object::new(name, kind))
}

fn edge_kind(kind: &str, span: Span) -> Result<EdgeKind, AdlError> {
    match kind {
        "FORWARD" => Ok(EdgeKind::Forward),
        "CONTAINS" => Ok(EdgeKind::Contains),
        "READ_DATA" => Ok(EdgeKind::ReadData),
        "WRITE_DATA" => Ok(EdgeKind::WriteData),
        other => Err(AdlError::at(
            span,
            format!("unknown edge kind `{other}` (FORWARD|CONTAINS|READ_DATA|WRITE_DATA)"),
        )),
    }
}

// -------------------------------------------------------------- target

fn target_spec(decl: &ast::TargetDecl) -> Result<TargetSpec, AdlError> {
    let mut attrs = AttrSet::new(decl.span, &decl.attrs);
    let spec = match decl.family.as_str() {
        "oma" => TargetSpec::Oma {
            cache: attrs.boolean("cache", true)?,
            mac_latency: match attrs.take("mac_latency")? {
                None => None,
                Some(a) => match &a.value {
                    ValueExpr::Int(v) if *v > 0 => Some(*v as u64),
                    _ => {
                        return Err(AdlError::at(
                            a.span,
                            "mac_latency must be a positive integer",
                        ))
                    }
                },
            },
        },
        "systolic" => TargetSpec::Systolic {
            rows: pos_usize(&mut attrs, "rows")?,
            cols: pos_usize(&mut attrs, "cols")?,
        },
        "gamma" => TargetSpec::Gamma {
            units: pos_usize(&mut attrs, "units")?,
        },
        other => {
            return Err(AdlError::at(
                decl.span,
                format!("unknown target family `{other}` (oma|systolic|gamma)"),
            ))
        }
    };
    attrs.finish(&format!("target family {}", decl.family))?;
    Ok(spec)
}

/// Elaborate the `platform { … }` block: `chips` is required; fabric,
/// DRAM, and microbatch knobs default from [`PlatformDesc::default`].
fn platform_desc(decl: &ast::PlatformDecl) -> Result<PlatformDesc, AdlError> {
    let mut attrs = AttrSet::new(decl.span, &decl.attrs);
    let mut d = PlatformDesc::default();
    let chips = attrs.req_int("chips")?;
    if chips < 1 {
        return Err(AdlError::at(decl.span, "attribute `chips` must be >= 1"));
    }
    d.chips = chips as usize;
    d.fabric.hop_latency = attrs.unsigned("hop_latency", d.fabric.hop_latency)?;
    d.fabric.link_words_per_cycle =
        attrs.unsigned("link_words_per_cycle", d.fabric.link_words_per_cycle)?;
    d.dram.base_latency = attrs.unsigned("dram_latency", d.dram.base_latency)?;
    d.dram.words_per_cycle = attrs.unsigned("dram_words_per_cycle", d.dram.words_per_cycle)?;
    let m = attrs.unsigned("microbatches", d.microbatches as u64)?;
    if m < 1 {
        return Err(AdlError::at(
            decl.span,
            "attribute `microbatches` must be >= 1",
        ));
    }
    d.microbatches = m as usize;
    attrs.finish("platform")?;
    Ok(d)
}

fn pos_usize(attrs: &mut AttrSet<'_>, key: &str) -> Result<usize, AdlError> {
    let v = attrs.req_int(key)?;
    if v < 1 {
        return Err(AdlError::at(
            attrs.span,
            format!("attribute `{key}` must be >= 1"),
        ));
    }
    Ok(v as usize)
}

// -------------------------------------------------------------- params

/// Sweepable keys per target family (tile/order are OMA workload knobs —
/// the other generators ignore them, so sweeping them there would only
/// create memo aliases).
fn param_allowed(family: &TargetSpec, key: &str) -> bool {
    match family {
        TargetSpec::Oma { .. } => {
            matches!(key, "cache" | "mac_latency" | "tile" | "order")
        }
        TargetSpec::Systolic { .. } => matches!(key, "rows" | "cols"),
        TargetSpec::Gamma { .. } => matches!(key, "units"),
    }
}

fn param_axis(
    target: &Option<TargetSpec>,
    decl: &ast::ParamDecl,
) -> Result<ParamAxis, AdlError> {
    let Some(t) = target else {
        return Err(AdlError::at(
            decl.span,
            "param declarations require a `targets` binding",
        ));
    };
    if !param_allowed(t, &decl.key) {
        return Err(AdlError::at(
            decl.span,
            format!(
                "param `{}` does not apply to this target family",
                decl.key
            ),
        ));
    }
    if decl.values.is_empty() {
        return Err(AdlError::at(decl.span, "param value list is empty"));
    }
    let mut values = Vec::with_capacity(decl.values.len());
    for v in &decl.values {
        let pv = match v {
            ValueExpr::Int(i) => ParamValue::Int(*i),
            ValueExpr::Bool(b) => ParamValue::Bool(*b),
            ValueExpr::Ident(s) => ParamValue::Name(s.clone()),
            other => {
                return Err(AdlError::at(
                    decl.span,
                    format!("unsupported param value ({})", other.kind()),
                ))
            }
        };
        // Validate each value by applying it to a scratch candidate.
        let mut probe = Candidate {
            target: t.clone(),
            tile: None,
            order: None,
        };
        apply_param(&mut probe, &decl.key, &pv)
            .map_err(|e| AdlError::at(decl.span, e))?;
        values.push(pv);
    }
    Ok(ParamAxis {
        key: decl.key.clone(),
        values,
    })
}

// --------------------------------------------------------- elaboration

/// Elaborate a parsed description into its validated graph + bindings.
pub fn elaborate(arch: &ast::Arch) -> Result<ElabArch, AdlError> {
    let mut ag = Ag::new();
    let target = match &arch.target {
        Some(t) => Some(target_spec(t)?),
        None => None,
    };
    let platform = match &arch.platform {
        Some(p) => Some(platform_desc(p)?),
        None => None,
    };
    let mut params: Vec<ParamAxis> = Vec::new();
    let mut templates: HashMap<&str, &ast::TemplateDecl> = HashMap::new();
    // (instance, port) -> exported half-edge.
    let mut ports: HashMap<(String, String), DanglingEdge> = HashMap::new();

    let lookup = |ag: &Ag, name: &str, span: Span| {
        ag.id(name)
            .ok_or_else(|| AdlError::at(span, format!("unknown object `{name}`")))
    };

    for item in &arch.items {
        match item {
            ast::Item::Object(decl) => {
                let obj = object_from_decl(decl.name.clone(), decl, "")?;
                ag.add(obj)
                    .map_err(|e| AdlError::at(decl.span, e.to_string()))?;
            }
            ast::Item::Connect(c) => {
                let src = lookup(&ag, &c.src, c.span)?;
                let dst = lookup(&ag, &c.dst, c.span)?;
                let kind = edge_kind(&c.kind, c.span)?;
                // Lower through the template machinery: a connect is the
                // join of a source half-edge and a target half-edge.
                connect_dangling(
                    &mut ag,
                    DanglingEdge::from_source(kind, src),
                    DanglingEdge::to_target(kind, dst),
                )
                .map_err(|e| {
                    AdlError::at(
                        c.span,
                        format!("cannot connect `{}` -> `{}`: {e}", c.src, c.dst),
                    )
                })?;
            }
            ast::Item::Param(p) => {
                if params.iter().any(|a| a.key == p.key) {
                    return Err(AdlError::at(
                        p.span,
                        format!("duplicate param axis `{}`", p.key),
                    ));
                }
                params.push(param_axis(&target, p)?);
            }
            ast::Item::Template(t) => {
                if templates.insert(t.name.as_str(), t).is_some() {
                    return Err(AdlError::at(
                        t.span,
                        format!("duplicate template `{}`", t.name),
                    ));
                }
            }
            ast::Item::Instance(inst) => {
                let Some(tpl) = templates.get(inst.template.as_str()) else {
                    return Err(AdlError::at(
                        inst.span,
                        format!("unknown template `{}`", inst.template),
                    ));
                };
                let prefix = format!("{}.", inst.prefix);
                for decl in &tpl.objects {
                    let obj = object_from_decl(
                        format!("{prefix}{}", decl.name),
                        decl,
                        &prefix,
                    )?;
                    ag.add(obj)
                        .map_err(|e| AdlError::at(inst.span, e.to_string()))?;
                }
                for c in &tpl.connects {
                    let src = lookup(&ag, &format!("{prefix}{}", c.src), c.span)?;
                    let dst = lookup(&ag, &format!("{prefix}{}", c.dst), c.span)?;
                    let kind = edge_kind(&c.kind, c.span)?;
                    connect_dangling(
                        &mut ag,
                        DanglingEdge::from_source(kind, src),
                        DanglingEdge::to_target(kind, dst),
                    )
                    .map_err(|e| {
                        AdlError::at(
                            c.span,
                            format!(
                                "cannot connect `{prefix}{}` -> `{prefix}{}`: {e}",
                                c.src, c.dst
                            ),
                        )
                    })?;
                }
                for d in &tpl.danglings {
                    let obj = lookup(&ag, &format!("{prefix}{}", d.obj), d.span)?;
                    let kind = edge_kind(&d.kind, d.span)?;
                    let edge = match d.dir {
                        DangleDir::From => DanglingEdge::from_source(kind, obj),
                        DangleDir::To => DanglingEdge::to_target(kind, obj),
                    };
                    let key = (inst.prefix.clone(), d.name.clone());
                    if ports.insert(key, edge).is_some() {
                        return Err(AdlError::at(
                            d.span,
                            format!(
                                "duplicate dangling edge `{}` on instance `{}`",
                                d.name, inst.prefix
                            ),
                        ));
                    }
                }
            }
            ast::Item::Join(j) => {
                let a = port(&mut ports, &j.a, j.span)?;
                let b = port(&mut ports, &j.b, j.span)?;
                connect_dangling(&mut ag, a, b).map_err(|e| {
                    AdlError::at(
                        j.span,
                        format!(
                            "cannot join `{}`.{} -> `{}`.{}: {e}",
                            j.a.instance, j.a.port, j.b.instance, j.b.port
                        ),
                    )
                })?;
            }
            ast::Item::Attach(a) => {
                let half = port(&mut ports, &a.port, a.span)?;
                let obj = lookup(&ag, &a.obj, a.span)?;
                connect_dangling_to(&mut ag, half, obj).map_err(|e| {
                    AdlError::at(
                        a.span,
                        format!(
                            "cannot attach `{}`.{} -> `{}`: {e}",
                            a.port.instance, a.port.port, a.obj
                        ),
                    )
                })?;
            }
        }
    }

    ag.validate()
        .map_err(|e| AdlError::at(arch.name_span, format!("graph validation failed: {e}")))?;
    Ok(ElabArch {
        name: arch.name.clone(),
        ag,
        target,
        platform,
        params,
    })
}

/// Look up and **consume** an exported half-edge: a dangling edge can be
/// joined or attached exactly once (one half-edge, one connection —
/// §4.2); a second use is an error rather than a silent duplicate edge.
fn port(
    ports: &mut HashMap<(String, String), DanglingEdge>,
    r: &ast::PortRef,
    span: Span,
) -> Result<DanglingEdge, AdlError> {
    ports
        .remove(&(r.instance.clone(), r.port.clone()))
        .ok_or_else(|| {
            AdlError::at(
                span,
                format!(
                    "unknown or already-connected dangling edge `{}`.{}",
                    r.instance, r.port
                ),
            )
        })
}

// ---------------------------------------------------------- equivalence

/// Order-insensitive graph equivalence: same objects (by name, with
/// identical attributes and register contents) and the same edge
/// multiset.  Returns a human-readable first difference.
pub fn ag_equiv(a: &Ag, b: &Ag) -> Result<(), String> {
    let canon = |ag: &Ag| -> BTreeMap<String, String> {
        ag.objects
            .iter()
            .map(|o| (o.name.clone(), printer::print_object(o)))
            .collect()
    };
    let am = canon(a);
    let bm = canon(b);
    for (name, sa) in &am {
        match bm.get(name) {
            None => return Err(format!("object `{name}` present only in the first graph")),
            Some(sb) if sb != sa => {
                return Err(format!(
                    "object `{name}` differs:\n--- first\n{sa}--- second\n{sb}"
                ))
            }
            _ => {}
        }
    }
    for name in bm.keys() {
        if !am.contains_key(name) {
            return Err(format!("object `{name}` present only in the second graph"));
        }
    }
    let edge_list = |ag: &Ag| -> Vec<(String, String, String)> {
        let mut v: Vec<_> = ag
            .edges
            .iter()
            .map(|e| {
                (
                    ag.name(e.src).to_string(),
                    ag.name(e.dst).to_string(),
                    e.kind.to_string(),
                )
            })
            .collect();
        v.sort();
        v
    };
    let ea = edge_list(a);
    let eb = edge_list(b);
    if ea != eb {
        for e in &ea {
            if !eb.contains(e) {
                return Err(format!(
                    "edge {} `{}` -> `{}` present only in the first graph",
                    e.2, e.0, e.1
                ));
            }
        }
        for e in &eb {
            if !ea.contains(e) {
                return Err(format!(
                    "edge {} `{}` -> `{}` present only in the second graph",
                    e.2, e.0, e.1
                ));
            }
        }
        return Err(format!(
            "edge multiplicities differ ({} vs {} edges)",
            ea.len(),
            eb.len()
        ));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::adl::{load_str, parse};

    const TINY: &str = r#"
arch "tiny"
object "ex0" : ExecuteStage {
  latency = 1
}
object "fu0" : FunctionalUnit {
  ops = [add, mac]
  latency = 2
}
object "rf0" : RegisterFile {
  width = 32
  regs {
    "r0" : i32 = 0
    "r1" : i32 = 7
  }
}
connect "ex0" -> "fu0" : CONTAINS
connect "rf0" -> "fu0" : READ_DATA
connect "fu0" -> "rf0" : WRITE_DATA
"#;

    #[test]
    fn tiny_arch_elaborates() {
        let e = load_str(TINY).unwrap();
        assert_eq!(e.ag.len(), 3);
        assert_eq!(e.ag.edges.len(), 3);
        assert_eq!(e.ag.reg_count(), 2);
        assert_eq!(e.ag.reg(e.ag.reg_id("r1").unwrap()).init.payload.as_int(), 7);
        let fu = e.ag.id("fu0").unwrap();
        assert!(e.ag.kind(fu).to_process().unwrap().contains("mac"));
        assert_eq!(e.ag.kind(fu).latency().unwrap().eval_const().unwrap(), 2);
    }

    #[test]
    fn unknown_class_and_attr_diagnosed_with_spans() {
        let e = load_str("arch \"x\"\nobject \"a\" : Sram2 {\n}").unwrap_err();
        assert!(e.to_string().contains("unknown ACADL class"), "{e}");
        assert_eq!(e.span.unwrap().line, 2);

        let e = load_str("arch \"x\"\nobject \"a\" : ExecuteStage {\n  wombat = 3\n}")
            .unwrap_err();
        assert!(e.to_string().contains("unknown attribute `wombat`"), "{e}");
        assert_eq!(e.span.unwrap().line, 3);
    }

    #[test]
    fn invalid_edges_diagnosed() {
        let src = r#"
arch "x"
object "rf0" : RegisterFile {
  width = 32
  regs {
    "r0" : i32 = 0
  }
}
object "ex0" : ExecuteStage {
  latency = 1
}
connect "rf0" -> "ex0" : FORWARD
"#;
        let e = load_str(src).unwrap_err();
        assert!(e.to_string().contains("FORWARD"), "{e}");
        assert_eq!(e.span.unwrap().line, 12);

        let e = load_str("arch \"x\"\nconnect \"a\" -> \"b\" : FORWARD").unwrap_err();
        assert!(e.to_string().contains("unknown object `a`"), "{e}");
    }

    #[test]
    fn validation_failures_surface() {
        // An orphan functional unit fails whole-graph validation.
        let e = load_str(
            "arch \"x\"\nobject \"fu0\" : FunctionalUnit {\n  ops = [add]\n  latency = 1\n}",
        )
        .unwrap_err();
        assert!(e.to_string().contains("graph validation failed"), "{e}");
    }

    #[test]
    fn targets_and_params_elaborate() {
        let src = r#"
arch "sweep" targets systolic {
  rows = 2
  cols = 4
}
param rows in [2, 4, 8]
param cols in [2, 4]
"#;
        let e = load_str(src).unwrap();
        assert_eq!(
            e.target,
            Some(TargetSpec::Systolic { rows: 2, cols: 4 })
        );
        assert_eq!(e.params.len(), 2);
        assert_eq!(e.params[0].values.len(), 3);
        let mut c = e.base_candidate().unwrap();
        apply_param(&mut c, "rows", &ParamValue::Int(8)).unwrap();
        assert_eq!(c.target, TargetSpec::Systolic { rows: 8, cols: 4 });
    }

    #[test]
    fn platform_block_elaborates_with_defaults() {
        let src = r#"
arch "quad" targets systolic {
  rows = 2
  cols = 2
}
platform {
  chips = 4
  hop_latency = 8
  microbatches = 6
}
"#;
        let e = load_str(src).unwrap();
        let p = e.platform.unwrap();
        assert_eq!(p.chips, 4);
        assert_eq!(p.fabric.hop_latency, 8);
        assert_eq!(p.microbatches, 6);
        // Unset knobs keep the library defaults.
        let d = PlatformDesc::default();
        assert_eq!(p.fabric.link_words_per_cycle, d.fabric.link_words_per_cycle);
        assert_eq!(p.dram.base_latency, d.dram.base_latency);
        assert_eq!(p.dram.words_per_cycle, d.dram.words_per_cycle);

        // `chips` is required; zero chips and unknown attrs are rejected.
        let e = load_str("arch \"p\" platform {\n  hop_latency = 2\n}").unwrap_err();
        assert!(e.to_string().contains("chips"), "{e}");
        let e = load_str("arch \"p\" platform {\n  chips = 0\n}").unwrap_err();
        assert!(e.to_string().contains(">= 1"), "{e}");
        let e = load_str("arch \"p\" platform {\n  chips = 2\n  wombat = 1\n}").unwrap_err();
        assert!(e.to_string().contains("unknown attribute"), "{e}");
    }

    #[test]
    fn bad_params_rejected() {
        let e = load_str("arch \"x\" param rows in [2]").unwrap_err();
        assert!(e.to_string().contains("targets"), "{e}");

        let e = load_str(
            "arch \"x\" targets gamma {\n  units = 1\n}\nparam rows in [2]",
        )
        .unwrap_err();
        assert!(e.to_string().contains("does not apply"), "{e}");

        let e = load_str(
            "arch \"x\" targets oma {\n  cache = true\n}\nparam order in [ijk, bogus]",
        )
        .unwrap_err();
        assert!(e.to_string().contains("unknown loop order"), "{e}");
    }

    #[test]
    fn templates_expand_through_dangling_edges() {
        let src = r#"
arch "pair"
template Pe {
  object "ex" : ExecuteStage {
    latency = 1
  }
  object "fu" : FunctionalUnit {
    ops = [macf]
    latency = 1
  }
  object "rf" : RegisterFile {
    width = 32
    regs {
      "acc" : f32 = 0
    }
  }
  connect "ex" -> "fu" : CONTAINS
  connect "rf" -> "fu" : READ_DATA
  connect "fu" -> "rf" : WRITE_DATA
  dangling "out" : WRITE_DATA from "fu"
  dangling "in" : WRITE_DATA to "rf"
}
instance "a" : Pe
instance "b" : Pe
join "a".out -> "b".in
"#;
        let e = load_str(src).unwrap();
        assert_eq!(e.ag.len(), 6);
        // 3 internal edges per instance + 1 joined; the unconnected
        // half-edges (`a`.in, `b`.out) never materialize.
        assert_eq!(e.ag.edges.len(), 7);
        let fu_a = e.ag.id("a.fu").unwrap();
        let rf_b = e.ag.id("b.rf").unwrap();
        assert!(e.ag.writable_rfs(fu_a).contains(&rf_b));
        // Registers are instance-prefixed.
        assert!(e.ag.reg_id("a.acc").is_some());
        assert!(e.ag.reg_id("b.acc").is_some());
    }

    #[test]
    fn join_errors_diagnosed() {
        let src = r#"
arch "pair"
template T {
  object "ex" : ExecuteStage {
    latency = 1
  }
  object "fu" : FunctionalUnit {
    ops = [add]
    latency = 1
  }
  connect "ex" -> "fu" : CONTAINS
  dangling "out" : WRITE_DATA from "fu"
}
instance "a" : T
instance "b" : T
join "a".out -> "b".out
"#;
        let e = load_str(src).unwrap_err();
        assert!(e.to_string().contains("cannot join"), "{e}");

        let e = load_str("arch \"x\"\ninstance \"a\" : Nope").unwrap_err();
        assert!(e.to_string().contains("unknown template"), "{e}");
    }

    #[test]
    fn dangling_edges_connect_exactly_once() {
        let base = r#"
arch "pair"
template Pe {
  object "ex" : ExecuteStage {
    latency = 1
  }
  object "fu" : FunctionalUnit {
    ops = [macf]
    latency = 1
  }
  object "rf" : RegisterFile {
    width = 32
    regs {
      "acc" : f32 = 0
    }
  }
  connect "ex" -> "fu" : CONTAINS
  connect "rf" -> "fu" : READ_DATA
  connect "fu" -> "rf" : WRITE_DATA
  dangling "out" : WRITE_DATA from "fu"
  dangling "in" : WRITE_DATA to "rf"
}
instance "a" : Pe
instance "b" : Pe
instance "c" : Pe
join "a".out -> "b".in
"#;
        // Re-joining a consumed half-edge is an error, not a duplicate
        // edge (one half-edge, one connection — §4.2).
        let e = load_str(&format!("{base}join \"a\".out -> \"c\".in\n")).unwrap_err();
        assert!(e.to_string().contains("already-connected"), "{e}");
        // Same for attach after join.
        let e = load_str(&format!("{base}attach \"a\".out -> \"c.rf\"\n")).unwrap_err();
        assert!(e.to_string().contains("already-connected"), "{e}");
    }

    #[test]
    fn oversized_widths_rejected_not_truncated() {
        let e = load_str(
            "arch \"x\"\nobject \"rf0\" : RegisterFile {\n  width = 4294967296\n  regs {\n    \"r0\" : i32 = 0\n  }\n}",
        )
        .unwrap_err();
        assert!(e.to_string().contains("out of range"), "{e}");
    }

    #[test]
    fn ag_equiv_detects_differences() {
        let a = load_str(TINY).unwrap().ag;
        let b = load_str(TINY).unwrap().ag;
        ag_equiv(&a, &b).unwrap();
        // Drop an edge.
        let mut c = load_str(TINY).unwrap().ag;
        c.edges.pop();
        let msg = ag_equiv(&a, &c).unwrap_err();
        assert!(msg.contains("only in the first graph"), "{msg}");
        // Change an attribute.
        let d = load_str(&TINY.replace("latency = 2", "latency = 3")).unwrap().ag;
        let msg = ag_equiv(&a, &d).unwrap_err();
        assert!(msg.contains("`fu0` differs"), "{msg}");
    }

    #[test]
    fn parse_is_pure_syntax() {
        // The parser accepts semantically-wrong input; elaboration rejects.
        let ast = parse("arch \"x\"\nobject \"a\" : Nope {\n}").unwrap();
        assert!(elaborate(&ast).is_err());
    }

    #[test]
    fn stamp_applies_param_indices_onto_the_base() {
        let src = "arch \"s\" targets systolic {\n  rows = 2\n  cols = 2\n}\n\
                   param rows in [2, 4]\nparam cols in [2, 4, 8]\n";
        let arch = load_str(src).unwrap();
        let c = arch.stamp(&[1, 2]).unwrap();
        assert_eq!(c.target, TargetSpec::Systolic { rows: 4, cols: 8 });
        // Missing trailing indices keep the base value.
        let c = arch.stamp(&[1]).unwrap();
        assert_eq!(c.target, TargetSpec::Systolic { rows: 4, cols: 2 });
        // Out-of-range index is an error, not a wrap.
        assert!(arch.stamp(&[2, 0]).is_err());
        // No binding: nothing to stamp.
        assert!(load_str("arch \"free\"").unwrap().stamp(&[]).is_err());
    }
}
