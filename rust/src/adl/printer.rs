//! The canonical `.acadl` pretty-printer.
//!
//! Printing is a pure function of the elaborated form: the `targets`
//! binding, the `param` axes, then every object (in graph insertion
//! order) and every edge (in insertion order).  Templates do not survive
//! printing — `fmt` canonicalizes them into their flattened objects and
//! edges.  The canonical form quotes every name, prints every attribute
//! of every class (in a fixed per-class order), and uses plain decimal
//! integers, so that:
//!
//! * `parse(print(ag))` elaborates to an equivalent graph
//!   ([`crate::adl::elab::ag_equiv`]), and
//! * printing is byte-idempotent — the contract `acadl-cli fmt --check`
//!   enforces over `examples/*.acadl`.

use std::fmt::Write as _;

use crate::acadl_core::data::Value;
use crate::acadl_core::graph::Ag;
use crate::acadl_core::latency::Latency;
use crate::acadl_core::object::{Object, ObjectKind};
use crate::adl::elab::{ElabArch, ParamAxis, ParamValue};
use crate::arch::platform::PlatformDesc;
use crate::coordinator::job::TargetSpec;
use crate::mem::cache::ReplacementPolicy;

/// Quote a name or expression string (the inverse of the lexer's string
/// rules).
fn quote(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            c => out.push(c),
        }
    }
    out.push('"');
    out
}

fn latency_str(l: &Latency) -> String {
    match l {
        Latency::Const(v) => v.to_string(),
        Latency::Expr(_) => quote(&l.to_string()),
    }
}

fn policy_name(p: ReplacementPolicy) -> &'static str {
    match p {
        ReplacementPolicy::Lru => "lru",
        ReplacementPolicy::Fifo => "fifo",
        ReplacementPolicy::Plru => "plru",
        ReplacementPolicy::Random => "random",
    }
}

/// Does a mnemonic re-lex as a plain identifier (and not a boolean
/// keyword)?  Anything else must be quoted or the canonical form would
/// not re-parse.
fn is_bare_ident(s: &str) -> bool {
    let mut chars = s.chars();
    let head_ok = chars
        .next()
        .is_some_and(|c| c.is_ascii_alphabetic() || c == '_');
    head_ok
        && chars.all(|c| c.is_ascii_alphanumeric() || c == '_')
        && s != "true"
        && s != "false"
}

fn ops_str(ops: &std::collections::BTreeSet<String>) -> String {
    let mut out = String::from("[");
    for (i, op) in ops.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        if is_bare_ident(op) {
            out.push_str(op);
        } else {
            out.push_str(&quote(op));
        }
    }
    out.push(']');
    out
}

/// Print one object declaration in canonical form (ends with a newline).
pub fn print_object(obj: &Object) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "object {} : {} {{", quote(&obj.name), obj.kind.class_name());
    match &obj.kind {
        ObjectKind::PipelineStage(p) => {
            let _ = writeln!(s, "  latency = {}", latency_str(&p.latency));
        }
        ObjectKind::ExecuteStage(e) => {
            let _ = writeln!(s, "  latency = {}", latency_str(&e.latency));
        }
        ObjectKind::InstructionFetchStage(i) => {
            let _ = writeln!(s, "  latency = {}", latency_str(&i.latency));
            let _ = writeln!(s, "  issue_buffer = {}", i.issue_buffer_size);
        }
        ObjectKind::FunctionalUnit(f) => {
            let _ = writeln!(s, "  ops = {}", ops_str(&f.to_process));
            let _ = writeln!(s, "  latency = {}", latency_str(&f.latency));
        }
        ObjectKind::MemoryAccessUnit(m) => {
            let _ = writeln!(s, "  ops = {}", ops_str(&m.to_process));
            let _ = writeln!(s, "  latency = {}", latency_str(&m.latency));
        }
        ObjectKind::InstructionMemoryAccessUnit(i) => {
            let _ = writeln!(s, "  latency = {}", latency_str(&i.latency));
        }
        ObjectKind::RegisterFile(rf) => {
            let _ = writeln!(s, "  width = {}", rf.data_width);
            if !rf.registers.is_empty() {
                s.push_str("  regs {\n");
                for (name, data) in &rf.registers {
                    match &data.payload {
                        Value::Int(v) => {
                            let _ = writeln!(s, "    {} : i{} = {}", quote(name), data.size, v);
                        }
                        Value::F32(v) => {
                            let _ = writeln!(s, "    {} : f32 = {}", quote(name), v);
                        }
                        Value::Vec(lanes) => {
                            let _ = writeln!(
                                s,
                                "    {} : vec({}, {})",
                                quote(name),
                                data.size,
                                lanes.len()
                            );
                        }
                    }
                }
                s.push_str("  }\n");
            }
        }
        ObjectKind::Sram(m) => {
            let _ = writeln!(s, "  base = {}", m.address_range.0);
            let _ = writeln!(s, "  end = {}", m.address_range.1);
            let _ = writeln!(s, "  read_latency = {}", latency_str(&m.read_latency));
            let _ = writeln!(s, "  write_latency = {}", latency_str(&m.write_latency));
            let _ = writeln!(s, "  width = {}", m.ds.data_width);
            let _ = writeln!(s, "  requests = {}", m.ds.max_concurrent_requests);
            let _ = writeln!(s, "  ports = {}", m.ds.read_write_ports);
            let _ = writeln!(s, "  port_width = {}", m.ds.port_width);
        }
        ObjectKind::Dram(d) => {
            let _ = writeln!(s, "  base = {}", d.address_range.0);
            let _ = writeln!(s, "  end = {}", d.address_range.1);
            let _ = writeln!(s, "  banks = {}", d.banks);
            let _ = writeln!(s, "  row_bytes = {}", d.row_bytes);
            let _ = writeln!(s, "  t_rcd = {}", d.t_rcd);
            let _ = writeln!(s, "  t_rp = {}", d.t_rp);
            let _ = writeln!(s, "  t_ras = {}", d.t_ras);
            let _ = writeln!(s, "  t_cas = {}", d.t_cas);
            let _ = writeln!(s, "  width = {}", d.ds.data_width);
            let _ = writeln!(s, "  requests = {}", d.ds.max_concurrent_requests);
            let _ = writeln!(s, "  ports = {}", d.ds.read_write_ports);
            let _ = writeln!(s, "  port_width = {}", d.ds.port_width);
        }
        ObjectKind::Cache(c) => {
            let _ = writeln!(s, "  sets = {}", c.sets);
            let _ = writeln!(s, "  ways = {}", c.ways);
            let _ = writeln!(s, "  line = {}", c.cache_line_size);
            let _ = writeln!(s, "  policy = {}", policy_name(c.replacement_policy));
            let _ = writeln!(s, "  hit_latency = {}", latency_str(&c.hit_latency));
            let _ = writeln!(s, "  miss_latency = {}", latency_str(&c.miss_latency));
            let _ = writeln!(s, "  write_allocate = {}", c.write_allocate);
            let _ = writeln!(s, "  write_back = {}", c.write_back);
            let _ = writeln!(s, "  width = {}", c.ds.data_width);
            let _ = writeln!(s, "  requests = {}", c.ds.max_concurrent_requests);
            let _ = writeln!(s, "  ports = {}", c.ds.read_write_ports);
            let _ = writeln!(s, "  port_width = {}", c.ds.port_width);
        }
    }
    s.push_str("}\n");
    s
}

fn param_value_str(v: &ParamValue) -> String {
    match v {
        ParamValue::Int(i) => i.to_string(),
        ParamValue::Bool(b) => b.to_string(),
        ParamValue::Name(n) => n.clone(),
    }
}

fn target_block(t: &TargetSpec) -> String {
    let mut s = String::new();
    match t {
        TargetSpec::Oma { cache, mac_latency } => {
            s.push_str("targets oma {\n");
            let _ = writeln!(s, "  cache = {cache}");
            if let Some(l) = mac_latency {
                let _ = writeln!(s, "  mac_latency = {l}");
            }
        }
        TargetSpec::Systolic { rows, cols } => {
            s.push_str("targets systolic {\n");
            let _ = writeln!(s, "  rows = {rows}");
            let _ = writeln!(s, "  cols = {cols}");
        }
        TargetSpec::Gamma { units } => {
            s.push_str("targets gamma {\n");
            let _ = writeln!(s, "  units = {units}");
        }
    }
    s.push('}');
    s
}

/// Canonical `platform { … }` block: every knob printed explicitly, in
/// declaration order, so the form is byte-idempotent under `fmt`.
fn platform_block(p: &PlatformDesc) -> String {
    let mut s = String::from("platform {\n");
    let _ = writeln!(s, "  chips = {}", p.chips);
    let _ = writeln!(s, "  hop_latency = {}", p.fabric.hop_latency);
    let _ = writeln!(s, "  link_words_per_cycle = {}", p.fabric.link_words_per_cycle);
    let _ = writeln!(s, "  dram_latency = {}", p.dram.base_latency);
    let _ = writeln!(s, "  dram_words_per_cycle = {}", p.dram.words_per_cycle);
    let _ = writeln!(s, "  microbatches = {}", p.microbatches);
    s.push('}');
    s
}

/// Print a full architecture description in canonical form.
pub fn print_arch(
    name: &str,
    target: Option<&TargetSpec>,
    platform: Option<&PlatformDesc>,
    params: &[ParamAxis],
    ag: &Ag,
) -> String {
    let mut s = String::new();
    match target {
        Some(t) => {
            let _ = writeln!(s, "arch {} {}", quote(name), target_block(t));
        }
        None => {
            let _ = writeln!(s, "arch {}", quote(name));
        }
    }
    if let Some(p) = platform {
        let _ = writeln!(s, "{}", platform_block(p));
    }
    for axis in params {
        let vals: Vec<String> = axis.values.iter().map(param_value_str).collect();
        let _ = writeln!(s, "param {} in [{}]", axis.key, vals.join(", "));
    }
    for obj in &ag.objects {
        s.push_str(&print_object(obj));
    }
    for e in &ag.edges {
        let _ = writeln!(
            s,
            "connect {} -> {} : {}",
            quote(ag.name(e.src)),
            quote(ag.name(e.dst)),
            e.kind
        );
    }
    s
}

/// Print an elaborated architecture (the `fmt` entry point).
pub fn print_elab(e: &ElabArch) -> String {
    print_arch(&e.name, e.target.as_ref(), e.platform.as_ref(), &e.params, &e.ag)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acadl_core::data::Data;
    use crate::acadl_core::latency::Latency;
    use crate::acadl_core::object::build;

    #[test]
    fn object_formats() {
        let fu = build::functional_unit("fu0", &["mov", "mac"], Latency::Const(2));
        assert_eq!(
            print_object(&fu),
            "object \"fu0\" : FunctionalUnit {\n  ops = [mac, mov]\n  latency = 2\n}\n"
        );
        let rf = build::register_file(
            "rf[0][1]",
            32,
            vec![
                ("r0".into(), Data::int(32, 7)),
                ("a".into(), Data::f32(0.0)),
                ("v".into(), Data::vec(128, 8)),
            ],
        );
        assert_eq!(
            print_object(&rf),
            "object \"rf[0][1]\" : RegisterFile {\n  width = 32\n  regs {\n    \"r0\" : i32 = 7\n    \"a\" : f32 = 0\n    \"v\" : vec(128, 8)\n  }\n}\n"
        );
    }

    #[test]
    fn exotic_ops_are_quoted() {
        // Mnemonics that would not re-lex as identifiers (or would
        // re-parse as booleans) must be quoted in canonical form.
        let fu = build::functional_unit("fu0", &["mov", "true", "2x"], Latency::Const(1));
        let s = print_object(&fu);
        assert!(s.contains("ops = [\"2x\", mov, \"true\"]"), "{s}");
    }

    #[test]
    fn expression_latency_is_quoted() {
        let fu = build::functional_unit(
            "fu0",
            &["mac"],
            Latency::parse("1 + is_mac * 3").unwrap(),
        );
        assert!(print_object(&fu).contains("latency = \"1 + is_mac * 3\""));
    }

    #[test]
    fn arch_header_forms() {
        let ag = Ag::new();
        let s = print_arch("empty", None, None, &[], &ag);
        assert_eq!(s, "arch \"empty\"\n");
        let t = TargetSpec::Systolic { rows: 2, cols: 3 };
        let s = print_arch("sys", Some(&t), None, &[], &ag);
        assert_eq!(
            s,
            "arch \"sys\" targets systolic {\n  rows = 2\n  cols = 3\n}\n"
        );
    }

    #[test]
    fn platform_block_prints_every_knob() {
        let ag = Ag::new();
        let p = PlatformDesc::new(4).with_hop_latency(8).with_microbatches(6);
        let t = TargetSpec::Systolic { rows: 2, cols: 2 };
        let s = print_arch("quad", Some(&t), Some(&p), &[], &ag);
        assert_eq!(
            s,
            "arch \"quad\" targets systolic {\n  rows = 2\n  cols = 2\n}\n\
             platform {\n  chips = 4\n  hop_latency = 8\n  link_words_per_cycle = 4\n  \
             dram_latency = 8\n  dram_words_per_cycle = 2\n  microbatches = 6\n}\n"
        );
        // The canonical form round-trips and is byte-idempotent.
        let e = crate::adl::load_str(&s).unwrap();
        assert_eq!(e.platform, Some(p));
        assert_eq!(print_elab(&e), s);
    }
}
