//! The ACADL textual frontend: parse, elaborate, and round-trip `.acadl`
//! architecture descriptions.
//!
//! The paper's central artifact is the *language* — Listings 1–3 define
//! accelerators as class/template descriptions.  This module gives the
//! repo a concrete textual syntax for it, so architectures arrive as
//! files (or inline job-spec strings) instead of recompiled Rust:
//!
//! * [`lexer`] / [`parser`] — a spanned token stream and a
//!   recursive-descent parser producing the [`ast`] of one `arch`
//!   description: object declarations with attributes and latencies,
//!   `connect` statements, templates with dangling edges
//!   (`template` / `instance` / `join` / `attach`), and a `param` block
//!   declaring DSE sweep axes.
//! * [`elab`] — the elaborator: lowers the AST through the existing
//!   [`crate::acadl_core::template`] machinery (every edge is formed by
//!   joining half-edges) into a validated [`Ag`], resolves the optional
//!   `targets` binding to a serializable
//!   [`TargetSpec`](crate::coordinator::job::TargetSpec), and reports
//!   rich `line:col` diagnostics ([`AdlError`]).
//! * [`printer`] — the canonical pretty-printer.  `parse(print(ag))`
//!   reproduces the graph exactly ([`elab::ag_equiv`]), and printing is
//!   byte-idempotent: `print(parse(print(parse(src))))
//!   == print(parse(src))` — the contract behind `acadl-cli fmt`.
//!
//! Grammar sketch (see DESIGN.md §"ACADL textual frontend" for the full
//! version):
//!
//! ```text
//! file     := 'arch' name [ 'targets' IDENT '{' attr* '}' ] item*
//! item     := object | connect | param | template | instance | join | attach
//! object   := 'object' name ':' CLASS '{' (attr | regs)* '}'
//! regs     := 'regs' '{' (name ':' regtype)* '}'
//! regtype  := 'i'WIDTH '=' INT | 'f32' '=' NUM | 'vec' '(' INT ',' INT ')'
//! connect  := 'connect' name '->' name ':' EDGE_KIND
//! param    := 'param' IDENT 'in' '[' value (',' value)* ']'
//! template := 'template' IDENT '{' (object | connect | dangling)* '}'
//! dangling := 'dangling' name ':' EDGE_KIND ('from'|'to') name
//! instance := 'instance' name ':' IDENT
//! join     := 'join' name '.' name '->' name '.' name
//! attach   := 'attach' name '.' name '->' name
//! name     := IDENT | STRING      (quote names containing `[ ] .`)
//! ```

pub mod ast;
pub mod elab;
pub mod lexer;
pub mod parser;
pub mod printer;

pub use elab::{ag_equiv, elaborate, ElabArch, ParamAxis, ParamValue};
pub use parser::parse;
pub use printer::{print_arch, print_elab};

use std::fmt;

/// A 1-based source position.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Span {
    pub line: u32,
    pub col: u32,
}

/// A frontend diagnostic: message plus (when known) the source position.
#[derive(Debug, Clone, PartialEq)]
pub struct AdlError {
    pub span: Option<Span>,
    pub msg: String,
}

impl AdlError {
    pub fn at(span: Span, msg: impl Into<String>) -> Self {
        AdlError {
            span: Some(span),
            msg: msg.into(),
        }
    }

    pub fn global(msg: impl Into<String>) -> Self {
        AdlError {
            span: None,
            msg: msg.into(),
        }
    }
}

impl fmt::Display for AdlError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self.span {
            Some(s) => write!(f, "{}:{}: {}", s.line, s.col, self.msg),
            None => f.write_str(&self.msg),
        }
    }
}

impl std::error::Error for AdlError {}

/// Parse and elaborate one `.acadl` source string.
pub fn load_str(src: &str) -> Result<ElabArch, AdlError> {
    elaborate(&parse(src)?)
}
