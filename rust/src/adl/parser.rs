//! Recursive-descent parser for the `.acadl` grammar (see the module docs
//! of [`crate::adl`] for the grammar sketch).  Produces the spanned
//! [`ast::Arch`]; all semantic checking is deferred to the elaborator.

use crate::adl::ast::*;
use crate::adl::lexer::{lex, Lexed, Tok};
use crate::adl::{AdlError, Span};

struct Parser {
    toks: Vec<Lexed>,
    pos: usize,
}

impl Parser {
    fn peek(&self) -> &Tok {
        &self.toks[self.pos].tok
    }

    fn span(&self) -> Span {
        self.toks[self.pos].span
    }

    fn bump(&mut self) -> Lexed {
        let l = self.toks[self.pos].clone();
        if self.pos + 1 < self.toks.len() {
            self.pos += 1;
        }
        l
    }

    fn err(&self, msg: impl Into<String>) -> AdlError {
        AdlError::at(self.span(), msg)
    }

    /// Consume a keyword (contextual identifier).
    fn expect_kw(&mut self, kw: &str) -> Result<Span, AdlError> {
        match self.peek() {
            Tok::Ident(s) if s == kw => Ok(self.bump().span),
            other => Err(self.err(format!("expected `{kw}`, found {other}"))),
        }
    }

    fn expect_tok(&mut self, want: Tok, desc: &str) -> Result<Span, AdlError> {
        if *self.peek() == want {
            Ok(self.bump().span)
        } else {
            Err(self.err(format!("expected {desc}, found {}", self.peek())))
        }
    }

    /// A bare identifier (class names, edge kinds, param keys).
    fn ident(&mut self, what: &str) -> Result<(String, Span), AdlError> {
        match self.peek().clone() {
            Tok::Ident(s) => Ok((s, self.bump().span)),
            other => Err(self.err(format!("expected {what}, found {other}"))),
        }
    }

    /// A name: quoted string or bare identifier.
    fn name(&mut self, what: &str) -> Result<(String, Span), AdlError> {
        match self.peek().clone() {
            Tok::Ident(s) | Tok::Str(s) => Ok((s, self.bump().span)),
            other => Err(self.err(format!("expected {what}, found {other}"))),
        }
    }

    fn value(&mut self) -> Result<ValueExpr, AdlError> {
        match self.peek().clone() {
            Tok::Int(v) => {
                self.bump();
                Ok(ValueExpr::Int(v))
            }
            Tok::Float(v) => {
                self.bump();
                Ok(ValueExpr::Float(v))
            }
            Tok::Str(s) => {
                self.bump();
                Ok(ValueExpr::Str(s))
            }
            Tok::Ident(s) => {
                self.bump();
                match s.as_str() {
                    "true" => Ok(ValueExpr::Bool(true)),
                    "false" => Ok(ValueExpr::Bool(false)),
                    _ => Ok(ValueExpr::Ident(s)),
                }
            }
            Tok::LBracket => {
                self.bump();
                let mut items = Vec::new();
                if *self.peek() == Tok::RBracket {
                    self.bump();
                    return Ok(ValueExpr::List(items));
                }
                loop {
                    items.push(self.value()?);
                    match self.peek() {
                        Tok::Comma => {
                            self.bump();
                        }
                        Tok::RBracket => {
                            self.bump();
                            return Ok(ValueExpr::List(items));
                        }
                        other => {
                            return Err(
                                self.err(format!("expected `,` or `]` in list, found {other}"))
                            )
                        }
                    }
                }
            }
            other => Err(self.err(format!("expected a value, found {other}"))),
        }
    }

    /// `key = value`, where the current token is the key identifier.
    fn attr(&mut self) -> Result<Attr, AdlError> {
        let (key, span) = self.ident("an attribute name")?;
        self.expect_tok(Tok::Eq, "`=`")?;
        let value = self.value()?;
        Ok(Attr { key, span, value })
    }

    /// `'{' attr* '}'`
    fn attr_block(&mut self) -> Result<Vec<Attr>, AdlError> {
        self.expect_tok(Tok::LBrace, "`{`")?;
        let mut attrs = Vec::new();
        while *self.peek() != Tok::RBrace {
            attrs.push(self.attr()?);
        }
        self.bump(); // `}`
        Ok(attrs)
    }

    fn reg_decl(&mut self) -> Result<RegDecl, AdlError> {
        let (name, span) = self.name("a register name")?;
        self.expect_tok(Tok::Colon, "`:`")?;
        let (ty_name, ty_span) = self.ident("a register type (i<width>, f32, vec)")?;
        let ty = match ty_name.as_str() {
            "f32" => {
                self.expect_tok(Tok::Eq, "`=`")?;
                let init = match self.bump().tok {
                    Tok::Int(v) => v as f32,
                    Tok::Float(v) => v as f32,
                    other => {
                        return Err(AdlError::at(
                            ty_span,
                            format!("expected a numeric f32 initializer, found {other}"),
                        ))
                    }
                };
                RegType::F32 { init }
            }
            "vec" => {
                self.expect_tok(Tok::LParen, "`(`")?;
                let size = self.int_in_range(ty_span, "vector bit size", 1, u32::MAX as i64)?;
                self.expect_tok(Tok::Comma, "`,`")?;
                let lanes = self.int_in_range(ty_span, "vector lane count", 1, 1 << 16)?;
                self.expect_tok(Tok::RParen, "`)`")?;
                RegType::Vec {
                    size: size as u32,
                    lanes: lanes as usize,
                }
            }
            other => {
                let width: u32 = other
                    .strip_prefix('i')
                    .and_then(|w| w.parse().ok())
                    .ok_or_else(|| {
                        AdlError::at(
                            ty_span,
                            format!("unknown register type `{other}` (expected i<width>, f32, or vec)"),
                        )
                    })?;
                self.expect_tok(Tok::Eq, "`=`")?;
                let init = match self.bump().tok {
                    Tok::Int(v) => v,
                    other => {
                        return Err(AdlError::at(
                            ty_span,
                            format!("expected an integer initializer, found {other}"),
                        ))
                    }
                };
                RegType::Int { width, init }
            }
        };
        Ok(RegDecl { name, span, ty })
    }

    fn int_in_range(
        &mut self,
        span: Span,
        what: &str,
        lo: i64,
        hi: i64,
    ) -> Result<i64, AdlError> {
        match self.bump().tok {
            Tok::Int(v) if v >= lo && v <= hi => Ok(v),
            Tok::Int(v) => Err(AdlError::at(
                span,
                format!("{what} {v} out of range [{lo}, {hi}]"),
            )),
            other => Err(AdlError::at(
                span,
                format!("expected an integer {what}, found {other}"),
            )),
        }
    }

    /// `object "name" : Class { … }` (the `object` keyword is consumed).
    fn object(&mut self) -> Result<ObjectDecl, AdlError> {
        let (name, span) = self.name("an object name")?;
        self.expect_tok(Tok::Colon, "`:`")?;
        let (class, class_span) = self.ident("an ACADL class name")?;
        self.expect_tok(Tok::LBrace, "`{`")?;
        let mut attrs = Vec::new();
        let mut regs = Vec::new();
        loop {
            match self.peek().clone() {
                Tok::RBrace => {
                    self.bump();
                    break;
                }
                Tok::Ident(k) if k == "regs" => {
                    self.bump();
                    self.expect_tok(Tok::LBrace, "`{`")?;
                    while *self.peek() != Tok::RBrace {
                        regs.push(self.reg_decl()?);
                    }
                    self.bump(); // `}`
                }
                Tok::Ident(_) => attrs.push(self.attr()?),
                other => {
                    return Err(
                        self.err(format!("expected an attribute or `}}`, found {other}"))
                    )
                }
            }
        }
        Ok(ObjectDecl {
            name,
            span,
            class,
            class_span,
            attrs,
            regs,
        })
    }

    /// `connect "a" -> "b" : KIND` (the `connect` keyword is consumed).
    fn connect(&mut self, span: Span) -> Result<ConnectDecl, AdlError> {
        let (src, _) = self.name("a source object name")?;
        self.expect_tok(Tok::Arrow, "`->`")?;
        let (dst, _) = self.name("a destination object name")?;
        self.expect_tok(Tok::Colon, "`:`")?;
        let (kind, _) = self.ident("an edge kind")?;
        Ok(ConnectDecl {
            src,
            dst,
            kind,
            span,
        })
    }

    fn port_ref(&mut self) -> Result<PortRef, AdlError> {
        let (instance, _) = self.name("an instance name")?;
        self.expect_tok(Tok::Dot, "`.`")?;
        let (port, _) = self.name("a dangling-edge name")?;
        Ok(PortRef { instance, port })
    }

    fn template(&mut self, span: Span) -> Result<TemplateDecl, AdlError> {
        let (name, _) = self.ident("a template name")?;
        self.expect_tok(Tok::LBrace, "`{`")?;
        let mut objects = Vec::new();
        let mut connects = Vec::new();
        let mut danglings = Vec::new();
        loop {
            match self.peek().clone() {
                Tok::RBrace => {
                    self.bump();
                    break;
                }
                Tok::Ident(k) if k == "object" => {
                    self.bump();
                    objects.push(self.object()?);
                }
                Tok::Ident(k) if k == "connect" => {
                    let s = self.bump().span;
                    connects.push(self.connect(s)?);
                }
                Tok::Ident(k) if k == "dangling" => {
                    let s = self.bump().span;
                    let (dname, _) = self.name("a dangling-edge name")?;
                    self.expect_tok(Tok::Colon, "`:`")?;
                    let (kind, _) = self.ident("an edge kind")?;
                    let dir = match self.peek().clone() {
                        Tok::Ident(d) if d == "from" => {
                            self.bump();
                            DangleDir::From
                        }
                        Tok::Ident(d) if d == "to" => {
                            self.bump();
                            DangleDir::To
                        }
                        other => {
                            return Err(
                                self.err(format!("expected `from` or `to`, found {other}"))
                            )
                        }
                    };
                    let (obj, _) = self.name("an object name")?;
                    danglings.push(DanglingDecl {
                        name: dname,
                        kind,
                        dir,
                        obj,
                        span: s,
                    });
                }
                other => {
                    return Err(self.err(format!(
                        "expected `object`, `connect`, `dangling`, or `}}` in template, found {other}"
                    )))
                }
            }
        }
        Ok(TemplateDecl {
            name,
            span,
            objects,
            connects,
            danglings,
        })
    }

    fn file(&mut self) -> Result<Arch, AdlError> {
        self.expect_kw("arch")?;
        let (name, name_span) = self.name("an architecture name")?;
        let mut target = None;
        if matches!(self.peek(), Tok::Ident(k) if k == "targets") {
            self.bump();
            let (family, span) = self.ident("a target family (oma, systolic, gamma)")?;
            let attrs = self.attr_block()?;
            target = Some(TargetDecl {
                family,
                span,
                attrs,
            });
        }
        let mut platform = None;
        if matches!(self.peek(), Tok::Ident(k) if k == "platform") {
            let span = self.bump().span;
            let attrs = self.attr_block()?;
            platform = Some(PlatformDecl { span, attrs });
        }
        let mut items = Vec::new();
        loop {
            match self.peek().clone() {
                Tok::Eof => break,
                Tok::Ident(k) => {
                    let span = self.span();
                    match k.as_str() {
                        "object" => {
                            self.bump();
                            items.push(Item::Object(self.object()?));
                        }
                        "connect" => {
                            self.bump();
                            items.push(Item::Connect(self.connect(span)?));
                        }
                        "param" => {
                            self.bump();
                            let (key, kspan) = self.ident("a parameter key")?;
                            self.expect_kw("in")?;
                            let values = match self.value()? {
                                ValueExpr::List(vs) => vs,
                                other => {
                                    return Err(AdlError::at(
                                        kspan,
                                        format!(
                                            "param values must be a list `[…]`, found {}",
                                            other.kind()
                                        ),
                                    ))
                                }
                            };
                            items.push(Item::Param(ParamDecl {
                                key,
                                span: kspan,
                                values,
                            }));
                        }
                        "template" => {
                            self.bump();
                            items.push(Item::Template(self.template(span)?));
                        }
                        "instance" => {
                            self.bump();
                            let (prefix, _) = self.name("an instance name")?;
                            self.expect_tok(Tok::Colon, "`:`")?;
                            let (template, _) = self.ident("a template name")?;
                            items.push(Item::Instance(InstanceDecl {
                                prefix,
                                template,
                                span,
                            }));
                        }
                        "join" => {
                            self.bump();
                            let a = self.port_ref()?;
                            self.expect_tok(Tok::Arrow, "`->`")?;
                            let b = self.port_ref()?;
                            items.push(Item::Join(JoinDecl { a, b, span }));
                        }
                        "attach" => {
                            self.bump();
                            let port = self.port_ref()?;
                            self.expect_tok(Tok::Arrow, "`->`")?;
                            let (obj, _) = self.name("an object name")?;
                            items.push(Item::Attach(AttachDecl { port, obj, span }));
                        }
                        other => {
                            return Err(self.err(format!(
                                "expected a declaration (object/connect/param/template/instance/join/attach), found `{other}`"
                            )))
                        }
                    }
                }
                other => {
                    return Err(self.err(format!("expected a declaration, found {other}")))
                }
            }
        }
        Ok(Arch {
            name,
            name_span,
            target,
            platform,
            items,
        })
    }
}

/// Parse one `.acadl` source string into its AST.
pub fn parse(src: &str) -> Result<Arch, AdlError> {
    let toks = lex(src)?;
    let mut p = Parser { toks, pos: 0 };
    p.file()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimal_arch() {
        let a = parse("arch \"tiny\"").unwrap();
        assert_eq!(a.name, "tiny");
        assert!(a.target.is_none());
        assert!(a.items.is_empty());
    }

    #[test]
    fn target_and_object_and_connect() {
        let src = r#"
arch "m" targets systolic {
  rows = 2
  cols = 2
}
object "ex0" : ExecuteStage {
  latency = 1
}
object "fu0" : FunctionalUnit {
  ops = [mac, mov]
  latency = "1 + is_mac * 3"
}
connect "ex0" -> "fu0" : CONTAINS
"#;
        let a = parse(src).unwrap();
        let t = a.target.as_ref().unwrap();
        assert_eq!(t.family, "systolic");
        assert_eq!(t.attrs.len(), 2);
        assert_eq!(a.items.len(), 3);
        match &a.items[1] {
            Item::Object(o) => {
                assert_eq!(o.class, "FunctionalUnit");
                assert_eq!(o.attrs.len(), 2);
                assert_eq!(
                    o.attrs[0].value,
                    ValueExpr::List(vec![
                        ValueExpr::Ident("mac".into()),
                        ValueExpr::Ident("mov".into())
                    ])
                );
                assert_eq!(o.attrs[1].value, ValueExpr::Str("1 + is_mac * 3".into()));
            }
            other => panic!("expected object, got {other:?}"),
        }
        match &a.items[2] {
            Item::Connect(c) => {
                assert_eq!((c.src.as_str(), c.dst.as_str()), ("ex0", "fu0"));
                assert_eq!(c.kind, "CONTAINS");
            }
            other => panic!("expected connect, got {other:?}"),
        }
    }

    #[test]
    fn platform_block_after_targets() {
        let src = r#"
arch "quad" targets systolic {
  rows = 2
  cols = 2
}
platform {
  chips = 4
  hop_latency = 4
  microbatches = 8
}
object "ex0" : ExecuteStage {
  latency = 1
}
"#;
        let a = parse(src).unwrap();
        let p = a.platform.as_ref().unwrap();
        assert_eq!(p.attrs.len(), 3);
        assert_eq!(p.attrs[0].key, "chips");
        assert_eq!(p.attrs[0].value, ValueExpr::Int(4));
        assert_eq!(a.items.len(), 1);

        // The block also parses without a targets binding, and its
        // absence stays absent.
        assert!(parse("arch \"p\" platform {\n  chips = 2\n}")
            .unwrap()
            .platform
            .is_some());
        assert!(parse("arch \"p\"").unwrap().platform.is_none());
    }

    #[test]
    fn register_files_and_params() {
        let src = r#"
arch "m" targets oma {
  cache = true
}
param mac_latency in [1, 2, 4]
param cache in [true, false]
param order in [ijk, kij]
object "rf0" : RegisterFile {
  width = 32
  regs {
    "r0" : i32 = 0
    "a" : f32 = 0
    "v[0].0" : vec(128, 8)
  }
}
"#;
        let a = parse(src).unwrap();
        let params: Vec<_> = a
            .items
            .iter()
            .filter_map(|i| match i {
                Item::Param(p) => Some(p),
                _ => None,
            })
            .collect();
        assert_eq!(params.len(), 3);
        assert_eq!(params[0].key, "mac_latency");
        assert_eq!(params[2].values[1], ValueExpr::Ident("kij".into()));
        let obj = a
            .items
            .iter()
            .find_map(|i| match i {
                Item::Object(o) => Some(o),
                _ => None,
            })
            .unwrap();
        assert_eq!(obj.regs.len(), 3);
        assert_eq!(obj.regs[0].ty, RegType::Int { width: 32, init: 0 });
        assert_eq!(obj.regs[1].ty, RegType::F32 { init: 0.0 });
        assert_eq!(
            obj.regs[2].ty,
            RegType::Vec {
                size: 128,
                lanes: 8
            }
        );
        assert_eq!(obj.regs[2].name, "v[0].0");
    }

    #[test]
    fn templates_instances_joins() {
        let src = r#"
arch "pair"
template Pe {
  object "ex" : ExecuteStage {
    latency = 1
  }
  object "fu" : FunctionalUnit {
    ops = [mac]
    latency = 1
  }
  object "rf" : RegisterFile {
    width = 32
    regs {
      "acc" : f32 = 0
    }
  }
  connect "ex" -> "fu" : CONTAINS
  connect "rf" -> "fu" : READ_DATA
  connect "fu" -> "rf" : WRITE_DATA
  dangling "out" : WRITE_DATA from "fu"
  dangling "in" : WRITE_DATA to "rf"
}
instance "a" : Pe
instance "b" : Pe
join "a".out -> "b".in
attach "b".out -> "a.rf"
"#;
        let a = parse(src).unwrap();
        let tpl = a
            .items
            .iter()
            .find_map(|i| match i {
                Item::Template(t) => Some(t),
                _ => None,
            })
            .unwrap();
        assert_eq!(tpl.objects.len(), 3);
        assert_eq!(tpl.connects.len(), 3);
        assert_eq!(tpl.danglings.len(), 2);
        assert_eq!(tpl.danglings[0].dir, DangleDir::From);
        assert_eq!(tpl.danglings[1].dir, DangleDir::To);
        let joins = a
            .items
            .iter()
            .filter(|i| matches!(i, Item::Join(_)))
            .count();
        assert_eq!(joins, 1);
    }

    #[test]
    fn errors_point_at_positions() {
        let e = parse("arch \"x\"\nobject \"a\" ; ExecuteStage {}").unwrap_err();
        // `;` is not even lexable — position on line 2.
        assert_eq!(e.span.unwrap().line, 2);

        let e = parse("arch \"x\"\nfrobnicate \"a\"").unwrap_err();
        assert!(e.to_string().contains("frobnicate"), "{e}");
        assert!(e.to_string().starts_with("2:"), "{e}");
    }

    #[test]
    fn param_requires_list() {
        let e = parse("arch \"x\" param rows in 4").unwrap_err();
        assert!(e.to_string().contains("list"), "{e}");
    }
}
