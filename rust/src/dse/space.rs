//! Candidate enumeration: the (architecture config × tile size × loop
//! order × backend) cross-product a sweep explores.
//!
//! Architecture knobs come from the arch layer's enumeration hooks
//! (`SystolicConfig::enumerate_grids`, `GammaConfig::enumerate_units`,
//! `OmaConfig::enumerate_cache_variants`); mapping knobs (tile, loop
//! order) are only attached to the OMA, the one target whose generator
//! reads them — on the others they would only inflate the sweep with
//! aliases the memo collapses anyway.

use crate::adl::elab::{apply_param, Candidate, ElabArch, ParamAxis};
use crate::arch::gamma::GammaConfig;
use crate::arch::oma::OmaConfig;
use crate::arch::systolic::SystolicConfig;
use crate::coordinator::job::{JobSpec, PlatformSpec, SimModeSpec, TargetSpec, Workload};
use crate::mapping::gemm::LoopOrder;
use crate::sim::backend::BackendKind;

/// The design space of one exploration: a square GeMM workload swept over
/// the model zoo's structural and mapping parameters.
#[derive(Debug, Clone)]
pub struct DseSpace {
    /// GeMM edge (`m = k = n = dim`).
    pub dim: usize,
    /// Systolic arrays up to `max_edge × max_edge` (powers of two).
    pub max_edge: usize,
    /// Γ̈ unit counts up to `max_units` (powers of two).
    pub max_units: usize,
    /// Include the scalar OMA floor (cache on/off × tiles × orders)?
    pub include_oma: bool,
    /// OMA tile sizes (None = untiled).
    pub tiles: Vec<Option<usize>>,
    /// OMA loop orders.
    pub orders: Vec<LoopOrder>,
    /// Timing backends to sweep (identical cycles; different wall time —
    /// the memo serves the second of each pair from cache).
    pub backends: Vec<BackendKind>,
    pub max_cycles: u64,
    /// When set, the sweep additionally evaluates transformer workloads
    /// at this sequence length on every architecture config (without the
    /// OMA's GeMM tile/order knobs — the transformer schedule fixes its
    /// own mapping), so the exploration ranks candidates on a full
    /// attention block, not just a square GeMM.
    pub transformer_seq: Option<usize>,
    /// Transformer model shapes `(layers, heads, decode_steps)` the
    /// sibling sweep crosses with the architecture axes.  `(1, 1, 0)` is
    /// the legacy single-block prefill; shapes with `decode_steps > 0`
    /// price the KV-cached serving loop and fill the report's
    /// prefill/cycles-per-token columns.  Empty falls back to the legacy
    /// shape alone (when `transformer_seq` is set).
    pub transformer_shapes: Vec<(usize, usize, usize)>,
    /// Platform sizes (chip counts) for the platform sibling sweep —
    /// empty disables it.  Each chip count is crossed with every fabric
    /// hop latency in [`Self::platform_hops`] over the systolic grids,
    /// producing the cycles-vs-chips Pareto axis.
    pub platform_chips: Vec<usize>,
    /// Fabric per-hop latencies for the platform sibling sweep.
    pub platform_hops: Vec<u64>,
}

impl DseSpace {
    /// The full sweep (≥ 100 candidates): 2 OMA variants × 4 tiles × 6
    /// orders, every power-of-two array up to 16×16, Γ̈ up to 8 units,
    /// both backends.
    pub fn standard(dim: usize) -> Self {
        DseSpace {
            dim,
            max_edge: 16,
            max_units: 8,
            include_oma: true,
            tiles: vec![None, Some(2), Some(4), Some(8)],
            orders: LoopOrder::ALL.to_vec(),
            backends: vec![BackendKind::CycleStepped, BackendKind::EventDriven],
            max_cycles: 500_000_000,
            transformer_seq: Some(8),
            transformer_shapes: vec![(1, 1, 0), (2, 2, 4)],
            platform_chips: vec![1, 2, 4],
            platform_hops: vec![4],
        }
    }

    /// A tiny space for smoke tests and CI (seconds, not minutes).
    pub fn quick(dim: usize) -> Self {
        DseSpace {
            dim,
            max_edge: 4,
            max_units: 2,
            include_oma: true,
            tiles: vec![None, Some(4)],
            orders: vec![LoopOrder::Ijk, LoopOrder::Kij],
            backends: vec![BackendKind::EventDriven],
            max_cycles: 500_000_000,
            transformer_seq: None,
            transformer_shapes: Vec::new(),
            platform_chips: Vec::new(),
            platform_hops: Vec::new(),
        }
    }

    fn gemm(&self, tile: Option<usize>, order: Option<LoopOrder>) -> Workload {
        Workload::Gemm {
            m: self.dim,
            k: self.dim,
            n: self.dim,
            tile,
            order,
        }
    }

    /// Total candidate count of [`Self::enumerate`], computed without
    /// materializing anything.
    pub fn total(&self) -> u64 {
        let b = self.backends.len() as u64;
        let oma = if self.include_oma {
            (OmaConfig::enumerate_cache_variants().len() * self.tiles.len() * self.orders.len())
                as u64
        } else {
            0
        };
        let sys = SystolicConfig::enumerate_grids(self.max_edge).len() as u64;
        let gam = GammaConfig::enumerate_units(self.max_units).len() as u64;
        (oma + sys + gam) * b
    }

    /// Decode enumeration index `idx` into its candidate — the lazy
    /// counterpart of [`Self::enumerate`] (`spec_at(i)` equals
    /// `enumerate()[i]`, a tested invariant).  `None` past the end.
    ///
    /// The blocks appear in enumeration order: OMA (cache × tile × order
    /// × backend, backend fastest), then systolic grids × backend, then
    /// Γ̈ units × backend.
    pub fn spec_at(&self, idx: u64) -> Option<JobSpec> {
        let nb = self.backends.len() as u64;
        if nb == 0 {
            return None;
        }
        let mut rest = idx;
        let spec = |target: TargetSpec, workload: Workload, backend: BackendKind| JobSpec {
            id: idx,
            target,
            workload,
            mode: SimModeSpec::Timed,
            backend,
            max_cycles: self.max_cycles,
            platform: None,
            deadline_ms: None,
        };
        if self.include_oma {
            let caches = OmaConfig::enumerate_cache_variants();
            let (nt, no) = (self.tiles.len() as u64, self.orders.len() as u64);
            let oma_block = caches.len() as u64 * nt * no * nb;
            if rest < oma_block {
                let backend = self.backends[(rest % nb) as usize];
                let order = self.orders[((rest / nb) % no) as usize];
                let tile = self.tiles[((rest / (nb * no)) % nt) as usize];
                let cache = caches[(rest / (nb * no * nt)) as usize];
                return Some(spec(
                    TargetSpec::Oma {
                        cache,
                        mac_latency: None,
                    },
                    self.gemm(tile, Some(order)),
                    backend,
                ));
            }
            rest -= oma_block;
        }
        let grids = SystolicConfig::enumerate_grids(self.max_edge);
        let sys_block = grids.len() as u64 * nb;
        if rest < sys_block {
            let backend = self.backends[(rest % nb) as usize];
            let (rows, cols) = grids[(rest / nb) as usize];
            return Some(spec(
                TargetSpec::Systolic { rows, cols },
                self.gemm(None, None),
                backend,
            ));
        }
        rest -= sys_block;
        let units = GammaConfig::enumerate_units(self.max_units);
        if rest < units.len() as u64 * nb {
            let backend = self.backends[(rest % nb) as usize];
            let u = units[(rest / nb) as usize];
            return Some(spec(
                TargetSpec::Gamma { units: u },
                self.gemm(None, None),
                backend,
            ));
        }
        None
    }

    /// Every candidate as a timed job spec (ids are enumeration order).
    pub fn enumerate(&self) -> Vec<JobSpec> {
        let mut specs = Vec::new();
        let push = |specs: &mut Vec<JobSpec>,
                        target: TargetSpec,
                        workload: Workload,
                        backend: BackendKind| {
            specs.push(JobSpec {
                id: 0, // assigned below
                target,
                workload,
                mode: SimModeSpec::Timed,
                backend,
                max_cycles: self.max_cycles,
                platform: None,
                deadline_ms: None,
            });
        };
        if self.include_oma {
            for cache in OmaConfig::enumerate_cache_variants() {
                for &tile in &self.tiles {
                    for &order in &self.orders {
                        for &backend in &self.backends {
                            push(
                                &mut specs,
                                TargetSpec::Oma {
                                    cache,
                                    mac_latency: None,
                                },
                                self.gemm(tile, Some(order)),
                                backend,
                            );
                        }
                    }
                }
            }
        }
        for (rows, cols) in SystolicConfig::enumerate_grids(self.max_edge) {
            for &backend in &self.backends {
                push(
                    &mut specs,
                    TargetSpec::Systolic { rows, cols },
                    self.gemm(None, None),
                    backend,
                );
            }
        }
        for units in GammaConfig::enumerate_units(self.max_units) {
            for &backend in &self.backends {
                push(
                    &mut specs,
                    TargetSpec::Gamma { units },
                    self.gemm(None, None),
                    backend,
                );
            }
        }
        for (i, s) in specs.iter_mut().enumerate() {
            s.id = i as u64;
        }
        specs
    }

    /// The transformer candidates: the same architecture axes (minus the
    /// OMA's GeMM-only mapping knobs) over every serving shape in
    /// [`Self::transformer_shapes`] at [`Self::transformer_seq`].  Kept
    /// as a **sibling exploration** rather than folded into
    /// [`Self::enumerate`]: the pruning incumbent is a *cycle* count, so
    /// mixing workloads in one sweep would let the cheaper workload's
    /// best cut the other's candidates.  The same caveat applies *across
    /// shapes* — candidates are emitted shape-contiguous so callers (the
    /// CLI does) can split them into one pruned exploration per shape.
    /// Empty when `transformer_seq` is `None`.
    pub fn enumerate_transformer(&self) -> Vec<JobSpec> {
        let Some(seq) = self.transformer_seq else {
            return Vec::new();
        };
        let legacy = [(1, 1, 0)];
        let shapes: &[(usize, usize, usize)] = if self.transformer_shapes.is_empty() {
            &legacy
        } else {
            &self.transformer_shapes
        };
        let mut specs = Vec::new();
        for &(layers, heads, decode_steps) in shapes {
            let wl = Workload::Transformer { seq, layers, heads, decode_steps };
            let push = |specs: &mut Vec<JobSpec>, target: TargetSpec, backend: BackendKind| {
                specs.push(JobSpec {
                    id: specs.len() as u64,
                    target,
                    workload: wl.clone(),
                    mode: SimModeSpec::Timed,
                    backend,
                    max_cycles: self.max_cycles,
                    platform: None,
                    deadline_ms: None,
                });
            };
            if self.include_oma {
                for cache in OmaConfig::enumerate_cache_variants() {
                    for &backend in &self.backends {
                        push(
                            &mut specs,
                            TargetSpec::Oma {
                                cache,
                                mac_latency: None,
                            },
                            backend,
                        );
                    }
                }
            }
            for (rows, cols) in SystolicConfig::enumerate_grids(self.max_edge) {
                for &backend in &self.backends {
                    push(&mut specs, TargetSpec::Systolic { rows, cols }, backend);
                }
            }
            for units in GammaConfig::enumerate_units(self.max_units) {
                for &backend in &self.backends {
                    push(&mut specs, TargetSpec::Gamma { units }, backend);
                }
            }
        }
        specs
    }

    /// The platform candidates: systolic grids × chip count × fabric hop
    /// latency over the sharded transformer workload, always on the
    /// `ParallelEvent` backend (the partitioned path).  Like
    /// [`Self::enumerate_transformer`], this is a **sibling exploration**
    /// — platform makespans and single-chip cycle counts must never share
    /// a pruning incumbent.  Empty unless `transformer_seq`,
    /// `platform_chips` and `platform_hops` are all populated; these are
    /// the cycles-vs-chips Pareto points `dse` reports.
    pub fn enumerate_platform(&self) -> Vec<JobSpec> {
        let Some(seq) = self.transformer_seq else {
            return Vec::new();
        };
        let mut specs = Vec::new();
        for (rows, cols) in SystolicConfig::enumerate_grids(self.max_edge) {
            for &chips in &self.platform_chips {
                for &hop in &self.platform_hops {
                    specs.push(JobSpec {
                        id: specs.len() as u64,
                        target: TargetSpec::Systolic { rows, cols },
                        workload: Workload::Transformer {
                            seq,
                            layers: 1,
                            heads: 1,
                            decode_steps: 0,
                        },
                        mode: SimModeSpec::Timed,
                        backend: BackendKind::ParallelEvent,
                        max_cycles: self.max_cycles,
                        platform: Some(PlatformSpec {
                            chips,
                            hop_latency: hop,
                            microbatches: 4,
                            threads: 0,
                        }),
                        deadline_ms: None,
                    });
                }
            }
        }
        specs
    }
}

/// A design space defined entirely by an `.acadl` file: the `targets`
/// binding is the base candidate and each `param` axis contributes one
/// dimension of the cross-product (in file order).  This is how a sweep
/// is specified without touching Rust: write the description, declare
/// the axes, run `acadl-cli dse --arch-file <file>`.
#[derive(Debug, Clone)]
pub struct FileSpace {
    pub base: Candidate,
    pub axes: Vec<ParamAxis>,
    /// GeMM edge (`m = k = n = dim`).
    pub dim: usize,
    pub backends: Vec<BackendKind>,
    pub max_cycles: u64,
}

impl FileSpace {
    /// Build the space from an elaborated description.  Errors when the
    /// file has no `targets` binding (nothing to sweep).
    pub fn from_arch(arch: &ElabArch, dim: usize) -> Result<Self, String> {
        let base = arch.base_candidate().ok_or_else(|| {
            format!(
                "architecture `{}` has no `targets` binding — add `targets <family> {{ … }}` \
                 to make it sweepable",
                arch.name
            )
        })?;
        Ok(FileSpace {
            base,
            axes: arch.params.clone(),
            dim,
            backends: vec![BackendKind::EventDriven],
            max_cycles: 500_000_000,
        })
    }

    /// Total candidate count: the axes' cross-product times the backend
    /// count, computed without materializing anything.  Errors only when
    /// the product overflows `u64` (a nonsense space).
    pub fn total(&self) -> Result<u64, String> {
        let mut t = self.backends.len() as u64;
        for axis in &self.axes {
            t = t
                .checked_mul(axis.values.len() as u64)
                .ok_or_else(|| "param cross-product overflows u64".to_string())?;
        }
        Ok(t)
    }

    /// Decode enumeration index `idx` into its candidate by mixed-radix
    /// substitution into the cached base — the lazy counterpart of
    /// [`Self::enumerate`] (`spec_at(i)` equals `enumerate()[i]`, a
    /// tested invariant).  Axis 0 is the most significant digit, the
    /// last axis varies faster, the backend fastest of all — exactly the
    /// order the materialized cross-product used.  `O(axes)` per call:
    /// the `.acadl` file was parsed and elaborated **once**; stamping a
    /// candidate re-applies `param` bindings, never the file.
    pub fn spec_at(&self, idx: u64) -> Result<JobSpec, String> {
        let nb = self.backends.len() as u64;
        if nb == 0 || idx >= self.total()? {
            return Err(format!("candidate index {idx} out of range"));
        }
        let backend = self.backends[(idx % nb) as usize];
        let mut rest = idx / nb;
        let mut c = self.base.clone();
        // Decode least-significant (last axis) first, apply in axis order
        // afterwards so interacting keys behave exactly as before.
        let mut indices = vec![0usize; self.axes.len()];
        for (i, axis) in self.axes.iter().enumerate().rev() {
            let radix = axis.values.len() as u64;
            indices[i] = (rest % radix) as usize;
            rest /= radix;
        }
        for (axis, &ix) in self.axes.iter().zip(&indices) {
            apply_param(&mut c, &axis.key, &axis.values[ix])
                .map_err(|e| format!("param `{}`: {e}", axis.key))?;
        }
        Ok(JobSpec {
            id: idx,
            target: c.target,
            workload: Workload::Gemm {
                m: self.dim,
                k: self.dim,
                n: self.dim,
                tile: c.tile,
                order: c.order,
            },
            mode: SimModeSpec::Timed,
            backend,
            max_cycles: self.max_cycles,
            platform: None,
            deadline_ms: None,
        })
    }

    /// Every candidate of the axes' cross-product as a timed job spec
    /// (ids are enumeration order).  A file with no `param` axes yields
    /// exactly the base candidate.  This is the materialized view of
    /// [`Self::spec_at`] — callers that can stream should use the lazy
    /// decode instead.
    pub fn enumerate(&self) -> Result<Vec<JobSpec>, String> {
        (0..self.total()?).map(|i| self.spec_at(i)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn standard_space_exceeds_hundred_candidates() {
        let space = DseSpace::standard(32);
        let specs = space.enumerate();
        // 2·4·6·2 OMA + 16·2 systolic + 4·2 Γ̈ = 136 GeMM candidates.
        assert!(specs.len() >= 100, "only {} candidates", specs.len());
        assert!(
            specs.iter().all(|s| matches!(s.workload, Workload::Gemm { .. })),
            "the GeMM sweep stays workload-pure (pruning compares cycles)"
        );
        // Ids are unique enumeration order.
        for (i, s) in specs.iter().enumerate() {
            assert_eq!(s.id, i as u64);
        }
        // The sibling transformer sweep covers every arch config once per
        // backend and serving shape: (2 + 16 + 4) · 2 backends · 2 shapes
        // = 88.
        let tf = space.enumerate_transformer();
        assert_eq!(tf.len(), 88);
        assert!(tf
            .iter()
            .all(|s| matches!(s.workload, Workload::Transformer { seq: 8, .. })));
        for (i, s) in tf.iter().enumerate() {
            assert_eq!(s.id, i as u64);
        }
        // The platform sibling sweep: 16 grids × 3 chip counts × 1 hop
        // latency, all on the partitioned parallel backend.
        let pf = space.enumerate_platform();
        assert_eq!(pf.len(), 48);
        for (i, s) in pf.iter().enumerate() {
            assert_eq!(s.id, i as u64);
            assert_eq!(s.backend, BackendKind::ParallelEvent);
            let p = s.platform.expect("platform candidates carry a spec");
            assert!([1, 2, 4].contains(&p.chips));
            assert_eq!(p.hop_latency, 4);
            assert_eq!(p.threads, 0, "threads come from the --jobs budget");
        }
        // The quick space opts out.
        assert!(DseSpace::quick(8).enumerate_transformer().is_empty());
        assert!(DseSpace::quick(8).enumerate_platform().is_empty());
    }

    #[test]
    fn quick_space_is_small_but_covers_all_families() {
        let specs = DseSpace::quick(8).enumerate();
        assert!(specs.len() < 20, "{}", specs.len());
        let has = |f: &dyn Fn(&TargetSpec) -> bool| specs.iter().any(|s| f(&s.target));
        assert!(has(&|t| matches!(t, TargetSpec::Oma { .. })));
        assert!(has(&|t| matches!(t, TargetSpec::Systolic { .. })));
        assert!(has(&|t| matches!(t, TargetSpec::Gamma { .. })));
    }

    #[test]
    fn file_space_enumerates_param_cross_product() {
        let src = r#"
arch "sweep" targets systolic {
  rows = 2
  cols = 2
}
param rows in [2, 4]
param cols in [2, 4, 8]
"#;
        let arch = crate::adl::load_str(src).unwrap();
        let space = FileSpace::from_arch(&arch, 16).unwrap();
        let specs = space.enumerate().unwrap();
        // 2 rows × 3 cols × 1 backend.
        assert_eq!(specs.len(), 6);
        for (i, s) in specs.iter().enumerate() {
            assert_eq!(s.id, i as u64);
            assert!(matches!(s.target, TargetSpec::Systolic { .. }));
        }
        assert_eq!(specs[0].target, TargetSpec::Systolic { rows: 2, cols: 2 });
        assert_eq!(specs[5].target, TargetSpec::Systolic { rows: 4, cols: 8 });

        // A file without params sweeps exactly its base candidate.
        let lone = crate::adl::load_str(
            "arch \"one\" targets gamma {\n  units = 2\n}",
        )
        .unwrap();
        let specs = FileSpace::from_arch(&lone, 8).unwrap().enumerate().unwrap();
        assert_eq!(specs.len(), 1);
        assert_eq!(specs[0].target, TargetSpec::Gamma { units: 2 });

        // No binding: not sweepable.
        let unbound = crate::adl::load_str("arch \"free\"").unwrap();
        assert!(FileSpace::from_arch(&unbound, 8).is_err());
    }

    #[test]
    fn lazy_decode_matches_materialized_enumeration() {
        // Built-in spaces: every index decodes to exactly the spec the
        // materialized enumeration put there.
        for space in [DseSpace::standard(32), DseSpace::quick(8)] {
            let specs = space.enumerate();
            assert_eq!(space.total(), specs.len() as u64);
            for (i, s) in specs.iter().enumerate() {
                assert_eq!(space.spec_at(i as u64).as_ref(), Some(s), "index {i}");
            }
            assert!(space.spec_at(space.total()).is_none());
        }

        // File spaces: same invariant across a multi-axis param block.
        let src = r#"
arch "sweep" targets oma {
  cache = true
}
param cache in [true, false]
param tile in [2, 4, 8]
param order in [ijk, kij]
"#;
        let arch = crate::adl::load_str(src).unwrap();
        let space = FileSpace::from_arch(&arch, 8).unwrap();
        let specs = space.enumerate().unwrap();
        assert_eq!(space.total().unwrap(), specs.len() as u64);
        assert_eq!(specs.len(), 12);
        for (i, s) in specs.iter().enumerate() {
            assert_eq!(&space.spec_at(i as u64).unwrap(), s, "index {i}");
        }
        assert!(space.spec_at(space.total().unwrap()).is_err());
    }

    #[test]
    fn enumeration_hooks_scale_with_limits() {
        assert_eq!(SystolicConfig::enumerate_grids(16).len(), 16);
        assert_eq!(SystolicConfig::enumerate_grids(4).len(), 4);
        assert_eq!(GammaConfig::enumerate_units(8), vec![1, 2, 4, 8]);
        assert_eq!(OmaConfig::enumerate_cache_variants().len(), 2);
    }
}
