//! The streaming exploration engine: lazy enumeration → windowed
//! analytical pre-filter → wave-parallel simulation → bounded-memory
//! Pareto maintenance, with optional checkpoint/resume.
//!
//! Memory is `O(window + frontier + samples)`, never `O(space)`:
//! candidates are *pulled* from a [`CandidateSource`] one lookahead
//! window at a time, each window is sorted cheapest-bound-first and fed
//! to the coordinator pool in waves, and evaluated points flow into a
//! running Pareto frontier plus a deterministically thinned reservoir of
//! non-frontier samples.  A million-candidate sweep therefore holds a
//! few thousand candidates at its peak — `DseStats::peak_resident`
//! measures exactly that.
//!
//! # Pre-filter soundness
//!
//! Three prune predicates, all applied **before** a machine is built:
//!
//! * **Infeasibility** (`JobSpec::infeasible`, any [`PruneMode`] except
//!   `Off`): the operand set exceeds the target's data-memory capacity,
//!   or the sound cycle lower bound exceeds the budget.  `execute_on`
//!   rejects on *exactly the same predicate*, so an exhaustive run turns
//!   these candidates into error rows — which never join the frontier —
//!   and pruning them changes nothing.
//! * **Incumbent bound** ([`PruneMode::Cycles`]): cut when the sound
//!   lower bound exceeds the best simulated cycles so far.  Such a
//!   candidate can never be cycle-optimal, so the reported optimum is
//!   preserved (the frontier then spans evaluated candidates only — the
//!   summary says so).
//! * **Domination** ([`PruneMode::Frontier`]): cut when some evaluated
//!   point already weakly dominates the candidate's `(bound, area)`.
//!   Since true cycles ≥ bound, the candidate is weakly dominated by an
//!   evaluated point, and by transitivity of `≤` the *exact* frontier
//!   pair set is preserved (see DESIGN.md "Scaling DSE" for the
//!   argument; the property tests enforce it).
//!
//! # Checkpoints
//!
//! With a [`CheckpointCfg`], sweep state (cursor, incumbent, frontier,
//! reservoir, thinning stride, counters) is serialized after any window
//! that crosses the `every` threshold, atomically (tmp + rename).  The
//! engine only stops at window boundaries, so a resumed run pulls the
//! same windows the uninterrupted run would have — evaluated sets and
//! cycle results are identical; only memo-served `src` flags can differ
//! (the memo is not checkpointed; losing it costs re-simulation, never
//! correctness).

use std::collections::{HashMap, HashSet};
use std::time::Instant;

use crate::adl::elab::{apply_param, ParamValue};
use crate::coordinator::job::{JobResult, JobSpec};
use crate::coordinator::pool;
use crate::dse::checkpoint::{Checkpoint, CheckpointCfg};
use crate::dse::memo::{Memo, DEFAULT_MEMO_CAPACITY};
use crate::dse::space::{DseSpace, FileSpace};
use crate::dse::{pareto_frontier, DsePoint, DseReport, DseStats};
use crate::util::hash::fnv1a_str;

/// Default lookahead window: enough that every built-in space fits in
/// one window (reproducing the old global bound-sort exactly), small
/// enough that a million-candidate sweep stays flat.
pub const DEFAULT_WINDOW: usize = 2048;

/// What the analytical pre-filter is allowed to cut.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PruneMode {
    /// Evaluate everything — the validation baseline the property tests
    /// compare against.
    Off,
    /// Infeasibility + incumbent-cycle pruning.  Preserves the reported
    /// **optimum**; the frontier spans evaluated candidates only.
    Cycles,
    /// Infeasibility + domination pruning against the running frontier.
    /// Preserves the **exact Pareto frontier pair set** (and therefore
    /// the optimum).
    Frontier,
}

/// Streaming-engine knobs.
#[derive(Debug, Clone)]
pub struct DseConfig {
    pub workers: usize,
    pub prune: PruneMode,
    /// Candidates pulled and bound-sorted at a time.
    pub window: usize,
    /// LRU retention bound of the cross-wave result memo.
    pub memo_capacity: usize,
    /// Maximum non-frontier points retained for the report table
    /// (`usize::MAX` keeps everything — the in-process API default).
    pub keep_points: usize,
    pub checkpoint: Option<CheckpointCfg>,
    /// Stop at the first window boundary after this many candidates have
    /// been processed *this run* (writing a checkpoint when configured) —
    /// deterministic mid-sweep interruption for tests, CI, and sharded
    /// sweeps.
    pub stop_after: Option<u64>,
}

impl DseConfig {
    pub fn new(workers: usize) -> Self {
        DseConfig {
            workers,
            prune: PruneMode::Cycles,
            window: DEFAULT_WINDOW,
            memo_capacity: DEFAULT_MEMO_CAPACITY,
            keep_points: usize::MAX,
            checkpoint: None,
            stop_after: None,
        }
    }

    /// The configuration behind the legacy `explore(.., prune: bool)`
    /// entry points.
    pub fn legacy(workers: usize, prune: bool) -> Self {
        DseConfig {
            prune: if prune { PruneMode::Cycles } else { PruneMode::Off },
            ..DseConfig::new(workers)
        }
    }
}

/// Per-window prune/evaluation accounting (the "wave" a report row
/// groups by: one lookahead window = one scheduling wave of the sweep).
#[derive(Debug, Clone, Default)]
pub struct WaveStats {
    pub index: usize,
    /// Enumeration-id range pulled into this window (inclusive).
    pub first_id: u64,
    pub last_id: u64,
    pub pulled: usize,
    pub evaluated: usize,
    pub pruned_infeasible: usize,
    pub pruned_bound: usize,
    pub pruned_dominated: usize,
    pub simulated: usize,
    pub cache_hits: usize,
}

/// A lazily enumerable candidate space.  Implementations yield specs in
/// a **deterministic enumeration order** with `id` equal to the
/// enumeration index — that is what makes cursors checkpointable.
pub trait CandidateSource {
    /// Total candidates, when cheaply known (reporting only).
    fn len_hint(&self) -> Option<u64>;
    /// The next candidate, or `None` when the space is exhausted.
    fn next_spec(&mut self) -> Option<JobSpec>;
    /// Position the source so the next yielded candidate has id
    /// `cursor` (a no-op past the end).
    fn seek(&mut self, cursor: u64);
    /// Stable identity of the space: a checkpoint written against one
    /// source refuses to resume against a different one.
    fn signature(&self) -> u64;
}

/// An already-materialized candidate list (the legacy `explore_specs`
/// path and hand-built sweeps).  Seeking treats the cursor as an index,
/// which coincides with ids for every in-tree producer.
pub struct VecSource {
    specs: Vec<JobSpec>,
    pos: usize,
}

impl VecSource {
    pub fn new(specs: Vec<JobSpec>) -> Self {
        VecSource { specs, pos: 0 }
    }
}

impl CandidateSource for VecSource {
    fn len_hint(&self) -> Option<u64> {
        Some(self.specs.len() as u64)
    }

    fn next_spec(&mut self) -> Option<JobSpec> {
        let s = self.specs.get(self.pos).cloned();
        if s.is_some() {
            self.pos += 1;
        }
        s
    }

    fn seek(&mut self, cursor: u64) {
        self.pos = (cursor as usize).min(self.specs.len());
    }

    fn signature(&self) -> u64 {
        let mut repr = String::from("dse-vec:");
        for s in &self.specs {
            repr.push_str(&s.to_json().to_string());
            repr.push(';');
        }
        fnv1a_str(&repr)
    }
}

/// Lazy enumeration of a built-in [`DseSpace`] via its index decode —
/// `O(1)` memory and `O(1)` seek.
pub struct SpaceSource {
    space: DseSpace,
    cursor: u64,
    total: u64,
}

impl SpaceSource {
    pub fn new(space: &DseSpace) -> Self {
        SpaceSource {
            space: space.clone(),
            cursor: 0,
            total: space.total(),
        }
    }
}

impl CandidateSource for SpaceSource {
    fn len_hint(&self) -> Option<u64> {
        Some(self.total)
    }

    fn next_spec(&mut self) -> Option<JobSpec> {
        let s = self.space.spec_at(self.cursor);
        if s.is_some() {
            self.cursor += 1;
        }
        s
    }

    fn seek(&mut self, cursor: u64) {
        self.cursor = cursor.min(self.total);
    }

    fn signature(&self) -> u64 {
        let s = &self.space;
        let orders: Vec<&str> = s.orders.iter().map(|o| o.name()).collect();
        let backends: Vec<&str> = s.backends.iter().map(|b| b.name()).collect();
        fnv1a_str(&format!(
            "dse-space:dim={},max_edge={},max_units={},oma={},tiles={:?},orders={:?},\
             backends={:?},max_cycles={}",
            s.dim, s.max_edge, s.max_units, s.include_oma, s.tiles, orders, backends, s.max_cycles
        ))
    }
}

fn param_value_repr(v: &ParamValue) -> String {
    match v {
        ParamValue::Int(i) => i.to_string(),
        ParamValue::Bool(b) => b.to_string(),
        ParamValue::Name(n) => n.clone(),
    }
}

/// Lazy enumeration of a `.acadl` `param` cross-product: the file is
/// parsed and elaborated **once** (into the [`FileSpace`]'s base
/// candidate + axes); each candidate is stamped out by mixed-radix
/// substitution — `O(axes)` per pull, `O(1)` seek, no re-parse.
pub struct FileSource {
    space: FileSpace,
    cursor: u64,
    total: u64,
}

impl FileSource {
    /// Validates every axis value against the base target family up
    /// front, so streaming never trips over a bad `param` mid-sweep
    /// (`apply_param` only inspects the key, the value, and the family —
    /// and the family never changes — so per-value validation against
    /// the base is exhaustive).
    pub fn new(space: &FileSpace) -> Result<Self, String> {
        let total = space.total()?;
        for axis in &space.axes {
            for v in &axis.values {
                let mut probe = space.base.clone();
                apply_param(&mut probe, &axis.key, v)
                    .map_err(|e| format!("param `{}`: {e}", axis.key))?;
            }
        }
        Ok(FileSource {
            space: space.clone(),
            cursor: 0,
            total,
        })
    }
}

impl CandidateSource for FileSource {
    fn len_hint(&self) -> Option<u64> {
        Some(self.total)
    }

    fn next_spec(&mut self) -> Option<JobSpec> {
        if self.cursor >= self.total {
            return None;
        }
        let s = self
            .space
            .spec_at(self.cursor)
            .expect("axes validated at FileSource construction");
        self.cursor += 1;
        Some(s)
    }

    fn seek(&mut self, cursor: u64) {
        self.cursor = cursor.min(self.total);
    }

    fn signature(&self) -> u64 {
        let s = &self.space;
        let axes: Vec<String> = s
            .axes
            .iter()
            .map(|a| {
                let vals: Vec<String> = a.values.iter().map(param_value_repr).collect();
                format!("{}={}", a.key, vals.join("|"))
            })
            .collect();
        let backends: Vec<&str> = s.backends.iter().map(|b| b.name()).collect();
        fnv1a_str(&format!(
            "dse-file:base={},tile={:?},order={:?},axes={:?},dim={},backends={:?},max_cycles={}",
            s.base.target.to_json(),
            s.base.tile,
            s.base.order.map(|o| o.name()),
            axes,
            s.dim,
            backends,
            s.max_cycles
        ))
    }
}

/// Does any frontier point weakly dominate a candidate whose cycles are
/// at least `lb` and whose area is `area`?
fn dominated_by_frontier(frontier: &[DsePoint], lb: u64, area: f64) -> bool {
    frontier
        .iter()
        .any(|f| f.result.error.is_none() && f.result.cycles <= lb && f.result.area_proxy <= area)
}

/// Deterministic reservoir thinning: a point is retained iff its
/// enumeration id is a multiple of the current stride; when the
/// reservoir overflows `keep`, the stride doubles and the reservoir is
/// re-filtered.  No RNG — the retained set depends only on ids, `keep`,
/// and the processing order, so a resumed sweep (which restores the
/// stride) reproduces it.
fn thin_into(samples: &mut Vec<DsePoint>, p: DsePoint, stride: &mut u64, keep: usize) {
    if keep == 0 || p.spec.id % *stride != 0 {
        return;
    }
    samples.push(p);
    while samples.len() > keep {
        *stride = stride.saturating_mul(2);
        samples.retain(|q| q.spec.id % *stride == 0);
    }
}

/// Fold an evaluated point into the running frontier/reservoir.
/// Error-free points join the frontier when no member weakly dominates
/// them (displacing members they dominate into the reservoir); everything
/// else is thinned into the reservoir.
fn admit_point(
    p: DsePoint,
    frontier: &mut Vec<DsePoint>,
    samples: &mut Vec<DsePoint>,
    stride: &mut u64,
    keep: usize,
) {
    if p.result.error.is_none() {
        let (cy, ar) = (p.result.cycles, p.result.area_proxy);
        let dominated = frontier
            .iter()
            .any(|f| f.result.cycles <= cy && f.result.area_proxy <= ar);
        if !dominated {
            let mut kept = Vec::with_capacity(frontier.len() + 1);
            for f in frontier.drain(..) {
                if cy <= f.result.cycles && ar <= f.result.area_proxy {
                    thin_into(samples, f, stride, keep);
                } else {
                    kept.push(f);
                }
            }
            *frontier = kept;
            frontier.push(p);
            return;
        }
    }
    thin_into(samples, p, stride, keep);
}

/// Run the streaming exploration over `source`.  `resume` continues from
/// a [`Checkpoint`] (validated against the source's signature).  Errors
/// only on a signature mismatch or a failed checkpoint write.
pub fn explore_source(
    source: &mut dyn CandidateSource,
    cfg: &DseConfig,
    resume: Option<Checkpoint>,
) -> Result<DseReport, String> {
    let t0 = Instant::now();
    let sig = source.signature();

    let mut frontier: Vec<DsePoint> = Vec::new();
    let mut samples: Vec<DsePoint> = Vec::new();
    let mut stride: u64 = 1;
    let mut best = u64::MAX;
    let mut best_target = String::new();
    let mut cursor: u64 = 0;
    let mut restored = 0usize;
    let mut evaluated = 0usize;
    let mut pruned_infeasible = 0usize;
    let mut pruned_bound = 0usize;
    let mut pruned_dominated = 0usize;
    let mut simulated = 0usize;
    let mut cache_hits = 0usize;
    let mut failed = 0usize;
    let mut waves: Vec<WaveStats> = Vec::new();

    if let Some(ck) = resume {
        if ck.signature != sig {
            return Err(format!(
                "checkpoint signature {:#018x} does not match this space ({sig:#018x}) — \
                 it was written by a different sweep",
                ck.signature
            ));
        }
        cursor = ck.cursor;
        stride = ck.stride.max(1);
        best = ck.best_cycles;
        best_target = ck.best_target;
        evaluated = ck.evaluated as usize;
        pruned_infeasible = ck.pruned_infeasible as usize;
        pruned_bound = ck.pruned_bound as usize;
        pruned_dominated = ck.pruned_dominated as usize;
        simulated = ck.simulated as usize;
        cache_hits = ck.cache_hits as usize;
        failed = ck.failed as usize;
        restored = ck.frontier.len() + ck.samples.len();
        frontier = ck.frontier;
        samples = ck.samples;
        source.seek(cursor);
    }

    let mut memo = Memo::with_capacity(cfg.memo_capacity);
    let wave_len = (cfg.workers.max(1) * 2).max(8);
    let window = cfg.window.max(1);
    let mut processed_this_run: u64 = 0;
    let mut since_checkpoint: u64 = 0;
    let mut peak_resident = frontier.len() + samples.len();
    // Cooperative cancellation (deadline / disconnect / shutdown): fetched
    // once; `None` costs one branch per window.  The engine only ever
    // stops at window boundaries (that is what makes cursors resumable),
    // so a trip observed *mid*-window rolls the sweep state back to the
    // boundary snapshot below — the cancelled window's partial results
    // (error rows from cancelled jobs) never reach the report or the
    // checkpoint.
    let cancel_token = crate::util::cancel::current();
    let mut cancelled = false;

    let write_checkpoint = |path: &str,
                            cursor: u64,
                            stride: u64,
                            best: u64,
                            best_target: &str,
                            frontier: &[DsePoint],
                            samples: &[DsePoint],
                            counters: &[usize; 7]|
     -> Result<(), String> {
        Checkpoint {
            version: Checkpoint::VERSION,
            signature: sig,
            cursor,
            stride,
            best_cycles: best,
            best_target: best_target.to_string(),
            evaluated: counters[0] as u64,
            pruned_infeasible: counters[1] as u64,
            pruned_bound: counters[2] as u64,
            pruned_dominated: counters[3] as u64,
            simulated: counters[4] as u64,
            cache_hits: counters[5] as u64,
            failed: counters[6] as u64,
            frontier: frontier.to_vec(),
            samples: samples.to_vec(),
        }
        .save(path)
    };

    loop {
        if cancel_token.as_ref().is_some_and(|t| t.cause().is_some()) {
            cancelled = true;
            break; // at a window boundary: state is checkpoint-consistent
        }
        // Boundary snapshot for mid-window cancellation rollback (taken
        // only when a token exists — the uncancellable path stays
        // allocation-free).
        let boundary = cancel_token.as_ref().map(|_| {
            (
                cursor,
                stride,
                best,
                best_target.clone(),
                frontier.clone(),
                samples.clone(),
                [
                    evaluated,
                    pruned_infeasible,
                    pruned_bound,
                    pruned_dominated,
                    simulated,
                    cache_hits,
                    failed,
                ],
                waves.len(),
                processed_this_run,
                since_checkpoint,
            )
        });
        // Pull one lookahead window (bounded: this buffer and the
        // frontier/reservoir are the only per-sweep state).
        let mut buf: Vec<(JobSpec, u64)> = Vec::with_capacity(window.min(4096));
        let first_id = cursor;
        while buf.len() < window {
            match source.next_spec() {
                Some(s) => {
                    let lb = s.lower_bound_cycles();
                    buf.push((s, lb));
                }
                None => break,
            }
        }
        if buf.is_empty() {
            break;
        }
        cursor += buf.len() as u64;
        peak_resident = peak_resident.max(buf.len() + frontier.len() + samples.len());

        // Cheapest bound first: the most promising candidates simulate
        // first and the prunable tail is cut without machine contact.
        buf.sort_by_key(|(s, lb)| (*lb, s.id));

        let mut ws = WaveStats {
            index: waves.len(),
            first_id,
            last_id: cursor - 1,
            pulled: buf.len(),
            ..Default::default()
        };

        let mut i = 0;
        while i < buf.len() {
            // Assemble the next wave, pruning against the *current*
            // incumbent/frontier as we go (both only improve, so a cut
            // decided here would also be cut later).
            let mut wave: Vec<(JobSpec, u64)> = Vec::with_capacity(wave_len);
            while i < buf.len() && wave.len() < wave_len {
                let (s, lb) = &buf[i];
                i += 1;
                let cut = match cfg.prune {
                    PruneMode::Off => None,
                    PruneMode::Cycles | PruneMode::Frontier => {
                        if s.infeasible().is_some() {
                            Some(&mut ws.pruned_infeasible)
                        } else if cfg.prune == PruneMode::Cycles && *lb > best {
                            Some(&mut ws.pruned_bound)
                        } else if cfg.prune == PruneMode::Frontier
                            && dominated_by_frontier(&frontier, *lb, s.target.area_proxy())
                        {
                            Some(&mut ws.pruned_dominated)
                        } else {
                            None
                        }
                    }
                };
                match cut {
                    Some(counter) => *counter += 1,
                    None => wave.push((s.clone(), *lb)),
                }
            }
            if wave.is_empty() {
                continue;
            }

            // One representative simulation per canonical key; everything
            // else is served from the wave's own results or the memo.
            let mut to_run: Vec<JobSpec> = Vec::new();
            let mut scheduled: HashSet<u64> = HashSet::new();
            let mut id_to_key: HashMap<u64, u64> = HashMap::new();
            for (spec, _) in &wave {
                let key = spec.canonical_key();
                if memo.contains(key) || !scheduled.insert(key) {
                    continue;
                }
                id_to_key.insert(spec.id, key);
                to_run.push(spec.clone());
            }
            let ran_ids: HashSet<u64> = to_run.iter().map(|s| s.id).collect();
            // The wave's results live in this map for the wave's own
            // aliases: the memo is a *bounded* cross-wave cache and may
            // evict under pressure, but a wave must always see its own
            // simulations.
            let mut fresh: HashMap<u64, JobResult> = HashMap::new();
            for r in pool::run_jobs(to_run, cfg.workers) {
                let key = id_to_key[&r.id];
                memo.insert(key, r.clone());
                fresh.insert(key, r);
            }

            for (spec, lb) in wave {
                let key = spec.canonical_key();
                // The miss arm is unreachable while the pool returns one
                // result per spec — but a degraded pool must still yield
                // an *accounted-for* error point, or
                // `evaluated + pruned == candidates` breaks.
                let mut result = fresh
                    .get(&key)
                    .cloned()
                    .or_else(|| memo.get(key).cloned())
                    .unwrap_or_else(|| JobResult {
                        id: spec.id,
                        target: spec.target.describe(),
                        workload: spec.workload.describe(),
                        mode: spec.mode,
                        cycles: 0,
                        instructions: 0,
                        ipc: 0.0,
                        utilization: 0.0,
                        numerics_ok: None,
                        wall_micros: 0,
                        error: Some("worker pool returned no result for this job".into()),
                        area_proxy: spec.target.area_proxy(),
                        prefill_cycles: None,
                        cycles_per_token: None,
                    });
                let cached = !ran_ids.contains(&spec.id);
                if cached {
                    memo.note_hit();
                    ws.cache_hits += 1;
                } else {
                    memo.note_miss();
                    ws.simulated += 1;
                }
                result.id = spec.id;
                if result.error.is_none() && result.cycles > 0 && result.cycles < best {
                    best = result.cycles;
                    best_target = result.target.clone();
                }
                if result.error.is_some() {
                    failed += 1;
                }
                ws.evaluated += 1;
                admit_point(
                    DsePoint {
                        spec,
                        lower_bound: lb,
                        result,
                        cached,
                    },
                    &mut frontier,
                    &mut samples,
                    &mut stride,
                    cfg.keep_points,
                );
                peak_resident = peak_resident.max(frontier.len() + samples.len());
            }
        }

        evaluated += ws.evaluated;
        pruned_infeasible += ws.pruned_infeasible;
        pruned_bound += ws.pruned_bound;
        pruned_dominated += ws.pruned_dominated;
        simulated += ws.simulated;
        cache_hits += ws.cache_hits;
        processed_this_run += ws.pulled as u64;
        since_checkpoint += ws.pulled as u64;
        waves.push(ws);

        if cancel_token.as_ref().is_some_and(|t| t.cause().is_some()) {
            // Tripped mid-window: the window just processed contains
            // cancelled-job error rows that a resumed run would wrongly
            // treat as evaluated.  Roll back to the boundary snapshot so
            // the report and the final checkpoint cover complete windows
            // only.
            if let Some((c, st, b, bt, fr, sa, ctr, nw, run, since)) = boundary {
                cursor = c;
                stride = st;
                best = b;
                best_target = bt;
                frontier = fr;
                samples = sa;
                [
                    evaluated,
                    pruned_infeasible,
                    pruned_bound,
                    pruned_dominated,
                    simulated,
                    cache_hits,
                    failed,
                ] = ctr;
                waves.truncate(nw);
                processed_this_run = run;
                since_checkpoint = since;
            }
            cancelled = true;
            break;
        }

        let stopping = cfg.stop_after.is_some_and(|limit| processed_this_run >= limit);
        if let Some(ck) = &cfg.checkpoint {
            if since_checkpoint >= ck.every || stopping {
                write_checkpoint(
                    &ck.path,
                    cursor,
                    stride,
                    best,
                    &best_target,
                    &frontier,
                    &samples,
                    &[
                        evaluated,
                        pruned_infeasible,
                        pruned_bound,
                        pruned_dominated,
                        simulated,
                        cache_hits,
                        failed,
                    ],
                )?;
                since_checkpoint = 0;
            }
        }
        if stopping {
            break;
        }
    }

    // Final checkpoint: lets downstream tooling read the finished
    // frontier without parsing the report, and makes `--resume` of a
    // completed sweep a cheap no-op.
    if let Some(ck) = &cfg.checkpoint {
        write_checkpoint(
            &ck.path,
            cursor,
            stride,
            best,
            &best_target,
            &frontier,
            &samples,
            &[
                evaluated,
                pruned_infeasible,
                pruned_bound,
                pruned_dominated,
                simulated,
                cache_hits,
                failed,
            ],
        )?;
    }

    let mut points: Vec<DsePoint> = frontier.into_iter().chain(samples).collect();
    points.sort_by(|a, b| {
        (a.result.cycles, a.result.area_proxy as u64, a.spec.id).cmp(&(
            b.result.cycles,
            b.result.area_proxy as u64,
            b.spec.id,
        ))
    });
    let frontier_idx = pareto_frontier(&points);
    Ok(DseReport {
        stats: DseStats {
            candidates: cursor as usize,
            evaluated,
            pruned: pruned_infeasible + pruned_bound + pruned_dominated,
            pruned_infeasible,
            pruned_bound,
            pruned_dominated,
            simulated,
            cache_hits,
            failed,
            best_cycles: best,
            best_target,
            wall: t0.elapsed(),
            memo_entries: memo.len(),
            memo_capacity: memo.capacity(),
            memo_evictions: memo.evictions(),
            peak_resident,
            restored,
            cancelled,
        },
        points,
        frontier: frontier_idx,
        waves,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::backend::BackendKind;

    #[test]
    fn space_source_streams_the_materialized_enumeration() {
        let space = DseSpace::quick(6);
        let mut src = SpaceSource::new(&space);
        let specs = space.enumerate();
        assert_eq!(src.len_hint(), Some(specs.len() as u64));
        let mut streamed = Vec::new();
        while let Some(s) = src.next_spec() {
            streamed.push(s);
        }
        assert_eq!(streamed, specs);
        // Seek replays a suffix.
        src.seek(3);
        assert_eq!(src.next_spec().unwrap(), specs[3]);
        // Distinct spaces have distinct signatures.
        let other = SpaceSource::new(&DseSpace::quick(8));
        assert_ne!(SpaceSource::new(&space).signature(), other.signature());
    }

    #[test]
    fn file_source_streams_the_param_cross_product() {
        let src_text = "arch \"sweep\" targets systolic {\n  rows = 2\n  cols = 2\n}\n\
                        param rows in [2, 4]\nparam cols in [2, 4, 8]\n";
        let arch = crate::adl::load_str(src_text).unwrap();
        let space = FileSpace::from_arch(&arch, 16).unwrap();
        let mut src = FileSource::new(&space).unwrap();
        let specs = space.enumerate().unwrap();
        let mut streamed = Vec::new();
        while let Some(s) = src.next_spec() {
            streamed.push(s);
        }
        assert_eq!(streamed, specs);
        src.seek(4);
        assert_eq!(src.next_spec().unwrap(), specs[4]);
        assert!(src.next_spec().is_some());
        assert!(src.next_spec().is_none());
    }

    #[test]
    fn streaming_with_tiny_windows_matches_one_shot_exploration() {
        // Same space, window 1 vs window ≫ space, pruning off: identical
        // evaluated sets and identical frontier pairs.
        let mut space = DseSpace::quick(6);
        space.backends = vec![BackendKind::EventDriven];
        let mut one_shot_cfg = DseConfig::legacy(2, false);
        one_shot_cfg.window = 4096;
        let one_shot =
            explore_source(&mut SpaceSource::new(&space), &one_shot_cfg, None).unwrap();
        let mut tiny_cfg = DseConfig::legacy(2, false);
        tiny_cfg.window = 1;
        let tiny = explore_source(&mut SpaceSource::new(&space), &tiny_cfg, None).unwrap();
        assert_eq!(one_shot.stats.candidates, tiny.stats.candidates);
        assert_eq!(one_shot.stats.evaluated, tiny.stats.evaluated);
        assert_eq!(one_shot.stats.best_cycles, tiny.stats.best_cycles);
        let pairs = |r: &DseReport| {
            let mut v: Vec<(u64, u64)> = r
                .frontier
                .iter()
                .map(|&i| {
                    (
                        r.points[i].result.cycles,
                        r.points[i].result.area_proxy as u64,
                    )
                })
                .collect();
            v.sort();
            v.dedup();
            v
        };
        assert_eq!(pairs(&one_shot), pairs(&tiny));
        // Multi-window runs record one WaveStats per window.
        assert_eq!(tiny.waves.len(), tiny.stats.candidates);
        assert_eq!(one_shot.waves.len(), 1);
    }

    #[test]
    fn cancelled_token_stops_the_sweep_and_reruns_are_unaffected() {
        let mut space = DseSpace::quick(6);
        space.backends = vec![BackendKind::EventDriven];
        let cfg = DseConfig::legacy(2, false);
        let clean = explore_source(&mut SpaceSource::new(&space), &cfg, None).unwrap();
        assert!(!clean.stats.cancelled);

        // An already-cancelled token stops the sweep before it pulls
        // anything.
        let token = crate::util::cancel::CancelToken::new();
        token.cancel();
        let guard = crate::util::cancel::install(token);
        let stopped = explore_source(&mut SpaceSource::new(&space), &cfg, None).unwrap();
        drop(guard);
        assert!(stopped.stats.cancelled, "{}", stopped.summary());
        assert_eq!(stopped.stats.candidates, 0);
        assert_eq!(stopped.stats.evaluated, 0);
        assert!(stopped.waves.is_empty());

        // Once the guard is gone the engine is back to normal: a rerun
        // reproduces the clean reference exactly.
        let rerun = explore_source(&mut SpaceSource::new(&space), &cfg, None).unwrap();
        assert!(!rerun.stats.cancelled);
        assert_eq!(rerun.stats.evaluated, clean.stats.evaluated);
        assert_eq!(rerun.stats.best_cycles, clean.stats.best_cycles);
    }

    #[test]
    fn deadline_mid_window_rolls_back_to_the_boundary() {
        // Chaos stall jobs hold their slot until the deadline token
        // trips, guaranteeing the trip lands *mid*-window — the rollback
        // path must leave the report as if the window never started.
        std::env::set_var("ACADL_CHAOS", "1");
        use crate::coordinator::job::{SimModeSpec, TargetSpec, Workload, CHAOS_STALL_MARK};
        let spec = |i: u64| JobSpec {
            id: CHAOS_STALL_MARK | i,
            target: TargetSpec::Systolic { rows: 2, cols: 2 },
            workload: Workload::Gemm {
                m: 4,
                k: 4,
                n: 4,
                tile: None,
                order: None,
            },
            mode: SimModeSpec::Timed,
            backend: BackendKind::EventDriven,
            max_cycles: 10_000_000,
            platform: None,
            deadline_ms: None,
        };
        let specs: Vec<JobSpec> = (0..4).map(spec).collect();
        let token = crate::util::cancel::CancelToken::with_deadline(
            std::time::Duration::from_millis(50),
        );
        let _guard = crate::util::cancel::install(token);
        let rep =
            explore_source(&mut VecSource::new(specs), &DseConfig::legacy(2, false), None)
                .unwrap();
        assert!(rep.stats.cancelled, "{}", rep.summary());
        assert_eq!(rep.stats.candidates, 0, "rollback to the window boundary");
        assert_eq!(rep.stats.evaluated, 0);
        assert!(rep.waves.is_empty());
        assert!(rep.points.is_empty());
    }

    #[test]
    fn reservoir_thinning_is_deterministic_and_bounded() {
        let mut samples = Vec::new();
        let mut stride = 1u64;
        let point = |id: u64| DsePoint {
            spec: DseSpace::quick(6).spec_at(0).unwrap(),
            lower_bound: 1,
            result: JobResult {
                id,
                target: "t".into(),
                workload: "w".into(),
                mode: crate::coordinator::job::SimModeSpec::Timed,
                cycles: id + 1,
                instructions: 0,
                ipc: 0.0,
                utilization: 0.0,
                numerics_ok: None,
                wall_micros: 0,
                error: None,
                area_proxy: 1.0,
                prefill_cycles: None,
                cycles_per_token: None,
            },
            cached: false,
        };
        for id in 0..1000u64 {
            let mut p = point(id);
            p.spec.id = id;
            thin_into(&mut samples, p, &mut stride, 16);
        }
        assert!(samples.len() <= 16);
        assert!(stride > 1, "thinning must have engaged");
        // Retained ids are exactly the stride multiples that survived.
        assert!(samples.iter().all(|p| p.spec.id % stride == 0));
    }
}
