//! Design-space exploration (§7's "optimization loop of hardware-aware
//! NAS and DNN/HW Co-Design"): enumerate → prune → simulate → frontier.
//!
//! The pipeline is **streaming** ([`stream::explore_source`]): candidates
//! are pulled lazily from a [`stream::CandidateSource`] one lookahead
//! window at a time, so a million-candidate sweep holds `O(window +
//! frontier + reservoir)` state, never the whole space.
//!
//! 1. **Enumerate lazily** ([`space::DseSpace::spec_at`],
//!    [`space::FileSpace::spec_at`]): each candidate is decoded from its
//!    enumeration index on demand.  File-driven spaces parse and
//!    elaborate the `.acadl` description **once** and stamp out
//!    candidates by `param` substitution.
//! 2. **Pre-filter** each candidate analytically before any machine is
//!    built: infeasible candidates (operands over data-memory capacity,
//!    bound over budget — the same predicate `execute_on` rejects) are
//!    cut in every [`stream::PruneMode`] except `Off`; `Cycles` also
//!    cuts candidates whose sound cycle lower bound exceeds the best
//!    simulated cycles (optimum-preserving); `Frontier` instead cuts
//!    candidates weakly dominated by an evaluated point
//!    (frontier-preserving).  Every cut is accounted per wave in
//!    [`DseReport::waves`].
//! 3. **Evaluate** each surviving wave in parallel on the coordinator
//!    pool (which shares cached machines), **memoizing** results in a
//!    bounded LRU ([`memo::Memo`]) keyed by the canonical job-spec hash,
//!    so aliased candidates (second backend, tile/order on targets that
//!    ignore them) cost nothing.
//! 4. **Maintain** the running cycles-vs-area Pareto frontier plus a
//!    deterministically thinned reservoir of non-frontier samples, and
//!    optionally **checkpoint** the sweep state to JSON
//!    ([`checkpoint::Checkpoint`]) so `dse --resume <file>` continues an
//!    interrupted sweep.
//!
//! # CLI quickstart
//!
//! ```text
//! acadl-cli dse                          # standard sweep: 136 candidates, 32³ GeMM
//! acadl-cli dse --dim 64                 # bigger workload
//! acadl-cli dse --quick true --dim 8     # tiny smoke sweep (CI)
//! acadl-cli dse --no-prune true          # exhaustive (validates the pre-filter)
//! acadl-cli dse --arch-file sweep.acadl  # file-driven `param` space, streamed
//! acadl-cli dse --arch-file sweep.acadl --checkpoint sweep.ck --checkpoint-every 5000
//! acadl-cli dse --arch-file sweep.acadl --resume sweep.ck   # continue
//! ```
//!
//! Programmatic: `dse::explore(&DseSpace::standard(32), workers, true)`,
//! or [`stream::explore_source`] with a [`stream::DseConfig`] for
//! windowing, checkpoints, and bounded point retention.

pub mod checkpoint;
pub mod memo;
pub mod space;
pub mod stream;

pub use checkpoint::{Checkpoint, CheckpointCfg};
pub use memo::Memo;
pub use space::{DseSpace, FileSpace};
pub use stream::{
    explore_source, CandidateSource, DseConfig, FileSource, PruneMode, SpaceSource, VecSource,
    WaveStats, DEFAULT_WINDOW,
};

use std::collections::HashSet;
use std::time::Duration;

use crate::coordinator::job::{JobResult, JobSpec};
use crate::metrics::Table;

/// Sound lower bound on the timed cycles of `spec` — the single
/// definition lives on [`JobSpec::lower_bound_cycles`] (shared with the
/// coordinator's feasibility gate); this alias keeps the historical DSE
/// entry point.
pub fn lower_bound_cycles(spec: &JobSpec) -> u64 {
    spec.lower_bound_cycles()
}

/// One explored candidate: its spec, bound, and (possibly cache-served)
/// result.
#[derive(Debug, Clone)]
pub struct DsePoint {
    pub spec: JobSpec,
    pub lower_bound: u64,
    pub result: JobResult,
    /// Served from the memo instead of simulated.
    pub cached: bool,
}

/// Exploration statistics (the headline numbers the CLI prints).
#[derive(Debug, Clone, Default)]
pub struct DseStats {
    /// Candidates processed (the full space, unless the sweep was stopped
    /// early — then the enumeration prefix up to the stop).
    pub candidates: usize,
    /// Candidates that received a result (simulated or cache-served).
    pub evaluated: usize,
    /// Candidates cut by the analytical pre-filter (sum of the three
    /// breakdowns below).
    pub pruned: usize,
    /// … because the operand footprint exceeds the target's data-memory
    /// capacity or the bound exceeds the cycle budget (`execute_on`
    /// rejects these identically, so pruning them changes nothing).
    pub pruned_infeasible: usize,
    /// … because the sound cycle bound exceeds the incumbent
    /// ([`PruneMode::Cycles`]).
    pub pruned_bound: usize,
    /// … because an evaluated point weakly dominates the candidate's
    /// (bound, area) ([`PruneMode::Frontier`]).
    pub pruned_dominated: usize,
    /// Unique simulations actually run.
    pub simulated: usize,
    pub cache_hits: usize,
    pub failed: usize,
    pub best_cycles: u64,
    pub best_target: String,
    pub wall: Duration,
    /// Memo occupancy/bound/evictions at sweep end (the result cache is
    /// LRU-bounded; evictions cost re-simulation, never correctness).
    pub memo_entries: usize,
    pub memo_capacity: usize,
    pub memo_evictions: u64,
    /// Peak candidates + retained points resident at once — the
    /// bounded-memory guarantee, measured (compare against `candidates`).
    pub peak_resident: usize,
    /// Points restored from a `--resume` checkpoint rather than
    /// evaluated this run.
    pub restored: usize,
    /// The sweep stopped early because a cancellation token (deadline,
    /// client disconnect, shutdown) tripped.  The report then covers the
    /// complete windows processed before the trip — identical to what a
    /// `stop_after` run at the same boundary would have produced — and
    /// the checkpoint (when configured) resumes from that boundary.
    pub cancelled: bool,
}

/// The exploration outcome: evaluated points (sorted by cycles, then
/// area), Pareto-frontier indices into `points`, per-window prune/eval
/// accounting, and statistics.
///
/// With [`PruneMode::Cycles`], `frontier` is the frontier **of the
/// evaluated candidates**: incumbent pruning serves the cycle objective,
/// so a candidate whose cycle bound exceeds the best (e.g. the
/// minimum-area scalar OMA) is cut before its area-frontier merit is
/// measured.  [`PruneMode::Off`] and [`PruneMode::Frontier`] both yield
/// the exhaustive frontier pair set.
#[derive(Debug, Clone)]
pub struct DseReport {
    pub points: Vec<DsePoint>,
    pub frontier: Vec<usize>,
    /// One entry per lookahead window, in processing order.
    pub waves: Vec<WaveStats>,
    pub stats: DseStats,
}

/// Run the exploration over a built-in space.  `prune = false` evaluates
/// exhaustively (the validation mode the property tests compare
/// against).  Streams via [`stream::SpaceSource`]; every point is
/// retained (in-process callers iterate the report), so use
/// [`stream::explore_source`] directly for spaces too large to hold.
pub fn explore(space: &DseSpace, workers: usize, prune: bool) -> DseReport {
    explore_source(
        &mut SpaceSource::new(space),
        &DseConfig::legacy(workers, prune),
        None,
    )
    .expect("in-memory exploration without checkpoints cannot fail")
}

/// Explore an explicit candidate list — the entry point for hand-built
/// spaces and small `.acadl` sweeps.  Same streaming pipeline over a
/// [`stream::VecSource`].
pub fn explore_specs(specs: Vec<JobSpec>, workers: usize, prune: bool) -> DseReport {
    explore_source(
        &mut VecSource::new(specs),
        &DseConfig::legacy(workers, prune),
        None,
    )
    .expect("in-memory exploration without checkpoints cannot fail")
}

/// Indices of the cycles-vs-area Pareto frontier among error-free points.
/// Duplicate (cycles, area) pairs — memo aliases — are starred once.
pub(crate) fn pareto_frontier(points: &[DsePoint]) -> Vec<usize> {
    let mut out = Vec::new();
    for (i, p) in points.iter().enumerate() {
        if p.result.error.is_some() {
            continue;
        }
        let dominated = points.iter().enumerate().any(|(j, o)| {
            o.result.error.is_none()
                && o.result.cycles <= p.result.cycles
                && o.result.area_proxy <= p.result.area_proxy
                && (o.result.cycles < p.result.cycles
                    || o.result.area_proxy < p.result.area_proxy
                    || (j < i
                        && o.result.cycles == p.result.cycles
                        && o.result.area_proxy == p.result.area_proxy))
        });
        if !dominated {
            out.push(i);
        }
    }
    out
}

impl DseReport {
    /// The point table the CLI and examples print.
    pub fn table(&self, title: &str) -> Table {
        let frontier: HashSet<usize> = self.frontier.iter().copied().collect();
        let mut t = Table::new(
            title,
            &[
                "target", "workload", "backend", "area", "bound", "cycles", "util", "prefill",
                "cyc/tok", "src", "pareto",
            ],
        );
        for (i, p) in self.points.iter().enumerate() {
            t.row(vec![
                p.result.target.clone(),
                p.result.workload.clone(),
                p.spec.backend.name().to_string(),
                format!("{:.0}", p.result.area_proxy),
                p.lower_bound.to_string(),
                if p.result.error.is_some() {
                    format!("ERR: {}", p.result.error.as_deref().unwrap_or(""))
                } else {
                    p.result.cycles.to_string()
                },
                format!("{:.1}%", p.result.utilization * 100.0),
                // Serving-phase metrics exist only for decode jobs; a dash
                // keeps the non-serving rows visually quiet.
                p.result
                    .prefill_cycles
                    .map_or_else(|| "-".to_string(), |c| c.to_string()),
                p.result
                    .cycles_per_token
                    .map_or_else(|| "-".to_string(), |c| format!("{c:.1}")),
                if p.cached { "cache" } else { "sim" }.to_string(),
                if frontier.contains(&i) { "★" } else { "" }.to_string(),
            ]);
        }
        t
    }

    /// One-line statistics summary (plus a memo/memory line, and a
    /// frontier caveat when incumbent pruning was active).
    pub fn summary(&self) -> String {
        let s = &self.stats;
        let mut line = format!(
            "{} candidates: {} evaluated ({} simulated + {} cache hits), \
             {} pruned analytically, {} failed; best {} @ {} cycles; \
             frontier {} points; wall {:.2?}",
            s.candidates,
            s.evaluated,
            s.simulated,
            s.cache_hits,
            s.pruned,
            s.failed,
            if s.best_target.is_empty() {
                "-"
            } else {
                &s.best_target
            },
            if s.best_cycles == u64::MAX {
                0
            } else {
                s.best_cycles
            },
            self.frontier.len(),
            s.wall
        );
        if s.pruned > 0 {
            line.push_str(&format!(
                "\nprune breakdown: {} infeasible, {} over incumbent bound, {} dominated",
                s.pruned_infeasible, s.pruned_bound, s.pruned_dominated
            ));
        }
        line.push_str(&format!(
            "\nmemo: {}/{} entries, {} hits / {} misses, {} evicted; peak resident {} of {} candidates",
            s.memo_entries,
            s.memo_capacity,
            s.cache_hits,
            s.simulated,
            s.memo_evictions,
            s.peak_resident,
            s.candidates
        ));
        if s.restored > 0 {
            line.push_str(&format!(
                "\nresumed from checkpoint: {} points restored",
                s.restored
            ));
        }
        if s.cancelled {
            line.push_str(
                "\nsweep cancelled before the space was exhausted (deadline or \
                 cancellation observed); resume from the checkpoint to continue",
            );
        }
        if s.pruned_bound > 0 {
            // Incumbent pruning optimizes the *cycle* objective, so cut
            // candidates (typically the high-bound, low-area scalar tail)
            // never get an area-frontier chance — say so rather than
            // implying the frontier is exhaustive.
            line.push_str(
                "\nnote: frontier spans evaluated candidates only — pruning targets the \
                 cycle objective; rerun with pruning off for the exhaustive frontier",
            );
        }
        line
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::{SimModeSpec, TargetSpec, Workload};
    use crate::sim::backend::BackendKind;

    fn gemm_spec(target: TargetSpec, dim: usize) -> JobSpec {
        JobSpec {
            id: 0,
            target,
            workload: Workload::Gemm {
                m: dim,
                k: dim,
                n: dim,
                tile: None,
                order: None,
            },
            mode: SimModeSpec::Timed,
            backend: BackendKind::EventDriven,
            max_cycles: 100_000_000,
            platform: None,
            deadline_ms: None,
        }
    }

    #[test]
    fn bounds_order_targets_sensibly() {
        let oma = lower_bound_cycles(&gemm_spec(
            TargetSpec::Oma {
                cache: true,
                mac_latency: None,
            },
            32,
        ));
        let sys = lower_bound_cycles(&gemm_spec(TargetSpec::Systolic { rows: 8, cols: 8 }, 32));
        let gamma = lower_bound_cycles(&gemm_spec(TargetSpec::Gamma { units: 4 }, 32));
        assert!(oma > sys && sys > gamma, "{oma} / {sys} / {gamma}");
        assert_eq!(oma, 32 * 32 * 32, "scalar bound is the MAC count");
    }

    #[test]
    fn mlp_bound_sums_dense_layers() {
        let spec = JobSpec {
            workload: Workload::Mlp {
                small: true,
                batch: 4,
            },
            ..gemm_spec(
                TargetSpec::Oma {
                    cache: true,
                    mac_latency: None,
                },
                1,
            )
        };
        // mlp_small: 16→24→8 at batch 4 ⇒ 4·16·24 + 4·24·8 MACs.
        assert_eq!(lower_bound_cycles(&spec), 4 * 16 * 24 + 4 * 24 * 8);
    }

    #[test]
    fn tiny_exploration_produces_frontier_and_cache_hits() {
        // Two backends ⇒ the second of every pair is a guaranteed memo hit.
        let mut space = DseSpace::quick(6);
        space.backends = vec![BackendKind::CycleStepped, BackendKind::EventDriven];
        space.include_oma = false; // keep the test fast
        let rep = explore(&space, 2, true);
        assert!(rep.stats.evaluated > 0);
        assert_eq!(rep.stats.failed, 0, "{}", rep.summary());
        assert!(rep.stats.cache_hits > 0, "{}", rep.summary());
        assert!(!rep.frontier.is_empty());
        // The streaming engine accounts every candidate and its waves.
        assert_eq!(
            rep.stats.evaluated + rep.stats.pruned,
            rep.stats.candidates,
            "{}",
            rep.summary()
        );
        assert!(!rep.waves.is_empty());
        let wave_eval: usize = rep.waves.iter().map(|w| w.evaluated).sum();
        assert_eq!(wave_eval, rep.stats.evaluated);
        assert!(rep.stats.peak_resident <= rep.stats.candidates);
        // Frontier points are mutually non-dominating.
        for &i in &rep.frontier {
            for &j in &rep.frontier {
                if i == j {
                    continue;
                }
                let (a, b) = (&rep.points[i].result, &rep.points[j].result);
                assert!(
                    !(a.cycles < b.cycles && a.area_proxy < b.area_proxy),
                    "{i} dominates {j}"
                );
            }
        }
        // Every evaluated point respects its own lower bound.
        for p in &rep.points {
            assert!(
                p.result.cycles >= p.lower_bound,
                "{}: {} < bound {}",
                p.result.target,
                p.result.cycles,
                p.lower_bound
            );
        }
    }
}
