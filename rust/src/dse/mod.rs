//! Design-space exploration (§7's "optimization loop of hardware-aware
//! NAS and DNN/HW Co-Design"): enumerate → prune → simulate → frontier.
//!
//! The pipeline:
//!
//! 1. **Enumerate** ([`space::DseSpace`]) the (arch config × tile × loop
//!    order × backend) candidate cross-product, via the arch layer's
//!    enumeration hooks.
//! 2. **Pre-filter** each candidate with its analytical cycle lower bound
//!    ([`lower_bound_cycles`]: the per-target `analytical::Roofline`).
//!    Candidates are evaluated in waves, cheapest bound first; once a
//!    bound exceeds the best simulated cycle count so far, the entire
//!    remaining (sorted) tail is pruned without simulating.  Because the
//!    bound is sound (simulated cycles can never undercut it — a tested
//!    property), pruning can never discard a cycle-optimal candidate.
//!    Pruning serves the *cycle* objective: a cut candidate never gets an
//!    area-frontier chance, so with pruning on, the reported frontier
//!    spans the evaluated candidates (the report says so; `--no-prune
//!    true` computes the exhaustive frontier).
//! 3. **Evaluate** each surviving wave in parallel on the coordinator
//!    pool (which shares cached machines), **memoizing** results by the
//!    canonical job-spec hash ([`memo::Memo`]) so aliased candidates
//!    (second backend, tile/order on targets that ignore them) cost
//!    nothing.
//! 4. **Report** the cycles-vs-area Pareto frontier plus pruning and
//!    cache statistics.
//!
//! # CLI quickstart
//!
//! ```text
//! acadl-cli dse                        # standard sweep: 136 candidates, 32³ GeMM
//! acadl-cli dse --dim 64               # bigger workload
//! acadl-cli dse --quick true --dim 8   # tiny smoke sweep (CI)
//! acadl-cli dse --no-prune true        # exhaustive (validates the pre-filter)
//! acadl-cli dse --workers 8            # pool width
//! ```
//!
//! Programmatic: `dse::explore(&DseSpace::standard(32), workers, true)`.

pub mod memo;
pub mod space;

pub use memo::Memo;
pub use space::{DseSpace, FileSpace};

use std::collections::{HashMap, HashSet};
use std::time::{Duration, Instant};

use crate::coordinator::job::{JobResult, JobSpec, Workload};
use crate::coordinator::pool;
use crate::dnn::graph::DnnGraph;
use crate::dnn::lowering::roofline_ops;
use crate::mapping::gemm::GemmParams;
use crate::metrics::Table;

/// Sound lower bound on the timed cycles of `spec`: the target's roofline
/// summed over the workload's operator sequence
/// ([`crate::dnn::lowering::roofline_ops`] — GeMM bounds for the
/// GeMM-backed operators, streaming-traffic bounds for the row-wise
/// transformer operators).  Target-side padding (Γ̈ rounds dims up to 8)
/// only raises true cycles, so bounding the unpadded problem stays sound.
pub fn lower_bound_cycles(spec: &JobSpec) -> u64 {
    let rl = spec.target.roofline();
    match &spec.workload {
        Workload::Gemm { m, k, n, .. } => rl.gemm_cycles(&GemmParams::new(*m, *k, *n)),
        Workload::Mlp { small, batch } => {
            let g = if *small {
                DnnGraph::mlp_small()
            } else {
                DnnGraph::mlp_784_256_128_10()
            };
            roofline_ops(&g, *batch).iter().map(|op| rl.op_cycles(op)).sum()
        }
        Workload::Transformer { seq } => roofline_ops(&DnnGraph::tiny_transformer(), *seq)
            .iter()
            .map(|op| rl.op_cycles(op))
            .sum(),
    }
}

/// One explored candidate: its spec, bound, and (possibly cache-served)
/// result.
#[derive(Debug, Clone)]
pub struct DsePoint {
    pub spec: JobSpec,
    pub lower_bound: u64,
    pub result: JobResult,
    /// Served from the memo instead of simulated.
    pub cached: bool,
}

/// Exploration statistics (the headline numbers the CLI prints).
#[derive(Debug, Clone, Default)]
pub struct DseStats {
    pub candidates: usize,
    /// Candidates that received a result (simulated or cache-served).
    pub evaluated: usize,
    /// Candidates cut by the analytical pre-filter.
    pub pruned: usize,
    /// Unique simulations actually run.
    pub simulated: usize,
    pub cache_hits: usize,
    pub failed: usize,
    pub best_cycles: u64,
    pub best_target: String,
    pub wall: Duration,
}

/// The exploration outcome: evaluated points (sorted by cycles, then
/// area), Pareto-frontier indices into `points`, and statistics.
///
/// With pruning on, `frontier` is the frontier **of the evaluated
/// candidates**: pruning serves the cycle objective, so a candidate whose
/// cycle bound exceeds the best (e.g. the minimum-area scalar OMA) is cut
/// before its area-frontier merit is measured.  `explore(.., false)`
/// yields the exhaustive frontier.
#[derive(Debug, Clone)]
pub struct DseReport {
    pub points: Vec<DsePoint>,
    pub frontier: Vec<usize>,
    pub stats: DseStats,
}

/// Run the exploration.  `prune = false` evaluates exhaustively (the
/// validation mode the property tests compare against).
pub fn explore(space: &DseSpace, workers: usize, prune: bool) -> DseReport {
    explore_specs(space.enumerate(), workers, prune)
}

/// Explore an explicit candidate list — the entry point for spaces that
/// don't come from [`DseSpace`], e.g. a `.acadl` file's `param` block
/// ([`space::FileSpace`]).  Same pipeline: sort by analytical bound,
/// prune the tail, evaluate waves in parallel with memoization.
pub fn explore_specs(specs: Vec<JobSpec>, workers: usize, prune: bool) -> DseReport {
    let t0 = Instant::now();
    let mut cands: Vec<(JobSpec, u64)> = specs
        .into_iter()
        .map(|s| {
            let lb = lower_bound_cycles(&s);
            (s, lb)
        })
        .collect();
    // Cheapest bound first: the most promising candidates simulate first,
    // and the prunable tail becomes one contiguous cut.
    cands.sort_by_key(|(s, lb)| (*lb, s.id));

    let mut memo = Memo::new();
    let mut points: Vec<DsePoint> = Vec::new();
    let mut best = u64::MAX;
    let mut best_target = String::new();
    let mut pruned = 0usize;
    let wave_len = (workers.max(1) * 2).max(8);

    let mut i = 0;
    while i < cands.len() {
        if prune && cands[i].1 > best {
            // Sorted ascending: every remaining bound also exceeds the
            // best simulated cycles — cut the whole tail analytically.
            pruned = cands.len() - i;
            break;
        }
        let mut end = (i + wave_len).min(cands.len());
        if prune {
            // Keep the wave inside the still-plausible prefix.
            while end > i + 1 && cands[end - 1].1 > best {
                end -= 1;
            }
        }
        let wave = &cands[i..end];

        // Partition the wave: one representative simulation per canonical
        // key; everything else is served from the memo.
        let mut to_run: Vec<JobSpec> = Vec::new();
        let mut scheduled: HashSet<u64> = HashSet::new();
        let mut id_to_key: HashMap<u64, u64> = HashMap::new();
        for (spec, _) in wave {
            let key = spec.canonical_key();
            if memo.contains(key) || !scheduled.insert(key) {
                continue;
            }
            id_to_key.insert(spec.id, key);
            to_run.push(spec.clone());
        }
        let ran_ids: HashSet<u64> = to_run.iter().map(|s| s.id).collect();
        for r in pool::run_jobs(to_run, workers) {
            let key = id_to_key[&r.id];
            memo.insert(key, r);
        }

        // Serve every wave candidate and fold in the new best.
        for (spec, lb) in wave {
            let key = spec.canonical_key();
            // run_jobs returns one result per spec, so the miss arm is
            // unreachable in practice — but if the pool ever degrades, the
            // candidate must still be *accounted for* (an error point, not
            // a silent drop, or `evaluated + pruned == candidates` breaks).
            let mut result = memo.get(key).cloned().unwrap_or_else(|| JobResult {
                id: spec.id,
                target: spec.target.describe(),
                workload: spec.workload.describe(),
                mode: spec.mode,
                cycles: 0,
                instructions: 0,
                ipc: 0.0,
                utilization: 0.0,
                numerics_ok: None,
                wall_micros: 0,
                error: Some("worker pool returned no result for this job".into()),
                area_proxy: spec.target.area_proxy(),
            });
            let cached = !ran_ids.contains(&spec.id);
            if cached {
                memo.note_hit();
            } else {
                memo.note_miss();
            }
            result.id = spec.id;
            if result.error.is_none() && result.cycles > 0 && result.cycles < best {
                best = result.cycles;
                best_target = result.target.clone();
            }
            points.push(DsePoint {
                spec: spec.clone(),
                lower_bound: *lb,
                result,
                cached,
            });
        }
        i = end;
    }

    points.sort_by(|a, b| {
        (a.result.cycles, a.result.area_proxy as u64, a.spec.id).cmp(&(
            b.result.cycles,
            b.result.area_proxy as u64,
            b.spec.id,
        ))
    });
    let frontier = pareto_frontier(&points);
    let (cache_hits, simulated) = memo.stats();
    let failed = points.iter().filter(|p| p.result.error.is_some()).count();
    DseReport {
        stats: DseStats {
            candidates: cands.len(),
            evaluated: points.len(),
            pruned,
            simulated: simulated as usize,
            cache_hits: cache_hits as usize,
            failed,
            best_cycles: best,
            best_target,
            wall: t0.elapsed(),
        },
        points,
        frontier,
    }
}

/// Indices of the cycles-vs-area Pareto frontier among error-free points.
/// Duplicate (cycles, area) pairs — memo aliases — are starred once.
fn pareto_frontier(points: &[DsePoint]) -> Vec<usize> {
    let mut out = Vec::new();
    for (i, p) in points.iter().enumerate() {
        if p.result.error.is_some() {
            continue;
        }
        let dominated = points.iter().enumerate().any(|(j, o)| {
            o.result.error.is_none()
                && o.result.cycles <= p.result.cycles
                && o.result.area_proxy <= p.result.area_proxy
                && (o.result.cycles < p.result.cycles
                    || o.result.area_proxy < p.result.area_proxy
                    || (j < i
                        && o.result.cycles == p.result.cycles
                        && o.result.area_proxy == p.result.area_proxy))
        });
        if !dominated {
            out.push(i);
        }
    }
    out
}

impl DseReport {
    /// The point table the CLI and examples print.
    pub fn table(&self, title: &str) -> Table {
        let frontier: HashSet<usize> = self.frontier.iter().copied().collect();
        let mut t = Table::new(
            title,
            &[
                "target", "workload", "backend", "area", "bound", "cycles", "util", "src",
                "pareto",
            ],
        );
        for (i, p) in self.points.iter().enumerate() {
            t.row(vec![
                p.result.target.clone(),
                p.result.workload.clone(),
                p.spec.backend.name().to_string(),
                format!("{:.0}", p.result.area_proxy),
                p.lower_bound.to_string(),
                if p.result.error.is_some() {
                    format!("ERR: {}", p.result.error.as_deref().unwrap_or(""))
                } else {
                    p.result.cycles.to_string()
                },
                format!("{:.1}%", p.result.utilization * 100.0),
                if p.cached { "cache" } else { "sim" }.to_string(),
                if frontier.contains(&i) { "★" } else { "" }.to_string(),
            ]);
        }
        t
    }

    /// One-line statistics summary.
    pub fn summary(&self) -> String {
        let s = &self.stats;
        let mut line = format!(
            "{} candidates: {} evaluated ({} simulated + {} cache hits), \
             {} pruned analytically, {} failed; best {} @ {} cycles; \
             frontier {} points; wall {:.2?}",
            s.candidates,
            s.evaluated,
            s.simulated,
            s.cache_hits,
            s.pruned,
            s.failed,
            if s.best_target.is_empty() {
                "-"
            } else {
                &s.best_target
            },
            if s.best_cycles == u64::MAX {
                0
            } else {
                s.best_cycles
            },
            self.frontier.len(),
            s.wall
        );
        if s.pruned > 0 {
            // Pruning optimizes the *cycle* objective, so cut candidates
            // (typically the high-bound, low-area scalar tail) never get
            // an area-frontier chance — say so rather than implying the
            // frontier is exhaustive.
            line.push_str(
                "\nnote: frontier spans evaluated candidates only — pruning targets the \
                 cycle objective; rerun with pruning off for the exhaustive frontier",
            );
        }
        line
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::{SimModeSpec, TargetSpec};
    use crate::sim::backend::BackendKind;

    fn gemm_spec(target: TargetSpec, dim: usize) -> JobSpec {
        JobSpec {
            id: 0,
            target,
            workload: Workload::Gemm {
                m: dim,
                k: dim,
                n: dim,
                tile: None,
                order: None,
            },
            mode: SimModeSpec::Timed,
            backend: BackendKind::EventDriven,
            max_cycles: 100_000_000,
        }
    }

    #[test]
    fn bounds_order_targets_sensibly() {
        let oma = lower_bound_cycles(&gemm_spec(
            TargetSpec::Oma {
                cache: true,
                mac_latency: None,
            },
            32,
        ));
        let sys = lower_bound_cycles(&gemm_spec(TargetSpec::Systolic { rows: 8, cols: 8 }, 32));
        let gamma = lower_bound_cycles(&gemm_spec(TargetSpec::Gamma { units: 4 }, 32));
        assert!(oma > sys && sys > gamma, "{oma} / {sys} / {gamma}");
        assert_eq!(oma, 32 * 32 * 32, "scalar bound is the MAC count");
    }

    #[test]
    fn mlp_bound_sums_dense_layers() {
        let spec = JobSpec {
            workload: Workload::Mlp {
                small: true,
                batch: 4,
            },
            ..gemm_spec(
                TargetSpec::Oma {
                    cache: true,
                    mac_latency: None,
                },
                1,
            )
        };
        // mlp_small: 16→24→8 at batch 4 ⇒ 4·16·24 + 4·24·8 MACs.
        assert_eq!(lower_bound_cycles(&spec), 4 * 16 * 24 + 4 * 24 * 8);
    }

    #[test]
    fn tiny_exploration_produces_frontier_and_cache_hits() {
        // Two backends ⇒ the second of every pair is a guaranteed memo hit.
        let mut space = DseSpace::quick(6);
        space.backends = vec![BackendKind::CycleStepped, BackendKind::EventDriven];
        space.include_oma = false; // keep the test fast
        let rep = explore(&space, 2, true);
        assert!(rep.stats.evaluated > 0);
        assert_eq!(rep.stats.failed, 0, "{}", rep.summary());
        assert!(rep.stats.cache_hits > 0, "{}", rep.summary());
        assert!(!rep.frontier.is_empty());
        // Frontier points are mutually non-dominating.
        for &i in &rep.frontier {
            for &j in &rep.frontier {
                if i == j {
                    continue;
                }
                let (a, b) = (&rep.points[i].result, &rep.points[j].result);
                assert!(
                    !(a.cycles < b.cycles && a.area_proxy < b.area_proxy),
                    "{i} dominates {j}"
                );
            }
        }
        // Every evaluated point respects its own lower bound.
        for p in &rep.points {
            assert!(
                p.result.cycles >= p.lower_bound,
                "{}: {} < bound {}",
                p.result.target,
                p.result.cycles,
                p.lower_bound
            );
        }
    }
}
