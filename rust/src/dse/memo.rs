//! Result memoization for design-space exploration.
//!
//! Keyed by [`JobSpec::canonical_key`]: semantically identical jobs (same
//! target + canonicalized workload + mode + cycle budget — backend and id
//! excluded) share one simulation.  The sweep enumerator deliberately
//! emits the full (arch × tile × order × backend) cross-product; the memo
//! is what collapses the axes a given target cannot observe, so e.g. the
//! second backend of every pair and every tile/order variant on a
//! systolic target are served from cache.

use std::collections::HashMap;

use crate::coordinator::job::JobResult;

/// A single-exploration memo (the orchestration loop is single-threaded;
/// parallelism lives inside the pool, so no locking here).
#[derive(Debug, Default)]
pub struct Memo {
    map: HashMap<u64, JobResult>,
    hits: u64,
    misses: u64,
}

impl Memo {
    pub fn new() -> Self {
        Memo::default()
    }

    /// Non-counting probe (wave scheduling).
    pub fn contains(&self, key: u64) -> bool {
        self.map.contains_key(&key)
    }

    pub fn get(&self, key: u64) -> Option<&JobResult> {
        self.map.get(&key)
    }

    pub fn insert(&mut self, key: u64, result: JobResult) {
        self.map.insert(key, result);
    }

    /// Record that a candidate was served from the memo.
    pub fn note_hit(&mut self) {
        self.hits += 1;
    }

    /// Record that a candidate required a fresh simulation.
    pub fn note_miss(&mut self) {
        self.misses += 1;
    }

    /// (hits, misses) over the exploration so far.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    /// Distinct results stored.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}
