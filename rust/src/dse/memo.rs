//! Result memoization for design-space exploration.
//!
//! Keyed by [`JobSpec::canonical_key`]: semantically identical jobs (same
//! target + canonicalized workload + mode + cycle budget — backend and id
//! excluded) share one simulation.  The sweep enumerator deliberately
//! emits the full (arch × tile × order × backend) cross-product; the memo
//! is what collapses the axes a given target cannot observe, so e.g. the
//! second backend of every pair and every tile/order variant on a
//! systolic target are served from cache.
//!
//! The memo is **bounded**: at most `capacity` results are retained, with
//! least-recently-used eviction (a `tick → key` index beside the map, so
//! both lookup and eviction are `O(log n)`).  A streaming sweep over
//! hundreds of thousands of candidates therefore holds a fixed-size
//! result cache instead of growing with the space; evictions only cost
//! re-simulation, never correctness.
//!
//! [`JobSpec::canonical_key`]: crate::coordinator::job::JobSpec::canonical_key

use std::collections::{BTreeMap, HashMap};

use crate::coordinator::job::JobResult;

/// Default retention: comfortably above every built-in space and any
/// plausible wave, small enough that a million-candidate sweep stays flat.
pub const DEFAULT_MEMO_CAPACITY: usize = 4096;

/// A single-exploration memo (the orchestration loop is single-threaded;
/// parallelism lives inside the pool, so no locking here).
#[derive(Debug)]
pub struct Memo {
    /// key → (last-use tick, result).
    map: HashMap<u64, (u64, JobResult)>,
    /// last-use tick → key (the LRU order; ticks are unique).
    order: BTreeMap<u64, u64>,
    tick: u64,
    capacity: usize,
    hits: u64,
    misses: u64,
    evictions: u64,
}

impl Default for Memo {
    fn default() -> Self {
        Memo::with_capacity(DEFAULT_MEMO_CAPACITY)
    }
}

impl Memo {
    pub fn new() -> Self {
        Memo::default()
    }

    /// An explicitly bounded memo (`capacity` 0 disables retention —
    /// every probe misses, which is valid, just slow).
    pub fn with_capacity(capacity: usize) -> Self {
        Memo {
            map: HashMap::new(),
            order: BTreeMap::new(),
            tick: 0,
            capacity,
            hits: 0,
            misses: 0,
            evictions: 0,
        }
    }

    fn next_tick(&mut self) -> u64 {
        self.tick += 1;
        self.tick
    }

    /// Non-counting probe (wave scheduling).  Does not refresh recency.
    pub fn contains(&self, key: u64) -> bool {
        self.map.contains_key(&key)
    }

    /// Fetch a result, refreshing its LRU position.
    pub fn get(&mut self, key: u64) -> Option<&JobResult> {
        let tick = self.next_tick();
        match self.map.get_mut(&key) {
            Some((last, result)) => {
                self.order.remove(last);
                self.order.insert(tick, key);
                *last = tick;
                Some(result)
            }
            None => None,
        }
    }

    pub fn insert(&mut self, key: u64, result: JobResult) {
        if self.capacity == 0 {
            return;
        }
        let tick = self.next_tick();
        if let Some((last, _)) = self.map.get(&key) {
            self.order.remove(last);
        } else if self.map.len() >= self.capacity {
            // Evict the least-recently-used entry to make room.
            if let Some((&oldest, &victim)) = self.order.iter().next() {
                self.order.remove(&oldest);
                self.map.remove(&victim);
                self.evictions += 1;
            }
        }
        self.order.insert(tick, key);
        self.map.insert(key, (tick, result));
    }

    /// Record that a candidate was served from the memo.
    pub fn note_hit(&mut self) {
        self.hits += 1;
    }

    /// Record that a candidate required a fresh simulation.
    pub fn note_miss(&mut self) {
        self.misses += 1;
    }

    /// (hits, misses) over the exploration so far.
    pub fn stats(&self) -> (u64, u64) {
        (self.hits, self.misses)
    }

    /// Entries evicted by the LRU bound so far.
    pub fn evictions(&self) -> u64 {
        self.evictions
    }

    /// The retention bound this memo was built with.
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Distinct results stored.
    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::SimModeSpec;

    fn result(id: u64) -> JobResult {
        JobResult {
            id,
            target: "t".into(),
            workload: "w".into(),
            mode: SimModeSpec::Timed,
            cycles: id,
            instructions: 0,
            ipc: 0.0,
            utilization: 0.0,
            numerics_ok: None,
            wall_micros: 0,
            error: None,
            area_proxy: 1.0,
            prefill_cycles: None,
            cycles_per_token: None,
        }
    }

    #[test]
    fn capacity_bounds_entries_and_counts_evictions() {
        let mut m = Memo::with_capacity(3);
        for k in 0..5u64 {
            m.insert(k, result(k));
        }
        assert_eq!(m.len(), 3);
        assert_eq!(m.evictions(), 2);
        // Oldest two (0, 1) were evicted; newest three remain.
        assert!(!m.contains(0) && !m.contains(1));
        assert!(m.contains(2) && m.contains(3) && m.contains(4));
    }

    #[test]
    fn get_refreshes_lru_order() {
        let mut m = Memo::with_capacity(2);
        m.insert(1, result(1));
        m.insert(2, result(2));
        // Touch 1 so 2 becomes the LRU victim.
        assert_eq!(m.get(1).unwrap().id, 1);
        m.insert(3, result(3));
        assert!(m.contains(1) && m.contains(3));
        assert!(!m.contains(2));
        assert_eq!(m.evictions(), 1);
    }

    #[test]
    fn reinsert_updates_in_place_without_eviction() {
        let mut m = Memo::with_capacity(2);
        m.insert(1, result(1));
        m.insert(2, result(2));
        m.insert(1, result(10));
        assert_eq!(m.len(), 2);
        assert_eq!(m.evictions(), 0);
        assert_eq!(m.get(1).unwrap().cycles, 10);
    }

    #[test]
    fn zero_capacity_disables_retention() {
        let mut m = Memo::with_capacity(0);
        m.insert(1, result(1));
        assert!(m.is_empty());
        assert!(m.get(1).is_none());
    }
}
