//! Sweep checkpoints: everything a streaming exploration needs to
//! continue after an interruption, as one JSON object on disk.
//!
//! The format (version 1) is deliberately flat and built from the
//! existing wire serializations ([`JobSpec::to_json`],
//! [`JobResult::to_json`]), so external tooling that already parses job
//! lines parses checkpoint points too:
//!
//! ```text
//! {
//!   "version": 1,
//!   "signature": "0x…",          // space identity (FNV-1a, hex string)
//!   "cursor": 10240,             // next enumeration index to pull
//!   "stride": 4,                 // reservoir thinning stride
//!   "best_cycles": 1234,         // incumbent (null before any success)
//!   "best_target": "…",
//!   "evaluated": …, "pruned_infeasible": …, "pruned_bound": …,
//!   "pruned_dominated": …, "simulated": …, "cache_hits": …, "failed": …,
//!   "frontier": [ {"spec": …, "lower_bound": …, "result": …, "cached": …} ],
//!   "samples":  [ … ]            // thinned non-frontier reservoir
//! }
//! ```
//!
//! The signature is serialized as a hex *string* because a 64-bit hash
//! does not survive the JSON number type (f64 mantissa).  Writes are
//! atomic (sibling `.tmp` + rename), so a kill mid-write leaves the
//! previous checkpoint intact.  The simulation memo is deliberately
//! **not** checkpointed: losing it costs re-simulation on resume, never
//! correctness, and keeps checkpoints small.

use std::fs;

use crate::coordinator::job::{JobResult, JobSpec};
use crate::dse::DsePoint;
use crate::util::json::Json;

/// Where and how often to checkpoint: after any lookahead window that
/// crosses `every` processed candidates since the last write (plus a
/// final write at stop/completion).
#[derive(Debug, Clone)]
pub struct CheckpointCfg {
    pub path: String,
    pub every: u64,
}

/// A serialized sweep position (see the module docs for the format).
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub version: u64,
    pub signature: u64,
    pub cursor: u64,
    pub stride: u64,
    /// `u64::MAX` = no successful evaluation yet (serialized as null).
    pub best_cycles: u64,
    pub best_target: String,
    pub evaluated: u64,
    pub pruned_infeasible: u64,
    pub pruned_bound: u64,
    pub pruned_dominated: u64,
    pub simulated: u64,
    pub cache_hits: u64,
    pub failed: u64,
    pub frontier: Vec<DsePoint>,
    pub samples: Vec<DsePoint>,
}

fn point_to_json(p: &DsePoint) -> Json {
    Json::obj(vec![
        ("spec", p.spec.to_json()),
        ("lower_bound", Json::num(p.lower_bound as f64)),
        ("result", p.result.to_json()),
        ("cached", Json::Bool(p.cached)),
    ])
}

fn point_from_json(v: &Json) -> Result<DsePoint, String> {
    Ok(DsePoint {
        spec: JobSpec::from_json(v.field("spec").map_err(|e| e.to_string())?)
            .map_err(|e| format!("checkpoint point spec: {e}"))?,
        lower_bound: v
            .field("lower_bound")
            .and_then(|x| x.as_u64())
            .map_err(|e| format!("checkpoint point lower_bound: {e}"))?,
        result: JobResult::from_json(v.field("result").map_err(|e| e.to_string())?)
            .map_err(|e| format!("checkpoint point result: {e}"))?,
        cached: v
            .field("cached")
            .and_then(|x| x.as_bool())
            .map_err(|e| format!("checkpoint point cached: {e}"))?,
    })
}

fn points_from_json(v: &Json, what: &str) -> Result<Vec<DsePoint>, String> {
    v.as_arr()
        .map_err(|e| format!("checkpoint {what}: {e}"))?
        .iter()
        .map(point_from_json)
        .collect()
}

impl Checkpoint {
    pub const VERSION: u64 = 1;

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("version", Json::num(self.version as f64)),
            ("signature", Json::str(format!("{:#018x}", self.signature))),
            ("cursor", Json::num(self.cursor as f64)),
            ("stride", Json::num(self.stride as f64)),
            (
                "best_cycles",
                if self.best_cycles == u64::MAX {
                    Json::Null
                } else {
                    Json::num(self.best_cycles as f64)
                },
            ),
            ("best_target", Json::str(self.best_target.clone())),
            ("evaluated", Json::num(self.evaluated as f64)),
            (
                "pruned_infeasible",
                Json::num(self.pruned_infeasible as f64),
            ),
            ("pruned_bound", Json::num(self.pruned_bound as f64)),
            ("pruned_dominated", Json::num(self.pruned_dominated as f64)),
            ("simulated", Json::num(self.simulated as f64)),
            ("cache_hits", Json::num(self.cache_hits as f64)),
            ("failed", Json::num(self.failed as f64)),
            (
                "frontier",
                Json::Arr(self.frontier.iter().map(point_to_json).collect()),
            ),
            (
                "samples",
                Json::Arr(self.samples.iter().map(point_to_json).collect()),
            ),
        ])
    }

    pub fn from_json(v: &Json) -> Result<Self, String> {
        let version = v
            .field("version")
            .and_then(|x| x.as_u64())
            .map_err(|e| format!("checkpoint version: {e}"))?;
        if version != Self::VERSION {
            return Err(format!(
                "unsupported checkpoint version {version} (this build reads version {})",
                Self::VERSION
            ));
        }
        let sig_str = v
            .field("signature")
            .and_then(|x| x.as_str())
            .map_err(|e| format!("checkpoint signature: {e}"))?;
        let signature = u64::from_str_radix(sig_str.trim_start_matches("0x"), 16)
            .map_err(|e| format!("checkpoint signature `{sig_str}`: {e}"))?;
        let num = |key: &str| -> Result<u64, String> {
            v.field(key)
                .and_then(|x| x.as_u64())
                .map_err(|e| format!("checkpoint {key}: {e}"))
        };
        let ck = Checkpoint {
            version,
            signature,
            cursor: num("cursor")?,
            stride: num("stride")?.max(1),
            best_cycles: match v.get("best_cycles") {
                None | Some(Json::Null) => u64::MAX,
                Some(x) => x
                    .as_u64()
                    .map_err(|e| format!("checkpoint best_cycles: {e}"))?,
            },
            best_target: v
                .field("best_target")
                .and_then(|x| x.as_str())
                .map_err(|e| format!("checkpoint best_target: {e}"))?
                .to_string(),
            evaluated: num("evaluated")?,
            pruned_infeasible: num("pruned_infeasible")?,
            pruned_bound: num("pruned_bound")?,
            pruned_dominated: num("pruned_dominated")?,
            simulated: num("simulated")?,
            cache_hits: num("cache_hits")?,
            failed: num("failed")?,
            frontier: points_from_json(v.field("frontier").map_err(|e| e.to_string())?, "frontier")?,
            samples: points_from_json(v.field("samples").map_err(|e| e.to_string())?, "samples")?,
        };
        ck.validate()?;
        Ok(ck)
    }

    /// Cross-field consistency: every checkpoint the engine writes sits
    /// at a window boundary, where these invariants hold by
    /// construction.  A file that parses but violates one was truncated
    /// mid-edit, bit-flipped, or hand-altered — resuming from it would
    /// silently skip or double-count candidates, so reject it with a
    /// diagnostic instead.
    pub fn validate(&self) -> Result<(), String> {
        let pruned = self
            .pruned_infeasible
            .saturating_add(self.pruned_bound)
            .saturating_add(self.pruned_dominated);
        if self.evaluated.checked_add(pruned) != Some(self.cursor) {
            return Err(format!(
                "inconsistent counters: {} evaluated + {pruned} pruned != cursor {} — \
                 the file is corrupt (every pulled candidate is exactly one of the two)",
                self.evaluated, self.cursor
            ));
        }
        if self.simulated.checked_add(self.cache_hits) != Some(self.evaluated) {
            return Err(format!(
                "inconsistent counters: {} simulated + {} cache hits != {} evaluated — \
                 the file is corrupt",
                self.simulated, self.cache_hits, self.evaluated
            ));
        }
        if self.failed > self.evaluated {
            return Err(format!(
                "inconsistent counters: {} failed > {} evaluated — the file is corrupt",
                self.failed, self.evaluated
            ));
        }
        if !self.stride.is_power_of_two() {
            return Err(format!(
                "invalid thinning stride {} (strides start at 1 and only double) — \
                 the file is corrupt",
                self.stride
            ));
        }
        let retained = (self.frontier.len() + self.samples.len()) as u64;
        if retained > self.evaluated {
            return Err(format!(
                "{retained} retained points exceed {} evaluated candidates — \
                 the file is corrupt",
                self.evaluated
            ));
        }
        if let Some(p) = self.frontier.iter().find(|p| p.result.error.is_some()) {
            return Err(format!(
                "frontier contains an error row (id {}) — error points never join \
                 the frontier; the file is corrupt",
                p.result.id
            ));
        }
        Ok(())
    }

    /// Atomic write: serialize to a sibling `.tmp`, then rename over the
    /// destination, so readers (and a killed writer) never see a torn
    /// file.
    pub fn save(&self, path: &str) -> Result<(), String> {
        let tmp = format!("{path}.tmp");
        fs::write(&tmp, self.to_json().to_string())
            .map_err(|e| format!("cannot write checkpoint `{tmp}`: {e}"))?;
        fs::rename(&tmp, path)
            .map_err(|e| format!("cannot move checkpoint into place at `{path}`: {e}"))
    }

    pub fn load(path: &str) -> Result<Self, String> {
        let text =
            fs::read_to_string(path).map_err(|e| format!("cannot read checkpoint `{path}`: {e}"))?;
        let json = Json::parse(&text).map_err(|e| format!("checkpoint `{path}`: {e}"))?;
        Self::from_json(&json).map_err(|e| format!("checkpoint `{path}`: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::SimModeSpec;
    use crate::coordinator::job::TargetSpec;
    use crate::coordinator::job::Workload;

    fn point(id: u64, cycles: u64) -> DsePoint {
        DsePoint {
            spec: JobSpec {
                id,
                target: TargetSpec::Systolic { rows: 4, cols: 4 },
                workload: Workload::Gemm {
                    m: 8,
                    k: 8,
                    n: 8,
                    tile: None,
                    order: None,
                },
                mode: SimModeSpec::Timed,
                backend: Default::default(),
                max_cycles: 1_000_000,
                platform: None,
                deadline_ms: None,
            },
            lower_bound: cycles / 2,
            result: JobResult {
                id,
                target: "systolic 4x4".into(),
                workload: "gemm 8x8x8".into(),
                mode: SimModeSpec::Timed,
                cycles,
                instructions: 3,
                ipc: 1.5,
                utilization: 0.5,
                numerics_ok: Some(true),
                wall_micros: 17,
                error: None,
                area_proxy: 16.0,
                prefill_cycles: None,
                cycles_per_token: None,
            },
            cached: id % 2 == 0,
        }
    }

    fn checkpoint() -> Checkpoint {
        Checkpoint {
            version: Checkpoint::VERSION,
            signature: 0xDEAD_BEEF_CAFE_F00D,
            cursor: 10_240,
            stride: 4,
            best_cycles: 321,
            best_target: "systolic 4x4".into(),
            evaluated: 9_000,
            pruned_infeasible: 100,
            pruned_bound: 1_100,
            pruned_dominated: 40,
            simulated: 123,
            cache_hits: 8_877,
            failed: 2,
            frontier: vec![point(3, 321), point(9, 400)],
            samples: vec![point(12, 999)],
        }
    }

    #[test]
    fn checkpoint_roundtrips_through_json() {
        let ck = checkpoint();
        let back = Checkpoint::from_json(&Json::parse(&ck.to_json().to_string()).unwrap()).unwrap();
        // The 64-bit signature survives (it travels as a hex string).
        assert_eq!(back.signature, ck.signature);
        assert_eq!(back.cursor, ck.cursor);
        assert_eq!(back.stride, ck.stride);
        assert_eq!(back.best_cycles, ck.best_cycles);
        assert_eq!(back.best_target, ck.best_target);
        assert_eq!(back.evaluated, ck.evaluated);
        assert_eq!(back.pruned_bound, ck.pruned_bound);
        assert_eq!(back.frontier.len(), 2);
        assert_eq!(back.samples.len(), 1);
        assert_eq!(back.frontier[0].spec, ck.frontier[0].spec);
        assert_eq!(back.frontier[0].result, ck.frontier[0].result);
        assert_eq!(back.frontier[0].cached, ck.frontier[0].cached);
    }

    #[test]
    fn empty_incumbent_serializes_as_null() {
        let mut ck = checkpoint();
        ck.best_cycles = u64::MAX;
        let text = ck.to_json().to_string();
        assert!(text.contains("\"best_cycles\":null"), "{text}");
        let back = Checkpoint::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.best_cycles, u64::MAX);
    }

    #[test]
    fn save_and_load_are_atomic_siblings() {
        let path = std::env::temp_dir().join(format!(
            "acadl_ck_test_{}.json",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();
        let ck = checkpoint();
        ck.save(&path).unwrap();
        // No tmp residue after a successful write.
        assert!(!std::path::Path::new(&format!("{path}.tmp")).exists());
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.cursor, ck.cursor);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn truncated_checkpoint_is_rejected_with_a_clean_diagnostic() {
        let path = std::env::temp_dir().join(format!(
            "acadl_ck_trunc_{}.json",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();
        checkpoint().save(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        // Cut at several depths: mid-object, mid-points-array, mid-key.
        for cut in [text.len() / 2, text.len() - 2, 10, 0] {
            std::fs::write(&path, &text[..cut]).unwrap();
            let err = Checkpoint::load(&path).expect_err("truncated file must not load");
            assert!(err.contains(&path), "diagnostic names the file: {err}");
        }
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn flipped_bytes_are_rejected_not_resumed() {
        let path = std::env::temp_dir().join(format!(
            "acadl_ck_flip_{}.json",
            std::process::id()
        ));
        let path = path.to_str().unwrap().to_string();
        checkpoint().save(&path).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        // A non-UTF8 byte in the middle: the read/parse layer rejects it.
        let mut garbled = bytes.clone();
        garbled[bytes.len() / 2] = 0xFF;
        std::fs::write(&path, &garbled).unwrap();
        assert!(Checkpoint::load(&path).is_err(), "garbled byte must not load");
        // A *parseable* corruption — a counter digit flipped — is caught
        // by the cross-field consistency check instead of silently
        // resuming with broken accounting.
        let text = String::from_utf8(bytes).unwrap();
        let tampered = text.replace("\"evaluated\":9000", "\"evaluated\":9001");
        assert_ne!(tampered, text, "fixture drifted: evaluated counter not found");
        std::fs::write(&path, &tampered).unwrap();
        let err = Checkpoint::load(&path).expect_err("inconsistent counters must not load");
        assert!(err.contains("corrupt"), "{err}");
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn consistency_validation_catches_broken_invariants() {
        assert!(checkpoint().validate().is_ok());
        let mut ck = checkpoint();
        ck.cursor += 1; // evaluated + pruned no longer covers the cursor
        assert!(ck.validate().unwrap_err().contains("cursor"));
        let mut ck = checkpoint();
        ck.cache_hits += 3;
        assert!(ck.validate().unwrap_err().contains("cache hits"));
        let mut ck = checkpoint();
        ck.failed = ck.evaluated + 1;
        assert!(ck.validate().unwrap_err().contains("failed"));
        let mut ck = checkpoint();
        ck.stride = 6;
        assert!(ck.validate().unwrap_err().contains("stride"));
        let mut ck = checkpoint();
        ck.frontier[0].result.error = Some("boom".into());
        assert!(ck.validate().unwrap_err().contains("error row"));
    }

    #[test]
    fn version_and_signature_are_validated() {
        let mut ck = checkpoint();
        ck.version = 99;
        let err = Checkpoint::from_json(&Json::parse(&ck.to_json().to_string()).unwrap());
        assert!(err.is_err());
        assert!(err.unwrap_err().contains("version 99"), "wrong error");
    }
}
