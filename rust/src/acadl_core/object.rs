//! The ACADL class set (Fig. 1): twelve classes, two interfaces, one virtual
//! base class, modeled as [`ObjectKind`] variants plus `is_*` hierarchy
//! predicates.
//!
//! | Paper class                  | Here                              |
//! |------------------------------|-----------------------------------|
//! | `ACADLObject` (virtual base) | [`Object`] (`name` + kind)        |
//! | `PipelineStage`              | [`PipelineStage`]                 |
//! | `ExecuteStage`               | [`ExecuteStage`]                  |
//! | `InstructionFetchStage`      | [`InstructionFetchStage`]         |
//! | `FunctionalUnit`             | [`FunctionalUnit`]                |
//! | `MemoryAccessUnit`           | [`MemoryAccessUnit`]              |
//! | `InstructionMemoryAccessUnit`| [`InstructionMemoryAccessUnit`]   |
//! | `RegisterFile`               | [`RegisterFile`]                  |
//! | `DataStorage` (virtual)      | [`DataStorageParams`] (composed)  |
//! | `MemoryInterface` (iface)    | [`Sram`] / [`Dram`] share it      |
//! | `SRAM`, `DRAM`               | [`Sram`], [`Dram`]                |
//! | `CacheInterface` (iface)     | [`SetAssociativeCache`]           |
//! | `SetAssociativeCache`        | [`SetAssociativeCache`]           |
//!
//! `Data` and `Instruction` live in [`super::data`] and [`crate::isa`].

use std::collections::BTreeSet;

use crate::acadl_core::data::Data;
use crate::acadl_core::latency::Latency;
use crate::mem::cache::ReplacementPolicy;

/// Forwarding stage: holds an instruction for `latency` cycles, then
/// forwards it to a connected, ready `PipelineStage` (§3).
#[derive(Debug, Clone)]
pub struct PipelineStage {
    pub latency: Latency,
}

/// A `PipelineStage` that additionally contains `FunctionalUnit`s; when a
/// contained FU supports a received instruction, the stage hands it over
/// and its own latency is *not* accumulated (§3).
#[derive(Debug, Clone)]
pub struct ExecuteStage {
    pub latency: Latency,
}

/// Fetches instructions through its contained `InstructionMemoryAccessUnit`
/// into an issue buffer and forwards them — possibly several per cycle,
/// out-of-order — to ready pipeline stages (§3, Fig. 9).
#[derive(Debug, Clone)]
pub struct InstructionFetchStage {
    pub latency: Latency,
    /// Maximum instructions resident in the issue buffer; also the
    /// upper bound on instructions issued in one clock cycle.
    pub issue_buffer_size: usize,
}

/// Executes instructions whose `operation` is in `to_process`, taking
/// `latency` cycles once all data dependencies are resolved (§3).
#[derive(Debug, Clone)]
pub struct FunctionalUnit {
    /// Supported instruction mnemonics.
    pub to_process: BTreeSet<String>,
    pub latency: Latency,
}

/// A `FunctionalUnit` that accesses `RegisterFile`s *and* `DataStorage`s
/// (loads/stores) (§3).
#[derive(Debug, Clone)]
pub struct MemoryAccessUnit {
    pub to_process: BTreeSet<String>,
    pub latency: Latency,
}

/// A `MemoryAccessUnit` specialized for fetching instructions from the
/// instruction memory (`fetch(address, length)`) (§3).
#[derive(Debug, Clone)]
pub struct InstructionMemoryAccessUnit {
    pub latency: Latency,
}

/// Named registers with a fixed per-register width (§3).
#[derive(Debug, Clone)]
pub struct RegisterFile {
    /// Size of each register in bits.
    pub data_width: u32,
    /// Ordered (name, initial value) pairs; order defines dense indices.
    pub registers: Vec<(String, Data)>,
}

/// Attributes shared by every `DataStorage` (§3, virtual base).
#[derive(Debug, Clone)]
pub struct DataStorageParams {
    /// Bit-length of one data word.
    pub data_width: u32,
    /// Read/write requests that can be in flight simultaneously
    /// (one request slot each, Fig. 12–13).
    pub max_concurrent_requests: usize,
    /// How many `MemoryAccessUnit`s may connect.
    pub read_write_ports: usize,
    /// Data words transferred per transaction (>1 = wide port).
    pub port_width: usize,
}

impl Default for DataStorageParams {
    fn default() -> Self {
        DataStorageParams {
            data_width: 32,
            max_concurrent_requests: 1,
            read_write_ports: 1,
            port_width: 1,
        }
    }
}

/// On-chip scratchpad memory with flat read/write latencies
/// (`MemoryInterface` implementation).
#[derive(Debug, Clone)]
pub struct Sram {
    pub ds: DataStorageParams,
    pub read_latency: Latency,
    pub write_latency: Latency,
    /// Inclusive start, exclusive end byte addresses served.
    pub address_range: (u64, u64),
}

/// Off-chip DRAM with banked row-buffer timing (t_RCD / t_RP / t_RAS),
/// the paper's stateful-latency `DRAM` class. The timing state machine
/// itself lives in [`crate::mem::dram`].
#[derive(Debug, Clone)]
pub struct Dram {
    pub ds: DataStorageParams,
    pub address_range: (u64, u64),
    /// Number of banks; bank index = (addr / row_bytes) % banks.
    pub banks: usize,
    /// Row size in bytes (row-buffer granularity).
    pub row_bytes: u64,
    /// Activate-to-read/write delay (cycles).
    pub t_rcd: u64,
    /// Precharge delay (cycles).
    pub t_rp: u64,
    /// Minimum row-active time (cycles).
    pub t_ras: u64,
    /// Column access latency on a row hit (cycles).
    pub t_cas: u64,
}

/// Set-associative cache (`CacheInterface` + `SetAssociativeCache`).
/// The hit/miss state machine lives in [`crate::mem::cache`].
#[derive(Debug, Clone)]
pub struct SetAssociativeCache {
    pub ds: DataStorageParams,
    pub write_allocate: bool,
    pub write_back: bool,
    pub miss_latency: Latency,
    pub hit_latency: Latency,
    /// Cache line size in bytes.
    pub cache_line_size: u64,
    pub replacement_policy: ReplacementPolicy,
    pub sets: usize,
    pub ways: usize,
}

/// One modeled hardware element: the virtual `ACADLObject` base (unique
/// `name`) plus its concrete class.
#[derive(Debug, Clone)]
pub struct Object {
    pub name: String,
    pub kind: ObjectKind,
}

/// The concrete ACADL class of an [`Object`].
#[derive(Debug, Clone)]
pub enum ObjectKind {
    PipelineStage(PipelineStage),
    ExecuteStage(ExecuteStage),
    InstructionFetchStage(InstructionFetchStage),
    FunctionalUnit(FunctionalUnit),
    MemoryAccessUnit(MemoryAccessUnit),
    InstructionMemoryAccessUnit(InstructionMemoryAccessUnit),
    RegisterFile(RegisterFile),
    Sram(Sram),
    Dram(Dram),
    Cache(SetAssociativeCache),
}

impl ObjectKind {
    /// Class name as in the paper's Fig. 1 (diagnostics, error messages).
    pub fn class_name(&self) -> &'static str {
        match self {
            ObjectKind::PipelineStage(_) => "PipelineStage",
            ObjectKind::ExecuteStage(_) => "ExecuteStage",
            ObjectKind::InstructionFetchStage(_) => "InstructionFetchStage",
            ObjectKind::FunctionalUnit(_) => "FunctionalUnit",
            ObjectKind::MemoryAccessUnit(_) => "MemoryAccessUnit",
            ObjectKind::InstructionMemoryAccessUnit(_) => "InstructionMemoryAccessUnit",
            ObjectKind::RegisterFile(_) => "RegisterFile",
            ObjectKind::Sram(_) => "SRAM",
            ObjectKind::Dram(_) => "DRAM",
            ObjectKind::Cache(_) => "SetAssociativeCache",
        }
    }

    // ----- class-hierarchy predicates (Fig. 1 inheritance) -----

    /// `PipelineStage` or any subclass (`ExecuteStage`,
    /// `InstructionFetchStage`).
    pub fn is_pipeline_stage(&self) -> bool {
        matches!(
            self,
            ObjectKind::PipelineStage(_)
                | ObjectKind::ExecuteStage(_)
                | ObjectKind::InstructionFetchStage(_)
        )
    }

    /// `ExecuteStage` or its subclass `InstructionFetchStage`.
    pub fn is_execute_stage(&self) -> bool {
        matches!(
            self,
            ObjectKind::ExecuteStage(_) | ObjectKind::InstructionFetchStage(_)
        )
    }

    /// `FunctionalUnit` or any subclass (`MemoryAccessUnit`,
    /// `InstructionMemoryAccessUnit`).
    pub fn is_functional_unit(&self) -> bool {
        matches!(
            self,
            ObjectKind::FunctionalUnit(_)
                | ObjectKind::MemoryAccessUnit(_)
                | ObjectKind::InstructionMemoryAccessUnit(_)
        )
    }

    /// `MemoryAccessUnit` or its subclass.
    pub fn is_memory_access_unit(&self) -> bool {
        matches!(
            self,
            ObjectKind::MemoryAccessUnit(_) | ObjectKind::InstructionMemoryAccessUnit(_)
        )
    }

    /// Anything inheriting the virtual `DataStorage` base.
    pub fn is_data_storage(&self) -> bool {
        matches!(
            self,
            ObjectKind::Sram(_) | ObjectKind::Dram(_) | ObjectKind::Cache(_)
        )
    }

    /// Implements the `MemoryInterface` (address-range-bearing storages).
    pub fn is_memory_interface(&self) -> bool {
        matches!(self, ObjectKind::Sram(_) | ObjectKind::Dram(_))
    }

    pub fn is_cache(&self) -> bool {
        matches!(self, ObjectKind::Cache(_))
    }

    pub fn is_register_file(&self) -> bool {
        matches!(self, ObjectKind::RegisterFile(_))
    }

    /// Supported mnemonics, for FunctionalUnit-like classes.
    pub fn to_process(&self) -> Option<&BTreeSet<String>> {
        match self {
            ObjectKind::FunctionalUnit(f) => Some(&f.to_process),
            ObjectKind::MemoryAccessUnit(m) => Some(&m.to_process),
            _ => None,
        }
    }

    /// The `latency` attribute shared by most classes (§6: every object
    /// with `latency` gets a `t`/`ready` pair at simulation init).
    pub fn latency(&self) -> Option<&Latency> {
        match self {
            ObjectKind::PipelineStage(p) => Some(&p.latency),
            ObjectKind::ExecuteStage(e) => Some(&e.latency),
            ObjectKind::InstructionFetchStage(i) => Some(&i.latency),
            ObjectKind::FunctionalUnit(f) => Some(&f.latency),
            ObjectKind::MemoryAccessUnit(m) => Some(&m.latency),
            ObjectKind::InstructionMemoryAccessUnit(i) => Some(&i.latency),
            ObjectKind::RegisterFile(_) => None,
            ObjectKind::Sram(_) | ObjectKind::Dram(_) | ObjectKind::Cache(_) => None,
        }
    }

    /// Data-storage parameters, for DataStorage subclasses.
    pub fn storage_params(&self) -> Option<&DataStorageParams> {
        match self {
            ObjectKind::Sram(s) => Some(&s.ds),
            ObjectKind::Dram(d) => Some(&d.ds),
            ObjectKind::Cache(c) => Some(&c.ds),
            _ => None,
        }
    }

    /// Byte-address range served, for `MemoryInterface` implementors.
    pub fn address_range(&self) -> Option<(u64, u64)> {
        match self {
            ObjectKind::Sram(s) => Some(s.address_range),
            ObjectKind::Dram(d) => Some(d.address_range),
            _ => None,
        }
    }
}

impl Object {
    pub fn new(name: impl Into<String>, kind: ObjectKind) -> Self {
        Object {
            name: name.into(),
            kind,
        }
    }
}

/// Builder helpers mirroring the Python front-end constructors (Listing 1).
pub mod build {
    use super::*;

    pub fn pipeline_stage(name: &str, latency: u64) -> Object {
        Object::new(
            name,
            ObjectKind::PipelineStage(PipelineStage {
                latency: Latency::Const(latency),
            }),
        )
    }

    pub fn execute_stage(name: &str, latency: u64) -> Object {
        Object::new(
            name,
            ObjectKind::ExecuteStage(ExecuteStage {
                latency: Latency::Const(latency),
            }),
        )
    }

    pub fn fetch_stage(name: &str, latency: u64, issue_buffer_size: usize) -> Object {
        Object::new(
            name,
            ObjectKind::InstructionFetchStage(InstructionFetchStage {
                latency: Latency::Const(latency),
                issue_buffer_size,
            }),
        )
    }

    pub fn functional_unit(name: &str, ops: &[&str], latency: Latency) -> Object {
        Object::new(
            name,
            ObjectKind::FunctionalUnit(FunctionalUnit {
                to_process: ops.iter().map(|s| s.to_string()).collect(),
                latency,
            }),
        )
    }

    pub fn memory_access_unit(name: &str, ops: &[&str], latency: u64) -> Object {
        Object::new(
            name,
            ObjectKind::MemoryAccessUnit(MemoryAccessUnit {
                to_process: ops.iter().map(|s| s.to_string()).collect(),
                latency: Latency::Const(latency),
            }),
        )
    }

    pub fn instruction_memory_access_unit(name: &str, latency: u64) -> Object {
        Object::new(
            name,
            ObjectKind::InstructionMemoryAccessUnit(InstructionMemoryAccessUnit {
                latency: Latency::Const(latency),
            }),
        )
    }

    pub fn register_file(name: &str, data_width: u32, regs: Vec<(String, Data)>) -> Object {
        Object::new(
            name,
            ObjectKind::RegisterFile(RegisterFile {
                data_width,
                registers: regs,
            }),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hierarchy_predicates() {
        let ifs = build::fetch_stage("ifs0", 1, 4);
        assert!(ifs.kind.is_pipeline_stage());
        assert!(ifs.kind.is_execute_stage());
        assert!(!ifs.kind.is_functional_unit());

        let imau = build::instruction_memory_access_unit("imau0", 1);
        assert!(imau.kind.is_functional_unit());
        assert!(imau.kind.is_memory_access_unit());
        assert!(!imau.kind.is_pipeline_stage());

        let fu = build::functional_unit("fu0", &["add"], Latency::Const(1));
        assert!(fu.kind.is_functional_unit());
        assert!(!fu.kind.is_memory_access_unit());
    }

    #[test]
    fn to_process_and_latency() {
        let fu = build::functional_unit("fu0", &["mac", "add"], Latency::Const(2));
        let ops = fu.kind.to_process().unwrap();
        assert!(ops.contains("mac") && ops.contains("add"));
        assert_eq!(fu.kind.latency().unwrap().eval_const().unwrap(), 2);
        let rf = build::register_file("rf0", 32, vec![]);
        assert!(rf.kind.latency().is_none());
        assert!(rf.kind.to_process().is_none());
    }

    #[test]
    fn class_names_match_paper() {
        assert_eq!(
            build::fetch_stage("x", 1, 1).kind.class_name(),
            "InstructionFetchStage"
        );
        assert_eq!(
            build::instruction_memory_access_unit("x", 1).kind.class_name(),
            "InstructionMemoryAccessUnit"
        );
    }
}
