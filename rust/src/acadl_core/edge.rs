//! Typed edges between ACADL objects and the class-diagram validity rules.
//!
//! The Python front-end's `ACADLEdge(src, dst, TYPE)` (Listing 1) maps to
//! [`Edge`]; the implicit validity check performed by `@generate` maps to
//! [`check_edge`], which enforces exactly the association/composition arrows
//! of Fig. 1:
//!
//! * `FORWARD`    — PipelineStage → PipelineStage (`:forward()`/`:receive()`)
//! * `CONTAINS`   — ExecuteStage → FunctionalUnit (composition)
//! * `READ_DATA`  — RegisterFile → FunctionalUnit (`:read()`),
//!                  DataStorage → MemoryAccessUnit (memory reads, incl. the
//!                  instruction memory → InstructionMemoryAccessUnit fetch
//!                  path), DataStorage → DataStorage (backing store → cache)
//! * `WRITE_DATA` — FunctionalUnit → RegisterFile (`:write()`),
//!                  MemoryAccessUnit → DataStorage,
//!                  DataStorage → DataStorage (cache → backing store)

use std::fmt;

use thiserror::Error;

use crate::acadl_core::graph::ObjId;
use crate::acadl_core::object::ObjectKind;

/// The four ACADL edge types used by the paper's listings.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EdgeKind {
    /// Instruction forwarding between pipeline stages.
    Forward,
    /// Composition: an execute stage contains a functional unit.
    Contains,
    /// Data flows from `src` when `dst` reads.
    ReadData,
    /// `src` writes data into `dst`.
    WriteData,
}

impl fmt::Display for EdgeKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let s = match self {
            EdgeKind::Forward => "FORWARD",
            EdgeKind::Contains => "CONTAINS",
            EdgeKind::ReadData => "READ_DATA",
            EdgeKind::WriteData => "WRITE_DATA",
        };
        f.write_str(s)
    }
}

/// A directed, typed edge of the architecture graph.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Edge {
    pub src: ObjId,
    pub dst: ObjId,
    pub kind: EdgeKind,
}

#[derive(Debug, Error, Clone, PartialEq, Eq)]
#[error("invalid {kind} edge: {src_class}(`{src_name}`) -> {dst_class}(`{dst_name}`)")]
pub struct EdgeError {
    pub kind: EdgeKind,
    pub src_class: &'static str,
    pub src_name: String,
    pub dst_class: &'static str,
    pub dst_name: String,
}

/// Is `src --kind--> dst` permitted by the Fig. 1 class diagram?
pub fn edge_allowed(kind: EdgeKind, src: &ObjectKind, dst: &ObjectKind) -> bool {
    match kind {
        EdgeKind::Forward => src.is_pipeline_stage() && dst.is_pipeline_stage(),
        EdgeKind::Contains => src.is_execute_stage() && dst.is_functional_unit(),
        EdgeKind::ReadData => {
            // RegisterFile -> FunctionalUnit-like: operand reads.
            (src.is_register_file() && dst.is_functional_unit())
                // DataStorage -> MemoryAccessUnit-like: loads / ifetch.
                || (src.is_data_storage() && dst.is_memory_access_unit())
                // DataStorage -> DataStorage: backing memory feeds a cache.
                || (src.is_data_storage() && dst.is_data_storage())
        }
        EdgeKind::WriteData => {
            // FunctionalUnit-like -> RegisterFile: result writeback
            // (includes InstructionMemoryAccessUnit -> pc RegisterFile).
            (src.is_functional_unit() && dst.is_register_file())
                // MemoryAccessUnit-like -> DataStorage: stores.
                || (src.is_memory_access_unit() && dst.is_data_storage())
                // DataStorage -> DataStorage: cache evicts to backing store.
                || (src.is_data_storage() && dst.is_data_storage())
        }
    }
}

/// Validate one edge, with class/name context for error messages.
pub fn check_edge(
    kind: EdgeKind,
    src: (&str, &ObjectKind),
    dst: (&str, &ObjectKind),
) -> Result<(), EdgeError> {
    if edge_allowed(kind, src.1, dst.1) {
        Ok(())
    } else {
        Err(EdgeError {
            kind,
            src_class: src.1.class_name(),
            src_name: src.0.to_string(),
            dst_class: dst.1.class_name(),
            dst_name: dst.0.to_string(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::acadl_core::latency::Latency;
    use crate::acadl_core::object::build;

    fn kinds() -> Vec<ObjectKind> {
        vec![
            build::pipeline_stage("ps", 1).kind,
            build::execute_stage("ex", 1).kind,
            build::fetch_stage("ifs", 1, 4).kind,
            build::functional_unit("fu", &["add"], Latency::Const(1)).kind,
            build::memory_access_unit("mau", &["load"], 1).kind,
            build::instruction_memory_access_unit("imau", 1).kind,
            build::register_file("rf", 32, vec![]).kind,
            crate::arch::parts::sram("s", 0, 1024, 1, 1).kind,
            crate::arch::parts::dram_default("d", 0x1000, 0x10000).kind,
            crate::arch::parts::cache_default("c").kind,
        ]
    }

    /// Exhaustively compare `edge_allowed` against an independent statement
    /// of the Fig. 1 rules (E11 conformance; the proptest version lives in
    /// `rust/tests/`).
    #[test]
    fn exhaustive_rule_table() {
        for src in kinds() {
            for dst in kinds() {
                let fwd = src.is_pipeline_stage() && dst.is_pipeline_stage();
                assert_eq!(edge_allowed(EdgeKind::Forward, &src, &dst), fwd);

                let contains = src.is_execute_stage() && dst.is_functional_unit();
                assert_eq!(edge_allowed(EdgeKind::Contains, &src, &dst), contains);

                let rd = (src.is_register_file() && dst.is_functional_unit())
                    || (src.is_data_storage() && dst.is_memory_access_unit())
                    || (src.is_data_storage() && dst.is_data_storage());
                assert_eq!(edge_allowed(EdgeKind::ReadData, &src, &dst), rd);

                let wr = (src.is_functional_unit() && dst.is_register_file())
                    || (src.is_memory_access_unit() && dst.is_data_storage())
                    || (src.is_data_storage() && dst.is_data_storage());
                assert_eq!(edge_allowed(EdgeKind::WriteData, &src, &dst), wr);
            }
        }
    }

    #[test]
    fn listing1_edges_all_legal() {
        // Every edge from Listing 1 (OMA) must pass.
        let imem = crate::arch::parts::sram("imem0", 0, 4096, 1, 4).kind;
        let imau = build::instruction_memory_access_unit("imau0", 1).kind;
        let pcrf = build::register_file("pcrf0", 32, vec![]).kind;
        let ifs = build::fetch_stage("ifs0", 1, 4).kind;
        let ds = build::pipeline_stage("ds0", 1).kind;
        let ex = build::execute_stage("ex0", 1).kind;
        let fu = build::functional_unit("fu0", &["mov"], Latency::Const(1)).kind;
        let rf = build::register_file("rf0", 32, vec![]).kind;
        let mau = build::memory_access_unit("mau0", &["load", "store"], 1).kind;
        let dmem = crate::arch::parts::sram("dmem0", 0x1000, 0x11000, 2, 1).kind;
        let dcache = crate::arch::parts::cache_default("dcache0").kind;

        use EdgeKind::*;
        let table: Vec<(&ObjectKind, &ObjectKind, EdgeKind)> = vec![
            (&imem, &imau, ReadData),
            (&pcrf, &imau, ReadData),
            (&imau, &pcrf, WriteData),
            (&ifs, &imau, Contains),
            (&ifs, &ds, Forward),
            (&ds, &ex, Forward),
            (&ex, &fu, Contains),
            (&fu, &rf, WriteData),
            (&rf, &fu, ReadData),
            (&ex, &mau, Contains),
            (&mau, &rf, WriteData),
            (&rf, &mau, ReadData),
            (&mau, &dcache, WriteData),
            (&dcache, &mau, ReadData),
            (&dcache, &dmem, WriteData),
            (&dmem, &dcache, ReadData),
        ];
        for (src, dst, kind) in table {
            assert!(edge_allowed(kind, src, dst), "{kind} {src:?} -> {dst:?}");
        }
    }

    #[test]
    fn obvious_illegal_edges_rejected() {
        let rf = build::register_file("rf", 32, vec![]).kind;
        let ex = build::execute_stage("ex", 1).kind;
        let fu = build::functional_unit("fu", &[], Latency::Const(1)).kind;
        // RegisterFile cannot forward, contain, or receive READ_DATA from a FU.
        assert!(!edge_allowed(EdgeKind::Forward, &rf, &ex));
        assert!(!edge_allowed(EdgeKind::Contains, &rf, &fu));
        assert!(!edge_allowed(EdgeKind::ReadData, &fu, &rf));
        // FunctionalUnit cannot contain anything.
        assert!(!edge_allowed(EdgeKind::Contains, &fu, &fu));
        // PipelineStage (non-execute) cannot contain.
        let ps = build::pipeline_stage("ps", 1).kind;
        assert!(!edge_allowed(EdgeKind::Contains, &ps, &fu));
        let err = check_edge(EdgeKind::Contains, ("ps", &ps), ("fu", &fu)).unwrap_err();
        assert!(err.to_string().contains("CONTAINS"));
    }
}
